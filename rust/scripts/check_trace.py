#!/usr/bin/env python3
"""Check the repro binary's trace/series exports (stdlib only).

  check_trace.py validate TRACE.json
  check_trace.py compare A_TRACE B_TRACE A_SERIES B_SERIES

`validate` checks the Chrome trace-event schema that chrome://tracing
and Perfetto expect of a --trace export: a `traceEvents` array whose
records carry name/ph/pid/tid, complete slices ("X") carrying a
duration, flow records ("s"/"f") carrying a shared `machine:seq` id,
and every flow finish paired with a recorded flow start.

`compare` takes two recordings of the same seeded run and requires
everything driven by the virtual transport clock — event order, trace
contexts on the wire, timestamps, committed round statistics — to be
identical. Only the wall-clock span fields (slice `dur`, `args.dur_ns`,
the `*_ns` series columns) may differ between the two runs.
"""

import json
import sys

ALLOWED_PH = {"M", "X", "i", "s", "f"}
FLOW_WALLCLOCK_KEYS = ("dur",)


def fail(msg):
    sys.exit(f"check_trace: {msg}")


def load_events(path):
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        fail(f"{path}: no traceEvents array")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        fail(f"{path}: traceEvents empty or not an array")
    return doc, events


def validate(path):
    doc, events = load_events(path)
    if doc.get("displayTimeUnit") != "ms":
        fail(f"{path}: displayTimeUnit missing or not 'ms'")
    flow_starts, flow_finishes = set(), []
    counts = {ph: 0 for ph in ALLOWED_PH}
    for i, ev in enumerate(events):
        where = f"{path}: traceEvents[{i}]"
        if not isinstance(ev, dict):
            fail(f"{where}: not an object")
        ph = ev.get("ph")
        if ph not in ALLOWED_PH:
            fail(f"{where}: ph {ph!r} not in {sorted(ALLOWED_PH)}")
        counts[ph] += 1
        if not isinstance(ev.get("name"), str):
            fail(f"{where}: name missing or not a string")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), (int, float)):
                fail(f"{where}: {key} missing or not numeric")
        if ph != "M" and not isinstance(ev.get("ts"), (int, float)):
            fail(f"{where}: ts missing on a timed record")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur <= 0:
                fail(f"{where}: complete slice without a positive dur")
        if ph in ("s", "f"):
            fid = ev.get("id")
            if not isinstance(fid, str) or ":" not in fid:
                fail(f"{where}: flow record without a machine:seq id")
            if ph == "s":
                flow_starts.add(fid)
            else:
                flow_finishes.append((i, fid))
    for i, fid in flow_finishes:
        if fid not in flow_starts:
            fail(f"{path}: traceEvents[{i}]: flow finish {fid} has no start")
    for ph, label in (("X", "slice"), ("i", "commit instant"),
                      ("M", "track metadata")):
        if counts[ph] == 0:
            fail(f"{path}: no {label} records")
    print(f"check_trace: {path}: OK ({len(events)} events, "
          f"{counts['s']} flow starts, {counts['f']} flow finishes, "
          f"{counts['i']} commits)")


def canon_trace(path):
    """Events with the wall-clock-derived fields stripped."""
    _, events = load_events(path)
    out = []
    for ev in events:
        ev = dict(ev)
        for key in FLOW_WALLCLOCK_KEYS:
            ev.pop(key, None)
        args = ev.get("args")
        if isinstance(args, dict):
            args = dict(args)
            args.pop("dur_ns", None)
            ev["args"] = args
        out.append(ev)
    return out


def canon_series(path):
    """CSV rows with the *_ns (wall-clock span) columns dropped."""
    with open(path) as f:
        lines = [ln.rstrip("\n") for ln in f if ln.strip()]
    if not lines:
        fail(f"{path}: empty series CSV")
    header = lines[0].split(",")
    keep = [i for i, col in enumerate(header) if not col.endswith("_ns")]
    if len(keep) == len(header):
        fail(f"{path}: no *_ns columns in header (schema rot? update "
             "check_trace.py)")
    return [[row.split(",")[i] for i in keep] for row in lines]


def compare(trace_a, trace_b, series_a, series_b):
    a, b = canon_trace(trace_a), canon_trace(trace_b)
    if len(a) != len(b):
        fail(f"trace event counts differ: {trace_a} has {len(a)}, "
             f"{trace_b} has {len(b)}")
    for i, (ea, eb) in enumerate(zip(a, b)):
        if ea != eb:
            fail(f"traces diverge at traceEvents[{i}] (after stripping "
                 f"wall-clock fields):\n  {trace_a}: {json.dumps(ea)}\n"
                 f"  {trace_b}: {json.dumps(eb)}")
    sa, sb = canon_series(series_a), canon_series(series_b)
    if sa != sb:
        fail(f"series CSVs diverge (after dropping *_ns columns): "
             f"{series_a} vs {series_b}")
    print(f"check_trace: deterministic ({len(a)} trace events, "
          f"{len(sa) - 1} series rows agree across both runs)")


def main(argv):
    if len(argv) >= 3 and argv[1] == "validate":
        for path in argv[2:]:
            validate(path)
    elif len(argv) == 6 and argv[1] == "compare":
        compare(*argv[2:])
    else:
        sys.exit(__doc__.strip())


if __name__ == "__main__":
    main(sys.argv)
