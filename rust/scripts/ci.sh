#!/usr/bin/env bash
# Tier-1 verification plus bench smoke for the fadmm crate.
#
#   rust/scripts/ci.sh            # build + test + clippy + bench smoke
#   rust/scripts/ci.sh --no-bench # skip the bench smoke
#
# Everything runs offline: the default feature set has zero external
# dependencies (the xla backend is feature-gated).

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== kernel single-transcription grep gate =="
# The protocol kernel extraction (PR 5) holds only if the λ dual step and
# the Chan-style centered-statistics fold exist in exactly one place each:
# src/kernel/ (golden.rs keeps a frozen test-only copy by design) and
# src/metrics/. Any reappearance in a runtime is a re-transcription — the
# bug class the refactor removed.
gate_fail=0
# the dual step, in every spelling the repo has ever used: an indexed
# `+=` whose increment multiplies by a half-penalty (`0.5 * eta…`, any
# binding name — the pre-refactor engine called it `eta`, the runtimes
# `eta_bar`), plus the named-field forms
if grep -rn "\[k\] += .*0\.5 \* eta\|lambda\[k\] +=\|lambda\[k\]+=\|0\.5 \* eta_bar\|0\.5\*eta_bar" \
    src --include='*.rs' | grep -v "^src/kernel/"; then
  echo "grep gate: λ-update / dual-step transcription found outside src/kernel/" >&2
  gate_fail=1
fi
if grep -rn "centered_sq +=\|delta_sq" src --include='*.rs' \
    | grep -v "^src/metrics/"; then
  echo "grep gate: Chan-fold arithmetic outside src/metrics/" >&2
  gate_fail=1
fi
# pattern-rot guard: the canonical transcriptions must still match their
# own patterns, or the gate is silently vacuous
if ! grep -q "lambda\[k\] +=" src/kernel/node.rs; then
  echo "grep gate: kernel λ step no longer matches the gate pattern (update ci.sh)" >&2
  gate_fail=1
fi
if ! grep -q "centered_sq +=" src/metrics/mod.rs; then
  echo "grep gate: metrics Chan fold no longer matches the gate pattern (update ci.sh)" >&2
  gate_fail=1
fi
if [[ "$gate_fail" -ne 0 ]]; then
  exit 1
fi
echo "grep gate: OK (λ step only in kernel/, Chan fold only in metrics/)"

echo "== kernel golden-trace parity (pre-refactor Engine::step, bitwise) =="
cargo test -q --release kernel::golden

echo "== transport seam grep gate =="
# The Transport extraction (PR 7) holds only if the simulator stays one
# impl of the seam: the protocol layers (machine/node/collective) and
# the real backends (inproc/proc) must be simulator-blind. NetSim may
# appear in cluster::runner only because its sim-pinned constructor
# builds one — the protocol body is generic over T: Transport.
if ! grep -q "impl Transport for NetSim" src/net/transport.rs; then
  echo "transport gate: NetSim no longer implements the Transport seam" >&2
  exit 1
fi
if grep -rn "NetSim" src/cluster/machine.rs src/cluster/node.rs \
    src/cluster/collective.rs src/cluster/inproc.rs src/cluster/proc.rs; then
  echo "transport gate: protocol layer references the simulator concretely" >&2
  exit 1
fi
if ! grep -q "impl<S: LocalSolver + Send, T: Transport> ClusterRunner<S, T>" \
    src/cluster/runner.rs; then
  echo "transport gate: ClusterRunner protocol body is no longer generic over Transport (update ci.sh if the signature moved)" >&2
  exit 1
fi
echo "transport gate: OK (protocol layers are simulator-blind)"

echo "== obs timing-source grep gate =="
# The unified telemetry layer (PR 8) holds only if wall-clock reads in
# the protocol layers go through obs spans — src/obs owns the metric
# clock. Exceptions: net/transport.rs and cluster/proc.rs are the
# real-time transports (virtual-clock epoch, stdio routing deadlines)
# and read the wall clock for transport, not metric, purposes.
if grep -rn "Instant::now" \
    src/kernel src/consensus src/coordinator src/cluster src/net src/metrics \
    --include='*.rs' \
    | grep -v "^src/net/transport\.rs" \
    | grep -v "^src/cluster/proc\.rs"; then
  echo "obs gate: stray Instant::now in a protocol layer (time through crate::obs spans)" >&2
  exit 1
fi
# pattern-rot guard: the one sanctioned metric clock read (Span start)
# must still match, or the gate is silently vacuous
if ! grep -q "Instant::now" src/obs/registry.rs; then
  echo "obs gate: obs span clock read no longer matches the gate pattern (update ci.sh)" >&2
  exit 1
fi
echo "obs gate: OK (protocol layers read time only through obs spans)"

echo "== cross-transport parity (sim vs threads vs processes) =="
# The zero-fault contract: identical committed iteration counts on all
# three backends. The proc suite spawns real fadmm-node child processes
# and skips itself (with a stderr note) where children cannot spawn.
cargo test -q --release cluster::inproc
cargo test -q --release --test proc_transport

# clippy: warning-clean, modulo the two idioms this codebase uses on
# purpose (index-based math loops; wide arg lists in the actor plumbing)
if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy =="
  cargo clippy --all-targets -q -- \
    -D warnings \
    -A clippy::needless_range_loop \
    -A clippy::too_many_arguments \
    -A clippy::manual_memcpy \
    -A clippy::type_complexity \
    -A clippy::inherent_to_string \
    -A clippy::len_without_is_empty \
    -A clippy::new_without_default
else
  echo "(clippy not installed; skipping lint pass)"
fi

echo "== net_scenarios smoke matrix (small n, 3 seeds) =="
# the full loss × latency × churn matrix at toy size: exercises every
# scenario cell for every scheme end-to-end through the repro binary
net_dir="$(mktemp -d)"
cargo run --release --quiet --bin repro -- net \
  --nodes 8 --seeds 3 --max-iters 150 --out "$net_dir"
if [[ ! -f "$net_dir/net_scenarios.csv" ]]; then
  echo "net smoke: net_scenarios.csv missing" >&2
  exit 1
fi
# every (scenario × scheme) row present: 9 scenarios × 7 schemes + header
# (the stale3 triple: raw / damped / skip-λ-on-fallback)
net_rows="$(wc -l < "$net_dir/net_scenarios.csv")"
if [[ "$net_rows" -ne 64 ]]; then
  echo "net smoke: expected 64 csv lines (9 scenarios × 7 schemes + header), got $net_rows" >&2
  exit 1
fi
rm -rf "$net_dir"

echo "== cluster smoke matrix (2–4 machines × both collectives × 3 schemes) =="
# the hybrid runtime end to end through the repro binary: every
# (machines × scenario × collective × scheme) cell, seeded
cluster_dir="$(mktemp -d)"
cargo run --release --quiet --bin repro -- cluster \
  --nodes 12 --machines 2,4 --seeds 1 --max-iters 120 \
  --schemes admm,admm-rb,admm-nap --loss 0,0.1 --out "$cluster_dir"
if [[ ! -f "$cluster_dir/cluster_scenarios.csv" ]]; then
  echo "cluster smoke: cluster_scenarios.csv missing" >&2
  exit 1
fi
# 2 machine counts × 2 scenarios × 2 collectives × 3 schemes + header
cluster_rows="$(wc -l < "$cluster_dir/cluster_scenarios.csv")"
if [[ "$cluster_rows" -ne 25 ]]; then
  echo "cluster smoke: expected 25 csv lines (2×2×2×3 cells + header), got $cluster_rows" >&2
  exit 1
fi
# replay the shipped example FaultPlan through the net runtime (plan
# loader round-trips through the CLI path)
cargo run --release --quiet --bin repro -- net \
  --nodes 8 --seeds 1 --max-iters 100 --schemes admm \
  --plan ../examples/net_plan_loss_partition.json --out "$cluster_dir"
# the D-PPCA cluster cell (4 machines @ 10% loss, subspace-angle hook)
cargo run --release --quiet --bin repro -- cluster --dppca \
  --max-iters 120 --out "$cluster_dir"
if [[ ! -f "$cluster_dir/cluster_dppca.csv" ]]; then
  echo "cluster smoke: cluster_dppca.csv missing" >&2
  exit 1
fi
rm -rf "$cluster_dir"

echo "== trace determinism + schema gate =="
# Two recordings of the same seeded run must agree on everything the
# virtual transport clock drives: event order, trace contexts on the
# wire, timestamps, and committed round statistics. Only the wall-clock
# span fields (slice dur, args.dur_ns, the *_ns series columns) may
# differ. The checker also validates the Chrome trace-event schema so
# the export stays loadable in chrome://tracing / Perfetto.
if ! command -v python3 >/dev/null 2>&1; then
  echo "trace gate: python3 unavailable; skipping"
else
  trace_dir="$(mktemp -d)"
  for run in a b; do
    cargo run --release --quiet --bin repro -- cluster \
      --nodes 12 --machines 2 --seeds 1 --max-iters 80 \
      --schemes admm --loss 0.1 \
      --trace "$trace_dir/$run.trace.json" \
      --series "$trace_dir/$run.series.csv" \
      --out "$trace_dir/$run"
  done
  for side in "$trace_dir/a" "$trace_dir/b"; do
    for f in "$side.trace.json" "$side.trace.json.critical_path.json" \
             "$side.series.csv" "$side.series.csv.json"; do
      if [[ ! -f "$f" ]]; then
        echo "trace gate: expected output $f missing" >&2
        exit 1
      fi
    done
    # the armed sweep also interleaves series rows into its cell outputs
    if [[ ! -f "$side/cluster_series.csv" ]]; then
      echo "trace gate: $side/cluster_series.csv missing (sweep series rows)" >&2
      exit 1
    fi
  done
  python3 scripts/check_trace.py validate "$trace_dir/a.trace.json"
  python3 scripts/check_trace.py compare \
    "$trace_dir/a.trace.json" "$trace_dir/b.trace.json" \
    "$trace_dir/a.series.csv" "$trace_dir/b.series.csv"
  rm -rf "$trace_dir"
fi

if [[ "${1:-}" != "--no-bench" ]]; then
  echo "== bench smoke (FADMM_BENCH_FAST=1) =="
  # fast-mode numbers are noisy: keep the smoke's BENCH_*.json out of the
  # repo root so the committed perf trajectory only sees full-budget runs
  smoke_dir="$(mktemp -d)"
  FADMM_BENCH_FAST=1 FADMM_BENCH_DIR="$smoke_dir" \
    cargo bench --bench bench_coordinator
  FADMM_BENCH_FAST=1 FADMM_BENCH_DIR="$smoke_dir" \
    cargo bench --bench bench_node_update
  FADMM_BENCH_FAST=1 FADMM_BENCH_DIR="$smoke_dir" \
    cargo bench --bench bench_net
  if [[ ! -f "$smoke_dir/BENCH_net.json" ]]; then
    echo "bench smoke: bench_net wrote no BENCH_net.json" >&2
    exit 1
  fi
  FADMM_BENCH_FAST=1 FADMM_BENCH_DIR="$smoke_dir" \
    cargo bench --bench bench_cluster
  if [[ ! -f "$smoke_dir/BENCH_cluster.json" ]]; then
    echo "bench smoke: bench_cluster wrote no BENCH_cluster.json" >&2
    exit 1
  fi
  FADMM_BENCH_FAST=1 FADMM_BENCH_DIR="$smoke_dir" \
    cargo bench --bench bench_scale
  if [[ ! -f "$smoke_dir/BENCH_scale.json" ]]; then
    echo "bench smoke: bench_scale wrote no BENCH_scale.json" >&2
    exit 1
  fi

  # ---- scale memory gate ---------------------------------------------
  # The 1e4-ring smoke cell must stay inside the layout envelope: CSR
  # graph + padded f64 arena at dim 4 is ~150 bytes/node, gated at
  # FADMM_SCALE_GATE_BYTES (default 256 — headroom for Vec capacity
  # overshoot and per-shard padding on many-core machines), and the f32
  # parameter buffers must cost at most 0.55x the f64 ones (layout math
  # says exactly 0.5x; the slack covers only future metadata drift).
  # Machine-speed independent, so it holds for smoke runs too.
  echo "== scale memory gate =="
  if ! command -v python3 >/dev/null 2>&1; then
    echo "scale gate: python3 unavailable; skipping"
  else
    python3 - "$smoke_dir/BENCH_scale.json" \
              "${FADMM_SCALE_GATE_BYTES:-256}" <<'PY'
import json, sys

doc = json.load(open(sys.argv[1]))
max_bytes = float(sys.argv[2])
cells = doc.get("cells", [])
ring = next((c for c in cells
             if c.get("topology") == "ring" and c.get("nodes") == 10000), None)
if ring is None:
    sys.exit("scale gate: 1e4 ring cell missing from fresh BENCH_scale.json")
failures = []
b64, b32 = ring.get("bytes_per_node_f64"), ring.get("bytes_per_node_f32")
ratio = ring.get("f32_param_ratio")
if b64 is None or b64 > max_bytes:
    failures.append(f"bytes/node f64 {b64} > gate {max_bytes:.0f} "
                    "(FADMM_SCALE_GATE_BYTES)")
if b32 is None or b64 is None or b32 >= b64:
    failures.append(f"bytes/node f32 {b32} not below f64 {b64}")
if ratio is None or ratio > 0.55:
    failures.append(f"f32/f64 param ratio {ratio} > 0.55")
if ring.get("iters_per_sec_f64", 0) <= 0:
    failures.append("f64 cell recorded no throughput")
if failures:
    sys.exit("scale gate: " + "; ".join(failures))
print(f"scale gate: OK (1e4 ring: {b64:.1f} B/node f64, {b32:.1f} B/node f32, "
      f"param ratio {ratio:.3f})")
PY
  fi

  # ---- cluster baseline gate -----------------------------------------
  # Check the fresh bench_cluster scenario metrics against the committed
  # BENCH_cluster.json envelope: the clean_tree cells must cost exactly
  # the committed extra rounds vs the oracle (0 — the parity contract as
  # a number), and no cell may blow past the committed round bound.
  # Machine-speed independent, so it holds for smoke runs too.
  echo "== cluster baseline gate =="
  cluster_baseline="../BENCH_cluster.json"
  cluster_fresh="$smoke_dir/BENCH_cluster.json"
  if [[ ! -f "$cluster_baseline" ]]; then
    echo "cluster gate: no committed BENCH_cluster.json baseline; skipping"
  elif ! command -v python3 >/dev/null 2>&1; then
    echo "cluster gate: python3 unavailable; skipping"
  else
    python3 - "$cluster_baseline" "$cluster_fresh" <<'PY'
import json, sys

base = json.load(open(sys.argv[1]))
fresh = json.load(open(sys.argv[2]))
env = base.get("scenario", {}).get("envelope", {})
want_extra = env.get("clean_tree_extra_rounds", 0)
max_rounds = env.get("max_rounds_any_cell")
failures = []
cells = fresh.get("scenario", {})
for key, cell in cells.items():
    if not isinstance(cell, dict) or "rounds" not in cell:
        continue
    if key.startswith("clean_tree_") and cell.get("extra_rounds") != want_extra:
        failures.append(f"{key}: extra_rounds {cell.get('extra_rounds')} != {want_extra}")
    if max_rounds is not None and cell["rounds"] > max_rounds:
        failures.append(f"{key}: rounds {cell['rounds']} > envelope {max_rounds}")
if failures:
    sys.exit("cluster gate: " + "; ".join(failures))
print(f"cluster gate: OK ({len(cells)} cells)")
PY
  fi

  # ---- pool gates ----------------------------------------------------
  # Two machine-speed-tolerant checks on the persistent worker pool:
  #  * overlap win (cluster): the fresh pool_ns_per_iter cell may not
  #    exceed scoped_ns_per_iter by more than FADMM_POOL_GATE_FACTOR
  #    (default 1.5 — smoke numbers are noisy; the committed envelope and
  #    full-budget runs hold pool <= scoped), and the latency cells must
  #    actually have overlapped (overlap_dispatches > 0);
  #  * spawn amortization (coordinator): thread spawns per runner must be
  #    O(workers), not O(runs x workers) — the scoped baseline count
  #    doubles as the pattern-rot guard for the instrumentation.
  echo "== pool gates (overlap win + spawn amortization) =="
  if ! command -v python3 >/dev/null 2>&1; then
    echo "pool gates: python3 unavailable; skipping"
  else
    python3 - "$smoke_dir/BENCH_cluster.json" \
              "$smoke_dir/BENCH_coordinator.json" \
              "${FADMM_POOL_GATE_FACTOR:-1.5}" <<'PY'
import json, sys

cluster = json.load(open(sys.argv[1]))
coord = json.load(open(sys.argv[2]))
factor = float(sys.argv[3])
failures = []

cpool = cluster.get("pool", {})
for key in ("dim_3", "dim_32"):
    cell = cpool.get(key)
    if not isinstance(cell, dict):
        failures.append(f"cluster pool.{key}: cell missing from fresh JSON")
        continue
    p, s = cell.get("pool_ns_per_iter"), cell.get("scoped_ns_per_iter")
    if p is None or s is None or s <= 0:
        failures.append(f"cluster pool.{key}: ns/iter fields missing")
        continue
    print(f"pool gate: cluster {key}: pool {p:.0f}ns/iter vs scoped {s:.0f}ns/iter "
          f"(x{p / s:.2f})")
    if p > s * factor:
        failures.append(f"cluster pool.{key}: pool {p:.0f}ns > {factor} x scoped {s:.0f}ns")
    if cell.get("overlap_dispatches", 0) <= 0:
        failures.append(f"cluster pool.{key}: no interior overlap dispatched")

kpool = coord.get("pool", {})
workers = kpool.get("workers")
runs = kpool.get("spawn_runs")
for key in ("dim_3", "dim_32"):
    cell = kpool.get(key)
    if not isinstance(cell, dict) or workers is None or runs is None:
        failures.append(f"coordinator pool.{key}: spawn cell missing from fresh JSON")
        continue
    pooled, scoped = cell.get("threads_spawned_pool"), cell.get("threads_spawned_scoped")
    if pooled is not None and scoped is not None:
        print(f"pool gate: coordinator {key}: spawns over {runs:.0f} runs: "
              f"pool {pooled:.0f}, scoped {scoped:.0f} ({workers:.0f} workers)")
    if pooled is None or pooled > workers:
        failures.append(f"coordinator pool.{key}: pool spawned {pooled} threads, "
                        f"want <= {workers:.0f} per runner")
    if scoped is None or scoped != runs * workers:
        failures.append(f"coordinator pool.{key}: scoped spawn count {scoped} != "
                        f"runs x workers {runs * workers:.0f} (instrumentation rot?)")

if failures:
    sys.exit("pool gates: " + "; ".join(failures))
print("pool gates: OK")
PY
  fi

  # ---- obs overhead gate ---------------------------------------------
  # The instrumented sharded run may not cost more than FADMM_OBS_GATE_PCT
  # percent (default 2) over the identical obs-off run, and an obs-on
  # steady-state iteration must stay allocation-free — the same bound
  # holds with the timeline + series recorders armed (the bench's
  # timeline cell). All numbers come from the fresh BENCH_coordinator.json;
  # the bench itself asserts the zero-alloc claims at runtime, so the
  # JSON checks double as the instrumentation-rot guard. Fast-mode
  # numbers are noisy — raise the env knob on shared machines, tighten
  # for full-budget runs.
  echo "== obs overhead gate =="
  if ! command -v python3 >/dev/null 2>&1; then
    echo "obs overhead gate: python3 unavailable; skipping"
  else
    python3 - "$smoke_dir/BENCH_coordinator.json" \
              "${FADMM_OBS_GATE_PCT:-2}" <<'PY'
import json, sys

coord = json.load(open(sys.argv[1]))
pct = float(sys.argv[2])
cell = coord.get("obs")
if not isinstance(cell, dict):
    sys.exit("obs overhead gate: obs cell missing from fresh BENCH_coordinator.json "
             "(instrumentation rot?)")
failures = []
allocs = cell.get("steady_state_allocs_per_iter_obs_on")
if allocs != 0:
    failures.append(f"obs-on steady state allocates ({allocs} per iter, want 0)")
overhead = cell.get("overhead_pct")
if overhead is None:
    failures.append("overhead_pct field missing")
else:
    print(f"obs overhead gate: instrumented run {overhead:+.2f}% vs baseline "
          f"(gate {pct:.0f}%)")
    if overhead > pct:
        failures.append(f"obs overhead {overhead:.2f}% > gate {pct:.0f}% "
                        "(FADMM_OBS_GATE_PCT)")
tl = coord.get("timeline")
if not isinstance(tl, dict):
    failures.append("timeline cell missing from fresh BENCH_coordinator.json "
                    "(instrumentation rot?)")
else:
    tl_allocs = tl.get("steady_state_allocs_per_iter_recording_on")
    if tl_allocs != 0:
        failures.append(f"recording-on steady state allocates ({tl_allocs} "
                        "per iter, want 0)")
    if tl.get("events_in_8_iter_run", 0) <= 0:
        failures.append("timeline recorded no events")
    if tl.get("series_rows_in_8_iter_run", 0) <= 0:
        failures.append("series recorded no rows")
    else:
        print("obs overhead gate: timeline+series recording steady state "
              f"allocation-free ({tl['events_in_8_iter_run']:.0f} events, "
              f"{tl['series_rows_in_8_iter_run']:.0f} rows in probe run)")
if failures:
    sys.exit("obs overhead gate: " + "; ".join(failures))
print("obs overhead gate: OK")
PY
  fi

  # ---- bench regression gate -----------------------------------------
  # Compare the freshly measured per-iteration coordination overhead
  # against the committed BENCH_coordinator.json at the repo root. Fails
  # when the fresh overhead regresses by more than FADMM_BENCH_GATE_PCT
  # percent (default 50 — fast-mode smoke numbers are noisy; tighten for
  # full-budget runs). Skips gracefully when there is no committed
  # baseline, no fresh JSON, or no python3.
  echo "== bench regression gate =="
  baseline="../BENCH_coordinator.json"
  fresh="$smoke_dir/BENCH_coordinator.json"
  if [[ ! -f "$baseline" ]]; then
    echo "bench gate: no committed BENCH_coordinator.json baseline; skipping"
  elif [[ ! -f "$fresh" ]]; then
    echo "bench gate: bench wrote no fresh JSON; skipping"
  elif ! command -v python3 >/dev/null 2>&1; then
    echo "bench gate: python3 unavailable; skipping"
  else
    python3 - "$baseline" "$fresh" "${FADMM_BENCH_GATE_PCT:-50}" <<'PY'
import json, sys

base = json.load(open(sys.argv[1]))
fresh = json.load(open(sys.argv[2]))
pct = float(sys.argv[3])

def overhead(doc, key):
    try:
        v = doc["scale"][key]["coordination_overhead_sharded_ns_per_iter"]
        return float(v)
    except (KeyError, TypeError, ValueError):
        return None

failures = []
for key in ("ring_256", "ring_1024"):
    b, f = overhead(base, key), overhead(fresh, key)
    if b is None or f is None:
        print(f"bench gate: {key}: overhead field missing (skipping entry)")
        continue
    if b <= 0:
        print(f"bench gate: {key}: baseline overhead {b:.0f}ns <= 0 (skipping entry)")
        continue
    delta = (f - b) / b * 100.0
    print(f"bench gate: {key}: overhead/iter {f:.0f}ns vs baseline {b:.0f}ns "
          f"({delta:+.1f}%)")
    if delta > pct:
        failures.append(key)
if failures:
    sys.exit(f"bench gate: regression above {pct:.0f}% on: {', '.join(failures)}")
print("bench gate: OK")
PY
  fi
  rm -rf "$smoke_dir"
fi

echo "== ci.sh: all green =="
