#!/usr/bin/env bash
# Tier-1 verification plus bench smoke for the fadmm crate.
#
#   rust/scripts/ci.sh            # build + test + clippy + bench smoke
#   rust/scripts/ci.sh --no-bench # skip the bench smoke
#
# Everything runs offline: the default feature set has zero external
# dependencies (the xla backend is feature-gated).

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

# clippy: warning-clean, modulo the two idioms this codebase uses on
# purpose (index-based math loops; wide arg lists in the actor plumbing)
if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy =="
  cargo clippy --all-targets -q -- \
    -D warnings \
    -A clippy::needless_range_loop \
    -A clippy::too_many_arguments \
    -A clippy::manual_memcpy \
    -A clippy::type_complexity \
    -A clippy::inherent_to_string \
    -A clippy::len_without_is_empty \
    -A clippy::new_without_default
else
  echo "(clippy not installed; skipping lint pass)"
fi

if [[ "${1:-}" != "--no-bench" ]]; then
  echo "== bench smoke (FADMM_BENCH_FAST=1) =="
  # fast-mode numbers are noisy: keep the smoke's BENCH_*.json out of the
  # repo root so the committed perf trajectory only sees full-budget runs
  smoke_dir="$(mktemp -d)"
  FADMM_BENCH_FAST=1 FADMM_BENCH_DIR="$smoke_dir" \
    cargo bench --bench bench_coordinator
  FADMM_BENCH_FAST=1 FADMM_BENCH_DIR="$smoke_dir" \
    cargo bench --bench bench_node_update
  rm -rf "$smoke_dir"
fi

echo "== ci.sh: all green =="
