#!/usr/bin/env bash
# Refresh the committed bench baselines from a full-budget run.
#
#   rust/scripts/bench_baseline.sh            # coordinator (the gated one)
#   rust/scripts/bench_baseline.sh --all      # + net + cluster
#
# Run this on a quiet machine (no other load): the ci.sh regression gate
# compares every future smoke run against the numbers written here. The
# full budget (no FADMM_BENCH_FAST) writes BENCH_<target>.json at the
# repo root, replacing any provisional envelope baseline.
#
# Both gated targets now carry persistent-pool cells: bench_coordinator
# reports spawn amortization (threads spawned per runner vs per run) and
# bench_cluster reports the overlap win (pool vs scoped ns/iter under
# link latency). Refresh with --all so the committed BENCH_cluster.json
# pool envelope tracks measured numbers, not the provisional bound.
#
# --all also runs bench_scale at its default tier (1e4 + 1e5, ring +
# power-law, both precisions). Set FADMM_BENCH_SCALE_FULL=1 first to
# include the 1e6 cells (minutes of wall time, gigabyte-scale RSS) when
# refreshing the committed BENCH_scale.json envelope — the ci.sh scale
# memory gate only reads the 1e4 ring cell, which every tier includes.

set -euo pipefail
cd "$(dirname "$0")/.."

echo "== full-budget bench_coordinator (writes ../BENCH_coordinator.json) =="
cargo bench --bench bench_coordinator

if [[ "${1:-}" == "--all" ]]; then
  echo "== full-budget bench_net (writes ../BENCH_net.json) =="
  cargo bench --bench bench_net
  echo "== full-budget bench_cluster (writes ../BENCH_cluster.json) =="
  cargo bench --bench bench_cluster
  echo "== full-budget bench_scale (writes ../BENCH_scale.json) =="
  cargo bench --bench bench_scale
fi

echo "baseline refreshed; commit the updated BENCH_*.json"
