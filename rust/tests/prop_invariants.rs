//! Repo-wide property tests (in-repo harness; proptest unavailable
//! offline — failures report the replayable seed).

use fadmm::consensus::solvers::QuadraticNode;
use fadmm::consensus::{Engine, EngineConfig};
use fadmm::dppca::{em, PpcaParams};
use fadmm::graph::{random_connected, Topology};
use fadmm::linalg::{max_principal_angle_deg, qr_thin, Mat, Svd};
use fadmm::penalty::{make_scheme, NodeObservation, SchemeKind, SchemeParams};
use fadmm::util::prop;
use fadmm::util::rng::Pcg;

#[test]
fn svd_of_any_matrix_reconstructs() {
    prop::check_named("SVD reconstruction across aspect ratios", 40, |rng| {
        let m = 1 + rng.below(20);
        let n = 1 + rng.below(20);
        let scale = 10f64.powf(rng.range(-3.0, 3.0));
        let a = Mat::randn(m, n, rng).scale(scale);
        let svd = Svd::new(&a).unwrap();
        let rec = svd.low_rank(m.min(n));
        assert!(rec.max_abs_diff(&a) < 1e-9 * scale.max(1.0),
                "m={m} n={n} scale={scale}");
    });
}

#[test]
fn principal_angle_triangle_like_bound() {
    // θ(A,C) ≤ θ(A,B) + θ(B,C) for 1-dim subspaces
    prop::check_named("angle triangle inequality (lines)", 40, |rng| {
        let d = 3 + rng.below(8);
        let a = Mat::randn(d, 1, rng);
        let b = Mat::randn(d, 1, rng);
        let c = Mat::randn(d, 1, rng);
        let ab = max_principal_angle_deg(&a, &b).unwrap();
        let bc = max_principal_angle_deg(&b, &c).unwrap();
        let ac = max_principal_angle_deg(&a, &c).unwrap();
        assert!(ac <= ab + bc + 1e-7, "{ac} > {ab} + {bc}");
    });
}

#[test]
fn graph_builders_satisfy_handshake() {
    prop::check_named("Σ degrees = 2·|E| on all builders", 30, |rng| {
        let n = 4 + rng.below(20);
        for t in [Topology::Complete, Topology::Ring, Topology::Chain,
                  Topology::Star, Topology::Cluster] {
            let g = t.build(n).unwrap();
            let total: usize = (0..n).map(|i| g.degree(i)).sum();
            assert_eq!(total, 2 * g.edge_count(), "{t:?}");
        }
        let g = random_connected(n, rng.range(0.1, 0.9), rng).unwrap();
        let total: usize = (0..n).map(|i| g.degree(i)).sum();
        assert_eq!(total, 2 * g.edge_count());
    });
}

#[test]
fn penalty_schemes_never_produce_invalid_eta() {
    prop::check_named("η finite & positive under adversarial streams", 24, |rng| {
        let p = SchemeParams {
            eta0: 10f64.powf(rng.range(-1.0, 2.0)),
            ..Default::default()
        };
        let deg = 1 + rng.below(5);
        for kind in SchemeKind::ALL {
            let mut scheme = make_scheme(kind, p, deg);
            let mut eta = vec![p.eta0; deg];
            let mut f_nb = vec![0.0; deg];
            for t in 0..80 {
                for f in f_nb.iter_mut() {
                    // adversarial: occasionally non-finite neighbour objectives
                    *f = if rng.f64() < 0.05 { f64::NAN } else { rng.range(-1e6, 1e6) };
                }
                let obs = NodeObservation {
                    t,
                    primal_norm: rng.range(0.0, 1e3),
                    dual_norm: rng.range(0.0, 1e3),
                    global_primal: rng.range(0.0, 1e3),
                    global_dual: rng.range(0.0, 1e3),
                    f_self: rng.range(-1e6, 1e6),
                    f_self_prev: rng.range(-1e6, 1e6),
                    f_neighbors: &f_nb,
                    live: None,
                };
                scheme.update(&obs, &mut eta);
                for &e in &eta {
                    assert!(e.is_finite() && e > 0.0, "{kind:?} η = {e}");
                }
            }
        }
    });
}

#[test]
fn ppca_node_update_preserves_feasibility() {
    prop::check_named("a⁺ > 0, W⁺ finite for random consensus inputs", 24, |rng| {
        let d = 2 + rng.below(8);
        let m = 1 + rng.below(d.min(3));
        let n = m + 2 + rng.below(10);
        let x = Mat::randn(d, n, rng);
        let mom = em::moments(&x, &vec![1.0; n]);
        let params = PpcaParams {
            w: Mat::randn(d, m, rng),
            mu: rng.normal_vec(d),
            a: rng.range(0.05, 20.0),
        };
        let mult = PpcaParams {
            w: Mat::randn(d, m, rng).scale(0.1),
            mu: rng.normal_vec(d).iter().map(|v| 0.1 * v).collect(),
            a: rng.range(-0.5, 0.5),
        };
        let eta_sum = rng.range(0.1, 100.0);
        let target = PpcaParams {
            w: Mat::randn(d, m, rng),
            mu: rng.normal_vec(d),
            a: rng.range(0.05, 20.0),
        };
        let eta_w = PpcaParams {
            w: (&params.w + &target.w).scale(eta_sum),
            mu: params.mu.iter().zip(&target.mu).map(|(a, b)| eta_sum * (a + b)).collect(),
            a: eta_sum * (params.a + target.a),
        };
        let (p_new, nll) = em::node_update(&mom, &params, &mult, eta_sum, &eta_w).unwrap();
        assert!(p_new.a > 0.0 && p_new.a.is_finite());
        assert!(p_new.w.is_finite());
        assert!(nll.is_finite());
    });
}

#[test]
fn consensus_engine_invariance_to_node_relabeling() {
    // permuting node identities (on a symmetric topology) permutes the
    // solution but preserves the consensus value
    prop::check_named("relabeling invariance (complete graph)", 8, |rng| {
        let n = 4 + rng.below(4);
        let seed = rng.next_u64();
        let build = |perm: &[usize]| {
            let mut base_rng = Pcg::seed(seed);
            let mut nodes: Vec<QuadraticNode> =
                (0..n).map(|_| QuadraticNode::random(2, &mut base_rng)).collect();
            let mut permuted: Vec<Option<QuadraticNode>> =
                nodes.drain(..).map(Some).collect();
            let reordered: Vec<QuadraticNode> =
                perm.iter().map(|&i| permuted[i].take().unwrap()).collect();
            let mut engine = Engine::new(Topology::Complete.build(n).unwrap(),
                                         reordered, EngineConfig {
                                             scheme: SchemeKind::Fixed,
                                             tol: 1e-12,
                                             max_iters: 600,
                                             seed: 9,
                                             ..Default::default()
                                         });
            let report = engine.run();
            // consensus mean parameter
            let dim = report.thetas[0].len();
            (0..dim)
                .map(|k| report.thetas.iter().map(|t| t[k]).sum::<f64>() / n as f64)
                .collect::<Vec<f64>>()
        };
        let id: Vec<usize> = (0..n).collect();
        let mut shuffled = id.clone();
        rng.shuffle(&mut shuffled);
        let a = build(&id);
        let b = build(&shuffled);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    });
}

#[test]
fn qr_handles_scaled_bases() {
    prop::check_named("QR across magnitudes", 30, |rng| {
        let d = 4 + rng.below(12);
        let k = 1 + rng.below(3);
        let scale = 10f64.powf(rng.range(-6.0, 6.0));
        let a = Mat::randn(d, k, rng).scale(scale);
        let (q, r) = qr_thin(&a).unwrap();
        assert!(q.matmul(&r).max_abs_diff(&a) < 1e-8 * scale.max(1.0));
    });
}
