//! D-PPCA end-to-end on the native backend: distributed vs centralized
//! consistency, SfM accuracy, scheme orderings from the paper.

use fadmm::data::turntable::TurntableSpec;
use fadmm::data::{even_split, SubspaceSpec};
use fadmm::dppca::{centralized_em, InitStrategy};
use fadmm::experiments::common::{run_dppca, BackendChoice, DppcaSpec};
use fadmm::graph::Topology;
use fadmm::linalg::{max_principal_angle_deg, Mat};
use fadmm::penalty::SchemeKind;
use fadmm::sfm;
use fadmm::util::rng::Pcg;

fn synthetic_blocks(j: usize) -> (Vec<Mat>, usize, Mat) {
    let data = SubspaceSpec::default().generate(&mut Pcg::seed(7));
    let part = even_split(500, j);
    let blocks = part
        .ranges
        .iter()
        .map(|&(lo, hi)| data.x.col_slice(lo, hi))
        .collect();
    (blocks, part.padded, data.w_true)
}

#[test]
fn distributed_matches_centralized_subspace() {
    let data = SubspaceSpec::default().generate(&mut Pcg::seed(7));
    let central = centralized_em(&data.x, 5, 1e-10, 3000, &mut Pcg::seed(1)).unwrap();

    let (blocks, padded, _) = synthetic_blocks(12);
    let mut spec = DppcaSpec::new(blocks, padded, 5,
                                  Topology::Complete.build(12).unwrap(),
                                  SchemeKind::Fixed);
    spec.max_iters = 600;
    spec.tol = 1e-6;
    let result = run_dppca(&spec, BackendChoice::Native.build().unwrap()).unwrap();

    for p in &result.params {
        let angle = max_principal_angle_deg(&p.w, &central.params.w).unwrap();
        assert!(angle < 3.0, "node vs centralized subspace: {angle}°");
        assert!((p.a - central.params.a).abs() / central.params.a < 0.2,
                "precision {} vs {}", p.a, central.params.a);
    }
}

#[test]
fn all_schemes_recover_synthetic_subspace() {
    for scheme in SchemeKind::PAPER {
        let (blocks, padded, w_true) = synthetic_blocks(12);
        let mut spec = DppcaSpec::new(blocks, padded, 5,
                                      Topology::Complete.build(12).unwrap(), scheme);
        spec.max_iters = 400;
        spec.reference = Some(&w_true);
        let result = run_dppca(&spec, BackendChoice::Native.build().unwrap()).unwrap();
        assert!(result.final_angle < 8.0,
                "{scheme:?}: final angle {}", result.final_angle);
    }
}

#[test]
fn sfm_all_schemes_on_complete_graph() {
    let object = TurntableSpec::default().generate("BoxStuff", 3);
    let data = sfm::ppca_input(&object.measurements);
    let (baseline, _) = sfm::svd_structure(&object.measurements).unwrap();
    let blocks = sfm::split_frames(&data, object.frames, 5);
    for scheme in [SchemeKind::Fixed, SchemeKind::Vp, SchemeKind::Nap] {
        let mut spec = DppcaSpec::new(blocks.clone(), 12, 3,
                                      Topology::Complete.build(5).unwrap(), scheme);
        spec.max_iters = 400;
        spec.init = InitStrategy::LocalPca;
        spec.reference = Some(&baseline);
        let result = run_dppca(&spec, BackendChoice::Native.build().unwrap()).unwrap();
        // single-seed runs stop at the paper criterion, which can leave a
        // mid-teens residual angle; the figure runs take medians over seeds
        assert!(result.final_angle < 25.0,
                "{scheme:?}: {}°", result.final_angle);
    }
}

#[test]
fn consensus_disagreement_small_at_convergence() {
    let (blocks, padded, _) = synthetic_blocks(12);
    let mut spec = DppcaSpec::new(blocks, padded, 5,
                                  Topology::Ring.build(12).unwrap(),
                                  SchemeKind::Nap);
    spec.max_iters = 500;
    spec.tol = 1e-5;
    let result = run_dppca(&spec, BackendChoice::Native.build().unwrap()).unwrap();
    // all nodes must agree on the subspace pairwise
    for i in 1..result.params.len() {
        let angle = max_principal_angle_deg(&result.params[0].w,
                                            &result.params[i].w).unwrap();
        assert!(angle < 2.0, "node 0 vs {i}: {angle}°");
    }
}

#[test]
fn vp_accelerates_on_complete_synthetic() {
    // the paper's headline effect, E1: VP converges in fewer iterations
    // than fixed-penalty ADMM on a complete graph (median over 3 seeds)
    let mut fixed = Vec::new();
    let mut vp = Vec::new();
    for seed in 0..3 {
        for (kind, out) in [(SchemeKind::Fixed, &mut fixed), (SchemeKind::Vp, &mut vp)] {
            let (blocks, padded, _) = synthetic_blocks(20);
            let mut spec = DppcaSpec::new(blocks, padded, 5,
                                          Topology::Complete.build(20).unwrap(), kind);
            spec.max_iters = 400;
            spec.seed = seed;
            let r = run_dppca(&spec, BackendChoice::Native.build().unwrap()).unwrap();
            out.push(r.iterations as f64);
        }
    }
    let f = fadmm::util::stats::median(&fixed);
    let v = fadmm::util::stats::median(&vp);
    assert!(v <= f, "VP {v} should not be slower than fixed {f}");
}
