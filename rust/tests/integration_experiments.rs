//! Experiment-harness integration: miniature versions of every paper
//! artifact run end to end (CSV output + summary invariants), plus the
//! XLA-backend variant when artifacts are present.

use fadmm::experiments::common::BackendChoice;
use fadmm::experiments::{ablations, caltech, fig2, hopkins};
use fadmm::graph::Topology;
use fadmm::penalty::SchemeKind;
use fadmm::runtime::Manifest;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("fadmm_it_{name}"));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn have_artifacts() -> bool {
    Manifest::default_dir().join("manifest.json").exists()
}

#[test]
fn fig2_size_axis_smoke() {
    let dir = tmp("fig2");
    let cfg = fig2::Fig2Config {
        seeds: 2,
        max_iters: 60,
        schemes: vec![SchemeKind::Fixed, SchemeKind::Vp],
        axis_size: true,
        axis_topology: false,
        ..Default::default()
    };
    let rows = fig2::run(&cfg, &dir).unwrap();
    assert_eq!(rows.len(), 3 * 2); // J ∈ {12,16,20} × 2 schemes
    // every curve starts high and ends lower (subspace being recovered)
    for r in &rows {
        assert!(r.curve[0] > *r.curve.last().unwrap(),
                "{}/{:?} curve did not decrease", r.config, r.scheme);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fig2_runs_on_xla_backend_when_available() {
    if !have_artifacts() {
        eprintln!("SKIP: run `make artifacts` for the XLA-backend test");
        return;
    }
    let dir = tmp("fig2_xla");
    let cfg = fig2::Fig2Config {
        seeds: 1,
        max_iters: 40,
        backend: BackendChoice::Xla,
        schemes: vec![SchemeKind::Ap],
        axis_size: false,
        axis_topology: true,
        ..Default::default()
    };
    let rows = fig2::run(&cfg, &dir).unwrap();
    assert_eq!(rows.len(), 3);

    // native backend must produce the identical numbers (same seeds)
    let dir2 = tmp("fig2_native_xcheck");
    let cfg2 = fig2::Fig2Config { backend: BackendChoice::Native, ..cfg };
    let rows2 = fig2::run(&cfg2, &dir2).unwrap();
    for (a, b) in rows.iter().zip(&rows2) {
        assert_eq!(a.median_iterations, b.median_iterations,
                   "xla vs native iterations for {}", a.config);
        assert!((a.median_final_angle - b.median_final_angle).abs() < 1e-6,
                "xla vs native angle for {}", a.config);
    }
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
}

#[test]
fn caltech_one_object_all_settings() {
    let dir = tmp("caltech");
    let cfg = caltech::CaltechConfig {
        seeds: 2,
        max_iters: 120,
        schemes: vec![SchemeKind::Fixed, SchemeKind::Nap],
        objects: vec!["BoxStuff".to_string()],
        ..Default::default()
    };
    let rows = caltech::run(&cfg, &dir).unwrap();
    assert_eq!(rows.len(), 3 * 2);
    // complete/tmax50 should reach a small error for at least one scheme
    let best = rows
        .iter()
        .filter(|r| r.setting == "complete_tmax50")
        .map(|r| r.median_final_angle)
        .fold(f64::INFINITY, f64::min);
    assert!(best < 15.0, "best complete-graph angle {best}");
    caltech::describe(&dir, 0).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hopkins_mini_corpus_table() {
    let dir = tmp("hopkins");
    let cfg = hopkins::HopkinsConfig {
        objects: 12,
        seeds: 2,
        max_iters: 300,
        schemes: vec![SchemeKind::Fixed, SchemeKind::Vp, SchemeKind::Nap],
        topologies: vec![Topology::Complete],
        degenerate_frac: 0.15,
        ..Default::default()
    };
    let rows = hopkins::run(&cfg, &dir).unwrap();
    assert_eq!(rows.len(), 3);
    let fixed = rows.iter().find(|r| r.scheme == SchemeKind::Fixed).unwrap();
    let vp = rows.iter().find(|r| r.scheme == SchemeKind::Vp).unwrap();
    assert!(fixed.objects_used > 0);
    // E4's qualitative claim: VP at least as fast as the baseline
    assert!(vp.mean_iterations <= fixed.mean_iterations * 1.05,
            "VP {} vs fixed {}", vp.mean_iterations, fixed.mean_iterations);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn ablation_eta0_shows_adaptive_robustness() {
    let dir = tmp("ablation");
    let cfg = ablations::AblationConfig {
        seeds: 2,
        max_iters: 150,
        j: 8,
        ..Default::default()
    };
    let rows = ablations::eta0(&cfg, &dir).unwrap();
    assert_eq!(rows.len(), 3 * 4); // 3 η⁰ × 4 schemes
    for r in &rows {
        assert!(r.median_iters > 0.0);
    }
    std::fs::remove_dir_all(&dir).ok();
}
