//! XLA artifacts vs the native Rust oracle — the cross-layer correctness
//! contract: ref.py (jnp) == Pallas kernel == lowered HLO == dppca::em.
//!
//! Requires `make artifacts` (skipped with a loud message otherwise) and a
//! build with the `xla` cargo feature (the whole file is compiled out of
//! the default offline build).

#![cfg(feature = "xla")]

use fadmm::dppca::{Moments, PpcaParams};
use fadmm::linalg::Mat;
use fadmm::runtime::{Backend, Manifest, NativeBackend, XlaBackend};
use fadmm::util::rng::Pcg;

fn artifact_dir() -> Option<std::path::PathBuf> {
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?}; run `make artifacts`");
        None
    }
}

fn backends() -> Option<(XlaBackend, NativeBackend)> {
    let dir = artifact_dir()?;
    Some((XlaBackend::new(dir).expect("xla backend"), NativeBackend::new()))
}

fn random_inputs(seed: u64, d: usize, m: usize, n: usize)
                 -> (Mat, Vec<f64>, PpcaParams, PpcaParams, f64, PpcaParams) {
    let mut rng = Pcg::seed(seed);
    let x = Mat::randn(d, n, &mut rng);
    let mask: Vec<f64> = (0..n).map(|k| f64::from(k < n - 2)).collect();
    let params = PpcaParams {
        w: Mat::randn(d, m, &mut rng),
        mu: rng.normal_vec(d),
        a: rng.range(0.5, 2.0),
    };
    let mult = PpcaParams {
        w: Mat::randn(d, m, &mut rng).scale(0.05),
        mu: rng.normal_vec(d).iter().map(|v| v * 0.05).collect(),
        a: 0.02,
    };
    let eta_sum = 30.0;
    let eta_w = PpcaParams {
        w: (&params.w + &Mat::randn(d, m, &mut rng)).scale(eta_sum),
        mu: params.mu.iter().map(|v| eta_sum * (v + 0.3)).collect(),
        a: eta_sum * (params.a + 1.2),
    };
    (x, mask, params, mult, eta_sum, eta_w)
}

#[test]
fn moments_kernel_matches_native() {
    let Some((mut xla, mut native)) = backends() else { return };
    for (d, m, n) in [(8, 2, 16), (20, 5, 25), (120, 3, 12)] {
        let (x, mask, ..) = random_inputs(d as u64, d, m, n);
        let a: Moments = xla.moments(&x, &mask).unwrap();
        let b: Moments = native.moments(&x, &mask).unwrap();
        assert!((a.n - b.n).abs() < 1e-9, "d{d}");
        for (u, v) in a.sx.iter().zip(&b.sx) {
            assert!((u - v).abs() < 1e-9, "d{d}");
        }
        assert!(a.sxx.max_abs_diff(&b.sxx) < 1e-8, "d{d}");
    }
}

#[test]
fn node_update_matches_native() {
    let Some((mut xla, mut native)) = backends() else { return };
    for (d, m, n) in [(8, 2, 16), (20, 5, 25), (20, 5, 42), (60, 3, 6)] {
        let (x, mask, params, mult, eta_sum, eta_w) =
            random_inputs(100 + d as u64, d, m, n);
        let mom = native.moments(&x, &mask).unwrap();
        let (pa, fa) = xla
            .node_update(&mom, &params, &mult, eta_sum, &eta_w)
            .unwrap();
        let (pb, fb) = native
            .node_update(&mom, &params, &mult, eta_sum, &eta_w)
            .unwrap();
        assert!(pa.w.max_abs_diff(&pb.w) < 1e-7, "d{d} W");
        for (u, v) in pa.mu.iter().zip(&pb.mu) {
            assert!((u - v).abs() < 1e-7, "d{d} mu");
        }
        assert!((pa.a - pb.a).abs() < 1e-7, "d{d} a: {} vs {}", pa.a, pb.a);
        assert!((fa - fb).abs() < 1e-6 * fb.abs().max(1.0), "d{d} nll: {fa} vs {fb}");
    }
}

#[test]
fn direct_update_matches_cached_moments_path() {
    let Some((mut xla, _)) = backends() else { return };
    let (d, m, n) = (8, 2, 16);
    let (x, mask, params, mult, eta_sum, eta_w) = random_inputs(7, d, m, n);
    let mom = xla.moments(&x, &mask).unwrap();
    let (pa, fa) = xla
        .node_update(&mom, &params, &mult, eta_sum, &eta_w)
        .unwrap();
    let (pb, fb) = xla
        .node_update_direct(&x, &mask, &params, &mult, eta_sum, &eta_w)
        .unwrap();
    assert!(pa.w.max_abs_diff(&pb.w) < 1e-10);
    assert!((fa - fb).abs() < 1e-9);
}

#[test]
fn objective_matches_native() {
    let Some((mut xla, mut native)) = backends() else { return };
    for (d, m, n) in [(8, 2, 16), (100, 3, 12), (140, 3, 6)] {
        let (x, mask, params, ..) = random_inputs(200 + d as u64, d, m, n);
        let mom = native.moments(&x, &mask).unwrap();
        let fa = xla.objective(&mom, &params).unwrap();
        let fb = native.objective(&mom, &params).unwrap();
        assert!(
            (fa - fb).abs() < 1e-7 * fb.abs().max(1.0),
            "d{d}: {fa} vs {fb}"
        );
    }
}

#[test]
fn estep_z_matches_native() {
    let Some((mut xla, mut native)) = backends() else { return };
    for (d, m, n) in [(8, 2, 16), (20, 5, 32), (120, 3, 12)] {
        let (x, mask, params, ..) = random_inputs(300 + d as u64, d, m, n);
        let za = xla.estep_z(&x, &mask, &params).unwrap();
        let zb = native.estep_z(&x, &mask, &params).unwrap();
        assert!(za.max_abs_diff(&zb) < 1e-8, "d{d}: {}", za.max_abs_diff(&zb));
    }
}

#[test]
fn objective_batch_matches_scalar_objective() {
    let Some((mut xla, mut native)) = backends() else { return };
    let mut rng = Pcg::seed(55);
    for (d, m, n, count) in [(8, 2, 16, 3), (20, 5, 25, 19), (120, 3, 12, 25)] {
        let (x, mask, ..) = random_inputs(d as u64, d, m, n);
        let mom = native.moments(&x, &mask).unwrap();
        let params: Vec<PpcaParams> = (0..count)
            .map(|_| PpcaParams {
                w: Mat::randn(d, m, &mut rng),
                mu: rng.normal_vec(d),
                a: rng.range(0.2, 5.0),
            })
            .collect();
        let batched = xla.objective_batch(&mom, &params).unwrap();
        assert_eq!(batched.len(), count);
        for (p, &fb) in params.iter().zip(&batched) {
            let fs = native.objective(&mom, p).unwrap();
            assert!((fb - fs).abs() < 1e-7 * fs.abs().max(1.0),
                    "d{d} batch {fb} vs scalar {fs}");
        }
    }
}

#[test]
fn warmup_compiles_every_needed_artifact() {
    let Some((mut xla, _)) = backends() else { return };
    let compiled = xla.warmup(8, 2, 16).unwrap();
    assert_eq!(compiled, 6);
    // second warmup is a no-op
    assert_eq!(xla.warmup(8, 2, 16).unwrap(), 0);
}

#[test]
fn manifest_covers_all_experiment_shapes() {
    let Some(dir) = artifact_dir() else { return };
    let man = Manifest::load(dir).unwrap();
    // every shape the experiment harness uses (fig2 / caltech / hopkins)
    for (d, m, n) in [
        (8, 2, 16),
        (20, 5, 25), (20, 5, 32), (20, 5, 42),
        (120, 3, 12),
        (60, 3, 6), (60, 3, 12), (100, 3, 6), (100, 3, 12),
        (140, 3, 6), (140, 3, 12),
    ] {
        for name in [
            format!("moments_d{d}_n{n}"),
            format!("node_update_d{d}_m{m}"),
            format!("objective_d{d}_m{m}"),
            format!("node_update_direct_d{d}_m{m}_n{n}"),
            format!("estep_z_d{d}_m{m}_n{n}"),
        ] {
            assert!(man.contains(&name), "missing artifact {name}");
        }
    }
}

#[test]
fn repeated_executions_are_stable() {
    // PJRT buffers must not alias: identical inputs → identical outputs
    let Some((mut xla, _)) = backends() else { return };
    let (x, mask, params, mult, eta_sum, eta_w) = random_inputs(11, 8, 2, 16);
    let mom = xla.moments(&x, &mask).unwrap();
    let (p1, f1) = xla.node_update(&mom, &params, &mult, eta_sum, &eta_w).unwrap();
    for _ in 0..5 {
        let (p2, f2) = xla.node_update(&mom, &params, &mult, eta_sum, &eta_w).unwrap();
        assert_eq!(f1, f2);
        assert!(p1.w.max_abs_diff(&p2.w) == 0.0);
    }
}
