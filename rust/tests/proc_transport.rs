//! End-to-end process-transport tests: real `fadmm-node` child
//! processes, line-delimited JSON through the star router, and a real
//! SIGKILL mid-run.
//!
//! The zero-fault case pins the transport contract: the committed
//! iteration count over real processes equals the simulated
//! [`fadmm::cluster::ClusterRunner`] oracle (the fold arithmetic is
//! schedule-invariant, so only the transport changed). The kill case
//! asserts the recovery semantics documented in `cluster::node`:
//! survivors re-root away from the victim and still converge — iteration
//! counts after a hard kill are *not* oracle-comparable (the fresh
//! tracker restarts its curves), and the test only asserts liveness and
//! convergence.
//!
//! Both tests skip gracefully (with a note on stderr) if the node
//! binary cannot be spawned in this environment.

use std::time::Duration;

use fadmm::cluster::proc::{ProcCluster, ProcInit};
use fadmm::cluster::{ClusterConfig, ClusterRunner, CollectiveKind};
use fadmm::experiments::common::quad_problem_factory;
use fadmm::graph::Topology;
use fadmm::net::FaultPlan;
use fadmm::penalty::SchemeKind;

const NODE_BIN: &str = env!("CARGO_BIN_EXE_fadmm-node");

fn init(machine: usize, scheme: SchemeKind, tol: f64, max_iters: usize)
    -> ProcInit {
    ProcInit {
        machine,
        machines: 3,
        nodes: 12,
        dim: 2,
        problem_seed: 41,
        topology: Topology::Ring,
        scheme,
        tol,
        patience: 3,
        warmup: 5,
        max_iters,
        seed: 11,
        workers: 1,
        max_staleness: 0,
        // wall ms on the real transport; the same numbers are virtual
        // ticks for the sim oracle — unreachable either way at zero
        // faults, so neither schedule is timeout-perturbed
        silence_timeout: 5_000,
        collective_timeout: 5_000,
        fallback_after: 3,
        pipeline: 2,
        obs: false,
    }
}

fn sim_oracle(scheme: SchemeKind, tol: f64, max_iters: usize)
    -> fadmm::cluster::ClusterReport {
    ClusterRunner::new(
        Topology::Ring.build(12).unwrap(),
        ClusterConfig {
            scheme,
            tol,
            max_iters,
            seed: 11,
            machines: 3,
            workers: 1,
            collective: CollectiveKind::Tree,
            silence_timeout: 5_000,
            collective_timeout: 5_000,
            tracing: false,
            ..Default::default()
        },
        FaultPlan::none(),
        quad_problem_factory(12, 2, 41),
    )
    .unwrap()
    .run()
}

fn spawn_or_skip(inits: &[ProcInit]) -> Option<ProcCluster> {
    match ProcCluster::spawn(NODE_BIN, inits) {
        Ok(c) => Some(c),
        Err(e) => {
            eprintln!("skipping process-transport test: cannot spawn \
                       {NODE_BIN}: {e}");
            None
        }
    }
}

#[test]
fn three_machine_ring_matches_sim_iteration_count() {
    // RB is the strictest scheme here: it *waits* on every round's
    // collective verdict, so a protocol bug shows up as a hang or a
    // different iteration count, not a silent drift
    for scheme in [SchemeKind::Fixed, SchemeKind::Rb, SchemeKind::VpNap] {
        let inits: Vec<ProcInit> =
            (0..3).map(|m| init(m, scheme, 1e-4, 60)).collect();
        let Some(mut cluster) = spawn_or_skip(&inits) else { return };
        assert!(
            cluster.route_until_done(Duration::from_secs(120)),
            "{scheme:?}: process cluster did not finish in time"
        );
        let done = cluster.shutdown();
        let oracle = sim_oracle(scheme, 1e-4, 60);

        let holders: Vec<_> = done
            .iter()
            .flatten()
            .filter(|d| d.is_holder)
            .collect();
        assert_eq!(holders.len(), 1, "{scheme:?}: exactly one tracker holder");
        assert_eq!(
            holders[0].iterations, oracle.iterations,
            "{scheme:?}: iteration count over real processes vs sim oracle"
        );
        assert_eq!(holders[0].converged, oracle.converged, "{scheme:?}");

        // θ agreement at convergence tolerance, compared in relabeled
        // span order (the oracle report is in original ids)
        let order = fadmm::graph::rcm_order(&Topology::Ring.build(12).unwrap());
        for d in done.iter().flatten() {
            let dim = 2;
            for off in 0..(d.span.1 - d.span.0) {
                let orig = order[d.span.0 + off];
                for k in 0..dim {
                    let diff = (d.thetas[off * dim + k]
                        - oracle.thetas[orig][k])
                        .abs();
                    assert!(
                        diff < 1e-6,
                        "{scheme:?}: machine {} node {orig} dim {k} drifted \
                         {diff:e} between transports",
                        d.machine
                    );
                }
            }
        }
    }
}

#[test]
fn metrics_lines_aggregate_to_the_inproc_registry() {
    // obs smoke over real processes: every node ships its registry as a
    // `metrics` line before `done`, and the driver-side aggregate agrees
    // with the in-process transport's aggregate on the *deterministic*
    // subset — committed rounds and trace accounting. (Message counts
    // depend on where the stop flood lands in each machine's queue, so
    // they only get sanity bounds.)
    let inits: Vec<ProcInit> = (0..3)
        .map(|m| {
            let mut i = init(m, SchemeKind::Fixed, 1e-4, 60);
            i.obs = true;
            i
        })
        .collect();
    let Some(mut cluster) = spawn_or_skip(&inits) else { return };
    assert!(
        cluster.route_until_done(Duration::from_secs(120)),
        "obs smoke: process cluster did not finish in time"
    );
    let agg = cluster.aggregate_obs();
    let reported = cluster.metrics.iter().flatten().count();
    let done = cluster.shutdown();
    assert_eq!(reported, 3, "every machine shipped a metrics line");

    let holder = done
        .iter()
        .flatten()
        .find(|d| d.is_holder)
        .expect("zero-fault run has a holder");

    // the same run over the thread transport, aggregated in-process
    let mut cfg = inits[0].cluster_config();
    cfg.obs = true;
    let reports = fadmm::cluster::inproc::run_inproc(
        &Topology::Ring.build(12).unwrap(),
        cfg,
        quad_problem_factory(12, 2, 41),
    )
    .unwrap();
    let inproc_agg = fadmm::cluster::aggregate_obs(&reports);

    // committed rounds: only the holder folds, so the cluster-wide sum
    // is the committed iteration count — identical across transports
    let rounds = agg.counter_by_name("fadmm_rounds_total").unwrap();
    assert_eq!(rounds, holder.iterations as u64);
    assert_eq!(
        rounds,
        inproc_agg.counter_by_name("fadmm_rounds_total").unwrap(),
        "committed rounds disagree between proc and inproc aggregates"
    );
    // neither transport traces here, so nothing may be dropped
    assert_eq!(agg.counter_by_name("fadmm_trace_dropped_total"), Some(0));
    assert_eq!(agg.counter_by_name("fadmm_trace_events_total"), Some(0));
    // traffic sanity: the cluster really exchanged messages
    let sent = agg.counter_by_name("fadmm_net_sent_total").unwrap();
    let delivered = agg.counter_by_name("fadmm_net_delivered_total").unwrap();
    assert!(sent > 0 && delivered > 0, "no traffic in the obs aggregate");
    assert!(delivered <= sent, "delivered {delivered} > sent {sent}");
    // phase spans were live on every machine (obs = true)
    let solve = agg.hist_by_name("fadmm_phase_solve_ns").unwrap();
    assert!(solve.count > 0, "no solve spans recorded with obs on");
}

#[test]
fn sigkill_mid_run_survivors_reroot_and_converge() {
    // tol 0 keeps the run going to the round budget, so the kill always
    // lands mid-run; survivors must re-root off machine 0 (the initial
    // root and tracker holder), adopt a fresh tracker, and finish
    let inits: Vec<ProcInit> =
        (0..3).map(|m| init(m, SchemeKind::Fixed, 0.0, 300)).collect();
    let Some(mut cluster) = spawn_or_skip(&inits) else { return };

    assert!(
        cluster.route_until_traffic(60, Duration::from_secs(60)),
        "no traffic before the kill — cluster never started"
    );
    cluster.kill(0);
    assert!(
        cluster.route_until_done(Duration::from_secs(120)),
        "survivors did not finish after the kill"
    );
    let done = cluster.shutdown();

    assert!(done[0].is_none(), "the killed machine cannot report");
    let survivors: Vec<_> = done.iter().flatten().collect();
    assert_eq!(survivors.len(), 2, "both survivors reported");
    for d in &survivors {
        assert!(d.final_root != 0, "machine {} still rooted at the victim",
                d.machine);
    }
    let holders: Vec<_> = survivors.iter().filter(|d| d.is_holder).collect();
    assert_eq!(holders.len(), 1, "exactly one surviving holder");
    assert!(holders[0].iterations > 0, "the new tracker committed rounds");
}
