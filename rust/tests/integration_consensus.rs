//! Cross-module integration: consensus engine × every scheme × larger
//! convex problems, and sequential-vs-threaded agreement.

use std::sync::Arc;

use fadmm::consensus::solvers::{LassoNode, LeastSquaresNode, QuadraticNode, RidgeNode};
use fadmm::consensus::{Engine, EngineConfig};
use fadmm::coordinator::{ThreadedConfig, ThreadedRunner};
use fadmm::graph::{random_connected, Topology};
use fadmm::linalg::Mat;
use fadmm::penalty::{SchemeKind, SchemeParams};
use fadmm::util::rng::Pcg;

fn quad_problem(n: usize, dim: usize, seed: u64) -> (Vec<QuadraticNode>, Vec<f64>) {
    let mut rng = Pcg::seed(seed);
    let nodes: Vec<QuadraticNode> =
        (0..n).map(|_| QuadraticNode::random(dim, &mut rng)).collect();
    let opt = QuadraticNode::central_optimum(&nodes);
    (nodes, opt)
}

fn max_err(thetas: &[Vec<f64>], opt: &[f64]) -> f64 {
    thetas
        .iter()
        .map(|th| {
            th.iter().zip(opt).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt()
        })
        .fold(0.0, f64::max)
}

#[test]
fn twenty_node_network_all_schemes() {
    for scheme in SchemeKind::ALL {
        let (nodes, opt) = quad_problem(20, 4, 99);
        let mut engine = Engine::new(Topology::Complete.build(20).unwrap(), nodes,
                                     EngineConfig {
                                         scheme,
                                         tol: 1e-10,
                                         max_iters: 800,
                                         ..Default::default()
                                     });
        let report = engine.run();
        assert!(max_err(&report.thetas, &opt) < 1e-3,
                "{scheme:?}: err {}", max_err(&report.thetas, &opt));
    }
}

#[test]
fn grid_and_star_topologies() {
    for topo in [Topology::Grid, Topology::Star] {
        let n = if topo == Topology::Grid { 16 } else { 12 };
        let (nodes, opt) = quad_problem(n, 3, 5);
        let mut engine = Engine::new(topo.build(n).unwrap(), nodes, EngineConfig {
            scheme: SchemeKind::VpNap,
            tol: 1e-10,
            max_iters: 900,
            ..Default::default()
        });
        let report = engine.run();
        assert!(max_err(&report.thetas, &opt) < 5e-3, "{topo:?}");
    }
}

#[test]
fn mixed_solver_kinds_share_engine_api() {
    // LS / ridge / lasso all plug into the same engine generically
    let mut rng = Pcg::seed(17);
    let dim = 4;
    let mut make = |rng: &mut Pcg| {
        let a = Mat::randn(20, dim, rng);
        let b: Vec<f64> = (0..20).map(|_| rng.normal()).collect();
        (a, b)
    };
    {
        let (a, b) = make(&mut rng);
        let nodes: Vec<LeastSquaresNode> = (0..4)
            .map(|_| LeastSquaresNode::new(a.clone(), b.clone()))
            .collect();
        let report = Engine::new(Topology::Ring.build(4).unwrap(), nodes,
                                 EngineConfig::default()).run();
        assert!(report.iterations > 0);
    }
    {
        let (a, b) = make(&mut rng);
        let nodes: Vec<RidgeNode> = (0..4)
            .map(|_| RidgeNode::new(a.clone(), b.clone(), 0.5))
            .collect();
        let report = Engine::new(Topology::Ring.build(4).unwrap(), nodes,
                                 EngineConfig::default()).run();
        assert!(report.converged);
    }
    {
        let (a, b) = make(&mut rng);
        let nodes: Vec<LassoNode> = (0..4)
            .map(|_| LassoNode::new(a.clone(), b.clone(), 1.0))
            .collect();
        let report = Engine::new(Topology::Ring.build(4).unwrap(), nodes,
                                 EngineConfig::default()).run();
        assert!(report.iterations > 0);
    }
}

#[test]
fn threaded_and_sequential_reach_same_optimum() {
    let (nodes, opt) = quad_problem(8, 3, 23);
    let mut engine = Engine::new(Topology::Ring.build(8).unwrap(), nodes,
                                 EngineConfig {
                                     scheme: SchemeKind::Ap,
                                     tol: 1e-11,
                                     max_iters: 800,
                                     ..Default::default()
                                 });
    let sequential = engine.run();

    let runner = ThreadedRunner::new(Topology::Ring.build(8).unwrap(),
                                     ThreadedConfig {
                                         scheme: SchemeKind::Ap,
                                         tol: 1e-11,
                                         max_iters: 800,
                                         ..Default::default()
                                     });
    let threaded = runner
        .run(Arc::new(move |i| {
            // regenerate the same deterministic problem inside the worker
            let mut rng = Pcg::seed(23);
            let mut nodes: Vec<QuadraticNode> = Vec::new();
            for _ in 0..8 {
                nodes.push(QuadraticNode::random(3, &mut rng));
            }
            nodes.swap_remove(i)
        }))
        .unwrap();

    assert!(max_err(&sequential.thetas, &opt) < 1e-3);
    assert!(max_err(&threaded.thetas, &opt) < 1e-3);
}

#[test]
fn random_graphs_with_custom_params() {
    let mut rng = Pcg::seed(77);
    for _ in 0..3 {
        let n = 5 + rng.below(10);
        let graph = random_connected(n, 0.4, &mut rng).unwrap();
        let (nodes, opt) = quad_problem(n, 2, rng.next_u64());
        let params = SchemeParams { eta0: 5.0, t_max: 30, ..Default::default() };
        let mut engine = Engine::new(graph, nodes, EngineConfig {
            scheme: SchemeKind::VpAp,
            params,
            tol: 1e-10,
            max_iters: 700,
            ..Default::default()
        });
        let report = engine.run();
        assert!(max_err(&report.thetas, &opt) < 5e-3);
    }
}
