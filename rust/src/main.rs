//! `repro` — the fadmm experiment launcher.
//!
//! Subcommands (see `repro help`):
//!   fig2       synthetic sweeps (paper Fig. 2)
//!   caltech    turntable SfM curves (Fig. 3/5) + dataset description (Fig. 4)
//!   hopkins    trajectory-corpus iteration table (§5.2)
//!   ablation   η⁰ / NAP-budget / VP sweeps
//!   net        fault-scenario matrix on the simulated-network runtime
//!   run        one JSON-configured consensus run
//!   check-artifacts   validate the AOT artifact manifest + compile warmup

use std::path::PathBuf;

use fadmm::cluster::CollectiveKind;
use fadmm::config::{CliArgs, RunConfig};
use fadmm::data::{even_split, SubspaceSpec};
use fadmm::experiments::{ablations, caltech, cluster_scenarios, common, fig2,
                         hopkins, net_scenarios};
use fadmm::experiments::common::BackendChoice;
use fadmm::linalg::Mat;
use fadmm::util::rng::Pcg;

const HELP: &str = "\
repro — Fast ADMM with Adaptive Penalty (AAAI'16) reproduction

USAGE: repro <subcommand> [options]

SUBCOMMANDS
  fig2        synthetic D-PPCA sweeps (paper Fig. 2)
                --axis size|topology|all   (default all)
                --seeds N                  (default 20)
                --schemes a,b,...          (default: paper set)
                --backend xla|native       (default native; numerically identical)
                --max-iters N              (default 400)
                --out DIR                  (default results)
  caltech     turntable SfM (Fig. 3/5); --describe adds the Fig. 4 table
                --objects Name1,Name2  --seeds N (default 5)  --out DIR
  hopkins     trajectory corpus table (§5.2)
                --objects N (default 135)  --seeds N (default 5)  --out DIR
  ablation    --name eta0|budget|vp|all  --seeds N  --out DIR
  net         loss × latency × churn matrix on the async simulated-network
              runtime, all schemes by default
                --nodes N (default 12)  --seeds N (default 5)
                --max-iters N (default 400)  --schemes a,b,...  --out DIR
                --plan file.json  replay a recorded FaultPlan as the only
                                  scenario (node ids; churn on id == nodes
                                  drives the bridging joiner)
  cluster     machines × loss × collective × scheme matrix on the hybrid
              cluster runtime (sharded pool per machine over the simulated
              network), reporting extra rounds vs the oracle fold
                --nodes N (default 24)  --machines a,b,... (default 2,4)
                --seeds N (default 3)  --max-iters N (default 300)
                --schemes a,b,...  --collectives tree,gossip
                --loss a,b,... (default 0,0.1,0.3)  --out DIR
                --plan file.json  replay a recorded machine-level FaultPlan
                --dppca  run the D-PPCA cell instead (4 machines @ 10% loss,
                         subspace-angle hook vs the single-box oracle)
  run         --config cfg.json          one consensus run, prints summary
  check-artifacts   validate manifest and compile one artifact set
  help        this text

Every subcommand also accepts
  --obs FILE  write the run's merged telemetry registry (phase spans,
              transport counters, trace accounting — see the obs module)
              as JSON to FILE and Prometheus text to FILE.prom; also arms
              a panic hook that flushes the registry collected so far to
              FILE.crash.json if the run dies
  --trace FILE  record the causal round timeline on every runtime and
              write it as Chrome trace-event JSON to FILE (open in
              chrome://tracing or Perfetto; one track per machine,
              send→deliver flow arrows) plus per-round critical-path
              attribution to FILE.critical_path.json
  --series FILE  record the per-round convergence series (committed
              IterStats, live node/edge counts, phase durations) and
              write CSV to FILE plus a JSON mirror to FILE.json

cluster additionally accepts
  --transport sim|threads|procs   (default sim)
              sim runs the scenario matrix on the simulated driver;
              threads/procs run ONE configuration (--nodes, --machines M,
              first --schemes entry, --max-iters, ring topology) over the
              in-process thread mesh or real fadmm-node child processes
              and print the per-machine reports

All experiments are seeded and deterministic; CSVs land in --out.
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(raw: Vec<String>) -> fadmm::Result<()> {
    let args = CliArgs::parse(raw, &["describe", "verbose", "dppca"])?;
    // --obs FILE: arm the global telemetry sink before anything runs;
    // every runtime merges its finished registry into it. The crash hook
    // flushes whatever was merged so far if the run panics.
    let obs_path = args.get("obs").map(PathBuf::from);
    if let Some(path) = &obs_path {
        fadmm::obs::enable_global();
        fadmm::obs::install_crash_hook(PathBuf::from(format!(
            "{}.crash.json",
            path.display()
        )));
    }
    // --trace FILE / --series FILE: arm the timeline / series sinks the
    // same way; runtimes feed them only while armed (bit-transparent off)
    let trace_path = args.get("trace").map(PathBuf::from);
    if trace_path.is_some() {
        fadmm::obs::enable_global_timeline();
    }
    let series_path = args.get("series").map(PathBuf::from);
    if series_path.is_some() {
        fadmm::obs::enable_global_series();
    }
    let result = match args.subcommand.as_str() {
        "" | "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        "fig2" => cmd_fig2(&args),
        "caltech" => cmd_caltech(&args),
        "hopkins" => cmd_hopkins(&args),
        "ablation" => cmd_ablation(&args),
        "net" => cmd_net(&args),
        "cluster" => cmd_cluster(&args),
        "run" => cmd_run(&args),
        "check-artifacts" => cmd_check_artifacts(),
        other => Err(fadmm::Error::Config(format!(
            "unknown subcommand '{other}' (try `repro help`)"
        ))),
    };
    if result.is_ok() {
        if let Some(path) = obs_path {
            write_obs(&path)?;
        }
        if let Some(path) = trace_path {
            write_trace(&path)?;
        }
        if let Some(path) = series_path {
            write_series(&path)?;
        }
    }
    result
}

/// Drain the global telemetry sink and write the JSON + Prometheus
/// report files next to each other.
fn write_obs(path: &std::path::Path) -> fadmm::Result<()> {
    let reg = fadmm::obs::take_global().unwrap_or_default();
    std::fs::write(path, reg.to_json().to_string()).map_err(|e| {
        fadmm::Error::io(format!("writing obs report {}", path.display()), e)
    })?;
    let prom = PathBuf::from(format!("{}.prom", path.display()));
    std::fs::write(&prom, reg.to_prometheus()).map_err(|e| {
        fadmm::Error::io(format!("writing obs report {}", prom.display()), e)
    })?;
    eprintln!("obs: wrote {} and {}", path.display(), prom.display());
    Ok(())
}

/// Drain the global timeline sink: Chrome trace-event JSON at `path`,
/// per-round critical-path attribution next to it, and a terse stderr
/// table of the slowest rounds.
fn write_trace(path: &std::path::Path) -> fadmm::Result<()> {
    let events = fadmm::obs::take_global_timeline().unwrap_or_default();
    fadmm::obs::chrome::write_chrome_trace(path, "repro", &events)?;
    let paths = fadmm::obs::critical_path::analyze(&events, 5);
    let cp = PathBuf::from(format!("{}.critical_path.json", path.display()));
    let doc = fadmm::obs::critical_path::critical_path_json(&paths, events.len());
    std::fs::write(&cp, doc.to_string()).map_err(|e| {
        fadmm::Error::io(format!("writing critical path {}", cp.display()), e)
    })?;
    eprintln!("trace: wrote {} and {} ({} events)", path.display(),
              cp.display(), events.len());
    eprint!("{}", fadmm::obs::critical_path::critical_path_text(&paths));
    Ok(())
}

/// Drain the global series sink: per-round CSV at `path` plus a JSON
/// mirror (with drop accounting) next to it.
fn write_series(path: &std::path::Path) -> fadmm::Result<()> {
    let (rows, dropped) = fadmm::obs::take_global_series().unwrap_or_default();
    fadmm::obs::write_series_csv(path, &rows)?;
    let json = PathBuf::from(format!("{}.json", path.display()));
    fadmm::obs::write_series_json(&json, &rows, dropped)?;
    eprintln!("series: wrote {} and {} ({} rows, {} dropped)", path.display(),
              json.display(), rows.len(), dropped);
    Ok(())
}

fn out_dir(args: &CliArgs) -> PathBuf {
    PathBuf::from(args.get_or("out", "results"))
}

fn backend(args: &CliArgs) -> fadmm::Result<BackendChoice> {
    BackendChoice::parse(&args.get_or("backend", "native"))
}

fn cmd_fig2(args: &CliArgs) -> fadmm::Result<()> {
    let axis = args.get_or("axis", "all");
    let cfg = fig2::Fig2Config {
        seeds: args.get_usize("seeds", 20)?,
        backend: backend(args)?,
        max_iters: args.get_usize("max-iters", 400)?,
        schemes: args.schemes()?,
        axis_size: axis == "all" || axis == "size",
        axis_topology: axis == "all" || axis == "topology",
    };
    let out = out_dir(args);
    eprintln!("fig2: {} seeds, backend {:?}, out {}", cfg.seeds, cfg.backend,
              out.display());
    let rows = fig2::run(&cfg, &out)?;
    fig2::print_summary(&rows);
    Ok(())
}

fn cmd_caltech(args: &CliArgs) -> fadmm::Result<()> {
    let out = out_dir(args);
    if args.has_flag("describe") {
        caltech::describe(&out, 0)?;
        println!("wrote {}", out.join("caltech_objects.csv").display());
    }
    let cfg = caltech::CaltechConfig {
        seeds: args.get_usize("seeds", 5)?,
        backend: backend(args)?,
        max_iters: args.get_usize("max-iters", 400)?,
        schemes: args.schemes()?,
        objects: args
            .get("objects")
            .map(|s| s.split(',').map(str::to_string).collect())
            .unwrap_or_default(),
        data_seed: args.get_usize("data-seed", 0)? as u64,
    };
    let rows = caltech::run(&cfg, &out)?;
    caltech::print_summary(&rows);
    Ok(())
}

fn cmd_hopkins(args: &CliArgs) -> fadmm::Result<()> {
    let cfg = hopkins::HopkinsConfig {
        objects: args.get_usize("objects", 135)?,
        seeds: args.get_usize("seeds", 5)?,
        backend: backend(args)?,
        max_iters: args.get_usize("max-iters", 400)?,
        schemes: args.schemes()?,
        ..Default::default()
    };
    let out = out_dir(args);
    eprintln!("hopkins: {} objects × {} seeds", cfg.objects, cfg.seeds);
    let rows = hopkins::run(&cfg, &out)?;
    hopkins::print_summary(&rows);
    Ok(())
}

fn cmd_ablation(args: &CliArgs) -> fadmm::Result<()> {
    let cfg = ablations::AblationConfig {
        seeds: args.get_usize("seeds", 5)?,
        backend: backend(args)?,
        max_iters: args.get_usize("max-iters", 400)?,
        j: args.get_usize("nodes", 20)?,
    };
    let out = out_dir(args);
    let name = args.get_or("name", "all");
    let mut rows = Vec::new();
    if name == "all" || name == "eta0" {
        rows.extend(ablations::eta0(&cfg, &out)?);
    }
    if name == "all" || name == "budget" {
        rows.extend(ablations::budget(&cfg, &out)?);
    }
    if name == "all" || name == "vp" {
        rows.extend(ablations::vp(&cfg, &out)?);
    }
    if rows.is_empty() {
        return Err(fadmm::Error::Config(format!("unknown ablation '{name}'")));
    }
    ablations::print_summary(&rows);
    Ok(())
}

fn cmd_net(args: &CliArgs) -> fadmm::Result<()> {
    let cfg = net_scenarios::NetScenarioConfig {
        nodes: args.get_usize("nodes", 12)?,
        seeds: args.get_usize("seeds", 5)?,
        max_iters: args.get_usize("max-iters", 400)?,
        schemes: match args.get("schemes") {
            None => fadmm::penalty::SchemeKind::ALL.to_vec(),
            Some(_) => args.schemes()?,
        },
    };
    let out = out_dir(args);
    let rows = match args.get("plan") {
        Some(path) => {
            let plan = fadmm::net::load_plan(std::path::Path::new(path))?;
            eprintln!("net: replaying plan {} on {} nodes × {} seeds, out {}",
                      path, cfg.nodes, cfg.seeds, out.display());
            net_scenarios::run_plan(&cfg, plan, &out)?
        }
        None => {
            eprintln!("net: {} nodes × {} seeds × {} schemes, out {}", cfg.nodes,
                      cfg.seeds, cfg.schemes.len(), out.display());
            net_scenarios::run(&cfg, &out)?
        }
    };
    net_scenarios::print_summary(&rows);
    Ok(())
}

fn parse_list<T, E>(raw: Option<&str>, default: Vec<T>,
                    parse: impl Fn(&str) -> std::result::Result<T, E>)
                    -> fadmm::Result<Vec<T>>
where
    E: std::fmt::Display,
{
    match raw {
        None => Ok(default),
        Some(s) => s
            .split(',')
            .filter(|t| !t.is_empty())
            .map(|t| {
                parse(t.trim()).map_err(|e| {
                    fadmm::Error::Config(format!("bad list entry '{t}': {e}"))
                })
            })
            .collect(),
    }
}

fn cmd_cluster(args: &CliArgs) -> fadmm::Result<()> {
    match args.get_or("transport", "sim").as_str() {
        "sim" => {}
        "threads" => return cmd_cluster_threads(args),
        "procs" => return cmd_cluster_procs(args),
        other => {
            return Err(fadmm::Error::Config(format!(
                "--transport: '{other}' is not sim|threads|procs"
            )))
        }
    }
    if args.has_flag("dppca") {
        // the D-PPCA cell: 4 machines, 10% loss, subspace-angle hook vs
        // the single-box oracle (ROADMAP open item)
        let out = out_dir(args);
        let max_iters = args.get_usize("max-iters", 200)?;
        eprintln!("cluster --dppca: 4 machines @ 10% loss, {} iters, out {}",
                  max_iters, out.display());
        let row = cluster_scenarios::run_dppca(max_iters, &out)?;
        cluster_scenarios::print_dppca(&row);
        return Ok(());
    }
    let cfg = cluster_scenarios::ClusterScenarioConfig {
        nodes: args.get_usize("nodes", 24)?,
        machines_list: parse_list(args.get("machines"), vec![2, 4],
                                  str::parse::<usize>)?,
        seeds: args.get_usize("seeds", 3)?,
        max_iters: args.get_usize("max-iters", 300)?,
        schemes: match args.get("schemes") {
            None => fadmm::penalty::SchemeKind::ALL.to_vec(),
            Some(_) => args.schemes()?,
        },
        loss_levels: parse_list(args.get("loss"), vec![0.0, 0.10, 0.30],
                                str::parse::<f64>)?,
        collectives: match args.get("collectives") {
            None => CollectiveKind::ALL.to_vec(),
            Some(s) => parse_list(Some(s), vec![], |t| CollectiveKind::parse(t))?,
        },
    };
    let out = out_dir(args);
    let rows = match args.get("plan") {
        Some(path) => {
            let plan = fadmm::net::load_plan(std::path::Path::new(path))?;
            eprintln!("cluster: replaying plan {} across machines {:?}, out {}",
                      path, cfg.machines_list, out.display());
            cluster_scenarios::run_plan(&cfg, plan, &out)?
        }
        None => {
            eprintln!("cluster: {} nodes, machines {:?}, {} seeds, {} schemes, \
                       out {}",
                      cfg.nodes, cfg.machines_list, cfg.seeds,
                      cfg.schemes.len(), out.display());
            cluster_scenarios::run(&cfg, &out)?
        }
    };
    cluster_scenarios::print_summary(&rows);
    Ok(())
}

/// The single cluster configuration the real-transport paths run: ring
/// topology, the quadratic consensus problem keyed by `(nodes, 2, 41)`,
/// first scheme of the list, generous wall-clock timeouts.
fn real_transport_shape(args: &CliArgs)
    -> fadmm::Result<(usize, usize, fadmm::penalty::SchemeKind, usize, f64)> {
    let nodes = args.get_usize("nodes", 24)?;
    let machines = parse_list(args.get("machines"), vec![3],
                              str::parse::<usize>)?
        .first()
        .copied()
        .unwrap_or(3);
    let scheme = args
        .schemes()?
        .first()
        .copied()
        .unwrap_or(fadmm::penalty::SchemeKind::Fixed);
    let max_iters = args.get_usize("max-iters", 60)?;
    let tol = args.get_f64("tol", 1e-4)?;
    Ok((nodes, machines, scheme, max_iters, tol))
}

fn print_node_report(machine: usize, span: (usize, usize), iterations: usize,
                     converged: bool, holder: bool) {
    println!(
        "machine={machine} span={}..{} iterations={iterations} \
         converged={converged} holder={holder}",
        span.0, span.1
    );
}

fn cmd_cluster_threads(args: &CliArgs) -> fadmm::Result<()> {
    let (nodes, machines, scheme, max_iters, tol) = real_transport_shape(args)?;
    eprintln!("cluster --transport threads: {nodes} nodes on {machines} \
               machines, scheme {}", scheme.name());
    let cfg = fadmm::cluster::ClusterConfig {
        scheme,
        tol,
        max_iters,
        seed: args.get_usize("seed", 11)? as u64,
        machines,
        workers: 1,
        collective: CollectiveKind::Tree,
        silence_timeout: 5_000,
        collective_timeout: 5_000,
        obs: fadmm::obs::global_spans_enabled(),
        timeline: fadmm::obs::global_timeline_enabled(),
        series: fadmm::obs::global_series_enabled(),
        ..Default::default()
    };
    let graph = fadmm::graph::Topology::Ring.build(nodes)?;
    let reports = fadmm::cluster::inproc::run_inproc(
        &graph, cfg, common::quad_problem_factory(nodes, 2, 41),
    )?;
    for rep in &reports {
        print_node_report(rep.machine, (rep.span.start, rep.span.end),
                          rep.iterations, rep.converged, rep.is_holder);
    }
    let agg = fadmm::cluster::aggregate_obs(&reports);
    println!(
        "cluster rounds={} sent={} delivered={}",
        agg.counter_by_name("fadmm_rounds_total").unwrap_or(0),
        agg.counter_by_name("fadmm_net_sent_total").unwrap_or(0),
        agg.counter_by_name("fadmm_net_delivered_total").unwrap_or(0),
    );
    Ok(())
}

fn cmd_cluster_procs(args: &CliArgs) -> fadmm::Result<()> {
    use fadmm::cluster::proc::{ProcCluster, ProcInit};
    let (nodes, machines, scheme, max_iters, tol) = real_transport_shape(args)?;
    let exe = std::env::current_exe()
        .map_err(|e| fadmm::Error::io("locating the repro binary", e))?;
    let bin = exe.with_file_name("fadmm-node");
    let bin = bin.to_str().ok_or_else(|| {
        fadmm::Error::Config("non-UTF-8 path to fadmm-node".into())
    })?;
    eprintln!("cluster --transport procs: {nodes} nodes on {machines} \
               fadmm-node processes ({bin}), scheme {}", scheme.name());
    let inits: Vec<ProcInit> = (0..machines)
        .map(|m| ProcInit {
            machine: m,
            machines,
            nodes,
            dim: 2,
            problem_seed: 41,
            topology: fadmm::graph::Topology::Ring,
            scheme,
            tol,
            patience: 3,
            warmup: 5,
            max_iters,
            seed: 11,
            workers: 1,
            max_staleness: 0,
            silence_timeout: 5_000,
            collective_timeout: 5_000,
            fallback_after: 3,
            pipeline: 2,
            obs: fadmm::obs::global_spans_enabled(),
            timeline: fadmm::obs::global_timeline_enabled(),
            series: fadmm::obs::global_series_enabled(),
        })
        .collect();
    let mut cluster = ProcCluster::spawn(bin, &inits).map_err(|e| {
        fadmm::Error::io(
            "spawning fadmm-node (build it with `cargo build --bin fadmm-node`)",
            e,
        )
    })?;
    if !cluster.route_until_done(std::time::Duration::from_secs(600)) {
        return Err(fadmm::Error::Config(
            "proc cluster did not finish within 600s".into(),
        ));
    }
    // the child processes can't reach this process's sink; bridge the
    // driver-side aggregate of their metrics lines into it
    let agg = cluster.aggregate_obs();
    fadmm::obs::global_merge(&agg);
    let done = cluster.shutdown();
    for d in done.iter().flatten() {
        print_node_report(d.machine, d.span, d.iterations, d.converged,
                          d.is_holder);
    }
    println!(
        "cluster rounds={} sent={} delivered={}",
        agg.counter_by_name("fadmm_rounds_total").unwrap_or(0),
        agg.counter_by_name("fadmm_net_sent_total").unwrap_or(0),
        agg.counter_by_name("fadmm_net_delivered_total").unwrap_or(0),
    );
    Ok(())
}

fn cmd_run(args: &CliArgs) -> fadmm::Result<()> {
    let path = args
        .get("config")
        .ok_or_else(|| fadmm::Error::Config("run needs --config file.json".into()))?;
    let cfg = RunConfig::from_file(std::path::Path::new(path))?;
    if cfg.problem != "synthetic" {
        return Err(fadmm::Error::Config(format!(
            "run: only 'synthetic' is wired here (got '{}'); use the caltech/\
             hopkins subcommands for SfM problems",
            cfg.problem
        )));
    }
    let data = SubspaceSpec::default().generate(&mut Pcg::seed(7));
    let part = even_split(500, cfg.nodes);
    let blocks: Vec<Mat> = part
        .ranges
        .iter()
        .map(|&(lo, hi)| data.x.col_slice(lo, hi))
        .collect();
    let mut spec = common::DppcaSpec::new(blocks, part.padded, 5,
                                          cfg.topology.build(cfg.nodes)?, cfg.scheme);
    spec.params = cfg.params;
    spec.seed = cfg.seed;
    spec.max_iters = cfg.max_iters;
    spec.tol = cfg.tol;
    spec.reference = Some(&data.w_true);
    let backend = BackendChoice::parse(&cfg.backend)?.build()?;
    let result = common::run_dppca(&spec, backend)?;
    println!(
        "scheme={} topology={} nodes={} iterations={} converged={} final_angle={:.4}°",
        cfg.scheme.name(), cfg.topology.name(), cfg.nodes, result.iterations,
        result.converged, result.final_angle
    );
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_check_artifacts() -> fadmm::Result<()> {
    let mut backend = fadmm::runtime::XlaBackend::from_default_dir()?;
    println!("manifest: {} artifacts at {}", backend.manifest().len(),
             fadmm::runtime::Manifest::default_dir().display());
    let compiled = backend.warmup(8, 2, 16)?;
    println!("compiled {compiled} executables for the d8/m2/n16 smoke shape — OK");
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_check_artifacts() -> fadmm::Result<()> {
    Err(fadmm::Error::Config(
        "check-artifacts requires the `xla` feature: \
         cargo run --features xla -- check-artifacts".into(),
    ))
}
