//! Crate-wide error type.

/// Errors surfaced by the fadmm library.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Shape or dimension mismatch in linear algebra / marshalling.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// Numerical failure (singular matrix, non-convergence of a factorization).
    #[error("numerical failure: {0}")]
    Numeric(String),

    /// Invalid configuration (topology, scheme parameters, experiment spec).
    #[error("invalid config: {0}")]
    Config(String),

    /// Artifact manifest / HLO loading problems.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// JSON parse error (in-repo parser, see `util::json`).
    #[error("json error at byte {offset}: {msg}")]
    Json { offset: usize, msg: String },

    /// Propagated XLA/PJRT error.
    #[error("xla error: {0}")]
    Xla(String),

    /// I/O error with context.
    #[error("io error ({context}): {source}")]
    Io {
        context: String,
        #[source]
        source: std::io::Error,
    },
}

impl Error {
    /// Attach a context string to an I/O error.
    pub fn io(context: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { context: context.into(), source }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
