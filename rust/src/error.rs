//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`): the default build
//! must compile with zero external dependencies in the offline build
//! environment.

/// Errors surfaced by the fadmm library.
#[derive(Debug)]
pub enum Error {
    /// Shape or dimension mismatch in linear algebra / marshalling.
    Shape(String),

    /// Numerical failure (singular matrix, non-convergence of a factorization).
    Numeric(String),

    /// Invalid configuration (topology, scheme parameters, experiment spec).
    Config(String),

    /// Artifact manifest / HLO loading problems.
    Artifact(String),

    /// JSON parse error (in-repo parser, see `util::json`).
    Json { offset: usize, msg: String },

    /// Propagated XLA/PJRT error.
    Xla(String),

    /// I/O error with context.
    Io {
        context: String,
        source: std::io::Error,
    },
}

impl Error {
    /// Attach a context string to an I/O error.
    pub fn io(context: impl Into<String>, source: std::io::Error) -> Self {
        Error::Io { context: context.into(), source }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Numeric(m) => write!(f, "numerical failure: {m}"),
            Error::Config(m) => write!(f, "invalid config: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Json { offset, msg } => write!(f, "json error at byte {offset}: {msg}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Io { context, source } => write!(f, "io error ({context}): {source}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_context() {
        let e = Error::Config("bad topology".into());
        assert_eq!(e.to_string(), "invalid config: bad topology");
        let io = Error::io("reading manifest",
                           std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        assert!(io.to_string().contains("reading manifest"));
        assert!(std::error::Error::source(&io).is_some());
        assert!(std::error::Error::source(&e).is_none());
    }
}
