//! CLI argument parsing and JSON run configuration.
//!
//! clap is unavailable offline; this is a deliberately small
//! `--key value` / `--flag` parser plus a JSON-driven single-run config
//! for the `repro run` subcommand.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::graph::Topology;
use crate::penalty::{SchemeKind, SchemeParams};
use crate::util::json::Json;

/// Parsed command line: one subcommand, positionals, `--key value` options
/// and `--flag` booleans.
#[derive(Debug, Clone, Default)]
pub struct CliArgs {
    pub subcommand: String,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl CliArgs {
    /// Parse from an iterator of arguments (excluding argv[0]).
    /// `known_flags` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(args: I, known_flags: &[&str])
                                                 -> Result<CliArgs> {
        let mut out = CliArgs::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.next() {
            if first.starts_with("--") {
                return Err(Error::Config(format!("expected subcommand, got '{first}'")));
            }
            out.subcommand = first;
        }
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if known_flags.contains(&key) {
                    out.flags.push(key.to_string());
                } else {
                    let value = it.next().ok_or_else(|| {
                        Error::Config(format!("option --{key} needs a value"))
                    })?;
                    out.options.insert(key.to_string(), value);
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: '{v}' is not an integer"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key}: '{v}' is not a number"))),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    /// Comma-separated scheme list (`--schemes vp,ap`) or the paper set.
    pub fn schemes(&self) -> Result<Vec<SchemeKind>> {
        match self.get("schemes") {
            None => Ok(SchemeKind::PAPER.to_vec()),
            Some(spec) => spec.split(',').map(|s| SchemeKind::parse(s.trim())).collect(),
        }
    }
}

/// JSON-driven single-run configuration for `repro run --config cfg.json`.
///
/// ```json
/// {
///   "problem": "synthetic",        // synthetic | turntable | trajectory
///   "nodes": 20, "topology": "ring", "scheme": "admm-nap",
///   "eta0": 10.0, "t_max": 50, "budget": 1.0, "alpha": 0.5, "beta": 0.1,
///   "seed": 0, "max_iters": 400, "tol": 1e-3, "backend": "xla"
/// }
/// ```
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub problem: String,
    pub nodes: usize,
    pub topology: Topology,
    pub scheme: SchemeKind,
    pub params: SchemeParams,
    pub seed: u64,
    pub max_iters: usize,
    pub tol: f64,
    pub backend: String,
}

impl RunConfig {
    pub fn from_file(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::io(format!("read {}", path.display()), e))?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let s = |key: &str, default: &str| -> String {
            j.get(key).and_then(Json::as_str).unwrap_or(default).to_string()
        };
        let f = |key: &str, default: f64| -> f64 {
            j.get(key).and_then(Json::as_f64).unwrap_or(default)
        };
        let defaults = SchemeParams::default();
        Ok(RunConfig {
            problem: s("problem", "synthetic"),
            nodes: f("nodes", 20.0) as usize,
            topology: Topology::parse(&s("topology", "complete"))?,
            scheme: SchemeKind::parse(&s("scheme", "admm-nap"))?,
            params: SchemeParams {
                eta0: f("eta0", defaults.eta0),
                mu: f("mu", defaults.mu),
                tau: f("tau", defaults.tau),
                t_max: f("t_max", defaults.t_max as f64) as usize,
                budget: f("budget", defaults.budget),
                alpha: f("alpha", defaults.alpha),
                beta: f("beta", defaults.beta),
                ..defaults
            },
            seed: f("seed", 0.0) as u64,
            max_iters: f("max_iters", 400.0) as usize,
            tol: f("tol", 1e-3),
            backend: s("backend", "xla"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> CliArgs {
        CliArgs::parse(s.split_whitespace().map(String::from), &["verbose"]).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = args("fig2 --seeds 5 --axis size --verbose extra");
        assert_eq!(a.subcommand, "fig2");
        assert_eq!(a.get("seeds"), Some("5"));
        assert_eq!(a.get_usize("seeds", 20).unwrap(), 5);
        assert_eq!(a.get_or("axis", "all"), "size");
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn rejects_missing_value_and_bad_numbers() {
        assert!(CliArgs::parse(["x".to_string(), "--seeds".to_string()], &[]).is_err());
        let a = args("x --seeds five");
        assert!(a.get_usize("seeds", 1).is_err());
    }

    #[test]
    fn schemes_parsing() {
        assert_eq!(args("x").schemes().unwrap().len(), SchemeKind::PAPER.len());
        let picked = args("x --schemes vp,ap").schemes().unwrap();
        assert_eq!(picked, vec![SchemeKind::Vp, SchemeKind::Ap]);
        assert!(args("x --schemes bogus").schemes().is_err());
    }

    #[test]
    fn run_config_from_json() {
        let j = Json::parse(
            r#"{"problem":"synthetic","nodes":12,"topology":"ring",
                "scheme":"admm-vp+ap","eta0":5.0,"t_max":25,"backend":"native"}"#,
        )
        .unwrap();
        let cfg = RunConfig::from_json(&j).unwrap();
        assert_eq!(cfg.nodes, 12);
        assert_eq!(cfg.topology, Topology::Ring);
        assert_eq!(cfg.scheme, SchemeKind::VpAp);
        assert_eq!(cfg.params.eta0, 5.0);
        assert_eq!(cfg.params.t_max, 25);
        assert_eq!(cfg.backend, "native");
    }
}
