//! Native Rust implementation of the D-PPCA node computation.
//!
//! This mirrors `python/compile/model.py` *operation for operation* (the
//! integration tests assert both paths agree to ~1e-9): masked moments,
//! marginal NLL via the matrix-determinant lemma / Woodbury identity in
//! M×M space, and the consensus M-step derived from the paper's eq. 15.

use super::model::{Moments, PpcaParams};
use crate::error::{Error, Result};
use crate::linalg::{Cholesky, Mat};

const LOG_2PI: f64 = 1.8378770664093453;

/// Masked raw moments of a (D, N) sample block (oracle for the L1 kernel).
pub fn moments(x: &Mat, mask: &[f64]) -> Moments {
    let (d, n_cols) = x.shape();
    assert_eq!(mask.len(), n_cols, "mask length");
    let mut n = 0.0;
    let mut sx = vec![0.0; d];
    let mut sxx = Mat::zeros(d, d);
    for k in 0..n_cols {
        let m = mask[k];
        if m == 0.0 {
            continue;
        }
        n += m;
        for i in 0..d {
            let xi = m * x[(i, k)];
            sx[i] += xi;
            // rank-1 update on the upper triangle, mirrored below
            for j in i..d {
                sxx[(i, j)] += xi * x[(j, k)];
            }
        }
    }
    for i in 0..d {
        for j in 0..i {
            sxx[(i, j)] = sxx[(j, i)];
        }
    }
    Moments { n, sx, sxx }
}

/// `M = WᵀW + a⁻¹I` factored; returns (M⁻¹, log|M|).
fn latent_gram_inverse(w: &Mat, a: f64) -> Result<(Mat, f64)> {
    let m = w.cols();
    let mut mmat = w.t_matmul(w);
    for i in 0..m {
        mmat[(i, i)] += 1.0 / a;
    }
    let ch = Cholesky::new(&mmat)?;
    Ok((ch.inverse(), ch.logdet()))
}

/// Marginal PPCA negative log-likelihood −log p(X | W, μ, a).
pub fn marginal_nll(mom: &Moments, p: &PpcaParams) -> Result<f64> {
    if !(p.a > 0.0) || !p.a.is_finite() {
        return Err(Error::Numeric(format!("nll: invalid precision a={}", p.a)));
    }
    let (d, m) = (p.d(), p.m());
    let (minv, logdet_m) = latent_gram_inverse(&p.w, p.a)?;
    let s = mom.centred_scatter(&p.mu);
    let wtsw = p.w.t_matmul(&s.matmul(&p.w));
    let tr_term = p.a * (s.trace() - minv.fro_dot(&wtsw));
    let logdet_c = (m as f64 - d as f64) * p.a.ln() + logdet_m;
    Ok(0.5 * (mom.n * d as f64 * LOG_2PI + mom.n * logdet_c + tr_term))
}

/// One E-step + consensus M-step (paper eq. 15 and its W/a analogues).
///
/// `eta_w` carries the aggregates Σ_j η_ij (θ_i + θ_j) in its (w, mu, a)
/// slots; `eta_sum` is Σ_j η_ij; `mult` holds (λ, γ, β).
pub fn node_update(mom: &Moments, p: &PpcaParams, mult: &PpcaParams,
                   eta_sum: f64, eta_w: &PpcaParams) -> Result<(PpcaParams, f64)> {
    let (d, m) = (p.d(), p.m());
    let n = mom.n;

    // ---- E-step aggregates (old parameters) ------------------------------
    let (minv, _) = latent_gram_inverse(&p.w, p.a)?;
    let s_old = mom.centred_scatter(&p.mu);
    let sw = s_old.matmul(&p.w);
    let cxz = sw.matmul(&minv); // Σ (x−μ)E[z]ᵀ           (D, M)
    let wtssw = p.w.t_matmul(&sw);
    let mut ezz_sum = minv.matmul(&wtssw).matmul(&minv); // Σ E[zzᵀ]  (M, M)
    ezz_sum.axpy(n / p.a, &minv);
    // Σ E[z] = M⁻¹Wᵀ(sx − nμ)
    let centred_sum: Vec<f64> = (0..d).map(|k| mom.sx[k] - n * p.mu[k]).collect();
    let sz = minv.matvec(&p.w.t_matvec(&centred_sum));

    // ---- W update ---------------------------------------------------------
    let mut numer_w = cxz.scale(p.a);
    numer_w.axpy(-2.0, &mult.w);
    numer_w += &eta_w.w;
    let mut denom_w = ezz_sum.scale(p.a);
    for i in 0..m {
        denom_w[(i, i)] += 2.0 * eta_sum;
    }
    let denom_inv = Cholesky::new(&denom_w)?.inverse();
    let w_new = numer_w.matmul(&denom_inv);

    // ---- μ update (fresh W; paper eq. 15) ---------------------------------
    let w_sz = w_new.matvec(&sz);
    let denom_mu = n * p.a + 2.0 * eta_sum;
    let mu_new: Vec<f64> = (0..d)
        .map(|k| (p.a * (mom.sx[k] - w_sz[k]) - 2.0 * mult.mu[k] + eta_w.mu[k]) / denom_mu)
        .collect();

    // ---- a update: positive root of A·a² + B·a − C = 0 --------------------
    let s_new = mom.centred_scatter(&mu_new);
    // Σ (x−μ_new)E[z]ᵀ = cxz + (μ_old − μ_new) szᵀ
    let mu_diff: Vec<f64> = (0..d).map(|k| p.mu[k] - mu_new[k]).collect();
    let mut cxz_new = cxz.clone();
    cxz_new += &Mat::outer(&mu_diff, &sz);
    let c_sum = s_new.trace() - 2.0 * w_new.fro_dot(&cxz_new)
        + w_new.t_matmul(&w_new).fro_dot(&ezz_sum);
    let a_coef = 2.0 * eta_sum;
    let b_coef = 2.0 * mult.a + 0.5 * c_sum - eta_w.a;
    let c_coef = n * d as f64 / 2.0;
    let a_new = if a_coef > 1e-12 {
        let disc = (b_coef * b_coef + 4.0 * a_coef * c_coef).sqrt();
        (disc - b_coef) / (2.0 * a_coef)
    } else {
        c_coef / b_coef
    };
    if !(a_new > 0.0) || !a_new.is_finite() {
        return Err(Error::Numeric(format!("node_update: a⁺ = {a_new}")));
    }

    let p_new = PpcaParams { w: w_new, mu: mu_new, a: a_new };
    let nll = marginal_nll(mom, &p_new)?;
    Ok((p_new, nll))
}

/// Posterior means E[z_k] = M⁻¹Wᵀ(x_k − μ) for every masked sample
/// (oracle for the L1 `estep_z` kernel). Masked columns are zero.
pub fn estep_z(x: &Mat, mask: &[f64], p: &PpcaParams) -> Result<Mat> {
    let (d, n_cols) = x.shape();
    let m = p.m();
    let (minv, _) = latent_gram_inverse(&p.w, p.a)?;
    let pw = minv.matmul_t(&p.w); // (M, D)
    let mut z = Mat::zeros(m, n_cols);
    for k in 0..n_cols {
        if mask[k] == 0.0 {
            continue;
        }
        let xc: Vec<f64> = (0..d).map(|r| (x[(r, k)] - p.mu[r]) * mask[k]).collect();
        z.set_col(k, &pw.matvec(&xc));
    }
    Ok(z)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg;

    fn random_setup(rng: &mut Pcg, d: usize, m: usize, n: usize)
                    -> (Mat, Vec<f64>, PpcaParams) {
        let x = Mat::randn(d, n, rng);
        let mask: Vec<f64> = (0..n).map(|_| f64::from(rng.f64() < 0.8)).collect();
        let p = PpcaParams {
            w: Mat::randn(d, m, rng),
            mu: rng.normal_vec(d),
            a: rng.range(0.3, 3.0),
        };
        (x, mask, p)
    }

    #[test]
    fn moments_match_naive() {
        prop::check("masked moments", |rng| {
            let (d, n) = (2 + rng.below(6), 1 + rng.below(12));
            let (x, mask, _) = random_setup(rng, d, 1, n);
            let mom = moments(&x, &mask);
            let n_direct: f64 = mask.iter().sum();
            assert!((mom.n - n_direct).abs() < 1e-12);
            for i in 0..d {
                let direct: f64 = (0..n).map(|k| mask[k] * x[(i, k)]).sum();
                assert!((mom.sx[i] - direct).abs() < 1e-10);
            }
        });
    }

    #[test]
    fn nll_matches_dense_gaussian() {
        prop::check("Woodbury NLL = dense NLL", |rng| {
            let (d, m, n) = (3 + rng.below(5), 1 + rng.below(3), 5 + rng.below(10));
            let (x, mask, p) = random_setup(rng, d, m, n);
            let mom = moments(&x, &mask);
            let got = marginal_nll(&mom, &p).unwrap();
            // dense evaluation: C = WWᵀ + a⁻¹I
            let mut c = p.w.matmul_t(&p.w);
            for i in 0..d {
                c[(i, i)] += 1.0 / p.a;
            }
            let ch = Cholesky::new(&c).unwrap();
            let cinv = ch.inverse();
            let s = mom.centred_scatter(&p.mu);
            let want = 0.5 * (mom.n * d as f64 * LOG_2PI + mom.n * ch.logdet()
                + cinv.fro_dot(&s));
            assert!((got - want).abs() < 1e-8 * want.abs().max(1.0),
                    "{got} vs {want}");
        });
    }

    #[test]
    fn centralized_em_monotone() {
        prop::check_named("EM decreases marginal NLL", 16, |rng| {
            let (d, m, n) = (6, 2, 40);
            let x = Mat::randn(d, n, rng);
            let mask = vec![1.0; n];
            let mom = moments(&x, &mask);
            let zeros = PpcaParams::zeros(d, m);
            let mut p = PpcaParams {
                w: Mat::randn(d, m, rng),
                mu: rng.normal_vec(d),
                a: 1.0,
            };
            let mut prev = marginal_nll(&mom, &p).unwrap();
            for _ in 0..30 {
                let (p_new, nll) = node_update(&mom, &p, &zeros, 0.0, &zeros).unwrap();
                assert!(nll <= prev + 1e-7, "{nll} > {prev}");
                prev = nll;
                p = p_new;
            }
        });
    }

    #[test]
    fn huge_penalty_pins_to_target() {
        let mut rng = Pcg::seed(4);
        let (d, m, n) = (5, 2, 30);
        let x = Mat::randn(d, n, &mut rng);
        let mom = moments(&x, &vec![1.0; n]);
        let p = PpcaParams { w: Mat::randn(d, m, &mut rng), mu: rng.normal_vec(d), a: 1.0 };
        let target = PpcaParams { w: Mat::randn(d, m, &mut rng), mu: rng.normal_vec(d), a: 2.0 };
        let eta = 1e8;
        let mut eta_w = PpcaParams {
            w: (&p.w + &target.w).scale(eta),
            mu: p.mu.iter().zip(&target.mu).map(|(a, b)| eta * (a + b)).collect(),
            a: eta * (p.a + target.a),
        };
        eta_w.a = eta * (p.a + target.a);
        let zeros = PpcaParams::zeros(d, m);
        let (p_new, _) = node_update(&mom, &p, &zeros, eta, &eta_w).unwrap();
        let mid_w = (&p.w + &target.w).scale(0.5);
        assert!(p_new.w.max_abs_diff(&mid_w) < 1e-4);
        assert!((p_new.a - (p.a + target.a) / 2.0).abs() < 1e-4);
    }

    #[test]
    fn estep_z_reconstructs_latents() {
        // x = Wz exactly, huge a → posterior mean ≈ z (shrunk by M⁻¹WᵀW)
        let mut rng = Pcg::seed(6);
        let (d, m, n) = (10, 3, 8);
        let w = Mat::randn(d, m, &mut rng);
        let z_true = Mat::randn(m, n, &mut rng);
        let x = w.matmul(&z_true);
        let p = PpcaParams { w: w.clone(), mu: vec![0.0; d], a: 1e9 };
        let z = estep_z(&x, &vec![1.0; n], &p).unwrap();
        assert!(z.max_abs_diff(&z_true) < 1e-5);
    }

    #[test]
    fn estep_z_zeroes_masked_columns() {
        let mut rng = Pcg::seed(7);
        let (x, _, p) = random_setup(&mut rng, 6, 2, 9);
        let mask = vec![1.0, 0.0, 1.0, 0.0, 0.0, 1.0, 1.0, 0.0, 1.0];
        let z = estep_z(&x, &mask, &p).unwrap();
        for (k, &mk) in mask.iter().enumerate() {
            if mk == 0.0 {
                assert!(z.col(k).iter().all(|&v| v == 0.0));
            }
        }
    }

    #[test]
    fn invalid_precision_rejected() {
        let mom = Moments { n: 3.0, sx: vec![0.0; 2], sxx: Mat::eye(2) };
        let p = PpcaParams { w: Mat::zeros(2, 1), mu: vec![0.0; 2], a: -1.0 };
        assert!(marginal_nll(&mom, &p).is_err());
    }
}
