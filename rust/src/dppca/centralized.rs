//! Centralized PPCA baselines (single node, no consensus).

use super::em;
use super::model::{Moments, PpcaParams};
use crate::error::Result;
use crate::linalg::Mat;
use crate::util::rng::Pcg;

/// Result of a centralized EM fit.
#[derive(Debug, Clone)]
pub struct CentralizedFit {
    pub params: PpcaParams,
    pub nll: f64,
    pub iterations: usize,
    pub converged: bool,
}

/// Fit PPCA by EM on pooled data (the `η ≡ 0` special case of the
/// consensus node update — shares all math with the distributed path).
pub fn centralized_em(x: &Mat, m: usize, tol: f64, max_iters: usize,
                      rng: &mut Pcg) -> Result<CentralizedFit> {
    let d = x.rows();
    let mask = vec![1.0; x.cols()];
    let mom = em::moments(x, &mask);
    centralized_em_moments(&mom, d, m, tol, max_iters, rng)
}

/// EM from precomputed moments.
pub fn centralized_em_moments(mom: &Moments, d: usize, m: usize, tol: f64,
                              max_iters: usize, rng: &mut Pcg)
                              -> Result<CentralizedFit> {
    let zeros = PpcaParams::zeros(d, m);
    let mut params = PpcaParams {
        w: Mat::randn(d, m, rng),
        mu: mom.mean(),
        a: 1.0,
    };
    let mut nll = em::marginal_nll(mom, &params)?;
    let mut converged = false;
    let mut iterations = 0;
    for it in 0..max_iters {
        let (p_new, nll_new) = em::node_update(mom, &params, &zeros, 0.0, &zeros)?;
        iterations = it + 1;
        let rel = (nll - nll_new).abs() / nll.abs().max(1e-12);
        params = p_new;
        nll = nll_new;
        if rel < tol {
            converged = true;
            break;
        }
    }
    Ok(CentralizedFit { params, nll, iterations, converged })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SubspaceSpec;
    use crate::linalg::max_principal_angle_deg;

    #[test]
    fn recovers_planted_subspace() {
        let spec = SubspaceSpec { d: 12, m: 3, n: 300, noise_var: 0.1, random_mean: false };
        let data = spec.generate(&mut Pcg::seed(2));
        let fit = centralized_em(&data.x, 3, 1e-9, 3000, &mut Pcg::seed(3)).unwrap();
        assert!(fit.converged);
        let angle = max_principal_angle_deg(&fit.params.w, &data.w_true).unwrap();
        assert!(angle < 3.0, "angle {angle}");
        // noise precision ≈ 1/0.1
        assert!((1.0 / fit.params.a - 0.1).abs() < 0.05, "σ² = {}", 1.0 / fit.params.a);
    }

    #[test]
    fn independent_restarts_agree_on_subspace() {
        let spec = SubspaceSpec { d: 10, m: 2, n: 200, noise_var: 0.05, random_mean: true };
        let data = spec.generate(&mut Pcg::seed(5));
        let f1 = centralized_em(&data.x, 2, 1e-10, 800, &mut Pcg::seed(10)).unwrap();
        let f2 = centralized_em(&data.x, 2, 1e-10, 800, &mut Pcg::seed(11)).unwrap();
        let angle = max_principal_angle_deg(&f1.params.w, &f2.params.w).unwrap();
        assert!(angle < 0.5, "restart disagreement {angle}°");
    }
}
