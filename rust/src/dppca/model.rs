//! PPCA parameter containers and flattening.

use crate::linalg::Mat;

/// PPCA parameters θ = (W ∈ R^{D×M}, μ ∈ R^D, a > 0).
///
/// The same container also carries the Lagrange multipliers (λ, γ, β) and
/// the η-weighted neighbour sums, which share the (D×M, D, scalar) shape.
#[derive(Debug, Clone, PartialEq)]
pub struct PpcaParams {
    pub w: Mat,
    pub mu: Vec<f64>,
    pub a: f64,
}

impl PpcaParams {
    /// All-zero container (multiplier initialization).
    pub fn zeros(d: usize, m: usize) -> PpcaParams {
        PpcaParams { w: Mat::zeros(d, m), mu: vec![0.0; d], a: 0.0 }
    }

    pub fn d(&self) -> usize {
        self.w.rows()
    }

    pub fn m(&self) -> usize {
        self.w.cols()
    }

    /// Flattened dimension D·M + D + 1.
    pub fn flat_dim(d: usize, m: usize) -> usize {
        d * m + d + 1
    }

    /// Flatten as [vec(W) row-major | μ | a].
    pub fn flatten(&self) -> Vec<f64> {
        let mut out = vec![0.0; Self::flat_dim(self.d(), self.m())];
        self.flatten_into(&mut out);
        out
    }

    /// [`PpcaParams::flatten`] into a caller-owned buffer (the hot-loop
    /// variant behind `DppcaSolver::solve_into`: the buffer survives
    /// across iterations, so steady-state flattening allocates nothing).
    pub fn flatten_into(&self, out: &mut [f64]) {
        let dm = self.w.data().len();
        let d = self.mu.len();
        assert_eq!(out.len(), dm + d + 1, "flatten_into length");
        out[..dm].copy_from_slice(self.w.data());
        out[dm..dm + d].copy_from_slice(&self.mu);
        out[dm + d] = self.a;
    }

    /// Inverse of [`flatten`].
    pub fn unflatten(d: usize, m: usize, flat: &[f64]) -> PpcaParams {
        assert_eq!(flat.len(), Self::flat_dim(d, m), "unflatten length");
        PpcaParams {
            w: Mat::from_rows(d, m, &flat[..d * m]),
            mu: flat[d * m..d * m + d].to_vec(),
            a: flat[d * m + d],
        }
    }
}

/// Masked raw moments of a node's data block (output of the L1 kernel):
/// `n = Σ m_k`, `sx = Σ m_k x_k`, `sxx = Σ m_k x_k x_kᵀ`.
#[derive(Debug, Clone, PartialEq)]
pub struct Moments {
    pub n: f64,
    pub sx: Vec<f64>,
    pub sxx: Mat,
}

impl Moments {
    pub fn d(&self) -> usize {
        self.sx.len()
    }

    /// Centred scatter S(μ) = Sxx − sx μᵀ − μ sxᵀ + n μμᵀ.
    pub fn centred_scatter(&self, mu: &[f64]) -> Mat {
        let d = self.d();
        let mut s = self.sxx.clone();
        for i in 0..d {
            for j in 0..d {
                s[(i, j)] += -self.sx[i] * mu[j] - mu[i] * self.sx[j]
                    + self.n * mu[i] * mu[j];
            }
        }
        s
    }

    /// Sample mean (undefined for empty blocks → zeros).
    pub fn mean(&self) -> Vec<f64> {
        if self.n <= 0.0 {
            return vec![0.0; self.d()];
        }
        self.sx.iter().map(|x| x / self.n).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg;

    #[test]
    fn flatten_roundtrip() {
        prop::check("unflatten ∘ flatten = id", |rng| {
            let d = 2 + rng.below(8);
            let m = 1 + rng.below(d.min(4));
            let p = PpcaParams {
                w: Mat::randn(d, m, rng),
                mu: rng.normal_vec(d),
                a: rng.range(0.1, 5.0),
            };
            let q = PpcaParams::unflatten(d, m, &p.flatten());
            assert_eq!(p, q);
            assert_eq!(p.flatten().len(), PpcaParams::flat_dim(d, m));
        });
    }

    #[test]
    fn centred_scatter_matches_direct() {
        prop::check("S(μ) from moments = direct Σ(x−μ)(x−μ)ᵀ", |rng| {
            let d = 2 + rng.below(5);
            let n = 3 + rng.below(10);
            let x = Mat::randn(d, n, rng);
            let mu = rng.normal_vec(d);
            let mom = moments_of(&x);
            let s = mom.centred_scatter(&mu);
            let mut direct = Mat::zeros(d, d);
            for k in 0..n {
                let xc: Vec<f64> = (0..d).map(|r| x[(r, k)] - mu[r]).collect();
                direct += &Mat::outer(&xc, &xc);
            }
            assert!(s.max_abs_diff(&direct) < 1e-9);
        });
    }

    fn moments_of(x: &Mat) -> Moments {
        let (d, n) = x.shape();
        let mut sx = vec![0.0; d];
        let mut sxx = Mat::zeros(d, d);
        for k in 0..n {
            let col = x.col(k);
            for i in 0..d {
                sx[i] += col[i];
            }
            sxx += &Mat::outer(&col, &col);
        }
        Moments { n: n as f64, sx, sxx }
    }

    #[test]
    fn mean_of_empty_block() {
        let m = Moments { n: 0.0, sx: vec![0.0; 3], sxx: Mat::zeros(3, 3) };
        assert_eq!(m.mean(), vec![0.0; 3]);
        let mut rng = Pcg::seed(1);
        let x = Mat::randn(3, 5, &mut rng);
        let mom = moments_of(&x);
        let mean = mom.mean();
        for i in 0..3 {
            let direct: f64 = x.row(i).iter().sum::<f64>() / 5.0;
            assert!((mean[i] - direct).abs() < 1e-12);
        }
    }
}
