//! Distributed probabilistic PCA (the paper's §4 application).
//!
//! The local per-node computation (E-step + consensus M-step + marginal
//! NLL) exists twice, by design:
//!
//! * the **lowered XLA artifacts** built from `python/compile/model.py`
//!   (JAX L2 calling the Pallas L1 kernels) — the production path, driven
//!   through [`crate::runtime::XlaBackend`];
//! * the **native Rust oracle** in [`em`] — the identical math on
//!   [`crate::linalg`], used by [`crate::runtime::NativeBackend`] for
//!   artifact-free tests, threaded-coordinator runs, and as a
//!   cross-validation oracle for the artifacts (see
//!   `rust/tests/integration_runtime.rs`).
//!
//! [`DppcaSolver`] adapts either backend to the consensus engine's
//! [`crate::consensus::LocalSolver`] interface by flattening
//! θ = (W, μ, a) into a single parameter vector.

pub mod centralized;
pub mod em;
mod model;
mod solver;

pub use centralized::{centralized_em, CentralizedFit};
pub use model::{Moments, PpcaParams};
pub use solver::{DppcaSolver, InitStrategy, UpdateMode};
