//! Adapter: a D-PPCA node as a consensus-engine [`LocalSolver`].

use super::model::{Moments, PpcaParams};
use crate::consensus::LocalSolver;
use crate::linalg::Mat;
use crate::runtime::SharedBackend;
use crate::util::rng::Pcg;

/// Parameter initialization policy (paper: "randomly initialize
/// W_i⁰, μ_i⁰, a_i⁰" — restart variance comes through `rng`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitStrategy {
    /// W ~ N(0,1), μ ~ N(0,1), a = 1 (the paper's fully random setting).
    Random,
    /// W ~ N(0,1), μ = local sample mean, a = 1 (practical warm start;
    /// used by the ablation A-init).
    DataMean,
    /// Local Tipping-Bishop solution (top-M eigenvectors of the node's own
    /// scatter) plus a seed-dependent perturbation. Random init puts EM on
    /// a long saddle for high-dimensional pixel-scale SfM data; starting
    /// from each node's *local* ML leaves the consensus dynamics — the
    /// paper's subject — as the dominant transient. Restart variance comes
    /// from the perturbation.
    LocalPca,
}

/// Which artifact serves the per-iteration update.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UpdateMode {
    /// L1 moments kernel once at construction, per-iteration work on the
    /// cached moments (exact refactoring for fully observed data;
    /// DESIGN.md §Perf headline).
    CachedMoments,
    /// Full pass over the raw block every iteration (the paper's
    /// per-iteration cost model).
    Direct,
}

/// One node's local PPCA problem bound to a compute backend.
pub struct DppcaSolver {
    x: Mat,
    mask: Vec<f64>,
    mom: Moments,
    d: usize,
    m: usize,
    backend: SharedBackend,
    init: InitStrategy,
    mode: UpdateMode,
    /// (θ⁺, nll) of the most recent solve — lets `objective(θ⁺)` reuse the
    /// NLL the update artifact already produced instead of re-executing
    last_solve: Option<(Vec<f64>, f64)>,
}

impl DppcaSolver {
    /// Build a node from its padded data block and 0/1 sample mask.
    pub fn new(x: Mat, mask: Vec<f64>, m: usize, backend: SharedBackend)
               -> crate::Result<DppcaSolver> {
        assert_eq!(x.cols(), mask.len(), "mask length");
        let d = x.rows();
        let mom = backend.borrow_mut().moments(&x, &mask)?;
        Ok(DppcaSolver {
            x,
            mask,
            mom,
            d,
            m,
            backend,
            init: InitStrategy::Random,
            mode: UpdateMode::CachedMoments,
            last_solve: None,
        })
    }

    /// Convenience: unpadded block (all columns valid).
    pub fn from_block(x: Mat, m: usize, backend: SharedBackend)
                      -> crate::Result<DppcaSolver> {
        let mask = vec![1.0; x.cols()];
        Self::new(x, mask, m, backend)
    }

    /// Pad a block to `n_padded` columns with a matching mask (artifact
    /// shapes are padded; see `python/compile/shapes.py`).
    pub fn from_padded_block(x: &Mat, n_padded: usize, m: usize,
                             backend: SharedBackend) -> crate::Result<DppcaSolver> {
        assert!(x.cols() <= n_padded, "block wider than padding");
        let mut xp = Mat::zeros(x.rows(), n_padded);
        for r in 0..x.rows() {
            xp.row_mut(r)[..x.cols()].copy_from_slice(x.row(r));
        }
        let mut mask = vec![0.0; n_padded];
        mask[..x.cols()].iter_mut().for_each(|v| *v = 1.0);
        Self::new(xp, mask, m, backend)
    }

    pub fn with_init(mut self, init: InitStrategy) -> Self {
        self.init = init;
        self
    }

    pub fn with_mode(mut self, mode: UpdateMode) -> Self {
        self.mode = mode;
        self
    }

    pub fn moments(&self) -> &Moments {
        &self.mom
    }

    /// Extract the node's posterior latents under `params` (the
    /// reconstructed structure in the SfM experiments).
    pub fn latents(&self, params: &PpcaParams) -> crate::Result<Mat> {
        self.backend.borrow_mut().estep_z(&self.x, &self.mask, params)
    }

    /// Unflatten an engine parameter vector into PPCA shape.
    pub fn unflatten(&self, flat: &[f64]) -> PpcaParams {
        PpcaParams::unflatten(self.d, self.m, flat)
    }

    /// Local Tipping-Bishop ML + perturbation (see [`InitStrategy::LocalPca`]).
    fn local_pca_init(&self, rng: &mut Pcg) -> Vec<f64> {
        let mean = self.mom.mean();
        let n = self.mom.n.max(1.0);
        let scatter = self.mom.centred_scatter(&mean);
        let p = match crate::linalg::Svd::new(&scatter) {
            Ok(svd) => {
                // eigenvalues of the covariance = scatter singular values / n
                let eig: Vec<f64> = svd.s.iter().map(|s| s / n).collect();
                let m_eff = self.m.min(eig.len());
                // σ² from the *nonzero* tail spectrum only: with N_i ≤ D the
                // scatter has rank ≤ N_i − 1 and the trailing zeros would
                // drive σ² → 0 (a → ∞, an overconfident degenerate start);
                // floor relative to the top eigenvalue for the same reason
                let rank = ((n as usize).saturating_sub(1)).min(eig.len()).max(m_eff);
                let tail = &eig[m_eff..rank];
                let sigma2_raw = if tail.is_empty() {
                    0.1 * eig.get(m_eff.saturating_sub(1)).copied().unwrap_or(1.0)
                } else {
                    tail.iter().sum::<f64>() / tail.len() as f64
                };
                let sigma2 = sigma2_raw.max(1e-4 * eig[0]).max(1e-6);
                let mut w = Mat::zeros(self.d, self.m);
                for k in 0..m_eff {
                    let scale = (eig[k] - sigma2).max(1e-6).sqrt();
                    let col = svd.u.col(k);
                    for r in 0..self.d {
                        w[(r, k)] = scale * col[r];
                    }
                }
                // seed-dependent perturbation = the run's restart variance
                let pert = 0.2 * w.fro_norm() / ((self.d * self.m) as f64).sqrt();
                w += &Mat::randn(self.d, self.m, rng).scale(pert);
                PpcaParams { w, mu: mean.clone(), a: 1.0 / sigma2 }
            }
            Err(_) => PpcaParams {
                w: Mat::randn(self.d, self.m, rng),
                mu: mean.clone(),
                a: 1.0,
            },
        };
        p.flatten()
    }
}

impl LocalSolver for DppcaSolver {
    fn dim(&self) -> usize {
        PpcaParams::flat_dim(self.d, self.m)
    }

    fn initial_param(&mut self, rng: &mut Pcg) -> Vec<f64> {
        if self.init == InitStrategy::LocalPca {
            return self.local_pca_init(rng);
        }
        let mu = match self.init {
            InitStrategy::Random => rng.normal_vec(self.d),
            _ => self.mom.mean(),
        };
        PpcaParams { w: Mat::randn(self.d, self.m, rng), mu, a: 1.0 }.flatten()
    }

    fn objective(&mut self, theta: &[f64]) -> f64 {
        if let Some((cached_theta, nll)) = &self.last_solve {
            if cached_theta.as_slice() == theta {
                return *nll;
            }
        }
        let p = PpcaParams::unflatten(self.d, self.m, theta);
        if !(p.a > 0.0) || !p.a.is_finite() {
            return f64::INFINITY; // infeasible foreign parameters
        }
        self.backend
            .borrow_mut()
            .objective(&self.mom, &p)
            .unwrap_or(f64::INFINITY)
    }

    fn objective_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        let params: Vec<PpcaParams> = thetas
            .iter()
            .map(|t| PpcaParams::unflatten(self.d, self.m, t))
            .collect();
        if params.iter().any(|p| !(p.a > 0.0) || !p.a.is_finite()) {
            // fall back to per-item evaluation with infeasibility handling
            return thetas.iter().map(|t| self.objective(t)).collect();
        }
        match self.backend.borrow_mut().objective_batch(&self.mom, &params) {
            Ok(v) => v,
            Err(_) => vec![f64::INFINITY; thetas.len()],
        }
    }

    fn objective_batch_into(&mut self, thetas: &[Vec<f64>], out: &mut Vec<f64>) {
        // keep the single-dispatch batched path (the default would loop
        // scalar objectives and lose the backend's batching)
        let scores = self.objective_batch(thetas);
        out.clear();
        out.extend_from_slice(&scores);
    }

    fn solve(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
             eta_wsum: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; theta.len()];
        self.solve_into(theta, lambda, eta_sum, eta_wsum, &mut out);
        out
    }

    fn solve_into(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
                  eta_wsum: &[f64], out: &mut [f64]) {
        let p = PpcaParams::unflatten(self.d, self.m, theta);
        let mult = PpcaParams::unflatten(self.d, self.m, lambda);
        let eta_w = PpcaParams::unflatten(self.d, self.m, eta_wsum);
        let result = match self.mode {
            UpdateMode::CachedMoments => self
                .backend
                .borrow_mut()
                .node_update(&self.mom, &p, &mult, eta_sum, &eta_w),
            UpdateMode::Direct => self.backend.borrow_mut().node_update_direct(
                &self.x, &self.mask, &p, &mult, eta_sum, &eta_w),
        };
        match result {
            Ok((p_new, nll)) => {
                // refresh the (θ⁺, nll) cache in place where possible so
                // the flatten layer allocates nothing in steady state
                match &mut self.last_solve {
                    Some((flat, cached_nll)) if flat.len() == out.len() => {
                        p_new.flatten_into(flat);
                        *cached_nll = nll;
                        out.copy_from_slice(flat);
                    }
                    slot => {
                        let mut flat = vec![0.0; out.len()];
                        p_new.flatten_into(&mut flat);
                        out.copy_from_slice(&flat);
                        *slot = Some((flat, nll));
                    }
                }
            }
            // a failed local solve keeps the previous parameters (the
            // engine's residuals will reflect the stall); this only fires
            // on numerically degenerate foreign input
            Err(_) => out.copy_from_slice(theta),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{shared, NativeBackend};

    fn sample_block(seed: u64, d: usize, n: usize) -> Mat {
        let mut rng = Pcg::seed(seed);
        Mat::randn(d, n, &mut rng)
    }

    #[test]
    fn padding_matches_unpadded_moments() {
        let backend = shared(NativeBackend::new());
        let x = sample_block(1, 6, 10);
        let a = DppcaSolver::from_block(x.clone(), 2, backend.clone()).unwrap();
        let b = DppcaSolver::from_padded_block(&x, 16, 2, backend).unwrap();
        assert!((a.moments().n - b.moments().n).abs() < 1e-12);
        assert!(a.moments().sxx.max_abs_diff(&b.moments().sxx) < 1e-12);
    }

    #[test]
    fn solve_caches_objective() {
        let backend = shared(NativeBackend::new());
        let x = sample_block(2, 5, 12);
        let mut s = DppcaSolver::from_block(x, 2, backend).unwrap();
        let mut rng = Pcg::seed(3);
        let theta = s.initial_param(&mut rng);
        let dim = theta.len();
        let new = s.solve(&theta, &vec![0.0; dim], 0.0, &vec![0.0; dim]);
        let f_cached = s.objective(&new);
        // force a fresh backend evaluation and compare
        s.last_solve = None;
        let f_direct = s.objective(&new);
        assert!((f_cached - f_direct).abs() < 1e-9, "{f_cached} vs {f_direct}");
    }

    #[test]
    fn solve_into_matches_solve_bitwise() {
        let backend = shared(NativeBackend::new());
        let x = sample_block(8, 5, 12);
        let mut s = DppcaSolver::from_block(x, 2, backend).unwrap();
        let mut rng = Pcg::seed(4);
        let theta = s.initial_param(&mut rng);
        let dim = theta.len();
        let lambda = vec![0.05; dim];
        let eta_wsum: Vec<f64> = theta.iter().map(|v| 24.0 * v).collect();
        let direct = s.solve(&theta, &lambda, 12.0, &eta_wsum);
        let mut buffered = vec![f64::NAN; dim];
        s.solve_into(&theta, &lambda, 12.0, &eta_wsum, &mut buffered);
        assert_eq!(direct, buffered);
        // the (θ⁺, nll) cache refreshed through the into-path still
        // short-circuits objective() to the backend's value
        let f_cached = s.objective(&buffered);
        s.last_solve = None;
        let f_direct = s.objective(&buffered);
        assert!((f_cached - f_direct).abs() < 1e-9, "{f_cached} vs {f_direct}");
    }

    #[test]
    fn infeasible_precision_gives_infinite_objective() {
        let backend = shared(NativeBackend::new());
        let x = sample_block(4, 4, 8);
        let mut s = DppcaSolver::from_block(x, 2, backend).unwrap();
        let mut rng = Pcg::seed(5);
        let mut theta = s.initial_param(&mut rng);
        *theta.last_mut().unwrap() = -3.0; // a < 0
        assert!(s.objective(&theta).is_infinite());
    }

    #[test]
    fn init_strategies_differ_in_mu() {
        let backend = shared(NativeBackend::new());
        let x = sample_block(6, 4, 20);
        let mut s1 = DppcaSolver::from_block(x.clone(), 2, backend.clone())
            .unwrap()
            .with_init(InitStrategy::DataMean);
        let mut rng = Pcg::seed(7);
        let th = s1.initial_param(&mut rng);
        let p = s1.unflatten(&th);
        let mean = s1.moments().mean();
        for (a, b) in p.mu.iter().zip(&mean) {
            assert!((a - b).abs() < 1e-12);
        }
        assert_eq!(p.a, 1.0);
    }
}
