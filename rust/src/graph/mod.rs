//! Communication-graph substrate.
//!
//! The paper's schemes are sensitive to topology (complete vs ring vs
//! cluster — Fig. 2c-e), and ADMM-NAP effectively induces a *dynamic*
//! topology by driving per-edge penalties (Fig. 1c). This module provides
//! the static graph builders, validation, and the effective-topology
//! statistics used to visualize edge influence.

mod builders;
mod graph;
mod live;
mod relabel;
mod sharding;

pub use builders::{random_connected, Topology};
pub use graph::{EdgeId, Graph, NodeId};
pub use live::LiveView;
pub use relabel::{bandwidth, rcm_order, relabel_graph, Relabel};
pub use sharding::{shard_ranges, shard_ranges_in};

/// Effective-influence summary of a penalized graph state: for every edge,
/// the ratio of its penalty to the mean penalty. Values ≪ 1 correspond to
/// the "dotted" (weakly influencing) edges of the paper's Fig. 1c.
pub fn edge_influence(graph: &Graph, eta: impl Fn(NodeId, NodeId) -> f64) -> Vec<(NodeId, NodeId, f64)> {
    let mut raw = Vec::new();
    let mut total = 0.0;
    for (i, j) in graph.directed_edges() {
        let e = eta(i, j);
        total += e;
        raw.push((i, j, e));
    }
    let mean = if raw.is_empty() { 1.0 } else { total / raw.len() as f64 };
    raw.into_iter().map(|(i, j, e)| (i, j, e / mean)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn influence_normalizes_to_unit_mean() {
        let g = Topology::Ring.build(4).unwrap();
        let inf = edge_influence(&g, |i, j| (i + j) as f64 + 1.0);
        let mean: f64 = inf.iter().map(|(_, _, e)| e).sum::<f64>() / inf.len() as f64;
        assert!((mean - 1.0).abs() < 1e-12);
    }
}
