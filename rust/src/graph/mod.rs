//! Communication-graph substrate.
//!
//! The paper's schemes are sensitive to topology (complete vs ring vs
//! cluster — Fig. 2c-e), and ADMM-NAP effectively induces a *dynamic*
//! topology by driving per-edge penalties (Fig. 1c). This module provides
//! the static graph builders, validation, and the effective-topology
//! statistics used to visualize edge influence.
//!
//! ## Memory layout (the million-node contract)
//!
//! [`Graph`] is CSR: one `offsets` array (n + 1 `usize`s) plus one flat
//! `targets` array (2E `NodeId`s) — `8(n + 1) + 16E` bytes of adjacency
//! total, with no per-node heap allocation. `neighbors(i)` is a
//! contiguous sorted slice, so a sweep over `0..n` walks `targets` front
//! to back in streaming order. Everything downstream leans on that:
//!
//! * [`rcm_order`] / [`bandwidth`] make neighbour ids *numerically*
//!   close, which under contiguous sharding makes them *physically*
//!   close in both `targets` and the parameter arena;
//! * [`shard_ranges`] cuts `0..n` into contiguous cost-balanced ranges
//!   (degree-skew capped — see its docs), so each worker's slice of
//!   `targets` and of the arena is a dense block;
//! * for cluster-scale graphs, `rcm_order_in` re-runs RCM inside each
//!   machine's range (hierarchical two-level ordering; see
//!   `cluster::partition`).
//!
//! Rule of thumb at 10^6 nodes, mean degree 4: adjacency ≈ 72 MB,
//! which is dominated by the parameter arena (`dim`-dependent) — see
//! `coordinator`'s module docs for the arena side of the layout.

mod builders;
mod graph;
mod live;
mod relabel;
mod sharding;

pub use builders::{power_law, random_connected, Topology};
pub use graph::{EdgeId, Graph, NodeId};
pub use live::LiveView;
pub use relabel::{bandwidth, rcm_order, rcm_order_in, relabel_graph, Relabel};
pub use sharding::{shard_ranges, shard_ranges_in};

/// Effective-influence summary of a penalized graph state: for every edge,
/// the ratio of its penalty to the mean penalty. Values ≪ 1 correspond to
/// the "dotted" (weakly influencing) edges of the paper's Fig. 1c.
pub fn edge_influence(graph: &Graph, eta: impl Fn(NodeId, NodeId) -> f64) -> Vec<(NodeId, NodeId, f64)> {
    let mut raw = Vec::new();
    let mut total = 0.0;
    for (i, j) in graph.directed_edges() {
        let e = eta(i, j);
        total += e;
        raw.push((i, j, e));
    }
    let mean = if raw.is_empty() { 1.0 } else { total / raw.len() as f64 };
    raw.into_iter().map(|(i, j, e)| (i, j, e / mean)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn influence_normalizes_to_unit_mean() {
        let g = Topology::Ring.build(4).unwrap();
        let inf = edge_influence(&g, |i, j| (i + j) as f64 + 1.0);
        let mean: f64 = inf.iter().map(|(_, _, e)| e).sum::<f64>() / inf.len() as f64;
        assert!((mean - 1.0).abs() < 1e-12);
    }
}
