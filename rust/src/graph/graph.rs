//! Undirected connected graph in CSR (compressed sparse row) storage.
//!
//! ## Memory layout
//!
//! The graph is two flat arrays — no per-node heap `Vec`s:
//!
//! ```text
//! offsets: [0, d0, d0+d1, …, 2E]          (n + 1 entries)
//! targets: [B_0 sorted | B_1 sorted | …]  (2E entries)
//! ```
//!
//! `neighbors(i)` is `&targets[offsets[i]..offsets[i+1]]` — one bounds
//! check, one contiguous cache-friendly slice, and the same *sorted*
//! neighbour order the old `Vec<Vec<NodeId>>` representation exposed, so
//! every caller (RCM, sharding, `edge_slot` binary search, the arenas'
//! slot indexing) works unchanged and bit-identically. At 10^6 nodes the
//! adjacency costs `8(n+1) + 8·2E` bytes total instead of ~70 bytes of
//! `Vec` header + allocator overhead *per node* on top of the payload,
//! and construction is one `O(E log E)` sort instead of the old
//! `O(Σ deg²)` `contains`-dedup (quadratic at a power-law hub).
//!
//! The directed-edge list is no longer materialized: `directed_edges()`
//! walks the CSR rows, which *is* the (i, j)-sorted order the old list
//! stored (32 bytes per directed edge saved).

use crate::error::{Error, Result};

/// Node index.
pub type NodeId = usize;
/// Index into the directed-edge list.
pub type EdgeId = usize;

/// An undirected graph stored in CSR form (see module docs).
///
/// Invariants (enforced by [`Graph::new`]):
/// * symmetric: `j ∈ B_i ⇔ i ∈ B_j`
/// * irreflexive: no self-loops
/// * per-row sorted, deduplicated neighbour lists
/// * connected (required by consensus ADMM for a consistent consensus)
#[derive(Debug, Clone)]
pub struct Graph {
    /// CSR row offsets: node i's neighbours live at
    /// `targets[offsets[i]..offsets[i+1]]`; `offsets[n] == 2E`.
    offsets: Vec<usize>,
    /// Flat neighbour array, sorted ascending within each row.
    targets: Vec<NodeId>,
    /// `targets.len() / 2`
    undirected_count: usize,
}

impl Graph {
    /// Build and validate from undirected edge pairs (parallel edges are
    /// deduplicated; order of the input list is irrelevant).
    pub fn new(n: usize, edges: &[(NodeId, NodeId)]) -> Result<Graph> {
        if n == 0 {
            return Err(Error::Config("graph: zero nodes".into()));
        }
        // normalize to (min, max), validating as we go
        let mut pairs: Vec<(NodeId, NodeId)> = Vec::with_capacity(edges.len());
        for &(i, j) in edges {
            if i >= n || j >= n {
                return Err(Error::Config(format!("graph: edge ({i},{j}) out of range")));
            }
            if i == j {
                return Err(Error::Config(format!("graph: self-loop at {i}")));
            }
            pairs.push(if i < j { (i, j) } else { (j, i) });
        }
        pairs.sort_unstable();
        pairs.dedup();

        // degree counts → prefix-sum offsets → fill (two passes, no sort
        // needed for the rows: pairs are (i, j)-sorted, so each row first
        // receives its smaller-id neighbours in ascending order via the
        // second-endpoint sweep interleaved below, then … see the proof
        // in the fill loop comment)
        let mut offsets = vec![0usize; n + 1];
        for &(i, j) in &pairs {
            offsets[i + 1] += 1;
            offsets[j + 1] += 1;
        }
        for k in 0..n {
            offsets[k + 1] += offsets[k];
        }
        let mut cursor = offsets.clone();
        let mut targets = vec![0 as NodeId; offsets[n]];
        // pairs are sorted by (i, j) with i < j. For a fixed node v, every
        // pair (u, v) with u < v precedes every pair (v, w), and within
        // each group the other endpoint ascends — so row v is filled in
        // ascending neighbour order without a per-row sort.
        for &(i, j) in &pairs {
            targets[cursor[i]] = j;
            cursor[i] += 1;
            targets[cursor[j]] = i;
            cursor[j] += 1;
        }

        let g = Graph { undirected_count: pairs.len(), offsets, targets };
        if n > 1 && !g.is_connected() {
            return Err(Error::Config("graph: not connected".into()));
        }
        Ok(g)
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.offsets.len() == 1
    }

    /// One-hop neighbours B_i (sorted).
    pub fn neighbors(&self, i: NodeId) -> &[NodeId] {
        &self.targets[self.offsets[i]..self.offsets[i + 1]]
    }

    /// Degree |B_i|.
    pub fn degree(&self, i: NodeId) -> usize {
        self.offsets[i + 1] - self.offsets[i]
    }

    /// Number of undirected edges.
    pub fn edge_count(&self) -> usize {
        self.undirected_count
    }

    /// All directed edges (i, j); each undirected edge appears twice.
    /// Deterministic order: sorted by (i, j) — a row-major CSR walk.
    pub fn directed_edges(&self) -> impl Iterator<Item = (NodeId, NodeId)> + '_ {
        (0..self.len())
            .flat_map(move |i| self.neighbors(i).iter().map(move |&j| (i, j)))
    }

    /// Index of directed edge (i, j) within node i's neighbour list.
    pub fn edge_slot(&self, i: NodeId, j: NodeId) -> Option<usize> {
        self.neighbors(i).binary_search(&j).ok()
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        let n = self.len();
        if n == 0 {
            return false;
        }
        let mut seen = vec![false; n];
        let mut queue = std::collections::VecDeque::from([0usize]);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for &v in self.neighbors(u) {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        count == n
    }

    /// Graph diameter (longest shortest path); O(V·E) BFS from each node.
    pub fn diameter(&self) -> usize {
        let mut best = 0;
        for s in 0..self.len() {
            let mut dist = vec![usize::MAX; self.len()];
            dist[s] = 0;
            let mut queue = std::collections::VecDeque::from([s]);
            while let Some(u) = queue.pop_front() {
                for &v in self.neighbors(u) {
                    if dist[v] == usize::MAX {
                        dist[v] = dist[u] + 1;
                        queue.push_back(v);
                    }
                }
            }
            best = best.max(dist.iter().copied().max().unwrap_or(0));
        }
        best
    }

    /// Mean degree (graph-connectivity proxy used in experiment summaries).
    pub fn mean_degree(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        self.targets.len() as f64 / self.len() as f64
    }

    /// Heap bytes held by the CSR arrays (capacity-based; the scale bench
    /// reports this as bytes/node).
    pub fn heap_bytes(&self) -> usize {
        self.offsets.capacity() * std::mem::size_of::<usize>()
            + self.targets.capacity() * std::mem::size_of::<NodeId>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_invariants() {
        let g = Graph::new(4, &[(0, 1), (1, 2), (2, 3)]).unwrap();
        assert_eq!(g.len(), 4);
        assert_eq!(g.edge_count(), 3);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.diameter(), 3);
    }

    #[test]
    fn symmetry_of_directed_edges() {
        let g = Graph::new(5, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0)]).unwrap();
        for (i, j) in g.directed_edges() {
            assert!(g.neighbors(j).contains(&i));
        }
        assert_eq!(g.directed_edges().count(), 2 * g.edge_count());
    }

    #[test]
    fn dedupes_parallel_edges() {
        let g = Graph::new(3, &[(0, 1), (1, 0), (1, 2)]).unwrap();
        assert_eq!(g.edge_count(), 2);
    }

    #[test]
    fn rejects_disconnected() {
        assert!(Graph::new(4, &[(0, 1), (2, 3)]).is_err());
    }

    #[test]
    fn rejects_self_loop_and_range() {
        assert!(Graph::new(3, &[(0, 0)]).is_err());
        assert!(Graph::new(3, &[(0, 5)]).is_err());
    }

    #[test]
    fn singleton_graph_ok() {
        let g = Graph::new(1, &[]).unwrap();
        assert_eq!(g.len(), 1);
        assert_eq!(g.degree(0), 0);
    }

    #[test]
    fn edge_slot_lookup() {
        let g = Graph::new(4, &[(0, 2), (0, 3), (0, 1)]).unwrap();
        assert_eq!(g.edge_slot(0, 1), Some(0));
        assert_eq!(g.edge_slot(0, 2), Some(1));
        assert_eq!(g.edge_slot(0, 3), Some(2));
        assert_eq!(g.edge_slot(1, 2), None);
    }

    // -- CSR ⇔ adjacency-list equivalence -----------------------------------

    /// The seed's representation, kept as the property-test oracle: one
    /// sorted `Vec` per node, `contains`-deduplicated.
    struct AdjListRef {
        adj: Vec<Vec<NodeId>>,
    }

    impl AdjListRef {
        fn new(n: usize, edges: &[(NodeId, NodeId)]) -> AdjListRef {
            let mut adj: Vec<Vec<NodeId>> = vec![Vec::new(); n];
            for &(i, j) in edges {
                if !adj[i].contains(&j) {
                    adj[i].push(j);
                    adj[j].push(i);
                }
            }
            for a in adj.iter_mut() {
                a.sort_unstable();
            }
            AdjListRef { adj }
        }
    }

    #[test]
    fn csr_matches_adjacency_list_reference() {
        crate::util::prop::check("CSR ≡ Vec<Vec> on random graphs", |rng| {
            let n = 2 + rng.below(40);
            // raw random edge set, possibly with duplicates and both
            // orientations — exactly what both constructors must normalize
            let mut edges: Vec<(NodeId, NodeId)> = Vec::new();
            for i in 0..n {
                for j in 0..n {
                    if i != j && rng.f64() < 0.2 {
                        edges.push((i, j));
                    }
                }
            }
            let Ok(g) = Graph::new(n, &edges) else {
                return; // disconnected sample; nothing to compare
            };
            let r = AdjListRef::new(n, &edges);
            let mut expect_directed = Vec::new();
            for i in 0..n {
                assert_eq!(g.neighbors(i), &r.adj[i][..], "row {i}");
                assert_eq!(g.degree(i), r.adj[i].len());
                for (slot, &j) in r.adj[i].iter().enumerate() {
                    assert_eq!(g.edge_slot(i, j), Some(slot));
                    expect_directed.push((i, j));
                }
            }
            assert_eq!(g.directed_edges().collect::<Vec<_>>(), expect_directed);
            assert_eq!(g.edge_count() * 2, expect_directed.len());
        });
    }

    #[test]
    fn heap_bytes_counts_both_arrays() {
        let g = Graph::new(3, &[(0, 1), (1, 2), (2, 0)]).unwrap();
        // ≥ the exact payload; capacity may round up
        assert!(g.heap_bytes() >= 4 * 8 + 6 * 8);
    }
}
