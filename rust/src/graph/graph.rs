//! Undirected connected graph with adjacency lists.

use crate::error::{Error, Result};

/// Node index.
pub type NodeId = usize;
/// Index into the directed-edge list.
pub type EdgeId = usize;

/// An undirected graph stored as sorted adjacency lists.
///
/// Invariants (enforced by [`Graph::new`]):
/// * symmetric: `j ∈ B_i ⇔ i ∈ B_j`
/// * irreflexive: no self-loops
/// * connected (required by consensus ADMM for a consistent consensus)
#[derive(Debug, Clone)]
pub struct Graph {
    adj: Vec<Vec<NodeId>>,
    /// directed edge list (i, j) for all i, j ∈ B_i, in deterministic order
    directed: Vec<(NodeId, NodeId)>,
    /// directed.len() == 2 × undirected edge count
    undirected_count: usize,
}

impl Graph {
    /// Build and validate from undirected edge pairs.
    pub fn new(n: usize, edges: &[(NodeId, NodeId)]) -> Result<Graph> {
        if n == 0 {
            return Err(Error::Config("graph: zero nodes".into()));
        }
        let mut adj: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for &(i, j) in edges {
            if i >= n || j >= n {
                return Err(Error::Config(format!("graph: edge ({i},{j}) out of range")));
            }
            if i == j {
                return Err(Error::Config(format!("graph: self-loop at {i}")));
            }
            if !adj[i].contains(&j) {
                adj[i].push(j);
                adj[j].push(i);
            }
        }
        for a in adj.iter_mut() {
            a.sort_unstable();
        }
        let g = Graph {
            undirected_count: adj.iter().map(|a| a.len()).sum::<usize>() / 2,
            directed: adj
                .iter()
                .enumerate()
                .flat_map(|(i, nb)| nb.iter().map(move |&j| (i, j)))
                .collect(),
            adj,
        };
        if n > 1 && !g.is_connected() {
            return Err(Error::Config("graph: not connected".into()));
        }
        Ok(g)
    }

    /// Number of nodes.
    pub fn len(&self) -> usize {
        self.adj.len()
    }

    pub fn is_empty(&self) -> bool {
        self.adj.is_empty()
    }

    /// One-hop neighbours B_i (sorted).
    pub fn neighbors(&self, i: NodeId) -> &[NodeId] {
        &self.adj[i]
    }

    /// Degree |B_i|.
    pub fn degree(&self, i: NodeId) -> usize {
        self.adj[i].len()
    }

    /// Number of undirected edges.
    pub fn edge_count(&self) -> usize {
        self.undirected_count
    }

    /// All directed edges (i, j); each undirected edge appears twice.
    /// Deterministic order: sorted by (i, j).
    pub fn directed_edges(&self) -> impl Iterator<Item = (NodeId, NodeId)> + '_ {
        self.directed.iter().copied()
    }

    /// Index of directed edge (i, j) within node i's neighbour list.
    pub fn edge_slot(&self, i: NodeId, j: NodeId) -> Option<usize> {
        self.adj[i].binary_search(&j).ok()
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        if self.adj.is_empty() {
            return false;
        }
        let mut seen = vec![false; self.adj.len()];
        let mut queue = std::collections::VecDeque::from([0usize]);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for &v in &self.adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        count == self.adj.len()
    }

    /// Graph diameter (longest shortest path); O(V·E) BFS from each node.
    pub fn diameter(&self) -> usize {
        let mut best = 0;
        for s in 0..self.len() {
            let mut dist = vec![usize::MAX; self.len()];
            dist[s] = 0;
            let mut queue = std::collections::VecDeque::from([s]);
            while let Some(u) = queue.pop_front() {
                for &v in &self.adj[u] {
                    if dist[v] == usize::MAX {
                        dist[v] = dist[u] + 1;
                        queue.push_back(v);
                    }
                }
            }
            best = best.max(dist.iter().copied().max().unwrap_or(0));
        }
        best
    }

    /// Mean degree (graph-connectivity proxy used in experiment summaries).
    pub fn mean_degree(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        self.adj.iter().map(|a| a.len()).sum::<usize>() as f64 / self.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_invariants() {
        let g = Graph::new(4, &[(0, 1), (1, 2), (2, 3)]).unwrap();
        assert_eq!(g.len(), 4);
        assert_eq!(g.edge_count(), 3);
        assert_eq!(g.neighbors(1), &[0, 2]);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.diameter(), 3);
    }

    #[test]
    fn symmetry_of_directed_edges() {
        let g = Graph::new(5, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 0)]).unwrap();
        for (i, j) in g.directed_edges() {
            assert!(g.neighbors(j).contains(&i));
        }
        assert_eq!(g.directed_edges().count(), 2 * g.edge_count());
    }

    #[test]
    fn dedupes_parallel_edges() {
        let g = Graph::new(3, &[(0, 1), (1, 0), (1, 2)]).unwrap();
        assert_eq!(g.edge_count(), 2);
    }

    #[test]
    fn rejects_disconnected() {
        assert!(Graph::new(4, &[(0, 1), (2, 3)]).is_err());
    }

    #[test]
    fn rejects_self_loop_and_range() {
        assert!(Graph::new(3, &[(0, 0)]).is_err());
        assert!(Graph::new(3, &[(0, 5)]).is_err());
    }

    #[test]
    fn singleton_graph_ok() {
        let g = Graph::new(1, &[]).unwrap();
        assert_eq!(g.len(), 1);
        assert_eq!(g.degree(0), 0);
    }

    #[test]
    fn edge_slot_lookup() {
        let g = Graph::new(4, &[(0, 2), (0, 3), (0, 1)]).unwrap();
        assert_eq!(g.edge_slot(0, 1), Some(0));
        assert_eq!(g.edge_slot(0, 2), Some(1));
        assert_eq!(g.edge_slot(0, 3), Some(2));
        assert_eq!(g.edge_slot(1, 2), None);
    }
}
