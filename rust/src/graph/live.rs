//! A mutable liveness overlay over an immutable [`Graph`].
//!
//! The net runtime ([`crate::net`]) needs a topology that changes while a
//! run is in flight: nodes join and leave (scripted churn) and edges get
//! switched off and on (the NAP scheme's effective-topology decisions).
//! Rebuilding a [`Graph`] per change would invalidate every neighbour-slot
//! index held by in-flight node state, so instead the graph stays frozen —
//! it enumerates every node and edge that can *ever* exist — and this view
//! masks subsets of it in and out.
//!
//! Degree-dependent quantities must follow the mask, not the frozen graph:
//! [`LiveView::live_degree`] is what η̄ normalization divides by, and a node
//! whose live degree reaches zero takes the isolated-node semantics of the
//! synchronous runtimes (η̄ = 0, no consensus term). Every mutation bumps a
//! generation counter so derived artifacts — the RCM ordering cached here,
//! or anything a caller keys on [`LiveView::generation`] — invalidate
//! incrementally instead of being recomputed per read.

use super::{Graph, NodeId};

/// Liveness mask over a frozen [`Graph`] (see module docs).
#[derive(Debug, Clone)]
pub struct LiveView {
    graph: Graph,
    node_live: Vec<bool>,
    /// slot_live[i][slot] — whether the directed edge (i, neighbors(i)[slot])
    /// is active. Kept symmetric by the mutators: (i→j) and (j→i) always
    /// agree, like the underlying undirected graph.
    slot_live: Vec<Vec<bool>>,
    generation: u64,
    /// (generation at compute time, live-subgraph RCM order)
    rcm_cache: Option<(u64, Vec<NodeId>)>,
}

impl LiveView {
    /// A view with every node and edge live.
    pub fn new(graph: Graph) -> LiveView {
        let n = graph.len();
        let slot_live = (0..n).map(|i| vec![true; graph.degree(i)]).collect();
        LiveView {
            node_live: vec![true; n],
            slot_live,
            generation: 0,
            rcm_cache: None,
            graph,
        }
    }

    /// The frozen underlying graph.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Bumped by every mutation; key derived artifacts on it.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn node_live(&self, i: NodeId) -> bool {
        self.node_live[i]
    }

    /// Whether the directed slot (i, neighbors(i)[slot]) is active.
    pub fn slot_live(&self, i: NodeId, slot: usize) -> bool {
        self.slot_live[i][slot]
    }

    /// Number of active slots at node i (what η̄ normalization divides by).
    pub fn live_degree(&self, i: NodeId) -> usize {
        self.slot_live[i].iter().filter(|&&l| l).count()
    }

    /// Whether every slot of node i is active (the common fast path: when
    /// true, callers can skip per-slot masking entirely and run the exact
    /// arithmetic of the synchronous runtimes).
    pub fn all_slots_live(&self, i: NodeId) -> bool {
        self.slot_live[i].iter().all(|&l| l)
    }

    /// Number of live nodes.
    pub fn live_count(&self) -> usize {
        self.node_live.iter().filter(|&&l| l).count()
    }

    /// Number of live undirected edges. Slots stay symmetric under every
    /// mutation, so halving the live directed-slot count is exact.
    pub fn live_edge_count(&self) -> usize {
        self.slot_live
            .iter()
            .map(|slots| slots.iter().filter(|&&l| l).count())
            .sum::<usize>()
            / 2
    }

    /// Activate/deactivate a node. Deactivation also masks every incident
    /// edge (both directions); activation restores edges only toward
    /// neighbours that are themselves live.
    pub fn set_node(&mut self, i: NodeId, live: bool) {
        self.node_live[i] = live;
        for slot in 0..self.graph.degree(i) {
            let j = self.graph.neighbors(i)[slot];
            let on = live && self.node_live[j];
            self.slot_live[i][slot] = on;
            let rev = self.graph.edge_slot(j, i).expect("graph symmetry");
            self.slot_live[j][rev] = on;
        }
        self.generation += 1;
    }

    /// Activate/deactivate the undirected edge {i, j} (both directed
    /// slots). No-op masking-in if either endpoint is dead. Returns whether
    /// the edge ended up live.
    pub fn set_edge(&mut self, i: NodeId, j: NodeId, live: bool) -> bool {
        let slot = self.graph.edge_slot(i, j).expect("edge exists in frozen graph");
        let rev = self.graph.edge_slot(j, i).expect("graph symmetry");
        let on = live && self.node_live[i] && self.node_live[j];
        self.slot_live[i][slot] = on;
        self.slot_live[j][rev] = on;
        self.generation += 1;
        on
    }

    /// BFS connectivity over the live subgraph (dead nodes ignored).
    /// Vacuously true with ≤ 1 live node.
    pub fn live_connected(&self) -> bool {
        let n = self.graph.len();
        let start = match (0..n).find(|&i| self.node_live[i]) {
            Some(s) => s,
            None => return true,
        };
        let mut seen = vec![false; n];
        let mut queue = std::collections::VecDeque::from([start]);
        seen[start] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for (slot, &v) in self.graph.neighbors(u).iter().enumerate() {
                if self.slot_live[u][slot] && !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        count == self.live_count()
    }

    /// Reverse Cuthill–McKee order over the *live* subgraph, cached by
    /// generation: repeated reads between mutations reuse the permutation,
    /// and any mutation invalidates it incrementally (next read recomputes).
    /// Dead nodes are appended after the live ordering so the result is
    /// always a full permutation of `0..n`.
    pub fn rcm_order_live(&mut self) -> &[NodeId] {
        if self
            .rcm_cache
            .as_ref()
            .is_none_or(|(gen, _)| *gen != self.generation)
        {
            let order = self.compute_rcm_live();
            self.rcm_cache = Some((self.generation, order));
        }
        &self.rcm_cache.as_ref().unwrap().1
    }

    /// Whether a cached RCM order for the current generation exists (test
    /// and diagnostics hook — lets callers verify reuse without timing).
    pub fn rcm_cache_fresh(&self) -> bool {
        self.rcm_cache
            .as_ref()
            .is_some_and(|(gen, _)| *gen == self.generation)
    }

    fn compute_rcm_live(&self) -> Vec<NodeId> {
        let n = self.graph.len();
        let live_deg: Vec<usize> = (0..n).map(|i| self.live_degree(i)).collect();
        let mut visited = vec![false; n];
        let mut order: Vec<NodeId> = Vec::with_capacity(n);
        let mut nbrs: Vec<NodeId> = Vec::new();
        // deterministic: start each live component from its minimum-degree
        // node (ties by id), BFS with degree-sorted neighbour expansion —
        // the same discipline as `graph::rcm_order`, restricted to live
        // slots
        loop {
            let start = (0..n)
                .filter(|&i| self.node_live[i] && !visited[i])
                .min_by_key(|&i| (live_deg[i], i));
            let start = match start {
                Some(s) => s,
                None => break,
            };
            visited[start] = true;
            let head = order.len();
            order.push(start);
            let mut cursor = head;
            while cursor < order.len() {
                let u = order[cursor];
                cursor += 1;
                nbrs.clear();
                for (slot, &v) in self.graph.neighbors(u).iter().enumerate() {
                    if self.slot_live[u][slot] && !visited[v] {
                        nbrs.push(v);
                    }
                }
                nbrs.sort_unstable_by_key(|&v| (live_deg[v], v));
                for &v in &nbrs {
                    visited[v] = true;
                    order.push(v);
                }
            }
        }
        order.reverse();
        // dead nodes last, in id order (full permutation invariant)
        for i in 0..n {
            if !self.node_live[i] {
                order.push(i);
            }
        }
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;

    #[test]
    fn starts_fully_live() {
        let v = LiveView::new(Topology::Ring.build(5).unwrap());
        assert_eq!(v.live_count(), 5);
        assert!((0..5).all(|i| v.all_slots_live(i)));
        assert_eq!(v.live_degree(0), 2);
        assert_eq!(v.live_edge_count(), 5, "a 5-ring has 5 undirected edges");
        assert!(v.live_connected());
        assert_eq!(v.generation(), 0);
    }

    #[test]
    fn node_leave_masks_both_directions() {
        let mut v = LiveView::new(Topology::Ring.build(5).unwrap());
        v.set_node(2, false);
        assert!(!v.node_live(2));
        assert_eq!(v.live_degree(2), 0);
        assert_eq!(v.live_edge_count(), 3, "both edges of node 2 masked");
        assert_eq!(v.live_degree(1), 1, "edge 1-2 masked from node 1's side");
        assert_eq!(v.live_degree(3), 1);
        assert!(v.live_connected(), "ring minus one node is a live path");
        assert_eq!(v.generation(), 1);
    }

    #[test]
    fn rejoin_restores_only_live_neighbours() {
        let mut v = LiveView::new(Topology::Ring.build(5).unwrap());
        v.set_node(2, false);
        v.set_node(3, false);
        v.set_node(2, true);
        assert_eq!(v.live_degree(2), 1, "edge to dead node 3 stays masked");
        assert_eq!(v.live_degree(1), 2);
    }

    #[test]
    fn edge_toggle_is_symmetric() {
        let mut v = LiveView::new(Topology::Complete.build(4).unwrap());
        assert!(!v.set_edge(0, 3, false));
        assert_eq!(v.live_degree(0), 2);
        assert_eq!(v.live_degree(3), 2);
        let slot03 = v.graph().edge_slot(0, 3).unwrap();
        let slot30 = v.graph().edge_slot(3, 0).unwrap();
        assert!(!v.slot_live(0, slot03));
        assert!(!v.slot_live(3, slot30));
        assert!(v.set_edge(0, 3, true));
        assert!(v.all_slots_live(0));
    }

    #[test]
    fn isolated_live_node_disconnects_view() {
        let mut v = LiveView::new(Topology::Chain.build(3).unwrap());
        v.set_edge(0, 1, false);
        assert!(!v.live_connected());
        assert_eq!(v.live_degree(0), 0, "isolated-node semantics apply");
    }

    #[test]
    fn rcm_cache_invalidates_on_mutation() {
        let mut v = LiveView::new(Topology::Ring.build(8).unwrap());
        let a = v.rcm_order_live().to_vec();
        assert!(v.rcm_cache_fresh());
        let b = v.rcm_order_live().to_vec();
        assert_eq!(a, b, "no mutation ⇒ cached permutation reused");
        v.set_node(5, false);
        assert!(!v.rcm_cache_fresh(), "mutation invalidates the cache");
        let c = v.rcm_order_live().to_vec();
        assert_ne!(a, c, "dead node moves to the tail of the order");
        assert_eq!(c[7], 5, "dead nodes appended after the live ordering");
        // still a permutation
        let mut sorted = c.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn fully_live_rcm_is_a_permutation_of_all_nodes() {
        let mut v = LiveView::new(Topology::Grid.build(16).unwrap());
        let order = v.rcm_order_live().to_vec();
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..16).collect::<Vec<_>>());
    }
}
