//! Bandwidth-reducing node relabeling (reverse Cuthill–McKee).
//!
//! The sharded runtime partitions nodes into *contiguous* id ranges
//! ([`super::shard_ranges`]), so a node's phase-B arena reads stay inside
//! its own shard exactly when its neighbours carry nearby ids. Arbitrary
//! input labelings (or adversarial ones — a ring labeled by a random
//! permutation) scatter neighbours across shards and turn every neighbour
//! read into a cross-shard cache miss. RCM relabels the graph so that
//! adjacent nodes get adjacent ids: it is the classic bandwidth-reduction
//! ordering (BFS from a low-degree root, neighbours visited in ascending
//! degree order, then the whole order reversed).
//!
//! The runner applies the permutation *transparently*: solvers, RNG
//! streams, app-metric snapshots and the reported θ all stay keyed by the
//! caller's original node ids (see `coordinator::runner`). Relabeling only
//! changes which worker owns which node and the in-shard visit order — and
//! therefore the floating-point grouping of leader-side reductions, never
//! any node-level arithmetic.

use super::{Graph, NodeId};
use crate::error::Result;

/// Node-relabeling policy applied by the sharded runner before
/// partitioning.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Relabel {
    /// Keep the caller's node ids (the pre-relabeling behaviour).
    Identity,
    /// Reverse Cuthill–McKee: neighbours get nearby ids, so contiguous
    /// shards keep most phase-B parameter reads shard-local.
    #[default]
    Rcm,
}

/// Reverse Cuthill–McKee ordering. Returns `order` with
/// `order[new_id] = old_id`; applying it via [`relabel_graph`] yields a
/// graph whose [`bandwidth`] is (near-)minimal for BFS-style orderings.
///
/// Deterministic: roots are the lowest-degree unvisited nodes (ties by
/// smallest id) and neighbours are enqueued in ascending (degree, id)
/// order, so the same graph always produces the same permutation — a
/// requirement for the runner's bit-reproducibility guarantees.
pub fn rcm_order(graph: &Graph) -> Vec<NodeId> {
    let n = graph.len();
    let mut order: Vec<NodeId> = Vec::with_capacity(n);
    let mut visited = vec![false; n];
    let mut nbrs: Vec<NodeId> = Vec::new();
    // Graph::new guarantees connectivity for n > 1, but sweep for further
    // components anyway so the result is always a total permutation.
    loop {
        let mut root: Option<NodeId> = None;
        for i in 0..n {
            if !visited[i] && root.is_none_or(|r| graph.degree(i) < graph.degree(r)) {
                root = Some(i);
            }
        }
        let Some(root) = root else { break };
        visited[root] = true;
        order.push(root);
        let mut head = order.len() - 1;
        while head < order.len() {
            let u = order[head];
            head += 1;
            nbrs.clear();
            nbrs.extend(graph.neighbors(u).iter().copied().filter(|&v| !visited[v]));
            // stable sort on degree; neighbour lists are id-sorted, so the
            // effective key is (degree, id)
            nbrs.sort_by_key(|&v| graph.degree(v));
            for &v in &nbrs {
                visited[v] = true;
                order.push(v);
            }
        }
    }
    order.reverse();
    order
}

/// [`rcm_order`] restricted to the subgraph induced by a contiguous id
/// `span`: edges leaving the span are ignored, degrees are span-internal,
/// and the returned order is a permutation of `span` (absolute ids,
/// `span.len()` entries). The induced subgraph may be disconnected —
/// every component is swept, lowest-internal-degree roots first — which
/// is why this works directly on the host [`Graph`] instead of building
/// (and failing to validate) a standalone subgraph.
///
/// `rcm_order_in(g, 0..n) == rcm_order(g)`: on the full span the
/// internal degree *is* the degree, so the hierarchical two-level path
/// (machine partition → per-machine RCM; see `cluster::partition`)
/// degenerates to the flat ordering at one machine.
pub fn rcm_order_in(graph: &Graph, span: std::ops::Range<usize>) -> Vec<NodeId> {
    let lo = span.start;
    let len = span.end.saturating_sub(lo);
    let in_span = |v: usize| v >= lo && v < span.end;
    // span-internal degrees, precomputed once (the sort key below)
    let deg_in: Vec<usize> = span
        .clone()
        .map(|i| graph.neighbors(i).iter().filter(|&&u| in_span(u)).count())
        .collect();
    let mut order: Vec<NodeId> = Vec::with_capacity(len);
    let mut visited = vec![false; len];
    let mut nbrs: Vec<NodeId> = Vec::new();
    loop {
        let mut root: Option<NodeId> = None;
        for i in span.clone() {
            if !visited[i - lo]
                && root.is_none_or(|r| deg_in[i - lo] < deg_in[r - lo])
            {
                root = Some(i);
            }
        }
        let Some(root) = root else { break };
        visited[root - lo] = true;
        order.push(root);
        let mut head = order.len() - 1;
        while head < order.len() {
            let u = order[head];
            head += 1;
            nbrs.clear();
            nbrs.extend(graph.neighbors(u).iter().copied()
                .filter(|&v| in_span(v) && !visited[v - lo]));
            // stable sort on internal degree; neighbour lists are
            // id-sorted, so the effective key is (degree, id)
            nbrs.sort_by_key(|&v| deg_in[v - lo]);
            for &v in &nbrs {
                visited[v - lo] = true;
                order.push(v);
            }
        }
    }
    order.reverse();
    order
}

/// Apply a permutation (`order[new_id] = old_id`, e.g. from
/// [`rcm_order`]) to a graph, producing the relabeled graph.
pub fn relabel_graph(graph: &Graph, order: &[NodeId]) -> Result<Graph> {
    let n = graph.len();
    assert_eq!(order.len(), n, "relabel_graph: permutation length");
    let mut inv = vec![0usize; n];
    for (new, &old) in order.iter().enumerate() {
        inv[old] = new;
    }
    let edges: Vec<(NodeId, NodeId)> = graph
        .directed_edges()
        .filter(|&(a, b)| a < b)
        .map(|(a, b)| (inv[a], inv[b]))
        .collect();
    Graph::new(n, &edges)
}

/// Graph bandwidth: `max |i − j|` over edges — the quantity RCM reduces,
/// and a direct proxy for cross-shard neighbour reads under contiguous
/// sharding.
pub fn bandwidth(graph: &Graph) -> usize {
    graph
        .directed_edges()
        .map(|(i, j)| i.abs_diff(j))
        .fold(0, usize::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{random_connected, Topology};
    use crate::util::prop;
    use crate::util::rng::Pcg;

    fn is_permutation(order: &[usize]) -> bool {
        let mut seen = vec![false; order.len()];
        for &i in order {
            if i >= order.len() || seen[i] {
                return false;
            }
            seen[i] = true;
        }
        true
    }

    /// A ring/chain whose labels were scrambled by a seeded shuffle.
    fn scrambled(topo: Topology, n: usize, seed: u64) -> Graph {
        let g = topo.build(n).unwrap();
        let mut perm: Vec<usize> = (0..n).collect();
        Pcg::seed(seed).shuffle(&mut perm);
        relabel_graph(&g, &perm).unwrap()
    }

    #[test]
    fn rcm_is_a_permutation_on_random_graphs() {
        prop::check("rcm_order permutes 0..n", |rng| {
            let n = 1 + rng.below(40);
            let g = random_connected(n, 0.3, rng).unwrap();
            let order = rcm_order(&g);
            assert_eq!(order.len(), n);
            assert!(is_permutation(&order));
        });
    }

    #[test]
    fn rcm_restores_chain_locality() {
        // a scrambled chain has bandwidth O(n); RCM restores exactly 1
        let g = scrambled(Topology::Chain, 41, 7);
        assert!(bandwidth(&g) > 5, "scramble must actually scatter labels");
        let relabeled = relabel_graph(&g, &rcm_order(&g)).unwrap();
        assert_eq!(bandwidth(&relabeled), 1);
    }

    #[test]
    fn rcm_bounds_ring_bandwidth() {
        let g = scrambled(Topology::Ring, 64, 3);
        assert!(bandwidth(&g) > 8);
        let relabeled = relabel_graph(&g, &rcm_order(&g)).unwrap();
        assert!(bandwidth(&relabeled) <= 2, "cycle RCM bandwidth is ≤ 2, got {}",
                bandwidth(&relabeled));
    }

    #[test]
    fn rcm_is_deterministic() {
        let mut rng = Pcg::seed(11);
        let g = random_connected(25, 0.2, &mut rng).unwrap();
        assert_eq!(rcm_order(&g), rcm_order(&g));
    }

    #[test]
    fn relabel_preserves_structure() {
        prop::check("relabeling preserves degrees, edges, connectivity", |rng| {
            let n = 2 + rng.below(30);
            let g = random_connected(n, 0.3, rng).unwrap();
            let order = rcm_order(&g);
            let r = relabel_graph(&g, &order).unwrap();
            assert_eq!(r.len(), n);
            assert_eq!(r.edge_count(), g.edge_count());
            assert!(r.is_connected());
            let mut inv = vec![0usize; n];
            for (new, &old) in order.iter().enumerate() {
                inv[old] = new;
            }
            for (new, &old) in order.iter().enumerate() {
                assert_eq!(r.degree(new), g.degree(old));
            }
            for (a, b) in g.directed_edges() {
                assert!(r.neighbors(inv[a]).contains(&inv[b]));
            }
        });
    }

    #[test]
    fn rcm_in_full_span_matches_flat_rcm() {
        prop::check("rcm_order_in(0..n) ≡ rcm_order", |rng| {
            let n = 1 + rng.below(30);
            let g = random_connected(n, 0.25, rng).unwrap();
            assert_eq!(rcm_order_in(&g, 0..n), rcm_order(&g));
        });
    }

    #[test]
    fn rcm_in_handles_disconnected_spans() {
        // middle of a ring: the induced span is one path; ends of the
        // span on a star's leaves: fully disconnected singletons
        let ring = Topology::Ring.build(10).unwrap();
        let ord = rcm_order_in(&ring, 3..8);
        assert_eq!(ord.len(), 5);
        let mut sorted = ord.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![3, 4, 5, 6, 7], "permutation of the span");

        let star = Topology::Star.build(8).unwrap();
        let leaves = rcm_order_in(&star, 2..6);
        // all-isolated: swept in id order, each its own component
        assert_eq!(leaves.len(), 4);
        let mut s = leaves.clone();
        s.sort_unstable();
        assert_eq!(s, vec![2, 3, 4, 5]);
    }

    #[test]
    fn rcm_reduces_power_law_bandwidth() {
        // the RCM-on-CSR regression: a seeded heavy-tailed graph must
        // relabel deterministically and never lose locality vs the raw
        // attachment order
        let g = crate::graph::power_law(300, 2, &mut Pcg::seed(31)).unwrap();
        let order = rcm_order(&g);
        assert!(is_permutation(&order));
        assert_eq!(order, rcm_order(&g), "deterministic");
        let relabeled = relabel_graph(&g, &order).unwrap();
        assert!(bandwidth(&relabeled) <= bandwidth(&g),
                "RCM bandwidth {} vs raw {}", bandwidth(&relabeled), bandwidth(&g));
    }

    #[test]
    fn singleton_and_identity_cases() {
        let g = Graph::new(1, &[]).unwrap();
        assert_eq!(rcm_order(&g), vec![0]);
        assert_eq!(bandwidth(&g), 0);
        let r = relabel_graph(&g, &[0]).unwrap();
        assert_eq!(r.len(), 1);
    }
}
