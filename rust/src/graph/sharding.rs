//! Contiguous load-balanced node partitioning for the worker-pool runtime.

use std::ops::Range;

use super::Graph;

/// Split nodes `0..n` into at most `max_shards` contiguous, non-empty
/// ranges of near-equal total cost, where a node costs `1 + degree(i)`
/// (one local solve plus per-neighbour exchange/objective work).
///
/// Contiguity matters twice over: each worker's parameter-arena reads and
/// writes stay on adjacent cache lines, and concatenating the shards in
/// order reproduces the sequential node order, so shard-combined
/// reductions visit nodes exactly as a single-threaded sweep would.
///
/// **Degree-skew cap:** on heavy-tailed graphs a hub's cost can exceed
/// the per-shard budget, which used to strand the hub in one huge shard
/// while every other shard got a sliver — pathological max/min cost
/// imbalance. The splitter now returns *fewer* shards when needed: the
/// count is capped so each shard's budget is at least half the heaviest
/// node's cost (`shards ≤ ⌊2·total/cmax⌋`), keeping the max/min shard
/// cost ratio bounded instead of growing with the hub degree. The cap
/// never reduces below 2 shards and never fires on degree-uniform
/// graphs (rings, grids, complete), so existing splits are unchanged;
/// callers must size worker state off `ranges.len()`, not the request.
///
/// Deterministic: same graph + same `max_shards` → same ranges.
pub fn shard_ranges(graph: &Graph, max_shards: usize) -> Vec<Range<usize>> {
    shard_ranges_in(graph, 0..graph.len(), max_shards)
}

/// [`shard_ranges`] restricted to a contiguous node sub-range: split
/// `span` into at most `max_shards` contiguous, non-empty ranges of
/// near-equal total cost. The cluster runtime shards each *machine's*
/// node slice this way, so a one-machine cluster reproduces the global
/// `shard_ranges` split exactly (`shard_ranges_in(g, 0..n, w) ==
/// shard_ranges(g, w)` by construction).
pub fn shard_ranges_in(graph: &Graph, span: Range<usize>,
                       max_shards: usize) -> Vec<Range<usize>> {
    debug_assert!(span.end <= graph.len());
    let lo = span.start;
    let n = span.end;
    let len = n.saturating_sub(lo);
    if len == 0 {
        return Vec::new();
    }
    let cost = |i: usize| (1 + graph.degree(i)) as f64;
    let total: f64 = (lo..n).map(cost).sum();
    let cmax = (lo..n).map(cost).fold(0.0, f64::max);
    // hub cap (see shard_ranges docs): every shard's budget stays ≥ cmax/2
    let cap = ((2.0 * total / cmax).floor() as usize).max(1);
    let shards = max_shards.max(1).min(len).min(cap);

    let mut out = Vec::with_capacity(shards);
    let mut start = lo;
    let mut spent = 0.0;
    for s in 0..shards {
        let remaining = shards - s;
        if remaining == 1 {
            out.push(start..n);
            break;
        }
        // leave at least one node for each later shard
        let max_end = n - (remaining - 1);
        let target = (total - spent) / remaining as f64;
        let mut end = start + 1;
        let mut acc = cost(start);
        while end < max_end {
            let c = cost(end);
            // stop once the midpoint of the next node overshoots the target
            if acc + 0.5 * c > target {
                break;
            }
            acc += c;
            end += 1;
        }
        spent += acc;
        out.push(start..end);
        start = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;
    use crate::util::prop;

    fn cost_of(g: &Graph, r: &std::ops::Range<usize>) -> f64 {
        r.clone().map(|i| (1 + g.degree(i)) as f64).sum()
    }

    fn check_partition(g: &Graph, shards: usize) {
        let ranges = shard_ranges(g, shards);
        // the hub cap may return fewer shards than requested, never more
        // and never zero
        assert!(!ranges.is_empty());
        assert!(ranges.len() <= shards.max(1).min(g.len()));
        if shards >= 2 && g.len() >= 2 {
            assert!(ranges.len() >= 2.min(shards), "cap floor is two shards");
        }
        let mut expect = 0usize;
        for r in &ranges {
            assert_eq!(r.start, expect, "contiguous, in order");
            assert!(r.end > r.start, "non-empty");
            expect = r.end;
        }
        assert_eq!(expect, g.len(), "covers every node");
        // the cap's point: each shard's budget is at least half the
        // heaviest node, so no multi-node shard can dwarf the average
        let total: f64 = cost_of(g, &(0..g.len()));
        let cmax = (0..g.len()).map(|i| (1 + g.degree(i)) as f64).fold(0.0, f64::max);
        assert!(total / ranges.len() as f64 >= 0.5 * cmax - 1e-9,
                "budget {} under half of cmax {cmax}", total / ranges.len() as f64);
    }

    #[test]
    fn covers_all_named_topologies() {
        for topo in [Topology::Complete, Topology::Ring, Topology::Chain,
                     Topology::Star, Topology::Cluster, Topology::PowerLaw] {
            let g = topo.build(13).unwrap();
            for shards in [1, 2, 3, 5, 13, 64] {
                check_partition(&g, shards);
            }
        }
    }

    #[test]
    fn star_hub_gets_a_small_shard() {
        // node 0 of a star carries almost all the edge cost; a balanced
        // 2-way split must not give shard 0 half the nodes
        let g = Topology::Star.build(41).unwrap();
        let ranges = shard_ranges(&g, 2);
        assert!(ranges[0].len() < ranges[1].len(),
                "hub shard {:?} should be smaller than leaf shard {:?}",
                ranges[0], ranges[1]);
    }

    #[test]
    fn uniform_costs_split_evenly() {
        let g = Topology::Ring.build(12).unwrap();
        let ranges = shard_ranges(&g, 4);
        assert_eq!(ranges, vec![0..3, 3..6, 6..9, 9..12]);
    }

    #[test]
    fn more_shards_than_nodes_clamps() {
        let g = Topology::Ring.build(5).unwrap();
        assert_eq!(shard_ranges(&g, 99).len(), 5);
        let singleton = Graph::new(1, &[]).unwrap();
        assert_eq!(shard_ranges(&singleton, 8), vec![0..1]);
    }

    #[test]
    fn sub_range_sharding_matches_global_on_full_span() {
        for topo in [Topology::Ring, Topology::Star, Topology::Cluster] {
            let g = topo.build(14).unwrap();
            for shards in [1, 3, 5, 14] {
                assert_eq!(shard_ranges_in(&g, 0..14, shards),
                           shard_ranges(&g, shards), "{topo:?}/{shards}");
            }
        }
    }

    #[test]
    fn sub_range_sharding_partitions_the_span() {
        let g = Topology::Star.build(20).unwrap();
        for (span, shards) in [(3..17, 4), (0..5, 2), (10..11, 3), (7..7, 2)] {
            let ranges = shard_ranges_in(&g, span.clone(), shards);
            if span.is_empty() {
                assert!(ranges.is_empty());
                continue;
            }
            let mut expect = span.start;
            for r in &ranges {
                assert_eq!(r.start, expect);
                assert!(r.end > r.start);
                expect = r.end;
            }
            assert_eq!(expect, span.end);
            assert_eq!(ranges.len(), shards.min(span.len()));
        }
    }

    #[test]
    fn uniform_degree_graphs_never_capped() {
        // rings/complete graphs have cmax == mean cost: the hub cap must
        // be invisible (exact requested shard count, PR 9 splits intact)
        for (topo, n) in [(Topology::Ring, 12), (Topology::Complete, 9)] {
            let g = topo.build(n).unwrap();
            for shards in 1..=n {
                assert_eq!(shard_ranges(&g, shards).len(), shards,
                           "{topo:?} n={n} shards={shards}");
            }
        }
    }

    #[test]
    fn star_hub_caps_the_shard_count() {
        // hub cost 1001 vs total 3001: more than 5 shards would hand some
        // worker a budget below half the hub. The old splitter returned 64
        // ranges with a 1001-vs-~30 cost spread.
        let g = Topology::Star.build(1001).unwrap();
        let ranges = shard_ranges(&g, 64);
        assert_eq!(ranges.len(), 5);
        let costs: Vec<f64> = ranges.iter().map(|r| cost_of(&g, r)).collect();
        let max = costs.iter().cloned().fold(0.0, f64::max);
        let min = costs.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min <= 4.0, "max/min shard cost {max}/{min}");
        // two-shard requests are never shrunk
        assert_eq!(shard_ranges(&g, 2).len(), 2);
    }

    #[test]
    fn power_law_shard_costs_stay_balanced() {
        // the regression the cap exists for: a heavy-tailed graph sharded
        // wide must keep the max/min shard-cost ratio bounded
        let g = crate::graph::power_law(400, 2,
                                        &mut crate::util::rng::Pcg::seed(9)).unwrap();
        for shards in [4, 16, 64] {
            let ranges = shard_ranges(&g, shards);
            assert!(ranges.len() <= shards);
            let costs: Vec<f64> = ranges.iter().map(|r| cost_of(&g, r)).collect();
            let max = costs.iter().cloned().fold(0.0, f64::max);
            let min = costs.iter().cloned().fold(f64::INFINITY, f64::min);
            // the uncapped splitter reaches ~cmax/cmin (> 10) at 64 shards
            assert!(max / min <= 6.0,
                    "shards={shards}: cost spread {max}/{min} over {} ranges",
                    ranges.len());
        }
    }

    #[test]
    fn random_graphs_partition_property() {
        prop::check("shard_ranges partitions any connected graph", |rng| {
            let n = 2 + rng.below(30);
            let g = crate::graph::random_connected(n, 0.3, rng).unwrap();
            let shards = 1 + rng.below(n + 3);
            check_partition(&g, shards);
        });
    }
}
