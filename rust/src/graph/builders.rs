//! Standard topology builders used across the paper's experiments.

use super::{Graph, NodeId};
use crate::error::{Error, Result};
use crate::util::rng::Pcg;

/// Named topology families.
///
/// `Complete`, `Ring` and `Cluster` are the three used in the paper's
/// synthetic study (§5.1); the rest are provided for ablations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// Every pair connected.
    Complete,
    /// Cycle 0—1—…—(n−1)—0.
    Ring,
    /// Path 0—1—…—(n−1).
    Chain,
    /// Node 0 connected to all others.
    Star,
    /// Two complete halves linked by a single bridge edge (paper §5.1:
    /// "a connected graph consisting of two complete graphs linked with
    /// an edge").
    Cluster,
    /// √n × √n 4-neighbour grid (n must be a perfect square).
    Grid,
}

impl Topology {
    /// Build an n-node instance.
    pub fn build(self, n: usize) -> Result<Graph> {
        match self {
            Topology::Complete => {
                let mut edges = Vec::new();
                for i in 0..n {
                    for j in (i + 1)..n {
                        edges.push((i, j));
                    }
                }
                Graph::new(n, &edges)
            }
            Topology::Ring => {
                if n < 3 {
                    return Err(Error::Config("ring needs ≥ 3 nodes".into()));
                }
                let edges: Vec<_> = (0..n).map(|i| (i, (i + 1) % n)).collect();
                Graph::new(n, &edges)
            }
            Topology::Chain => {
                if n < 2 {
                    return Err(Error::Config("chain needs ≥ 2 nodes".into()));
                }
                let edges: Vec<_> = (0..n - 1).map(|i| (i, i + 1)).collect();
                Graph::new(n, &edges)
            }
            Topology::Star => {
                if n < 2 {
                    return Err(Error::Config("star needs ≥ 2 nodes".into()));
                }
                let edges: Vec<_> = (1..n).map(|i| (0, i)).collect();
                Graph::new(n, &edges)
            }
            Topology::Cluster => {
                if n < 4 {
                    return Err(Error::Config("cluster needs ≥ 4 nodes".into()));
                }
                let half = n / 2;
                let mut edges = Vec::new();
                for i in 0..half {
                    for j in (i + 1)..half {
                        edges.push((i, j));
                    }
                }
                for i in half..n {
                    for j in (i + 1)..n {
                        edges.push((i, j));
                    }
                }
                // bridge between the last node of part one and the first of part two
                edges.push((half - 1, half));
                Graph::new(n, &edges)
            }
            Topology::Grid => {
                let side = (n as f64).sqrt().round() as usize;
                if side * side != n {
                    return Err(Error::Config(format!("grid needs a square node count, got {n}")));
                }
                let mut edges = Vec::new();
                for r in 0..side {
                    for c in 0..side {
                        let u = r * side + c;
                        if c + 1 < side {
                            edges.push((u, u + 1));
                        }
                        if r + 1 < side {
                            edges.push((u, u + side));
                        }
                    }
                }
                Graph::new(n, &edges)
            }
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Result<Topology> {
        match s {
            "complete" => Ok(Topology::Complete),
            "ring" => Ok(Topology::Ring),
            "chain" => Ok(Topology::Chain),
            "star" => Ok(Topology::Star),
            "cluster" => Ok(Topology::Cluster),
            "grid" => Ok(Topology::Grid),
            _ => Err(Error::Config(format!("unknown topology '{s}'"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Topology::Complete => "complete",
            Topology::Ring => "ring",
            Topology::Chain => "chain",
            Topology::Star => "star",
            Topology::Cluster => "cluster",
            Topology::Grid => "grid",
        }
    }
}

/// Connected Erdős–Rényi G(n, p): sampled until connected (p well above the
/// connectivity threshold in practice), with a spanning-tree fallback to
/// guarantee termination.
pub fn random_connected(n: usize, p: f64, rng: &mut Pcg) -> Result<Graph> {
    if n == 0 {
        return Err(Error::Config("graph: zero nodes".into()));
    }
    for _attempt in 0..64 {
        let mut edges: Vec<(NodeId, NodeId)> = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.f64() < p {
                    edges.push((i, j));
                }
            }
        }
        if let Ok(g) = Graph::new(n, &edges) {
            return Ok(g);
        }
    }
    // fallback: random spanning tree + extra edges
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut edges: Vec<(NodeId, NodeId)> = Vec::new();
    for k in 1..n {
        let parent = order[rng.below(k)];
        edges.push((order[k], parent));
    }
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.f64() < p {
                edges.push((i, j));
            }
        }
    }
    Graph::new(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn complete_degrees() {
        let g = Topology::Complete.build(6).unwrap();
        assert!((0..6).all(|i| g.degree(i) == 5));
        assert_eq!(g.edge_count(), 15);
        assert_eq!(g.diameter(), 1);
    }

    #[test]
    fn ring_degrees_and_diameter() {
        let g = Topology::Ring.build(8).unwrap();
        assert!((0..8).all(|i| g.degree(i) == 2));
        assert_eq!(g.diameter(), 4);
    }

    #[test]
    fn cluster_structure() {
        let g = Topology::Cluster.build(10).unwrap();
        // bridge endpoints have degree 5, everyone else 4
        assert_eq!(g.degree(4), 5);
        assert_eq!(g.degree(5), 5);
        assert_eq!(g.degree(0), 4);
        assert_eq!(g.edge_count(), 2 * 10 + 1);
    }

    #[test]
    fn grid_shape() {
        let g = Topology::Grid.build(9).unwrap();
        assert_eq!(g.degree(4), 4); // centre
        assert_eq!(g.degree(0), 2); // corner
        assert!(Topology::Grid.build(8).is_err());
    }

    #[test]
    fn star_and_chain() {
        let star = Topology::Star.build(5).unwrap();
        assert_eq!(star.degree(0), 4);
        assert_eq!(star.diameter(), 2);
        let chain = Topology::Chain.build(5).unwrap();
        assert_eq!(chain.diameter(), 4);
    }

    #[test]
    fn all_named_topologies_connected() {
        prop::check("builders produce connected graphs", |rng| {
            let n = 4 + rng.below(17);
            for t in [Topology::Complete, Topology::Ring, Topology::Chain,
                      Topology::Star, Topology::Cluster] {
                let g = t.build(n).unwrap();
                assert!(g.is_connected(), "{t:?} n={n}");
            }
        });
    }

    #[test]
    fn random_connected_always_connected() {
        prop::check("G(n,p) retried to connectivity", |rng| {
            let n = 2 + rng.below(15);
            let p = rng.range(0.05, 0.9);
            let g = random_connected(n, p, rng).unwrap();
            assert!(g.is_connected());
            assert_eq!(g.len(), n);
        });
    }

    #[test]
    fn parse_roundtrip() {
        for t in [Topology::Complete, Topology::Ring, Topology::Chain,
                  Topology::Star, Topology::Cluster, Topology::Grid] {
            assert_eq!(Topology::parse(t.name()).unwrap(), t);
        }
        assert!(Topology::parse("möbius").is_err());
    }
}
