//! Standard topology builders used across the paper's experiments.

use super::{Graph, NodeId};
use crate::error::{Error, Result};
use crate::util::rng::Pcg;

/// Named topology families.
///
/// `Complete`, `Ring` and `Cluster` are the three used in the paper's
/// synthetic study (§5.1); the rest are provided for ablations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// Every pair connected.
    Complete,
    /// Cycle 0—1—…—(n−1)—0.
    Ring,
    /// Path 0—1—…—(n−1).
    Chain,
    /// Node 0 connected to all others.
    Star,
    /// Two complete halves linked by a single bridge edge (paper §5.1:
    /// "a connected graph consisting of two complete graphs linked with
    /// an edge").
    Cluster,
    /// √n × √n 4-neighbour grid (n must be a perfect square).
    Grid,
    /// Heavy-tailed preferential-attachment graph (Barabási–Albert,
    /// m = 2, internally seeded by `n` so repeated builds agree) — the
    /// degree-skew stressor for the sharder and the scale benches; see
    /// [`power_law`] for the seedable variant.
    PowerLaw,
}

impl Topology {
    /// Build an n-node instance.
    pub fn build(self, n: usize) -> Result<Graph> {
        match self {
            Topology::Complete => {
                let mut edges = Vec::new();
                for i in 0..n {
                    for j in (i + 1)..n {
                        edges.push((i, j));
                    }
                }
                Graph::new(n, &edges)
            }
            Topology::Ring => {
                if n < 3 {
                    return Err(Error::Config("ring needs ≥ 3 nodes".into()));
                }
                let edges: Vec<_> = (0..n).map(|i| (i, (i + 1) % n)).collect();
                Graph::new(n, &edges)
            }
            Topology::Chain => {
                if n < 2 {
                    return Err(Error::Config("chain needs ≥ 2 nodes".into()));
                }
                let edges: Vec<_> = (0..n - 1).map(|i| (i, i + 1)).collect();
                Graph::new(n, &edges)
            }
            Topology::Star => {
                if n < 2 {
                    return Err(Error::Config("star needs ≥ 2 nodes".into()));
                }
                let edges: Vec<_> = (1..n).map(|i| (0, i)).collect();
                Graph::new(n, &edges)
            }
            Topology::Cluster => {
                if n < 4 {
                    return Err(Error::Config("cluster needs ≥ 4 nodes".into()));
                }
                let half = n / 2;
                let mut edges = Vec::new();
                for i in 0..half {
                    for j in (i + 1)..half {
                        edges.push((i, j));
                    }
                }
                for i in half..n {
                    for j in (i + 1)..n {
                        edges.push((i, j));
                    }
                }
                // bridge between the last node of part one and the first of part two
                edges.push((half - 1, half));
                Graph::new(n, &edges)
            }
            Topology::Grid => {
                let side = (n as f64).sqrt().round() as usize;
                if side * side != n {
                    return Err(Error::Config(format!("grid needs a square node count, got {n}")));
                }
                let mut edges = Vec::new();
                for r in 0..side {
                    for c in 0..side {
                        let u = r * side + c;
                        if c + 1 < side {
                            edges.push((u, u + 1));
                        }
                        if r + 1 < side {
                            edges.push((u, u + side));
                        }
                    }
                }
                Graph::new(n, &edges)
            }
            Topology::PowerLaw => {
                power_law(n, 2, &mut Pcg::new(0x50574c41, n as u64))
            }
        }
    }

    /// Parse from a CLI string.
    pub fn parse(s: &str) -> Result<Topology> {
        match s {
            "complete" => Ok(Topology::Complete),
            "ring" => Ok(Topology::Ring),
            "chain" => Ok(Topology::Chain),
            "star" => Ok(Topology::Star),
            "cluster" => Ok(Topology::Cluster),
            "grid" => Ok(Topology::Grid),
            "power-law" | "powerlaw" => Ok(Topology::PowerLaw),
            _ => Err(Error::Config(format!("unknown topology '{s}'"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Topology::Complete => "complete",
            Topology::Ring => "ring",
            Topology::Chain => "chain",
            Topology::Star => "star",
            Topology::Cluster => "cluster",
            Topology::Grid => "grid",
            Topology::PowerLaw => "power-law",
        }
    }
}

/// Seeded preferential-attachment (Barabási–Albert) graph: start from a
/// complete seed on `m + 1` nodes, then attach each new node to `m`
/// distinct existing nodes sampled with probability proportional to
/// degree (uniform draws from the running edge-endpoint list). The
/// resulting degree sequence is heavy-tailed (`P(deg = k) ~ k^{-3}`),
/// which is exactly the regime that breaks naive degree-balanced
/// sharding — see [`super::shard_ranges`]'s hub cap.
///
/// Connected by construction (every new node attaches to the existing
/// component), deterministic for a fixed `rng` state, and `O(m·n)`
/// expected time — safe at 10^6 nodes.
pub fn power_law(n: usize, m: usize, rng: &mut Pcg) -> Result<Graph> {
    if n == 0 {
        return Err(Error::Config("graph: zero nodes".into()));
    }
    let m = m.max(1);
    if n <= m + 1 {
        // too small for attachment; a complete graph is the natural cap
        return Topology::Complete.build(n);
    }
    let mut edges: Vec<(NodeId, NodeId)> = Vec::with_capacity(m * n);
    // each edge contributes both endpoints: sampling an entry uniformly
    // is degree-proportional node sampling
    let mut endpoints: Vec<NodeId> = Vec::with_capacity(2 * m * n);
    for i in 0..=m {
        for j in (i + 1)..=m {
            edges.push((i, j));
            endpoints.push(i);
            endpoints.push(j);
        }
    }
    let mut picked: Vec<NodeId> = Vec::with_capacity(m);
    for v in (m + 1)..n {
        picked.clear();
        while picked.len() < m {
            let t = endpoints[rng.below(endpoints.len())];
            if !picked.contains(&t) {
                picked.push(t);
            }
        }
        for &t in &picked {
            edges.push((v, t));
            endpoints.push(v);
            endpoints.push(t);
        }
    }
    Graph::new(n, &edges)
}

/// Connected Erdős–Rényi G(n, p): sampled until connected (p well above the
/// connectivity threshold in practice), with a spanning-tree fallback to
/// guarantee termination.
pub fn random_connected(n: usize, p: f64, rng: &mut Pcg) -> Result<Graph> {
    if n == 0 {
        return Err(Error::Config("graph: zero nodes".into()));
    }
    for _attempt in 0..64 {
        let mut edges: Vec<(NodeId, NodeId)> = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                if rng.f64() < p {
                    edges.push((i, j));
                }
            }
        }
        if let Ok(g) = Graph::new(n, &edges) {
            return Ok(g);
        }
    }
    // fallback: random spanning tree + extra edges
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut edges: Vec<(NodeId, NodeId)> = Vec::new();
    for k in 1..n {
        let parent = order[rng.below(k)];
        edges.push((order[k], parent));
    }
    for i in 0..n {
        for j in (i + 1)..n {
            if rng.f64() < p {
                edges.push((i, j));
            }
        }
    }
    Graph::new(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn complete_degrees() {
        let g = Topology::Complete.build(6).unwrap();
        assert!((0..6).all(|i| g.degree(i) == 5));
        assert_eq!(g.edge_count(), 15);
        assert_eq!(g.diameter(), 1);
    }

    #[test]
    fn ring_degrees_and_diameter() {
        let g = Topology::Ring.build(8).unwrap();
        assert!((0..8).all(|i| g.degree(i) == 2));
        assert_eq!(g.diameter(), 4);
    }

    #[test]
    fn cluster_structure() {
        let g = Topology::Cluster.build(10).unwrap();
        // bridge endpoints have degree 5, everyone else 4
        assert_eq!(g.degree(4), 5);
        assert_eq!(g.degree(5), 5);
        assert_eq!(g.degree(0), 4);
        assert_eq!(g.edge_count(), 2 * 10 + 1);
    }

    #[test]
    fn grid_shape() {
        let g = Topology::Grid.build(9).unwrap();
        assert_eq!(g.degree(4), 4); // centre
        assert_eq!(g.degree(0), 2); // corner
        assert!(Topology::Grid.build(8).is_err());
    }

    #[test]
    fn star_and_chain() {
        let star = Topology::Star.build(5).unwrap();
        assert_eq!(star.degree(0), 4);
        assert_eq!(star.diameter(), 2);
        let chain = Topology::Chain.build(5).unwrap();
        assert_eq!(chain.diameter(), 4);
    }

    #[test]
    fn all_named_topologies_connected() {
        prop::check("builders produce connected graphs", |rng| {
            let n = 4 + rng.below(17);
            for t in [Topology::Complete, Topology::Ring, Topology::Chain,
                      Topology::Star, Topology::Cluster] {
                let g = t.build(n).unwrap();
                assert!(g.is_connected(), "{t:?} n={n}");
            }
        });
    }

    #[test]
    fn random_connected_always_connected() {
        prop::check("G(n,p) retried to connectivity", |rng| {
            let n = 2 + rng.below(15);
            let p = rng.range(0.05, 0.9);
            let g = random_connected(n, p, rng).unwrap();
            assert!(g.is_connected());
            assert_eq!(g.len(), n);
        });
    }

    #[test]
    fn parse_roundtrip() {
        for t in [Topology::Complete, Topology::Ring, Topology::Chain,
                  Topology::Star, Topology::Cluster, Topology::Grid,
                  Topology::PowerLaw] {
            assert_eq!(Topology::parse(t.name()).unwrap(), t);
        }
        assert_eq!(Topology::parse("powerlaw").unwrap(), Topology::PowerLaw);
        assert!(Topology::parse("möbius").is_err());
    }

    #[test]
    fn power_law_is_connected_and_heavy_tailed() {
        let g = power_law(500, 2, &mut Pcg::seed(42)).unwrap();
        assert_eq!(g.len(), 500);
        assert!(g.is_connected());
        // attachment adds m edges per node beyond the seed clique
        assert_eq!(g.edge_count(), 3 + 2 * (500 - 3));
        let max_deg = (0..500).map(|i| g.degree(i)).max().unwrap();
        assert!(max_deg as f64 > 4.0 * g.mean_degree(),
                "hub degree {max_deg} should dwarf the mean {}", g.mean_degree());
        assert!((0..500).all(|i| g.degree(i) >= 2), "m = 2 floor");
    }

    #[test]
    fn power_law_is_deterministic() {
        let a = power_law(120, 3, &mut Pcg::seed(7)).unwrap();
        let b = power_law(120, 3, &mut Pcg::seed(7)).unwrap();
        for i in 0..120 {
            assert_eq!(a.neighbors(i), b.neighbors(i));
        }
        // and the named topology reseeds internally per n
        let c = Topology::PowerLaw.build(64).unwrap();
        let d = Topology::PowerLaw.build(64).unwrap();
        for i in 0..64 {
            assert_eq!(c.neighbors(i), d.neighbors(i));
        }
    }

    #[test]
    fn power_law_small_n_falls_back_to_complete() {
        let g = power_law(3, 2, &mut Pcg::seed(1)).unwrap();
        assert_eq!(g.edge_count(), 3);
        assert!(Topology::PowerLaw.build(2).unwrap().is_connected());
        assert!(power_law(0, 2, &mut Pcg::seed(1)).is_err());
    }
}
