//! Penalty-update schemes — the paper's contribution.
//!
//! Every scheme adapts the ADMM constraint penalties each iteration from
//! purely node-local information (except the non-decentralized reference
//! scheme [`SchemeKind::Rb`], kept as a baseline):
//!
//! | kind   | paper       | state                | granularity |
//! |--------|-------------|----------------------|-------------|
//! | Fixed  | baseline    | —                    | global      |
//! | Rb     | eq. (4)     | global residuals     | global      |
//! | Vp     | §3.1        | local residuals      | per node    |
//! | Ap     | §3.2 (6-8)  | local objectives     | per edge    |
//! | Nap    | §3.3 (9-11) | + per-edge budget    | per edge    |
//! | VpAp   | §3.4 (12)   | residuals × τ_ij     | per edge    |
//! | VpNap  | §3.4        | + per-edge budget    | per edge    |
//!
//! A scheme instance lives *inside one node* and only sees that node's
//! [`NodeObservation`]; the engine owns one instance per node.
//!
//! The `global_*` observation fields are populated differently per
//! runtime: the sequential/sharded engines and the async runtime feed RB
//! an exact (omniscient) fold, while the cluster runtime
//! ([`crate::cluster`]) feeds it *collective results* — the spanning-tree
//! fold (exact, delayed by tree latency) or the gossip estimate
//! (approximate, per-node normalized; RB's balance test compares the
//! primal/dual ratio, from which the normalization cancels). Schemes are
//! agnostic to the source by design — `needs_global_residuals()` is the
//! only coupling, and it gates how long a runtime must wait before the
//! scheme's update can run.

mod kappa;
mod schemes;

pub use kappa::{tau_from_objectives, tau_from_objectives_into,
                tau_from_objectives_masked_into};
pub use schemes::{make_scheme, NodeObservation, PenaltyScheme, SchemeKind, SchemeParams};

#[cfg(test)]
mod tests;
