//! The seven penalty schemes.
//!
//! Clamping policy: every adaptive update routes through [`clamp_eta`]
//! (η ∈ [η⁰/eta_clamp, η⁰·eta_clamp]) — including AP, whose normalized
//! τ ∈ [−½, 1] already bounds the step to [η⁰/2, 2η⁰]. At the default
//! `eta_clamp = 1e4` the clamp is therefore a no-op for AP, but routing
//! it through anyway keeps degenerate configurations (`eta_clamp < 2`)
//! and future τ definitions safe, and makes AP behave like VP/RB/NAP.
//!
//! Allocation hygiene: the τ-computing schemes own a per-node scratch
//! buffer pre-sized to the node's degree, so steady-state updates never
//! allocate (the coordinator's phase C runs inside the hot loop).
//!
//! Liveness: under a dynamic topology ([`crate::net`]) the observation
//! carries an optional per-slot mask. Dead slots are frozen — η
//! untouched, excluded from τ normalization, no budget spent — and a
//! `None` mask (what the synchronous runtimes pass) is bit-identical to
//! the pre-liveness behaviour.

use super::kappa::tau_from_objectives_masked_into;

/// Which scheme to run. See module docs for the paper mapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchemeKind {
    Fixed,
    Rb,
    Vp,
    Ap,
    Nap,
    VpAp,
    VpNap,
}

impl SchemeKind {
    /// Every scheme, in the order the paper's figures list them.
    pub const ALL: [SchemeKind; 7] = [
        SchemeKind::Fixed, SchemeKind::Rb, SchemeKind::Vp, SchemeKind::Ap,
        SchemeKind::Nap, SchemeKind::VpAp, SchemeKind::VpNap,
    ];

    /// The six compared in the paper's plots (Fixed baseline + proposed).
    pub const PAPER: [SchemeKind; 6] = [
        SchemeKind::Fixed, SchemeKind::Vp, SchemeKind::Ap, SchemeKind::Nap,
        SchemeKind::VpAp, SchemeKind::VpNap,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SchemeKind::Fixed => "admm",
            SchemeKind::Rb => "admm-rb",
            SchemeKind::Vp => "admm-vp",
            SchemeKind::Ap => "admm-ap",
            SchemeKind::Nap => "admm-nap",
            SchemeKind::VpAp => "admm-vp+ap",
            SchemeKind::VpNap => "admm-vp+nap",
        }
    }

    pub fn parse(s: &str) -> crate::Result<SchemeKind> {
        match s {
            "admm" | "fixed" => Ok(SchemeKind::Fixed),
            "admm-rb" | "rb" => Ok(SchemeKind::Rb),
            "admm-vp" | "vp" => Ok(SchemeKind::Vp),
            "admm-ap" | "ap" => Ok(SchemeKind::Ap),
            "admm-nap" | "nap" => Ok(SchemeKind::Nap),
            "admm-vp+ap" | "vp+ap" | "vpap" => Ok(SchemeKind::VpAp),
            "admm-vp+nap" | "vp+nap" | "vpnap" => Ok(SchemeKind::VpNap),
            _ => Err(crate::Error::Config(format!("unknown scheme '{s}'"))),
        }
    }
}

/// Scheme hyper-parameters; defaults are the paper's suggestions.
#[derive(Debug, Clone, Copy)]
pub struct SchemeParams {
    /// η⁰, the initial/reset penalty (paper: 10).
    pub eta0: f64,
    /// residual-balance threshold μ > 1 (paper/He et al.: 10).
    pub mu: f64,
    /// multiplicative step τ for VP/RB (paper/He et al.: 1 → ×2 / ÷2).
    pub tau: f64,
    /// maximum penalty-update iteration t_max (paper: 50).
    pub t_max: usize,
    /// NAP initial budget 𝒯 (paper: "any small value"; default 1).
    pub budget: f64,
    /// NAP budget growth rate α ∈ (0,1).
    pub alpha: f64,
    /// NAP objective-change threshold β ∈ (0,1) — budget keeps growing
    /// while |f_i(θ_i^t) − f_i(θ_i^{t−1})| is still above it.
    pub beta: f64,
    /// numerical guard: multiplicative schemes clamp η to
    /// [η⁰/eta_clamp, η⁰·eta_clamp].
    pub eta_clamp: f64,
    /// VP: reset to η⁰ at t_max (the paper's choice — heterogeneously
    /// frozen penalties oscillate near the saddle point). `false` freezes
    /// instead (ablation A3).
    pub vp_reset: bool,
}

impl Default for SchemeParams {
    fn default() -> Self {
        SchemeParams {
            eta0: 10.0,
            mu: 10.0,
            tau: 1.0,
            t_max: 50,
            budget: 1.0,
            alpha: 0.5,
            beta: 0.1,
            eta_clamp: 1e4,
            vp_reset: true,
        }
    }
}

/// Everything a node-local scheme may observe at iteration `t`.
///
/// `global_*` residuals are populated by the engine for the RB reference
/// scheme only; decentralized schemes must not read them.
#[derive(Debug, Clone)]
pub struct NodeObservation<'a> {
    pub t: usize,
    /// ‖r_i‖ — local primal residual norm (paper eq. 5)
    pub primal_norm: f64,
    /// ‖s_i‖ — local dual residual norm (paper eq. 5)
    pub dual_norm: f64,
    /// network-wide residual norms (RB baseline only)
    pub global_primal: f64,
    pub global_dual: f64,
    /// f_i(θ_i^t)
    pub f_self: f64,
    /// f_i(θ_i^{t−1})
    pub f_self_prev: f64,
    /// f_i evaluated at each neighbour estimate, in neighbour-slot order
    pub f_neighbors: &'a [f64],
    /// Per-slot edge liveness under a dynamic topology ([`crate::net`]):
    /// `None` means every slot is live (what the synchronous runtimes pass
    /// — bit-identical to the pre-liveness behaviour). With `Some(mask)`,
    /// dead slots are frozen: their η is left untouched, they are excluded
    /// from the τ normalization, and budgeted schemes neither spend nor
    /// grow budget on them.
    pub live: Option<&'a [bool]>,
}

/// Whether a neighbour slot is live under an optional mask (`None` ⇒ all
/// slots live).
#[inline]
fn slot_is_live(live: Option<&[bool]>, slot: usize) -> bool {
    live.is_none_or(|m| m[slot])
}

/// A node-local penalty scheduler. `eta` is the node's out-edge penalty
/// array, indexed by neighbour slot; the scheme mutates it in place once
/// per iteration.
pub trait PenaltyScheme: Send {
    fn kind(&self) -> SchemeKind;
    fn update(&mut self, obs: &NodeObservation<'_>, eta: &mut [f64]);
    /// Whether this scheme needs f_i evaluated at neighbour estimates
    /// (lets the engine skip those objective evaluations otherwise).
    fn needs_neighbor_objectives(&self) -> bool {
        false
    }
    /// Whether this scheme reads the network-wide residuals
    /// (`global_primal`/`global_dual`). The async runtime gates such a
    /// scheme's update on the round's global fold; decentralized schemes
    /// keep the default and never wait.
    fn needs_global_residuals(&self) -> bool {
        false
    }
}

/// Instantiate a scheme for a node of the given degree.
pub fn make_scheme(kind: SchemeKind, params: SchemeParams, degree: usize)
                   -> Box<dyn PenaltyScheme> {
    match kind {
        SchemeKind::Fixed => Box::new(Fixed),
        SchemeKind::Rb => Box::new(Rb { p: params }),
        SchemeKind::Vp => Box::new(Vp { p: params }),
        SchemeKind::Ap => Box::new(Ap { p: params, tau: Vec::with_capacity(degree) }),
        SchemeKind::Nap => Box::new(Nap::new(params, degree)),
        SchemeKind::VpAp => Box::new(VpAp { p: params, tau: Vec::with_capacity(degree) }),
        SchemeKind::VpNap => Box::new(VpNap { inner: Nap::new(params, degree) }),
    }
}

// ---------------------------------------------------------------------------

/// Standard ADMM: constant penalty.
struct Fixed;

impl PenaltyScheme for Fixed {
    fn kind(&self) -> SchemeKind {
        SchemeKind::Fixed
    }

    fn update(&mut self, _obs: &NodeObservation<'_>, _eta: &mut [f64]) {}
}

/// He et al. (2000) residual balancing on *global* residuals — the
/// non-decentralized reference (paper eq. 4). Freezes after t_max.
struct Rb {
    p: SchemeParams,
}

impl PenaltyScheme for Rb {
    fn kind(&self) -> SchemeKind {
        SchemeKind::Rb
    }

    fn needs_global_residuals(&self) -> bool {
        true
    }

    fn update(&mut self, obs: &NodeObservation<'_>, eta: &mut [f64]) {
        if obs.t >= self.p.t_max {
            return; // η frozen (homogeneous, so no reset needed)
        }
        let factor = balance_factor(obs.global_primal, obs.global_dual, self.p.mu, self.p.tau);
        for (slot, e) in eta.iter_mut().enumerate() {
            if slot_is_live(obs.live, slot) {
                *e = clamp_eta(*e * factor, &self.p);
            }
        }
    }
}

/// ADMM-VP (paper §3.1): residual balancing on *local* residuals with a
/// per-node penalty; resets to η⁰ at t_max because heterogeneously frozen
/// penalties oscillate near the saddle point.
struct Vp {
    p: SchemeParams,
}

impl PenaltyScheme for Vp {
    fn kind(&self) -> SchemeKind {
        SchemeKind::Vp
    }

    fn update(&mut self, obs: &NodeObservation<'_>, eta: &mut [f64]) {
        if obs.t >= self.p.t_max {
            if self.p.vp_reset {
                // homogeneous reset; standard ADMM from here on (dead
                // slots stay frozen — their edge is not participating)
                for (slot, e) in eta.iter_mut().enumerate() {
                    if slot_is_live(obs.live, slot) {
                        *e = self.p.eta0;
                    }
                }
            }
            // else: heterogeneous freeze (ablation A3 — the paper warns
            // this oscillates near the saddle point)
            return;
        }
        let factor = balance_factor(obs.primal_norm, obs.dual_norm, self.p.mu, self.p.tau);
        for (slot, e) in eta.iter_mut().enumerate() {
            if slot_is_live(obs.live, slot) {
                *e = clamp_eta(*e * factor, &self.p);
            }
        }
    }
}

/// ADMM-AP (paper §3.2): η_ij = η⁰(1 + τ_ij) from the normalized local
/// objective ratio; falls back to η⁰ after t_max. Clamped like every
/// other adaptive scheme (see the module docs — a no-op at defaults).
struct Ap {
    p: SchemeParams,
    tau: Vec<f64>,
}

impl PenaltyScheme for Ap {
    fn kind(&self) -> SchemeKind {
        SchemeKind::Ap
    }

    fn needs_neighbor_objectives(&self) -> bool {
        true
    }

    fn update(&mut self, obs: &NodeObservation<'_>, eta: &mut [f64]) {
        debug_assert_eq!(obs.f_neighbors.len(), eta.len());
        if obs.t >= self.p.t_max {
            for (slot, e) in eta.iter_mut().enumerate() {
                if slot_is_live(obs.live, slot) {
                    *e = self.p.eta0;
                }
            }
            return;
        }
        tau_from_objectives_masked_into(obs.f_self, obs.f_neighbors, obs.live,
                                        &mut self.tau);
        for (slot, (e, t)) in eta.iter_mut().zip(&self.tau).enumerate() {
            if slot_is_live(obs.live, slot) {
                *e = clamp_eta(self.p.eta0 * (1.0 + t), &self.p);
            }
        }
    }
}

/// ADMM-NAP (paper §3.3): AP gated by a per-edge adaptation *budget*
/// Σ|τ| < 𝒯_ij; the budget grows geometrically (α^n·𝒯) while the local
/// objective still moves more than β per iteration (eq. 10), bounded by
/// 𝒯/(1−α) (eq. 11).
struct Nap {
    p: SchemeParams,
    /// Σ_u |τ_ij^u| spent per edge slot
    spent: Vec<f64>,
    /// current upper bound 𝒯_ij per edge slot
    bound: Vec<f64>,
    /// growth counter n per edge slot (increments start at α¹)
    n: Vec<u32>,
    /// reusable τ buffer (hot-loop allocation hygiene)
    tau: Vec<f64>,
}

impl Nap {
    fn new(p: SchemeParams, degree: usize) -> Nap {
        Nap {
            spent: vec![0.0; degree],
            bound: vec![p.budget; degree],
            n: vec![1; degree],
            tau: Vec::with_capacity(degree),
            p,
        }
    }

    /// Apply the budget logic around a caller-supplied η update.
    /// `proposed(slot, tau, old)` returns the new η for an in-budget edge.
    fn gated_update(&mut self, obs: &NodeObservation<'_>, eta: &mut [f64],
                    proposed: impl Fn(usize, f64, f64) -> f64) {
        tau_from_objectives_masked_into(obs.f_self, obs.f_neighbors, obs.live,
                                        &mut self.tau);
        let objective_moving = (obs.f_self - obs.f_self_prev).abs() > self.p.beta;
        for slot in 0..eta.len() {
            if !slot_is_live(obs.live, slot) {
                // dead edge: η frozen, no budget spent or grown
                continue;
            }
            let tau = self.tau[slot];
            if self.spent[slot] < self.bound[slot] {
                eta[slot] = clamp_eta(proposed(slot, tau, eta[slot]), &self.p);
                self.spent[slot] += tau.abs();
            } else {
                eta[slot] = self.p.eta0;
                // eq. (10): grow the budget while the objective still moves
                if objective_moving {
                    self.bound[slot] += self.p.alpha.powi(self.n[slot] as i32) * self.p.budget;
                    self.n[slot] += 1;
                }
            }
        }
    }
}

impl PenaltyScheme for Nap {
    fn kind(&self) -> SchemeKind {
        SchemeKind::Nap
    }

    fn needs_neighbor_objectives(&self) -> bool {
        true
    }

    fn update(&mut self, obs: &NodeObservation<'_>, eta: &mut [f64]) {
        debug_assert_eq!(obs.f_neighbors.len(), eta.len());
        let eta0 = self.p.eta0;
        self.gated_update(obs, eta, |_slot, tau, _old| eta0 * (1.0 + tau));
    }
}

/// ADMM-VP+AP (paper eq. 12): residual direction chooses ×2 / ÷2, the
/// objective ratio modulates the magnitude; cumulative until t_max, then
/// reset to η⁰.
struct VpAp {
    p: SchemeParams,
    tau: Vec<f64>,
}

impl PenaltyScheme for VpAp {
    fn kind(&self) -> SchemeKind {
        SchemeKind::VpAp
    }

    fn needs_neighbor_objectives(&self) -> bool {
        true
    }

    fn update(&mut self, obs: &NodeObservation<'_>, eta: &mut [f64]) {
        debug_assert_eq!(obs.f_neighbors.len(), eta.len());
        if obs.t >= self.p.t_max {
            for (slot, e) in eta.iter_mut().enumerate() {
                if slot_is_live(obs.live, slot) {
                    *e = self.p.eta0;
                }
            }
            return;
        }
        tau_from_objectives_masked_into(obs.f_self, obs.f_neighbors, obs.live,
                                        &mut self.tau);
        let dir = residual_direction(obs.primal_norm, obs.dual_norm, self.p.mu);
        for (slot, (e, t)) in eta.iter_mut().zip(&self.tau).enumerate() {
            if !slot_is_live(obs.live, slot) {
                continue;
            }
            match dir {
                Direction::Grow => *e = clamp_eta(*e * (1.0 + t) * 2.0, &self.p),
                Direction::Shrink => *e = clamp_eta(*e * (1.0 + t) * 0.5, &self.p),
                Direction::Hold => {}
            }
        }
    }
}

/// ADMM-VP+NAP (paper §3.4): the VP+AP update gated by the NAP budget
/// instead of t_max.
struct VpNap {
    inner: Nap,
}

impl PenaltyScheme for VpNap {
    fn kind(&self) -> SchemeKind {
        SchemeKind::VpNap
    }

    fn needs_neighbor_objectives(&self) -> bool {
        true
    }

    fn update(&mut self, obs: &NodeObservation<'_>, eta: &mut [f64]) {
        debug_assert_eq!(obs.f_neighbors.len(), eta.len());
        let dir = residual_direction(obs.primal_norm, obs.dual_norm, self.inner.p.mu);
        self.inner.gated_update(obs, eta, |_slot, tau, old| match dir {
            Direction::Grow => old * (1.0 + tau) * 2.0,
            Direction::Shrink => old * (1.0 + tau) * 0.5,
            Direction::Hold => old,
        });
    }
}

// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Direction {
    Grow,
    Shrink,
    Hold,
}

/// Which way residual balancing pushes the penalty (He et al. / eq. 4).
fn residual_direction(primal: f64, dual: f64, mu: f64) -> Direction {
    if primal > mu * dual {
        Direction::Grow
    } else if dual > mu * primal {
        Direction::Shrink
    } else {
        Direction::Hold
    }
}

fn balance_factor(primal: f64, dual: f64, mu: f64, tau: f64) -> f64 {
    match residual_direction(primal, dual, mu) {
        Direction::Grow => 1.0 + tau,
        Direction::Shrink => 1.0 / (1.0 + tau),
        Direction::Hold => 1.0,
    }
}

fn clamp_eta(eta: f64, p: &SchemeParams) -> f64 {
    eta.clamp(p.eta0 / p.eta_clamp, p.eta0 * p.eta_clamp)
}
