//! The normalized objective-ratio weight τ_ij (paper eq. 7–8).
//!
//! κ_i(θ) = (f_i(θ) − f_min)/(f_max − f_min) + 1 ∈ [1, 2], where the
//! min/max run over the local objective evaluated at the node's own
//! parameters and every neighbour's. τ_ij = κ_i(θ_i)/κ_i(θ_j) − 1, hence
//! τ ∈ [−1/2, 1] and the AP multiplier (1 + τ) ∈ [1/2, 2] — the bounded
//! step the paper matches against He et al.'s suggested factors.

/// Compute τ_ij for every neighbour slot from the local objective values.
///
/// * `f_self` — f_i(θ_i^t)
/// * `f_neighbors` — f_i evaluated at each neighbour's parameter estimate
///   (the paper uses ρ_ij in place of θ_j to retain locality)
///
/// Degenerate spread (all objectives equal, or non-finite input) yields
/// τ = 0 for every edge: the scheme then leaves the penalty at η⁰, which
/// is the paper's "onus on consensus" regime.
pub fn tau_from_objectives(f_self: f64, f_neighbors: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(f_neighbors.len());
    tau_from_objectives_into(f_self, f_neighbors, &mut out);
    out
}

/// [`tau_from_objectives`] into a caller-owned buffer — the hot-loop
/// variant behind the per-node schemes: each scheme owns a τ buffer
/// pre-sized to its degree and reuses it every iteration, so steady-state
/// penalty updates allocate nothing.
pub fn tau_from_objectives_into(f_self: f64, f_neighbors: &[f64], out: &mut Vec<f64>) {
    tau_from_objectives_masked_into(f_self, f_neighbors, None, out);
}

/// [`tau_from_objectives_into`] restricted to the *live* neighbour slots.
///
/// The net runtime ([`crate::net`]) runs the schemes over a dynamic
/// topology: slots whose edge is currently masked off carry stale or
/// placeholder objective values that must not contaminate the κ
/// normalization. With `live = Some(mask)`, the min/max spread runs over
/// `f_self` and the live entries only, dead slots get τ = 0, and a node
/// whose live neighbourhood is empty degenerates to all-zero τ (the η⁰
/// regime). `live = None` means every slot is live — bit-identical to the
/// unmasked computation, which is what the synchronous runtimes pass.
pub fn tau_from_objectives_masked_into(f_self: f64, f_neighbors: &[f64],
                                       live: Option<&[bool]>, out: &mut Vec<f64>) {
    out.clear();
    let is_live = |slot: usize| live.is_none_or(|m| m[slot]);
    if !f_self.is_finite()
        || f_neighbors
            .iter()
            .enumerate()
            .any(|(slot, f)| is_live(slot) && !f.is_finite())
    {
        out.resize(f_neighbors.len(), 0.0);
        return;
    }
    let mut f_min = f_self;
    let mut f_max = f_self;
    for (slot, &f) in f_neighbors.iter().enumerate() {
        if is_live(slot) {
            f_min = f_min.min(f);
            f_max = f_max.max(f);
        }
    }
    let spread = f_max - f_min;
    if !(spread.is_finite() && spread > 1e-300) {
        out.resize(f_neighbors.len(), 0.0);
        return;
    }
    let kappa = |f: f64| (f - f_min) / spread + 1.0;
    let k_self = kappa(f_self);
    out.extend(f_neighbors.iter().enumerate().map(|(slot, &f)| {
        if is_live(slot) {
            k_self / kappa(f) - 1.0
        } else {
            0.0
        }
    }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn better_neighbor_gets_positive_tau() {
        // neighbour 0 fits our data better (lower local objective) → τ > 0
        let tau = tau_from_objectives(10.0, &[5.0, 15.0]);
        assert!(tau[0] > 0.0, "{tau:?}");
        assert!(tau[1] < 0.0, "{tau:?}");
    }

    #[test]
    fn bounded_in_half_to_one() {
        prop::check("τ ∈ [−1/2, 1]", |rng| {
            let f_self = rng.range(-100.0, 100.0);
            let f_nb: Vec<f64> = (0..1 + rng.below(8))
                .map(|_| rng.range(-100.0, 100.0))
                .collect();
            for &t in &tau_from_objectives(f_self, &f_nb) {
                assert!((-0.5 - 1e-12..=1.0 + 1e-12).contains(&t), "τ = {t}");
            }
        });
    }

    #[test]
    fn equal_objectives_give_zero() {
        assert_eq!(tau_from_objectives(3.0, &[3.0, 3.0]), vec![0.0, 0.0]);
        assert_eq!(tau_from_objectives(3.0, &[]), Vec::<f64>::new());
    }

    #[test]
    fn extremes_hit_bounds() {
        // self is worst, neighbour is best: κ_self = 2, κ_nb = 1 → τ = 1
        let tau = tau_from_objectives(10.0, &[0.0]);
        assert!((tau[0] - 1.0).abs() < 1e-12);
        // self best, neighbour worst: κ_self = 1, κ_nb = 2 → τ = −1/2
        let tau = tau_from_objectives(0.0, &[10.0]);
        assert!((tau[0] + 0.5).abs() < 1e-12);
    }

    #[test]
    fn non_finite_objectives_fail_safe() {
        let tau = tau_from_objectives(f64::NAN, &[1.0, 2.0]);
        assert_eq!(tau, vec![0.0, 0.0]);
        let tau = tau_from_objectives(1.0, &[f64::INFINITY]);
        assert_eq!(tau, vec![0.0]);
    }

    #[test]
    fn masked_slots_get_zero_and_skip_normalization() {
        // unmasked: f_nb = [5, 1000] would stretch the spread; masking slot
        // 1 must reproduce the 2-point computation on [self, 5] exactly
        let mut masked = Vec::new();
        tau_from_objectives_masked_into(10.0, &[5.0, 1000.0],
                                        Some(&[true, false]), &mut masked);
        let two_point = tau_from_objectives(10.0, &[5.0]);
        assert_eq!(masked.len(), 2);
        assert_eq!(masked[0], two_point[0], "live slot matches unmasked 2-point τ");
        assert_eq!(masked[1], 0.0, "dead slot pinned to τ = 0");
    }

    #[test]
    fn masked_non_finite_dead_slot_is_harmless() {
        // a dead slot carrying NaN must not trip the fail-safe for the rest
        let mut masked = Vec::new();
        tau_from_objectives_masked_into(10.0, &[5.0, f64::NAN],
                                        Some(&[true, false]), &mut masked);
        assert!(masked[0] > 0.0, "{masked:?}");
        assert_eq!(masked[1], 0.0);
    }

    #[test]
    fn none_mask_is_bit_identical_to_unmasked() {
        prop::check("masked(None) ≡ unmasked", |rng| {
            let f_self = rng.range(-100.0, 100.0);
            let f_nb: Vec<f64> = (0..1 + rng.below(6))
                .map(|_| rng.range(-100.0, 100.0))
                .collect();
            let mut a = Vec::new();
            let mut b = Vec::new();
            tau_from_objectives_into(f_self, &f_nb, &mut a);
            tau_from_objectives_masked_into(f_self, &f_nb, None, &mut b);
            assert_eq!(a, b);
        });
    }

    #[test]
    fn all_dead_mask_degenerates_to_zero() {
        let mut out = Vec::new();
        tau_from_objectives_masked_into(10.0, &[5.0, 7.0],
                                        Some(&[false, false]), &mut out);
        assert_eq!(out, vec![0.0, 0.0]);
    }

    #[test]
    fn scale_invariant() {
        prop::check("τ invariant to affine objective rescaling", |rng| {
            let f_self = rng.range(0.0, 10.0);
            let f_nb: Vec<f64> = (0..3).map(|_| rng.range(0.0, 10.0)).collect();
            let a = rng.range(0.5, 20.0);
            let b = rng.range(-50.0, 50.0);
            let t1 = tau_from_objectives(f_self, &f_nb);
            let scaled: Vec<f64> = f_nb.iter().map(|&f| a * f + b).collect();
            let t2 = tau_from_objectives(a * f_self + b, &scaled);
            for (x, y) in t1.iter().zip(&t2) {
                assert!((x - y).abs() < 1e-9);
            }
        });
    }
}
