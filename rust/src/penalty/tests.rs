//! Scheme invariants (unit + property tests).

use super::*;
use crate::util::prop;
use crate::util::rng::Pcg;

fn obs<'a>(t: usize, primal: f64, dual: f64, f_self: f64, f_prev: f64,
           f_nb: &'a [f64]) -> NodeObservation<'a> {
    NodeObservation {
        t,
        primal_norm: primal,
        dual_norm: dual,
        global_primal: primal,
        global_dual: dual,
        f_self,
        f_self_prev: f_prev,
        f_neighbors: f_nb,
        live: None,
    }
}

fn random_obs<'a>(rng: &mut Pcg, t: usize, f_nb: &'a mut Vec<f64>, deg: usize)
                  -> NodeObservation<'a> {
    f_nb.clear();
    for _ in 0..deg {
        f_nb.push(rng.range(0.0, 100.0));
    }
    NodeObservation {
        t,
        primal_norm: rng.range(0.0, 5.0),
        dual_norm: rng.range(0.0, 5.0),
        global_primal: rng.range(0.0, 5.0),
        global_dual: rng.range(0.0, 5.0),
        f_self: rng.range(0.0, 100.0),
        f_self_prev: rng.range(0.0, 100.0),
        f_neighbors: f_nb,
        live: None,
    }
}

#[test]
fn fixed_never_changes() {
    let mut s = make_scheme(SchemeKind::Fixed, SchemeParams::default(), 3);
    let mut eta = vec![10.0; 3];
    s.update(&obs(0, 100.0, 0.1, 5.0, 9.0, &[1.0, 2.0, 3.0]), &mut eta);
    assert_eq!(eta, vec![10.0; 3]);
}

#[test]
fn vp_doubles_on_large_primal_and_halves_on_large_dual() {
    let p = SchemeParams::default();
    let mut s = make_scheme(SchemeKind::Vp, p, 2);
    let mut eta = vec![10.0; 2];
    s.update(&obs(0, 100.0, 0.1, 0.0, 0.0, &[0.0, 0.0]), &mut eta);
    assert_eq!(eta, vec![20.0; 2]);
    s.update(&obs(1, 0.1, 100.0, 0.0, 0.0, &[0.0, 0.0]), &mut eta);
    assert_eq!(eta, vec![10.0; 2]);
    // within the μ band: hold
    s.update(&obs(2, 1.0, 1.0, 0.0, 0.0, &[0.0, 0.0]), &mut eta);
    assert_eq!(eta, vec![10.0; 2]);
}

#[test]
fn vp_resets_homogeneously_at_tmax() {
    let p = SchemeParams { t_max: 5, ..Default::default() };
    let mut s = make_scheme(SchemeKind::Vp, p, 2);
    let mut eta = vec![10.0; 2];
    for t in 0..5 {
        s.update(&obs(t, 100.0, 0.1, 0.0, 0.0, &[0.0, 0.0]), &mut eta);
    }
    assert!(eta[0] > 100.0); // grew substantially
    s.update(&obs(5, 100.0, 0.1, 0.0, 0.0, &[0.0, 0.0]), &mut eta);
    assert_eq!(eta, vec![10.0; 2]); // homogeneous reset
}

#[test]
fn vp_keeps_slots_homogeneous() {
    prop::check("VP slots identical (per-node penalty)", |rng| {
        let mut s = make_scheme(SchemeKind::Vp, SchemeParams::default(), 4);
        let mut eta = vec![10.0; 4];
        let mut f_nb = Vec::new();
        for t in 0..30 {
            let o = random_obs(rng, t, &mut f_nb, 4);
            s.update(&o, &mut eta);
            for w in eta.windows(2) {
                assert_eq!(w[0], w[1]);
            }
        }
    });
}

#[test]
fn ap_bounded_by_half_and_double_eta0() {
    prop::check("AP η ∈ [η⁰/2, 2η⁰]", |rng| {
        let p = SchemeParams::default();
        let mut s = make_scheme(SchemeKind::Ap, p, 3);
        let mut eta = vec![p.eta0; 3];
        let mut f_nb = Vec::new();
        for t in 0..60 {
            let o = random_obs(rng, t, &mut f_nb, 3);
            s.update(&o, &mut eta);
            for &e in &eta {
                assert!(e >= p.eta0 * 0.5 - 1e-9 && e <= p.eta0 * 2.0 + 1e-9, "η = {e}");
            }
        }
    });
}

#[test]
fn ap_rewards_better_neighbors() {
    let p = SchemeParams::default();
    let mut s = make_scheme(SchemeKind::Ap, p, 2);
    let mut eta = vec![p.eta0; 2];
    // neighbour 0 much better than us, neighbour 1 much worse
    s.update(&obs(0, 1.0, 1.0, 10.0, 11.0, &[0.0, 20.0]), &mut eta);
    assert!(eta[0] > p.eta0, "{eta:?}");
    assert!(eta[1] < p.eta0, "{eta:?}");
}

#[test]
fn ap_respects_tight_eta_clamp() {
    // degenerate configuration: eta_clamp < 2 makes AP's natural range
    // [η⁰/2, 2η⁰] overflow the numerical clamp — AP must clamp exactly
    // like VP/RB/NAP do (regression: AP used to publish η unclamped)
    let p = SchemeParams { eta_clamp: 1.2, ..Default::default() };
    let mut s = make_scheme(SchemeKind::Ap, p, 2);
    let mut eta = vec![p.eta0; 2];
    // neighbour 0 far better (τ = ½ → unclamped 1.5η⁰ > 1.2η⁰),
    // neighbour 1 far worse (τ = −¼ → unclamped 0.75η⁰ < η⁰/1.2)
    s.update(&obs(0, 1.0, 1.0, 10.0, 10.0, &[0.0, 20.0]), &mut eta);
    assert_eq!(eta[0], p.eta0 * p.eta_clamp);
    assert_eq!(eta[1], p.eta0 / p.eta_clamp);
}

#[test]
fn ap_degenerate_objective_ratio_pins_to_eta0() {
    // degenerate objective ratios (no spread / non-finite) give τ = 0:
    // the update must land exactly on η⁰, inside any clamp
    let p = SchemeParams { eta_clamp: 1.5, ..Default::default() };
    let mut s = make_scheme(SchemeKind::Ap, p, 2);
    let mut eta = vec![p.eta0 * 1.4; 2];
    s.update(&obs(0, 5.0, 5.0, f64::NAN, 0.0, &[1.0, 2.0]), &mut eta);
    assert_eq!(eta, vec![p.eta0; 2]);
    let mut eta = vec![p.eta0 * 1.4; 2];
    s.update(&obs(1, 5.0, 5.0, 3.0, 3.0, &[3.0, 3.0]), &mut eta);
    assert_eq!(eta, vec![p.eta0; 2]);
}

#[test]
fn ap_reverts_to_eta0_after_tmax() {
    let p = SchemeParams { t_max: 3, ..Default::default() };
    let mut s = make_scheme(SchemeKind::Ap, p, 1);
    let mut eta = vec![p.eta0];
    s.update(&obs(2, 1.0, 1.0, 10.0, 10.0, &[0.0]), &mut eta);
    assert!(eta[0] > p.eta0);
    s.update(&obs(3, 1.0, 1.0, 10.0, 10.0, &[0.0]), &mut eta);
    assert_eq!(eta[0], p.eta0);
}

#[test]
fn nap_budget_blocks_after_exhaustion() {
    // tiny budget, stable objective: after spending 𝒯 the edge pins to η⁰
    let p = SchemeParams { budget: 0.5, beta: 1e9, ..Default::default() };
    let mut s = make_scheme(SchemeKind::Nap, p, 1);
    let mut eta = vec![p.eta0];
    let mut pinned = 0;
    for t in 0..50 {
        // τ = 1 every iteration (neighbour always better)
        s.update(&obs(t, 1.0, 1.0, 10.0, 10.0, &[0.0]), &mut eta);
        if eta[0] == p.eta0 {
            pinned += 1;
        } else {
            assert_eq!(eta[0], 2.0 * p.eta0);
        }
    }
    assert!(pinned >= 48, "budget 0.5 admits one τ=1 update, got {pinned} pins");
}

#[test]
fn nap_budget_grows_while_objective_moves() {
    // same budget but the objective keeps moving → bound grows past the
    // spent τ (α close to 1 so the geometric limit 𝒯/(1−α) = 5 > Σ|τ|)
    let p = SchemeParams { budget: 0.5, alpha: 0.9, beta: 0.1, ..Default::default() };
    let mut s = make_scheme(SchemeKind::Nap, p, 1);
    let mut eta = vec![p.eta0];
    let mut adapted = 0;
    for t in 0..50 {
        // objective moving by 1.0 > β each iteration
        s.update(&obs(t, 1.0, 1.0, 10.0 + t as f64, 9.0 + t as f64, &[0.0]), &mut eta);
        if eta[0] != p.eta0 {
            adapted += 1;
        }
    }
    assert!(adapted >= 2, "growing budget admits ≥ 2 updates, got {adapted}");
}

#[test]
fn nap_budget_respects_geometric_bound() {
    prop::check("𝒯_ij ≤ 𝒯/(1−α) (paper eq. 11)", |rng| {
        let alpha = rng.range(0.2, 0.9);
        let budget = rng.range(0.1, 3.0);
        let p = SchemeParams { budget, alpha, beta: 0.0, ..Default::default() };
        let mut s = make_scheme(SchemeKind::Nap, p, 2);
        let mut eta = vec![p.eta0; 2];
        let mut f_nb = Vec::new();
        // adversarial: objective always moves, τ always ±1-ish
        for t in 0..200 {
            let o = random_obs(rng, t, &mut f_nb, 2);
            s.update(&o, &mut eta);
        }
        // drive once more and introspect via behaviour: an edge pinned to η⁰
        // implies spent ≥ bound; the bound can never exceed 𝒯/(1−α)
        let limit = budget / (1.0 - alpha) + 1e-9;
        // we can't read private state, so assert the *observable* bound:
        // total adaptation budget implies spent ≤ limit + final |τ| ≤ limit + 1
        // (checked indirectly by the pin count over a long horizon)
        let mut pins = 0;
        for t in 200..400 {
            s.update(&obs(t, 1.0, 1.0, 10.0, 10.0, &[0.0, 20.0]), &mut eta);
            if eta[0] == p.eta0 {
                pins += 1;
            }
        }
        // with a stable objective the budget stops growing ⇒ eventually all pins
        assert!(pins >= 195, "edges must pin once spent exceeds ≤ {limit}, pins={pins}");
    });
}

#[test]
fn vpap_direction_and_magnitude() {
    let p = SchemeParams::default();
    let mut s = make_scheme(SchemeKind::VpAp, p, 1);
    let mut eta = vec![p.eta0];
    // primal-dominant, neighbour better (τ = 1): η ← η·2·2
    s.update(&obs(0, 100.0, 0.1, 10.0, 10.0, &[0.0]), &mut eta);
    assert_eq!(eta[0], 40.0);
    // dual-dominant, neighbour worse (τ = −1/2): η ← η·(1/2)·(1/2)
    s.update(&obs(1, 0.1, 100.0, 0.0, 0.0, &[10.0]), &mut eta);
    assert_eq!(eta[0], 10.0);
    // band: hold
    s.update(&obs(2, 1.0, 1.0, 5.0, 5.0, &[5.0]), &mut eta);
    assert_eq!(eta[0], 10.0);
}

#[test]
fn vpap_resets_after_tmax() {
    let p = SchemeParams { t_max: 2, ..Default::default() };
    let mut s = make_scheme(SchemeKind::VpAp, p, 1);
    let mut eta = vec![p.eta0];
    s.update(&obs(0, 100.0, 0.1, 10.0, 10.0, &[0.0]), &mut eta);
    s.update(&obs(1, 100.0, 0.1, 10.0, 10.0, &[0.0]), &mut eta);
    assert!(eta[0] > p.eta0);
    s.update(&obs(2, 100.0, 0.1, 10.0, 10.0, &[0.0]), &mut eta);
    assert_eq!(eta[0], p.eta0);
}

#[test]
fn vpnap_gated_by_budget_not_tmax() {
    // t_max tiny but budget generous: VP+NAP keeps adapting past t_max
    let p = SchemeParams { t_max: 1, budget: 100.0, beta: 1e9, ..Default::default() };
    let mut s = make_scheme(SchemeKind::VpNap, p, 1);
    let mut eta = vec![p.eta0];
    for t in 0..10 {
        s.update(&obs(t, 100.0, 0.1, 10.0, 10.0, &[0.0]), &mut eta);
    }
    assert!(eta[0] > p.eta0, "still adapting at t=10 despite t_max=1: {eta:?}");
}

#[test]
fn rb_uses_global_residuals_and_freezes() {
    let p = SchemeParams { t_max: 2, ..Default::default() };
    let mut s = make_scheme(SchemeKind::Rb, p, 2);
    let mut eta = vec![p.eta0; 2];
    // local says shrink, global says grow → RB must grow
    let o = NodeObservation {
        t: 0,
        primal_norm: 0.1,
        dual_norm: 100.0,
        global_primal: 100.0,
        global_dual: 0.1,
        f_self: 0.0,
        f_self_prev: 0.0,
        f_neighbors: &[0.0, 0.0],
        live: None,
    };
    s.update(&o, &mut eta);
    assert_eq!(eta, vec![20.0; 2]);
    // after t_max: frozen, not reset
    s.update(&NodeObservation { t: 2, ..o.clone() }, &mut eta);
    assert_eq!(eta, vec![20.0; 2]);
}

#[test]
fn eta_clamped_under_adversarial_residuals() {
    prop::check("η stays within clamp under any observation stream", |rng| {
        let p = SchemeParams::default();
        for kind in SchemeKind::ALL {
            let mut s = make_scheme(kind, p, 2);
            let mut eta = vec![p.eta0; 2];
            let mut f_nb = Vec::new();
            for t in 0..120 {
                let o = random_obs(rng, t, &mut f_nb, 2);
                s.update(&o, &mut eta);
                for &e in &eta {
                    assert!(e.is_finite() && e > 0.0, "{kind:?}: η = {e}");
                    assert!(e <= p.eta0 * p.eta_clamp * 2.0 + 1e-9, "{kind:?}: η = {e}");
                }
            }
        }
    });
}

#[test]
fn dead_slots_freeze_eta_in_every_scheme() {
    // under a liveness mask, every scheme must leave a dead slot's η
    // untouched no matter what the observations say, while live slots
    // keep adapting
    let p = SchemeParams::default();
    for kind in SchemeKind::ALL {
        let mut s = make_scheme(kind, p, 3);
        let mut eta = vec![p.eta0; 3];
        let f_nb = [1.0, 2.0, 50.0];
        let live = [true, false, true];
        for t in 0..80 {
            let o = NodeObservation {
                t,
                primal_norm: 100.0,
                dual_norm: 0.1,
                global_primal: 100.0,
                global_dual: 0.1,
                f_self: 25.0,
                f_self_prev: 40.0,
                f_neighbors: &f_nb,
                live: Some(&live),
            };
            s.update(&o, &mut eta);
            assert_eq!(eta[1], p.eta0, "{kind:?}: dead slot drifted at t={t}");
            for &e in &eta {
                assert!(e.is_finite() && e > 0.0, "{kind:?}: η = {e}");
            }
        }
    }
}

#[test]
fn all_live_mask_matches_none_bitwise() {
    // Some(all-true) must reproduce the unmasked trajectory bit-for-bit —
    // the async runtime relies on this to switch masks on and off freely
    let p = SchemeParams::default();
    let live = [true, true];
    for kind in SchemeKind::ALL {
        let mut a = make_scheme(kind, p, 2);
        let mut b = make_scheme(kind, p, 2);
        let mut eta_a = vec![p.eta0; 2];
        let mut eta_b = vec![p.eta0; 2];
        let mut rng = Pcg::seed(99);
        let mut f_nb = Vec::new();
        for t in 0..60 {
            let o = random_obs(&mut rng, t, &mut f_nb, 2);
            a.update(&o, &mut eta_a);
            let masked = NodeObservation { live: Some(&live), ..o.clone() };
            b.update(&masked, &mut eta_b);
            assert_eq!(eta_a, eta_b, "{kind:?} diverged at t={t}");
        }
    }
}

#[test]
fn nap_budget_not_spent_on_dead_slots() {
    // freeze slot 0 for the whole budgeted phase: when the mask lifts, the
    // slot must still have budget to spend (its η starts adapting) while
    // an always-live slot with the same stream has already exhausted its
    let p = SchemeParams { budget: 0.5, ..Default::default() };
    let mut s = make_scheme(SchemeKind::Nap, p, 2);
    let mut eta = vec![p.eta0; 2];
    let f_nb = [1.0, 1.0];
    let live = [false, true];
    // τ for a live slot here: f_self = 5 > f_nb → τ = 1 (spends 1.0 > 0.5)
    for t in 0..4 {
        let o = NodeObservation {
            t,
            primal_norm: 1.0,
            dual_norm: 1.0,
            global_primal: 1.0,
            global_dual: 1.0,
            f_self: 5.0,
            f_self_prev: 5.0,
            f_neighbors: &f_nb,
            live: Some(&live),
        };
        s.update(&o, &mut eta);
    }
    assert_eq!(eta[0], p.eta0, "dead slot untouched");
    assert_eq!(eta[1], p.eta0, "live slot exhausted its budget → reset to η⁰");
    // unmask slot 0: it still has budget, so the AP-style step applies
    let o = NodeObservation {
        t: 4,
        primal_norm: 1.0,
        dual_norm: 1.0,
        global_primal: 1.0,
        global_dual: 1.0,
        f_self: 5.0,
        f_self_prev: 5.0,
        f_neighbors: &f_nb,
        live: Some(&[true, true]),
    };
    s.update(&o, &mut eta);
    assert_eq!(eta[0], p.eta0 * 2.0, "fresh budget spends on first live update");
    assert_eq!(eta[1], p.eta0, "exhausted slot stays at η⁰");
}

#[test]
fn parse_name_roundtrip() {
    for kind in SchemeKind::ALL {
        assert_eq!(SchemeKind::parse(kind.name()).unwrap(), kind);
    }
    assert!(SchemeKind::parse("bogus").is_err());
}

#[test]
fn needs_neighbor_objectives_flags() {
    let p = SchemeParams::default();
    assert!(!make_scheme(SchemeKind::Fixed, p, 1).needs_neighbor_objectives());
    assert!(!make_scheme(SchemeKind::Vp, p, 1).needs_neighbor_objectives());
    assert!(make_scheme(SchemeKind::Ap, p, 1).needs_neighbor_objectives());
    assert!(make_scheme(SchemeKind::Nap, p, 1).needs_neighbor_objectives());
    assert!(make_scheme(SchemeKind::VpNap, p, 1).needs_neighbor_objectives());
}

#[test]
fn needs_global_residuals_flags() {
    // only the non-decentralized RB reference reads the folded global
    // residuals (the async runtime gates its update on the round's fold)
    let p = SchemeParams::default();
    for kind in SchemeKind::ALL {
        let expect = kind == SchemeKind::Rb;
        assert_eq!(make_scheme(kind, p, 2).needs_global_residuals(), expect,
                   "{kind:?}");
    }
}
