//! Process-global report sink for the `repro --obs` path.
//!
//! The experiment drivers build their runtime configs internally, so a
//! CLI flag cannot thread `obs: true` through every sweep cell. Instead
//! the launcher calls [`enable_global`] once before dispatching; from
//! then on every runtime constructs its registry with spans live
//! ([`global_spans_enabled`] ORs into the per-config `obs` knob) and
//! merges its finished registry here ([`global_merge`]) exactly once,
//! at report construction. The launcher drains the aggregate with
//! [`take_global`] after the experiment returns and writes the JSON /
//! Prometheus files.
//!
//! Off by default: the statics cost one relaxed atomic load per *run*
//! (not per round), nothing is registered, and the library test suite
//! never touches this path. The precedent for a process-global counter
//! is [`crate::pool::threads_spawned`].

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use super::timeline::{RoundRow, TlEvent};
use super::MetricsRegistry;

static SPANS: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<MetricsRegistry>> = Mutex::new(None);

static TIMELINE_ON: AtomicBool = AtomicBool::new(false);
static TIMELINE_SINK: Mutex<Option<Vec<TlEvent>>> = Mutex::new(None);

static SERIES_ON: AtomicBool = AtomicBool::new(false);
/// retained rows + decimation-dropped count, summed across merged runs
static SERIES_SINK: Mutex<Option<(Vec<RoundRow>, u64)>> = Mutex::new(None);

fn sink() -> std::sync::MutexGuard<'static, Option<MetricsRegistry>> {
    // a panicking merger cannot corrupt a registry (merge is additive),
    // so recover from poison instead of propagating it
    SINK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn timeline_sink() -> std::sync::MutexGuard<'static, Option<Vec<TlEvent>>> {
    TIMELINE_SINK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

fn series_sink() -> std::sync::MutexGuard<'static, Option<(Vec<RoundRow>, u64)>> {
    SERIES_SINK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Turn the global sink on: spans go live in every subsequently built
/// registry and finished runs merge into one process-wide aggregate.
/// Idempotent; there is deliberately no `disable` — the launcher
/// enables once and drains once.
pub fn enable_global() {
    SPANS.store(true, Ordering::Relaxed);
    let mut g = sink();
    if g.is_none() {
        *g = Some(MetricsRegistry::new(false));
    }
}

/// Whether [`enable_global`] has been called in this process. Runtimes
/// OR this into their config's `obs` flag when building a registry.
pub fn global_spans_enabled() -> bool {
    SPANS.load(Ordering::Relaxed)
}

/// Fold a finished run's registry into the global aggregate (no-op
/// while the sink is disabled). Counters and histograms add across
/// runs; gauges keep the last run's value.
pub fn global_merge(reg: &MetricsRegistry) {
    if let Some(agg) = sink().as_mut() {
        agg.merge(reg);
    }
}

/// Drain the aggregate (leaves the sink empty but spans still live).
pub fn take_global() -> Option<MetricsRegistry> {
    sink().take()
}

/// Turn the global timeline sink on (`repro … --trace`): every
/// subsequently built runtime records a live [`crate::obs::Timeline`]
/// and appends its drained events here at finish. Idempotent.
pub fn enable_global_timeline() {
    TIMELINE_ON.store(true, Ordering::Relaxed);
    let mut g = timeline_sink();
    if g.is_none() {
        *g = Some(Vec::new());
    }
}

/// Whether [`enable_global_timeline`] has been called. Runtimes OR this
/// into their config's `timeline` knob.
pub fn global_timeline_enabled() -> bool {
    TIMELINE_ON.load(Ordering::Relaxed)
}

/// Append a finished run's drained timeline events (no-op while the
/// timeline sink is disabled).
pub fn global_timeline_merge(events: Vec<TlEvent>) {
    if let Some(agg) = timeline_sink().as_mut() {
        agg.extend(events);
    }
}

/// Drain the accumulated timeline events.
pub fn take_global_timeline() -> Option<Vec<TlEvent>> {
    timeline_sink().take()
}

/// Turn the global series sink on (`repro … --series`). Idempotent.
pub fn enable_global_series() {
    SERIES_ON.store(true, Ordering::Relaxed);
    let mut g = series_sink();
    if g.is_none() {
        *g = Some((Vec::new(), 0));
    }
}

/// Whether [`enable_global_series`] has been called. Runtimes OR this
/// into their config's `series` knob.
pub fn global_series_enabled() -> bool {
    SERIES_ON.load(Ordering::Relaxed)
}

/// Append a finished run's series rows and decimation drop count
/// (no-op while the series sink is disabled).
pub fn global_series_merge(rows: Vec<RoundRow>, dropped: u64) {
    if let Some((agg, drops)) = series_sink().as_mut() {
        agg.extend(rows);
        *drops += dropped;
    }
}

/// Drain the accumulated series rows and drop count.
pub fn take_global_series() -> Option<(Vec<RoundRow>, u64)> {
    series_sink().take()
}

/// Install a panic hook that flushes a best-effort crash snapshot to
/// `path` before unwinding: the panic message and location, whatever
/// the metrics sink has aggregated so far, and the timeline event
/// count. Chains the previous hook (so the default backtrace still
/// prints). SIGKILL leaves nothing — this covers panics; the proc
/// transport's SIGKILL scenarios get their evidence from the *other*
/// machines' hooks and the driver's snapshot.
pub fn install_crash_hook(path: PathBuf) {
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        write_crash_snapshot(&path, info);
        prev(info);
    }));
}

fn write_crash_snapshot(path: &std::path::Path, info: &std::panic::PanicHookInfo<'_>) {
    use crate::util::json::{num, obj, s};
    let msg = if let Some(m) = info.payload().downcast_ref::<&str>() {
        (*m).to_string()
    } else if let Some(m) = info.payload().downcast_ref::<String>() {
        m.clone()
    } else {
        "non-string panic payload".to_string()
    };
    let location = info
        .location()
        .map(|l| format!("{}:{}:{}", l.file(), l.line(), l.column()))
        .unwrap_or_else(|| "unknown".to_string());
    // clone rather than take: the snapshot must not consume state the
    // normal (caught-panic) reporting path still wants to write
    let metrics = sink()
        .as_ref()
        .map(|r| r.to_json())
        .unwrap_or_else(|| obj(vec![]));
    let timeline_events =
        timeline_sink().as_ref().map(|v| v.len()).unwrap_or(0);
    let series_rows =
        series_sink().as_ref().map(|(v, _)| v.len()).unwrap_or(0);
    let doc = obj(vec![
        ("panic", s(msg)),
        ("location", s(location)),
        ("metrics", metrics),
        ("timeline_events", num(timeline_events as f64)),
        ("series_rows", num(series_rows as f64)),
    ]);
    // best-effort by design: a failing write must not abort the unwind
    let _ = std::fs::write(path, doc.to_string());
}

#[cfg(test)]
mod tests {
    // NOTE: no test enables the global sink — it is process-wide state
    // and the harness runs tests concurrently. `enable_global` is
    // exercised end-to-end by the `repro --obs` launcher path; the
    // disabled-path contract (merge is a no-op) is what matters here.
    use super::*;

    #[test]
    fn disabled_sink_ignores_merges_and_drains_nothing() {
        let mut reg = MetricsRegistry::new(false);
        let c = reg.counter("fadmm_rounds_total");
        reg.inc(c, 3);
        global_merge(&reg);
        // the sink is never enabled in the test binary, so the merge
        // must have gone nowhere
        assert!(!global_spans_enabled());
        assert!(take_global().is_none());
    }
}
