//! Process-global report sink for the `repro --obs` path.
//!
//! The experiment drivers build their runtime configs internally, so a
//! CLI flag cannot thread `obs: true` through every sweep cell. Instead
//! the launcher calls [`enable_global`] once before dispatching; from
//! then on every runtime constructs its registry with spans live
//! ([`global_spans_enabled`] ORs into the per-config `obs` knob) and
//! merges its finished registry here ([`global_merge`]) exactly once,
//! at report construction. The launcher drains the aggregate with
//! [`take_global`] after the experiment returns and writes the JSON /
//! Prometheus files.
//!
//! Off by default: the statics cost one relaxed atomic load per *run*
//! (not per round), nothing is registered, and the library test suite
//! never touches this path. The precedent for a process-global counter
//! is [`crate::pool::threads_spawned`].

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use super::MetricsRegistry;

static SPANS: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<MetricsRegistry>> = Mutex::new(None);

fn sink() -> std::sync::MutexGuard<'static, Option<MetricsRegistry>> {
    // a panicking merger cannot corrupt a registry (merge is additive),
    // so recover from poison instead of propagating it
    SINK.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Turn the global sink on: spans go live in every subsequently built
/// registry and finished runs merge into one process-wide aggregate.
/// Idempotent; there is deliberately no `disable` — the launcher
/// enables once and drains once.
pub fn enable_global() {
    SPANS.store(true, Ordering::Relaxed);
    let mut g = sink();
    if g.is_none() {
        *g = Some(MetricsRegistry::new(false));
    }
}

/// Whether [`enable_global`] has been called in this process. Runtimes
/// OR this into their config's `obs` flag when building a registry.
pub fn global_spans_enabled() -> bool {
    SPANS.load(Ordering::Relaxed)
}

/// Fold a finished run's registry into the global aggregate (no-op
/// while the sink is disabled). Counters and histograms add across
/// runs; gauges keep the last run's value.
pub fn global_merge(reg: &MetricsRegistry) {
    if let Some(agg) = sink().as_mut() {
        agg.merge(reg);
    }
}

/// Drain the aggregate (leaves the sink empty but spans still live).
pub fn take_global() -> Option<MetricsRegistry> {
    sink().take()
}

#[cfg(test)]
mod tests {
    // NOTE: no test enables the global sink — it is process-wide state
    // and the harness runs tests concurrently. `enable_global` is
    // exercised end-to-end by the `repro --obs` launcher path; the
    // disabled-path contract (merge is a no-op) is what matters here.
    use super::*;

    #[test]
    fn disabled_sink_ignores_merges_and_drains_nothing() {
        let mut reg = MetricsRegistry::new(false);
        let c = reg.counter("fadmm_rounds_total");
        reg.inc(c, 3);
        global_merge(&reg);
        // the sink is never enabled in the test binary, so the merge
        // must have gone nowhere
        assert!(!global_spans_enabled());
        assert!(take_global().is_none());
    }
}
