//! Chrome trace-event JSON export of a [`Timeline`] — open the file in
//! `chrome://tracing` or <https://ui.perfetto.dev>.
//!
//! Layout: one process (`pid` 1, named after the run), one track
//! (`tid` = machine id) per machine, with a `thread_name` metadata
//! record per track. Events map as:
//!
//! | timeline event | trace event |
//! |---|---|
//! | [`TlKind::Phase`] | `"X"` complete slice (`dur` from the span ns) |
//! | [`TlKind::Send`] | 1 µs `"X"` slice + `"s"` flow start |
//! | [`TlKind::Recv`] | 1 µs `"X"` slice + `"f"` flow finish |
//! | [`TlKind::Commit`] | `"i"` instant (thread scope) |
//!
//! Flow ids are the frame's `"machine:seq"` context key, so every
//! delivered frame draws a send→deliver arrow between machine tracks —
//! including duplicated deliveries, which share the send's id. `ts` is
//! transport ticks converted to µs (ticks are ms on every transport:
//! virtual ms on the simulator, wall ms on the real backends), so the
//! horizontal axis is the transport clock, not the host clock.

use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::{arr, num, obj, s, Json};

use super::timeline::{TlEvent, TlKind};

/// Ticks (ms) → trace-event `ts` (µs).
fn ts_us(at: u64) -> f64 {
    (at as f64) * 1000.0
}

fn base(name: &str, ph: &str, machine: usize, at: u64) -> Vec<(&'static str, Json)> {
    vec![
        ("name", s(name)),
        ("ph", s(ph)),
        ("pid", num(1.0)),
        ("tid", num(machine as f64)),
        ("ts", num(ts_us(at))),
        ("cat", s("fadmm")),
    ]
}

/// Build the trace-event document for a drained timeline. `run` names
/// the process track (e.g. the repro subcommand).
pub fn chrome_trace_json(run: &str, events: &[TlEvent]) -> Json {
    let mut out: Vec<Json> = Vec::new();

    // one metadata record per machine track, emitted for every machine
    // that appears anywhere in the event stream
    let mut machines: Vec<usize> = events.iter().map(|e| e.machine).collect();
    machines.sort_unstable();
    machines.dedup();
    out.push(obj(vec![
        ("name", s("process_name")),
        ("ph", s("M")),
        ("pid", num(1.0)),
        ("tid", num(0.0)),
        ("args", obj(vec![("name", s(run))])),
    ]));
    for &m in &machines {
        out.push(obj(vec![
            ("name", s("thread_name")),
            ("ph", s("M")),
            ("pid", num(1.0)),
            ("tid", num(m as f64)),
            ("args", obj(vec![("name", s(format!("machine {m}")))])),
        ]));
    }

    for ev in events {
        let round_arg = ("round", num(ev.round as f64));
        match ev.kind {
            TlKind::Phase { phase, dur_ns } => {
                let mut e = base(phase.name(), "X", ev.machine, ev.at);
                // a slice needs a visible duration; spans-off runs
                // record 0 ns, rendered as the 1 µs minimum
                e.push(("dur", num(((dur_ns / 1000).max(1)) as f64)));
                e.push(("args", obj(vec![round_arg, ("dur_ns", num(dur_ns as f64))])));
                out.push(obj(e));
            }
            TlKind::Send { seq, dst, what } => {
                let id = format!("{}:{}", ev.machine, seq);
                let mut slice = base(&format!("send {what}"), "X", ev.machine, ev.at);
                slice.push(("dur", num(1.0)));
                slice.push(("args", obj(vec![round_arg, ("dst", num(dst as f64))])));
                out.push(obj(slice));
                let mut flow = base(what, "s", ev.machine, ev.at);
                flow.push(("id", s(id)));
                out.push(obj(flow));
            }
            TlKind::Recv { seq, src, what } => {
                let id = format!("{src}:{seq}");
                let mut slice = base(&format!("recv {what}"), "X", ev.machine, ev.at);
                slice.push(("dur", num(1.0)));
                slice.push(("args", obj(vec![round_arg, ("src", num(src as f64))])));
                out.push(obj(slice));
                let mut flow = base(what, "f", ev.machine, ev.at);
                flow.push(("id", s(id)));
                // bind to the enclosing (recv) slice rather than the next
                flow.push(("bp", s("e")));
                out.push(obj(flow));
            }
            TlKind::Commit => {
                let mut e = base(&format!("commit r{}", ev.round), "i", ev.machine, ev.at);
                e.push(("s", s("t")));
                e.push(("args", obj(vec![round_arg])));
                out.push(obj(e));
            }
        }
    }

    obj(vec![
        ("traceEvents", arr(out)),
        ("displayTimeUnit", s("ms")),
    ])
}

/// Write the trace-event document to `path`.
pub fn write_chrome_trace(path: &Path, run: &str, events: &[TlEvent]) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .map_err(|e| Error::io(format!("mkdir {}", dir.display()), e))?;
        }
    }
    std::fs::write(path, chrome_trace_json(run, events).to_string())
        .map_err(|e| Error::io(format!("write {}", path.display()), e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::timeline::{Phase, TraceCtx, Timeline};

    fn sample_events() -> Vec<TlEvent> {
        let mut tl = Timeline::new(true);
        let ctx = TraceCtx { round: 3, machine: 0, seq: 17 };
        tl.phase(10, 0, 3, Phase::Solve, 2_500_000);
        tl.send(11, ctx, 1, "theta");
        tl.recv(14, 1, ctx, "theta");
        tl.commit(15, 1, 3);
        tl.drain()
    }

    fn events_of(j: &Json) -> Vec<Json> {
        j.get("traceEvents").unwrap().as_arr().unwrap().to_vec()
    }

    #[test]
    fn tracks_and_flows_are_emitted() {
        let j = chrome_trace_json("test", &sample_events());
        let evs = events_of(&j);
        // process_name + two thread_name records (machines 0 and 1)
        let meta: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("M"))
            .collect();
        assert_eq!(meta.len(), 3);
        assert!(meta.iter().any(|e| {
            e.get("args").unwrap().get("name").unwrap().as_str() == Some("machine 1")
        }));
        // the send→deliver flow shares one id across "s" and "f"
        let flow_s = evs
            .iter()
            .find(|e| e.get("ph").unwrap().as_str() == Some("s"))
            .expect("flow start");
        let flow_f = evs
            .iter()
            .find(|e| e.get("ph").unwrap().as_str() == Some("f"))
            .expect("flow finish");
        assert_eq!(flow_s.get("id"), flow_f.get("id"));
        assert_eq!(flow_s.get("id").unwrap().as_str(), Some("0:17"));
        assert_eq!(flow_s.get("tid").unwrap().as_f64(), Some(0.0));
        assert_eq!(flow_f.get("tid").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn phase_slices_carry_duration_and_round() {
        let j = chrome_trace_json("test", &sample_events());
        let evs = events_of(&j);
        let solve = evs
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("solve"))
            .expect("solve slice");
        assert_eq!(solve.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(solve.get("dur").unwrap().as_f64(), Some(2500.0), "ns → µs");
        assert_eq!(solve.get("ts").unwrap().as_f64(), Some(10_000.0), "ms → µs");
        assert_eq!(
            solve.get("args").unwrap().get("round").unwrap().as_f64(),
            Some(3.0)
        );
    }

    #[test]
    fn document_parses_and_has_display_unit() {
        let j = chrome_trace_json("test", &sample_events());
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
        assert!(!events_of(&back).is_empty());
    }
}
