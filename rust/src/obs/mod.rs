//! Unified observability layer: one metrics registry, phase spans, and
//! a bounded flight recorder shared by all four runtimes and all three
//! transports.
//!
//! Before this module, telemetry was runtime-specific fragments:
//! [`crate::metrics::NetCounters`], `NetSim`'s unbounded trace log,
//! `pool::threads_spawned()`, and ad-hoc bench JSON. Everything now
//! funnels into a [`MetricsRegistry`] carried by each run report, with
//! one JSON + Prometheus export path (`repro … --obs <path>`) and one
//! cross-machine aggregation rule ([`MetricsRegistry::merge`] — used by
//! the in-process cluster at join and by `ProcCluster` over the stdio
//! `metrics` line).
//!
//! # Hard contracts
//!
//! - **Bit-transparent**: instrumentation never touches protocol state,
//!   RNG draws, or float arithmetic; an instrumented run is bitwise
//!   identical to an uninstrumented one (asserted in `cluster::tests`).
//! - **Zero-alloc steady state**: registries, histograms, and the
//!   flight recorder preallocate at registration/run setup; the per-
//!   iteration hot path performs only array indexing (asserted with the
//!   counting allocator in `bench_coordinator`, obs *on*).
//! - **Cheap when off**: `obs: false` still counts deterministic events
//!   (counters/gauges) but never reads the wall clock —
//!   [`MetricsRegistry::span`] returns a no-op [`Span`].
//!
//! # Instrumentation points
//!
//! | runtime | phase | metric |
//! |---|---|---|
//! | `consensus::Engine` | A: local solve | `fadmm_phase_solve_ns` |
//! | `consensus::Engine` | B: exchange + reduce | `fadmm_phase_reduce_ns` |
//! | `consensus::Engine` | C: duals + observe/stop | `fadmm_phase_observe_ns` |
//! | `coordinator::ShardedRunner` | barrier-phase dispatch (scoped or pool) | `fadmm_pool_dispatch_ns`, `fadmm_threads_spawned_total` |
//! | `net::AsyncRunner` | per-node Solve / Reduce / Observe steps | `fadmm_phase_{solve,reduce,observe}_ns` |
//! | `net::AsyncRunner` | oracle global fold | `fadmm_collective_fold_ns`, `fadmm_rounds_total` |
//! | `cluster::ClusterRunner` | machine phase A (+ overlap) / B / C | `fadmm_phase_{solve,reduce,observe}_ns` |
//! | `cluster::ClusterRunner` | boundary θ/η batches | `fadmm_boundary_io_ns` |
//! | `cluster::ClusterRunner` | tree root fold / gossip commit | `fadmm_collective_fold_ns`, `fadmm_rounds_total` |
//! | `cluster::NodeRuntime` | same as `ClusterRunner`, per machine | same names (merged at join / over stdio) |
//! | all transports | counters at finish | `fadmm_net_*_total` (from [`NetCounters`]) |
//! | all transports | flight recorder at finish | `fadmm_trace_events_total`, `fadmm_trace_dropped_total` |
//! | all runtimes | outcome gauges | `fadmm_iterations`, `fadmm_converged` |
//!
//! Timing in protocol layers goes through [`MetricsRegistry::span`]
//! exclusively — ci.sh greps those layers for stray `Instant::now`.
//!
//! # Observability guide: the three artifacts
//!
//! Every `repro` subcommand can emit three run artifacts; all are
//! written by the launcher from the process-global sink after the run:
//!
//! 1. **Metrics** (`--obs FILE`) — the merged [`MetricsRegistry`] as
//!    JSON (`FILE`) and Prometheus text exposition (`FILE.prom`).
//!    Counters are `_total`-suffixed monotone sums, gauges last-run
//!    outcomes, histograms log₂-bucketed with full cumulative
//!    `_bucket{le="…"}` series plus `_sum`/`_count` — scrapeable
//!    as-is or `curl`-diffable between runs.
//! 2. **Convergence series** (`--series FILE`) — one CSV row per
//!    committed round ([`RoundRow`]): the committed [`IterStats`]
//!    (residuals, objective, per-scheme ρ min/mean/max — bit-for-bit
//!    the recorder stream), live node/edge counts from the effective
//!    topology, and per-phase span nanoseconds. A sibling `FILE.json`
//!    carries the same rows plus decimation drop accounting. Plot
//!    `max_primal`/`max_dual`/`mean_eta` against `round` to see *when*
//!    an adaptive scheme moved ρ.
//! 3. **Causal trace** (`--trace FILE`) — Chrome trace-event JSON of
//!    the [`Timeline`]: one track per machine, phase slices,
//!    send→deliver flow arrows, commit instants. Open it in
//!    `chrome://tracing` or drag it into <https://ui.perfetto.dev>
//!    (both read the JSON directly; in Perfetto use "Open trace file").
//!    The launcher also writes `FILE.critical_path.json` — the top-k
//!    slowest rounds with wall time attributed to
//!    solve/reduce/observe/boundary-io/collective-fold/network/
//!    straggler-wait (see [`critical_path`]) — and prints the summary
//!    table to stderr. Read it as: `wall_ticks` is the commit-to-commit
//!    gap, `dominant` names the bucket that consumed it; a large
//!    `straggler_wait` means the round waited on something outside the
//!    instrumented phases (a slow peer, collective retries).
//!
//! A crashing run (panic in the launcher or a `fadmm-node` process)
//! leaves `<obs-file>.crash.json` behind with the partial metrics and
//! timeline via the panic hook installed by the launchers
//! ([`install_crash_hook`]).
//!
//! [`IterStats`]: crate::metrics::IterStats

pub mod chrome;
pub mod critical_path;
mod export;
mod registry;
mod ring;
mod sink;
mod timeline;

pub use registry::{CounterId, GaugeId, Hist, HistId, MetricsRegistry, Span, HIST_BUCKETS};
pub use ring::FlightRecorder;
pub use sink::{
    enable_global, enable_global_series, enable_global_timeline, global_merge,
    global_series_enabled, global_series_merge, global_spans_enabled,
    global_timeline_enabled, global_timeline_merge, install_crash_hook, take_global,
    take_global_series, take_global_timeline,
};
pub use timeline::{
    series_csv_row, series_to_json, write_series_csv, write_series_json, Phase,
    RoundRow, RoundSeries, Timeline, TlEvent, TlKind, TraceCtx,
    DEFAULT_SERIES_CAPACITY, DEFAULT_TIMELINE_CAPACITY, NPHASES, SERIES_CSV_HEADER,
};

use crate::metrics::NetCounters;

/// Default flight-recorder capacity when tracing is enabled (events, not
/// bytes). Large enough that every existing test scenario stays under it
/// (bit-identical traces); bounded so ROADMAP-scale runs cannot OOM.
pub const DEFAULT_TRACE_CAPACITY: usize = 1 << 16;

/// The standard per-runtime probe set (see the module table). Each
/// runtime registers this once at run setup and records through the
/// `Copy` ids on the hot path.
#[derive(Debug, Clone, Copy)]
pub struct RuntimeProbes {
    pub solve: HistId,
    pub reduce: HistId,
    pub observe: HistId,
    pub boundary_io: HistId,
    pub collective_fold: HistId,
    pub pool_dispatch: HistId,
    pub rounds: CounterId,
    pub iterations: GaugeId,
    pub converged: GaugeId,
}

impl RuntimeProbes {
    pub fn register(reg: &mut MetricsRegistry) -> RuntimeProbes {
        RuntimeProbes {
            solve: reg.hist("fadmm_phase_solve_ns"),
            reduce: reg.hist("fadmm_phase_reduce_ns"),
            observe: reg.hist("fadmm_phase_observe_ns"),
            boundary_io: reg.hist("fadmm_boundary_io_ns"),
            collective_fold: reg.hist("fadmm_collective_fold_ns"),
            pool_dispatch: reg.hist("fadmm_pool_dispatch_ns"),
            rounds: reg.counter("fadmm_rounds_total"),
            iterations: reg.gauge("fadmm_iterations"),
            converged: reg.gauge("fadmm_converged"),
        }
    }
}

impl MetricsRegistry {
    /// Absorb a transport's [`NetCounters`] snapshot as
    /// `fadmm_net_<field>_total` counters (additive, so repeated calls
    /// from multiple machines aggregate).
    pub fn absorb_net(&mut self, c: &NetCounters) {
        for (name, v) in [
            ("fadmm_net_sent_total", c.sent),
            ("fadmm_net_delivered_total", c.delivered),
            ("fadmm_net_dropped_loss_total", c.dropped_loss),
            ("fadmm_net_dropped_partition_total", c.dropped_partition),
            ("fadmm_net_dropped_dead_total", c.dropped_dead),
            ("fadmm_net_duplicated_total", c.duplicated),
            ("fadmm_net_stale_reads_total", c.stale_reads),
            ("fadmm_net_fallback_reads_total", c.fallback_reads),
            ("fadmm_net_timeouts_total", c.timeouts),
            ("fadmm_net_joins_total", c.joins),
            ("fadmm_net_leaves_total", c.leaves),
            ("fadmm_net_edges_deactivated_total", c.edges_deactivated),
            ("fadmm_net_edges_reactivated_total", c.edges_reactivated),
            ("fadmm_net_collective_timeouts_total", c.collective_timeouts),
            ("fadmm_net_collective_fallbacks_total", c.collective_fallbacks),
            ("fadmm_net_collective_retries_total", c.collective_retries),
            ("fadmm_net_gossip_ticks_total", c.gossip_ticks),
            ("fadmm_net_overlap_dispatches_total", c.overlap_dispatches),
        ] {
            let id = self.counter(name);
            self.inc(id, v);
        }
    }

    /// Absorb a flight recorder's retention stats (retained event count
    /// and drops) as counters.
    pub fn absorb_trace(&mut self, retained: usize, dropped: u64) {
        let ev = self.counter("fadmm_trace_events_total");
        self.inc(ev, retained as u64);
        let dr = self.counter("fadmm_trace_dropped_total");
        self.inc(dr, dropped);
    }

    /// Absorb a [`Timeline`] + [`RoundSeries`] retention snapshot as
    /// counters (retained totals plus ring-overwrite / decimation drops).
    pub fn absorb_timeline(&mut self, events: usize, ev_dropped: u64, rows: usize, row_dropped: u64) {
        for (name, v) in [
            ("fadmm_timeline_events_total", events as u64),
            ("fadmm_timeline_dropped_total", ev_dropped),
            ("fadmm_series_rows_total", rows as u64),
            ("fadmm_series_dropped_total", row_dropped),
        ] {
            let id = self.counter(name);
            self.inc(id, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probes_register_the_documented_names() {
        let mut reg = MetricsRegistry::new(true);
        let p = RuntimeProbes::register(&mut reg);
        reg.inc(p.rounds, 3);
        reg.set_gauge(p.iterations, 12.0);
        let sp = reg.span();
        reg.end(p.solve, sp);
        assert_eq!(reg.counter_by_name("fadmm_rounds_total"), Some(3));
        assert_eq!(reg.gauge_by_name("fadmm_iterations"), Some(12.0));
        assert_eq!(reg.hist_by_name("fadmm_phase_solve_ns").unwrap().count, 1);
        // re-registering is a lookup, not a duplicate
        let p2 = RuntimeProbes::register(&mut reg);
        assert_eq!(p.rounds, p2.rounds);
    }

    #[test]
    fn absorb_net_is_additive_across_machines() {
        let mut reg = MetricsRegistry::new(false);
        let a = NetCounters { sent: 10, delivered: 8, ..Default::default() };
        let b = NetCounters { sent: 5, delivered: 5, ..Default::default() };
        reg.absorb_net(&a);
        reg.absorb_net(&b);
        assert_eq!(reg.counter_by_name("fadmm_net_sent_total"), Some(15));
        assert_eq!(reg.counter_by_name("fadmm_net_delivered_total"), Some(13));
        assert_eq!(reg.counter_by_name("fadmm_net_gossip_ticks_total"), Some(0));
    }

    #[test]
    fn absorb_trace_counts_retained_and_dropped() {
        let mut reg = MetricsRegistry::new(false);
        let mut ring = FlightRecorder::new(2);
        for k in 0..5 {
            ring.push(k);
        }
        reg.absorb_trace(ring.len(), ring.dropped());
        assert_eq!(reg.counter_by_name("fadmm_trace_events_total"), Some(2));
        assert_eq!(reg.counter_by_name("fadmm_trace_dropped_total"), Some(3));
    }
}
