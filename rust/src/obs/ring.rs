//! Bounded flight recorder: a preallocated ring buffer with drop
//! accounting.
//!
//! Replaces the unbounded `Vec<TraceEvent>` trace logs: the buffer is
//! allocated once at construction (capacity is a config knob), pushes
//! past capacity overwrite the *oldest* entry and count a drop, and
//! [`FlightRecorder::drain`] returns the retained events in
//! chronological order. Runs that stay under the capacity keep the exact
//! same-seed ⇒ bit-identical-trace guarantee as the unbounded log;
//! runs that overflow keep a bit-identical *suffix* plus an exact
//! dropped count (asserted by the determinism tests in `net::tests`).
//!
//! Generic over the event type so the `net`/`cluster` trace machinery
//! and any future event stream share one eviction policy.

/// A bounded ring of `T` with oldest-first eviction (see module docs).
/// A capacity of 0 records nothing and counts every push as dropped.
#[derive(Debug, Clone, Default)]
pub struct FlightRecorder<T> {
    buf: Vec<T>,
    /// index of the oldest retained entry once the buffer is full
    head: usize,
    dropped: u64,
    cap: usize,
}

impl<T> FlightRecorder<T> {
    /// A recorder holding at most `cap` events. The buffer is allocated
    /// here, in full, so steady-state pushes never allocate.
    pub fn new(cap: usize) -> FlightRecorder<T> {
        FlightRecorder { buf: Vec::with_capacity(cap), head: 0, dropped: 0, cap }
    }

    /// Append an event; evicts the oldest entry (and counts a drop) once
    /// the buffer is full.
    pub fn push(&mut self, ev: T) {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else if self.cap > 0 {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        } else {
            self.dropped += 1;
        }
    }

    /// Retained event count (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events evicted (or refused, at capacity 0) so far.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Take the retained events in chronological (oldest → newest)
    /// order, leaving the recorder empty but keeping its drop count.
    pub fn drain(&mut self) -> Vec<T> {
        let head = self.head;
        self.head = 0;
        let mut v = std::mem::take(&mut self.buf);
        v.rotate_left(head);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn under_capacity_keeps_everything_in_order() {
        let mut r = FlightRecorder::new(8);
        for k in 0..5 {
            r.push(k);
        }
        assert_eq!(r.len(), 5);
        assert_eq!(r.dropped(), 0);
        assert_eq!(r.drain(), vec![0, 1, 2, 3, 4]);
        assert_eq!(r.len(), 0, "drain empties the ring");
        assert_eq!(r.dropped(), 0, "drain keeps the drop count");
    }

    #[test]
    fn overflow_evicts_oldest_and_counts_drops() {
        let mut r = FlightRecorder::new(4);
        for k in 0..10 {
            r.push(k);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.dropped(), 6, "10 pushes into 4 slots drop 6");
        assert_eq!(r.drain(), vec![6, 7, 8, 9], "newest suffix, in order");
    }

    #[test]
    fn exact_capacity_boundary_drops_nothing() {
        let mut r = FlightRecorder::new(3);
        for k in 0..3 {
            r.push(k);
        }
        assert_eq!((r.len(), r.dropped()), (3, 0));
        r.push(3); // first eviction
        assert_eq!((r.len(), r.dropped()), (3, 1));
        assert_eq!(r.drain(), vec![1, 2, 3]);
    }

    #[test]
    fn zero_capacity_refuses_and_counts() {
        let mut r = FlightRecorder::new(0);
        for k in 0..7 {
            r.push(k);
        }
        assert_eq!(r.len(), 0);
        assert_eq!(r.dropped(), 7);
        assert!(r.drain().is_empty());
    }

    #[test]
    fn drain_after_multiple_wraps_is_chronological() {
        let mut r = FlightRecorder::new(3);
        for k in 0..11 {
            r.push(k);
        }
        // 11 pushes, 3 slots: head has wrapped 2.67 times
        assert_eq!(r.drain(), vec![8, 9, 10]);
        // reusable after drain
        r.push(99);
        assert_eq!(r.drain(), vec![99]);
    }
}
