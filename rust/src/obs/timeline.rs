//! Per-round structured event layer: causal trace context, the bounded
//! machine-timeline recorder, and the convergence time-series.
//!
//! Three pieces, all following the PR 8 instrumentation contract
//! (bit-transparent, allocation-free in steady state, no clock reads of
//! their own):
//!
//! * [`TraceCtx`] — a compact causal context minted by every
//!   [`crate::net::Transport::send`] and carried on the frame through
//!   delivery (and, for the proc transport, on the wire — absent fields
//!   keep old driver/node interop). `(machine, seq)` uniquely names a
//!   frame; `round` is the payload's stamp. Minting is unconditional and
//!   costs one counter increment, so the wire bytes and event schedule
//!   are identical whether recording is on or off.
//! * [`Timeline`] — a bounded [`FlightRecorder`] of [`TlEvent`]s (sends,
//!   deliveries, phase durations, commits) plus a fixed-size per-round
//!   phase-duration window. Timestamps come from the transport clock
//!   (`Transport::now()` ticks); durations come from the value
//!   [`crate::obs::MetricsRegistry::end`] already measured — the
//!   timeline itself never touches a clock, which is what keeps the
//!   ci.sh `Instant::now` containment gate honest.
//! * [`RoundSeries`] — the per-committed-round convergence time-series:
//!   one [`RoundRow`] per commit carrying the [`IterStats`] verbatim
//!   (so CSV columns match the recorder stream bit-for-bit), liveness
//!   counts, and the round's accumulated phase durations. Bounded like
//!   the flight recorder, but with *stride-doubling decimation* instead
//!   of oldest-first eviction: past capacity the series keeps every
//!   2nd, then 4th, … row, preserving whole-run coverage with exact
//!   drop accounting.
//!
//! Export paths: [`write_series_csv`] / [`series_to_json`] here,
//! Chrome trace-event JSON in [`crate::obs::chrome`], and the per-round
//! wall-time attribution in [`crate::obs::critical_path`].

use std::path::Path;

use crate::error::{Error, Result};
use crate::metrics::IterStats;
use crate::util::csv::{fnum, CsvWriter};
use crate::util::json::{arr, num, obj, Json};

use super::ring::FlightRecorder;

/// Default timeline event capacity (matches the net trace recorder).
pub const DEFAULT_TIMELINE_CAPACITY: usize = 1 << 16;

/// Default bound on retained series rows before decimation begins.
pub const DEFAULT_SERIES_CAPACITY: usize = 1 << 14;

/// Causal context stamped on every transport frame (see module docs).
/// `Default` is the "absent on the wire" value for old-peer interop.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TraceCtx {
    /// The frame's protocol round (its payload stamp; 0 when stampless).
    pub round: u64,
    /// Sending endpoint (machine id on the cluster transports).
    pub machine: usize,
    /// Per-transport monotone frame counter. `(machine, seq)` names the
    /// frame uniquely within a run, keying send→deliver flow edges.
    pub seq: u64,
}

/// Protocol phases attributed per round. Indices are stable (they order
/// [`RoundRow::phase_ns`] and the series CSV columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Solve,
    Reduce,
    Observe,
    BoundaryIo,
    CollectiveFold,
}

/// Number of attributed phases (the length of [`RoundRow::phase_ns`]).
pub const NPHASES: usize = 5;

impl Phase {
    pub const ALL: [Phase; NPHASES] = [
        Phase::Solve,
        Phase::Reduce,
        Phase::Observe,
        Phase::BoundaryIo,
        Phase::CollectiveFold,
    ];

    pub fn index(self) -> usize {
        match self {
            Phase::Solve => 0,
            Phase::Reduce => 1,
            Phase::Observe => 2,
            Phase::BoundaryIo => 3,
            Phase::CollectiveFold => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Phase::Solve => "solve",
            Phase::Reduce => "reduce",
            Phase::Observe => "observe",
            Phase::BoundaryIo => "boundary_io",
            Phase::CollectiveFold => "collective_fold",
        }
    }
}

/// One timeline event. `at` is transport ticks (virtual ms on the
/// simulator, wall ms on the real transports); `machine` is the track
/// the event belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TlEvent {
    pub at: u64,
    pub machine: usize,
    pub round: u64,
    pub kind: TlKind,
}

/// Event payloads. Flow edges pair a `Send` with the `Recv` carrying
/// the same `(machine→src, seq)` context.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TlKind {
    /// A phase finished on this machine; `dur_ns` is the span
    /// measurement (0 when obs spans are disabled — the event sequence
    /// stays deterministic, only the duration field is wall-clock).
    Phase { phase: Phase, dur_ns: u64 },
    /// Frame handed to the transport (`machine` = sender).
    Send { seq: u64, dst: usize, what: &'static str },
    /// Frame delivered (`machine` = receiver, `src` from the ctx).
    Recv { seq: u64, src: usize, what: &'static str },
    /// A round committed on this machine (the fold holder).
    Commit,
}

/// Per-round phase-duration accumulation window. Fixed size: rounds in
/// flight never span more than a few commits, so a 64-slot ring indexed
/// by `round % 64` is exact for every live round and self-cleaning.
const PHASE_WINDOW: usize = 64;

#[derive(Debug, Clone, Copy, Default)]
struct PhaseSlot {
    round: u64,
    ns: [u64; NPHASES],
    used: bool,
}

/// Bounded per-run timeline recorder (see module docs). Capacity 0 (the
/// disabled state) makes every recording method a cheap no-op.
#[derive(Debug)]
pub struct Timeline {
    events: FlightRecorder<TlEvent>,
    window: [PhaseSlot; PHASE_WINDOW],
}

impl Timeline {
    /// Enabled timelines get [`DEFAULT_TIMELINE_CAPACITY`]; disabled
    /// ones record nothing (capacity 0).
    pub fn new(enabled: bool) -> Timeline {
        Timeline::with_capacity(if enabled { DEFAULT_TIMELINE_CAPACITY } else { 0 })
    }

    /// The buffer is allocated here, in full — steady-state recording
    /// never allocates.
    pub fn with_capacity(cap: usize) -> Timeline {
        Timeline {
            events: FlightRecorder::new(cap),
            window: [PhaseSlot::default(); PHASE_WINDOW],
        }
    }

    pub fn enabled(&self) -> bool {
        self.events.capacity() > 0
    }

    /// Record a frame handed to the transport. `ctx` is the context the
    /// send minted; `what` is the payload kind name.
    pub fn send(&mut self, at: u64, ctx: TraceCtx, dst: usize, what: &'static str) {
        if !self.enabled() {
            return;
        }
        self.events.push(TlEvent {
            at,
            machine: ctx.machine,
            round: ctx.round,
            kind: TlKind::Send { seq: ctx.seq, dst, what },
        });
    }

    /// Record a frame delivery on `machine` (the receiver).
    pub fn recv(&mut self, at: u64, machine: usize, ctx: TraceCtx, what: &'static str) {
        if !self.enabled() {
            return;
        }
        self.events.push(TlEvent {
            at,
            machine,
            round: ctx.round,
            kind: TlKind::Recv { seq: ctx.seq, src: ctx.machine, what },
        });
    }

    /// Record a finished phase and accumulate its duration into the
    /// round's window slot (read back by [`Timeline::phase_ns`] at
    /// commit time).
    pub fn phase(&mut self, at: u64, machine: usize, round: u64, phase: Phase,
                 dur_ns: u64) {
        if !self.enabled() {
            return;
        }
        self.events.push(TlEvent {
            at,
            machine,
            round,
            kind: TlKind::Phase { phase, dur_ns },
        });
        let slot = &mut self.window[(round as usize) % PHASE_WINDOW];
        if !slot.used || slot.round != round {
            *slot = PhaseSlot { round, ns: [0; NPHASES], used: true };
        }
        slot.ns[phase.index()] += dur_ns;
    }

    /// Record a round commit on `machine`.
    pub fn commit(&mut self, at: u64, machine: usize, round: u64) {
        if !self.enabled() {
            return;
        }
        self.events.push(TlEvent { at, machine, round, kind: TlKind::Commit });
    }

    /// The phase durations accumulated for `round` so far (zeros when
    /// the slot was recycled or the timeline is disabled).
    pub fn phase_ns(&self, round: u64) -> [u64; NPHASES] {
        let slot = &self.window[(round as usize) % PHASE_WINDOW];
        if slot.used && slot.round == round { slot.ns } else { [0; NPHASES] }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Events evicted past capacity.
    pub fn dropped(&self) -> u64 {
        self.events.dropped()
    }

    /// Take the retained events (oldest → newest), leaving the recorder
    /// empty but keeping its drop count.
    pub fn drain(&mut self) -> Vec<TlEvent> {
        self.events.drain()
    }
}

/// One committed round of the convergence time-series. `stats` is the
/// [`IterStats`] the runtime committed, copied verbatim — the CSV
/// residual/ρ columns are bit-for-bit the recorder stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundRow {
    pub round: u64,
    /// Transport ticks at commit (round index on the clockless
    /// sequential/sharded runtimes).
    pub at: u64,
    pub stats: IterStats,
    pub live_nodes: u64,
    /// Live edges in the effective (NAP-masked, churned) topology.
    pub live_edges: u64,
    /// Accumulated per-phase durations for the round, ordered by
    /// [`Phase::index`]; zeros where spans were off or not attributed.
    pub phase_ns: [u64; NPHASES],
}

/// Bounded convergence time-series with stride-doubling decimation (see
/// module docs). Capacity 0 = disabled (pushes are no-ops).
#[derive(Debug)]
pub struct RoundSeries {
    rows: Vec<RoundRow>,
    cap: usize,
    stride: u64,
    seen: u64,
    dropped: u64,
}

impl RoundSeries {
    pub fn new(enabled: bool) -> RoundSeries {
        RoundSeries::with_capacity(if enabled { DEFAULT_SERIES_CAPACITY } else { 0 })
    }

    /// Rows are preallocated here (capacity is clamped to ≥ 2 when
    /// enabled so decimation can always halve), and the buffer never
    /// grows — steady-state pushes are allocation-free.
    pub fn with_capacity(cap: usize) -> RoundSeries {
        let cap = if cap == 0 { 0 } else { cap.max(2) };
        RoundSeries { rows: Vec::with_capacity(cap), cap, stride: 1, seen: 0, dropped: 0 }
    }

    pub fn enabled(&self) -> bool {
        self.cap > 0
    }

    /// Record a committed round. Under capacity this keeps every row;
    /// past it, retained rows are exactly those whose 0-based commit
    /// index is a multiple of the current stride (which doubles on each
    /// compaction), so coverage always spans the whole run.
    pub fn push(&mut self, row: RoundRow) {
        if self.cap == 0 {
            return;
        }
        self.seen += 1;
        if (self.seen - 1) % self.stride != 0 {
            self.dropped += 1;
            return;
        }
        if self.rows.len() == self.cap {
            // compact in place: keep even positions (index multiples of
            // the doubled stride), count the rest as dropped
            let mut w = 0;
            for r in (0..self.rows.len()).step_by(2) {
                self.rows[w] = self.rows[r];
                w += 1;
            }
            self.dropped += (self.rows.len() - w) as u64;
            self.rows.truncate(w);
            self.stride *= 2;
            if (self.seen - 1) % self.stride != 0 {
                self.dropped += 1;
                return;
            }
        }
        self.rows.push(row);
    }

    pub fn rows(&self) -> &[RoundRow] {
        &self.rows
    }

    /// Rows ever pushed (retained + dropped).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Rows decimated away (exact accounting).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Current decimation stride (1 until the first compaction).
    pub fn stride(&self) -> u64 {
        self.stride
    }

    /// Take the retained rows, keeping the drop accounting.
    pub fn drain(&mut self) -> Vec<RoundRow> {
        std::mem::take(&mut self.rows)
    }
}

/// Column order of the series CSV. The `iter..app_error` block is the
/// [`IterStats`] layout, formatted through the same [`fnum`] path as
/// [`crate::metrics::Recorder::write_csv`] so the two files agree
/// bit-for-bit on shared columns.
pub const SERIES_CSV_HEADER: [&str; 17] = [
    "round", "at", "iter", "objective", "max_primal", "max_dual",
    "mean_eta", "min_eta", "max_eta", "app_error", "live_nodes",
    "live_edges", "solve_ns", "reduce_ns", "observe_ns", "boundary_io_ns",
    "collective_fold_ns",
];

/// Write series rows as CSV (see [`SERIES_CSV_HEADER`]).
pub fn write_series_csv(path: &Path, rows: &[RoundRow]) -> Result<()> {
    let mut w = CsvWriter::create(path, &SERIES_CSV_HEADER)?;
    for r in rows {
        w.row(&series_csv_row(r))?;
    }
    w.finish()
}

/// One CSV row for a series entry (shared with the fault-sweep writers,
/// which prepend their own scenario-cell columns).
pub fn series_csv_row(r: &RoundRow) -> Vec<String> {
    vec![
        r.round.to_string(),
        r.at.to_string(),
        r.stats.iter.to_string(),
        fnum(r.stats.objective),
        fnum(r.stats.max_primal),
        fnum(r.stats.max_dual),
        fnum(r.stats.mean_eta),
        fnum(r.stats.min_eta),
        fnum(r.stats.max_eta),
        fnum(r.stats.app_error),
        r.live_nodes.to_string(),
        r.live_edges.to_string(),
        r.phase_ns[0].to_string(),
        r.phase_ns[1].to_string(),
        r.phase_ns[2].to_string(),
        r.phase_ns[3].to_string(),
        r.phase_ns[4].to_string(),
    ]
}

/// Series rows + drop accounting as JSON (the `--series FILE` sibling
/// artifact, `FILE.json`). Non-finite residuals use the codec sentinels
/// so the document stays parseable.
pub fn series_to_json(rows: &[RoundRow], dropped: u64) -> Json {
    let jnum = crate::net::codec::fnum;
    let items = rows
        .iter()
        .map(|r| {
            obj(vec![
                ("round", num(r.round as f64)),
                ("at", num(r.at as f64)),
                ("iter", num(r.stats.iter as f64)),
                ("objective", jnum(r.stats.objective)),
                ("max_primal", jnum(r.stats.max_primal)),
                ("max_dual", jnum(r.stats.max_dual)),
                ("mean_eta", jnum(r.stats.mean_eta)),
                ("min_eta", jnum(r.stats.min_eta)),
                ("max_eta", jnum(r.stats.max_eta)),
                ("app_error", jnum(r.stats.app_error)),
                ("live_nodes", num(r.live_nodes as f64)),
                ("live_edges", num(r.live_edges as f64)),
                ("phase_ns",
                 arr(r.phase_ns.iter().map(|&n| num(n as f64)).collect())),
            ])
        })
        .collect();
    obj(vec![
        ("rows", arr(items)),
        ("retained", num(rows.len() as f64)),
        ("dropped", num(dropped as f64)),
    ])
}

/// Write the series JSON artifact.
pub fn write_series_json(path: &Path, rows: &[RoundRow], dropped: u64) -> Result<()> {
    std::fs::write(path, series_to_json(rows, dropped).to_string())
        .map_err(|e| Error::io(format!("write {}", path.display()), e))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(iter: usize) -> IterStats {
        IterStats {
            iter,
            objective: 1.5 * iter as f64,
            max_primal: 0.25,
            max_dual: 0.125,
            mean_eta: 10.0,
            min_eta: 5.0,
            max_eta: 20.0,
            app_error: 0.0,
        }
    }

    fn row(round: u64) -> RoundRow {
        RoundRow {
            round,
            at: round * 3,
            stats: stats(round as usize),
            live_nodes: 12,
            live_edges: 12,
            phase_ns: [0; NPHASES],
        }
    }

    #[test]
    fn disabled_timeline_records_nothing() {
        let mut tl = Timeline::new(false);
        tl.send(1, TraceCtx { round: 0, machine: 0, seq: 1 }, 1, "theta");
        tl.phase(2, 0, 0, Phase::Solve, 100);
        tl.commit(3, 0, 0);
        assert!(!tl.enabled());
        assert!(tl.is_empty());
        assert_eq!(tl.dropped(), 0, "disabled timelines do not count drops");
        assert_eq!(tl.phase_ns(0), [0; NPHASES]);
    }

    #[test]
    fn phase_window_accumulates_and_recycles() {
        let mut tl = Timeline::new(true);
        tl.phase(1, 0, 7, Phase::Solve, 100);
        tl.phase(2, 1, 7, Phase::Solve, 50);
        tl.phase(3, 0, 7, Phase::CollectiveFold, 9);
        let ns = tl.phase_ns(7);
        assert_eq!(ns[Phase::Solve.index()], 150);
        assert_eq!(ns[Phase::CollectiveFold.index()], 9);
        assert_eq!(ns[Phase::Reduce.index()], 0);
        // the slot 64 rounds later reuses the same window index
        tl.phase(4, 0, 7 + PHASE_WINDOW as u64, Phase::Solve, 1);
        assert_eq!(tl.phase_ns(7), [0; NPHASES], "recycled slot reads zero");
        assert_eq!(tl.phase_ns(7 + PHASE_WINDOW as u64)[0], 1);
    }

    #[test]
    fn timeline_events_drain_in_order() {
        let mut tl = Timeline::new(true);
        let ctx = TraceCtx { round: 2, machine: 1, seq: 9 };
        tl.send(5, ctx, 0, "theta");
        tl.recv(7, 0, ctx, "theta");
        tl.commit(8, 0, 2);
        let evs = tl.drain();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0], TlEvent {
            at: 5,
            machine: 1,
            round: 2,
            kind: TlKind::Send { seq: 9, dst: 0, what: "theta" },
        });
        assert_eq!(evs[1].kind, TlKind::Recv { seq: 9, src: 1, what: "theta" });
        assert_eq!(evs[2].kind, TlKind::Commit);
        assert!(tl.is_empty(), "drain empties the recorder");
    }

    #[test]
    fn series_under_capacity_keeps_every_row_verbatim() {
        let mut s = RoundSeries::with_capacity(16);
        for r in 0..10 {
            s.push(row(r));
        }
        assert_eq!(s.rows().len(), 10);
        assert_eq!(s.dropped(), 0);
        assert_eq!(s.stride(), 1);
        assert_eq!(s.rows()[3].stats, stats(3), "stats are copied verbatim");
    }

    #[test]
    fn series_decimation_doubles_stride_and_accounts_drops() {
        let mut s = RoundSeries::with_capacity(4);
        for r in 0..32 {
            s.push(row(r));
        }
        assert_eq!(s.seen(), 32);
        assert_eq!(s.dropped() + s.rows().len() as u64, 32,
                   "every pushed row is retained or counted dropped");
        assert!(s.rows().len() <= 4);
        // retained rounds are multiples of the final stride, covering
        // the whole run rather than just a suffix
        let stride = s.stride();
        assert!(stride >= 4, "32 rows through 4 slots forces stride ≥ 4");
        for w in s.rows() {
            assert_eq!(w.round % stride, 0, "round {} vs stride {stride}", w.round);
        }
        assert_eq!(s.rows()[0].round, 0, "first row always survives");
    }

    #[test]
    fn series_csv_matches_recorder_formatting() {
        let dir = std::env::temp_dir().join("fadmm_series_csv_test");
        let path = dir.join("s.csv");
        let mut s = RoundSeries::with_capacity(4);
        s.push(row(0));
        s.push(row(1));
        write_series_csv(&path, s.rows()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let mut lines = text.lines();
        assert_eq!(lines.next().unwrap().split(',').count(),
                   SERIES_CSV_HEADER.len());
        let first: Vec<&str> = lines.next().unwrap().split(',').collect();
        // the IterStats block is formatted through the same fnum path as
        // Recorder::write_csv: integral floats compact, others %.6e
        assert_eq!(first[3], fnum(0.0), "objective");
        assert_eq!(first[4], fnum(0.25), "max_primal");
        assert_eq!(first[6], fnum(10.0), "mean_eta");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn series_json_round_trips_counts() {
        let mut s = RoundSeries::with_capacity(2);
        for r in 0..5 {
            s.push(row(r));
        }
        let j = series_to_json(s.rows(), s.dropped());
        let parsed = Json::parse(&j.to_string()).unwrap();
        let rows = parsed.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), s.rows().len());
        assert_eq!(parsed.get("dropped").unwrap().as_f64().unwrap(),
                   s.dropped() as f64);
    }
}
