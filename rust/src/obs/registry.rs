//! The metrics registry: named counters, gauges and log-bucketed
//! histograms behind index-typed handles.
//!
//! Registration (run setup) returns a small `Copy` id; recording (the
//! hot path) is a plain array index — no hashing, no string compare, no
//! allocation. All storage is preallocated at registration time, so the
//! steady-state zero-allocation contract of the runtimes holds with
//! observability enabled (asserted by `bench_coordinator`).
//!
//! Instrumentation is **bit-transparent** by construction: nothing in
//! this module feeds back into the optimization (no RNG draws, no
//! float arithmetic on protocol state), and the only wall-clock reads
//! ([`MetricsRegistry::span`]) are gated on the `enabled` flag — a
//! disabled registry never touches the clock.

use std::time::Instant;

/// Handle to a registered counter (monotone `u64`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CounterId(pub(crate) usize);

/// Handle to a registered gauge (last-written `f64`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GaugeId(pub(crate) usize);

/// Handle to a registered histogram.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HistId(pub(crate) usize);

/// Number of log₂ buckets per histogram. Bucket 0 holds exact zeros;
/// bucket `i ≥ 1` holds values in `[2^(i-1), 2^i)`; the last bucket
/// absorbs everything from `2^62` up.
pub const HIST_BUCKETS: usize = 64;

/// HDR-style log₂-bucketed histogram of `u64` observations
/// (nanoseconds, byte counts, …). Fixed-size inline storage: recording
/// is one shift, one index, five adds.
#[derive(Debug, Clone, PartialEq)]
pub struct Hist {
    pub buckets: [u64; HIST_BUCKETS],
    pub count: u64,
    /// saturating Σ of observed values
    pub sum: u64,
    /// `u64::MAX` while empty
    pub min: u64,
    pub max: u64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist {
            buckets: [0; HIST_BUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }
}

impl Hist {
    /// Bucket index for a value (see [`HIST_BUCKETS`]).
    pub fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1)
        }
    }

    /// Smallest value landing in bucket `i`.
    pub fn bucket_lower(i: usize) -> u64 {
        if i == 0 { 0 } else { 1u64 << (i - 1) }
    }

    /// Largest value landing in bucket `i`, or `None` for the open-ended
    /// last bucket (Prometheus `le="+Inf"`).
    pub fn bucket_upper(i: usize) -> Option<u64> {
        if i + 1 >= HIST_BUCKETS {
            None
        } else {
            Some((1u64 << i) - 1)
        }
    }

    pub fn record(&mut self, v: u64) {
        self.buckets[Self::bucket_index(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// `min` with the empty-histogram sentinel mapped to 0 (for export).
    pub fn min_or_zero(&self) -> u64 {
        if self.count == 0 { 0 } else { self.min }
    }

    /// Fold another histogram into this one (bucket-wise sum, min/max
    /// fold) — the cross-machine / cross-run aggregation primitive.
    pub fn merge(&mut self, other: &Hist) {
        for (b, o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// An in-flight phase span. `None` inside means observability was
/// disabled when the span started — ending it is free and touches no
/// clock. `Copy`, so spans move through the state machines without
/// borrow gymnastics.
#[derive(Debug, Clone, Copy)]
pub struct Span(pub(crate) Option<Instant>);

impl Span {
    /// A span that records nothing when ended.
    pub fn noop() -> Span {
        Span(None)
    }
}

/// The unified metrics registry (see module docs and [`crate::obs`]).
///
/// Also serves as the inert *data* form: reports carry a registry by
/// value, [`MetricsRegistry::merge`] folds per-machine/per-run
/// registries into an aggregate, and the export module round-trips it
/// through JSON for the proc transport's `metrics` wire line.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsRegistry {
    enabled: bool,
    counters: Vec<(String, u64)>,
    gauges: Vec<(String, f64)>,
    hists: Vec<(String, Hist)>,
}

impl MetricsRegistry {
    /// `enabled` gates only the wall-clock span reads; counters and
    /// gauges always record (they are deterministic and cheap).
    pub fn new(enabled: bool) -> MetricsRegistry {
        MetricsRegistry { enabled, ..Default::default() }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.hists.is_empty()
    }

    // -- registration (run setup; allocates) --------------------------------

    /// Register (or look up) a counter by name. Idempotent: the same
    /// name always yields the same id.
    pub fn counter(&mut self, name: &str) -> CounterId {
        if let Some(i) = self.counters.iter().position(|(n, _)| n == name) {
            return CounterId(i);
        }
        self.counters.push((name.to_string(), 0));
        CounterId(self.counters.len() - 1)
    }

    /// Register (or look up) a gauge by name.
    pub fn gauge(&mut self, name: &str) -> GaugeId {
        if let Some(i) = self.gauges.iter().position(|(n, _)| n == name) {
            return GaugeId(i);
        }
        self.gauges.push((name.to_string(), 0.0));
        GaugeId(self.gauges.len() - 1)
    }

    /// Register (or look up) a histogram by name.
    pub fn hist(&mut self, name: &str) -> HistId {
        if let Some(i) = self.hists.iter().position(|(n, _)| n == name) {
            return HistId(i);
        }
        self.hists.push((name.to_string(), Hist::default()));
        HistId(self.hists.len() - 1)
    }

    // -- recording (hot path; never allocates) ------------------------------

    pub fn inc(&mut self, id: CounterId, by: u64) {
        self.counters[id.0].1 += by;
    }

    pub fn set_gauge(&mut self, id: GaugeId, v: f64) {
        self.gauges[id.0].1 = v;
    }

    pub fn record(&mut self, id: HistId, v: u64) {
        self.hists[id.0].1.record(v);
    }

    /// Start a phase span. Disabled registries return a no-op span
    /// without reading the clock.
    pub fn span(&self) -> Span {
        Span(if self.enabled { Some(Instant::now()) } else { None })
    }

    /// End a span, recording its elapsed nanoseconds into `id`. Returns
    /// the recorded duration (0 for a no-op span) so callers can feed
    /// the same measurement into per-round attribution
    /// ([`crate::obs::Timeline`]) without a second clock read.
    pub fn end(&mut self, id: HistId, span: Span) -> u64 {
        match span.0 {
            Some(start) => {
                let ns = start.elapsed().as_nanos() as u64;
                self.hists[id.0].1.record(ns);
                ns
            }
            None => 0,
        }
    }

    // -- reads --------------------------------------------------------------

    pub fn counter_value(&self, id: CounterId) -> u64 {
        self.counters[id.0].1
    }

    pub fn gauge_value(&self, id: GaugeId) -> f64 {
        self.gauges[id.0].1
    }

    pub fn hist_value(&self, id: HistId) -> &Hist {
        &self.hists[id.0].1
    }

    pub fn counter_by_name(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn gauge_by_name(&self, name: &str) -> Option<f64> {
        self.gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    pub fn hist_by_name(&self, name: &str) -> Option<&Hist> {
        self.hists.iter().find(|(n, _)| n == name).map(|(_, v)| v)
    }

    pub(crate) fn counters_iter(&self) -> impl Iterator<Item = (&str, u64)> {
        self.counters.iter().map(|(n, v)| (n.as_str(), *v))
    }

    pub(crate) fn gauges_iter(&self) -> impl Iterator<Item = (&str, f64)> {
        self.gauges.iter().map(|(n, v)| (n.as_str(), *v))
    }

    pub(crate) fn hists_iter(&self) -> impl Iterator<Item = (&str, &Hist)> {
        self.hists.iter().map(|(n, v)| (n.as_str(), v))
    }

    // -- aggregation --------------------------------------------------------

    /// Fold a standalone [`Hist`] into the histogram behind `id` (used
    /// by the JSON parse and the transport absorbers).
    pub(crate) fn merge_hist(&mut self, id: HistId, h: &Hist) {
        self.hists[id.0].1.merge(h);
    }

    /// Fold another registry into this one: counters add, gauges take
    /// the other's value (last-wins; per-machine gauges that must not
    /// collide should aggregate as counters or histograms instead),
    /// histograms merge bucket-wise. Names absent here are registered.
    pub fn merge(&mut self, other: &MetricsRegistry) {
        for (name, v) in other.counters.clone() {
            let id = self.counter(&name);
            self.inc(id, v);
        }
        for (name, v) in other.gauges.clone() {
            let id = self.gauge(&name);
            self.set_gauge(id, v);
        }
        for (name, h) in &other.hists {
            let id = self.hist(name);
            self.hists[id.0].1.merge(h);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registration_is_idempotent_and_ids_are_stable() {
        let mut r = MetricsRegistry::new(true);
        let a = r.counter("a_total");
        let b = r.counter("b_total");
        assert_ne!(a, b);
        assert_eq!(r.counter("a_total"), a);
        r.inc(a, 3);
        r.inc(a, 2);
        assert_eq!(r.counter_value(a), 5);
        assert_eq!(r.counter_by_name("a_total"), Some(5));
        assert_eq!(r.counter_by_name("missing"), None);
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let mut r = MetricsRegistry::new(false);
        let h = r.hist("phase_ns");
        let sp = r.span();
        assert!(sp.0.is_none(), "disabled registry never reads the clock");
        r.end(h, sp);
        assert_eq!(r.hist_value(h).count, 0);
    }

    #[test]
    fn enabled_spans_record_elapsed_time() {
        let mut r = MetricsRegistry::new(true);
        let h = r.hist("phase_ns");
        let sp = r.span();
        r.end(h, sp);
        assert_eq!(r.hist_value(h).count, 1);
    }

    #[test]
    fn hist_bucket_boundaries_are_exact() {
        // property sweep: every power of two starts a new bucket; the
        // value one below it still lands in the previous one
        for i in 1..63usize {
            let lo = Hist::bucket_lower(i);
            assert_eq!(Hist::bucket_index(lo), i, "2^{} starts bucket {i}", i - 1);
            assert_eq!(Hist::bucket_index(lo - 1),
                       if i == 1 { 0 } else { i - 1 },
                       "value below 2^{} stays in bucket {}", i - 1, i - 1);
            if let Some(hi) = Hist::bucket_upper(i) {
                assert_eq!(Hist::bucket_index(hi), i);
                assert_eq!(hi + 1, Hist::bucket_lower(i + 1),
                           "buckets tile the axis with no gaps");
            }
        }
        assert_eq!(Hist::bucket_index(0), 0);
        assert_eq!(Hist::bucket_index(u64::MAX), HIST_BUCKETS - 1);
        assert_eq!(Hist::bucket_upper(HIST_BUCKETS - 1), None, "last bucket open");
    }

    #[test]
    fn hist_records_and_merges() {
        let mut a = Hist::default();
        for v in [0u64, 1, 7, 1024] {
            a.record(v);
        }
        assert_eq!(a.count, 4);
        assert_eq!(a.sum, 1032);
        assert_eq!((a.min, a.max), (0, 1024));
        assert_eq!(a.buckets[0], 1);
        assert_eq!(a.buckets[Hist::bucket_index(7)], 1);

        let mut b = Hist::default();
        b.record(5000);
        a.merge(&b);
        assert_eq!(a.count, 5);
        assert_eq!(a.max, 5000);
        assert_eq!(a.min, 0);
        let total: u64 = a.buckets.iter().sum();
        assert_eq!(total, a.count, "every observation lands in one bucket");
    }

    #[test]
    fn empty_hist_merge_keeps_min_sentinel_out_of_exports() {
        let mut a = Hist::default();
        a.merge(&Hist::default());
        assert_eq!(a.count, 0);
        assert_eq!(a.min_or_zero(), 0, "export never sees the u64::MAX sentinel");
    }

    /// Two registries agree on every counter and histogram (gauges are
    /// last-wins by contract, so the merge algebra below excludes them).
    fn assert_counters_hists_equiv(a: &MetricsRegistry, b: &MetricsRegistry) {
        for (name, v) in a.counters_iter() {
            assert_eq!(b.counter_by_name(name), Some(v), "counter {name}");
        }
        for (name, _) in b.counters_iter() {
            assert!(a.counter_by_name(name).is_some(), "counter {name} missing");
        }
        for (name, h) in a.hists_iter() {
            assert_eq!(b.hist_by_name(name), Some(h), "hist {name}");
        }
        for (name, _) in b.hists_iter() {
            assert!(a.hist_by_name(name).is_some(), "hist {name} missing");
        }
    }

    /// A registry with seeded-random counter bumps and histogram
    /// observations over a shared name pool (so merges genuinely
    /// overlap on some names and not others).
    fn random_registry(rng: &mut crate::util::rng::Pcg) -> MetricsRegistry {
        const NAMES: [&str; 5] =
            ["a_total", "b_total", "c_total", "x_ns", "y_ns"];
        let mut r = MetricsRegistry::new(false);
        for _ in 0..(2 + rng.below(8)) {
            let name = NAMES[rng.below(NAMES.len())];
            if name.ends_with("_total") {
                let id = r.counter(name);
                r.inc(id, rng.next_u64() % 1000);
            } else {
                let id = r.hist(name);
                r.record(id, rng.next_u64() % (1 << 40));
            }
        }
        r
    }

    #[test]
    fn merge_is_commutative_on_counters_and_hists() {
        let mut rng = crate::util::rng::Pcg::seed(0xC0FFEE);
        for _ in 0..50 {
            let a = random_registry(&mut rng);
            let b = random_registry(&mut rng);
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            assert_counters_hists_equiv(&ab, &ba);
        }
    }

    #[test]
    fn merge_is_associative() {
        let mut rng = crate::util::rng::Pcg::seed(0xA550C);
        for _ in 0..50 {
            let a = random_registry(&mut rng);
            let b = random_registry(&mut rng);
            let c = random_registry(&mut rng);
            // ((a·b)·c)
            let mut left = a.clone();
            left.merge(&b);
            left.merge(&c);
            // (a·(b·c))
            let mut bc = b.clone();
            bc.merge(&c);
            let mut right = a.clone();
            right.merge(&bc);
            assert_counters_hists_equiv(&left, &right);
        }
    }

    #[test]
    fn machine_sharded_merge_equals_single_registry_run() {
        // the proc-transport aggregation contract: recording a stream of
        // events sharded across per-machine registries and merging must
        // equal recording the whole stream into one registry
        let mut rng = crate::util::rng::Pcg::seed(77);
        let events: Vec<(usize, u64)> =
            (0..300).map(|_| (rng.below(3), rng.next_u64() % (1 << 30))).collect();

        let mut single = MetricsRegistry::new(false);
        let mut shards: Vec<MetricsRegistry> =
            (0..3).map(|_| MetricsRegistry::new(false)).collect();
        for &(machine, v) in &events {
            for r in [&mut single, &mut shards[machine]] {
                let c = r.counter("events_total");
                r.inc(c, 1);
                let h = r.hist("value_ns");
                r.record(h, v);
            }
        }
        let mut agg = MetricsRegistry::new(false);
        for s in &shards {
            agg.merge(s);
        }
        assert_counters_hists_equiv(&agg, &single);
    }

    #[test]
    fn merge_adds_counters_and_folds_hists() {
        let mut a = MetricsRegistry::new(false);
        let c = a.counter("sent_total");
        a.inc(c, 2);
        let h = a.hist("ns");
        a.record(h, 10);

        let mut b = MetricsRegistry::new(false);
        let c2 = b.counter("sent_total");
        b.inc(c2, 5);
        let only_b = b.counter("b_only_total");
        b.inc(only_b, 1);
        let h2 = b.hist("ns");
        b.record(h2, 1000);
        let g = b.gauge("iterations");
        b.set_gauge(g, 40.0);

        a.merge(&b);
        assert_eq!(a.counter_by_name("sent_total"), Some(7));
        assert_eq!(a.counter_by_name("b_only_total"), Some(1));
        assert_eq!(a.gauge_by_name("iterations"), Some(40.0));
        let m = a.hist_by_name("ns").unwrap();
        assert_eq!(m.count, 2);
        assert_eq!((m.min, m.max), (10, 1000));
    }
}
