//! Registry export: per-run JSON report, Prometheus-style text
//! exposition, and the JSON → registry parse used to ship per-machine
//! snapshots over the `fadmm-node` stdio line protocol.
//!
//! JSON numbers carry every `u64` this repo actually produces (counts
//! and nanosecond sums stay far below 2^53 for any run we can drive),
//! and gauges reuse the `net/codec.rs` non-finite sentinels (`"nan"`,
//! `"inf"`, `"-inf"`, `"-0"`) so the round-trip is exact for the same
//! reason the proc wire format is. The Prometheus text form follows the
//! exposition conventions: cumulative `_bucket{le="..."}` series per
//! histogram with a terminal `le="+Inf"`, plus `_sum` and `_count`.

use crate::error::{Error, Result};
use crate::net::codec::{f64_of, fnum};
use crate::util::json::{arr, num, obj, s, Json};

use super::registry::{Hist, MetricsRegistry, HIST_BUCKETS};

fn hist_to_json(h: &Hist) -> Json {
    obj(vec![
        ("count", num(h.count as f64)),
        ("sum", num(h.sum as f64)),
        ("min", num(h.min_or_zero() as f64)),
        ("max", num(h.max as f64)),
        ("buckets", arr(h.buckets.iter().map(|&b| num(b as f64)).collect())),
    ])
}

fn hist_from_json(v: &Json, name: &str) -> Result<Hist> {
    let mut h = Hist::default();
    let u = |key: &str| -> Result<u64> {
        v.get(key)
            .and_then(Json::as_f64)
            .map(|x| x as u64)
            .ok_or_else(|| Error::Config(format!("obs: histogram '{name}': missing '{key}'")))
    };
    h.count = u("count")?;
    h.sum = u("sum")?;
    h.max = u("max")?;
    h.min = if h.count == 0 { u64::MAX } else { u("min")? };
    let buckets = v
        .get("buckets")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Config(format!("obs: histogram '{name}': missing 'buckets'")))?;
    if buckets.len() != HIST_BUCKETS {
        return Err(Error::Config(format!(
            "obs: histogram '{name}': expected {HIST_BUCKETS} buckets, got {}",
            buckets.len()
        )));
    }
    for (slot, b) in h.buckets.iter_mut().zip(buckets) {
        *slot = b
            .as_f64()
            .ok_or_else(|| Error::Config(format!("obs: histogram '{name}': non-numeric bucket")))?
            as u64;
    }
    Ok(h)
}

impl MetricsRegistry {
    /// The registry as a JSON object — the obs report body, the proc
    /// transport's `metrics` line payload, and the input to
    /// [`MetricsRegistry::from_json`].
    pub fn to_json(&self) -> Json {
        let counters = self
            .counters_iter()
            .map(|(n, v)| (n.to_string(), num(v as f64)))
            .collect::<Vec<_>>();
        let gauges = self
            .gauges_iter()
            .map(|(n, v)| (n.to_string(), fnum(v)))
            .collect::<Vec<_>>();
        let hists = self
            .hists_iter()
            .map(|(n, h)| (n.to_string(), hist_to_json(h)))
            .collect::<Vec<_>>();
        let own = |pairs: Vec<(String, Json)>| {
            obj(pairs.iter().map(|(n, v)| (n.as_str(), v.clone())).collect())
        };
        obj(vec![
            ("counters", own(counters)),
            ("gauges", own(gauges)),
            ("histograms", own(hists)),
        ])
    }

    /// Parse a registry back from [`MetricsRegistry::to_json`] output.
    /// The result is a data-only registry (spans disabled); merge it
    /// into an aggregate or export it onward.
    pub fn from_json(v: &Json) -> Result<MetricsRegistry> {
        let section = |key: &str| -> Result<Vec<(String, Json)>> {
            match v.req(key)? {
                Json::Obj(pairs) => Ok(pairs.clone()),
                _ => Err(Error::Config(format!("obs: '{key}' must be an object"))),
            }
        };
        let mut reg = MetricsRegistry::new(false);
        for (name, val) in section("counters")? {
            let raw = val
                .as_f64()
                .ok_or_else(|| Error::Config(format!("obs: counter '{name}': not a number")))?;
            let id = reg.counter(&name);
            reg.inc(id, raw as u64);
        }
        for (name, val) in section("gauges")? {
            let x = f64_of(&val, &name)?;
            let id = reg.gauge(&name);
            reg.set_gauge(id, x);
        }
        for (name, val) in section("histograms")? {
            let h = hist_from_json(&val, &name)?;
            let id = reg.hist(&name);
            reg.merge_hist(id, &h);
        }
        Ok(reg)
    }

    /// Prometheus text exposition of the registry (see module docs):
    /// `# HELP` + `# TYPE` per metric, and the *full* cumulative
    /// `le`-labelled bucket series per histogram — every boundary is
    /// emitted (not just occupied ones) so scrapes from different runs
    /// always expose the same series set and quantile math over the
    /// buckets never sees gaps.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, v) in self.counters_iter() {
            out.push_str(&format!(
                "# HELP {name} {}\n# TYPE {name} counter\n{name} {v}\n",
                help_for(name)
            ));
        }
        for (name, v) in self.gauges_iter() {
            let val = if v.is_nan() {
                "NaN".to_string()
            } else if v == f64::INFINITY {
                "+Inf".to_string()
            } else if v == f64::NEG_INFINITY {
                "-Inf".to_string()
            } else {
                format!("{v}")
            };
            out.push_str(&format!(
                "# HELP {name} {}\n# TYPE {name} gauge\n{name} {val}\n",
                help_for(name)
            ));
        }
        for (name, h) in self.hists_iter() {
            out.push_str(&format!(
                "# HELP {name} {}\n# TYPE {name} histogram\n",
                help_for(name)
            ));
            let mut cum = 0u64;
            for (i, &b) in h.buckets.iter().enumerate() {
                cum += b;
                if let Some(le) = Hist::bucket_upper(i) {
                    out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
                }
            }
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
            out.push_str(&format!("{name}_sum {}\n", h.sum));
            out.push_str(&format!("{name}_count {}\n", h.count));
        }
        out
    }
}

/// Static help text for the exposition format. Known `fadmm_*` names
/// get specific text; anything else a structural description, so the
/// `# HELP` line is always present (some scrapers warn on its absence).
fn help_for(name: &str) -> &'static str {
    match name {
        "fadmm_rounds_total" => "Committed protocol rounds",
        "fadmm_iterations" => "Iterations at run end",
        "fadmm_converged" => "1 when the run converged, else 0",
        "fadmm_virtual_time" => "Transport clock at run end (ticks)",
        "fadmm_machines" => "Cluster machines in the run",
        "fadmm_phase_solve_ns" => "Phase A local-solve span durations (ns)",
        "fadmm_phase_reduce_ns" => "Phase B exchange/reduce span durations (ns)",
        "fadmm_phase_observe_ns" => "Phase C dual/observe span durations (ns)",
        "fadmm_boundary_io_ns" => "Boundary theta/eta batch I/O span durations (ns)",
        "fadmm_collective_fold_ns" => "Collective stop-fold span durations (ns)",
        "fadmm_pool_dispatch_ns" => "Worker-pool dispatch span durations (ns)",
        "fadmm_threads_spawned_total" => "OS threads spawned by worker pools",
        "fadmm_trace_events_total" => "Flight-recorder events retained at finish",
        "fadmm_trace_dropped_total" => "Flight-recorder events evicted past capacity",
        "fadmm_timeline_events_total" => "Timeline events retained at finish",
        "fadmm_timeline_dropped_total" => "Timeline events evicted past capacity",
        "fadmm_series_rows_total" => "Convergence-series rows retained at finish",
        "fadmm_series_dropped_total" => "Convergence-series rows decimated away",
        "fadmm_net_sent_total" => "Frames handed to the transport",
        "fadmm_net_delivered_total" => "Frames delivered",
        "fadmm_net_dropped_loss_total" => "Frames dropped by simulated loss",
        "fadmm_net_dropped_partition_total" => "Frames dropped by partitions",
        "fadmm_net_dropped_dead_total" => "Frames dropped to dead endpoints",
        "fadmm_net_duplicated_total" => "Frames duplicated by the fault plan",
        "fadmm_net_stale_reads_total" => "Neighbour reads beyond the staleness budget",
        "fadmm_net_fallback_reads_total" => "Silence-timeout fallback reads",
        "fadmm_net_timeouts_total" => "Protocol timer expiries",
        "fadmm_net_joins_total" => "Machine joins",
        "fadmm_net_leaves_total" => "Machine leaves",
        "fadmm_net_edges_deactivated_total" => "Edges masked by NAP/churn",
        "fadmm_net_edges_reactivated_total" => "Edges unmasked",
        "fadmm_net_collective_timeouts_total" => "Collective fold timeouts",
        "fadmm_net_collective_fallbacks_total" => "Collective local-fallback verdicts",
        "fadmm_net_collective_retries_total" => "Collective retransmits",
        "fadmm_net_gossip_ticks_total" => "Gossip all-reduce ticks",
        "fadmm_net_overlap_dispatches_total" => "Interior solves overlapped with boundary I/O",
        n if n.ends_with("_total") => "Monotone event count",
        n if n.ends_with("_ns") => "Span durations (ns)",
        _ => "Run outcome value",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> MetricsRegistry {
        let mut r = MetricsRegistry::new(false);
        let c = r.counter("fadmm_rounds_total");
        r.inc(c, 42);
        let c2 = r.counter("fadmm_net_sent_total");
        r.inc(c2, 1000);
        let g = r.gauge("fadmm_iterations");
        r.set_gauge(g, 37.0);
        let h = r.hist("fadmm_phase_solve_ns");
        for v in [0u64, 3, 900, 65_536, 1 << 40] {
            let id = h;
            r.record(id, v);
        }
        r
    }

    #[test]
    fn json_round_trip_is_exact() {
        let reg = sample();
        let j = reg.to_json();
        let back = MetricsRegistry::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.counter_by_name("fadmm_rounds_total"), Some(42));
        assert_eq!(back.counter_by_name("fadmm_net_sent_total"), Some(1000));
        assert_eq!(back.gauge_by_name("fadmm_iterations"), Some(37.0));
        let h = back.hist_by_name("fadmm_phase_solve_ns").unwrap();
        let orig = reg.hist_by_name("fadmm_phase_solve_ns").unwrap();
        assert_eq!(h, orig, "histogram survives the wire bit-for-bit");
    }

    #[test]
    fn non_finite_gauges_use_codec_sentinels() {
        let mut r = MetricsRegistry::new(false);
        for (name, v) in [
            ("g_nan", f64::NAN),
            ("g_inf", f64::INFINITY),
            ("g_ninf", f64::NEG_INFINITY),
            ("g_nzero", -0.0),
        ] {
            let id = r.gauge(name);
            r.set_gauge(id, v);
        }
        let text = r.to_json().to_string();
        let back = MetricsRegistry::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(back.gauge_by_name("g_nan").unwrap().is_nan());
        assert_eq!(back.gauge_by_name("g_inf"), Some(f64::INFINITY));
        assert_eq!(back.gauge_by_name("g_ninf"), Some(f64::NEG_INFINITY));
        let nz = back.gauge_by_name("g_nzero").unwrap();
        assert_eq!(nz, 0.0);
        assert!(nz.is_sign_negative(), "-0 sign survives");
    }

    #[test]
    fn empty_hist_round_trips_without_min_sentinel_loss() {
        let mut r = MetricsRegistry::new(false);
        r.hist("fadmm_empty_ns");
        let back =
            MetricsRegistry::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        let h = back.hist_by_name("fadmm_empty_ns").unwrap();
        assert_eq!(h.count, 0);
        assert_eq!(h.min, u64::MAX, "empty-hist sentinel restored on parse");
        // …so merging a real observation still computes the true min
        let mut live = MetricsRegistry::new(false);
        let id = live.hist("fadmm_empty_ns");
        live.record(id, 7);
        let mut agg = back.clone();
        agg.merge(&live);
        assert_eq!(agg.hist_by_name("fadmm_empty_ns").unwrap().min, 7);
    }

    #[test]
    fn prometheus_text_has_cumulative_buckets_and_totals() {
        let reg = sample();
        let text = reg.to_prometheus();
        assert!(text.contains("# TYPE fadmm_rounds_total counter"));
        assert!(text.contains("fadmm_rounds_total 42"));
        assert!(text.contains("# TYPE fadmm_iterations gauge"));
        assert!(text.contains("# TYPE fadmm_phase_solve_ns histogram"));
        assert!(text.contains("fadmm_phase_solve_ns_bucket{le=\"+Inf\"} 5"));
        assert!(text.contains("fadmm_phase_solve_ns_count 5"));
        // cumulative: the le-series is non-decreasing
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.starts_with("fadmm_phase_solve_ns_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "bucket series must be cumulative: {line}");
            last = v;
        }
        assert_eq!(last, 5);
    }

    #[test]
    fn prometheus_emits_help_and_full_bucket_series() {
        let reg = sample();
        let text = reg.to_prometheus();
        // every metric family gets a # HELP line directly above # TYPE
        for name in [
            "fadmm_rounds_total",
            "fadmm_net_sent_total",
            "fadmm_iterations",
            "fadmm_phase_solve_ns",
        ] {
            let help = format!("# HELP {name} ");
            assert!(text.contains(&help), "missing help for {name}");
            let lines: Vec<&str> = text.lines().collect();
            let hi = lines
                .iter()
                .position(|l| l.starts_with(&help))
                .unwrap();
            assert!(
                lines[hi + 1].starts_with(&format!("# TYPE {name} ")),
                "HELP must be immediately followed by TYPE for {name}"
            );
        }
        // the full cumulative series: every finite boundary plus +Inf,
        // even though only 5 observations landed in 5 buckets
        let bucket_lines: Vec<&str> = text
            .lines()
            .filter(|l| l.starts_with("fadmm_phase_solve_ns_bucket"))
            .collect();
        assert_eq!(bucket_lines.len(), HIST_BUCKETS, "63 finite les + +Inf");
        // boundaries are the log2 uppers, ascending, ending at +Inf
        assert!(bucket_lines[0].contains("le=\"0\""));
        assert!(bucket_lines[1].contains("le=\"1\""));
        assert!(bucket_lines[2].contains("le=\"3\""));
        assert!(bucket_lines[HIST_BUCKETS - 1].contains("le=\"+Inf\""));
        // cumulative counts are non-decreasing and reach the total
        let mut last = 0u64;
        for line in &bucket_lines {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "cumulative series must be non-decreasing: {line}");
            last = v;
        }
        assert_eq!(last, 5);
        // unknown names still get a generic help line
        let mut r = MetricsRegistry::new(false);
        let c = r.counter("custom_thing_total");
        r.inc(c, 1);
        assert!(r
            .to_prometheus()
            .contains("# HELP custom_thing_total Monotone event count"));
    }

    #[test]
    fn prometheus_non_finite_gauges_render_inf_nan() {
        let mut r = MetricsRegistry::new(false);
        let a = r.gauge("g_inf");
        r.set_gauge(a, f64::INFINITY);
        let b = r.gauge("g_nan");
        r.set_gauge(b, f64::NAN);
        let text = r.to_prometheus();
        assert!(text.contains("g_inf +Inf"));
        assert!(text.contains("g_nan NaN"));
    }
}
