//! Per-round critical-path attribution over the causal timeline.
//!
//! [`analyze`] walks a drained [`Timeline`] event stream and, for every
//! round that committed, attributes the round's wall time to:
//!
//! * the five protocol phases (max across machines per phase — the
//!   slowest machine is the one the commit waited on),
//! * **network** — the slowest matched send→deliver frame latency of
//!   the round (transport ticks; on the simulator this is virtual link
//!   latency, on the real transports wall ms),
//! * **straggler_wait** — whatever remains of the commit-to-commit wall
//!   gap after phases and network are accounted, clamped at zero. Large
//!   values mean the round sat waiting on something the timeline did
//!   not see (a stalled peer, collective retries, host scheduling).
//!
//! Phase durations are span nanoseconds (host clock); wall and network
//! come from transport ticks (ms). The two clocks agree on the real
//! backends; on the simulator compute-ns are host time while the wall
//! is virtual, which still ranks rounds correctly (ticks dominate) and
//! is documented in the run-report guide. A round's `dominant` bucket
//! is the largest of the seven attributions on a common ns scale.
//!
//! The report ([`critical_path_json`] / [`critical_path_text`]) lists
//! the top-k slowest rounds — the "why was round 412 slow?" answer.

use crate::util::json::{arr, num, obj, s, Json};

use super::timeline::{Phase, TlEvent, TlKind, NPHASES};

/// Ticks (ms) expressed as nanoseconds, for comparing against span ns.
fn ticks_ns(t: u64) -> u64 {
    t.saturating_mul(1_000_000)
}

/// One analyzed round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RoundPath {
    pub round: u64,
    /// Commit-to-previous-commit gap, in transport ticks (ms).
    pub wall_ticks: u64,
    /// Per-phase span ns, max across machines (ordered by
    /// [`Phase::index`]).
    pub phase_ns: [u64; NPHASES],
    /// Slowest matched frame latency in the round, in ticks.
    pub network_ticks: u64,
    /// Frames of the round observed sent / delivered.
    pub frames_sent: u64,
    pub frames_delivered: u64,
    /// Unattributed remainder of the wall gap, in ns (clamped ≥ 0).
    pub straggler_wait_ns: u64,
    /// The largest attribution bucket.
    pub dominant: &'static str,
}

impl RoundPath {
    pub fn wall_ns(&self) -> u64 {
        ticks_ns(self.wall_ticks)
    }
}

/// Scratch per-round accumulation keyed by round id.
#[derive(Debug, Default, Clone)]
struct RoundAcc {
    /// phase ns summed per (machine, phase), folded to a max in finish
    per_machine: Vec<(usize, [u64; NPHASES])>,
    /// open sends of this round: (machine, seq, at)
    sends: Vec<(usize, u64, u64)>,
    max_latency: u64,
    frames_sent: u64,
    frames_delivered: u64,
    commit_at: Option<u64>,
}

impl RoundAcc {
    fn machine_slot(&mut self, machine: usize) -> &mut [u64; NPHASES] {
        if let Some(i) = self.per_machine.iter().position(|(m, _)| *m == machine) {
            return &mut self.per_machine[i].1;
        }
        self.per_machine.push((machine, [0; NPHASES]));
        &mut self.per_machine.last_mut().unwrap().1
    }
}

/// Analyze a drained timeline: one [`RoundPath`] per committed round,
/// sorted by descending wall time, truncated to `top_k` (0 = keep all).
pub fn analyze(events: &[TlEvent], top_k: usize) -> Vec<RoundPath> {
    // group events by round id (rounds are few and mostly ordered, so a
    // linear-probed Vec beats a map here and keeps ordering stable)
    let mut rounds: Vec<(u64, RoundAcc)> = Vec::new();
    let acc = |rounds: &mut Vec<(u64, RoundAcc)>, r: u64| -> usize {
        if let Some(i) = rounds.iter().position(|(k, _)| *k == r) {
            return i;
        }
        rounds.push((r, RoundAcc::default()));
        rounds.len() - 1
    };

    for ev in events {
        let i = acc(&mut rounds, ev.round);
        let a = &mut rounds[i].1;
        match ev.kind {
            TlKind::Phase { phase, dur_ns } => {
                a.machine_slot(ev.machine)[phase.index()] += dur_ns;
            }
            TlKind::Send { seq, .. } => {
                a.frames_sent += 1;
                a.sends.push((ev.machine, seq, ev.at));
            }
            TlKind::Recv { seq, src, .. } => {
                a.frames_delivered += 1;
                if let Some(p) = a.sends.iter().position(|&(m, q, _)| m == src && q == seq)
                {
                    let sent_at = a.sends[p].2;
                    a.max_latency = a.max_latency.max(ev.at.saturating_sub(sent_at));
                }
            }
            TlKind::Commit => {
                // keep the latest commit timestamp (gossip can re-commit)
                a.commit_at = Some(a.commit_at.map_or(ev.at, |c| c.max(ev.at)));
            }
        }
    }

    // wall time per round = gap between consecutive commit timestamps;
    // the first committed round measures from the earliest event seen
    let t0 = events.iter().map(|e| e.at).min().unwrap_or(0);
    let mut committed: Vec<(u64, u64)> = rounds
        .iter()
        .filter_map(|(r, a)| a.commit_at.map(|at| (*r, at)))
        .collect();
    committed.sort_unstable_by_key(|&(_, at)| at);

    let mut out: Vec<RoundPath> = Vec::with_capacity(committed.len());
    let mut prev_at = t0;
    for (r, at) in committed {
        let a = &rounds.iter().find(|(k, _)| *k == r).unwrap().1;
        let wall_ticks = at.saturating_sub(prev_at);
        prev_at = at;

        let mut phase_ns = [0u64; NPHASES];
        for (_, ns) in &a.per_machine {
            for (slot, &v) in phase_ns.iter_mut().zip(ns.iter()) {
                *slot = (*slot).max(v);
            }
        }
        let network_ns = ticks_ns(a.max_latency);
        let accounted: u64 = phase_ns.iter().sum::<u64>() + network_ns;
        let straggler_wait_ns = ticks_ns(wall_ticks).saturating_sub(accounted);

        let mut dominant = "network";
        let mut best = network_ns;
        for p in Phase::ALL {
            if phase_ns[p.index()] > best {
                best = phase_ns[p.index()];
                dominant = p.name();
            }
        }
        if straggler_wait_ns > best {
            dominant = "straggler_wait";
        }

        out.push(RoundPath {
            round: r,
            wall_ticks,
            phase_ns,
            network_ticks: a.max_latency,
            frames_sent: a.frames_sent,
            frames_delivered: a.frames_delivered,
            straggler_wait_ns,
            dominant,
        });
    }

    out.sort_by(|a, b| {
        b.wall_ticks.cmp(&a.wall_ticks).then(a.round.cmp(&b.round))
    });
    if top_k > 0 {
        out.truncate(top_k);
    }
    out
}

/// The critical-path report as JSON (`<trace>.critical_path.json`).
pub fn critical_path_json(paths: &[RoundPath], analyzed_events: usize) -> Json {
    let items = paths
        .iter()
        .map(|p| {
            obj(vec![
                ("round", num(p.round as f64)),
                ("wall_ticks", num(p.wall_ticks as f64)),
                ("network_ticks", num(p.network_ticks as f64)),
                ("frames_sent", num(p.frames_sent as f64)),
                ("frames_delivered", num(p.frames_delivered as f64)),
                ("straggler_wait_ns", num(p.straggler_wait_ns as f64)),
                ("dominant", s(p.dominant)),
                (
                    "phase_ns",
                    obj(Phase::ALL
                        .iter()
                        .map(|ph| (ph.name(), num(p.phase_ns[ph.index()] as f64)))
                        .collect()),
                ),
            ])
        })
        .collect();
    obj(vec![
        ("rounds", arr(items)),
        ("analyzed_events", num(analyzed_events as f64)),
    ])
}

/// One-line-per-round human summary for stderr.
pub fn critical_path_text(paths: &[RoundPath]) -> String {
    let mut out = String::from(
        "critical path (slowest rounds): round  wall_ms  dominant  net_ms  straggler_ms\n",
    );
    for p in paths {
        out.push_str(&format!(
            "  r{:<6} {:>8} {:>14} {:>7} {:>12.3}\n",
            p.round,
            p.wall_ticks,
            p.dominant,
            p.network_ticks,
            p.straggler_wait_ns as f64 / 1e6,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::timeline::{Timeline, TraceCtx};

    /// Two rounds: round 0 commits at t=10 dominated by a slow solve,
    /// round 1 commits at t=50 dominated by a 30-tick frame latency.
    fn two_round_timeline() -> Vec<TlEvent> {
        let mut tl = Timeline::new(true);
        // round 0: solve takes 8 ms (8e6 ns) of the 10-tick wall
        tl.phase(8, 0, 0, Phase::Solve, 8_000_000);
        tl.phase(9, 0, 0, Phase::CollectiveFold, 100_000);
        tl.commit(10, 0, 0);
        // round 1: a frame sent at 15 lands at 45 (30 ticks in flight)
        let ctx = TraceCtx { round: 1, machine: 1, seq: 42 };
        tl.phase(14, 1, 1, Phase::Solve, 1_000_000);
        tl.send(15, ctx, 0, "theta");
        tl.recv(45, 0, ctx, "theta");
        tl.commit(50, 0, 1);
        tl.drain()
    }

    #[test]
    fn attributes_solve_and_network_dominance() {
        let paths = analyze(&two_round_timeline(), 0);
        assert_eq!(paths.len(), 2);
        // sorted slowest-first: round 1 (wall 40) before round 0 (wall 2:
        // commit at 10 minus first event at 8)
        assert_eq!(paths[0].round, 1);
        assert_eq!(paths[0].wall_ticks, 40);
        assert_eq!(paths[0].network_ticks, 30);
        assert_eq!(paths[0].dominant, "network");
        assert_eq!(paths[0].frames_sent, 1);
        assert_eq!(paths[0].frames_delivered, 1);

        let r0 = &paths[1];
        assert_eq!(r0.round, 0);
        assert_eq!(r0.wall_ticks, 2);
        assert_eq!(r0.phase_ns[Phase::Solve.index()], 8_000_000);
        assert_eq!(r0.dominant, "solve", "8 ms solve beats the 2-tick wall");
        assert_eq!(r0.network_ticks, 0, "no frames in round 0");
    }

    #[test]
    fn straggler_wait_absorbs_unattributed_wall() {
        let mut tl = Timeline::new(true);
        // 100-tick wall with only 1 ms of recorded work
        tl.phase(1, 0, 0, Phase::Solve, 1_000_000);
        tl.commit(100, 0, 0);
        let paths = analyze(&tl.drain(), 0);
        assert_eq!(paths.len(), 1);
        assert_eq!(paths[0].wall_ticks, 99);
        assert_eq!(paths[0].dominant, "straggler_wait");
        assert_eq!(paths[0].straggler_wait_ns, 99_000_000 - 1_000_000);
    }

    #[test]
    fn top_k_truncates_after_sorting() {
        let paths = analyze(&two_round_timeline(), 1);
        assert_eq!(paths.len(), 1);
        assert_eq!(paths[0].round, 1, "keeps the slowest round");
    }

    #[test]
    fn json_report_round_trips() {
        let paths = analyze(&two_round_timeline(), 0);
        let j = critical_path_json(&paths, 9);
        let back = Json::parse(&j.to_string()).unwrap();
        let rounds = back.get("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds.len(), 2);
        assert_eq!(rounds[0].get("dominant").unwrap().as_str(), Some("network"));
        assert_eq!(
            rounds[0].get("phase_ns").unwrap().get("solve").unwrap().as_f64(),
            Some(1_000_000.0)
        );
        assert_eq!(back.get("analyzed_events").unwrap().as_f64(), Some(9.0));
        let text = critical_path_text(&paths);
        assert!(text.contains("r1"));
        assert!(text.contains("network"));
    }

    #[test]
    fn uncommitted_rounds_are_ignored() {
        let mut tl = Timeline::new(true);
        tl.phase(1, 0, 7, Phase::Solve, 5);
        // no commit event for round 7
        let paths = analyze(&tl.drain(), 0);
        assert!(paths.is_empty());
    }
}
