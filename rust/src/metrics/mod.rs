//! Convergence checking and per-iteration metric recording.

use crate::error::Result;
use crate::util::csv::{fnum, CsvWriter};
use crate::util::json::{num, obj, Json};

/// Relative-change convergence criterion (paper §5: "we compare the
/// relative change of (14) to a fixed threshold, 1e-3").
///
/// Deviation from the paper, documented in DESIGN.md: the change is
/// normalized by the objective's *observed range* rather than its
/// absolute value. The marginal NLL carries a large data-scale-dependent
/// additive constant (n·D·log 2π + …), so |Δf|/|f| silently changes
/// meaning with measurement units (raw |f|-relative 1e-3 stops pixel-unit
/// SfM runs after <10 iterations while the subspace is still random).
/// |Δf| / (max f − min f) is invariant to both offset and scale and
/// reproduces the paper's "typically < 100 iterations" behaviour.
#[derive(Debug, Clone)]
pub struct ConvergenceChecker {
    tol: f64,
    /// number of consecutive under-threshold iterations required
    patience: usize,
    prev: Option<f64>,
    f_min: f64,
    f_max: f64,
    streak: usize,
    /// iterations to skip before checking (lets ADMM escape the initial
    /// plateau where the objective barely moves)
    warmup: usize,
    seen: usize,
}

/// Serialized [`ConvergenceChecker`] state — plain data, so the cluster
/// runtime's leader handoff can ship the checker over its simulated
/// network ([`crate::kernel::StopSnapshot`]).
#[derive(Debug, Clone, PartialEq)]
pub struct CheckerState {
    pub prev: Option<f64>,
    pub f_min: f64,
    pub f_max: f64,
    pub streak: usize,
    pub seen: usize,
}

impl ConvergenceChecker {
    pub fn new(tol: f64) -> Self {
        ConvergenceChecker {
            tol,
            patience: 1,
            prev: None,
            f_min: f64::INFINITY,
            f_max: f64::NEG_INFINITY,
            streak: 0,
            warmup: 2,
            seen: 0,
        }
    }

    pub fn with_patience(mut self, patience: usize) -> Self {
        self.patience = patience.max(1);
        self
    }

    pub fn with_warmup(mut self, warmup: usize) -> Self {
        self.warmup = warmup;
        self
    }

    /// Feed the iteration's global objective; returns true on convergence.
    pub fn update(&mut self, objective: f64) -> bool {
        self.seen += 1;
        let delta = match self.prev {
            Some(p) => (objective - p).abs(),
            None => f64::INFINITY,
        };
        self.prev = Some(objective);
        if objective.is_finite() {
            self.f_min = self.f_min.min(objective);
            self.f_max = self.f_max.max(objective);
        }
        let range = (self.f_max - self.f_min).max(1e-12);
        let rel = delta / range;
        if self.seen <= self.warmup {
            self.streak = 0;
            return false;
        }
        if rel < self.tol {
            self.streak += 1;
        } else {
            self.streak = 0;
        }
        self.streak >= self.patience
    }

    pub fn reset(&mut self) {
        self.prev = None;
        self.f_min = f64::INFINITY;
        self.f_max = f64::NEG_INFINITY;
        self.streak = 0;
        self.seen = 0;
    }

    /// Serialize the mutable state (tol/patience/warmup are configuration
    /// and stay with the receiving checker).
    pub fn snapshot(&self) -> CheckerState {
        CheckerState {
            prev: self.prev,
            f_min: self.f_min,
            f_max: self.f_max,
            streak: self.streak,
            seen: self.seen,
        }
    }

    /// Restore serialized state into this checker.
    pub fn restore(&mut self, s: &CheckerState) {
        self.prev = s.prev;
        self.f_min = s.f_min;
        self.f_max = s.f_max;
        self.streak = s.streak;
        self.seen = s.seen;
    }
}

/// One iteration's engine-level statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct IterStats {
    pub iter: usize,
    /// Σ_i f_i(θ_i)
    pub objective: f64,
    /// max_i ‖r_i‖ (local primal residual norm)
    pub max_primal: f64,
    /// max_i ‖s_i‖ (local dual residual norm)
    pub max_dual: f64,
    /// mean penalty over all directed edges
    pub mean_eta: f64,
    /// min/max penalty over edges (effective-topology spread)
    pub min_eta: f64,
    pub max_eta: f64,
    /// application metric (subspace-angle error for PPCA experiments)
    pub app_error: f64,
}

/// One shard's (or machine's) contribution to a global statistics fold,
/// accumulated over its nodes in sequential id order. Shared by the
/// sharded coordinator's leader fold and the cluster collectives
/// ([`crate::cluster`]), so every runtime combines partial statistics
/// with exactly the same arithmetic.
///
/// `theta_sum` and `centered_sq` are the sufficient statistics for the
/// global primal residual: `centered_sq = Σ_i ‖θ_i − m_s‖²` about the
/// *local* mean `m_s = theta_sum / node_count`. Centering locally (rather
/// than shipping raw Σ‖θ‖²) lets [`RunningFold`] combine partials with
/// Chan et al.'s pairwise update, which stays accurate at any ‖θ‖ scale.
#[derive(Debug, Clone, PartialEq)]
pub struct StatPartial {
    /// Σ_i f_i(θ_i) over the partial's nodes
    pub f_sum: f64,
    /// max_i ‖r_i‖ (local primal residual norm)
    pub max_primal: f64,
    /// max_i ‖s_i‖ (local dual residual norm)
    pub max_dual: f64,
    pub eta_min: f64,
    pub eta_max: f64,
    pub eta_sum: f64,
    pub eta_count: usize,
    /// Σ_i θ_i (flat, `dim` entries)
    pub theta_sum: Vec<f64>,
    /// number of nodes folded into this partial
    pub node_count: usize,
    /// Σ_i ‖θ_i − m_s‖² about the partial's own mean (see type docs)
    pub centered_sq: f64,
}

impl StatPartial {
    pub fn new(dim: usize) -> StatPartial {
        StatPartial {
            f_sum: 0.0,
            max_primal: 0.0,
            max_dual: 0.0,
            eta_min: f64::INFINITY,
            eta_max: 0.0,
            eta_sum: 0.0,
            eta_count: 0,
            theta_sum: vec![0.0; dim],
            node_count: 0,
            centered_sq: 0.0,
        }
    }

    pub fn reset(&mut self) {
        self.f_sum = 0.0;
        self.max_primal = 0.0;
        self.max_dual = 0.0;
        self.eta_min = f64::INFINITY;
        self.eta_max = 0.0;
        self.eta_sum = 0.0;
        self.eta_count = 0;
        self.theta_sum.iter_mut().for_each(|x| *x = 0.0);
        self.node_count = 0;
        self.centered_sq = 0.0;
    }

    /// Fold one node's contribution (objective, residual norms, the η
    /// stream over its out-edges, Σθ) — the single transcription of the
    /// per-node statistics accumulation the sharded coordinator and the
    /// cluster machines share. Callers feed nodes in sequential id order
    /// so combining partials in shard order reproduces a flat sweep.
    pub fn absorb_node(&mut self, f_self: f64, primal: f64, dual: f64,
                       etas: &[f64], theta: &[f64]) {
        self.f_sum += f_self;
        self.max_primal = self.max_primal.max(primal);
        self.max_dual = self.max_dual.max(dual);
        for &e in etas {
            self.eta_min = self.eta_min.min(e);
            self.eta_max = self.eta_max.max(e);
            self.eta_sum += e;
        }
        self.eta_count += etas.len();
        for (k, &x) in theta.iter().enumerate() {
            self.theta_sum[k] += x;
        }
    }

    /// The centered second pass: spread about the partial's *own* mean
    /// (`m_s = theta_sum / count`, written into `mean_scratch`), visiting
    /// the same θ slices in the same order as the absorb pass. Centering
    /// here — instead of folding raw Σ‖θ‖² — keeps the combined global
    /// residual accurate at any ‖θ‖ scale (the subtraction a raw
    /// sum-of-squares needs cancels catastrophically once ‖θ‖² ≫ spread).
    pub fn finish_centered<'a, I>(&mut self, count: usize, thetas: I,
                                  mean_scratch: &mut [f64])
    where
        I: IntoIterator<Item = &'a [f64]>,
    {
        self.finish_centered_with(count, mean_scratch, |absorb| {
            for th in thetas {
                absorb(th);
            }
        });
    }

    /// Push-style [`StatPartial::finish_centered`]: the caller receives an
    /// `absorb(θ_i)` sink and feeds the same θ slices in the same order.
    /// Needed where the θ storage is not `f64` (the coordinator's reduced-
    /// precision arena widens each block into one scratch buffer, so an
    /// iterator of simultaneously-live slices cannot exist). Arithmetic is
    /// identical to the pull variant — element order, accumulation order,
    /// and the centered update all unchanged.
    pub fn finish_centered_with(&mut self, count: usize, mean_scratch: &mut [f64],
                                feed: impl FnOnce(&mut dyn FnMut(&[f64]))) {
        self.node_count = count;
        if count == 0 {
            return;
        }
        let dim = self.theta_sum.len();
        let inv_count = 1.0 / count as f64;
        for k in 0..dim {
            mean_scratch[k] = self.theta_sum[k] * inv_count;
        }
        let mean = &mean_scratch[..dim];
        feed(&mut |th: &[f64]| {
            for k in 0..dim {
                let d = th[k] - mean[k];
                self.centered_sq += d * d;
            }
        });
    }

    /// Copy into a pre-sized slot without reallocating its `theta_sum`.
    pub fn store_into(&self, dst: &mut StatPartial) {
        dst.f_sum = self.f_sum;
        dst.max_primal = self.max_primal;
        dst.max_dual = self.max_dual;
        dst.eta_min = self.eta_min;
        dst.eta_max = self.eta_max;
        dst.eta_sum = self.eta_sum;
        dst.eta_count = self.eta_count;
        dst.theta_sum.copy_from_slice(&self.theta_sum);
        dst.node_count = self.node_count;
        dst.centered_sq = self.centered_sq;
    }
}

/// Sequential combination of [`StatPartial`]s: after absorbing partials
/// `p_1 … p_k` (in that order), `gmean` holds the mean over all folded
/// nodes and `gr2` their spread about it, combined with Chan et al.'s
/// pairwise mean/spread update — the exact arithmetic of the sharded
/// coordinator's leader fold, factored out so the cluster collectives
/// reproduce it bit-for-bit when they absorb the same partials in the
/// same order.
#[derive(Debug, Clone)]
pub struct RunningFold {
    pub objective: f64,
    pub max_primal: f64,
    pub max_dual: f64,
    pub eta_min: f64,
    pub eta_max: f64,
    pub eta_sum: f64,
    pub eta_count: usize,
    /// running mean over folded nodes (valid once `agg_n > 0`)
    pub gmean: Vec<f64>,
    /// nodes folded so far
    pub agg_n: usize,
    /// running Σ‖θ − gmean‖² (may drift a hair below 0; clamp at read)
    pub gr2: f64,
}

impl RunningFold {
    pub fn new(dim: usize) -> RunningFold {
        RunningFold {
            objective: 0.0,
            max_primal: 0.0,
            max_dual: 0.0,
            eta_min: f64::INFINITY,
            eta_max: 0.0,
            eta_sum: 0.0,
            eta_count: 0,
            gmean: vec![0.0; dim],
            agg_n: 0,
            gr2: 0.0,
        }
    }

    pub fn reset(&mut self) {
        self.objective = 0.0;
        self.max_primal = 0.0;
        self.max_dual = 0.0;
        self.eta_min = f64::INFINITY;
        self.eta_max = 0.0;
        self.eta_sum = 0.0;
        self.eta_count = 0;
        self.gmean.iter_mut().for_each(|x| *x = 0.0);
        self.agg_n = 0;
        self.gr2 = 0.0;
    }

    /// Fold one more partial (order-sensitive; callers fold in node-id
    /// order for reproducibility).
    pub fn absorb(&mut self, part: &StatPartial) {
        let dim = self.gmean.len();
        self.objective += part.f_sum;
        self.max_primal = self.max_primal.max(part.max_primal);
        self.max_dual = self.max_dual.max(part.max_dual);
        self.eta_min = self.eta_min.min(part.eta_min);
        self.eta_max = self.eta_max.max(part.eta_max);
        self.eta_sum += part.eta_sum;
        self.eta_count += part.eta_count;
        if part.node_count == 0 {
            return;
        }
        let nb = part.node_count as f64;
        let inv_b = 1.0 / nb;
        if self.agg_n == 0 {
            for k in 0..dim {
                self.gmean[k] = part.theta_sum[k] * inv_b;
            }
            self.gr2 = part.centered_sq;
        } else {
            let na = self.agg_n as f64;
            let inv_tot = 1.0 / (na + nb);
            let mut delta_sq = 0.0;
            for k in 0..dim {
                let mb = part.theta_sum[k] * inv_b;
                let d = mb - self.gmean[k];
                delta_sq += d * d;
                self.gmean[k] = (self.gmean[k] * na + part.theta_sum[k]) * inv_tot;
            }
            self.gr2 += part.centered_sq + delta_sq * na * nb * inv_tot;
        }
        self.agg_n += part.node_count;
    }

    /// √Σ‖θ − ḡ‖² — the folded global primal residual.
    pub fn global_primal(&self) -> f64 {
        self.gr2.max(0.0).sqrt()
    }

    pub fn mean_eta(&self) -> f64 {
        if self.eta_count == 0 { 0.0 } else { self.eta_sum / self.eta_count as f64 }
    }

    pub fn min_eta(&self) -> f64 {
        if self.eta_count == 0 { 0.0 } else { self.eta_min }
    }
}

/// Per-scenario event and staleness counters for a simulated-network run
/// ([`crate::net`]). Purely additive bookkeeping: the simulator and the
/// async runner bump these as events fire, and experiment CSVs / bench
/// JSONs report them next to the convergence metrics so a scenario's
/// fault load is visible alongside its cost.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetCounters {
    /// messages handed to the transport (before loss/partition sampling)
    pub sent: u64,
    /// messages delivered to a live destination
    pub delivered: u64,
    /// messages dropped by the Bernoulli loss model
    pub dropped_loss: u64,
    /// messages dropped for crossing an active partition cut
    pub dropped_partition: u64,
    /// messages dropped because the destination was dead at delivery time
    pub dropped_dead: u64,
    /// extra deliveries injected by the duplication model
    pub duplicated: u64,
    /// neighbour-cache reads older than the ideal stamp (any age > 0)
    pub stale_reads: u64,
    /// forced reads past the staleness bound (silent-neighbour fallback)
    pub fallback_reads: u64,
    /// silence timeouts that fired (a forced advance was *attempted*; it
    /// still blocks if a live slot has no cache entry yet)
    pub timeouts: u64,
    pub joins: u64,
    pub leaves: u64,
    /// NAP effective-topology decisions applied by the controller
    pub edges_deactivated: u64,
    pub edges_reactivated: u64,
    /// cluster collective: a machine gave up waiting for a subtree /
    /// verdict and proceeded with what it had
    pub collective_timeouts: u64,
    /// cluster collective: a machine substituted a *local* fold for a
    /// verdict that never arrived (isolated-machine survival mode)
    pub collective_fallbacks: u64,
    /// cluster collective: contribution retransmissions after a timeout
    pub collective_retries: u64,
    /// cluster gossip: push-sum exchange ticks performed
    pub gossip_ticks: u64,
    /// cluster overlap: interior phase-A job sets dispatched to the pool
    /// while boundary batches were still in flight
    pub overlap_dispatches: u64,
    /// trace events evicted from the bounded flight recorder
    /// ([`crate::obs::FlightRecorder`]); never serialized on the proc
    /// wire — each side maintains its own recorder
    pub trace_dropped: u64,
}

impl NetCounters {
    /// Machine-readable form (embedded in `BENCH_net.json` and run
    /// summaries).
    pub fn summary_json(&self) -> Json {
        obj(vec![
            ("sent", num(self.sent as f64)),
            ("delivered", num(self.delivered as f64)),
            ("dropped_loss", num(self.dropped_loss as f64)),
            ("dropped_partition", num(self.dropped_partition as f64)),
            ("dropped_dead", num(self.dropped_dead as f64)),
            ("duplicated", num(self.duplicated as f64)),
            ("stale_reads", num(self.stale_reads as f64)),
            ("fallback_reads", num(self.fallback_reads as f64)),
            ("timeouts", num(self.timeouts as f64)),
            ("joins", num(self.joins as f64)),
            ("leaves", num(self.leaves as f64)),
            ("edges_deactivated", num(self.edges_deactivated as f64)),
            ("edges_reactivated", num(self.edges_reactivated as f64)),
            ("collective_timeouts", num(self.collective_timeouts as f64)),
            ("collective_fallbacks", num(self.collective_fallbacks as f64)),
            ("collective_retries", num(self.collective_retries as f64)),
            ("gossip_ticks", num(self.gossip_ticks as f64)),
            ("overlap_dispatches", num(self.overlap_dispatches as f64)),
            ("trace_dropped", num(self.trace_dropped as f64)),
        ])
    }

    /// Total messages lost to any cause (loss + partition + dead dst).
    pub fn dropped_total(&self) -> u64 {
        self.dropped_loss + self.dropped_partition + self.dropped_dead
    }
}

/// Records per-iteration curves for one run.
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    pub stats: Vec<IterStats>,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Recorder with the stats buffer pre-sized for a known iteration
    /// budget, so hot-loop `push`es never reallocate. The pre-size is
    /// capped so "run until tol" sentinels (`max_iters: usize::MAX`)
    /// don't eagerly allocate or overflow; past the cap, pushes fall
    /// back to amortized growth.
    pub fn with_capacity(iters: usize) -> Self {
        Recorder { stats: Vec::with_capacity(iters.min(1 << 16)) }
    }

    pub fn push(&mut self, s: IterStats) {
        self.stats.push(s);
    }

    pub fn iterations(&self) -> usize {
        self.stats.len()
    }

    /// The app-error series (the paper's plotted curves).
    pub fn error_curve(&self) -> Vec<f64> {
        self.stats.iter().map(|s| s.app_error).collect()
    }

    pub fn objective_curve(&self) -> Vec<f64> {
        self.stats.iter().map(|s| s.objective).collect()
    }

    /// Final recorded app error.
    pub fn final_error(&self) -> f64 {
        self.stats.last().map(|s| s.app_error).unwrap_or(f64::NAN)
    }

    /// Compact machine-readable run summary (consumed by the bench JSON
    /// reports; curves stay in CSV via [`Recorder::write_csv`]). The
    /// `final_*` fields are omitted for an empty recorder (NaN is not
    /// representable in JSON).
    pub fn summary_json(&self) -> Json {
        let mut fields = vec![("iterations", num(self.stats.len() as f64))];
        if let Some(last) = self.stats.last() {
            fields.push(("final_objective", num(last.objective)));
            fields.push(("final_max_primal", num(last.max_primal)));
            fields.push(("final_max_dual", num(last.max_dual)));
            fields.push(("final_mean_eta", num(last.mean_eta)));
        }
        obj(fields)
    }

    /// Dump the full run as CSV.
    pub fn write_csv(&self, path: &std::path::Path) -> Result<()> {
        let mut w = CsvWriter::create(path, &[
            "iter", "objective", "max_primal", "max_dual",
            "mean_eta", "min_eta", "max_eta", "app_error",
        ])?;
        for s in &self.stats {
            w.row(&[
                s.iter.to_string(), fnum(s.objective), fnum(s.max_primal),
                fnum(s.max_dual), fnum(s.mean_eta), fnum(s.min_eta),
                fnum(s.max_eta), fnum(s.app_error),
            ])?;
        }
        w.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_on_plateau() {
        let mut c = ConvergenceChecker::new(1e-3);
        assert!(!c.update(100.0));
        assert!(!c.update(50.0));
        assert!(!c.update(49.9)); // warmup consumed, rel ≈ 2e-3 ≥ tol
        assert!(c.update(49.899)); // rel ≈ 2e-5 < tol
    }

    #[test]
    fn patience_requires_streak() {
        let mut c = ConvergenceChecker::new(1e-3).with_patience(2).with_warmup(0);
        assert!(!c.update(1.0));
        assert!(!c.update(1.0)); // first under-tol iteration
        assert!(c.update(1.0)); // second → converged
    }

    #[test]
    fn streak_resets_on_spike() {
        let mut c = ConvergenceChecker::new(1e-3).with_patience(2).with_warmup(0);
        c.update(1.0);
        c.update(1.0);
        assert!(!c.update(2.0)); // spike resets
        assert!(!c.update(2.0));
        assert!(c.update(2.0));
    }

    #[test]
    fn warmup_blocks_early_convergence() {
        let mut c = ConvergenceChecker::new(1e-1).with_warmup(5);
        for _ in 0..5 {
            assert!(!c.update(1.0));
        }
        assert!(c.update(1.0));
    }

    #[test]
    fn recorder_curves() {
        let mut r = Recorder::new();
        for i in 0..3 {
            r.push(IterStats { iter: i, app_error: i as f64, ..Default::default() });
        }
        assert_eq!(r.error_curve(), vec![0.0, 1.0, 2.0]);
        assert_eq!(r.final_error(), 2.0);
        assert_eq!(r.iterations(), 3);
    }

    #[test]
    fn recorder_summary_json_shape() {
        let mut r = Recorder::new();
        r.push(IterStats { iter: 0, objective: 2.0, max_primal: 0.5,
                           ..Default::default() });
        r.push(IterStats { iter: 1, objective: 1.0, max_primal: 0.25,
                           ..Default::default() });
        let j = r.summary_json();
        assert_eq!(j.get("iterations").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("final_objective").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("final_max_primal").unwrap().as_f64(), Some(0.25));
    }

    #[test]
    fn net_counters_json_and_totals() {
        let c = NetCounters {
            sent: 10,
            delivered: 6,
            dropped_loss: 2,
            dropped_partition: 1,
            dropped_dead: 1,
            ..Default::default()
        };
        assert_eq!(c.dropped_total(), 4);
        let j = c.summary_json();
        assert_eq!(j.get("sent").unwrap().as_usize(), Some(10));
        assert_eq!(j.get("dropped_loss").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("timeouts").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn running_fold_matches_flat_statistics() {
        // two partials over a 5-point scalar dataset: the Chan combination
        // must reproduce the flat mean and spread to fp accuracy
        let data = [1.0f64, 4.0, -2.0, 8.0, 0.5];
        let mut parts = Vec::new();
        for chunk in [&data[..2], &data[2..]] {
            let mut p = StatPartial::new(1);
            let mean: f64 = chunk.iter().sum::<f64>() / chunk.len() as f64;
            p.theta_sum[0] = chunk.iter().sum();
            p.node_count = chunk.len();
            p.centered_sq = chunk.iter().map(|x| (x - mean) * (x - mean)).sum();
            p.f_sum = 1.0;
            p.eta_min = 2.0;
            p.eta_max = 3.0;
            p.eta_sum = 5.0;
            p.eta_count = 2;
            parts.push(p);
        }
        let mut fold = RunningFold::new(1);
        for p in &parts {
            fold.absorb(p);
        }
        let flat_mean: f64 = data.iter().sum::<f64>() / data.len() as f64;
        let flat_sq: f64 = data.iter().map(|x| (x - flat_mean) * (x - flat_mean)).sum();
        assert_eq!(fold.agg_n, 5);
        assert!((fold.gmean[0] - flat_mean).abs() < 1e-12);
        assert!((fold.gr2 - flat_sq).abs() < 1e-9);
        assert_eq!(fold.objective, 2.0);
        assert_eq!(fold.mean_eta(), 2.5);
        assert_eq!(fold.min_eta(), 2.0);
        // empty partials are absorbed without touching the mean state
        fold.absorb(&StatPartial::new(1));
        assert_eq!(fold.agg_n, 5);
    }

    #[test]
    fn recorder_csv_roundtrip() {
        let dir = std::env::temp_dir().join("fadmm_rec_test");
        let path = dir.join("run.csv");
        let mut r = Recorder::new();
        r.push(IterStats { iter: 0, objective: 1.5, ..Default::default() });
        r.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("iter,objective"));
        assert_eq!(text.lines().count(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
