//! Machine-level partitioning: contiguous node slices and the machine
//! quotient graph.
//!
//! The cluster runtime splits the (relabeled) node graph into `M`
//! contiguous id ranges with the same degree-weighted splitter the
//! worker pool uses for shards ([`crate::graph::shard_ranges`]) — with
//! RCM relabeling on (the default), neighbours carry nearby ids, so the
//! contiguous machine slices are also *locality-aware*: most edges stay
//! machine-internal and the boundary surface the simulated network has
//! to carry is small.
//!
//! The **quotient graph** has one vertex per machine and an edge wherever
//! any node edge crosses the cut. It is the topology of everything
//! machine-level: boundary-exchange links, the collective spanning tree /
//! gossip links, the machine [`crate::graph::LiveView`] that scripted
//! churn and the NAP activity rule mutate, and the id space of the
//! machine-level [`crate::net::FaultPlan`].

use std::ops::Range;

use crate::error::{Error, Result};
use crate::graph::{rcm_order, rcm_order_in, relabel_graph, shard_ranges, Graph,
                   NodeId};

/// A machine partition of a node graph (see module docs).
#[derive(Debug, Clone)]
pub struct MachinePartition {
    /// `ranges[m]` — machine m's contiguous slice of (relabeled) node ids,
    /// ascending and exhaustive.
    pub ranges: Vec<Range<usize>>,
    /// `machine_of[node] = m` (relabeled ids).
    pub machine_of: Vec<usize>,
    /// Machine quotient graph: machines adjacent iff a node edge crosses.
    pub quotient: Graph,
}

impl MachinePartition {
    /// Partition `graph` into at most `machines` contiguous slices.
    pub fn new(graph: &Graph, machines: usize) -> Result<MachinePartition> {
        MachinePartition::from_ranges(graph, shard_ranges(graph, machines))
    }

    /// Build a partition from an explicit set of contiguous ranges (the
    /// hierarchical path hands the splitter's output back in after
    /// reordering nodes *within* each range). Ranges must be ascending,
    /// non-empty, and cover `0..graph.len()` exactly.
    pub fn from_ranges(graph: &Graph, ranges: Vec<Range<usize>>)
                       -> Result<MachinePartition> {
        let mut expect = 0usize;
        for r in &ranges {
            if r.start != expect || r.end <= r.start {
                return Err(Error::Config(format!(
                    "partition: range {r:?} breaks contiguous coverage at {expect}")));
            }
            expect = r.end;
        }
        if expect != graph.len() {
            return Err(Error::Config(format!(
                "partition: ranges cover 0..{expect}, graph has {} nodes",
                graph.len())));
        }
        let m = ranges.len();
        let mut machine_of = vec![0usize; graph.len()];
        for (mid, r) in ranges.iter().enumerate() {
            for i in r.clone() {
                machine_of[i] = mid;
            }
        }
        let mut edges: Vec<(NodeId, NodeId)> = Vec::new();
        for (i, j) in graph.directed_edges() {
            let (a, b) = (machine_of[i], machine_of[j]);
            if a < b {
                edges.push((a, b));
            }
        }
        edges.sort_unstable();
        edges.dedup();
        let quotient = Graph::new(m, &edges)?;
        Ok(MachinePartition { ranges, machine_of, quotient })
    }

    /// Number of machines actually created (≤ the requested count).
    pub fn len(&self) -> usize {
        self.ranges.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }
}

/// Two-level hierarchical ordering — the documented construction path
/// for 10^6-node cluster runs.
///
/// Level one is global RCM (cross-machine locality: the contiguous
/// machine split cuts few edges), level two re-runs RCM *inside each
/// machine's range* ([`rcm_order_in`]) so every machine's in-range
/// neighbourhoods are also bandwidth-minimized — that is what keeps each
/// per-machine worker pool's arena reads dense once the machine shards
/// its own slice with `shard_ranges_in`. Local reordering permutes ids
/// only within their range, so the machine ranges (and the quotient
/// graph) are exactly the level-one split.
///
/// Returns `order[new_id] = original_id` over the whole graph plus the
/// machine ranges in new-id space. Compose with [`relabel_graph`] and
/// [`MachinePartition::from_ranges`] — or call
/// [`hierarchical_partition`], which does all three.
///
/// At `machines = 1` the result degenerates to a flat double-RCM pass
/// (level two sees the full span), so the hierarchy adds nothing on one
/// machine — by construction, not by special case.
pub fn hierarchical_order(graph: &Graph, machines: usize)
                          -> Result<(Vec<NodeId>, Vec<Range<usize>>)> {
    let global = rcm_order(graph);
    let relabeled = relabel_graph(graph, &global)?;
    let ranges = shard_ranges(&relabeled, machines);
    let mut order = Vec::with_capacity(graph.len());
    for r in &ranges {
        for &local in rcm_order_in(&relabeled, r.clone()).iter() {
            order.push(global[local]);
        }
    }
    Ok((order, ranges))
}

/// [`hierarchical_order`] + relabel + partition in one call: the graph a
/// cluster run should execute on, the permutation back to the caller's
/// ids (`order[new_id] = original_id`), and the machine partition.
pub fn hierarchical_partition(graph: &Graph, machines: usize)
                              -> Result<(Graph, Vec<NodeId>, MachinePartition)> {
    let (order, ranges) = hierarchical_order(graph, machines)?;
    let relabeled = relabel_graph(graph, &order)?;
    let partition = MachinePartition::from_ranges(&relabeled, ranges)?;
    Ok((relabeled, order, partition))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;

    #[test]
    fn single_machine_covers_everything() {
        let g = Topology::Ring.build(9).unwrap();
        let p = MachinePartition::new(&g, 1).unwrap();
        assert_eq!(p.ranges, vec![0..9]);
        assert_eq!(p.quotient.len(), 1);
        assert_eq!(p.quotient.edge_count(), 0);
        assert!(p.machine_of.iter().all(|&m| m == 0));
    }

    #[test]
    fn ring_quotient_is_a_ring_of_machines() {
        let g = Topology::Ring.build(12).unwrap();
        let p = MachinePartition::new(&g, 4).unwrap();
        assert_eq!(p.len(), 4);
        // contiguous + exhaustive
        let mut expect = 0;
        for r in &p.ranges {
            assert_eq!(r.start, expect);
            expect = r.end;
        }
        assert_eq!(expect, 12);
        // each machine borders its two neighbouring slices (wrap included)
        assert_eq!(p.quotient.len(), 4);
        assert!(p.quotient.edge_slot(0, 1).is_some());
        assert!(p.quotient.edge_slot(0, 3).is_some(), "ring wraps");
        assert!(p.quotient.edge_slot(0, 2).is_none());
        assert!(p.quotient.is_connected());
    }

    #[test]
    fn more_machines_than_nodes_clamps() {
        let g = Topology::Chain.build(3).unwrap();
        let p = MachinePartition::new(&g, 10).unwrap();
        assert_eq!(p.len(), 3);
        assert_eq!(p.quotient.len(), 3);
    }

    #[test]
    fn machine_ranges_match_shard_ranges() {
        // the machine split IS the worker-pool splitter at machine count
        let g = Topology::Star.build(21).unwrap();
        let p = MachinePartition::new(&g, 3).unwrap();
        assert_eq!(p.ranges, shard_ranges(&g, 3));
    }

    #[test]
    fn from_ranges_rejects_bad_coverage() {
        let g = Topology::Ring.build(8).unwrap();
        // gap
        assert!(MachinePartition::from_ranges(&g, vec![0..3, 4..8]).is_err());
        // overlap
        assert!(MachinePartition::from_ranges(&g, vec![0..5, 4..8]).is_err());
        // empty range
        assert!(MachinePartition::from_ranges(&g, vec![0..4, 4..4, 4..8]).is_err());
        // short coverage
        assert!(MachinePartition::from_ranges(&g, vec![0..7]).is_err());
        // exact coverage is fine and matches the direct constructor
        let p = MachinePartition::from_ranges(&g, vec![0..4, 4..8]).unwrap();
        let q = MachinePartition::new(&g, 2).unwrap();
        assert_eq!(p.ranges, q.ranges);
        assert_eq!(p.machine_of, q.machine_of);
        assert_eq!(p.quotient.edge_count(), q.quotient.edge_count());
    }

    /// A ring whose ids were deliberately scrambled: the two-level path
    /// must (a) return a true permutation, (b) keep the level-one machine
    /// ranges, and (c) recover ring-like machine locality — each machine
    /// borders at most its two neighbours, instead of the near-complete
    /// quotient the scrambled labels would produce.
    #[test]
    fn hierarchical_partition_recovers_ring_locality() {
        use crate::graph::{bandwidth, relabel_graph};
        let ring = Topology::Ring.build(40).unwrap();
        // stride-scramble: new id i held original node (i * 17) % 40
        let scramble: Vec<usize> = (0..40).map(|i| (i * 17) % 40).collect();
        let g = relabel_graph(&ring, &scramble).unwrap();
        assert!(bandwidth(&g) > 10, "scramble must actually destroy locality");

        let (relabeled, order, part) = hierarchical_partition(&g, 4).unwrap();

        // (a) permutation over 0..40
        let mut seen = order.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..40).collect::<Vec<_>>());
        // structure is preserved: still a connected 2-regular ring
        assert_eq!(relabeled.len(), 40);
        assert_eq!(relabeled.edge_count(), g.edge_count());
        assert!(relabeled.is_connected());
        assert!((0..40).all(|i| relabeled.degree(i) == 2));

        // (b) ranges are the level-one split of the level-one relabeling
        let (order2, ranges2) = hierarchical_order(&g, 4).unwrap();
        assert_eq!(order2, order, "construction is deterministic");
        assert_eq!(part.ranges, ranges2);

        // (c) locality: each machine borders ≤ 2 others, and the
        // node-level bandwidth collapsed versus the scrambled labels
        assert_eq!(part.len(), 4);
        assert!((0..4).all(|m| part.quotient.degree(m) <= 2));
        assert!(bandwidth(&relabeled) < bandwidth(&g));
    }

    /// One machine degenerates to a flat RCM pass: same range set as the
    /// direct constructor and a valid permutation — no special-casing.
    #[test]
    fn hierarchical_single_machine_is_flat() {
        let g = Topology::Star.build(9).unwrap();
        let (relabeled, order, part) = hierarchical_partition(&g, 1).unwrap();
        assert_eq!(part.ranges, vec![0..9]);
        assert_eq!(part.quotient.len(), 1);
        let mut seen = order.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..9).collect::<Vec<_>>());
        assert_eq!(relabeled.edge_count(), g.edge_count());
    }

    /// Power-law graphs exercise the degree-skew shard cap underneath the
    /// hierarchy: the partition must still be contiguous/exhaustive and
    /// the quotient connected whenever the node graph is.
    #[test]
    fn hierarchical_partition_handles_power_law() {
        use crate::graph::power_law;
        use crate::util::rng::Pcg;
        let mut rng = Pcg::new(7, 7);
        let g = power_law(300, 2, &mut rng).unwrap();
        let (relabeled, order, part) = hierarchical_partition(&g, 8).unwrap();
        let mut expect = 0;
        for r in &part.ranges {
            assert_eq!(r.start, expect);
            assert!(r.end > r.start);
            expect = r.end;
        }
        assert_eq!(expect, 300);
        assert!(part.len() >= 2 && part.len() <= 8);
        assert!(relabeled.is_connected());
        assert!(part.quotient.is_connected());
        let mut seen = order;
        seen.sort_unstable();
        assert_eq!(seen, (0..300).collect::<Vec<_>>());
    }
}
