//! Machine-level partitioning: contiguous node slices and the machine
//! quotient graph.
//!
//! The cluster runtime splits the (relabeled) node graph into `M`
//! contiguous id ranges with the same degree-weighted splitter the
//! worker pool uses for shards ([`crate::graph::shard_ranges`]) — with
//! RCM relabeling on (the default), neighbours carry nearby ids, so the
//! contiguous machine slices are also *locality-aware*: most edges stay
//! machine-internal and the boundary surface the simulated network has
//! to carry is small.
//!
//! The **quotient graph** has one vertex per machine and an edge wherever
//! any node edge crosses the cut. It is the topology of everything
//! machine-level: boundary-exchange links, the collective spanning tree /
//! gossip links, the machine [`crate::graph::LiveView`] that scripted
//! churn and the NAP activity rule mutate, and the id space of the
//! machine-level [`crate::net::FaultPlan`].

use std::ops::Range;

use crate::error::Result;
use crate::graph::{shard_ranges, Graph, NodeId};

/// A machine partition of a node graph (see module docs).
#[derive(Debug, Clone)]
pub struct MachinePartition {
    /// `ranges[m]` — machine m's contiguous slice of (relabeled) node ids,
    /// ascending and exhaustive.
    pub ranges: Vec<Range<usize>>,
    /// `machine_of[node] = m` (relabeled ids).
    pub machine_of: Vec<usize>,
    /// Machine quotient graph: machines adjacent iff a node edge crosses.
    pub quotient: Graph,
}

impl MachinePartition {
    /// Partition `graph` into at most `machines` contiguous slices.
    pub fn new(graph: &Graph, machines: usize) -> Result<MachinePartition> {
        let ranges = shard_ranges(graph, machines);
        let m = ranges.len();
        let mut machine_of = vec![0usize; graph.len()];
        for (mid, r) in ranges.iter().enumerate() {
            for i in r.clone() {
                machine_of[i] = mid;
            }
        }
        let mut edges: Vec<(NodeId, NodeId)> = Vec::new();
        for (i, j) in graph.directed_edges() {
            let (a, b) = (machine_of[i], machine_of[j]);
            if a < b {
                edges.push((a, b));
            }
        }
        edges.sort_unstable();
        edges.dedup();
        let quotient = Graph::new(m, &edges)?;
        Ok(MachinePartition { ranges, machine_of, quotient })
    }

    /// Number of machines actually created (≤ the requested count).
    pub fn len(&self) -> usize {
        self.ranges.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ranges.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;

    #[test]
    fn single_machine_covers_everything() {
        let g = Topology::Ring.build(9).unwrap();
        let p = MachinePartition::new(&g, 1).unwrap();
        assert_eq!(p.ranges, vec![0..9]);
        assert_eq!(p.quotient.len(), 1);
        assert_eq!(p.quotient.edge_count(), 0);
        assert!(p.machine_of.iter().all(|&m| m == 0));
    }

    #[test]
    fn ring_quotient_is_a_ring_of_machines() {
        let g = Topology::Ring.build(12).unwrap();
        let p = MachinePartition::new(&g, 4).unwrap();
        assert_eq!(p.len(), 4);
        // contiguous + exhaustive
        let mut expect = 0;
        for r in &p.ranges {
            assert_eq!(r.start, expect);
            expect = r.end;
        }
        assert_eq!(expect, 12);
        // each machine borders its two neighbouring slices (wrap included)
        assert_eq!(p.quotient.len(), 4);
        assert!(p.quotient.edge_slot(0, 1).is_some());
        assert!(p.quotient.edge_slot(0, 3).is_some(), "ring wraps");
        assert!(p.quotient.edge_slot(0, 2).is_none());
        assert!(p.quotient.is_connected());
    }

    #[test]
    fn more_machines_than_nodes_clamps() {
        let g = Topology::Chain.build(3).unwrap();
        let p = MachinePartition::new(&g, 10).unwrap();
        assert_eq!(p.len(), 3);
        assert_eq!(p.quotient.len(), 3);
    }

    #[test]
    fn machine_ranges_match_shard_ranges() {
        // the machine split IS the worker-pool splitter at machine count
        let g = Topology::Star.build(21).unwrap();
        let p = MachinePartition::new(&g, 3).unwrap();
        assert_eq!(p.ranges, shard_ranges(&g, 3));
    }
}
