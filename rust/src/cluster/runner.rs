//! The cluster driver: a single-threaded discrete-event loop over the
//! machine-level transport.
//!
//! Each machine is a state machine (`Solve → Reduce → FoldWait → …`)
//! advanced by message arrivals and timers popped from the shared
//! [`Transport`] queue, exactly like the per-node
//! [`crate::net::AsyncRunner`] — but one step of a machine executes a
//! whole barrier-synchronous worker-pool iteration over its local node
//! slice ([`super::machine`]), and the global fold travels through the
//! chosen collective ([`super::collective`]) instead of an omniscient
//! oracle. The runner is generic over the transport seam
//! ([`crate::net::Transport`]); [`ClusterRunner::new`] instantiates it
//! over the deterministic [`NetSim`], which is the configuration every
//! parity suite pins. The real transports drive the same protocol one
//! machine per thread/process through [`super::node::NodeRuntime`].
//! See the [`super`] module docs for the full protocol and the parity
//! contracts.

use std::sync::Arc;

use crate::consensus::LocalSolver;
use crate::coordinator::SolverFactory;
use crate::error::{Error, Result};
use crate::graph::{rcm_order, relabel_graph, Graph, NodeId, Relabel};
use crate::kernel::{AppMetricHook, StopTracker};
use crate::metrics::{IterStats, NetCounters, Recorder, RunningFold, StatPartial};
use crate::net::sim::{Event, FaultPlan, NetSim, Payload, Ticks, TimerKind,
                      TraceEvent, TraceKind};
use crate::net::transport::{send_traced, Transport};
use crate::net::{ActivityConfig, TopologyController};
use crate::obs::{Phase, RoundRow};
use crate::penalty::{SchemeKind, SchemeParams};
use crate::pool::{ExecMode, PhasePool, Ticket};

use super::collective::{build_tree_rooted, estimate, subtree, CollectiveKind,
                        GossipState, TreeState, MASS_COUNT, MASS_ETA,
                        MASS_ETA_CNT, MASS_F, MASS_ONE, MASS_SQ, MASS_THETA};
use super::machine::{MPhase, MachineRt};
use super::partition::MachinePartition;

/// Cluster-run configuration (mirrors [`crate::coordinator::ShardedConfig`]
/// plus the machine/network/collective knobs).
#[derive(Debug, Clone, Copy)]
pub struct ClusterConfig {
    pub scheme: SchemeKind,
    pub params: SchemeParams,
    pub tol: f64,
    pub patience: usize,
    pub warmup: usize,
    pub max_iters: usize,
    pub seed: u64,
    /// Simulated machine count (clamped to the node count).
    pub machines: usize,
    /// Worker-pool size per machine; 0 resolves to
    /// `min(local nodes, available_parallelism)` like the sharded runner.
    pub workers: usize,
    /// Node-relabeling policy applied before the machine split (default
    /// RCM — locality-aware machine slices, small boundary surface).
    pub relabel: Relabel,
    /// Which reduction layer replaces the oracle fold.
    pub collective: CollectiveKind,
    /// Boundary-read staleness budget in rounds (0 = lock-step).
    pub max_staleness: u64,
    /// Silent-neighbour fallback timeout in ticks (0 = pure blocking).
    pub silence_timeout: Ticks,
    /// Collective patience in ticks before forwarding/folding without
    /// stragglers and before retransmitting (0 = pure blocking).
    pub collective_timeout: Ticks,
    /// Retransmits before a machine substitutes a local fallback verdict.
    pub fallback_after: u32,
    /// Rounds a machine may run ahead of its verdict horizon.
    pub pipeline: u64,
    /// Push-sum exchange ticks per round (0 = auto: 4⌈log₂M⌉+4, min 8 —
    /// see [`super::collective`] for the measured accuracy rationale).
    pub gossip_ticks: u32,
    /// Virtual ticks between push-sum exchanges.
    pub gossip_spacing: Ticks,
    /// Machine-level NAP activity rule over the quotient graph.
    pub activity: Option<ActivityConfig>,
    /// Scripted leader handoff (tree collective): after the root commits
    /// round `.0`, re-root the tree at machine `.1` and ship the
    /// [`crate::kernel::StopSnapshot`] there over the network — the
    /// leader-election drill the handoff regression test runs with
    /// faults off (churn-driven handoffs need no script: a departing
    /// root always serializes to its successor).
    pub handoff: Option<(u64, usize)>,
    pub tracing: bool,
    /// Flight-recorder capacity when tracing (0 = keep nothing, count
    /// every event as dropped).
    pub trace_capacity: usize,
    /// enable phase-span timing ([`crate::obs`]); counters/gauges are
    /// always recorded
    pub obs: bool,
    /// record the causal round timeline ([`crate::obs::Timeline`]):
    /// per-frame send/recv events with [`crate::obs::TraceCtx`], phase
    /// attributions and round commits — the feed for the Chrome trace
    /// export and the critical-path analysis
    pub timeline: bool,
    /// record the per-round convergence series
    /// ([`crate::obs::RoundSeries`]): one row per committed round with
    /// the committed [`IterStats`] verbatim plus live node/edge counts
    /// and the round's phase durations
    pub series: bool,
    /// How per-phase shard jobs execute: the persistent [`PhasePool`]
    /// (default; also enables interior/boundary phase-A overlap while
    /// boundary batches are in flight) or seed-style scoped spawns (the
    /// bit-parity baseline).
    pub exec: ExecMode,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            scheme: SchemeKind::Fixed,
            params: SchemeParams::default(),
            tol: 1e-3,
            patience: 3,
            warmup: 5,
            max_iters: 1000,
            seed: 0,
            machines: 2,
            workers: 0,
            relabel: Relabel::default(),
            collective: CollectiveKind::Tree,
            max_staleness: 0,
            silence_timeout: 64,
            collective_timeout: 128,
            fallback_after: 3,
            pipeline: 2,
            gossip_ticks: 0,
            gossip_spacing: 4,
            activity: None,
            handoff: None,
            tracing: true,
            trace_capacity: crate::obs::DEFAULT_TRACE_CAPACITY,
            obs: false,
            timeline: false,
            series: false,
            exec: ExecMode::Pool,
        }
    }
}

/// Outcome of a cluster run.
#[derive(Debug)]
pub struct ClusterReport {
    /// Completed collective rounds recorded by the designated machine.
    pub iterations: usize,
    pub converged: bool,
    pub recorder: Recorder,
    /// Final per-node parameters at the stop round (original node ids).
    pub thetas: Vec<Vec<f64>>,
    pub virtual_time: Ticks,
    pub counters: NetCounters,
    pub trace: Vec<TraceEvent>,
    pub machines: usize,
    pub live_machines: Vec<bool>,
    /// Resolved per-machine worker-pool target.
    pub workers_per_machine: usize,
    /// unified telemetry ([`crate::obs`]): per-phase histograms (when
    /// `cfg.obs`), absorbed net counters and trace retention stats
    pub obs: crate::obs::MetricsRegistry,
    /// causal round timeline (empty unless `cfg.timeline` or the global
    /// timeline sink was enabled); feed for [`crate::obs::chrome`] and
    /// [`crate::obs::critical_path`]
    pub timeline: Vec<crate::obs::TlEvent>,
    /// events the bounded timeline ring overwrote
    pub timeline_dropped: u64,
    /// per-round convergence series (empty unless `cfg.series` or the
    /// global series sink was enabled)
    pub series: Vec<crate::obs::RoundRow>,
    /// rows the series decimation dropped
    pub series_dropped: u64,
}

/// Designated-recorder state: the shared [`StopTracker`] (checker +
/// recorder + verdict memory) lives with the tree root (tree) or the
/// lowest live machine (gossip). Either way its location is *protocol
/// state*: `holder` names the machine carrying it, and on a re-root or
/// a holder departure the old holder serializes a
/// [`crate::kernel::StopSnapshot`] into a reliable `Checker` message
/// the successor resumes from — the tree root refuses to fold while
/// the state is in flight, and a gossip holder skips its commits (the
/// catch-up replay commits them once the snapshot lands). The
/// simulator halts the run the moment the stop decision is computed —
/// the broadcast a real deployment would need costs zero extra rounds
/// here, exactly like the async runner's `Stop` handling; the real
/// transports run that broadcast as an explicit [`Payload::Stop`]
/// flood.
struct RootState {
    cursor: u64,
    tracker: StopTracker,
    /// machine currently holding the tracker (tree collective)
    holder: usize,
    /// a serialized tracker is in flight to this machine
    in_flight_to: Option<usize>,
}

enum Coll {
    Tree(TreeState),
    Gossip(GossipState),
}

/// The hybrid cluster runner (see [`super`] and the module docs),
/// generic over the machine-level transport (default: the simulator).
pub struct ClusterRunner<S: LocalSolver + Send, T: Transport = NetSim> {
    /// Outstanding overlapped interior-dispatch tickets, one slot per
    /// machine. Declared *first*: a [`Ticket`]'s `Drop` blocks until its
    /// jobs finish, and fields drop in declaration order, so even on an
    /// unwind the jobs complete before `machines`/`graph` (whose buffers
    /// they point into) are freed.
    overlap: Vec<Option<(u64, Ticket)>>,
    /// Persistent per-run worker pool shared by every machine (sized to
    /// the widest machine's shard count; machines run their phases one
    /// at a time under the single-threaded driver).
    pool: PhasePool,
    cfg: ClusterConfig,
    /// relabeled node graph
    graph: Graph,
    /// `order[new] = orig` relabeling permutation
    order: Vec<NodeId>,
    part: MachinePartition,
    ctrl: TopologyController,
    sim: T,
    machines: Vec<MachineRt<S>>,
    coll: Coll,
    fold: RootState,
    /// preferred tree root (set by the scripted handoff; cleared if dead)
    root_prefer: Option<usize>,
    /// unified app-metric hook, run by the designated recorder per commit
    metric: Option<Box<dyn AppMetricHook>>,
    /// reusable app-metric snapshot buffers (original-id keyed)
    metric_thetas: Vec<Vec<f64>>,
    metric_live: Vec<bool>,
    pending_wakes: Vec<usize>,
    stopped: bool,
    stop_round: Option<u64>,
    dim: usize,
    n_total: usize,
    workers_used: usize,
    /// unified telemetry: registered at construction, recorded via
    /// `Copy` ids on the hot path (clock reads only when `cfg.obs`)
    obs: crate::obs::MetricsRegistry,
    probes: crate::obs::RuntimeProbes,
    /// causal round timeline (no-op unless enabled; `at` stamps come
    /// from the transport clock, durations from the `obs` span ends —
    /// the timeline itself never reads a wall clock)
    timeline: crate::obs::Timeline,
    /// per-round convergence series (no-op unless enabled)
    series: crate::obs::RoundSeries,
}

impl<S: LocalSolver + Send> ClusterRunner<S, NetSim> {
    /// Build a runner over the deterministic simulator. Solver
    /// construction and θ⁰ seeding are keyed by *original* node ids
    /// through the factory, exactly like
    /// [`crate::coordinator::ShardedRunner`].
    pub fn new(graph: Graph, cfg: ClusterConfig, plan: FaultPlan,
               factory: SolverFactory<S>) -> Result<ClusterRunner<S>> {
        let n = graph.len();
        if n == 0 {
            return Err(Error::Config("cluster: empty graph".into()));
        }
        let dim = factory(0).dim();

        let order: Vec<NodeId> = match cfg.relabel {
            Relabel::Identity => (0..n).collect(),
            Relabel::Rcm => rcm_order(&graph),
        };
        let relabeled = match cfg.relabel {
            Relabel::Identity => graph,
            Relabel::Rcm => relabel_graph(&graph, &order)?,
        };
        let part = MachinePartition::new(&relabeled, cfg.machines.max(1))?;
        let mcount = part.len();

        for ev in &plan.churn {
            let m = match *ev {
                crate::net::ChurnEvent::Join { node, .. }
                | crate::net::ChurnEvent::Leave { node, .. } => node,
            };
            if m >= mcount {
                return Err(Error::Config(format!(
                    "cluster: churn event on machine {m} out of range (machines: {mcount})"
                )));
            }
        }
        if let Some(&d) = plan.initially_dormant.iter().find(|&&d| d >= mcount) {
            return Err(Error::Config(format!(
                "cluster: dormant machine {d} out of range (machines: {mcount})"
            )));
        }

        let mut ctrl = TopologyController::new(part.quotient.clone(), cfg.activity);
        for &m in &plan.initially_dormant {
            ctrl.view_mut().set_node(m, false);
        }

        let workers = if cfg.workers > 0 {
            cfg.workers
        } else {
            std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
        };

        let machines: Vec<MachineRt<S>> = (0..mcount)
            .map(|m| {
                MachineRt::build(
                    &relabeled, &part, m, workers, &order, &*factory, dim,
                    cfg.scheme, cfg.params, cfg.seed,
                    plan.initially_dormant.contains(&m), cfg.max_iters,
                )
            })
            .collect();

        let coll = match cfg.collective {
            CollectiveKind::Tree => Coll::Tree(TreeState::new(ctrl.view())),
            CollectiveKind::Gossip => {
                let ticks = if cfg.gossip_ticks > 0 {
                    cfg.gossip_ticks
                } else {
                    GossipState::auto_ticks(mcount)
                };
                Coll::Gossip(GossipState::new(mcount, dim, ticks,
                                              cfg.gossip_spacing.max(1)))
            }
        };

        let mut sim = NetSim::new(cfg.seed, plan, cfg.tracing);
        if cfg.tracing {
            sim.set_trace_capacity(cfg.trace_capacity);
        }
        let initial_root =
            (0..mcount).find(|&m| ctrl.view().node_live(m)).unwrap_or(0);
        let pool = PhasePool::new(
            machines.iter().map(|mm| mm.shards.len()).max().unwrap_or(1),
        );
        let mut obs = crate::obs::MetricsRegistry::new(
            cfg.obs || crate::obs::global_spans_enabled(),
        );
        let probes = crate::obs::RuntimeProbes::register(&mut obs);
        let timeline = crate::obs::Timeline::new(
            cfg.timeline || crate::obs::global_timeline_enabled(),
        );
        let series = crate::obs::RoundSeries::new(
            cfg.series || crate::obs::global_series_enabled(),
        );
        Ok(ClusterRunner {
            obs,
            probes,
            timeline,
            series,
            overlap: (0..mcount).map(|_| None).collect(),
            pool,
            fold: RootState {
                cursor: 0,
                tracker: StopTracker::new(dim, cfg.tol, cfg.patience,
                                          cfg.warmup, cfg.max_iters,
                                          cfg.params.eta0),
                holder: initial_root,
                in_flight_to: None,
            },
            root_prefer: None,
            metric: None,
            metric_thetas: Vec::new(),
            metric_live: Vec::new(),
            pending_wakes: Vec::new(),
            stopped: false,
            stop_round: None,
            dim,
            n_total: n,
            workers_used: workers,
            graph: relabeled,
            order,
            part,
            ctrl,
            sim,
            machines,
            coll,
            cfg,
        })
    }
}

impl<S: LocalSolver + Send, T: Transport> ClusterRunner<S, T> {
    /// Attach an application-metric hook — the unified
    /// [`crate::kernel::AppMetricHook`] surface (any
    /// `FnMut(round, θ, live) -> f64` closure qualifies); its value lands
    /// in [`IterStats::app_error`] at every committed round. The θ
    /// snapshot hands each node's newest committed parameters (keyed by
    /// *original* node ids) with per-node liveness derived from machine
    /// liveness. Under the tree collective the snapshot travels *with*
    /// the rootward `Part` traffic (each machine attaches its committed
    /// θ^{r+1} span), so the recorder assembles it from delivered
    /// messages; only machines whose span never arrived (forced folds,
    /// stragglers) fall back to the omniscient driver-side read. Gossip
    /// keeps the older omniscient assembly.
    pub fn with_app_metric(
        mut self,
        metric: impl AppMetricHook + 'static,
    ) -> Self {
        self.metric = Some(Box::new(metric));
        self
    }

    /// Assemble the committed-θ snapshot + liveness for round `r` into
    /// the reusable buffers and run the hook (no-op 0.0 without one; the
    /// buffers allocate once, on the first committed round).
    fn app_metric_value(&mut self, r: u64) -> f64 {
        let Some(mut hook) = self.metric.take() else { return 0.0 };
        let n = self.graph.len();
        if self.metric_thetas.len() != n {
            self.metric_thetas = vec![vec![0.0; self.dim]; n];
            self.metric_live = vec![false; n];
        }
        for mach in &self.machines {
            let mach_live = self.ctrl.view().node_live(mach.id);
            mach.snapshot_read(r, self.dim, &self.order, &mut self.metric_thetas);
            for i in mach.span.clone() {
                self.metric_live[self.order[i]] = mach_live;
            }
        }
        let v = hook.measure(r as usize, &self.metric_thetas, &self.metric_live);
        self.metric = Some(hook);
        v
    }

    /// Tree-collective metric assembly: machines whose committed θ span
    /// arrived with the round's `Part` traffic are read from `shipped`
    /// (byte-identical clones of the same snapshots the omniscient read
    /// would return — pinned by the θ-ship parity test); the rest fall
    /// back to the driver-side snapshot read.
    fn app_metric_value_tree(&mut self, r: u64,
                             shipped: &std::collections::BTreeMap<usize, Vec<f64>>)
                             -> f64 {
        let Some(mut hook) = self.metric.take() else { return 0.0 };
        let n = self.graph.len();
        if self.metric_thetas.len() != n {
            self.metric_thetas = vec![vec![0.0; self.dim]; n];
            self.metric_live = vec![false; n];
        }
        let dim = self.dim;
        for mach in &self.machines {
            let mach_live = self.ctrl.view().node_live(mach.id);
            if let Some(flat) = shipped.get(&mach.id) {
                for (off, i) in mach.span.clone().enumerate() {
                    self.metric_thetas[self.order[i]]
                        .copy_from_slice(&flat[off * dim..(off + 1) * dim]);
                }
            } else {
                mach.snapshot_read(r, dim, &self.order, &mut self.metric_thetas);
            }
            for i in mach.span.clone() {
                self.metric_live[self.order[i]] = mach_live;
            }
        }
        let v = hook.measure(r as usize, &self.metric_thetas, &self.metric_live);
        self.metric = Some(hook);
        v
    }

    /// Drive the cluster to completion and report.
    pub fn run(mut self) -> ClusterReport {
        self.init_handshake();
        for m in 0..self.machines.len() {
            self.try_advance(m, false);
        }
        self.drain();

        while !self.stopped {
            let Some((at, event)) = self.sim.pop() else { break };
            // stale wake-ups/timers are skipped without advancing the
            // clock, so virtual time reflects real activity only
            match &event {
                Event::Wake { node, epoch } => {
                    let mach = &self.machines[*node];
                    if *epoch != mach.wake_epoch || !mach.running() {
                        continue;
                    }
                }
                Event::Timer { node, kind: TimerKind::Collective, epoch } => {
                    // Done machines still service collective timers — the
                    // tail rounds' retransmissions must outlive the
                    // machine's own round budget
                    let mach = &self.machines[*node];
                    if *epoch != mach.coll_epoch
                        || matches!(mach.phase, MPhase::Dormant | MPhase::Dead)
                    {
                        continue;
                    }
                }
                _ => {}
            }
            self.sim.advance_to(at);
            match event {
                Event::Deliver { src, dst, payload, dup: _, ctx } => {
                    if self.timeline.enabled() {
                        self.timeline.recv(at, dst, ctx, payload.kind_name());
                    }
                    self.on_deliver(src, dst, payload);
                }
                Event::Wake { node, epoch: _ } => {
                    self.sim.counters().timeouts += 1;
                    self.machines[node].timeout_armed = false;
                    self.try_advance(node, true);
                }
                Event::Timer { node, kind: TimerKind::Gossip, .. } => {
                    self.on_gossip_timer(node);
                }
                Event::Timer { node, kind: TimerKind::Collective, .. } => {
                    self.on_coll_timer(node);
                }
                Event::Join { node } => self.on_join(node),
                Event::Leave { node } => self.on_leave(node),
            }
            self.drain();
        }
        self.finish()
    }

    // -- setup / teardown ---------------------------------------------------

    fn init_handshake(&mut self) {
        for m in 0..self.machines.len() {
            if !self.ctrl.view().node_live(m) {
                continue;
            }
            self.send_state(m, 0, 0);
        }
    }

    /// Quotient slots of machine `m` whose link currently carries
    /// traffic, as `(qslot, peer)` pairs in adjacency order — the one
    /// definition of "live neighbour machine" every send/gossip path
    /// shares.
    fn live_neighbors(&self, m: usize) -> Vec<(usize, usize)> {
        let view = self.ctrl.view();
        self.part
            .quotient
            .neighbors(m)
            .iter()
            .enumerate()
            .filter(|&(qslot, _)| view.slot_live(m, qslot))
            .map(|(qslot, &p)| (qslot, p))
            .collect()
    }

    /// Send through the transport and record the minted
    /// [`crate::obs::TraceCtx`] on the timeline (no-op when disabled).
    fn tsend(&mut self, src: usize, dst: usize, payload: Payload, reliable: bool) {
        send_traced(&mut self.sim, &mut self.timeline, src, dst, payload, reliable);
    }

    /// Reliably send machine `m`'s boundary θ (stamped `ts`) and η
    /// (stamped `es`) to every live neighbour machine.
    fn send_state(&mut self, m: usize, ts: u64, es: u64) {
        for (qslot, p) in self.live_neighbors(m) {
            let nodes = self.machines[m].boundary_theta(qslot, ts);
            let edges = self.machines[m].boundary_eta(qslot);
            self.tsend(m, p, Payload::BoundaryTheta { stamp: ts, nodes }, true);
            self.tsend(m, p, Payload::BoundaryEta { stamp: es, edges }, true);
        }
    }

    fn finish(mut self) -> ClusterReport {
        // a stop decision can land while another machine's overlapped
        // interior slice is still in flight; join everything before the
        // final θ assembly reads the arenas
        for m in 0..self.machines.len() {
            self.join_overlap(m);
        }
        let n = self.graph.len();
        let dim = self.dim;
        let target = self.stop_round.unwrap_or(u64::MAX);
        let mut thetas = vec![vec![0.0; dim]; n];
        for mach in &self.machines {
            let flat = mach.snapshot_for(target, dim);
            for (off, i) in mach.span.clone().enumerate() {
                thetas[self.order[i]]
                    .copy_from_slice(&flat[off * dim..(off + 1) * dim]);
            }
        }
        let live_machines =
            (0..self.machines.len()).map(|m| self.ctrl.view().node_live(m)).collect();
        let trace = self.sim.take_trace();
        let counters = self.sim.counters_snapshot();
        self.obs.set_gauge(self.probes.iterations, self.fold.cursor as f64);
        self.obs.set_gauge(self.probes.converged,
                           if self.fold.tracker.converged { 1.0 } else { 0.0 });
        let vt = self.obs.gauge("fadmm_virtual_time");
        self.obs.set_gauge(vt, self.sim.now() as f64);
        let mg = self.obs.gauge("fadmm_machines");
        self.obs.set_gauge(mg, self.machines.len() as f64);
        self.obs.absorb_net(&counters);
        self.obs.absorb_trace(trace.len(), counters.trace_dropped);
        let timeline = self.timeline.drain();
        let timeline_dropped = self.timeline.dropped();
        let series = self.series.drain();
        let series_dropped = self.series.dropped();
        self.obs.absorb_timeline(timeline.len(), timeline_dropped,
                                 series.len(), series_dropped);
        crate::obs::global_merge(&self.obs);
        if crate::obs::global_timeline_enabled() {
            crate::obs::global_timeline_merge(timeline.clone());
        }
        if crate::obs::global_series_enabled() {
            crate::obs::global_series_merge(series.clone(), series_dropped);
        }
        ClusterReport {
            iterations: self.fold.cursor as usize,
            converged: self.fold.tracker.converged,
            recorder: self.fold.tracker.take_recorder(),
            thetas,
            virtual_time: self.sim.now(),
            counters,
            trace,
            machines: self.machines.len(),
            live_machines,
            workers_per_machine: self.workers_used,
            obs: self.obs,
            timeline,
            timeline_dropped,
            series,
            series_dropped,
        }
    }

    // -- the machine state machine ------------------------------------------

    fn try_advance(&mut self, m: usize, mut force: bool) {
        loop {
            if self.stopped {
                return;
            }
            match self.machines[m].phase {
                MPhase::Dormant | MPhase::Dead | MPhase::Done => return,
                MPhase::Solve => {
                    let t = self.machines[m].t;
                    if t > self.machines[m].horizon + self.cfg.pipeline {
                        return; // woken when the verdict horizon advances
                    }
                    if !self.ready_a(m, force) {
                        // boundary batches still in flight: overlap the
                        // interior solves with the wait, so the phase
                        // barrier falls on the boundary slice only
                        self.begin_overlap(m);
                        self.arm_silence(m);
                        return;
                    }
                    let overlapped = self.join_overlap(m) == Some(t);
                    self.resolve_a(m);
                    let span = self.obs.span();
                    {
                        let graph = &self.graph;
                        let pool = &self.pool;
                        let exec = self.cfg.exec;
                        let mach = &mut self.machines[m];
                        if overlapped {
                            mach.run_phase_a_boundary(graph, t, pool, exec);
                        } else {
                            mach.run_phase_a(graph, t, pool, exec);
                        }
                        mach.snapshot(t);
                        mach.phase = MPhase::Reduce;
                    }
                    let ns = self.obs.end(self.probes.solve, span);
                    if self.timeline.enabled() {
                        self.timeline.phase(self.sim.now(), m, t, Phase::Solve, ns);
                    }
                    let span = self.obs.span();
                    self.send_boundary_theta(m, t + 1);
                    let ns = self.obs.end(self.probes.boundary_io, span);
                    if self.timeline.enabled() {
                        self.timeline
                            .phase(self.sim.now(), m, t, Phase::BoundaryIo, ns);
                    }
                }
                MPhase::Reduce => {
                    if !self.ready_b(m, force) {
                        self.arm_silence(m);
                        return;
                    }
                    self.resolve_b(m);
                    let t = self.machines[m].t;
                    let span = self.obs.span();
                    {
                        let graph = &self.graph;
                        let pool = &self.pool;
                        let exec = self.cfg.exec;
                        self.machines[m].run_phase_b(graph, t, pool, exec);
                    }
                    let ns = self.obs.end(self.probes.reduce, span);
                    if self.timeline.enabled() {
                        self.timeline.phase(self.sim.now(), m, t, Phase::Reduce, ns);
                    }
                    self.machines[m].phase = MPhase::FoldWait;
                    self.collective_ready(m, t);
                    if self.stopped {
                        return;
                    }
                }
                MPhase::FoldWait => {
                    let t = self.machines[m].t;
                    let verdict = self.machines[m].verdicts.get(&t).copied();
                    if self.machines[m].needs_globals && verdict.is_none() {
                        return; // woken by the verdict (or its fallback)
                    }
                    let globals =
                        verdict.unwrap_or(self.machines[m].latest_globals);
                    self.refresh_links(m);
                    let span = self.obs.span();
                    self.machines[m].run_phase_c(&self.graph, t, globals);
                    let ns = self.obs.end(self.probes.observe, span);
                    if self.timeline.enabled() {
                        self.timeline.phase(self.sim.now(), m, t, Phase::Observe, ns);
                    }
                    let span = self.obs.span();
                    self.send_boundary_eta(m, t + 1);
                    let ns = self.obs.end(self.probes.boundary_io, span);
                    if self.timeline.enabled() {
                        self.timeline
                            .phase(self.sim.now(), m, t, Phase::BoundaryIo, ns);
                    }
                    self.observe_machine_etas(m);
                    if self.stopped {
                        return;
                    }
                    let mach = &mut self.machines[m];
                    mach.t += 1;
                    mach.phase = if mach.t >= self.cfg.max_iters as u64 {
                        MPhase::Done
                    } else {
                        MPhase::Solve
                    };
                }
            }
            // progress happened: invalidate any armed silence timeout
            let mach = &mut self.machines[m];
            mach.wake_epoch = mach.wake_epoch.wrapping_add(1);
            mach.timeout_armed = false;
            force = false;
        }
    }

    // -- overlapped interior dispatch ---------------------------------------

    /// While machine `m` waits on boundary input for its current round,
    /// start its interior phase-A solves on the pool (idempotent per
    /// round; pool mode and multi-machine runs only — a single machine
    /// has no boundary and is always ready). The driver keeps processing
    /// network events while the jobs run; [`Self::join_overlap`] is the
    /// barrier.
    fn begin_overlap(&mut self, m: usize) {
        if self.cfg.exec != ExecMode::Pool
            || self.machines.len() <= 1
            || self.overlap[m].is_some()
        {
            return;
        }
        let t = self.machines[m].t;
        let ticket = {
            let graph = &self.graph;
            let pool = &self.pool;
            let mach = &mut self.machines[m];
            // Safety: the ticket is joined before the driver touches this
            // machine's nodes/scratch/arena again (the Solve arm after
            // ready_a, on_leave, finish); until then the driver only
            // reads/writes its boundary caches and timers, which are
            // disjoint allocations.
            unsafe { mach.dispatch_interior(graph, pool, t) }
        };
        if let Some(ticket) = ticket {
            self.sim.counters().overlap_dispatches += 1;
            self.overlap[m] = Some((t, ticket));
        }
    }

    /// Join machine `m`'s outstanding interior ticket, if any; returns
    /// the round it was dispatched for. A job panic propagates like a
    /// scoped-spawn panic would.
    fn join_overlap(&mut self, m: usize) -> Option<u64> {
        let (t, ticket) = self.overlap[m].take()?;
        if let Err(p) = ticket.join() {
            panic!("{}", p.message);
        }
        Some(t)
    }

    fn drain(&mut self) {
        while !self.stopped {
            let Some(m) = self.pending_wakes.pop() else { return };
            if self.machines[m].running() {
                self.try_advance(m, false);
            }
        }
    }

    fn arm_silence(&mut self, m: usize) {
        let timeout = self.cfg.silence_timeout;
        if timeout == 0 || self.machines[m].timeout_armed {
            return;
        }
        self.machines[m].timeout_armed = true;
        let epoch = self.machines[m].wake_epoch;
        let at = self.sim.now() + timeout;
        self.sim.schedule(at, Event::Wake { node: m, epoch });
    }

    /// Recompute `link_live` for machine `m` against the quotient view.
    fn refresh_links(&mut self, m: usize) {
        let gen = self.ctrl.view().generation();
        if self.machines[m].link_gen == gen {
            return;
        }
        let mcount = self.machines.len();
        let mut live = vec![false; mcount];
        live[m] = true;
        {
            let view = self.ctrl.view();
            for (qslot, &p) in self.part.quotient.neighbors(m).iter().enumerate() {
                live[p] = view.slot_live(m, qslot);
            }
        }
        let mach = &mut self.machines[m];
        mach.link_live = live;
        mach.link_gen = gen;
    }

    // -- boundary readiness / resolution ------------------------------------

    fn ready_a(&mut self, m: usize, force: bool) -> bool {
        self.refresh_links(m);
        let mach = &self.machines[m];
        let t = mach.t;
        let stale = self.cfg.max_staleness;
        for idx in 0..mach.in_nodes.len() {
            let p = mach.in_node_machine[idx];
            if !mach.link_live[p] {
                continue;
            }
            if !mach.in_theta_ready(idx, t, stale, force) {
                return false;
            }
        }
        true
    }

    fn resolve_a(&mut self, m: usize) {
        let t = self.machines[m].t;
        let stale = self.cfg.max_staleness;
        for idx in 0..self.machines[m].in_nodes.len() {
            let p = self.machines[m].in_node_machine[idx];
            if !self.machines[m].link_live[p] {
                continue;
            }
            let used = self.machines[m].resolve_in_theta(idx, t);
            self.note_read(m, p, t, used, stale);
        }
    }

    fn ready_b(&mut self, m: usize, force: bool) -> bool {
        self.refresh_links(m);
        let mach = &self.machines[m];
        let t = mach.t;
        let stale = self.cfg.max_staleness;
        for idx in 0..mach.in_nodes.len() {
            let p = mach.in_node_machine[idx];
            if !mach.link_live[p] {
                continue;
            }
            if !mach.in_theta_ready(idx, t + 1, stale, force) {
                return false;
            }
        }
        for idx in 0..mach.in_eta_edges.len() {
            let p = mach.in_eta_edges[idx].2;
            if !mach.link_live[p] {
                continue;
            }
            if !mach.in_eta_ready(idx, t, stale, force) {
                return false;
            }
        }
        true
    }

    fn resolve_b(&mut self, m: usize) {
        let t = self.machines[m].t;
        let stale = self.cfg.max_staleness;
        for idx in 0..self.machines[m].in_nodes.len() {
            let p = self.machines[m].in_node_machine[idx];
            if !self.machines[m].link_live[p] {
                continue;
            }
            let used = self.machines[m].resolve_in_theta(idx, t + 1);
            self.note_read(m, p, t + 1, used, stale);
        }
        for idx in 0..self.machines[m].in_eta_edges.len() {
            let p = self.machines[m].in_eta_edges[idx].2;
            if !self.machines[m].link_live[p] {
                continue;
            }
            let used = self.machines[m].resolve_in_eta(idx, t);
            self.note_read(m, p, t, used, stale);
        }
    }

    fn note_read(&mut self, m: usize, nbr: usize, ideal: u64, used: u64, stale: u64) {
        self.sim.note_stale_read(m, nbr, ideal, used, stale);
    }

    // -- boundary sends -----------------------------------------------------

    fn send_boundary_theta(&mut self, m: usize, stamp: u64) {
        for (qslot, p) in self.live_neighbors(m) {
            let nodes = self.machines[m].boundary_theta(qslot, stamp);
            self.tsend(m, p, Payload::BoundaryTheta { stamp, nodes }, false);
        }
    }

    fn send_boundary_eta(&mut self, m: usize, stamp: u64) {
        for (qslot, p) in self.live_neighbors(m) {
            let edges = self.machines[m].boundary_eta(qslot);
            self.tsend(m, p, Payload::BoundaryEta { stamp, edges }, false);
        }
    }

    // -- event handlers -----------------------------------------------------

    fn on_deliver(&mut self, src: usize, dst: usize, payload: Payload) {
        if matches!(self.machines[dst].phase, MPhase::Dormant | MPhase::Dead) {
            self.sim.note_dead_delivery(src, dst, &payload);
            return;
        }
        self.sim.note_delivered(src, dst, &payload);
        match payload {
            Payload::BoundaryTheta { stamp, nodes } => {
                for (node, th) in nodes {
                    let idx = self.machines[dst]
                        .in_nodes
                        .binary_search(&node)
                        .expect("boundary node known to the receiver");
                    self.machines[dst].in_theta[idx].insert(stamp, th);
                }
                self.try_advance(dst, false);
            }
            Payload::BoundaryEta { stamp, edges } => {
                for (i, j, eta) in edges {
                    let idx = *self.machines[dst]
                        .in_eta_index
                        .get(&(i, j))
                        .expect("cross edge known to the receiver");
                    self.machines[dst].in_eta[idx].insert(stamp, eta);
                }
                self.try_advance(dst, false);
            }
            Payload::Part { round, entries, thetas } => {
                self.on_part(dst, src, round, entries, thetas);
            }
            Payload::Verdict { round, global_primal, global_dual } => {
                self.on_verdict(dst, round, global_primal, global_dual);
            }
            Payload::Checker { cursor, snap } => {
                // the leader-election handoff lands: resume the tracker
                // here and release any folds the transfer window buffered
                if self.fold.in_flight_to == Some(dst) {
                    self.fold.tracker.resume(*snap);
                    self.fold.cursor = cursor;
                    self.fold.holder = dst;
                    self.fold.in_flight_to = None;
                    self.try_root_folds();
                    self.gossip_catch_up(dst);
                }
            }
            Payload::Gossip { round, mass, weight, maxes } => {
                self.on_gossip_mass(dst, src, round, mass, weight, maxes);
            }
            // per-node payloads never travel the machine-level transport,
            // and the stop flood only exists on real transports (the
            // simulated driver halts the run directly)
            Payload::Theta { .. } | Payload::Eta { .. } | Payload::Stop { .. } => {}
        }
    }

    fn on_leave(&mut self, m: usize) {
        if !self.ctrl.view().node_live(m) {
            return;
        }
        // a departing machine may have an overlapped interior slice in
        // flight; complete it before the state machine transitions
        self.join_overlap(m);
        // leader-election handoff: a departing tracker holder serializes
        // its state to the successor *before* its transport goes dark —
        // the new tree root, or gossip's next designated recorder (the
        // lowest live survivor, which the same `find` yields)
        if self.fold.holder == m && self.fold.in_flight_to.is_none() {
            let successor = (0..self.machines.len())
                .find(|&p| p != m && self.ctrl.view().node_live(p));
            if let Some(to) = successor {
                self.initiate_handoff(m, to);
            }
        }
        if !self.ctrl.apply_leave(m, &mut self.sim) {
            return;
        }
        self.machines[m].phase = MPhase::Dead;
        if self.root_prefer == Some(m) {
            self.root_prefer = None;
        }
        if self.fold.in_flight_to == Some(m) {
            // the receiver died with the snapshot in flight — resume at
            // the next root via the omniscient shortcut (a real
            // deployment would need checkpointed recovery here)
            self.fold.in_flight_to = None;
            self.fold.holder = (0..self.machines.len())
                .find(|&p| self.ctrl.view().node_live(p))
                .unwrap_or(0);
            let to = self.fold.holder;
            self.sim.record(TraceKind::Handoff { from: m, to });
        }
        self.after_view_change();
    }

    /// Serialize the tracker at `from` and ship it reliably to `to` (the
    /// simulated leader-election handoff). The state stays driver-held —
    /// what travels is the serialized [`crate::kernel::StopSnapshot`] —
    /// but the root will not fold again until the message lands and
    /// [`StopTracker::resume`] runs, so the protocol cost is real.
    fn initiate_handoff(&mut self, from: usize, to: usize) {
        let snap = self.fold.tracker.snapshot();
        self.fold.in_flight_to = Some(to);
        self.sim.record(TraceKind::Handoff { from, to });
        self.tsend(from, to,
                   Payload::Checker { cursor: self.fold.cursor,
                                      snap: Box::new(snap) },
                   true);
    }

    /// Whether the tree root currently holds a resumed tracker (folds and
    /// commits are gated on this; gossip gates its commits directly on
    /// `fold.holder` inside [`Self::gossip_complete`]).
    fn tracker_at_root(&mut self) -> bool {
        if !matches!(self.cfg.collective, CollectiveKind::Tree) {
            return true;
        }
        let root = {
            let Coll::Tree(tree) = &self.coll else { return true };
            tree.topo.root
        };
        if self.fold.in_flight_to.is_some() {
            return false;
        }
        if self.fold.holder != root {
            // no transfer in flight and the holder is not the root (the
            // holder died mid-flight, or a preferred machine vanished):
            // omniscient migration keeps the run live — counted in the
            // trace so the shortcut is visible
            let from = self.fold.holder;
            self.fold.holder = root;
            self.sim.record(TraceKind::Handoff { from, to: root });
        }
        true
    }

    fn on_join(&mut self, m: usize) {
        // a rejoiner may have been ahead of the survivors when it left;
        // never restart below one past its own last round
        let rejoin_floor = if self.machines[m].phase == MPhase::Dead {
            self.machines[m].t + 1
        } else {
            0
        };
        if !self.ctrl.apply_join(m, &mut self.sim) {
            return;
        }
        let frontier = self
            .machines
            .iter()
            .enumerate()
            .filter(|&(j, mm)| j != m && mm.running())
            .map(|(_, mm)| mm.t + 1)
            .max()
            .unwrap_or(0)
            .max(self.fold.cursor)
            .max(rejoin_floor);
        let start = frontier.min(self.cfg.max_iters as u64);
        {
            let mach = &mut self.machines[m];
            mach.t = start;
            mach.start_round = start;
            mach.horizon = mach.horizon.max(start);
            mach.phase = if start >= self.cfg.max_iters as u64 {
                MPhase::Done
            } else {
                MPhase::Solve
            };
            mach.sync_parities();
        }
        // two-way reliable boundary handshake so neither side starts from
        // an empty cache
        self.send_state(m, start, start);
        for (_, p) in self.live_neighbors(m) {
            // honor the dispatch_interior contract: never read a machine's
            // boundary state while it has an interior overlap in flight
            self.join_overlap(p);
            let (ts, es) = self.current_stamps(p);
            let rev = self
                .part
                .quotient
                .edge_slot(p, m)
                .expect("quotient symmetry");
            let nodes = self.machines[p].boundary_theta(rev, ts);
            let edges = self.machines[p].boundary_eta(rev);
            self.tsend(p, m, Payload::BoundaryTheta { stamp: ts, nodes }, true);
            self.tsend(p, m, Payload::BoundaryEta { stamp: es, edges }, true);
            self.pending_wakes.push(p);
        }
        self.after_view_change();
        // resume any push-sum rounds stranded while the machine was dead
        self.gossip_kick(m);
        self.try_advance(m, false);
    }

    /// Stamps describing what machine `p`'s θ/η currently hold.
    fn current_stamps(&self, p: usize) -> (u64, u64) {
        let mach = &self.machines[p];
        match mach.phase {
            MPhase::Reduce | MPhase::FoldWait => (mach.t + 1, mach.t),
            _ => (mach.t, mach.t),
        }
    }

    /// React to quotient-view mutations (churn, activity toggles): wake
    /// every running machine and re-evaluate pending collective rounds
    /// whose expectations may have shrunk.
    fn after_view_change(&mut self) {
        if matches!(self.cfg.collective, CollectiveKind::Tree) {
            self.tree_refresh();
            let pending: Vec<(usize, u64)> = {
                let Coll::Tree(t) = &self.coll else { return };
                (0..self.machines.len())
                    .flat_map(|m| t.inbox[m].keys().map(move |&r| (m, r)))
                    .collect()
            };
            for (m, r) in pending {
                if self.stopped {
                    return;
                }
                if self.machines[m].running() {
                    self.tree_progress(m, r);
                }
            }
        }
        for m in 0..self.machines.len() {
            if self.machines[m].running() {
                self.pending_wakes.push(m);
            }
        }
    }

    /// Feed the machine-level NAP activity rule: the mean directed η over
    /// each machine cut, observed by the quotient TopologyController.
    fn observe_machine_etas(&mut self, m: usize) {
        if self.cfg.activity.is_none() {
            return;
        }
        let means: Vec<f64> = {
            let mach = &self.machines[m];
            let lo = mach.span.start;
            (0..mach.out_edges.len())
                .map(|qslot| {
                    let edges = &mach.out_edges[qslot];
                    if edges.is_empty() {
                        return 0.0;
                    }
                    let mut s = 0.0;
                    for &(i, _j, slot) in edges {
                        s += mach.nodes[i - lo].kernel.etas[slot];
                    }
                    s / edges.len() as f64
                })
                .collect()
        };
        let toggled = self.ctrl.observe_etas(m, &means, &mut self.sim);
        if !toggled.is_empty() {
            self.after_view_change();
        }
    }

    // -- collective dispatch ------------------------------------------------

    fn collective_ready(&mut self, m: usize, round: u64) {
        match self.cfg.collective {
            CollectiveKind::Tree => self.tree_deposit(m, round),
            CollectiveKind::Gossip => self.gossip_start(m, round),
        }
    }

    /// Whether machine `p` owes a contribution to round `r`.
    fn expects(&self, p: usize, r: u64) -> bool {
        self.ctrl.view().node_live(p) && self.machines[p].start_round <= r
    }

    fn arm_coll(&mut self, m: usize) {
        let timeout = self.cfg.collective_timeout;
        if timeout == 0 || self.machines[m].coll_armed {
            return;
        }
        self.machines[m].coll_armed = true;
        let epoch = self.machines[m].coll_epoch;
        let at = self.sim.now() + timeout;
        self.sim
            .schedule(at, Event::Timer { node: m, kind: TimerKind::Collective, epoch });
    }

    /// Record a verdict at machine `m`. Returns false if it was a
    /// duplicate.
    fn store_verdict(&mut self, m: usize, r: u64, gp: f64, gd: f64) -> bool {
        let mach = &mut self.machines[m];
        if mach.verdicts.insert(r, (gp, gd)).is_some() {
            return false;
        }
        if r + 1 > mach.horizon {
            mach.horizon = r + 1;
            mach.latest_globals = (gp, gd);
        }
        mach.retries.remove(&r);
        // cancel the in-flight collective timer; outstanding rounds
        // re-arm through tree_rearm
        mach.coll_armed = false;
        mach.coll_epoch = mach.coll_epoch.wrapping_add(1);
        self.pending_wakes.push(m);
        true
    }

    // -- tree collective ----------------------------------------------------

    fn tree_refresh(&mut self) {
        let gen = self.ctrl.view().generation();
        let prefer = self.root_prefer;
        let Some((old_root, new_root)) = ({
            let view = self.ctrl.view();
            let Coll::Tree(tree) = &mut self.coll else { return };
            if tree.topo.built_gen == gen {
                None
            } else {
                let old_root = tree.topo.root;
                tree.topo = build_tree_rooted(view, prefer);
                Some((old_root, tree.topo.root))
            }
        }) else {
            return;
        };
        self.after_reroot(old_root, new_root);
    }

    /// Re-root the tree at `target` without a topology change (the
    /// scripted handoff drill) and start the tracker transfer.
    fn force_reroot(&mut self, target: usize) {
        self.root_prefer = Some(target);
        let (old_root, new_root) = {
            let view = self.ctrl.view();
            let Coll::Tree(tree) = &mut self.coll else { return };
            let old_root = tree.topo.root;
            tree.topo = build_tree_rooted(view, Some(target));
            (old_root, tree.topo.root)
        };
        self.after_reroot(old_root, new_root);
        // in-flight rootward traffic re-routes through the collective
        // timers (the same recovery machinery churn re-roots rely on);
        // nudge every running machine so nobody waits a full timeout
        self.after_view_change();
    }

    /// Shared re-root tail: trace it and, when the old root still holds a
    /// live tracker, start the serialize→send→resume handoff toward the
    /// new root (a dead old root already flushed its state in `on_leave`).
    fn after_reroot(&mut self, old_root: usize, new_root: usize) {
        if new_root == old_root {
            return;
        }
        self.sim.record(TraceKind::Reroot { root: new_root });
        if self.fold.holder == old_root
            && self.fold.in_flight_to.is_none()
            && self.ctrl.view().node_live(old_root)
        {
            self.initiate_handoff(old_root, new_root);
        }
    }

    fn tree_deposit(&mut self, m: usize, round: u64) {
        {
            let entry = self.machines[m].partials.clone();
            // app-metric runs only: ship the committed θ^{round+1} span
            // with the rootward traffic so the recorder's snapshot
            // assembly needs no remote reads
            let snap = if self.metric.is_some() {
                self.machines[m].snapshots.get(&round).cloned()
            } else {
                None
            };
            let Coll::Tree(tree) = &mut self.coll else { return };
            tree.inbox[m].entry(round).or_default().insert(m, entry);
            if let Some(s) = snap {
                tree.theta_inbox[m].entry(round).or_default().insert(m, s);
            }
        }
        self.tree_progress(m, round);
    }

    fn tree_progress(&mut self, m: usize, round: u64) {
        self.tree_refresh();
        let (is_root, parent) = {
            let Coll::Tree(tree) = &self.coll else { return };
            (tree.topo.root == m, tree.topo.parent[m])
        };
        if is_root {
            self.try_root_folds();
            return;
        }
        let (complete, own_present) = self.subtree_status(m, round);
        if !complete {
            if own_present {
                self.arm_coll(m);
            }
            return;
        }
        self.tree_forward(m, round, parent);
    }

    /// (subtree complete for `round`, own entry present) at machine `m`.
    fn subtree_status(&self, m: usize, round: u64) -> (bool, bool) {
        let Coll::Tree(tree) = &self.coll else { return (false, false) };
        let present = tree.inbox[m].get(&round);
        let own = present.is_some_and(|map| map.contains_key(&m));
        let members = subtree(&tree.topo, m);
        let complete = members.iter().all(|&p| {
            !self.expects(p, round)
                || present.is_some_and(|map| map.contains_key(&p))
        });
        (complete, own)
    }

    /// Send machine `m`'s accumulated round entries rootward (or mark
    /// them forwarded when detached) and await the verdict.
    fn tree_forward(&mut self, m: usize, round: u64, parent: Option<usize>) {
        let (entries, thetas) = {
            let Coll::Tree(tree) = &mut self.coll else { return };
            let Some(map) = tree.inbox[m].get(&round) else { return };
            let e: Vec<(usize, Vec<StatPartial>)> =
                map.iter().map(|(&k, v)| (k, v.clone())).collect();
            let th: Vec<(usize, Vec<f64>)> = tree.theta_inbox[m]
                .get(&round)
                .map(|map| map.iter().map(|(&k, v)| (k, v.clone())).collect())
                .unwrap_or_default();
            tree.sent_up[m].insert(round);
            (e, th)
        };
        if let Some(p) = parent {
            self.tsend(m, p, Payload::Part { round, entries, thetas }, false);
        }
        self.arm_coll(m);
    }

    fn on_part(&mut self, dst: usize, src: usize, round: u64,
               entries: Vec<(usize, Vec<StatPartial>)>,
               thetas: Vec<(usize, Vec<f64>)>) {
        // straggler for an already-verdicted round: answer directly
        if let Some(&(gp, gd)) = self.machines[dst].verdicts.get(&round) {
            self.tsend(dst, src,
                       Payload::Verdict { round, global_primal: gp, global_dual: gd },
                       false);
            return;
        }
        {
            let Coll::Tree(tree) = &mut self.coll else { return };
            let map = tree.inbox[dst].entry(round).or_default();
            for (mid, parts) in entries {
                map.insert(mid, parts);
            }
            if !thetas.is_empty() {
                let tmap = tree.theta_inbox[dst].entry(round).or_default();
                for (mid, flat) in thetas {
                    tmap.insert(mid, flat);
                }
            }
        }
        self.tree_progress(dst, round);
    }

    fn on_verdict(&mut self, dst: usize, round: u64, gp: f64, gd: f64) {
        if !self.store_verdict(dst, round, gp, gd) {
            return;
        }
        let children = {
            let Coll::Tree(tree) = &mut self.coll else { return };
            // prune only *settled* rounds: an older round whose verdict
            // was lost must keep its inbox entry alive, or the
            // retransmit → straggler-reply → fallback recovery would be
            // disarmed by a newer verdict overtaking it (tree_rearm
            // below re-arms for exactly those survivors)
            let settled = &self.machines[dst].verdicts;
            tree.inbox[dst]
                .retain(|&r, _| r > round || !settled.contains_key(&r));
            tree.theta_inbox[dst]
                .retain(|&r, _| r > round || !settled.contains_key(&r));
            tree.sent_up[dst]
                .retain(|&r| r > round || !settled.contains_key(&r));
            tree.topo.children[dst].clone()
        };
        for c in children {
            if self.ctrl.view().node_live(c) {
                self.tsend(dst, c,
                           Payload::Verdict { round, global_primal: gp, global_dual: gd },
                           false);
            }
        }
        self.tree_rearm(dst);
    }

    /// Re-arm the collective timer if machine `m` still has rounds
    /// awaiting a verdict.
    fn tree_rearm(&mut self, m: usize) {
        let outstanding = {
            let Coll::Tree(tree) = &self.coll else { return };
            tree.inbox[m]
                .iter()
                .any(|(r, map)| map.contains_key(&m)
                     && !self.machines[m].verdicts.contains_key(r))
        };
        if outstanding {
            self.arm_coll(m);
        }
    }

    fn try_root_folds(&mut self) {
        loop {
            if self.stopped {
                return;
            }
            // the root cannot commit while the tracker is in flight (the
            // leader-election handoff window); inboxes keep buffering and
            // the Checker delivery re-enters here
            if !self.tracker_at_root() {
                return;
            }
            let r = self.fold.cursor;
            if r >= self.cfg.max_iters as u64 {
                return;
            }
            let root = {
                let Coll::Tree(tree) = &self.coll else { return };
                tree.topo.root
            };
            let (complete, own) = self.subtree_status(root, r);
            if !complete {
                if own {
                    self.arm_coll(root);
                }
                return;
            }
            let has = {
                let Coll::Tree(tree) = &self.coll else { return };
                tree.inbox[root].contains_key(&r)
            };
            if !has {
                return;
            }
            self.root_fold(r, false);
        }
    }

    /// Record round `r`'s commit on the timeline and push its series row
    /// (no-ops when both recorders are off). `m` is the committing
    /// machine — the tree root or the gossip tracker holder. The row's
    /// `live_nodes` counts underlying nodes hosted on live machines;
    /// `live_edges` counts live *machine* links of the quotient graph,
    /// the inter-machine topology this protocol actually routes over.
    fn record_commit(&mut self, m: usize, r: u64, stats: IterStats, fold_ns: u64) {
        if self.timeline.enabled() {
            let now = self.sim.now();
            self.timeline.phase(now, m, r, Phase::CollectiveFold, fold_ns);
            self.timeline.commit(now, m, r);
        }
        if self.series.enabled() {
            let view = self.ctrl.view();
            let live_nodes = self
                .machines
                .iter()
                .enumerate()
                .filter(|&(j, _)| view.node_live(j))
                .map(|(_, mm)| mm.span.len())
                .sum::<usize>() as u64;
            let row = RoundRow {
                round: r,
                at: self.sim.now(),
                stats,
                live_nodes,
                live_edges: view.live_edge_count() as u64,
                phase_ns: self.timeline.phase_ns(r),
            };
            self.series.push(row);
        }
    }

    /// Fold round `r` at the root: absorb every delivered machine's shard
    /// partials in machine-id order (= node-id order, since machine
    /// slices ascend) through the shared [`StopTracker`] — the Chan-style
    /// combination, the verdict arithmetic and the stop decision all live
    /// in [`crate::kernel`] now — then start the verdict broadcast.
    fn root_fold(&mut self, r: u64, forced: bool) {
        if !self.tracker_at_root() {
            return;
        }
        let root = {
            let Coll::Tree(tree) = &self.coll else { return };
            tree.topo.root
        };
        let (entries, shipped) = {
            let Coll::Tree(tree) = &mut self.coll else { return };
            let Some(map) = tree.inbox[root].remove(&r) else { return };
            tree.sent_up[root].remove(&r);
            let shipped = tree.theta_inbox[root].remove(&r).unwrap_or_default();
            (map, shipped)
        };
        if forced {
            self.sim.counters().collective_timeouts += 1;
            self.sim
                .record(TraceKind::CollectiveTimeout { machine: root, round: r });
        }
        // nothing to fold (all contributors died) — bail before the
        // tracker's verdict memory is touched
        if entries.values().flatten().all(|p| p.node_count == 0) {
            return;
        }
        let span = self.obs.span();
        let g = self
            .fold
            .tracker
            .round_partials(entries.values().flat_map(|parts| parts.iter()));
        let app_error = self.app_metric_value_tree(r, &shipped);
        let stats = IterStats {
            iter: r as usize,
            objective: g.objective,
            max_primal: g.max_primal,
            max_dual: g.max_dual,
            mean_eta: g.mean_eta,
            min_eta: g.min_eta,
            max_eta: g.max_eta,
            app_error,
        };
        let stop = self.fold.tracker.commit(r as usize, stats);
        self.fold.cursor = r + 1;
        self.sim.record(TraceKind::Fold { round: r });
        let fold_ns = self.obs.end(self.probes.collective_fold, span);
        self.obs.inc(self.probes.rounds, 1);
        self.record_commit(root, r, stats, fold_ns);
        self.store_verdict(root, r, g.global_primal, g.global_dual);

        if stop {
            self.stopped = true;
            self.stop_round = Some(r);
            self.sim.record(TraceKind::Stop { rounds: r + 1 });
            return;
        }
        let children = {
            let Coll::Tree(tree) = &self.coll else { return };
            tree.topo.children[root].clone()
        };
        for c in children {
            if self.ctrl.view().node_live(c) {
                self.tsend(root, c,
                           Payload::Verdict {
                               round: r,
                               global_primal: g.global_primal,
                               global_dual: g.global_dual,
                           },
                           false);
            }
        }
        // the scripted leader-handoff drill fires right after its round
        // commits: re-root at the target and ship the tracker there
        if let Some((at, target)) = self.cfg.handoff {
            if r == at && target != root
                && matches!(self.cfg.collective, CollectiveKind::Tree)
                && self.ctrl.view().node_live(target)
            {
                self.force_reroot(target);
            }
        }
    }

    /// A machine's local substitute fold over whatever its subtree
    /// delivered for `round` (the isolated-machine survival path).
    fn local_fold(&mut self, m: usize, round: u64) -> (f64, f64) {
        let mut rf = RunningFold::new(self.dim);
        {
            let Coll::Tree(tree) = &self.coll else {
                return (f64::INFINITY, f64::INFINITY);
            };
            if let Some(map) = tree.inbox[m].get(&round) {
                for parts in map.values() {
                    for p in parts {
                        rf.absorb(p);
                    }
                }
            }
        }
        let gp = rf.global_primal();
        let mach = &mut self.machines[m];
        let mut gs2 = 0.0;
        for k in 0..self.dim {
            let d = rf.gmean[k] - mach.coll_mean_prev[k];
            gs2 += d * d;
        }
        mach.coll_mean_prev.copy_from_slice(&rf.gmean);
        let gd = self.cfg.params.eta0 * (rf.agg_n as f64).sqrt() * gs2.sqrt();
        (gp, gd)
    }

    fn on_coll_timer(&mut self, m: usize) {
        self.machines[m].coll_armed = false;
        self.machines[m].coll_epoch = self.machines[m].coll_epoch.wrapping_add(1);
        if !matches!(self.cfg.collective, CollectiveKind::Tree) {
            return;
        }
        self.tree_refresh();
        let root = {
            let Coll::Tree(tree) = &self.coll else { return };
            tree.topo.root
        };
        if m == root {
            if !self.tracker_at_root() {
                return; // handoff in flight: the Checker delivery resumes
            }
            let r = self.fold.cursor;
            if r >= self.cfg.max_iters as u64 {
                return;
            }
            let (_, own) = self.subtree_status(root, r);
            if own {
                self.root_fold(r, true);
                if !self.stopped {
                    self.try_root_folds();
                }
            }
            return;
        }
        // oldest outstanding round with our own entry and no verdict
        let (next, forwarded, parent) = {
            let Coll::Tree(tree) = &self.coll else { return };
            let cand = tree.inbox[m]
                .iter()
                .filter(|&(r, map)| {
                    map.contains_key(&m) && !self.machines[m].verdicts.contains_key(r)
                })
                .map(|(&r, _)| r)
                .next();
            match cand {
                None => return,
                Some(r) => (r, tree.sent_up[m].contains(&r), tree.topo.parent[m]),
            }
        };
        if !forwarded {
            // straggling children: forward what we have
            self.sim.counters().collective_timeouts += 1;
            self.sim
                .record(TraceKind::CollectiveTimeout { machine: m, round: next });
            self.tree_forward(m, next, parent);
            return;
        }
        let retries = {
            let e = self.machines[m].retries.entry(next).or_insert(0);
            *e += 1;
            *e
        };
        if retries > self.cfg.fallback_after {
            let (gp, gd) = self.local_fold(m, next);
            self.sim.counters().collective_fallbacks += 1;
            self.sim
                .record(TraceKind::FallbackVerdict { machine: m, round: next });
            self.store_verdict(m, next, gp, gd);
            self.tree_rearm(m);
        } else {
            self.sim.counters().collective_retries += 1;
            self.tree_forward(m, next, parent);
        }
    }

    // -- gossip collective --------------------------------------------------

    fn gossip_start(&mut self, m: usize, round: u64) {
        self.refresh_links(m);
        let dim = self.dim;
        // live-count estimator: the designated recorder seeds exactly one
        // unit of "ones" mass per round, so the push-sum ratio
        // count/ones estimates the *live* node cardinality n̂ (consumed
        // in gossip_complete). A designated change mid-round can
        // transiently double the ones mass; the RB balance ratio is
        // scale-invariant, so only the committed objective wobbles for
        // those rounds.
        let designated = (0..self.machines.len())
            .find(|&p| self.ctrl.view().node_live(p))
            .unwrap_or(0);
        let (mass, maxes) = {
            let mach = &self.machines[m];
            let mut mass = vec![0.0; MASS_THETA + dim];
            mass[MASS_COUNT] = mach.local_len() as f64;
            mass[MASS_SQ] = mach.raw_sq;
            if m == designated {
                mass[MASS_ONE] = 1.0;
            }
            let mut maxes = [0.0, 0.0, 0.0, f64::NEG_INFINITY];
            for p in &mach.partials {
                mass[MASS_F] += p.f_sum;
                mass[MASS_ETA] += p.eta_sum;
                mass[MASS_ETA_CNT] += p.eta_count as f64;
                for k in 0..dim {
                    mass[MASS_THETA + k] += p.theta_sum[k];
                }
                maxes[0] = maxes[0].max(p.max_primal);
                maxes[1] = maxes[1].max(p.max_dual);
                maxes[2] = maxes[2].max(p.eta_max);
                maxes[3] = maxes[3].max(-p.eta_min);
            }
            (mass, maxes)
        };
        {
            let Coll::Gossip(g) = &mut self.coll else { return };
            let len = g.mass_len;
            let gr = g.rounds[m]
                .entry(round)
                .or_insert_with(|| super::collective::GossipRound::new(len));
            gr.add_own(&mass, maxes);
        }
        self.gossip_tick(m, round);
    }

    fn gossip_tick(&mut self, m: usize, round: u64) {
        self.refresh_links(m);
        let peers: Vec<usize> =
            self.live_neighbors(m).into_iter().map(|(_, p)| p).collect();
        let (ticks, spacing) = {
            let Coll::Gossip(g) = &self.coll else { return };
            (g.ticks, g.spacing)
        };
        let mut finished = false;
        let mut outgoing: Option<(usize, Vec<f64>, f64, [f64; 4])> = None;
        {
            let Coll::Gossip(g) = &mut self.coll else { return };
            let Some(gr) = g.rounds[m].get_mut(&round) else { return };
            if gr.done || !gr.inited {
                return;
            }
            if peers.is_empty() || ticks == 0 {
                gr.sent = ticks;
                finished = true;
            } else {
                // deterministic rotation over the live peers
                let dst = peers[(round as usize + gr.sent as usize + m) % peers.len()];
                let (mass, w) = gr.push_half(dst);
                let maxes = gr.maxes;
                outgoing = Some((dst, mass, w, maxes));
                gr.sent += 1;
                if gr.sent >= ticks {
                    finished = true;
                }
            }
        }
        if let Some((dst, mass, weight, maxes)) = outgoing {
            self.sim.counters().gossip_ticks += 1;
            self.tsend(m, dst, Payload::Gossip { round, mass, weight, maxes }, false);
        }
        if finished {
            self.gossip_complete(m, round);
        } else {
            let at = self.sim.now() + spacing;
            let epoch = self.machines[m].coll_epoch;
            self.sim
                .schedule(at, Event::Timer { node: m, kind: TimerKind::Gossip, epoch });
        }
    }

    /// Restore the one-timer-per-unfinished-round invariant: if machine
    /// `m` still owes push-sum exchanges on any round, chain one fresh
    /// gossip timer. Needed after a round completes (its chain ends with
    /// it) and after a rejoin (timers that fired while the machine was
    /// dead were consumed without rescheduling). No-op under tree.
    fn gossip_kick(&mut self, m: usize) {
        let owes = {
            let Coll::Gossip(g) = &self.coll else { return };
            let ticks = g.ticks;
            g.rounds[m]
                .values()
                .any(|gr| gr.inited && !gr.done && gr.sent < ticks)
        };
        if owes {
            let spacing = {
                let Coll::Gossip(g) = &self.coll else { return };
                g.spacing
            };
            let epoch = self.machines[m].coll_epoch;
            let at = self.sim.now() + spacing;
            self.sim
                .schedule(at, Event::Timer { node: m, kind: TimerKind::Gossip, epoch });
        }
    }

    fn on_gossip_timer(&mut self, m: usize) {
        if matches!(self.machines[m].phase, MPhase::Dormant | MPhase::Dead) {
            return;
        }
        // tick the oldest unfinished round (each pending round keeps a
        // timer in flight, so every round eventually completes its budget)
        let next = {
            let Coll::Gossip(g) = &self.coll else { return };
            let ticks = g.ticks;
            g.rounds[m]
                .iter()
                .filter(|(_, gr)| gr.inited && !gr.done && gr.sent < ticks)
                .map(|(&r, _)| r)
                .next()
        };
        if let Some(round) = next {
            self.gossip_tick(m, round);
        }
    }

    fn on_gossip_mass(&mut self, dst: usize, src: usize, round: u64,
                      mass: Vec<f64>, weight: f64, maxes: [f64; 4]) {
        let Coll::Gossip(g) = &mut self.coll else { return };
        let len = g.mass_len;
        let gr = g.rounds[dst]
            .entry(round)
            .or_insert_with(|| super::collective::GossipRound::new(len));
        if gr.done {
            return; // late mass for an estimated round (documented loss)
        }
        gr.absorb(src, &mass, weight, maxes);
    }

    fn gossip_complete(&mut self, m: usize, round: u64) {
        let est = {
            let Coll::Gossip(g) = &mut self.coll else { return };
            let Some(gr) = g.rounds[m].get_mut(&round) else { return };
            gr.done = true;
            estimate(gr, self.dim)
        };
        {
            // bound per-machine gossip memory
            let Coll::Gossip(g) = &mut self.coll else { return };
            g.rounds[m].retain(|&r, _| r + 16 >= round);
        }
        // this round's tick chain just ended; keep other pending rounds
        // ticking (see gossip_kick)
        self.gossip_kick(m);
        // true-√n̂ verdict scale from the live-count estimator: n̂ targets
        // an integer cardinality, so snap it — the committed objective
        // scale then stays piecewise-constant instead of wobbling with
        // per-round mixing error. A component that never saw the
        // designated machine has zero ones mass (n̂ = 0): it keeps the
        // per-node-normalized verdict, which the RB balance ratio is
        // insensitive to either way (both sides scale together).
        let n_hat = if est.n_live > 0.5 { est.n_live.round() } else { 1.0 };
        let scale = n_hat.sqrt();
        let gd = {
            let mach = &mut self.machines[m];
            let mut gs2 = 0.0;
            for k in 0..self.dim {
                let d = est.gmean[k] - mach.coll_mean_prev[k];
                gs2 += d * d;
            }
            mach.coll_mean_prev.copy_from_slice(&est.gmean);
            self.cfg.params.eta0 * scale * gs2.sqrt()
        };
        self.store_verdict(m, round, est.gp * scale, gd);

        // the tracker holder commits — the same serialize→send→resume
        // Checker handoff the tree runs migrates it on churn (see
        // on_leave); rounds estimated while the snapshot is in flight
        // are replayed by gossip_catch_up when it lands
        if self.fold.holder == m
            && self.fold.in_flight_to.is_none()
            && round >= self.fold.cursor
        {
            self.gossip_commit(round, &est);
        }
    }

    /// Commit one completed gossip round's estimate at the tracker
    /// holder: Σf over the live component is mean-per-node f × the
    /// estimated live count (replacing the static full-graph node
    /// count, which overcounted after churn).
    fn gossip_commit(&mut self, round: u64, est: &super::collective::GossipEstimate) {
        let span = self.obs.span();
        let n_hat = if est.n_live > 0.5 { est.n_live.round() } else { 1.0 };
        let objective = est.avg_f * n_hat;
        let app_error = self.app_metric_value(round);
        let stats = IterStats {
            iter: round as usize,
            objective,
            max_primal: est.max_primal,
            max_dual: est.max_dual,
            mean_eta: est.mean_eta,
            min_eta: est.min_eta,
            max_eta: est.max_eta,
            app_error,
        };
        let stop = self.fold.tracker.commit(round as usize, stats);
        self.fold.cursor = round + 1;
        self.sim.record(TraceKind::Fold { round });
        let fold_ns = self.obs.end(self.probes.collective_fold, span);
        self.obs.inc(self.probes.rounds, 1);
        let holder = self.fold.holder;
        self.record_commit(holder, round, stats, fold_ns);
        if stop {
            self.stopped = true;
            self.stop_round = Some(round);
            self.sim.record(TraceKind::Stop { rounds: round + 1 });
        }
    }

    /// After a gossip-side Checker handoff lands at `m`: rounds this
    /// machine finished estimating while the snapshot was in flight were
    /// never committed (the holder gate was closed) — replay them in
    /// ascending order from the retained [`super::collective::GossipRound`]s
    /// ([`estimate`] is a pure read of a completed round). Rounds pruned
    /// by the 16-round retention window are lost, exactly like verdicts
    /// that age out elsewhere.
    fn gossip_catch_up(&mut self, m: usize) {
        if !matches!(self.cfg.collective, CollectiveKind::Gossip) {
            return;
        }
        loop {
            if self.stopped {
                return;
            }
            let next = {
                let Coll::Gossip(g) = &self.coll else { return };
                g.rounds[m]
                    .iter()
                    .filter(|&(&r, gr)| gr.done && r >= self.fold.cursor)
                    .map(|(&r, _)| r)
                    .next()
            };
            let Some(round) = next else { return };
            let est = {
                let Coll::Gossip(g) = &self.coll else { return };
                estimate(&g.rounds[m][&round], self.dim)
            };
            self.gossip_commit(round, &est);
        }
    }
}

/// Convenience: build a factory from a plain closure (parity with the
/// sharded runner's [`SolverFactory`]).
pub fn factory_of<S, F>(f: F) -> SolverFactory<S>
where
    F: Fn(NodeId) -> S + Send + Sync + 'static,
{
    Arc::new(f)
}
