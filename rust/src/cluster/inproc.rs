//! In-process real-transport backend: one OS thread per machine over a
//! [`channel_mesh`].
//!
//! This is the first rung of the deployment ladder out of the simulator
//! (see the transport matrix in [`crate::net`]): the protocol runs with
//! *real* scheduler interleavings — threads race, sends interleave,
//! wall-clock timers actually elapse — while staying cheap enough to run
//! in the unit-test suite. Each machine is a [`NodeRuntime`] driving a
//! [`ChannelTransport`]; nothing here is simulator-aware.
//!
//! Faults are injected from the harness: [`InprocCluster::leave`]
//! broadcasts an [`Event::Leave`] for the victim to every endpoint, so
//! the victim performs the graceful-exit drill (checker handoff if it
//! holds the tracker) and the survivors re-root — the same departure
//! protocol the simulator's churn scripts exercise. Hard kills (no
//! goodbye at all) need a process boundary and live in
//! [`super::proc`].
//!
//! At zero faults the committed iteration count matches the
//! [`super::runner::ClusterRunner`] oracle exactly: the fold absorbs
//! machine entries in id order out of a `BTreeMap`, every boundary read
//! is exact-stamp at `max_staleness = 0`, and the per-round arithmetic
//! is placement-invariant — thread timing changes the schedule, not the
//! numbers. The tests below pin that.

use std::sync::mpsc::Sender;
use std::thread::JoinHandle;

use crate::consensus::LocalSolver;
use crate::coordinator::SolverFactory;
use crate::error::Result;
use crate::graph::Graph;
use crate::net::sim::Event;
use crate::net::transport::channel_mesh;

use super::node::{NodeReport, NodeRuntime};
use super::runner::ClusterConfig;

/// A running in-process cluster: one thread per machine plus the raw
/// injector senders for harness-driven faults.
pub struct InprocCluster {
    threads: Vec<JoinHandle<NodeReport>>,
    injectors: Vec<Sender<Event>>,
}

impl InprocCluster {
    /// Build every machine's runtime (fail-fast: config errors surface
    /// here, not inside a thread), then start them.
    pub fn spawn<S: LocalSolver + Send + 'static>(
        graph: &Graph, cfg: ClusterConfig, factory: SolverFactory<S>,
    ) -> Result<InprocCluster> {
        let machines = cfg.machines.max(1).min(graph.len());
        let (mesh, injectors) = channel_mesh(machines, cfg.tracing);
        let mut runtimes = Vec::with_capacity(machines);
        for (m, net) in mesh.into_iter().enumerate() {
            runtimes.push(NodeRuntime::new(graph.clone(), cfg, m, net,
                                           &*factory)?);
        }
        let threads = runtimes
            .into_iter()
            .enumerate()
            .map(|(m, rt)| {
                std::thread::Builder::new()
                    .name(format!("fadmm-m{m}"))
                    .spawn(move || rt.run())
                    .expect("spawn machine thread")
            })
            .collect();
        Ok(InprocCluster { threads, injectors })
    }

    /// Broadcast a graceful departure of machine `m` to every endpoint
    /// (including the victim, which exits through the handoff drill).
    pub fn leave(&self, m: usize) {
        for tx in &self.injectors {
            let _ = tx.send(Event::Leave { node: m });
        }
    }

    /// Wait for every machine; reports come back in machine order.
    /// Dropping the injectors first is what lets the last survivor's
    /// channel disconnect and its `pop()` return `None`.
    pub fn join(self) -> Vec<NodeReport> {
        let InprocCluster { threads, injectors } = self;
        drop(injectors);
        threads
            .into_iter()
            .map(|h| h.join().expect("machine thread panicked"))
            .collect()
    }
}

/// Run a fault-free in-process cluster to completion.
pub fn run_inproc<S: LocalSolver + Send + 'static>(
    graph: &Graph, cfg: ClusterConfig, factory: SolverFactory<S>,
) -> Result<Vec<NodeReport>> {
    Ok(InprocCluster::spawn(graph, cfg, factory)?.join())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterRunner, CollectiveKind};
    use crate::experiments::common::quad_problem_factory as quad_factory;
    use crate::graph::Topology;
    use crate::net::FaultPlan;
    use crate::penalty::SchemeKind;

    /// Timeouts in wall ms generous enough that scheduler noise never
    /// fires them (the parity contract assumes a timeout-free schedule);
    /// the sim oracle gets the same values in virtual ticks, where they
    /// are equally unreachable at zero faults.
    fn cfg(scheme: SchemeKind, machines: usize) -> ClusterConfig {
        ClusterConfig {
            scheme,
            tol: 1e-4,
            max_iters: 60,
            seed: 11,
            machines,
            workers: 1,
            collective: CollectiveKind::Tree,
            silence_timeout: 5_000,
            collective_timeout: 5_000,
            tracing: false,
            ..Default::default()
        }
    }

    /// Assemble a full flat θ from per-machine spans.
    fn assemble(reports: &[NodeReport], n: usize) -> Vec<Vec<f64>> {
        let dim = reports[0].dim;
        let mut out = vec![vec![0.0; dim]; n];
        for rep in reports {
            for (off, _i) in rep.span.clone().enumerate() {
                // span indexes the *relabeled* order; the oracle
                // comparison below relabels identically, so comparing
                // in relabeled order is sound
                out[rep.span.start + off]
                    .copy_from_slice(&rep.thetas_flat[off * dim..(off + 1) * dim]);
            }
        }
        out
    }

    #[test]
    fn inproc_matches_sim_iteration_counts_on_ring_and_star() {
        // the transport contract: convergence within tolerance plus
        // *identical* committed iteration counts vs the simulator
        // oracle at zero faults, every scheme, ring and star
        for topo in [Topology::Ring, Topology::Star] {
            for scheme in SchemeKind::ALL {
                let n = 12;
                let graph = topo.build(n).unwrap();
                let oracle = ClusterRunner::new(
                    topo.build(n).unwrap(),
                    cfg(scheme, 3),
                    FaultPlan::none(),
                    quad_factory(n, 2, 41),
                )
                .unwrap()
                .run();

                let reports =
                    run_inproc(&graph, cfg(scheme, 3), quad_factory(n, 2, 41))
                        .unwrap();
                assert_eq!(reports.len(), 3);
                let holder: Vec<&NodeReport> =
                    reports.iter().filter(|r| r.is_holder).collect();
                assert_eq!(holder.len(), 1, "{topo:?}/{scheme:?}: one holder");
                assert_eq!(
                    holder[0].iterations, oracle.iterations,
                    "{topo:?}/{scheme:?}: iteration count vs sim oracle"
                );
                assert_eq!(holder[0].converged, oracle.converged,
                           "{topo:?}/{scheme:?}");

                // θ agreement at convergence tolerance: the oracle's
                // report is in original ids; undo its relabeling to
                // compare in the machine-span (relabeled) order
                let thetas = assemble(&reports, n);
                let order = crate::graph::rcm_order(&topo.build(n).unwrap());
                for (pos, &orig) in order.iter().enumerate() {
                    for k in 0..2 {
                        let d = (thetas[pos][k] - oracle.thetas[orig][k]).abs();
                        assert!(
                            d < 1e-6,
                            "{topo:?}/{scheme:?}: node {orig} dim {k} \
                             drifted {d:e} between transports"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn inproc_departing_holder_hands_off_and_survivors_finish() {
        // graceful-exit drill over real threads: machine 0 (initial
        // root and tracker holder) leaves immediately; the checker
        // hops to a survivor and the survivors still converge
        let n = 12;
        let graph = Topology::Ring.build(n).unwrap();
        let cluster = InprocCluster::spawn(
            &graph, cfg(SchemeKind::Fixed, 3), quad_factory(n, 2, 41),
        )
        .unwrap();
        cluster.leave(0);
        let reports = cluster.join();

        assert!(!reports[0].is_holder, "victim handed the tracker off");
        let holder: Vec<&NodeReport> =
            reports.iter().filter(|r| r.is_holder).collect();
        assert_eq!(holder.len(), 1, "exactly one surviving holder");
        assert!(holder[0].machine != 0);
        assert!(holder[0].converged, "survivors still converge");
        assert!(holder[0].iterations > 0);
        for rep in &reports[1..] {
            assert!(rep.final_root != 0, "survivors re-rooted off the victim");
        }
    }
}
