//! Process transport: every machine is an OS process (`fadmm-node`)
//! speaking line-delimited JSON over stdin/stdout, Maelstrom-style.
//!
//! The last rung of the deployment ladder (transport matrix in
//! [`crate::net`]): machine death is a real `SIGKILL` — no goodbye
//! message, no destructor, the socket just goes quiet — which is the one
//! failure mode neither the simulator (scripted [`Event::Leave`]) nor
//! the thread backend (injected leave, graceful exit) can produce.
//!
//! ## Wire protocol (one JSON document per line)
//!
//! | direction | line | meaning |
//! |---|---|---|
//! | driver → node (first) | `{"init":{…}}` | full run config; see [`ProcInit`] |
//! | node → node (via driver) | `{"src":m,"dst":p,"body":…}` | routed protocol message; `body` is the [`codec`] payload encoding |
//! | driver → node | `{"ctrl":"leave","machine":m}` | peer `m` is gone (the driver's death notice after a kill) |
//! | driver → node | `{"ctrl":"shutdown"}` | drain and exit |
//! | node → driver | `{"metrics":{"machine":m,"registry":…}}` | this machine's [`crate::obs::MetricsRegistry`] snapshot, emitted right before `done`; the driver's [`ProcCluster::aggregate_obs`] merges them |
//! | node → driver (last) | `{"done":{…}}` | final report; see [`ProcDone`] |
//!
//! The driver ([`ProcCluster`]) is a star router, not a participant: it
//! forwards `src/dst` lines verbatim and never inspects `body`. Nodes
//! rebuild the *entire* deterministic problem — graph, partition,
//! relabeling, θ⁰ — from the init line alone (everything downstream of
//! `(topology, nodes, dim, problem_seed)` is a pure function), so the
//! init message stays a few hundred bytes no matter the problem size.
//!
//! A killed node's in-flight lines die with its pipes; survivors see
//! silence, the driver broadcasts the `leave` notice, and the tree
//! re-roots exactly as under simulated churn ([`super::node`] module
//! docs cover the fresh-tracker recovery semantics).

use std::io::{BufRead, Write};
use std::process::{Child, ChildStdin, Command, Stdio};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::time::{Duration, Instant};

use crate::consensus::solvers::QuadraticNode;
use crate::error::{Error, Result};
use crate::graph::{NodeId, Topology};
use crate::metrics::NetCounters;
use crate::net::codec::{ctx_from_json, ctx_to_json, payload_from_json, payload_to_json};
use crate::net::sim::{Event, Payload, Ticks, TraceEvent, TraceKind};
use crate::net::transport::Transport;
use crate::obs::TraceCtx;
use crate::penalty::SchemeKind;
use crate::util::json::{arr, num, obj, s, Json};

use super::node::NodeRuntime;
use super::runner::ClusterConfig;

// -- wire helpers ------------------------------------------------------------

fn req_u64(v: &Json, key: &str) -> Result<u64> {
    let x = v.req(key)?.as_f64().ok_or_else(|| {
        Error::Config(format!("proc wire: '{key}' is not a number"))
    })?;
    if x < 0.0 || x.fract() != 0.0 {
        return Err(Error::Config(format!("proc wire: '{key}' is not a u64")));
    }
    Ok(x as u64)
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    v.req(key)?
        .as_usize()
        .ok_or_else(|| Error::Config(format!("proc wire: '{key}' is not a usize")))
}

fn req_f64(v: &Json, key: &str) -> Result<f64> {
    v.req(key)?
        .as_f64()
        .ok_or_else(|| Error::Config(format!("proc wire: '{key}' is not a number")))
}

// -- init line ---------------------------------------------------------------

/// Everything a node process needs to reconstruct its machine: the
/// deterministic problem family (quadratic consensus,
/// [`crate::experiments::common::quad_problem_factory`]) keyed by
/// `(nodes, dim, problem_seed)`, the topology by name, and the cluster
/// knobs that must agree across every participant.
#[derive(Debug, Clone)]
pub struct ProcInit {
    pub machine: usize,
    pub machines: usize,
    pub nodes: usize,
    pub dim: usize,
    pub problem_seed: u64,
    pub topology: Topology,
    pub scheme: SchemeKind,
    pub tol: f64,
    pub patience: usize,
    pub warmup: usize,
    pub max_iters: usize,
    pub seed: u64,
    pub workers: usize,
    pub max_staleness: u64,
    /// wall milliseconds (real transport)
    pub silence_timeout: Ticks,
    pub collective_timeout: Ticks,
    pub fallback_after: u32,
    pub pipeline: u64,
    /// enable phase spans in the node (absent on the wire = `false`, so
    /// old drivers and old nodes interoperate)
    pub obs: bool,
    /// enable the causal round timeline in the node (absent = `false`).
    /// Per-process timelines surface through the aggregated
    /// `fadmm_timeline_*` retention counters on the metrics line; the
    /// full event stream stays in-process (sim/inproc export it).
    pub timeline: bool,
    /// enable the per-round convergence series in the node (absent =
    /// `false`; rows accumulate only at the tracker holder)
    pub series: bool,
}

impl ProcInit {
    pub fn to_json(&self) -> Json {
        obj(vec![("init", obj(vec![
            ("machine", num(self.machine as f64)),
            ("machines", num(self.machines as f64)),
            ("nodes", num(self.nodes as f64)),
            ("dim", num(self.dim as f64)),
            ("problem_seed", num(self.problem_seed as f64)),
            ("topology", s(self.topology.name())),
            ("scheme", s(self.scheme.name())),
            ("tol", num(self.tol)),
            ("patience", num(self.patience as f64)),
            ("warmup", num(self.warmup as f64)),
            ("max_iters", num(self.max_iters as f64)),
            ("seed", num(self.seed as f64)),
            ("workers", num(self.workers as f64)),
            ("max_staleness", num(self.max_staleness as f64)),
            ("silence_timeout", num(self.silence_timeout as f64)),
            ("collective_timeout", num(self.collective_timeout as f64)),
            ("fallback_after", num(self.fallback_after as f64)),
            ("pipeline", num(self.pipeline as f64)),
            ("obs", Json::Bool(self.obs)),
            ("timeline", Json::Bool(self.timeline)),
            ("series", Json::Bool(self.series)),
        ]))])
    }

    pub fn from_json(v: &Json) -> Result<ProcInit> {
        let b = v.req("init")?;
        let topology = Topology::parse(
            b.req("topology")?.as_str().ok_or_else(|| {
                Error::Config("proc wire: 'topology' is not a string".into())
            })?,
        )?;
        let scheme = SchemeKind::parse(b.req("scheme")?.as_str().ok_or_else(
            || Error::Config("proc wire: 'scheme' is not a string".into()),
        )?)?;
        Ok(ProcInit {
            machine: req_usize(b, "machine")?,
            machines: req_usize(b, "machines")?,
            nodes: req_usize(b, "nodes")?,
            dim: req_usize(b, "dim")?,
            problem_seed: req_u64(b, "problem_seed")?,
            topology,
            scheme,
            tol: req_f64(b, "tol")?,
            patience: req_usize(b, "patience")?,
            warmup: req_usize(b, "warmup")?,
            max_iters: req_usize(b, "max_iters")?,
            seed: req_u64(b, "seed")?,
            workers: req_usize(b, "workers")?,
            max_staleness: req_u64(b, "max_staleness")?,
            silence_timeout: req_u64(b, "silence_timeout")?,
            collective_timeout: req_u64(b, "collective_timeout")?,
            fallback_after: req_u64(b, "fallback_after")? as u32,
            pipeline: req_u64(b, "pipeline")?,
            obs: b.get("obs").and_then(|x| x.as_bool()).unwrap_or(false),
            timeline: b.get("timeline").and_then(|x| x.as_bool()).unwrap_or(false),
            series: b.get("series").and_then(|x| x.as_bool()).unwrap_or(false),
        })
    }

    pub fn cluster_config(&self) -> ClusterConfig {
        ClusterConfig {
            scheme: self.scheme,
            tol: self.tol,
            patience: self.patience,
            warmup: self.warmup,
            max_iters: self.max_iters,
            seed: self.seed,
            machines: self.machines,
            workers: self.workers,
            max_staleness: self.max_staleness,
            silence_timeout: self.silence_timeout,
            collective_timeout: self.collective_timeout,
            fallback_after: self.fallback_after,
            pipeline: self.pipeline,
            tracing: false,
            obs: self.obs,
            timeline: self.timeline,
            series: self.series,
            ..Default::default()
        }
    }
}

// -- done line ---------------------------------------------------------------

/// A node's final report line.
#[derive(Debug, Clone)]
pub struct ProcDone {
    pub machine: usize,
    pub iterations: usize,
    pub converged: bool,
    pub is_holder: bool,
    pub final_root: usize,
    /// `[start, end)` of this machine's relabeled node span.
    pub span: (usize, usize),
    /// flat `span-len × dim` θ at the stop round
    pub thetas: Vec<f64>,
}

impl ProcDone {
    fn to_json(&self) -> Json {
        obj(vec![("done", obj(vec![
            ("machine", num(self.machine as f64)),
            ("iterations", num(self.iterations as f64)),
            ("converged", Json::Bool(self.converged)),
            ("is_holder", Json::Bool(self.is_holder)),
            ("root", num(self.final_root as f64)),
            ("span", arr(vec![num(self.span.0 as f64), num(self.span.1 as f64)])),
            ("thetas", arr(self.thetas.iter().map(|&x| num(x)).collect())),
        ]))])
    }

    fn from_json(v: &Json) -> Result<ProcDone> {
        let b = v.req("done")?;
        let span = b.req("span")?.as_arr().ok_or_else(|| {
            Error::Config("proc wire: 'span' is not an array".into())
        })?;
        if span.len() != 2 {
            return Err(Error::Config("proc wire: 'span' is not a pair".into()));
        }
        let thetas = b
            .req("thetas")?
            .as_arr()
            .ok_or_else(|| Error::Config("proc wire: 'thetas' is not an array".into()))?
            .iter()
            .map(|x| {
                x.as_f64().ok_or_else(|| {
                    Error::Config("proc wire: non-numeric theta".into())
                })
            })
            .collect::<Result<Vec<f64>>>()?;
        Ok(ProcDone {
            machine: req_usize(b, "machine")?,
            iterations: req_usize(b, "iterations")?,
            converged: b.req("converged")?.as_bool().ok_or_else(|| {
                Error::Config("proc wire: 'converged' is not a bool".into())
            })?,
            is_holder: b.req("is_holder")?.as_bool().ok_or_else(|| {
                Error::Config("proc wire: 'is_holder' is not a bool".into())
            })?,
            final_root: req_usize(b, "root")?,
            span: (
                span[0].as_usize().ok_or_else(|| {
                    Error::Config("proc wire: bad span start".into())
                })?,
                span[1].as_usize().ok_or_else(|| {
                    Error::Config("proc wire: bad span end".into())
                })?,
            ),
            thetas,
        })
    }
}

// -- the node-side transport -------------------------------------------------

/// [`Transport`] over the process's own stdin/stdout. A background
/// thread turns stdin lines into [`Event`]s on a channel; sends encode
/// through [`crate::net::codec`] and write-and-flush one line. Timer
/// logic is identical to the in-process channel transport: arrived
/// traffic first, then the earliest due timer, blocking with a timeout
/// derived from the next deadline. Stdin EOF (driver gone, or we were
/// orphaned by a kill) disconnects the channel; a final timer drain
/// lets fallback paths finish before `pop` returns `None`.
pub struct StdioTransport {
    id: NodeId,
    epoch: Instant,
    rx: Receiver<Event>,
    timers: Vec<(Ticks, u64, Event)>,
    seq: u64,
    /// frames minted so far (the next [`TraceCtx::seq`])
    frames: u64,
    counters: NetCounters,
}

impl StdioTransport {
    /// Wrap this process's stdio; `rx` must be fed by
    /// [`spawn_stdin_reader`].
    fn new(id: NodeId, rx: Receiver<Event>) -> StdioTransport {
        StdioTransport {
            id,
            epoch: Instant::now(),
            rx,
            timers: Vec::new(),
            seq: 0,
            frames: 0,
            counters: NetCounters::default(),
        }
    }

    fn next_timer(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, t) in self.timers.iter().enumerate() {
            match best {
                None => best = Some(i),
                Some(b) => {
                    if (t.0, t.1) < (self.timers[b].0, self.timers[b].1) {
                        best = Some(i);
                    }
                }
            }
        }
        best
    }

    fn pop_after_disconnect(&mut self) -> Option<(Ticks, Event)> {
        let i = self.next_timer()?;
        let due = self.timers[i].0;
        let now = self.now();
        if due > now {
            std::thread::sleep(Duration::from_millis(due - now));
        }
        let (_, _, event) = self.timers.remove(i);
        Some((self.now(), event))
    }
}

impl Transport for StdioTransport {
    fn now(&self) -> Ticks {
        self.epoch.elapsed().as_millis() as Ticks
    }

    fn send(&mut self, src: NodeId, dst: NodeId, payload: Payload, _reliable: bool)
        -> TraceCtx
    {
        self.counters.sent += 1;
        let ctx = TraceCtx { round: payload.stamp(), machine: src, seq: self.frames };
        self.frames += 1;
        let line = obj(vec![
            ("src", num(src as f64)),
            ("dst", num(dst as f64)),
            ("ctx", ctx_to_json(ctx)),
            ("body", payload_to_json(&payload)),
        ])
        .to_string();
        let out = std::io::stdout();
        let mut h = out.lock();
        // a broken pipe means the driver died — the run is over anyway,
        // and stdin EOF will end the event loop; don't panic mid-send
        let _ = writeln!(h, "{line}");
        let _ = h.flush();
        ctx
    }

    fn schedule(&mut self, at: Ticks, event: Event) {
        let seq = self.seq;
        self.seq += 1;
        self.timers.push((at.max(self.now()), seq, event));
    }

    fn pop(&mut self) -> Option<(Ticks, Event)> {
        loop {
            match self.rx.try_recv() {
                Ok(ev) => return Some((self.now(), ev)),
                Err(TryRecvError::Disconnected) => return self.pop_after_disconnect(),
                Err(TryRecvError::Empty) => {}
            }
            match self.next_timer() {
                Some(i) if self.timers[i].0 <= self.now() => {
                    let (_, _, event) = self.timers.remove(i);
                    return Some((self.now(), event));
                }
                Some(i) => {
                    // saturating: the clock may tick past the deadline
                    // between the guard above and this read
                    let wait = self.timers[i].0.saturating_sub(self.now());
                    match self.rx.recv_timeout(Duration::from_millis(wait)) {
                        Ok(ev) => return Some((self.now(), ev)),
                        Err(RecvTimeoutError::Timeout) => continue,
                        Err(RecvTimeoutError::Disconnected) => {
                            return self.pop_after_disconnect()
                        }
                    }
                }
                None => match self.rx.recv() {
                    Ok(ev) => return Some((self.now(), ev)),
                    Err(_) => return None,
                },
            }
        }
    }

    fn advance_to(&mut self, _at: Ticks) {}

    // process nodes keep counters but no trace (nobody collects it)
    fn record(&mut self, _kind: TraceKind) {}

    fn note_stale_read(&mut self, _node: NodeId, _nbr: NodeId, ideal: u64,
                       used: u64, stale: u64) {
        if used < ideal {
            self.counters.stale_reads += 1;
            if used + stale < ideal {
                self.counters.fallback_reads += 1;
            }
        }
    }

    fn note_delivered(&mut self, _src: NodeId, _dst: NodeId, _payload: &Payload) {
        self.counters.delivered += 1;
    }

    fn note_dead_delivery(&mut self, _src: NodeId, _dst: NodeId, _payload: &Payload) {
        self.counters.dropped_dead += 1;
    }

    fn counters(&mut self) -> &mut NetCounters {
        &mut self.counters
    }

    fn counters_snapshot(&self) -> NetCounters {
        self.counters
    }

    fn take_trace(&mut self) -> Vec<TraceEvent> {
        Vec::new()
    }
}

/// Feed stdin lines into the transport's event channel until EOF or an
/// explicit shutdown ctrl line. Runs on its own thread because the main
/// thread blocks in [`Transport::pop`].
fn spawn_stdin_reader(me: usize, tx: Sender<Event>) {
    std::thread::Builder::new()
        .name(format!("fadmm-stdin-{me}"))
        .spawn(move || {
            let stdin = std::io::stdin();
            for line in stdin.lock().lines() {
                let Ok(line) = line else { break };
                let line = line.trim();
                if line.is_empty() {
                    continue;
                }
                let Some(event) = parse_wire_line(line) else {
                    eprintln!("fadmm-node {me}: unparseable line skipped");
                    continue;
                };
                let shutdown = matches!(event, Event::Join { node } if node == SHUTDOWN);
                if shutdown || tx.send(event).is_err() {
                    break;
                }
            }
            // tx drops here → the transport's channel disconnects
        })
        .expect("spawn stdin reader");
}

/// Sentinel for the shutdown ctrl line (never a valid machine id —
/// the reader exits instead of forwarding it).
const SHUTDOWN: usize = usize::MAX;

/// Parse one driver → node line into an [`Event`] (`None` = malformed).
fn parse_wire_line(line: &str) -> Option<Event> {
    let v = Json::parse(line).ok()?;
    if let Some(ctrl) = v.get("ctrl").and_then(|c| c.as_str()) {
        return match ctrl {
            "leave" => Some(Event::Leave { node: v.get("machine")?.as_usize()? }),
            "shutdown" => Some(Event::Join { node: SHUTDOWN }),
            _ => None,
        };
    }
    let src = v.get("src")?.as_usize()?;
    let dst = v.get("dst")?.as_usize()?;
    let payload = payload_from_json(v.get("body")?).ok()?;
    // absent ctx (old peer) decodes to the zero context, not a parse error
    let ctx = ctx_from_json(v.get("ctx")).ok()?;
    Some(Event::Deliver { src, dst, payload, dup: false, ctx })
}

/// The `fadmm-node` binary body: read the init line, run one machine to
/// termination, emit the done line. Returns the process exit code.
pub fn node_main() -> i32 {
    let mut first = String::new();
    if std::io::stdin().read_line(&mut first).is_err() || first.trim().is_empty() {
        eprintln!("fadmm-node: missing init line");
        return 2;
    }
    let init = match Json::parse(first.trim()).and_then(|v| ProcInit::from_json(&v)) {
        Ok(i) => i,
        Err(e) => {
            eprintln!("fadmm-node: bad init line: {e}");
            return 2;
        }
    };
    // telemetry-on runs get a crash snapshot: a panicking node writes
    // its global-sink state next to the process before dying, so a
    // wedged cluster leaves per-machine forensics behind
    if init.obs {
        crate::obs::install_crash_hook(std::path::PathBuf::from(format!(
            "fadmm-node.{}.crash.json", init.machine,
        )));
    }
    let graph = match init.topology.build(init.nodes) {
        Ok(g) => g,
        Err(e) => {
            eprintln!("fadmm-node: bad topology: {e}");
            return 2;
        }
    };
    let factory = crate::experiments::common::quad_problem_factory(
        init.nodes, init.dim, init.problem_seed,
    );
    let (tx, rx) = std::sync::mpsc::channel();
    let net = StdioTransport::new(init.machine, rx);
    let rt: NodeRuntime<QuadraticNode, StdioTransport> = match NodeRuntime::new(
        graph, init.cluster_config(), init.machine, net, &*factory,
    ) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("fadmm-node: config rejected: {e}");
            return 2;
        }
    };
    spawn_stdin_reader(init.machine, tx);
    let report = rt.run();
    // metric snapshot first, done line last: the driver treats `done`
    // as this machine's terminal line
    let metrics = obj(vec![("metrics", obj(vec![
        ("machine", num(report.machine as f64)),
        ("registry", report.obs.to_json()),
    ]))]);
    println!("{}", metrics.to_string());
    let done = ProcDone {
        machine: report.machine,
        iterations: report.iterations,
        converged: report.converged,
        is_holder: report.is_holder,
        final_root: report.final_root,
        span: (report.span.start, report.span.end),
        thetas: report.thetas_flat.clone(),
    };
    println!("{}", done.to_json().to_string());
    let _ = std::io::stdout().flush();
    0
}

// -- the driver --------------------------------------------------------------

/// Star router over `fadmm-node` child processes: spawns them, writes
/// their init lines, forwards routed messages, records done lines, and
/// can SIGKILL a machine mid-run.
pub struct ProcCluster {
    children: Vec<Child>,
    stdins: Vec<Option<ChildStdin>>,
    from_children: Receiver<(usize, String)>,
    alive: Vec<bool>,
    pub done: Vec<Option<ProcDone>>,
    /// per-machine metric snapshots (the `metrics` wire line); a killed
    /// machine's slot stays `None`
    pub metrics: Vec<Option<crate::obs::MetricsRegistry>>,
    /// routed (node → node) lines forwarded so far — tests use it as a
    /// progress proxy for "mid-run"
    pub routed: u64,
}

impl ProcCluster {
    /// Spawn one `fadmm-node` per init and deliver the init lines.
    /// `bin` is the node binary path (tests use
    /// `env!("CARGO_BIN_EXE_fadmm-node")`).
    pub fn spawn(bin: &str, inits: &[ProcInit]) -> std::io::Result<ProcCluster> {
        let n = inits.len();
        let (tx, from_children) = std::sync::mpsc::channel::<(usize, String)>();
        let mut children = Vec::with_capacity(n);
        let mut stdins = Vec::with_capacity(n);
        for (m, init) in inits.iter().enumerate() {
            let mut child = Command::new(bin)
                .stdin(Stdio::piped())
                .stdout(Stdio::piped())
                .stderr(Stdio::inherit())
                .spawn()?;
            let mut stdin = child.stdin.take().expect("piped stdin");
            let stdout = child.stdout.take().expect("piped stdout");
            writeln!(stdin, "{}", init.to_json().to_string())?;
            stdin.flush()?;
            let tx = tx.clone();
            std::thread::Builder::new()
                .name(format!("fadmm-route-{m}"))
                .spawn(move || {
                    let reader = std::io::BufReader::new(stdout);
                    for line in reader.lines() {
                        let Ok(line) = line else { break };
                        if tx.send((m, line)).is_err() {
                            break;
                        }
                    }
                })
                .expect("spawn route reader");
            children.push(child);
            stdins.push(Some(stdin));
        }
        Ok(ProcCluster {
            children,
            stdins,
            from_children,
            alive: vec![true; n],
            done: vec![None; n],
            metrics: vec![None; n],
            routed: 0,
        })
    }

    fn write_line(&mut self, m: usize, line: &str) {
        if let Some(stdin) = self.stdins[m].as_mut() {
            // a dead child's pipe errors; that's equivalent to a lost
            // message to a dead machine — drop it
            let _ = writeln!(stdin, "{line}");
            let _ = stdin.flush();
        }
    }

    /// SIGKILL machine `m` and broadcast its death notice to survivors.
    pub fn kill(&mut self, m: usize) {
        if !self.alive[m] {
            return;
        }
        let _ = self.children[m].kill();
        let _ = self.children[m].wait();
        self.alive[m] = false;
        self.stdins[m] = None;
        let notice =
            obj(vec![("ctrl", s("leave")), ("machine", num(m as f64))]).to_string();
        for p in 0..self.alive.len() {
            if self.alive[p] {
                self.write_line(p, &notice);
            }
        }
    }

    /// Route until every live machine has reported done (or its pipe
    /// closed), or `deadline` passes. Returns `true` on a clean finish.
    pub fn route_until_done(&mut self, deadline: Duration) -> bool {
        let t0 = Instant::now();
        loop {
            let finished = (0..self.alive.len())
                .all(|m| !self.alive[m] || self.done[m].is_some());
            if finished {
                return true;
            }
            if t0.elapsed() > deadline {
                return false;
            }
            let msg = match self.from_children.recv_timeout(Duration::from_millis(200)) {
                Ok(msg) => msg,
                Err(RecvTimeoutError::Timeout) => continue,
                // every reader thread gone: nothing more will arrive
                Err(RecvTimeoutError::Disconnected) => {
                    return (0..self.alive.len())
                        .all(|m| !self.alive[m] || self.done[m].is_some());
                }
            };
            self.handle_line(msg.0, &msg.1);
        }
    }

    /// Route lines until `self.routed >= target` routed messages have
    /// been forwarded (a progress proxy), or the deadline passes.
    pub fn route_until_traffic(&mut self, target: u64, deadline: Duration) -> bool {
        let t0 = Instant::now();
        while self.routed < target {
            if t0.elapsed() > deadline {
                return false;
            }
            match self.from_children.recv_timeout(Duration::from_millis(200)) {
                Ok((m, line)) => self.handle_line(m, &line),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => return false,
            }
        }
        true
    }

    fn handle_line(&mut self, from: usize, line: &str) {
        let Ok(v) = Json::parse(line) else {
            eprintln!("proc driver: machine {from} wrote an unparseable line");
            return;
        };
        if v.get("done").is_some() {
            match ProcDone::from_json(&v) {
                Ok(d) => self.done[from] = Some(d),
                Err(e) => eprintln!("proc driver: bad done line from {from}: {e}"),
            }
            return;
        }
        if let Some(m) = v.get("metrics") {
            match m.req("registry")
                .and_then(crate::obs::MetricsRegistry::from_json)
            {
                Ok(reg) => self.metrics[from] = Some(reg),
                Err(e) => {
                    eprintln!("proc driver: bad metrics line from {from}: {e}")
                }
            }
            return;
        }
        let Some(dst) = v.get("dst").and_then(|d| d.as_usize()) else {
            eprintln!("proc driver: machine {from} wrote a routable line \
                       with no dst");
            return;
        };
        if dst < self.alive.len() && self.alive[dst] {
            self.write_line(dst, line);
            self.routed += 1;
        }
    }

    /// Merge every reporting machine's metric snapshot into one
    /// cluster-wide registry — the process-transport twin of
    /// [`super::node::aggregate_obs`]. Counters and histograms add
    /// across machines; killed machines simply contribute nothing.
    pub fn aggregate_obs(&self) -> crate::obs::MetricsRegistry {
        let mut agg = crate::obs::MetricsRegistry::new(false);
        for reg in self.metrics.iter().flatten() {
            agg.merge(reg);
        }
        agg
    }

    /// Send every survivor a shutdown ctrl, close pipes and reap.
    pub fn shutdown(mut self) -> Vec<Option<ProcDone>> {
        let bye = obj(vec![("ctrl", s("shutdown"))]).to_string();
        for m in 0..self.alive.len() {
            if self.alive[m] {
                self.write_line(m, &bye);
            }
        }
        self.stdins.clear(); // EOF for anyone ignoring the ctrl line
        for (m, mut child) in self.children.drain(..).enumerate() {
            if self.alive[m] {
                let _ = child.wait();
            }
        }
        self.done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn init(machine: usize) -> ProcInit {
        ProcInit {
            machine,
            machines: 3,
            nodes: 12,
            dim: 2,
            problem_seed: 41,
            topology: Topology::Ring,
            scheme: SchemeKind::Rb,
            tol: 1e-4,
            patience: 3,
            warmup: 5,
            max_iters: 60,
            seed: 11,
            workers: 1,
            max_staleness: 0,
            silence_timeout: 5_000,
            collective_timeout: 5_000,
            fallback_after: 3,
            pipeline: 2,
            obs: false,
            timeline: false,
            series: false,
        }
    }

    #[test]
    fn init_line_round_trips() {
        let a = init(1);
        let b = ProcInit::from_json(&Json::parse(&a.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(a.machine, b.machine);
        assert_eq!(a.machines, b.machines);
        assert_eq!(a.nodes, b.nodes);
        assert_eq!(a.dim, b.dim);
        assert_eq!(a.problem_seed, b.problem_seed);
        assert_eq!(a.topology, b.topology);
        assert_eq!(a.scheme, b.scheme);
        assert_eq!(a.tol, b.tol);
        assert_eq!(a.max_iters, b.max_iters);
        assert_eq!(a.silence_timeout, b.silence_timeout);
        assert_eq!(a.fallback_after, b.fallback_after);
    }

    #[test]
    fn done_line_round_trips() {
        let d = ProcDone {
            machine: 2,
            iterations: 37,
            converged: true,
            is_holder: false,
            final_root: 1,
            span: (8, 12),
            thetas: vec![1.5, -0.25, 0.0, 3.0e-7, -2.0, 8.0, 1.0, -1.0],
        };
        let r = ProcDone::from_json(&Json::parse(&d.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(d.machine, r.machine);
        assert_eq!(d.iterations, r.iterations);
        assert_eq!(d.converged, r.converged);
        assert_eq!(d.is_holder, r.is_holder);
        assert_eq!(d.final_root, r.final_root);
        assert_eq!(d.span, r.span);
        assert_eq!(d.thetas, r.thetas);
    }

    #[test]
    fn wire_lines_parse_into_events() {
        let leave = parse_wire_line(r#"{"ctrl":"leave","machine":2}"#).unwrap();
        assert_eq!(leave, Event::Leave { node: 2 });
        // ctx absent: an old peer's line still parses, with the zero ctx
        let routed = obj(vec![
            ("src", num(0.0)),
            ("dst", num(1.0)),
            ("body", payload_to_json(&Payload::Stop { round: 9, converged: true })),
        ])
        .to_string();
        match parse_wire_line(&routed).unwrap() {
            Event::Deliver { src: 0, dst: 1, payload, dup: false, ctx } => {
                assert_eq!(payload, Payload::Stop { round: 9, converged: true });
                assert_eq!(ctx, TraceCtx::default());
            }
            other => panic!("unexpected {other:?}"),
        }
        // ctx present: carried through verbatim
        let traced = obj(vec![
            ("src", num(0.0)),
            ("dst", num(1.0)),
            ("ctx", ctx_to_json(TraceCtx { round: 9, machine: 0, seq: 42 })),
            ("body", payload_to_json(&Payload::Stop { round: 9, converged: true })),
        ])
        .to_string();
        match parse_wire_line(&traced).unwrap() {
            Event::Deliver { ctx, .. } => {
                assert_eq!(ctx, TraceCtx { round: 9, machine: 0, seq: 42 });
            }
            other => panic!("unexpected {other:?}"),
        }
        assert!(parse_wire_line("not json").is_none());
        assert!(parse_wire_line(r#"{"ctrl":"warp"}"#).is_none());
    }
}
