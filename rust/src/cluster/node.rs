//! One machine of the cluster protocol as a *self-driving* runtime.
//!
//! [`super::runner::ClusterRunner`] is an omniscient single-threaded
//! driver: it owns every [`MachineRt`], pops one shared event queue and
//! advances whichever machine an event addresses. A real deployment has
//! no such driver — each machine owns exactly its own state and learns
//! about the rest of the cluster only through its [`Transport`].
//! [`NodeRuntime`] is that machine: the same `Solve → Reduce → FoldWait`
//! state machine, the same boundary-cache protocol, and the same
//! tree-collective fold/verdict/retransmit machinery, but scoped to one
//! machine and driven by its own event loop. The in-process backend
//! ([`super::inproc`]) runs one per thread over a channel mesh; the
//! process backend ([`super::proc`]) runs one per OS process over stdio.
//!
//! ## Deltas vs the simulated driver (documented, deliberate)
//!
//! * **Tree collective only.** Push-sum gossip, the machine-level
//!   activity rule, scripted handoffs and dormant starts are
//!   simulator-study features; [`NodeRuntime::new`] rejects them.
//! * **No interior/boundary phase overlap.** The overlap exists to keep
//!   a single driver thread busy; here every machine already runs on its
//!   own thread/process, so phases run unsplit (bit-identical by the
//!   overlap parity tests).
//! * **Explicit stop flood.** The simulator halts the instant the stop
//!   rule fires; here the tracker holder broadcasts [`Payload::Stop`] to
//!   every live machine and each receiver re-floods once before exiting,
//!   so termination is a protocol event with a real cost.
//! * **Explicit tracker recovery.** A gracefully departing holder
//!   serializes the [`crate::kernel::StopSnapshot`] to its successor
//!   (the same `Checker` message the simulator ships). After a *kill*
//!   (SIGKILL, dead thread) there is nothing to ship: the survivors
//!   re-root and the new root adopts a **fresh** tracker whose cursor
//!   starts at its oldest buffered round — recorded curves restart, the
//!   run still terminates. Zero-fault runs never take either path.
//!
//! At zero faults with timeouts too generous to fire, the protocol
//! schedule is message-driven and identical to the simulator's, so the
//! per-round arithmetic — and therefore the committed iteration count —
//! matches the [`ClusterRunner`] oracle exactly; the transport suites
//! assert that.

use std::collections::{BTreeMap, BTreeSet};
use std::ops::Range;

use crate::consensus::LocalSolver;
use crate::error::{Error, Result};
use crate::graph::{rcm_order, relabel_graph, Graph, NodeId, Relabel};
use crate::kernel::StopTracker;
use crate::metrics::{IterStats, NetCounters, RunningFold, StatPartial};
use crate::net::sim::{Event, Payload, TimerKind, TraceKind};
use crate::net::transport::{send_traced, Transport};
use crate::net::TopologyController;
use crate::obs::{Phase, RoundRow};
use crate::pool::PhasePool;

use super::collective::{build_tree_rooted, subtree, CollectiveKind, TreeTopology};
use super::machine::{MPhase, MachineRt};
use super::partition::MachinePartition;
use super::runner::ClusterConfig;

/// What one machine knows when its run ends.
#[derive(Debug)]
pub struct NodeReport {
    pub machine: usize,
    /// Committed rounds — authoritative only on the tracker holder
    /// (elsewhere it echoes the stop flood's round count).
    pub iterations: usize,
    pub converged: bool,
    /// Whether this machine held the [`StopTracker`] at exit.
    pub is_holder: bool,
    /// Tree root as this machine last saw it.
    pub final_root: usize,
    /// This machine's (relabeled) node slice.
    pub span: Range<usize>,
    /// Flat `span.len() × dim` θ at the stop round.
    pub thetas_flat: Vec<f64>,
    pub dim: usize,
    pub counters: NetCounters,
    /// This machine's metric registry (phase spans, transport counters,
    /// trace accounting). The backends merge one per machine into the
    /// cluster-wide aggregate.
    pub obs: crate::obs::MetricsRegistry,
    /// This machine's slice of the causal round timeline (empty unless
    /// enabled). The backends concatenate the per-machine slices — the
    /// Chrome export keys tracks by `machine`, so order between
    /// machines is irrelevant.
    pub timeline: Vec<crate::obs::TlEvent>,
    pub timeline_dropped: u64,
    /// Per-round convergence series — non-empty only on the tracker
    /// holder (commits happen there).
    pub series: Vec<crate::obs::RoundRow>,
    pub series_dropped: u64,
}

/// Merge every machine's registry into one cluster-wide view: counters
/// and histograms add across machines, gauges are last-wins (the
/// outcome gauges agree across machines at zero faults — everyone
/// echoes the same stop flood). Both real-transport backends and the
/// `repro cluster --obs` report path aggregate through this.
pub fn aggregate_obs(reports: &[NodeReport]) -> crate::obs::MetricsRegistry {
    let mut agg = crate::obs::MetricsRegistry::new(false);
    for rep in reports {
        agg.merge(&rep.obs);
    }
    agg
}

/// One machine of the cluster protocol over a real transport (see
/// module docs).
pub struct NodeRuntime<S: LocalSolver + Send, T: Transport> {
    cfg: ClusterConfig,
    /// relabeled node graph (every machine derives the identical one)
    graph: Graph,
    part: MachinePartition,
    /// local belief about peer liveness (updated by `Leave` events)
    ctrl: TopologyController,
    net: T,
    pool: PhasePool,
    mach: MachineRt<S>,
    me: usize,
    topo: TreeTopology,
    /// rootward partials buffered at this machine, per round
    inbox: BTreeMap<u64, BTreeMap<usize, Vec<StatPartial>>>,
    sent_up: BTreeSet<u64>,
    /// the designated-recorder state, present iff this machine holds it
    tracker: Option<StopTracker>,
    cursor: u64,
    pending_wake: bool,
    stopped: bool,
    stop_round: Option<u64>,
    flood_converged: bool,
    dim: usize,
    obs: crate::obs::MetricsRegistry,
    probes: crate::obs::RuntimeProbes,
    timeline: crate::obs::Timeline,
    series: crate::obs::RoundSeries,
}

impl<S: LocalSolver + Send, T: Transport> NodeRuntime<S, T> {
    /// Build machine `me` of an `cfg.machines`-way split of `graph`.
    /// Every participant must construct from identical `(graph, cfg)` —
    /// the partition, relabeling and θ⁰ seeding are pure functions of
    /// them, which is what lets a process rebuild its slice from a tiny
    /// init message.
    pub fn new(graph: Graph, cfg: ClusterConfig, me: usize, net: T,
               factory: &(dyn Fn(NodeId) -> S + Send + Sync))
               -> Result<NodeRuntime<S, T>> {
        if !matches!(cfg.collective, CollectiveKind::Tree) {
            return Err(Error::Config(
                "node runtime: only the tree collective is supported".into(),
            ));
        }
        if cfg.activity.is_some() || cfg.handoff.is_some() {
            return Err(Error::Config(
                "node runtime: activity rule / scripted handoff are \
                 simulator-only features".into(),
            ));
        }
        let n = graph.len();
        if n == 0 {
            return Err(Error::Config("node runtime: empty graph".into()));
        }
        let dim = factory(0).dim();
        let order: Vec<NodeId> = match cfg.relabel {
            Relabel::Identity => (0..n).collect(),
            Relabel::Rcm => rcm_order(&graph),
        };
        let relabeled = match cfg.relabel {
            Relabel::Identity => graph,
            Relabel::Rcm => relabel_graph(&graph, &order)?,
        };
        let part = MachinePartition::new(&relabeled, cfg.machines.max(1))?;
        let mcount = part.len();
        if me >= mcount {
            return Err(Error::Config(format!(
                "node runtime: machine {me} out of range (machines: {mcount})"
            )));
        }
        let workers = if cfg.workers > 0 {
            cfg.workers
        } else {
            std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
        };
        let mach = MachineRt::build(&relabeled, &part, me, workers, &order,
                                    factory, dim, cfg.scheme, cfg.params,
                                    cfg.seed, false, cfg.max_iters);
        let pool = PhasePool::new(mach.shards.len().max(1));
        let ctrl = TopologyController::new(part.quotient.clone(), None);
        let topo = build_tree_rooted(ctrl.view(), None);
        let tracker = (topo.root == me).then(|| {
            StopTracker::new(dim, cfg.tol, cfg.patience, cfg.warmup,
                             cfg.max_iters, cfg.params.eta0)
        });
        let mut obs = crate::obs::MetricsRegistry::new(
            cfg.obs || crate::obs::global_spans_enabled(),
        );
        let probes = crate::obs::RuntimeProbes::register(&mut obs);
        let timeline = crate::obs::Timeline::new(
            cfg.timeline || crate::obs::global_timeline_enabled(),
        );
        let series = crate::obs::RoundSeries::new(
            cfg.series || crate::obs::global_series_enabled(),
        );
        Ok(NodeRuntime {
            cfg,
            graph: relabeled,
            part,
            ctrl,
            net,
            pool,
            mach,
            me,
            topo,
            inbox: BTreeMap::new(),
            sent_up: BTreeSet::new(),
            tracker,
            cursor: 0,
            pending_wake: false,
            stopped: false,
            stop_round: None,
            flood_converged: false,
            dim,
            obs,
            probes,
            timeline,
            series,
        })
    }

    /// Drive this machine to termination: stop flood received/sent,
    /// round budget exhausted at the holder, or transport closed.
    pub fn run(mut self) -> NodeReport {
        // reliable boundary handshake, exactly like the driver's
        self.send_state(0, 0);
        self.try_advance(false);
        self.try_finish_holder();
        while !self.stopped {
            let Some((at, event)) = self.net.pop() else { break };
            match &event {
                Event::Wake { node: _, epoch } => {
                    if *epoch != self.mach.wake_epoch || !self.mach.running() {
                        continue;
                    }
                }
                Event::Timer { kind: TimerKind::Collective, epoch, .. } => {
                    if *epoch != self.mach.coll_epoch
                        || matches!(self.mach.phase, MPhase::Dormant | MPhase::Dead)
                    {
                        continue;
                    }
                }
                _ => {}
            }
            match event {
                Event::Deliver { src, dst: _, payload, dup: _, ctx } => {
                    if self.timeline.enabled() {
                        self.timeline.recv(at, self.me, ctx, payload.kind_name());
                    }
                    self.on_deliver(src, payload);
                }
                Event::Wake { .. } => {
                    self.net.counters().timeouts += 1;
                    self.mach.timeout_armed = false;
                    self.try_advance(true);
                }
                Event::Timer { kind: TimerKind::Collective, .. } => {
                    self.on_coll_timer();
                }
                // gossip timers / joins never occur on this runtime
                Event::Timer { kind: TimerKind::Gossip, .. } => {}
                Event::Join { .. } => {}
                Event::Leave { node } => self.on_leave(node),
            }
            if self.pending_wake {
                self.pending_wake = false;
                if self.mach.running() {
                    self.try_advance(false);
                }
            }
            self.try_finish_holder();
        }
        self.finish()
    }

    fn finish(mut self) -> NodeReport {
        let target = self.stop_round.unwrap_or(u64::MAX);
        let iterations = match &self.tracker {
            Some(tr) => tr.iterations.max(self.cursor as usize),
            None => self.stop_round.map(|r| r as usize + 1).unwrap_or(0),
        };
        let converged = self
            .tracker
            .as_ref()
            .map(|tr| tr.converged)
            .unwrap_or(self.flood_converged);
        // take_trace first: draining stamps `trace_dropped` into the
        // transport's counters before we snapshot them
        let trace = self.net.take_trace();
        let counters = self.net.counters_snapshot();
        self.obs.set_gauge(self.probes.iterations, iterations as f64);
        self.obs
            .set_gauge(self.probes.converged, if converged { 1.0 } else { 0.0 });
        let machines = self.obs.gauge("fadmm_machines");
        self.obs.set_gauge(machines, self.part.len() as f64);
        self.obs.absorb_net(&counters);
        self.obs.absorb_trace(trace.len(), counters.trace_dropped);
        let timeline = self.timeline.drain();
        let timeline_dropped = self.timeline.dropped();
        let series = self.series.drain();
        let series_dropped = self.series.dropped();
        self.obs.absorb_timeline(timeline.len(), timeline_dropped,
                                 series.len(), series_dropped);
        crate::obs::global_merge(&self.obs);
        if crate::obs::global_timeline_enabled() {
            crate::obs::global_timeline_merge(timeline.clone());
        }
        if crate::obs::global_series_enabled() {
            crate::obs::global_series_merge(series.clone(), series_dropped);
        }
        NodeReport {
            machine: self.me,
            iterations,
            converged,
            is_holder: self.tracker.is_some(),
            final_root: self.topo.root,
            span: self.mach.span.clone(),
            thetas_flat: self.mach.snapshot_for(target, self.dim),
            dim: self.dim,
            counters,
            obs: self.obs,
            timeline,
            timeline_dropped,
            series,
            series_dropped,
        }
    }

    // -- the machine state machine (mirrors the driver's try_advance) -------

    fn try_advance(&mut self, mut force: bool) {
        loop {
            if self.stopped {
                return;
            }
            match self.mach.phase {
                MPhase::Dormant | MPhase::Dead | MPhase::Done => return,
                MPhase::Solve => {
                    let t = self.mach.t;
                    if t > self.mach.horizon + self.cfg.pipeline {
                        return; // woken when the verdict horizon advances
                    }
                    if !self.ready_a(force) {
                        self.arm_silence();
                        return;
                    }
                    self.resolve_a();
                    let span = self.obs.span();
                    self.mach.run_phase_a(&self.graph, t, &self.pool,
                                          self.cfg.exec);
                    self.mach.snapshot(t);
                    let ns = self.obs.end(self.probes.solve, span);
                    if self.timeline.enabled() {
                        self.timeline
                            .phase(self.net.now(), self.me, t, Phase::Solve, ns);
                    }
                    self.mach.phase = MPhase::Reduce;
                    let io = self.obs.span();
                    self.send_boundary_theta(t + 1);
                    let ns = self.obs.end(self.probes.boundary_io, io);
                    if self.timeline.enabled() {
                        self.timeline.phase(self.net.now(), self.me, t,
                                            Phase::BoundaryIo, ns);
                    }
                }
                MPhase::Reduce => {
                    if !self.ready_b(force) {
                        self.arm_silence();
                        return;
                    }
                    self.resolve_b();
                    let t = self.mach.t;
                    let span = self.obs.span();
                    self.mach.run_phase_b(&self.graph, t, &self.pool,
                                          self.cfg.exec);
                    let ns = self.obs.end(self.probes.reduce, span);
                    if self.timeline.enabled() {
                        self.timeline
                            .phase(self.net.now(), self.me, t, Phase::Reduce, ns);
                    }
                    self.mach.phase = MPhase::FoldWait;
                    self.tree_deposit(t);
                    if self.stopped {
                        return;
                    }
                }
                MPhase::FoldWait => {
                    let t = self.mach.t;
                    let verdict = self.mach.verdicts.get(&t).copied();
                    if self.mach.needs_globals && verdict.is_none() {
                        return; // woken by the verdict (or its fallback)
                    }
                    let globals = verdict.unwrap_or(self.mach.latest_globals);
                    self.refresh_links();
                    let span = self.obs.span();
                    self.mach.run_phase_c(&self.graph, t, globals);
                    let ns = self.obs.end(self.probes.observe, span);
                    if self.timeline.enabled() {
                        self.timeline
                            .phase(self.net.now(), self.me, t, Phase::Observe, ns);
                    }
                    let io = self.obs.span();
                    self.send_boundary_eta(t + 1);
                    let ns = self.obs.end(self.probes.boundary_io, io);
                    if self.timeline.enabled() {
                        self.timeline.phase(self.net.now(), self.me, t,
                                            Phase::BoundaryIo, ns);
                    }
                    self.mach.t += 1;
                    self.mach.phase = if self.mach.t >= self.cfg.max_iters as u64 {
                        MPhase::Done
                    } else {
                        MPhase::Solve
                    };
                }
            }
            self.mach.wake_epoch = self.mach.wake_epoch.wrapping_add(1);
            self.mach.timeout_armed = false;
            force = false;
        }
    }

    fn arm_silence(&mut self) {
        let timeout = self.cfg.silence_timeout;
        if timeout == 0 || self.mach.timeout_armed {
            return;
        }
        self.mach.timeout_armed = true;
        let epoch = self.mach.wake_epoch;
        let at = self.net.now() + timeout;
        self.net.schedule(at, Event::Wake { node: self.me, epoch });
    }

    fn refresh_links(&mut self) {
        let gen = self.ctrl.view().generation();
        if self.mach.link_gen == gen {
            return;
        }
        let mcount = self.part.len();
        let mut live = vec![false; mcount];
        live[self.me] = true;
        {
            let view = self.ctrl.view();
            for (qslot, &p) in
                self.part.quotient.neighbors(self.me).iter().enumerate()
            {
                live[p] = view.slot_live(self.me, qslot);
            }
        }
        self.mach.link_live = live;
        self.mach.link_gen = gen;
    }

    // -- boundary readiness / resolution (verbatim driver ports) ------------

    fn ready_a(&mut self, force: bool) -> bool {
        self.refresh_links();
        let mach = &self.mach;
        let t = mach.t;
        let stale = self.cfg.max_staleness;
        for idx in 0..mach.in_nodes.len() {
            let p = mach.in_node_machine[idx];
            if !mach.link_live[p] {
                continue;
            }
            if !mach.in_theta_ready(idx, t, stale, force) {
                return false;
            }
        }
        true
    }

    fn resolve_a(&mut self) {
        let t = self.mach.t;
        let stale = self.cfg.max_staleness;
        for idx in 0..self.mach.in_nodes.len() {
            let p = self.mach.in_node_machine[idx];
            if !self.mach.link_live[p] {
                continue;
            }
            let used = self.mach.resolve_in_theta(idx, t);
            self.net.note_stale_read(self.me, p, t, used, stale);
        }
    }

    fn ready_b(&mut self, force: bool) -> bool {
        self.refresh_links();
        let mach = &self.mach;
        let t = mach.t;
        let stale = self.cfg.max_staleness;
        for idx in 0..mach.in_nodes.len() {
            let p = mach.in_node_machine[idx];
            if !mach.link_live[p] {
                continue;
            }
            if !mach.in_theta_ready(idx, t + 1, stale, force) {
                return false;
            }
        }
        for idx in 0..mach.in_eta_edges.len() {
            let p = mach.in_eta_edges[idx].2;
            if !mach.link_live[p] {
                continue;
            }
            if !mach.in_eta_ready(idx, t, stale, force) {
                return false;
            }
        }
        true
    }

    fn resolve_b(&mut self) {
        let t = self.mach.t;
        let stale = self.cfg.max_staleness;
        for idx in 0..self.mach.in_nodes.len() {
            let p = self.mach.in_node_machine[idx];
            if !self.mach.link_live[p] {
                continue;
            }
            let used = self.mach.resolve_in_theta(idx, t + 1);
            self.net.note_stale_read(self.me, p, t + 1, used, stale);
        }
        for idx in 0..self.mach.in_eta_edges.len() {
            let p = self.mach.in_eta_edges[idx].2;
            if !self.mach.link_live[p] {
                continue;
            }
            let used = self.mach.resolve_in_eta(idx, t);
            self.net.note_stale_read(self.me, p, t, used, stale);
        }
    }

    // -- boundary sends -----------------------------------------------------

    /// Quotient slots whose link currently carries traffic.
    fn live_neighbors(&self) -> Vec<(usize, usize)> {
        let view = self.ctrl.view();
        self.part
            .quotient
            .neighbors(self.me)
            .iter()
            .enumerate()
            .filter(|&(qslot, _)| view.slot_live(self.me, qslot))
            .map(|(qslot, &p)| (qslot, p))
            .collect()
    }

    /// Send through the transport and record the minted
    /// [`crate::obs::TraceCtx`] on the timeline (no-op when disabled).
    fn tsend(&mut self, dst: usize, payload: Payload, reliable: bool) {
        send_traced(&mut self.net, &mut self.timeline, self.me, dst, payload,
                    reliable);
    }

    fn send_state(&mut self, ts: u64, es: u64) {
        for (qslot, p) in self.live_neighbors() {
            let nodes = self.mach.boundary_theta(qslot, ts);
            let edges = self.mach.boundary_eta(qslot);
            self.tsend(p, Payload::BoundaryTheta { stamp: ts, nodes }, true);
            self.tsend(p, Payload::BoundaryEta { stamp: es, edges }, true);
        }
    }

    fn send_boundary_theta(&mut self, stamp: u64) {
        for (qslot, p) in self.live_neighbors() {
            let nodes = self.mach.boundary_theta(qslot, stamp);
            self.tsend(p, Payload::BoundaryTheta { stamp, nodes }, false);
        }
    }

    fn send_boundary_eta(&mut self, stamp: u64) {
        for (qslot, p) in self.live_neighbors() {
            let edges = self.mach.boundary_eta(qslot);
            self.tsend(p, Payload::BoundaryEta { stamp, edges }, false);
        }
    }

    // -- event handlers -----------------------------------------------------

    fn on_deliver(&mut self, src: usize, payload: Payload) {
        self.net.note_delivered(src, self.me, &payload);
        match payload {
            Payload::BoundaryTheta { stamp, nodes } => {
                for (node, th) in nodes {
                    let idx = self
                        .mach
                        .in_nodes
                        .binary_search(&node)
                        .expect("boundary node known to the receiver");
                    self.mach.in_theta[idx].insert(stamp, th);
                }
                self.try_advance(false);
            }
            Payload::BoundaryEta { stamp, edges } => {
                for (i, j, eta) in edges {
                    let idx = *self
                        .mach
                        .in_eta_index
                        .get(&(i, j))
                        .expect("cross edge known to the receiver");
                    self.mach.in_eta[idx].insert(stamp, eta);
                }
                self.try_advance(false);
            }
            Payload::Part { round, entries, thetas: _ } => {
                self.on_part(src, round, entries);
            }
            Payload::Verdict { round, global_primal, global_dual } => {
                self.on_verdict(round, global_primal, global_dual);
            }
            Payload::Checker { cursor, snap } => {
                // adopt unless we already carry a further-along tracker
                // (a freshly-adopted one racing a graceful handoff)
                if self.tracker.is_none() || cursor >= self.cursor {
                    let mut tr = StopTracker::new(
                        self.dim, self.cfg.tol, self.cfg.patience,
                        self.cfg.warmup, self.cfg.max_iters,
                        self.cfg.params.eta0,
                    );
                    tr.resume(*snap);
                    self.tracker = Some(tr);
                    self.cursor = cursor;
                    self.try_root_folds();
                }
            }
            Payload::Stop { round, converged } => {
                if !self.stopped {
                    self.stopped = true;
                    self.stop_round = Some(round);
                    self.flood_converged = converged;
                    // re-flood once so the broadcast survives the
                    // sender dying right after its first send
                    let mcount = self.part.len();
                    for p in 0..mcount {
                        if p != self.me && p != src
                            && self.ctrl.view().node_live(p)
                        {
                            self.tsend(p, Payload::Stop { round, converged },
                                       true);
                        }
                    }
                }
            }
            // per-node payloads / gossip never travel to this runtime
            Payload::Theta { .. } | Payload::Eta { .. }
            | Payload::Gossip { .. } => {}
        }
    }

    /// A peer (or this machine) left. Self-leave is the graceful-exit
    /// drill: hand the tracker off if we hold it, then terminate.
    fn on_leave(&mut self, node: usize) {
        if node == self.me {
            if self.tracker.is_some() {
                let successor = (0..self.part.len())
                    .find(|&p| p != self.me && self.ctrl.view().node_live(p));
                if let Some(to) = successor {
                    let snap = self.tracker.as_ref().unwrap().snapshot();
                    self.net.record(TraceKind::Handoff { from: self.me, to });
                    self.tsend(to,
                               Payload::Checker {
                                   cursor: self.cursor,
                                   snap: Box::new(snap),
                               },
                               true);
                    self.tracker = None;
                }
            }
            self.stopped = true;
            return;
        }
        if !self.ctrl.view().node_live(node) {
            return;
        }
        self.ctrl.apply_leave(node, &mut self.net);
        self.mach.wake_epoch = self.mach.wake_epoch.wrapping_add(1);
        self.mach.timeout_armed = false;
        self.tree_refresh();
        // expectations shrank: re-evaluate buffered collective rounds
        let pending: Vec<u64> = self.inbox.keys().copied().collect();
        for r in pending {
            if self.stopped {
                return;
            }
            self.tree_progress(r);
        }
        self.pending_wake = true;
    }

    // -- tree collective ----------------------------------------------------

    fn tree_refresh(&mut self) {
        let gen = self.ctrl.view().generation();
        if self.topo.built_gen == gen {
            return;
        }
        let old_root = self.topo.root;
        self.topo = build_tree_rooted(self.ctrl.view(), None);
        let new_root = self.topo.root;
        if new_root == old_root {
            return;
        }
        self.net.record(TraceKind::Reroot { root: new_root });
        if new_root == self.me && self.tracker.is_none() {
            // the old holder is gone and nothing arrived from it: adopt
            // a fresh tracker (kill recovery — see module docs). Start
            // at the oldest round still buffered here so every
            // commit has its partials.
            self.tracker = Some(StopTracker::new(
                self.dim, self.cfg.tol, self.cfg.patience, self.cfg.warmup,
                self.cfg.max_iters, self.cfg.params.eta0,
            ));
            self.cursor = self
                .inbox
                .keys()
                .next()
                .copied()
                .unwrap_or(self.mach.t)
                .max(self.cursor);
        } else if new_root != self.me && self.tracker.is_some() {
            // we hold the tracker but lost the root role (e.g. a leave
            // notification reordered against a handoff): ship it over
            let snap = self.tracker.as_ref().unwrap().snapshot();
            self.net.record(TraceKind::Handoff { from: self.me, to: new_root });
            self.tsend(new_root,
                       Payload::Checker {
                           cursor: self.cursor,
                           snap: Box::new(snap),
                       },
                       true);
            self.tracker = None;
        }
    }

    /// Whether peer `p` owes a contribution to round `r` (no dormant
    /// machines here: everyone starts at round 0).
    fn expects(&self, p: usize, r: u64) -> bool {
        self.ctrl.view().node_live(p) && self.mach.start_round <= r
    }

    fn tree_deposit(&mut self, round: u64) {
        let entry = self.mach.partials.clone();
        self.inbox.entry(round).or_default().insert(self.me, entry);
        self.tree_progress(round);
    }

    fn tree_progress(&mut self, round: u64) {
        self.tree_refresh();
        if self.topo.root == self.me {
            self.try_root_folds();
            return;
        }
        let (complete, own) = self.subtree_status(round);
        if !complete {
            if own {
                self.arm_coll();
            }
            return;
        }
        self.tree_forward(round);
    }

    /// (subtree complete for `round`, own entry present).
    fn subtree_status(&self, round: u64) -> (bool, bool) {
        let present = self.inbox.get(&round);
        let own = present.is_some_and(|map| map.contains_key(&self.me));
        let members = subtree(&self.topo, self.me);
        let complete = members.iter().all(|&p| {
            !self.expects(p, round)
                || present.is_some_and(|map| map.contains_key(&p))
        });
        (complete, own)
    }

    fn tree_forward(&mut self, round: u64) {
        let Some(map) = self.inbox.get(&round) else { return };
        let entries: Vec<(usize, Vec<StatPartial>)> =
            map.iter().map(|(&k, v)| (k, v.clone())).collect();
        self.sent_up.insert(round);
        if let Some(p) = self.topo.parent[self.me] {
            self.tsend(p, Payload::Part { round, entries, thetas: Vec::new() },
                       false);
        }
        self.arm_coll();
    }

    fn on_part(&mut self, src: usize, round: u64,
               entries: Vec<(usize, Vec<StatPartial>)>) {
        // straggler for an already-verdicted round: answer directly
        if let Some(&(gp, gd)) = self.mach.verdicts.get(&round) {
            self.tsend(src,
                       Payload::Verdict { round, global_primal: gp,
                                          global_dual: gd },
                       false);
            return;
        }
        let map = self.inbox.entry(round).or_default();
        for (mid, parts) in entries {
            map.insert(mid, parts);
        }
        self.tree_progress(round);
    }

    fn on_verdict(&mut self, round: u64, gp: f64, gd: f64) {
        if !self.store_verdict(round, gp, gd) {
            return;
        }
        let settled = &self.mach.verdicts;
        self.inbox.retain(|&r, _| r > round || !settled.contains_key(&r));
        self.sent_up.retain(|&r| r > round || !settled.contains_key(&r));
        for c in self.topo.children[self.me].clone() {
            if self.ctrl.view().node_live(c) {
                self.tsend(c,
                           Payload::Verdict { round, global_primal: gp,
                                              global_dual: gd },
                           false);
            }
        }
        self.tree_rearm();
    }

    fn store_verdict(&mut self, r: u64, gp: f64, gd: f64) -> bool {
        let mach = &mut self.mach;
        if mach.verdicts.insert(r, (gp, gd)).is_some() {
            return false;
        }
        if r + 1 > mach.horizon {
            mach.horizon = r + 1;
            mach.latest_globals = (gp, gd);
        }
        mach.retries.remove(&r);
        mach.coll_armed = false;
        mach.coll_epoch = mach.coll_epoch.wrapping_add(1);
        self.pending_wake = true;
        true
    }

    fn arm_coll(&mut self) {
        let timeout = self.cfg.collective_timeout;
        if timeout == 0 || self.mach.coll_armed {
            return;
        }
        self.mach.coll_armed = true;
        let epoch = self.mach.coll_epoch;
        let at = self.net.now() + timeout;
        self.net.schedule(at, Event::Timer {
            node: self.me,
            kind: TimerKind::Collective,
            epoch,
        });
    }

    fn tree_rearm(&mut self) {
        let outstanding = self.inbox.iter().any(|(r, map)| {
            map.contains_key(&self.me) && !self.mach.verdicts.contains_key(r)
        });
        if outstanding {
            self.arm_coll();
        }
    }

    // -- root folds / stop flood --------------------------------------------

    fn try_root_folds(&mut self) {
        loop {
            if self.stopped || self.topo.root != self.me || self.tracker.is_none()
            {
                return;
            }
            let r = self.cursor;
            if r >= self.cfg.max_iters as u64 {
                return; // try_finish_holder floods the budget exit
            }
            let (complete, own) = self.subtree_status(r);
            if !complete {
                if own {
                    self.arm_coll();
                }
                return;
            }
            if !self.inbox.contains_key(&r) {
                return;
            }
            self.root_fold(r, false);
        }
    }

    fn root_fold(&mut self, r: u64, forced: bool) {
        let Some(map) = self.inbox.remove(&r) else { return };
        self.sent_up.remove(&r);
        if forced {
            self.net.counters().collective_timeouts += 1;
            self.net
                .record(TraceKind::CollectiveTimeout { machine: self.me, round: r });
        }
        if map.values().flatten().all(|p| p.node_count == 0) {
            return; // nothing to fold: every contributor died
        }
        let span = self.obs.span();
        let Some(tracker) = self.tracker.as_mut() else { return };
        let g = tracker.round_partials(map.values().flat_map(|parts| parts.iter()));
        let stats = IterStats {
            iter: r as usize,
            objective: g.objective,
            max_primal: g.max_primal,
            max_dual: g.max_dual,
            mean_eta: g.mean_eta,
            min_eta: g.min_eta,
            max_eta: g.max_eta,
            app_error: 0.0,
        };
        let stop = tracker.commit(r as usize, stats);
        self.cursor = r + 1;
        self.net.record(TraceKind::Fold { round: r });
        let fold_ns = self.obs.end(self.probes.collective_fold, span);
        self.obs.inc(self.probes.rounds, 1);
        self.record_commit(r, stats, fold_ns);
        self.store_verdict(r, g.global_primal, g.global_dual);
        if stop {
            // `commit` also fires on a spent budget — report what the
            // checker actually concluded, not the flood itself
            let converged = self.tracker.as_ref().unwrap().converged;
            self.flood_stop(r, converged);
            return;
        }
        for c in self.topo.children[self.me].clone() {
            if self.ctrl.view().node_live(c) {
                self.tsend(c,
                           Payload::Verdict {
                               round: r,
                               global_primal: g.global_primal,
                               global_dual: g.global_dual,
                           },
                           false);
            }
        }
    }

    /// Record round `r`'s commit on the timeline and push its series row
    /// (holder only — commits happen here). `live_nodes` counts nodes on
    /// machines this holder *believes* live; `live_edges` counts live
    /// machine links of the quotient graph.
    fn record_commit(&mut self, r: u64, stats: IterStats, fold_ns: u64) {
        if self.timeline.enabled() {
            let now = self.net.now();
            self.timeline.phase(now, self.me, r, Phase::CollectiveFold, fold_ns);
            self.timeline.commit(now, self.me, r);
        }
        if self.series.enabled() {
            let view = self.ctrl.view();
            let live_nodes = (0..self.part.len())
                .filter(|&p| view.node_live(p))
                .map(|p| self.part.ranges[p].len())
                .sum::<usize>() as u64;
            let row = RoundRow {
                round: r,
                at: self.net.now(),
                stats,
                live_nodes,
                live_edges: view.live_edge_count() as u64,
                phase_ns: self.timeline.phase_ns(r),
            };
            self.series.push(row);
        }
    }

    /// Budget exit at the holder: every round committed and the local
    /// machine finished — flood the stop and terminate.
    fn try_finish_holder(&mut self) {
        if self.stopped || self.tracker.is_none() {
            return;
        }
        if self.cursor >= self.cfg.max_iters as u64
            && !matches!(self.mach.phase, MPhase::Solve | MPhase::Reduce
                         | MPhase::FoldWait)
        {
            let round = self.cursor.saturating_sub(1);
            let converged = self.tracker.as_ref().unwrap().converged;
            self.flood_stop(round, converged);
        }
    }

    fn flood_stop(&mut self, round: u64, converged: bool) {
        self.stopped = true;
        self.stop_round = Some(round);
        self.flood_converged = converged;
        self.net.record(TraceKind::Stop { rounds: round + 1 });
        for p in 0..self.part.len() {
            if p != self.me && self.ctrl.view().node_live(p) {
                self.tsend(p, Payload::Stop { round, converged }, true);
            }
        }
    }

    // -- collective timer (straggler recovery) ------------------------------

    fn on_coll_timer(&mut self) {
        self.mach.coll_armed = false;
        self.mach.coll_epoch = self.mach.coll_epoch.wrapping_add(1);
        self.tree_refresh();
        if self.topo.root == self.me {
            if self.tracker.is_none() {
                return; // handoff in flight: the Checker delivery resumes
            }
            let r = self.cursor;
            if r >= self.cfg.max_iters as u64 {
                return;
            }
            let (_, own) = self.subtree_status(r);
            if own {
                self.root_fold(r, true);
                if !self.stopped {
                    self.try_root_folds();
                }
            }
            return;
        }
        // oldest outstanding round with our own entry and no verdict
        let cand = self
            .inbox
            .iter()
            .filter(|(r, map)| {
                map.contains_key(&self.me) && !self.mach.verdicts.contains_key(r)
            })
            .map(|(&r, _)| r)
            .next();
        let Some(next) = cand else { return };
        if !self.sent_up.contains(&next) {
            self.net.counters().collective_timeouts += 1;
            self.net
                .record(TraceKind::CollectiveTimeout { machine: self.me,
                                                       round: next });
            self.tree_forward(next);
            return;
        }
        let retries = {
            let e = self.mach.retries.entry(next).or_insert(0);
            *e += 1;
            *e
        };
        if retries > self.cfg.fallback_after {
            let (gp, gd) = self.local_fold(next);
            self.net.counters().collective_fallbacks += 1;
            self.net
                .record(TraceKind::FallbackVerdict { machine: self.me,
                                                     round: next });
            self.store_verdict(next, gp, gd);
            self.tree_rearm();
        } else {
            self.net.counters().collective_retries += 1;
            self.tree_forward(next);
        }
    }

    /// Local substitute fold over whatever this subtree delivered for
    /// `round` (detached-survivor path; same arithmetic as the driver).
    fn local_fold(&mut self, round: u64) -> (f64, f64) {
        let mut rf = RunningFold::new(self.dim);
        if let Some(map) = self.inbox.get(&round) {
            for parts in map.values() {
                for p in parts {
                    rf.absorb(p);
                }
            }
        }
        let gp = rf.global_primal();
        let mut gs2 = 0.0;
        for k in 0..self.dim {
            let d = rf.gmean[k] - self.mach.coll_mean_prev[k];
            gs2 += d * d;
        }
        self.mach.coll_mean_prev.copy_from_slice(&rf.gmean);
        let gd = self.cfg.params.eta0 * (rf.agg_n as f64).sqrt() * gs2.sqrt();
        (gp, gd)
    }
}
