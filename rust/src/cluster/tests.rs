//! Cluster-runtime integration tests: bit-parity against the sharded
//! runner, trace determinism, and fault scenarios (loss, isolated
//! machines, machine churn).

use super::*;
use crate::coordinator::{ShardedConfig, ShardedRunner};
// the shared materialized problem: cluster and sharded oracle construct
// *identical* solvers (bit-parity depends on it)
use crate::experiments::common::quad_problem_factory as quad_factory;
use crate::graph::Topology;
use crate::metrics::IterStats;
use crate::net::{ChurnEvent, FaultPlan, LinkModel, Partition, TraceKind};
use crate::penalty::SchemeKind;
use crate::pool::ExecMode;

fn assert_stats_bit_equal(a: &IterStats, b: &IterStats) {
    assert_eq!(a.iter, b.iter);
    assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "iter {}", a.iter);
    assert_eq!(a.max_primal.to_bits(), b.max_primal.to_bits(), "iter {}", a.iter);
    assert_eq!(a.max_dual.to_bits(), b.max_dual.to_bits(), "iter {}", a.iter);
    assert_eq!(a.mean_eta.to_bits(), b.mean_eta.to_bits(), "iter {}", a.iter);
    assert_eq!(a.min_eta.to_bits(), b.min_eta.to_bits(), "iter {}", a.iter);
    assert_eq!(a.max_eta.to_bits(), b.max_eta.to_bits(), "iter {}", a.iter);
}

fn lossy(loss: f64) -> FaultPlan {
    FaultPlan {
        link: LinkModel { base: 2, jitter: 4, loss, dup: 0.02 },
        ..FaultPlan::none()
    }
}

// -- acceptance: one-machine bit parity --------------------------------------

#[test]
fn one_machine_cluster_is_bit_identical_to_sharded_runner() {
    // the acceptance bar: 1 machine, zero faults, tree collective ⇒
    // bit-for-bit equal to ShardedRunner (same worker count) for all
    // seven schemes on Ring and Star — θ, iterations, convergence flag
    // and every recorded IterStats field
    for topo in [Topology::Ring, Topology::Star] {
        for scheme in SchemeKind::ALL {
            let (tol, max_iters, seed) = (1e-4, 60usize, 11u64);
            let sharded = ShardedRunner::new(
                topo.build(6).unwrap(),
                ShardedConfig { scheme, tol, max_iters, seed, workers: 2,
                                ..Default::default() },
            )
            .run(quad_factory(6, 3, 5))
            .unwrap();

            let cluster = ClusterRunner::new(
                topo.build(6).unwrap(),
                ClusterConfig { scheme, tol, max_iters, seed, machines: 1,
                                workers: 2, collective: CollectiveKind::Tree,
                                ..Default::default() },
                FaultPlan::none(),
                quad_factory(6, 3, 5),
            )
            .unwrap()
            .run();

            assert_eq!(sharded.iterations, cluster.iterations, "{topo:?}/{scheme:?}");
            assert_eq!(sharded.converged, cluster.converged, "{topo:?}/{scheme:?}");
            assert_eq!(sharded.thetas, cluster.thetas,
                       "{topo:?}/{scheme:?}: θ must be bit-identical");
            assert_eq!(sharded.recorder.stats.len(), cluster.recorder.stats.len());
            for (a, b) in sharded.recorder.stats.iter().zip(&cluster.recorder.stats) {
                assert_stats_bit_equal(a, b);
            }
            // one machine ⇒ no network traffic at all
            assert_eq!(cluster.virtual_time, 0, "{topo:?}/{scheme:?}");
            assert_eq!(cluster.counters.sent, 0);
            assert_eq!(cluster.counters.stale_reads, 0);
            assert_eq!(cluster.machines, 1);
        }
    }
}

// -- acceptance: multi-machine tree parity -----------------------------------

#[test]
fn multi_machine_tree_matches_sharded_runner_bitwise() {
    // M machines × 1 worker over zero faults: the machine slices ARE the
    // W = M shard split, and the tree folds the same partials in the
    // same (node-id) order — so the whole trajectory, RB's folded
    // residuals included, is bit-identical to ShardedRunner(workers = M)
    for scheme in SchemeKind::ALL {
        let (tol, max_iters, seed) = (1e-4, 80usize, 23u64);
        let sharded = ShardedRunner::new(
            Topology::Ring.build(12).unwrap(),
            ShardedConfig { scheme, tol, max_iters, seed, workers: 3,
                            ..Default::default() },
        )
        .run(quad_factory(12, 2, 41))
        .unwrap();

        let cluster = ClusterRunner::new(
            Topology::Ring.build(12).unwrap(),
            ClusterConfig { scheme, tol, max_iters, seed, machines: 3,
                            workers: 1, collective: CollectiveKind::Tree,
                            ..Default::default() },
            FaultPlan::none(),
            quad_factory(12, 2, 41),
        )
        .unwrap()
        .run();

        assert_eq!(sharded.iterations, cluster.iterations, "{scheme:?}");
        assert_eq!(sharded.converged, cluster.converged, "{scheme:?}");
        assert_eq!(sharded.thetas, cluster.thetas, "{scheme:?}");
        assert_eq!(sharded.recorder.stats.len(), cluster.recorder.stats.len());
        for (a, b) in sharded.recorder.stats.iter().zip(&cluster.recorder.stats) {
            assert_stats_bit_equal(a, b);
        }
        // zero faults + ideal links ⇒ no virtual time, no drops, no
        // stale reads — but real boundary/collective traffic
        assert_eq!(cluster.virtual_time, 0, "{scheme:?}");
        assert!(cluster.counters.sent > 0);
        assert_eq!(cluster.counters.dropped_total(), 0);
        assert_eq!(cluster.counters.stale_reads, 0);
    }
}

#[test]
fn gossip_zero_fault_keeps_decentralized_node_trajectories_exact() {
    // the gossip estimates feed only RB and the stop rule; with a fixed
    // round budget every decentralized scheme's θ stream is untouched by
    // the collective, hence bit-identical to the sharded oracle — while
    // the recorded objective is a push-sum *estimate* near the exact fold
    for scheme in [SchemeKind::Fixed, SchemeKind::Ap, SchemeKind::Nap] {
        let sharded = ShardedRunner::new(
            Topology::Ring.build(12).unwrap(),
            ShardedConfig { scheme, tol: 0.0, max_iters: 40, seed: 9, workers: 4,
                            ..Default::default() },
        )
        .run(quad_factory(12, 2, 77))
        .unwrap();

        let cluster = ClusterRunner::new(
            Topology::Ring.build(12).unwrap(),
            ClusterConfig { scheme, tol: 0.0, max_iters: 40, seed: 9,
                            machines: 4, workers: 1,
                            collective: CollectiveKind::Gossip,
                            gossip_ticks: 16, // ≤ 0.1% ratio error on a 4-ring
                            ..Default::default() },
            FaultPlan::none(),
            quad_factory(12, 2, 77),
        )
        .unwrap()
        .run();

        assert_eq!(cluster.iterations, 40, "{scheme:?}");
        assert_eq!(sharded.thetas, cluster.thetas,
                   "{scheme:?}: gossip must not perturb decentralized θ");
        assert!(cluster.virtual_time > 0, "gossip ticks consume virtual time");
        assert!(cluster.counters.gossip_ticks > 0);
        let exact = sharded.recorder.stats.last().unwrap().objective;
        let est = cluster.recorder.stats.last().unwrap().objective;
        assert!((est - exact).abs() <= 0.35 * exact.abs().max(1.0),
                "{scheme:?}: push-sum estimate {est} too far from exact {exact}");
    }
}

// -- determinism --------------------------------------------------------------

#[test]
fn same_seed_identical_trace_both_collectives() {
    for collective in CollectiveKind::ALL {
        let run = || {
            let plan = FaultPlan {
                link: LinkModel { base: 2, jitter: 5, loss: 0.15, dup: 0.05 },
                partitions: vec![Partition { start: 40, end: 160, group: vec![3] }],
                ..FaultPlan::none()
            };
            ClusterRunner::new(
                Topology::Ring.build(12).unwrap(),
                ClusterConfig {
                    scheme: SchemeKind::Nap,
                    tol: 0.0,
                    max_iters: 60,
                    seed: 3,
                    machines: 4,
                    workers: 1,
                    collective,
                    max_staleness: 1,
                    silence_timeout: 8,
                    collective_timeout: 16,
                    fallback_after: 2,
                    ..Default::default()
                },
                plan,
                quad_factory(12, 2, 21),
            )
            .unwrap()
            .run()
        };
        let a = run();
        let b = run();
        assert!(!a.trace.is_empty(), "{collective:?}");
        assert_eq!(a.trace, b.trace, "{collective:?}: trace must replay identically");
        assert_eq!(a.thetas, b.thetas, "{collective:?}");
        assert_eq!(a.iterations, b.iterations, "{collective:?}");
        assert_eq!(a.virtual_time, b.virtual_time, "{collective:?}");
        assert_eq!(a.counters, b.counters, "{collective:?}");
        assert_eq!(a.recorder.objective_curve(), b.recorder.objective_curve());
    }
}

#[test]
fn obs_instrumentation_is_bit_transparent() {
    // the obs hard contract: turning instrumentation on may not change a
    // single protocol bit — same θ, same trace, same counters, same
    // recorded curves — even under faults and with tracing live
    let run = |obs: bool| {
        let plan = FaultPlan {
            link: LinkModel { base: 2, jitter: 5, loss: 0.15, dup: 0.05 },
            partitions: vec![Partition { start: 40, end: 160, group: vec![3] }],
            ..FaultPlan::none()
        };
        ClusterRunner::new(
            Topology::Ring.build(12).unwrap(),
            ClusterConfig {
                scheme: SchemeKind::Nap,
                tol: 0.0,
                max_iters: 60,
                seed: 3,
                machines: 4,
                workers: 1,
                collective: CollectiveKind::Tree,
                max_staleness: 1,
                silence_timeout: 8,
                collective_timeout: 16,
                fallback_after: 2,
                tracing: true,
                obs,
                ..Default::default()
            },
            plan,
            quad_factory(12, 2, 21),
        )
        .unwrap()
        .run()
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.thetas, on.thetas, "obs must not perturb θ");
    assert_eq!(off.iterations, on.iterations);
    assert_eq!(off.converged, on.converged);
    assert_eq!(off.virtual_time, on.virtual_time);
    assert_eq!(off.counters, on.counters);
    assert_eq!(off.trace, on.trace, "obs must not perturb the event trace");
    for (a, b) in off.recorder.stats.iter().zip(on.recorder.stats.iter()) {
        assert_stats_bit_equal(a, b);
    }
    // and the instrumented run actually measured something
    assert!(on.obs.hist_by_name("fadmm_phase_solve_ns").unwrap().count > 0);
    assert!(on.obs.counter_by_name("fadmm_rounds_total").unwrap() > 0);
    // counters flow into the registry identically on both runs — only
    // the wall-clock spans are gated on `obs`
    assert_eq!(
        off.obs.counter_by_name("fadmm_net_sent_total"),
        on.obs.counter_by_name("fadmm_net_sent_total"),
    );
    assert_eq!(off.obs.counter_by_name("fadmm_trace_events_total"),
               on.obs.counter_by_name("fadmm_trace_events_total"));
}

#[test]
fn timeline_and_series_are_bit_transparent() {
    // the same hard contract extended to the causal timeline and the
    // round series: recording may not change a single protocol bit, and
    // the recorded rows must carry the committed stats verbatim
    let run = |rec: bool| {
        let plan = FaultPlan {
            link: LinkModel { base: 2, jitter: 5, loss: 0.15, dup: 0.05 },
            partitions: vec![Partition { start: 40, end: 160, group: vec![3] }],
            ..FaultPlan::none()
        };
        ClusterRunner::new(
            Topology::Ring.build(12).unwrap(),
            ClusterConfig {
                scheme: SchemeKind::Nap,
                tol: 0.0,
                max_iters: 60,
                seed: 3,
                machines: 4,
                workers: 1,
                collective: CollectiveKind::Tree,
                max_staleness: 1,
                silence_timeout: 8,
                collective_timeout: 16,
                fallback_after: 2,
                tracing: true,
                timeline: rec,
                series: rec,
                ..Default::default()
            },
            plan,
            quad_factory(12, 2, 21),
        )
        .unwrap()
        .run()
    };
    let off = run(false);
    let on = run(true);
    assert_eq!(off.thetas, on.thetas, "recording must not perturb θ");
    assert_eq!(off.iterations, on.iterations);
    assert_eq!(off.converged, on.converged);
    assert_eq!(off.virtual_time, on.virtual_time);
    assert_eq!(off.counters, on.counters);
    assert_eq!(off.trace, on.trace, "recording must not perturb the trace");
    for (a, b) in off.recorder.stats.iter().zip(on.recorder.stats.iter()) {
        assert_stats_bit_equal(a, b);
    }
    // disabled recorders stay empty (and count nothing as dropped)
    assert!(off.timeline.is_empty() && off.series.is_empty());
    assert_eq!((off.timeline_dropped, off.series_dropped), (0, 0));
    // one series row per committed round, stats bit-for-bit from the
    // recorder stream
    assert_eq!(on.series.len(), on.recorder.stats.len());
    for (row, s) in on.series.iter().zip(on.recorder.stats.iter()) {
        assert_eq!(row.round as usize, s.iter);
        assert_stats_bit_equal(&row.stats, s);
        assert!(row.live_nodes > 0, "round {}: live nodes", row.round);
        assert!(row.live_edges > 0, "round {}: live edges", row.round);
    }
    // the timeline captured the full event vocabulary, and every
    // delivery's causal ctx names a sender the trace knows about
    use crate::obs::TlKind;
    assert!(on.timeline.iter().any(|e| matches!(e.kind, TlKind::Send { .. })));
    assert!(on.timeline.iter().any(|e| matches!(e.kind, TlKind::Phase { .. })));
    assert!(on.timeline.iter().any(|e| matches!(e.kind, TlKind::Commit)));
    let machines = 4usize;
    for ev in &on.timeline {
        if let TlKind::Recv { src, .. } = ev.kind {
            assert!(src < machines, "ctx src within the mesh");
            assert!(ev.machine < machines);
        }
    }
}

// -- fault scenarios ----------------------------------------------------------

#[test]
fn cluster_converges_under_loss_with_both_collectives() {
    for collective in CollectiveKind::ALL {
        let report = ClusterRunner::new(
            Topology::Ring.build(12).unwrap(),
            ClusterConfig {
                scheme: SchemeKind::Fixed,
                tol: 0.0,
                max_iters: 400,
                seed: 1,
                machines: 4,
                workers: 1,
                collective,
                max_staleness: 1,
                silence_timeout: 16,
                collective_timeout: 24,
                fallback_after: 2,
                ..Default::default()
            },
            lossy(0.10),
            quad_factory(12, 2, 33),
        )
        .unwrap()
        .run();
        assert_eq!(report.iterations, 400, "{collective:?}: every round folds");
        assert!(report.counters.dropped_loss > 0, "{collective:?}");
        assert!(report.counters.stale_reads > 0, "{collective:?}");
        let last = report.recorder.stats.last().unwrap();
        assert!(last.max_primal < 1e-2,
                "{collective:?}: consensus under 10% loss, primal {}",
                last.max_primal);
        assert!(report.virtual_time > 0);
    }
}

#[test]
fn isolated_machine_does_not_poison_the_collective() {
    // the satellite bar: one machine fully partitioned away for a long
    // window. The tree re-times around it (root folds without it, the
    // islander substitutes local fallback verdicts), gossip renormalizes
    // over the live component — and after the heal the cluster converges.
    // NetCounters must record the outage.
    for collective in CollectiveKind::ALL {
        let plan = FaultPlan {
            link: LinkModel { base: 1, jitter: 2, loss: 0.0, dup: 0.0 },
            partitions: vec![Partition { start: 50, end: 400, group: vec![2] }],
            ..FaultPlan::none()
        };
        let report = ClusterRunner::new(
            Topology::Ring.build(12).unwrap(),
            ClusterConfig {
                scheme: SchemeKind::Vp,
                tol: 0.0,
                max_iters: 300,
                seed: 17,
                machines: 4,
                workers: 1,
                collective,
                max_staleness: 1,
                silence_timeout: 8,
                collective_timeout: 12,
                fallback_after: 2,
                ..Default::default()
            },
            plan,
            quad_factory(12, 2, 17),
        )
        .unwrap()
        .run();
        assert!(report.counters.dropped_partition > 0, "{collective:?}");
        assert_eq!(report.iterations, 300,
                   "{collective:?}: the survivors keep folding every round");
        if collective == CollectiveKind::Tree {
            assert!(report.counters.collective_timeouts > 0,
                    "the root must have folded without the islander");
            assert!(report.counters.collective_fallbacks > 0,
                    "the islander must have substituted local verdicts");
        }
        assert!(report.live_machines.iter().all(|&l| l),
                "a transport partition is not churn");
        let last = report.recorder.stats.last().unwrap();
        assert!(last.max_primal < 1e-2,
                "{collective:?}: post-heal consensus, primal {}", last.max_primal);
    }
}

#[test]
fn machine_churn_reroots_and_survivors_converge() {
    // machine 3 joins mid-run from dormancy; machine 0 — the initial
    // tree root and designated recorder — leaves later, forcing a
    // deterministic re-root over the live quotient graph
    let plan = FaultPlan {
        link: LinkModel { base: 1, jitter: 2, loss: 0.05, dup: 0.0 },
        partitions: vec![],
        churn: vec![
            ChurnEvent::Join { at: 150, node: 3 },
            ChurnEvent::Leave { at: 600, node: 0 },
        ],
        initially_dormant: vec![3],
    };
    let report = ClusterRunner::new(
        Topology::Ring.build(12).unwrap(),
        ClusterConfig {
            scheme: SchemeKind::Nap,
            tol: 0.0,
            max_iters: 300,
            seed: 7,
            machines: 4,
            workers: 1,
            collective: CollectiveKind::Tree,
            max_staleness: 1,
            silence_timeout: 8,
            collective_timeout: 12,
            fallback_after: 2,
            ..Default::default()
        },
        plan,
        quad_factory(12, 2, 51),
    )
    .unwrap()
    .run();
    assert_eq!(report.counters.joins, 1);
    assert_eq!(report.counters.leaves, 1);
    assert!(!report.live_machines[0], "machine 0 left");
    assert!(report.live_machines[3], "machine 3 joined");
    assert!(report
        .trace
        .iter()
        .any(|e| matches!(e.kind, TraceKind::Reroot { root: 1 })),
        "losing the root must re-root the tree at machine 1");
    assert!(report.iterations > 0);
    let last = report.recorder.stats.last().unwrap();
    assert!(last.max_primal < 5e-2,
            "survivor consensus, primal {}", last.max_primal);
}

#[test]
fn gossip_survives_machine_churn_with_verdict_gated_scheme() {
    // regression: gossip tick timers consumed while a machine is dead
    // must be re-chained on rejoin (and after each round completes), or
    // an RB machine deadlocks in FoldWait waiting on an estimate that no
    // timer will ever finish
    let plan = FaultPlan {
        link: LinkModel { base: 1, jitter: 2, loss: 0.05, dup: 0.0 },
        partitions: vec![],
        churn: vec![
            ChurnEvent::Leave { at: 200, node: 2 },
            ChurnEvent::Join { at: 500, node: 2 },
        ],
        initially_dormant: vec![],
    };
    let report = ClusterRunner::new(
        Topology::Ring.build(12).unwrap(),
        ClusterConfig {
            scheme: SchemeKind::Rb, // needs_global_residuals: FoldWait gates
            tol: 0.0,
            max_iters: 250,
            seed: 29,
            machines: 4,
            workers: 1,
            collective: CollectiveKind::Gossip,
            max_staleness: 1,
            silence_timeout: 8,
            ..Default::default()
        },
        plan,
        quad_factory(12, 2, 29),
    )
    .unwrap()
    .run();
    assert_eq!(report.counters.leaves, 1);
    assert_eq!(report.counters.joins, 1);
    assert_eq!(report.iterations, 250,
               "the designated machine must estimate every round");
    assert!(report.live_machines[2], "machine 2 rejoined");
    let last = report.recorder.stats.last().unwrap();
    assert!(last.max_primal < 5e-2,
            "post-rejoin consensus, primal {}", last.max_primal);
}

#[test]
fn machine_level_activity_rule_runs_to_completion() {
    // the NAP effective-topology rule on the quotient graph: with an
    // aggressive config the run must stay finite and the trace/counter
    // books must agree whether or not links actually toggle
    let report = ClusterRunner::new(
        Topology::Complete.build(12).unwrap(),
        ClusterConfig {
            scheme: SchemeKind::Nap,
            tol: 0.0,
            max_iters: 120,
            seed: 13,
            machines: 4,
            workers: 1,
            collective: CollectiveKind::Tree,
            activity: Some(crate::net::ActivityConfig {
                off_below: 0.6,
                on_above: 0.95,
                patience: 2,
            }),
            ..Default::default()
        },
        FaultPlan::none(),
        quad_factory(12, 2, 13),
    )
    .unwrap()
    .run();
    assert_eq!(report.iterations, 120);
    for th in &report.thetas {
        assert!(th.iter().all(|x| x.is_finite()));
    }
    let offs = report
        .trace
        .iter()
        .filter(|e| matches!(e.kind, TraceKind::EdgeOff { .. }))
        .count() as u64;
    assert_eq!(offs, report.counters.edges_deactivated);
}

// -- satellite: checker-state leader-election handoff -------------------------

#[test]
fn scripted_handoff_matches_no_handoff_bitwise() {
    // the ROADMAP item made a regression test: a mid-run re-root with the
    // StopTracker serialized → shipped → resumed at the new root must
    // produce the same stop iteration and the same recorded curves as the
    // undisturbed run when faults are off — the handoff moves state, not
    // arithmetic (partials still fold in machine-id order at whichever
    // root commits them)
    for scheme in [SchemeKind::Fixed, SchemeKind::Nap, SchemeKind::Rb] {
        let run = |handoff: Option<(u64, usize)>| {
            ClusterRunner::new(
                Topology::Ring.build(12).unwrap(),
                ClusterConfig { scheme, tol: 1e-4, max_iters: 80, seed: 23,
                                machines: 3, workers: 1,
                                collective: CollectiveKind::Tree, handoff,
                                ..Default::default() },
                FaultPlan::none(),
                quad_factory(12, 2, 41),
            )
            .unwrap()
            .run()
        };
        let clean = run(None);
        // round 5 is always before the earliest possible stop (warmup 5 +
        // patience 3), so the drill fires mid-run in every scheme
        let handed = run(Some((5, 2)));
        // the drill actually ran: re-root + serialized state on the wire
        assert!(handed
            .trace
            .iter()
            .any(|e| matches!(e.kind, TraceKind::Reroot { root: 2 })),
            "{scheme:?}: the scripted handoff must re-root at machine 2");
        assert!(handed
            .trace
            .iter()
            .any(|e| matches!(e.kind,
                              TraceKind::Deliver { what: "checker", .. })),
            "{scheme:?}: the StopSnapshot must travel the network");
        // ... and changed nothing the protocol can observe
        assert_eq!(clean.iterations, handed.iterations, "{scheme:?}");
        assert_eq!(clean.converged, handed.converged, "{scheme:?}");
        assert_eq!(clean.thetas, handed.thetas, "{scheme:?}");
        assert_eq!(clean.recorder.stats.len(), handed.recorder.stats.len());
        for (a, b) in clean.recorder.stats.iter().zip(&handed.recorder.stats) {
            assert_stats_bit_equal(a, b);
        }
    }
}

#[test]
fn departing_root_hands_checker_to_successor() {
    // churn-driven handoff: when the root machine leaves, it serializes
    // the tracker to its successor before going dark; the survivors keep
    // folding every round and the recorder carries across the transfer
    let plan = FaultPlan {
        link: LinkModel { base: 1, jitter: 2, loss: 0.0, dup: 0.0 },
        partitions: vec![],
        churn: vec![ChurnEvent::Leave { at: 400, node: 0 }],
        initially_dormant: vec![],
    };
    let report = ClusterRunner::new(
        Topology::Ring.build(12).unwrap(),
        ClusterConfig {
            scheme: SchemeKind::Rb, // FoldWait-gated: verdicts must keep coming
            tol: 0.0,
            max_iters: 200,
            seed: 7,
            machines: 4,
            workers: 1,
            collective: CollectiveKind::Tree,
            max_staleness: 1,
            silence_timeout: 8,
            collective_timeout: 12,
            fallback_after: 2,
            ..Default::default()
        },
        plan,
        quad_factory(12, 2, 51),
    )
    .unwrap()
    .run();
    assert_eq!(report.counters.leaves, 1);
    assert!(!report.live_machines[0]);
    assert!(report
        .trace
        .iter()
        .any(|e| matches!(e.kind, TraceKind::Handoff { from: 0, to: 1 })),
        "the departing root must serialize its tracker to machine 1");
    assert!(report
        .trace
        .iter()
        .any(|e| matches!(e.kind, TraceKind::Deliver { what: "checker", .. })),
        "the snapshot must be delivered, not migrated omnisciently");
    assert_eq!(report.iterations, 200,
               "the resumed tracker keeps committing every round");
    let last = report.recorder.stats.last().unwrap();
    assert!(last.max_primal < 5e-2, "survivor consensus, primal {}",
            last.max_primal);
}

#[test]
fn departing_gossip_holder_hands_checker_to_successor() {
    // regression for the gossip-side handoff port: the push-sum recorder
    // used to be omniscient (the driver re-designated the lowest live
    // machine for free). Now the departing holder must serialize the
    // tracker and ship it like the tree does — and the successor must
    // replay rounds it finished estimating while the snapshot was in
    // flight, or the commit cursor stalls forever
    let plan = FaultPlan {
        link: LinkModel { base: 1, jitter: 2, loss: 0.0, dup: 0.0 },
        partitions: vec![],
        churn: vec![ChurnEvent::Leave { at: 400, node: 0 }],
        initially_dormant: vec![],
    };
    let report = ClusterRunner::new(
        Topology::Ring.build(12).unwrap(),
        ClusterConfig {
            scheme: SchemeKind::Rb, // FoldWait-gated: verdicts must keep coming
            tol: 0.0,
            max_iters: 200,
            seed: 7,
            machines: 4,
            workers: 1,
            collective: CollectiveKind::Gossip,
            max_staleness: 1,
            silence_timeout: 8,
            ..Default::default()
        },
        plan,
        quad_factory(12, 2, 51),
    )
    .unwrap()
    .run();
    assert_eq!(report.counters.leaves, 1);
    assert!(!report.live_machines[0]);
    assert!(report
        .trace
        .iter()
        .any(|e| matches!(e.kind, TraceKind::Handoff { from: 0, to: 1 })),
        "the departing gossip holder must hand the tracker to machine 1");
    assert!(report
        .trace
        .iter()
        .any(|e| matches!(e.kind, TraceKind::Deliver { what: "checker", .. })),
        "the snapshot must travel the network, not migrate omnisciently");
    assert_eq!(report.iterations, 200,
               "the resumed holder commits every estimated round");
    let last = report.recorder.stats.last().unwrap();
    assert!(last.max_primal < 5e-2, "survivor consensus, primal {}",
            last.max_primal);
}

// -- satellite: θ snapshots ride the tree's Part traffic ----------------------

#[test]
fn tree_app_metric_from_shipped_thetas_matches_sharded_hook_bitwise() {
    // with an app-metric hook installed, each machine attaches its
    // committed θ^{r+1} span to the rootward Part message and the
    // recorder assembles the snapshot from delivered payloads; the
    // hook's input — and hence the recorded app_error stream — must
    // stay bit-identical to the omniscient sharded leader's
    let hook = |_r: usize, thetas: &[Vec<f64>], live: &[bool]| {
        let mut acc = 0.0;
        for (th, &l) in thetas.iter().zip(live) {
            if l {
                for &x in th {
                    acc += x * x;
                }
            }
        }
        acc
    };
    for scheme in [SchemeKind::Fixed, SchemeKind::Rb, SchemeKind::VpNap] {
        let sharded = ShardedRunner::new(
            Topology::Ring.build(12).unwrap(),
            ShardedConfig { scheme, tol: 1e-4, max_iters: 80, seed: 23,
                            workers: 3, ..Default::default() },
        )
        .run_hooked(quad_factory(12, 2, 41), hook)
        .unwrap();

        let cluster = ClusterRunner::new(
            Topology::Ring.build(12).unwrap(),
            ClusterConfig { scheme, tol: 1e-4, max_iters: 80, seed: 23,
                            machines: 3, workers: 1,
                            collective: CollectiveKind::Tree,
                            ..Default::default() },
            FaultPlan::none(),
            quad_factory(12, 2, 41),
        )
        .unwrap()
        .with_app_metric(hook)
        .run();

        assert_eq!(sharded.iterations, cluster.iterations, "{scheme:?}");
        assert_eq!(sharded.thetas, cluster.thetas, "{scheme:?}");
        assert_eq!(sharded.recorder.stats.len(), cluster.recorder.stats.len());
        for (a, b) in sharded.recorder.stats.iter().zip(&cluster.recorder.stats) {
            assert_stats_bit_equal(a, b);
            assert_eq!(a.app_error.to_bits(), b.app_error.to_bits(),
                       "{scheme:?} iter {}: shipped-θ hook input must be \
                        bit-identical to the omniscient assembly", a.iter);
        }
    }
}

#[test]
fn zero_round_budget_returns_theta0() {
    let sharded = ShardedRunner::new(
        Topology::Ring.build(9).unwrap(),
        ShardedConfig { max_iters: 0, ..Default::default() },
    )
    .run(quad_factory(9, 3, 41))
    .unwrap();
    let cluster = ClusterRunner::new(
        Topology::Ring.build(9).unwrap(),
        ClusterConfig { max_iters: 0, machines: 3, workers: 1,
                        ..Default::default() },
        FaultPlan::none(),
        quad_factory(9, 3, 41),
    )
    .unwrap()
    .run();
    assert_eq!(cluster.iterations, 0);
    assert!(!cluster.converged);
    assert_eq!(cluster.thetas, sharded.thetas, "θ⁰ seeding is runner-identical");
}

// -- satellite: persistent pool vs scoped spawns ------------------------------

#[test]
fn pool_and_scoped_cluster_runs_are_bit_identical() {
    // the tentpole parity matrix at cluster level: pool execution
    // (interior/boundary overlap included) vs the seed's scoped spawns
    // must agree on everything observable — θ, stats, the full event
    // trace, and every counter except overlap_dispatches (scoped never
    // overlaps by construction)
    for lossy_links in [false, true] {
        for scheme in [SchemeKind::Ap, SchemeKind::Rb] {
            let run = |exec| {
                let plan = if lossy_links {
                    FaultPlan {
                        link: LinkModel { base: 2, jitter: 3, loss: 0.1, dup: 0.02 },
                        ..FaultPlan::none()
                    }
                } else {
                    FaultPlan::none()
                };
                ClusterRunner::new(
                    Topology::Ring.build(12).unwrap(),
                    ClusterConfig { scheme, tol: 0.0, max_iters: 60, seed: 5,
                                    machines: 3, workers: 2,
                                    max_staleness: 1, silence_timeout: 8,
                                    collective_timeout: 16, fallback_after: 2,
                                    exec, tracing: true,
                                    ..Default::default() },
                    plan,
                    quad_factory(12, 2, 37),
                )
                .unwrap()
                .run()
            };
            let pool = run(ExecMode::Pool);
            let scoped = run(ExecMode::Scoped);
            let tag = if lossy_links { "lossy" } else { "clean" };
            assert_eq!(pool.thetas, scoped.thetas, "{tag}/{scheme:?}");
            assert_eq!(pool.iterations, scoped.iterations, "{tag}/{scheme:?}");
            assert_eq!(pool.virtual_time, scoped.virtual_time, "{tag}/{scheme:?}");
            assert_eq!(pool.trace, scoped.trace,
                       "{tag}/{scheme:?}: overlap must not change the event flow");
            assert_eq!(pool.recorder.stats.len(), scoped.recorder.stats.len());
            for (a, b) in pool.recorder.stats.iter().zip(&scoped.recorder.stats) {
                assert_stats_bit_equal(a, b);
            }
            assert_eq!(scoped.counters.overlap_dispatches, 0, "{tag}/{scheme:?}");
            let mut pc = pool.counters;
            let mut sc = scoped.counters;
            pc.overlap_dispatches = 0;
            sc.overlap_dispatches = 0;
            assert_eq!(pc, sc, "{tag}/{scheme:?}: network books must agree");
        }
    }
}

#[test]
fn delayed_boundary_batches_stall_only_boundary_slices() {
    // the overlap-specific bar: with every boundary batch delayed by link
    // latency, a pool-mode machine must start its interior solves while
    // it waits (the phase barrier falls on the boundary slice only) — and
    // the split must be invisible in the results
    let run = |exec| {
        ClusterRunner::new(
            Topology::Ring.build(12).unwrap(),
            ClusterConfig { scheme: SchemeKind::Ap, tol: 0.0, max_iters: 40,
                            seed: 3, machines: 3, workers: 1, exec,
                            ..Default::default() },
            FaultPlan {
                link: LinkModel { base: 3, jitter: 0, loss: 0.0, dup: 0.0 },
                ..FaultPlan::none()
            },
            quad_factory(12, 2, 7),
        )
        .unwrap()
        .run()
    };
    let pool = run(ExecMode::Pool);
    let scoped = run(ExecMode::Scoped);
    assert!(pool.counters.overlap_dispatches > 0,
            "delayed boundary batches must trigger interior overlap");
    assert_eq!(scoped.counters.overlap_dispatches, 0);
    assert_eq!(pool.thetas, scoped.thetas,
               "overlapped interior slices must be bit-invisible");
    assert_eq!(pool.iterations, scoped.iterations);
    assert_eq!(pool.recorder.stats.len(), scoped.recorder.stats.len());
    for (a, b) in pool.recorder.stats.iter().zip(&scoped.recorder.stats) {
        assert_stats_bit_equal(a, b);
    }
}

#[test]
fn threaded_machine_pools_match_single_shard_pools() {
    // worker count only regroups the intra-machine partials; with a
    // fixed budget and a decentralized scheme, node results are
    // bit-identical whether each machine runs 1 shard inline or 3
    // shards on scoped threads
    let run = |workers: usize| {
        ClusterRunner::new(
            Topology::Ring.build(12).unwrap(),
            ClusterConfig { scheme: SchemeKind::Ap, tol: 0.0, max_iters: 50,
                            seed: 2, machines: 2, workers,
                            ..Default::default() },
            FaultPlan::none(),
            quad_factory(12, 2, 19),
        )
        .unwrap()
        .run()
    };
    let one = run(1);
    let three = run(3);
    assert_eq!(one.thetas, three.thetas);
    assert_eq!(one.iterations, three.iterations);
    assert_eq!(one.workers_per_machine, 1);
    assert_eq!(three.workers_per_machine, 3);
}
