//! Pluggable collective-reduction layer: rooted spanning-tree
//! reduce/broadcast and push-sum gossip all-reduce over live
//! inter-machine links.
//!
//! Both collectives consume the per-machine [`StatPartial`] lists that
//! phase B produces and deliver a per-round *verdict* — the global
//! residual pair the RB scheme and the convergence check consume — but
//! they sit at opposite ends of the exactness/decentralization tradeoff:
//!
//! * **Tree** ([`TreeTopology`]): partial lists travel rootward along a
//!   BFS spanning tree of the live machine graph (children concatenate,
//!   never pre-combine), and the root absorbs them **in machine-id
//!   order** with the shared [`crate::metrics::RunningFold`] — machine
//!   slices are ascending contiguous node ranges, so this *is* the
//!   node-id-order fold of the sharded coordinator, reproduced exactly.
//!   The price is 2·depth network hops of latency per round and a root
//!   bottleneck; lost messages are retransmitted on a timeout, and a
//!   machine that can't reach the root indefinitely substitutes a local
//!   fold (counted as a fallback) so an isolated machine never poisons
//!   the cluster.
//! * **Gossip** ([`GossipRound`]): every machine starts a push-sum
//!   instance per round — mass vector `[node count, Σf, Σ‖θ‖², Ση,
//!   η-count, ones, Σθ…]` and weight 1 — and repeatedly halves-and-pushes
//!   to a deterministically rotating live neighbour. *Cumulative*
//!   per-link mass makes the exchange loss-robust (a dropped message's
//!   mass rides on the next one), and max-gossip fields carry the max/min
//!   statistics. After a fixed tick budget each machine reads ratio
//!   estimates: ratios of mass components converge to ratios of the true
//!   totals over the machine's live component, so the estimates
//!   *renormalize* over whatever subset of the cluster is reachable — no
//!   membership oracle needed. The `ones` slot is the live-count
//!   estimator: the designated recorder deposits exactly one unit per
//!   round, so `count/ones` estimates the live node cardinality `n̂`, and
//!   the runner restores the true `√n̂` residual scale (and `Σf ≈ avg_f·n̂`
//!   objective) from the per-node-normalized base estimates
//!   (`√(avg‖θ‖² − ‖θ̄‖²)` and `η⁰‖θ̄ − θ̄_prev‖`). Both residuals carry
//!   the same factor, so the RB balance *ratio* — and hence every RB
//!   decision — is invariant to it; a component that never reaches the
//!   designated machine reads `n̂ = 0` and keeps the normalized scale.
//!
//! The driver (`cluster::runner`) owns all message flow; this module owns
//! the data structures and the pure arithmetic.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::error::{Error, Result};
use crate::graph::LiveView;
use crate::metrics::StatPartial;

/// Which reduction layer replaces the omniscient oracle fold.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    /// rooted spanning-tree reduce/broadcast (exact, centralized-ish)
    Tree,
    /// push-sum gossip all-reduce (approximate, fully decentralized)
    Gossip,
}

impl CollectiveKind {
    pub const ALL: [CollectiveKind; 2] = [CollectiveKind::Tree, CollectiveKind::Gossip];

    pub fn name(self) -> &'static str {
        match self {
            CollectiveKind::Tree => "tree",
            CollectiveKind::Gossip => "gossip",
        }
    }

    pub fn parse(s: &str) -> Result<CollectiveKind> {
        match s {
            "tree" => Ok(CollectiveKind::Tree),
            "gossip" => Ok(CollectiveKind::Gossip),
            other => Err(Error::Config(format!(
                "unknown collective '{other}' (tree|gossip)"
            ))),
        }
    }
}

// ---------------------------------------------------------------------------
// Tree

/// BFS spanning tree over the live machine graph, rooted at the lowest
/// live machine id. Deterministic: adjacency lists are sorted, so the
/// same live view always yields the same tree.
#[derive(Debug, Clone)]
pub(crate) struct TreeTopology {
    pub parent: Vec<Option<usize>>,
    pub children: Vec<Vec<usize>>,
    pub root: usize,
    /// the [`LiveView::generation`] this tree was built at
    pub built_gen: u64,
}

pub(crate) fn build_tree(view: &LiveView) -> TreeTopology {
    build_tree_rooted(view, None)
}

/// [`build_tree`] with an optional preferred root (the leader-election
/// handoff re-roots at the machine that received the checker state); a
/// dead or absent preference falls back to the lowest live machine.
pub(crate) fn build_tree_rooted(view: &LiveView, prefer: Option<usize>)
                                -> TreeTopology {
    let g = view.graph();
    let n = g.len();
    let mut parent = vec![None; n];
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
    let root = prefer
        .filter(|&m| m < n && view.node_live(m))
        .unwrap_or_else(|| (0..n).find(|&i| view.node_live(i)).unwrap_or(0));
    let mut seen = vec![false; n];
    seen[root] = true;
    let mut queue = VecDeque::from([root]);
    while let Some(u) = queue.pop_front() {
        for (slot, &v) in g.neighbors(u).iter().enumerate() {
            if view.slot_live(u, slot) && !seen[v] {
                seen[v] = true;
                parent[v] = Some(u);
                children[u].push(v);
                queue.push_back(v);
            }
        }
    }
    TreeTopology { parent, children, root, built_gen: view.generation() }
}

/// Members of `m`'s subtree (m first, then BFS order).
pub(crate) fn subtree(topo: &TreeTopology, m: usize) -> Vec<usize> {
    let mut out = vec![m];
    let mut i = 0;
    while i < out.len() {
        let u = out[i];
        i += 1;
        out.extend_from_slice(&topo.children[u]);
    }
    out
}

/// Tree-collective state (per-machine inboxes live here; the runner owns
/// the message flow).
pub(crate) struct TreeState {
    pub topo: TreeTopology,
    /// `inbox[m][round][origin] = origin's shard partials` — m's
    /// accumulated view of its subtree for each in-flight round
    pub inbox: Vec<BTreeMap<u64, BTreeMap<usize, Vec<StatPartial>>>>,
    /// `theta_inbox[m][round][origin] = origin's flat committed θ^{round+1}
    /// span` — populated only when the run carries an app-metric hook
    /// (the snapshots ride the rootward `Part` traffic so the recorder's
    /// metric assembly needs no remote reads)
    pub theta_inbox: Vec<BTreeMap<u64, BTreeMap<usize, Vec<f64>>>>,
    /// rounds machine m has already forwarded rootward
    pub sent_up: Vec<BTreeSet<u64>>,
}

impl TreeState {
    pub fn new(view: &LiveView) -> TreeState {
        let n = view.graph().len();
        TreeState {
            topo: build_tree(view),
            inbox: (0..n).map(|_| BTreeMap::new()).collect(),
            theta_inbox: (0..n).map(|_| BTreeMap::new()).collect(),
            sent_up: (0..n).map(|_| BTreeSet::new()).collect(),
        }
    }
}

// ---------------------------------------------------------------------------
// Gossip

/// Offsets into the push-sum mass vector (followed by `dim` θ-sum slots).
pub(crate) const MASS_COUNT: usize = 0;
pub(crate) const MASS_F: usize = 1;
pub(crate) const MASS_SQ: usize = 2;
pub(crate) const MASS_ETA: usize = 3;
pub(crate) const MASS_ETA_CNT: usize = 4;
/// live-count estimator mass: exactly one unit deposited per round by the
/// designated recorder, so `x[MASS_COUNT] / x[MASS_ONE]` estimates the
/// live node cardinality of the component
pub(crate) const MASS_ONE: usize = 5;
pub(crate) const MASS_THETA: usize = 6;

/// One machine's push-sum instance for one round.
pub(crate) struct GossipRound {
    /// current mass (see the `MASS_*` layout)
    pub x: Vec<f64>,
    pub w: f64,
    /// max-gossip: [max_primal, max_dual, max_eta, −min_eta]
    pub maxes: [f64; 4],
    /// exchange ticks performed
    pub sent: u32,
    /// own mass deposited (mass received before the machine reached this
    /// round is buffered in an uninited instance)
    pub inited: bool,
    pub done: bool,
    /// cumulative mass pushed per destination (loss robustness: the
    /// receiver consumes deltas, so a dropped message's mass rides along
    /// on the next push over the same link)
    pub cum_out: BTreeMap<usize, (Vec<f64>, f64)>,
    /// last cumulative mass seen per source
    pub last_in: BTreeMap<usize, (Vec<f64>, f64)>,
}

impl GossipRound {
    pub fn new(mass_len: usize) -> GossipRound {
        GossipRound {
            x: vec![0.0; mass_len],
            w: 0.0,
            maxes: [0.0, 0.0, 0.0, f64::NEG_INFINITY],
            sent: 0,
            inited: false,
            done: false,
            cum_out: BTreeMap::new(),
            last_in: BTreeMap::new(),
        }
    }

    /// Deposit the machine's own round mass (weight 1).
    pub fn add_own(&mut self, mass: &[f64], maxes: [f64; 4]) {
        debug_assert!(!self.inited);
        for (a, b) in self.x.iter_mut().zip(mass) {
            *a += b;
        }
        self.w += 1.0;
        for k in 0..4 {
            self.maxes[k] = self.maxes[k].max(maxes[k]);
        }
        self.inited = true;
    }

    /// Absorb a cumulative push from `src` (delta against the last seen
    /// cumulative from that source).
    pub fn absorb(&mut self, src: usize, mass: &[f64], weight: f64, maxes: [f64; 4]) {
        let len = self.x.len();
        let last = self
            .last_in
            .entry(src)
            .or_insert_with(|| (vec![0.0; len], 0.0));
        for k in 0..len {
            self.x[k] += mass[k] - last.0[k];
        }
        self.w += weight - last.1;
        last.0.copy_from_slice(mass);
        last.1 = weight;
        for k in 0..4 {
            self.maxes[k] = self.maxes[k].max(maxes[k]);
        }
    }

    /// Halve the mass, fold the pushed half into `dst`'s cumulative
    /// stream and return a clone of the cumulative (what goes on the
    /// wire).
    pub fn push_half(&mut self, dst: usize) -> (Vec<f64>, f64) {
        let len = self.x.len();
        self.x.iter_mut().for_each(|v| *v *= 0.5);
        self.w *= 0.5;
        let cum = self
            .cum_out
            .entry(dst)
            .or_insert_with(|| (vec![0.0; len], 0.0));
        for k in 0..len {
            cum.0[k] += self.x[k];
        }
        cum.1 += self.w;
        (cum.0.clone(), cum.1)
    }
}

/// Ratio estimates read off a finished gossip round.
pub(crate) struct GossipEstimate {
    pub gmean: Vec<f64>,
    /// per-node objective Σf / n (scale-free for the relative checker)
    pub avg_f: f64,
    /// per-node-normalized global primal √(avg‖θ‖² − ‖θ̄‖²)
    pub gp: f64,
    pub mean_eta: f64,
    pub min_eta: f64,
    pub max_eta: f64,
    pub max_primal: f64,
    pub max_dual: f64,
    /// estimated live node count (`count/ones` ratio); `0.0` when the
    /// component holds no ones mass (designated machine unreachable)
    pub n_live: f64,
}

pub(crate) fn estimate(round: &GossipRound, dim: usize) -> GossipEstimate {
    let count = round.x[MASS_COUNT];
    let mut gmean = vec![0.0; dim];
    let (avg_f, avg_sq) = if count > 0.0 {
        for (k, g) in gmean.iter_mut().enumerate() {
            *g = round.x[MASS_THETA + k] / count;
        }
        (round.x[MASS_F] / count, round.x[MASS_SQ] / count)
    } else {
        (0.0, 0.0)
    };
    let norm_sq: f64 = gmean.iter().map(|g| g * g).sum();
    let gp = (avg_sq - norm_sq).max(0.0).sqrt();
    let eta_cnt = round.x[MASS_ETA_CNT];
    let (mean_eta, min_eta) = if eta_cnt > 0.0 && round.maxes[3].is_finite() {
        (round.x[MASS_ETA] / eta_cnt, -round.maxes[3])
    } else {
        (0.0, 0.0)
    };
    let ones = round.x[MASS_ONE];
    let n_live = if ones > 1e-300 { count / ones } else { 0.0 };
    GossipEstimate {
        gmean,
        avg_f,
        gp,
        mean_eta,
        min_eta,
        max_eta: round.maxes[2],
        max_primal: round.maxes[0],
        max_dual: round.maxes[1],
        n_live,
    }
}

/// Gossip-collective state.
pub(crate) struct GossipState {
    /// push-sum exchange ticks per round
    pub ticks: u32,
    /// virtual ticks between exchanges
    pub spacing: u64,
    pub mass_len: usize,
    pub rounds: Vec<BTreeMap<u64, GossipRound>>,
}

impl GossipState {
    pub fn new(machines: usize, dim: usize, ticks: u32, spacing: u64) -> GossipState {
        GossipState {
            ticks,
            spacing,
            mass_len: MASS_THETA + dim,
            rounds: (0..machines).map(|_| BTreeMap::new()).collect(),
        }
    }

    /// Auto tick budget: 4·⌈log₂M⌉ + 4 (min 8). Push-sum error decays
    /// roughly geometrically per tick, but sparse quotient graphs (rings)
    /// mix by diameter rather than log M — measured on a 4-machine ring,
    /// 6 ticks can leave ~90% worst-case ratio error while 12 ticks is
    /// already ≤ 1.5% and 16 ticks ≤ 0.1%; the default leans accurate
    /// and the knob stays configurable for the latency-vs-accuracy sweep.
    pub fn auto_ticks(machines: usize) -> u32 {
        if machines <= 1 {
            0
        } else {
            (4 * (usize::BITS - (machines - 1).leading_zeros()) + 4).max(8)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, LiveView, Topology};

    #[test]
    fn tree_covers_all_live_machines() {
        let mut view = LiveView::new(Topology::Ring.build(6).unwrap());
        let t = build_tree(&view);
        assert_eq!(t.root, 0);
        assert_eq!(subtree(&t, 0).len(), 6, "root subtree spans the cluster");
        for m in 1..6 {
            assert!(t.parent[m].is_some());
        }
        // kill a machine: the tree re-spans the survivors
        view.set_node(0, false);
        let t2 = build_tree(&view);
        assert_eq!(t2.root, 1, "re-roots at the lowest live machine");
        assert_eq!(subtree(&t2, 1).len(), 5);
        assert!(t2.parent[0].is_none(), "dead machines hang off nothing");
        assert_ne!(t2.built_gen, t.built_gen);
    }

    #[test]
    fn subtree_members_are_consistent() {
        let view = LiveView::new(Topology::Chain.build(5).unwrap());
        let t = build_tree(&view); // chain: 0-1-2-3-4 rooted at 0
        assert_eq!(subtree(&t, 2), vec![2, 3]);
        assert_eq!(subtree(&t, 4), vec![4]);
        assert_eq!(t.parent[3], Some(2));
    }

    #[test]
    fn push_sum_ratios_converge_on_a_pair() {
        // two machines, mass [count, f]: after enough symmetric exchanges
        // both ratio estimates approach the global f per node
        let mut a = GossipRound::new(2);
        let mut b = GossipRound::new(2);
        a.add_own(&[2.0, 10.0], [0.0; 4]);
        b.add_own(&[3.0, 5.0], [0.0; 4]);
        for _ in 0..30 {
            let (ma, wa) = a.push_half(1);
            b.absorb(0, &ma, wa, [0.0; 4]);
            let (mb, wb) = b.push_half(0);
            a.absorb(1, &mb, wb, [0.0; 4]);
        }
        let truth = 15.0 / 5.0;
        for gr in [&a, &b] {
            let est = gr.x[1] / gr.x[0];
            assert!((est - truth).abs() < 1e-9, "est {est} vs {truth}");
        }
    }

    #[test]
    fn cumulative_stream_survives_a_dropped_message() {
        // drop one push: the next push's cumulative carries the mass, so
        // totals are conserved once a message finally lands
        let mut a = GossipRound::new(1);
        let mut b = GossipRound::new(1);
        a.add_own(&[8.0], [0.0; 4]);
        b.add_own(&[0.0], [0.0; 4]);
        let (_lost_mass, _lost_w) = a.push_half(1); // dropped on the wire
        let (m2, w2) = a.push_half(1); // delivered
        b.absorb(0, &m2, w2, [0.0; 4]);
        let total = a.x[0] + b.x[0];
        assert!((total - 8.0).abs() < 1e-12, "mass conserved: {total}");
        let wtot = a.w + b.w;
        assert!((wtot - 2.0).abs() < 1e-12, "weight conserved: {wtot}");
    }

    #[test]
    fn estimate_reads_ratio_statistics() {
        let mut gr = GossipRound::new(MASS_THETA + 2);
        // 4 nodes total, Σf = 8, Σ‖θ‖² = 20, Ση = 12 over 6 edges,
        // ones = 2 (mixing halved the unit twice against count),
        // Σθ = (4, 8)
        let mass = [4.0, 8.0, 20.0, 12.0, 6.0, 2.0, 4.0, 8.0];
        gr.add_own(&mass, [0.5, 0.25, 3.0, -1.0]);
        let est = estimate(&gr, 2);
        assert_eq!(est.avg_f, 2.0);
        assert_eq!(est.gmean, vec![1.0, 2.0]);
        // avg_sq = 5, ‖ḡ‖² = 5 ⇒ gp = 0
        assert_eq!(est.gp, 0.0);
        assert_eq!(est.mean_eta, 2.0);
        assert_eq!(est.min_eta, 1.0);
        assert_eq!(est.max_eta, 3.0);
        assert_eq!(est.max_primal, 0.5);
        assert_eq!(est.max_dual, 0.25);
        assert_eq!(est.n_live, 2.0, "count/ones ratio");
    }

    #[test]
    fn live_count_estimator_renormalizes_under_churn() {
        // full cluster: machines hold [2, 3, 4] nodes; machine 0 is the
        // designated recorder and deposits one unit of ones mass. After
        // all-pairs mixing every machine's count/ones ratio reads the
        // true cardinality 9.
        let run = |counts: &[f64], designated_present: bool| -> Vec<f64> {
            let n = counts.len();
            let mut rounds: Vec<GossipRound> =
                counts.iter().map(|_| GossipRound::new(MASS_THETA)).collect();
            for (m, gr) in rounds.iter_mut().enumerate() {
                let mut mass = vec![0.0; MASS_THETA];
                mass[MASS_COUNT] = counts[m];
                if m == 0 && designated_present {
                    mass[MASS_ONE] = 1.0;
                }
                gr.add_own(&mass, [0.0, 0.0, 0.0, f64::NEG_INFINITY]);
            }
            for _ in 0..24 {
                for src in 0..n {
                    let dst = (src + 1) % n;
                    let (mass, w) = rounds[src].push_half(dst);
                    let maxes = rounds[src].maxes;
                    rounds[dst].absorb(src, &mass, w, maxes);
                }
            }
            rounds.iter().map(|gr| estimate(gr, 0).n_live).collect()
        };

        for est in run(&[2.0, 3.0, 4.0], true) {
            assert!((est - 9.0).abs() < 1e-6, "full cluster n̂ = {est}");
        }
        // churn: the 4-node machine left — the surviving component's
        // ratio renormalizes to 5 with no membership oracle
        for est in run(&[2.0, 3.0], true) {
            assert!((est - 5.0).abs() < 1e-6, "post-churn n̂ = {est}");
        }
        // partitioned away from the designated machine: no ones mass, the
        // estimate degrades to the sentinel 0 (callers keep the
        // per-node-normalized scale)
        for est in run(&[3.0, 4.0], false) {
            assert_eq!(est, 0.0, "no designated ⇒ sentinel");
        }
    }

    #[test]
    fn auto_ticks_scale_with_machine_count() {
        assert_eq!(GossipState::auto_ticks(1), 0);
        assert_eq!(GossipState::auto_ticks(2), 8);
        assert_eq!(GossipState::auto_ticks(4), 12);
        assert_eq!(GossipState::auto_ticks(8), 16);
        assert_eq!(GossipState::auto_ticks(9), 20);
    }

    #[test]
    fn collective_kind_parses() {
        assert_eq!(CollectiveKind::parse("tree").unwrap(), CollectiveKind::Tree);
        assert_eq!(CollectiveKind::parse("gossip").unwrap(), CollectiveKind::Gossip);
        assert!(CollectiveKind::parse("ring").is_err());
        assert_eq!(CollectiveKind::Tree.name(), "tree");
    }

    #[test]
    fn tree_handles_singleton_cluster() {
        let view = LiveView::new(Graph::new(1, &[]).unwrap());
        let t = build_tree(&view);
        assert_eq!(t.root, 0);
        assert_eq!(subtree(&t, 0), vec![0]);
    }
}
