//! The per-machine runtime: a PR 1-style sharded worker pool over the
//! machine's contiguous node slice, with stamp-indexed boundary caches
//! toward neighbouring machines.
//!
//! Intra-machine execution is *barrier-synchronous* and reuses the
//! coordinator's building blocks verbatim: the zero-copy double-buffered
//! [`ParamArena`] (allocated over the full graph; only local and
//! boundary-in blocks are ever touched), the
//! [`crate::consensus::LocalSolver::solve_into`] hot path writing θ^{t+1}
//! straight into the parity-`q` block, and per-shard
//! [`StatPartial`]s with centered second-pass statistics, accumulated in
//! node order. Shards execute as one job set on the cluster's persistent
//! [`PhasePool`] (workers spawned once per run and fed per-phase jobs —
//! the set join is the phase barrier), as scoped spawns under
//! [`ExecMode::Scoped`] (the seed behaviour, kept as the bit-parity
//! baseline), or inline when the machine has a single shard. All three
//! paths share one dispatch helper ([`run_shards`]) so the spawn/inline
//! decision lives in exactly one place, and they are
//! arithmetic-identical because all cross-shard data flows through the
//! parity-disciplined arena and the partials combine in shard order.
//!
//! ## Interior vs boundary slices (the overlap contract)
//!
//! Each shard's nodes are split once at build time into *interior*
//! (every neighbour on this machine) and *boundary* (≥ 1 cross-machine
//! edge) index lists. Phase A is per-node independent, so while a
//! machine still waits for boundary θ/η batches in flight the driver may
//! dispatch the interior slice to the pool asynchronously
//! ([`MachineRt::dispatch_interior`]) and keep processing network
//! events; once the boundary caches are ready it joins the ticket,
//! resolves the caches, and completes only the boundary slice
//! ([`MachineRt::run_phase_a_boundary`]). Interior solves read only
//! local parity-p state — their liveness mask short-circuits on the
//! own-machine test, so they never touch the link mask — which keeps
//! the split bit-exact and race-free. Phase B is *never* split: its
//! [`StatPartial`] absorption order is part of the bit contract.
//!
//! The *driver* (the cluster runner's single-threaded event loop) owns
//! everything between phases: it resolves boundary θ/η reads from the
//! stamp-indexed caches into the arena's remote blocks before a phase
//! runs, and extracts boundary batches to send after a phase completes.
//! During a pool phase no driver code touches the arena, so the
//! coordinator's aliasing discipline carries over unchanged.

use std::collections::BTreeMap;
use std::ops::Range;

use crate::consensus::LocalSolver;
use crate::coordinator::ParamArena;
use crate::graph::{Graph, NodeId};
use crate::kernel::{DualPolicy, KernelScratch, NodeKernel, SlotView};
use crate::metrics::StatPartial;
use crate::penalty::{SchemeKind, SchemeParams};
use crate::pool::{note_thread_spawn, ExecMode, PhasePool, Ticket};
use crate::util::rng::Pcg;

use super::partition::MachinePartition;

/// Machine lifecycle phase (mirrors the async runner's node phases at
/// machine granularity).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum MPhase {
    /// waiting to run phase A of round `t`
    Solve,
    /// waiting to run phase B of round `t`
    Reduce,
    /// phase B done; phase C pending (RB waits for the round verdict)
    FoldWait,
    /// scripted joiner that has not activated yet
    Dormant,
    /// left the cluster
    Dead,
    /// finished `max_iters` rounds
    Done,
}

/// Per-node state owned by exactly one machine (and, within it, one
/// shard). θ and published η live only in the machine's arena; λ/η/scheme
/// state lives in the shared protocol kernel.
pub(crate) struct MNode<S> {
    /// relabeled global node id
    pub id: NodeId,
    pub solver: S,
    pub kernel: NodeKernel,
    /// flat η-arena index of the *incoming* penalty η_{j→i} per slot
    pub in_eta_idx: Vec<usize>,
    /// machine of each neighbour slot (own id ⇒ intra-machine edge)
    pub nbr_machine: Vec<usize>,
}

/// Per-shard worker scratch, reused across rounds.
pub(crate) struct ShardScratch {
    kernel: KernelScratch,
    pub partial: StatPartial,
    /// raw Σ‖θ‖² over the shard (gossip mass; separate accumulator so the
    /// centered statistics stay bit-identical to the coordinator's)
    pub raw_sq: f64,
}

impl ShardScratch {
    fn new(dim: usize, max_deg: usize) -> ShardScratch {
        ShardScratch {
            kernel: KernelScratch::new(dim, max_deg),
            partial: StatPartial::new(dim),
            raw_sq: 0.0,
        }
    }
}

/// The cluster's [`SlotView`]: zero-copy parity reads out of the
/// machine's arena (intra-machine neighbours and driver-materialized
/// boundary blocks alike), masked by machine-link liveness. Reads are
/// exact (lag 0): boundary staleness is resolved driver-side *before*
/// the pool phase runs, with its own accounting.
///
/// Safety of the unsafe reads: identical to the coordinator's aliasing
/// discipline — phase A reads only parity-p θ, phase B reads the
/// post-join parity-q θ and the stable parity-p η (see [`super`]).
struct MachineSlots<'a> {
    arena: &'a ParamArena,
    nbrs: &'a [NodeId],
    nbr_machine: &'a [usize],
    link_live: &'a [bool],
    mid: usize,
    theta_parity: usize,
    eta_parity: usize,
    in_eta_idx: &'a [usize],
}

impl SlotView for MachineSlots<'_> {
    fn live(&self, slot: usize) -> bool {
        let pm = self.nbr_machine[slot];
        pm == self.mid || self.link_live[pm]
    }

    fn theta(&mut self, slot: usize) -> (&[f64], u64) {
        // Safety: see type docs.
        (unsafe { self.arena.theta(self.theta_parity, self.nbrs[slot]) }, 0)
    }

    fn theta_again(&mut self, slot: usize) -> &[f64] {
        // Safety: see type docs.
        unsafe { self.arena.theta(self.theta_parity, self.nbrs[slot]) }
    }

    fn eta_in(&mut self, slot: usize) -> f64 {
        // Safety: see type docs.
        unsafe { self.arena.eta(self.eta_parity, self.in_eta_idx[slot]) }
    }
}

/// One simulated machine (see module docs).
pub(crate) struct MachineRt<S> {
    pub id: usize,
    /// this machine's contiguous slice of (relabeled) node ids
    pub span: Range<usize>,
    pub shards: Vec<Range<usize>>,
    /// per shard: chunk-local indices of nodes whose every neighbour is
    /// on this machine (safe to solve while boundary batches are in
    /// flight)
    pub interior: Vec<Vec<usize>>,
    /// per shard: chunk-local indices of nodes with ≥ 1 cross-machine
    /// edge (the slice the phase barrier is really about)
    pub boundary: Vec<Vec<usize>>,
    pub arena: ParamArena,
    pub nodes: Vec<MNode<S>>,
    pub scratch: Vec<ShardScratch>,
    mask_scratch: Vec<bool>,
    pub phase: MPhase,
    pub t: u64,
    pub start_round: u64,
    /// `link_live[p]` — whether the machine link self↔p currently carries
    /// traffic (true for p == self.id); refreshed against the quotient
    /// LiveView generation by the runner
    pub link_live: Vec<bool>,
    pub link_gen: u64,
    /// parity of the arena buffer holding the *current* θ / published η
    /// (for the rejoin parity sync; tracked by the phase runners)
    pub theta_parity: usize,
    pub eta_parity: usize,

    // -- boundary-in state ---------------------------------------------------
    /// sorted remote node ids this machine reads (θ side)
    pub in_nodes: Vec<NodeId>,
    pub in_node_machine: Vec<usize>,
    pub in_theta: Vec<BTreeMap<u64, Vec<f64>>>,
    /// incoming cross penalties: (remote j, slot of the local node in j's
    /// adjacency, machine of j) per cache entry
    pub in_eta_edges: Vec<(NodeId, usize, usize)>,
    pub in_eta: Vec<BTreeMap<u64, f64>>,
    /// (remote j, local i) → index into `in_eta`/`in_eta_edges`
    pub in_eta_index: BTreeMap<(NodeId, NodeId), usize>,

    // -- boundary-out state --------------------------------------------------
    /// per quotient slot: local nodes with ≥ 1 edge into that machine
    pub out_nodes: Vec<Vec<NodeId>>,
    /// per quotient slot: cross edges (local i, remote j, slot of j in i)
    pub out_edges: Vec<Vec<(NodeId, NodeId, usize)>>,

    // -- per-round products --------------------------------------------------
    pub partials: Vec<StatPartial>,
    pub raw_sq: f64,
    /// round → flat local θ^{round+1} (pruned behind the verdict horizon)
    pub snapshots: BTreeMap<u64, Vec<f64>>,
    /// round → folded/estimated (global_primal, global_dual)
    pub verdicts: BTreeMap<u64, (f64, f64)>,
    pub latest_globals: (f64, f64),
    /// verdicts known cover rounds `[0, horizon)`
    pub horizon: u64,
    pub needs_globals: bool,

    // -- timers --------------------------------------------------------------
    pub wake_epoch: u64,
    pub timeout_armed: bool,
    pub coll_epoch: u64,
    pub coll_armed: bool,
    /// per-round collective retransmit counts (tree)
    pub retries: BTreeMap<u64, u32>,
    /// this machine's previous collective mean estimate — the
    /// decentralized analogue of the leader's `global_mean_prev` (gossip
    /// duals and tree fallback verdicts derive their Δmean from it;
    /// starts at zero like the engines)
    pub coll_mean_prev: Vec<f64>,
}

/// Rounds of snapshots/verdicts retained behind a machine's own horizon
/// (bounds memory; far larger than any reachable run-ahead spread).
const KEEP_ROUNDS: u64 = 16;

impl<S: LocalSolver + Send> MachineRt<S> {
    /// Build machine `id`. `order[new] = orig` is the relabeling
    /// permutation; solver construction and θ⁰ seeding are keyed by
    /// *original* node ids exactly like the sharded runner, so a
    /// one-machine cluster is bit-identical to it.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn build(
        graph: &Graph,
        part: &MachinePartition,
        id: usize,
        workers: usize,
        order: &[NodeId],
        factory: &(dyn Fn(NodeId) -> S + Send + Sync),
        dim: usize,
        scheme: SchemeKind,
        params: SchemeParams,
        seed: u64,
        dormant: bool,
        max_iters: usize,
    ) -> MachineRt<S> {
        let span = part.ranges[id].clone();
        let shards = crate::graph::shard_ranges_in(graph, span.clone(), workers);
        let arena = ParamArena::new(graph, dim);

        let mut nodes: Vec<MNode<S>> = Vec::with_capacity(span.len());
        let mut max_deg = 0usize;
        let mut needs_globals = false;
        for i in span.clone() {
            let orig = order[i];
            let mut solver = factory(orig);
            assert_eq!(solver.dim(), dim, "homogeneous dims");
            let deg = graph.degree(i);
            max_deg = max_deg.max(deg);
            let mut rng = Pcg::new(seed, orig as u64 + 1);
            let theta0 = solver.initial_param(&mut rng);
            assert_eq!(theta0.len(), dim);
            let kernel = NodeKernel::new(scheme, params, deg, dim);
            // Safety: single-threaded construction; parity 0 is the
            // pre-loop write buffer.
            unsafe {
                arena.theta_mut(0, i).copy_from_slice(&theta0);
                arena.eta_out_mut(0, i).copy_from_slice(&kernel.etas);
            }
            let in_eta_idx = graph
                .neighbors(i)
                .iter()
                .map(|&j| {
                    let slot = graph.edge_slot(j, i).expect("graph symmetry");
                    arena.eta_index(j, slot)
                })
                .collect();
            let nbr_machine = graph
                .neighbors(i)
                .iter()
                .map(|&j| part.machine_of[j])
                .collect();
            needs_globals |= kernel.needs_global_residuals();
            nodes.push(MNode { id: i, solver, kernel, in_eta_idx, nbr_machine });
        }

        // boundary-in indices (sorted ⇒ deterministic cache layout)
        let mut in_set: Vec<NodeId> = Vec::new();
        let mut in_eta_edges: Vec<(NodeId, usize, usize)> = Vec::new();
        let mut in_eta_index: BTreeMap<(NodeId, NodeId), usize> = BTreeMap::new();
        for i in span.clone() {
            for &j in graph.neighbors(i) {
                if part.machine_of[j] == id {
                    continue;
                }
                in_set.push(j);
                let slot = graph.edge_slot(j, i).expect("graph symmetry");
                let idx = in_eta_edges.len();
                in_eta_edges.push((j, slot, part.machine_of[j]));
                in_eta_index.insert((j, i), idx);
            }
        }
        in_set.sort_unstable();
        in_set.dedup();
        let in_node_machine: Vec<usize> =
            in_set.iter().map(|&j| part.machine_of[j]).collect();
        let in_theta = in_set.iter().map(|_| BTreeMap::new()).collect();
        let in_eta = in_eta_edges.iter().map(|_| BTreeMap::new()).collect();

        // boundary-out, per quotient slot
        let qdeg = part.quotient.degree(id);
        let mut out_nodes: Vec<Vec<NodeId>> = vec![Vec::new(); qdeg];
        let mut out_edges: Vec<Vec<(NodeId, NodeId, usize)>> = vec![Vec::new(); qdeg];
        for (qslot, &p) in part.quotient.neighbors(id).iter().enumerate() {
            for i in span.clone() {
                let mut touches = false;
                for (slot, &j) in graph.neighbors(i).iter().enumerate() {
                    if part.machine_of[j] == p {
                        touches = true;
                        out_edges[qslot].push((i, j, slot));
                    }
                }
                if touches {
                    out_nodes[qslot].push(i);
                }
            }
        }

        // interior/boundary split per shard (chunk-local indices): a node
        // is interior iff every neighbour lives on this machine, so its
        // phase-A solve touches no boundary cache and no link mask
        let lo = span.start;
        let mut interior: Vec<Vec<usize>> = Vec::with_capacity(shards.len());
        let mut boundary: Vec<Vec<usize>> = Vec::with_capacity(shards.len());
        for shard in &shards {
            let mut ins = Vec::new();
            let mut outs = Vec::new();
            for i in shard.clone() {
                let k = i - shard.start;
                if nodes[i - lo].nbr_machine.iter().all(|&pm| pm == id) {
                    ins.push(k);
                } else {
                    outs.push(k);
                }
            }
            interior.push(ins);
            boundary.push(outs);
        }

        let workers_used = shards.len();
        MachineRt {
            id,
            phase: if dormant {
                MPhase::Dormant
            } else if max_iters == 0 {
                MPhase::Done
            } else {
                MPhase::Solve
            },
            t: 0,
            start_round: if dormant { u64::MAX } else { 0 },
            link_live: vec![true; part.len()],
            link_gen: u64::MAX, // force a refresh before the first phase
            theta_parity: 0,
            eta_parity: 0,
            scratch: (0..workers_used).map(|_| ShardScratch::new(dim, max_deg)).collect(),
            mask_scratch: Vec::with_capacity(max_deg),
            partials: (0..workers_used).map(|_| StatPartial::new(dim)).collect(),
            raw_sq: 0.0,
            snapshots: BTreeMap::new(),
            verdicts: BTreeMap::new(),
            latest_globals: (f64::INFINITY, f64::INFINITY),
            horizon: 0,
            needs_globals,
            wake_epoch: 0,
            timeout_armed: false,
            coll_epoch: 0,
            coll_armed: false,
            retries: BTreeMap::new(),
            coll_mean_prev: vec![0.0; dim],
            in_nodes: in_set,
            in_node_machine,
            in_theta,
            in_eta_edges,
            in_eta,
            in_eta_index,
            out_nodes,
            out_edges,
            span,
            shards,
            interior,
            boundary,
            arena,
            nodes,
        }
    }

    pub(crate) fn local_len(&self) -> usize {
        self.span.len()
    }

    /// Whether the machine participates in rounds at all right now.
    pub(crate) fn running(&self) -> bool {
        matches!(self.phase, MPhase::Solve | MPhase::Reduce | MPhase::FoldWait)
    }

    // -- boundary caches -----------------------------------------------------

    /// Cache readiness of boundary θ for ideal stamp `ideal` (phase A:
    /// `t`; phase B: `t+1`). Dead-link sources are the caller's concern.
    pub(crate) fn in_theta_ready(&self, idx: usize, ideal: u64, stale: u64,
                                 force: bool) -> bool {
        let c = &self.in_theta[idx];
        if force {
            !c.is_empty()
        } else {
            c.range(ideal.saturating_sub(stale)..).next().is_some()
        }
    }

    pub(crate) fn in_eta_ready(&self, idx: usize, ideal: u64, stale: u64,
                               force: bool) -> bool {
        let c = &self.in_eta[idx];
        if force {
            !c.is_empty()
        } else {
            c.range(ideal.saturating_sub(stale)..).next().is_some()
        }
    }

    /// Resolve a boundary θ read (largest stamp ≤ ideal, falling forward
    /// to the smallest newer stamp only when nothing older exists) and
    /// materialize it into the parity-`ideal&1` arena block. Returns the
    /// used stamp. Entries below the resolved stamp are pruned; the
    /// newest entry is never dropped.
    pub(crate) fn resolve_in_theta(&mut self, idx: usize, ideal: u64) -> u64 {
        let cache = &mut self.in_theta[idx];
        let best = cache.range(..=ideal).next_back().map(|(&s, _)| s);
        let used = match best {
            Some(s) => {
                cache.retain(|&k, _| k >= s);
                s
            }
            None => *cache.keys().next().expect("cache checked nonempty"),
        };
        let th = cache.get(&used).expect("retained");
        // Safety: the driver resolves boundary reads strictly between pool
        // phases; nothing else touches a remote block.
        unsafe { self.arena.theta_mut((ideal & 1) as usize, self.in_nodes[idx]) }
            .copy_from_slice(th);
        used
    }

    /// Resolve a boundary η read into the remote sender's out-edge slot of
    /// the parity-`ideal&1` η buffer. Returns the used stamp.
    pub(crate) fn resolve_in_eta(&mut self, idx: usize, ideal: u64) -> u64 {
        let cache = &mut self.in_eta[idx];
        let best = cache.range(..=ideal).next_back().map(|(&s, _)| s);
        let used = match best {
            Some(s) => {
                cache.retain(|&k, _| k >= s);
                s
            }
            None => *cache.keys().next().expect("cache checked nonempty"),
        };
        let v = *cache.get(&used).expect("retained");
        let (j, slot, _) = self.in_eta_edges[idx];
        // Safety: as in resolve_in_theta — remote η blocks are driver-only.
        unsafe { self.arena.eta_out_mut((ideal & 1) as usize, j) }[slot] = v;
        used
    }

    // -- pool phases ---------------------------------------------------------

    /// Phase A over all shards: local solves on epoch-`t` parameters,
    /// θ^{t+1} written into the parity-`q` arena blocks.
    pub(crate) fn run_phase_a(&mut self, graph: &Graph, t: u64,
                              pool: &PhasePool, mode: ExecMode) {
        let mid = self.id;
        let arena: &ParamArena = &self.arena;
        let link_live = &self.link_live[..];
        run_shards(&self.shards, &mut self.nodes, &mut self.scratch, pool, mode,
                   |_w, nodes, sc| {
                       shard_phase_a(graph, arena, link_live, mid, nodes, sc, t);
                   });
        self.theta_parity = ((t & 1) ^ 1) as usize;
    }

    /// Complete phase A for the boundary slices only — the tail of an
    /// overlapped round whose interior slices already ran via
    /// [`MachineRt::dispatch_interior`]. Bit-exact vs the unsplit phase:
    /// phase A is per-node independent and every node runs exactly once
    /// on the same parity-p inputs.
    pub(crate) fn run_phase_a_boundary(&mut self, graph: &Graph, t: u64,
                                       pool: &PhasePool, mode: ExecMode) {
        let mid = self.id;
        let arena: &ParamArena = &self.arena;
        let link_live = &self.link_live[..];
        let boundary = &self.boundary;
        run_shards(&self.shards, &mut self.nodes, &mut self.scratch, pool, mode,
                   |w, nodes, sc| {
                       shard_phase_a_subset(graph, arena, link_live, mid, nodes,
                                            &boundary[w], sc, t);
                   });
        self.theta_parity = ((t & 1) ^ 1) as usize;
    }

    /// Dispatch the interior slices of phase A to the pool *without
    /// waiting* — the overlap path, taken while boundary θ/η batches are
    /// still in flight. Returns `None` when every shard's interior list
    /// is empty (nothing worth overlapping).
    ///
    /// # Safety
    ///
    /// The jobs capture raw pointers into this machine's `nodes`,
    /// `scratch`, `interior` and `link_live` buffers, its `arena`, and
    /// the runner's `graph`. The caller must join (or drop — both block)
    /// the returned ticket before anything reads or writes those
    /// buffers again, and must not mutate the graph meanwhile. The
    /// driver honours this by only touching the stamp-indexed boundary
    /// caches and timers (plain `MachineRt` fields, disjoint
    /// allocations) between dispatch and join.
    pub(crate) unsafe fn dispatch_interior(&mut self, graph: &Graph,
                                           pool: &PhasePool, t: u64)
                                           -> Option<Ticket> {
        if self.interior.iter().all(|ix| ix.is_empty()) {
            return None;
        }
        let nodes_base = self.nodes.as_mut_ptr();
        let sc_base = self.scratch.as_mut_ptr();
        let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            Vec::with_capacity(self.shards.len());
        for (w, shard) in self.shards.iter().enumerate() {
            let ix = &self.interior[w];
            if ix.is_empty() {
                continue;
            }
            let job = InteriorJob::<S> {
                graph: graph as *const Graph,
                arena: &self.arena as *const ParamArena,
                link_live: self.link_live.as_ptr(),
                link_len: self.link_live.len(),
                mid: self.id,
                // Safety: shard chunks partition the nodes buffer; one
                // scratch slot per shard.
                nodes: unsafe { nodes_base.add(shard.start - self.span.start) },
                nodes_len: shard.end - shard.start,
                idx: ix.as_ptr(),
                idx_len: ix.len(),
                sc: unsafe { sc_base.add(w) },
                t,
            };
            // Safety: forwarded to the caller (see the doc contract).
            jobs.push(Box::new(move || unsafe { job.run() }));
        }
        // Safety: the jobs only capture raw pointers; the borrow contract
        // is the documented one above, discharged by the Ticket.
        Some(unsafe { pool.dispatch(jobs) })
    }

    /// Phase B over all shards: duals, residuals, objectives, per-shard
    /// partial reduction (and the raw Σ‖θ‖² gossip mass). Never split
    /// into interior/boundary slices — the partial absorption order is
    /// bit-sensitive.
    pub(crate) fn run_phase_b(&mut self, graph: &Graph, t: u64,
                              pool: &PhasePool, mode: ExecMode) {
        let mid = self.id;
        let arena: &ParamArena = &self.arena;
        let link_live = &self.link_live[..];
        run_shards(&self.shards, &mut self.nodes, &mut self.scratch, pool, mode,
                   |_w, nodes, sc| {
                       shard_phase_b(graph, arena, link_live, mid, nodes, sc, t);
                   });
        // fold products out of the scratch (shard order)
        self.raw_sq = 0.0;
        for w in 0..self.scratch.len() {
            self.scratch[w].partial.store_into(&mut self.partials[w]);
            self.raw_sq += self.scratch[w].raw_sq;
        }
    }

    /// Phase C: penalty-scheme updates + publish η^{t+1} into parity `q`.
    /// Sequential — per-node work is independent and reads nothing
    /// cross-node, so the arithmetic is placement-invariant.
    pub(crate) fn run_phase_c(&mut self, graph: &Graph, t: u64, globals: (f64, f64)) {
        let q = ((t & 1) ^ 1) as usize;
        let mid = self.id;
        let arena = &self.arena;
        let link_live = &self.link_live;
        let mask = &mut self.mask_scratch;
        for st in &mut self.nodes {
            let deg = graph.degree(st.id);
            mask.clear();
            let mut all = true;
            for slot in 0..deg {
                let pm = st.nbr_machine[slot];
                let l = pm == mid || link_live[pm];
                all &= l;
                mask.push(l);
            }
            // parity-critical: a fully live neighbourhood passes None so
            // the schemes run the exact pre-liveness arithmetic
            let live = if all { None } else { Some(&mask[..]) };
            st.kernel.observe(t as usize, globals, live);
            // Safety: we own every local node; parity-q η is the write
            // buffer until the next round's phase B resolves into parity p.
            unsafe { arena.eta_out_mut(q, st.id) }.copy_from_slice(&st.kernel.etas);
        }
        self.eta_parity = q;
    }

    /// Mirror every local θ/η block into the opposite-parity buffer — the
    /// rejoin path, where the machine may restart at a round of either
    /// parity while its buffers only hold the last-written side.
    pub(crate) fn sync_parities(&mut self) {
        let tp = self.theta_parity;
        let ep = self.eta_parity;
        for i in self.span.clone() {
            // Safety: driver-side; the machine is not running any phase.
            let th = unsafe { self.arena.theta(tp, i) }.to_vec();
            unsafe { self.arena.theta_mut(tp ^ 1, i) }.copy_from_slice(&th);
            let eta = unsafe { self.arena.eta_out_mut(ep, i) }.to_vec();
            unsafe { self.arena.eta_out_mut(ep ^ 1, i) }.copy_from_slice(&eta);
        }
    }

    /// Record the round-`t` θ^{t+1} snapshot (flat, local nodes in span
    /// order) and prune snapshots far behind the verdict horizon.
    pub(crate) fn snapshot(&mut self, t: u64) {
        let q = ((t & 1) ^ 1) as usize;
        let dim = self.arena.dim();
        let mut flat = vec![0.0; self.span.len() * dim];
        for (off, i) in self.span.clone().enumerate() {
            // Safety: driver-side, between pool phases.
            flat[off * dim..(off + 1) * dim]
                .copy_from_slice(unsafe { self.arena.theta(q, i) });
        }
        self.snapshots.insert(t, flat);
        let floor = self.horizon.saturating_sub(KEEP_ROUNDS);
        self.snapshots.retain(|&r, _| r >= floor);
        self.verdicts.retain(|&r, _| r >= floor);
        self.retries.retain(|&r, _| r >= floor);
    }

    /// Copy the machine's best round-`r` snapshot (same resolution rule
    /// as [`MachineRt::snapshot_for`]) straight into per-node slots of
    /// `out`, keyed by original ids via `order` — the allocation-free
    /// variant the per-commit app-metric path uses.
    pub(crate) fn snapshot_read(&self, r: u64, dim: usize, order: &[NodeId],
                                out: &mut [Vec<f64>]) {
        let flat = self
            .snapshots
            .range(..=r)
            .next_back()
            .map(|s| s.1)
            .or_else(|| self.snapshots.values().next());
        for (off, i) in self.span.clone().enumerate() {
            match flat {
                Some(flat) => out[order[i]]
                    .copy_from_slice(&flat[off * dim..(off + 1) * dim]),
                // never ran a round: θ⁰ sits in parity 0.
                // Safety: driver-side, between pool phases.
                None => out[order[i]]
                    .copy_from_slice(unsafe { self.arena.theta(0, i) }),
            }
        }
    }

    /// The machine's best θ snapshot for round `r` (exact round, else the
    /// newest older one, else the oldest available, else θ⁰).
    pub(crate) fn snapshot_for(&self, r: u64, dim: usize) -> Vec<f64> {
        if let Some(s) = self.snapshots.range(..=r).next_back() {
            return s.1.clone();
        }
        if let Some(s) = self.snapshots.iter().next() {
            return s.1.clone();
        }
        // never ran a round: θ⁰ sits in parity 0
        let mut flat = vec![0.0; self.span.len() * dim];
        for (off, i) in self.span.clone().enumerate() {
            // Safety: driver-side.
            flat[off * dim..(off + 1) * dim]
                .copy_from_slice(unsafe { self.arena.theta(0, i) });
        }
        flat
    }

    /// Extract the boundary θ batch toward quotient slot `qslot` from the
    /// parity of stamp `stamp` (θ^{stamp} = parity `stamp & 1`).
    pub(crate) fn boundary_theta(&self, qslot: usize, stamp: u64)
                                 -> Vec<(NodeId, Vec<f64>)> {
        let parity = (stamp & 1) as usize;
        self.out_nodes[qslot]
            .iter()
            .map(|&i| {
                // Safety: driver-side, between pool phases.
                (i, unsafe { self.arena.theta(parity, i) }.to_vec())
            })
            .collect()
    }

    /// Extract the boundary η batch toward quotient slot `qslot` from the
    /// nodes' current working penalties (η^{t+1} right after phase C; η⁰
    /// at the init handshake).
    pub(crate) fn boundary_eta(&self, qslot: usize) -> Vec<(NodeId, NodeId, f64)> {
        let lo = self.span.start;
        self.out_edges[qslot]
            .iter()
            .map(|&(i, j, slot)| (i, j, self.nodes[i - lo].kernel.etas[slot]))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Shard dispatch + phase bodies. The per-node arithmetic is the shared
// kernel ([`NodeKernel`]) behind the machine-link-masked [`MachineSlots`]
// view: when every link is live the mask never fires and the
// floating-point stream is the coordinator's — now by shared code, with
// the one-machine bit-parity test still pinning it end to end.

/// Run one phase body over every shard: inline for a single shard, as a
/// job set on the persistent pool, or on scoped spawns (the seed
/// behaviour). The spawn/inline decision for *both* phases lives here —
/// callers only supply the per-shard body `f(shard_index, chunk,
/// scratch)`.
fn run_shards<S, F>(shards: &[Range<usize>], nodes: &mut [MNode<S>],
                    scratch: &mut [ShardScratch], pool: &PhasePool,
                    mode: ExecMode, f: F)
where
    S: LocalSolver + Send,
    F: Fn(usize, &mut [MNode<S>], &mut ShardScratch) + Sync,
{
    if shards.len() == 1 {
        f(0, nodes, &mut scratch[0]);
        return;
    }
    match mode {
        ExecMode::Scoped => {
            let mut node_rest: &mut [MNode<S>] = nodes;
            let mut sc_rest: &mut [ShardScratch] = scratch;
            std::thread::scope(|s| {
                for (w, shard) in shards.iter().enumerate() {
                    let len = shard.end - shard.start;
                    let (nchunk, tail) = node_rest.split_at_mut(len);
                    node_rest = tail;
                    let (schunk, stail) = sc_rest.split_at_mut(1);
                    sc_rest = stail;
                    let fr = &f;
                    note_thread_spawn();
                    s.spawn(move || fr(w, nchunk, &mut schunk[0]));
                }
            });
        }
        ExecMode::Pool => {
            let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                Vec::with_capacity(shards.len());
            let mut node_rest: &mut [MNode<S>] = nodes;
            let mut sc_rest: &mut [ShardScratch] = scratch;
            for (w, shard) in shards.iter().enumerate() {
                let len = shard.end - shard.start;
                let (nchunk, tail) = node_rest.split_at_mut(len);
                node_rest = tail;
                let (schunk, stail) = sc_rest.split_at_mut(1);
                sc_rest = stail;
                let fr = &f;
                jobs.push(Box::new(move || fr(w, nchunk, &mut schunk[0])));
            }
            if let Err(p) = pool.run(jobs) {
                // scoped spawns propagate a shard panic onto the driver
                // thread at the join; keep that contract under the pool
                panic!("{}", p.message);
            }
        }
    }
}

/// Raw-pointer captures for one overlapped interior phase-A job. See
/// [`MachineRt::dispatch_interior`] for the lifetime/aliasing contract.
struct InteriorJob<S> {
    graph: *const Graph,
    arena: *const ParamArena,
    link_live: *const bool,
    link_len: usize,
    mid: usize,
    nodes: *mut MNode<S>,
    nodes_len: usize,
    idx: *const usize,
    idx_len: usize,
    sc: *mut ShardScratch,
    t: u64,
}

// Safety: the pointers target this machine's heap buffers (plus the
// runner-owned, never-mutated graph); nothing else reads or writes them
// between dispatch and the ticket join, which synchronizes-with the
// job's completion through the pool latch.
unsafe impl<S> Send for InteriorJob<S> {}

impl<S: LocalSolver> InteriorJob<S> {
    /// Safety: per the [`MachineRt::dispatch_interior`] contract.
    unsafe fn run(self) {
        let graph = unsafe { &*self.graph };
        let arena = unsafe { &*self.arena };
        let link_live =
            unsafe { std::slice::from_raw_parts(self.link_live, self.link_len) };
        let nodes =
            unsafe { std::slice::from_raw_parts_mut(self.nodes, self.nodes_len) };
        let idx = unsafe { std::slice::from_raw_parts(self.idx, self.idx_len) };
        let sc = unsafe { &mut *self.sc };
        shard_phase_a_subset(graph, arena, link_live, self.mid, nodes, idx, sc,
                             self.t);
    }
}

/// One node's phase-A solve (shared by the full-shard sweep and the
/// interior/boundary subset sweeps; the split changes only visit order,
/// which phase A is insensitive to — every node reads parity-p state
/// and writes its own parity-q block exactly once).
fn phase_a_node<S: LocalSolver>(graph: &Graph, arena: &ParamArena,
                                link_live: &[bool], mid: usize,
                                st: &mut MNode<S>, sc: &mut ShardScratch,
                                t: u64) {
    let p = (t & 1) as usize;
    let q = p ^ 1;
    // Safety: phase A reads only parity-p θ (local peers' θ^t and the
    // driver-materialized boundary θ) and writes only our parity-q
    // block — the coordinator's discipline verbatim; solve_into fully
    // overwrites the block.
    let theta_t = unsafe { arena.theta(p, st.id) };
    let mut view = MachineSlots {
        arena,
        nbrs: graph.neighbors(st.id),
        nbr_machine: &st.nbr_machine,
        link_live,
        mid,
        theta_parity: p,
        eta_parity: p,
        in_eta_idx: &st.in_eta_idx,
    };
    let theta_next = unsafe { arena.theta_mut(q, st.id) };
    st.kernel.solve_into(&mut st.solver, theta_t, graph.degree(st.id),
                         &mut view, &mut sc.kernel, theta_next);
}

fn shard_phase_a<S: LocalSolver>(graph: &Graph, arena: &ParamArena,
                                 link_live: &[bool], mid: usize,
                                 nodes: &mut [MNode<S>], sc: &mut ShardScratch,
                                 t: u64) {
    for st in nodes {
        phase_a_node(graph, arena, link_live, mid, st, sc, t);
    }
}

/// Phase A over the chunk-local subset `idx` of one shard's nodes.
fn shard_phase_a_subset<S: LocalSolver>(graph: &Graph, arena: &ParamArena,
                                        link_live: &[bool], mid: usize,
                                        nodes: &mut [MNode<S>], idx: &[usize],
                                        sc: &mut ShardScratch, t: u64) {
    for &k in idx {
        phase_a_node(graph, arena, link_live, mid, &mut nodes[k], sc, t);
    }
}

fn shard_phase_b<S: LocalSolver>(graph: &Graph, arena: &ParamArena,
                                 link_live: &[bool], mid: usize,
                                 nodes: &mut [MNode<S>], sc: &mut ShardScratch,
                                 t: u64) {
    let p = (t & 1) as usize;
    let q = p ^ 1;
    let dim = arena.dim();
    sc.partial.reset();
    sc.raw_sq = 0.0;
    for st in nodes.iter_mut() {
        let deg = graph.degree(st.id);
        // Safety: after the phase-A join every parity-q θ block is
        // complete; η parity-p holds the round's penalties (local peers'
        // phase-C publishes from last round + driver-resolved boundary η).
        let th_new = unsafe { arena.theta(q, st.id) };
        let mut view = MachineSlots {
            arena,
            nbrs: graph.neighbors(st.id),
            nbr_machine: &st.nbr_machine,
            link_live,
            mid,
            theta_parity: q,
            eta_parity: p,
            in_eta_idx: &st.in_eta_idx,
        };
        st.kernel.reduce(&mut st.solver, th_new, deg, &mut view,
                         DualPolicy::exact(), &mut sc.kernel);

        // shard-local reduction, node order = sequential order
        sc.partial.absorb_node(st.kernel.f_self, st.kernel.primal,
                               st.kernel.dual, &st.kernel.etas, th_new);
    }
    // second shard-local pass: spread about the shard mean (the centered
    // statistic the Chan-style fold needs), then the raw Σ‖θ‖² gossip
    // mass in a third sweep — separate accumulators, so splitting the
    // passes keeps both streams bit-identical.
    // Safety: parity-q θ is stable throughout phase B.
    sc.partial.finish_centered(
        nodes.len(),
        nodes.iter().map(|st| unsafe { arena.theta(q, st.id) }),
        &mut sc.kernel.nbr_mean,
    );
    for st in nodes.iter() {
        // Safety: as above.
        let th = unsafe { arena.theta(q, st.id) };
        for k in 0..dim {
            sc.raw_sq += th[k] * th[k];
        }
    }
}
