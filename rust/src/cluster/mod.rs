//! Hybrid cluster runtime: sharded worker pools per machine, a simulated
//! network between machines, and decentralized collective reductions.
//!
//! This is the composition of the three runtimes that came before it.
//! The sequential [`crate::consensus::Engine`] defines the arithmetic;
//! the sharded [`crate::coordinator`] runs it on a worker pool with a
//! zero-copy arena; the async [`crate::net`] runtime runs it over a
//! faulty network — but one node per endpoint, and with a global fold
//! that is an *omniscient-simulator oracle* (the simulator folds every
//! node's contribution in id order, something no real deployment could
//! do). Here the deployment shape is realistic end to end:
//!
//! ```text
//! cluster
//! ├── machine 0 ─ sharded worker pool over nodes  0..a   (PR 1 arena,
//! │               solve_into, per-shard centered partials — barrier-
//! │               synchronous inside the machine)
//! ├── machine 1 ─ pool over nodes a..b
//! │     ⋮            boundary θ/η and statistic partials travel ONLY
//! └── machine M─1    through net::sim (latency, loss, duplication,
//!                    partitions, churn — *between* machines)
//! ```
//!
//! **Hierarchy.** `machine ⊃ shard ⊃ node`: the (RCM-relabeled) node
//! graph is split into `M` contiguous machine slices by the same
//! degree-weighted splitter the pool uses for shards
//! ([`MachinePartition`]), and each machine splits its slice again into
//! `W` worker shards. For large graphs the documented construction path
//! is the *two-level* ordering [`hierarchical_partition`]: global RCM
//! picks the machine cut (few cross-machine edges), then RCM re-runs
//! *inside* each machine's range so per-machine arena reads stay dense
//! too — at 10^6 nodes build the graph (`graph` module docs put the CSR
//! itself at ~72 MB for mean degree 4), call
//! `hierarchical_partition(&g, machines)`, and hand the returned graph +
//! partition to the cluster runner; the returned `order[new_id] =
//! original_id` maps results back to caller ids. Intra-machine neighbour reads go through the
//! machine's arena exactly as in the coordinator; cross-machine edges
//! read stamp-indexed boundary caches filled by [`crate::net::sim`]
//! messages, with the async runtime's bounded-staleness and
//! silence-timeout semantics at machine granularity.
//!
//! **Execution and overlap.** The runner owns one persistent
//! [`crate::pool::PhasePool`] (sized to the widest machine's shard
//! count, created once per runner), and every machine feeds it per-phase
//! job sets ([`ClusterConfig::exec`]; `ExecMode::Scoped` keeps the
//! spawn-per-phase baseline). Because phase A is per-node independent,
//! a machine whose boundary batches are still in flight dispatches the
//! *interior* slice of each shard — nodes with no cross-machine edge,
//! the majority under RCM relabeling — to the pool and returns to the
//! event loop; once the boundary state lands, only the boundary slice
//! remains, so the phase barrier falls on that slice alone. Phase B
//! absorbs statistic partials in a bit-sensitive order and is never
//! split. The split is bit-invisible (same fold order, same StatPartial
//! absorption order, same kernel observation sequence — pinned by
//! `cluster::tests`), and driver code may not read a machine's node
//! state while its interior ticket is outstanding: overlap-window reads
//! are restricted to boundary caches, timers and the snapshot ring;
//! every other path joins the ticket first
//! ([`crate::metrics::NetCounters::overlap_dispatches`] counts the
//! overlap wins).
//!
//! **Collectives.** The oracle fold is replaced by a pluggable reduction
//! ([`CollectiveKind`]) over the live machine quotient graph:
//!
//! | fold        | exactness                        | cost / failure story |
//! |-------------|----------------------------------|----------------------|
//! | oracle (PR 3) | exact, node-id order           | physically unrealizable |
//! | `tree`      | **exact**: partial lists concatenate rootward and the root absorbs them in machine-id (= node-id) order with the coordinator's Chan-style fold | 2·depth hops latency per round; root bottleneck; timeout-retransmit under loss; detached machines fall back to local folds |
//! | `gossip`    | approximate: loss-robust push-sum ratio estimates + max-gossip; a ones-mass live-count estimator n̂ restores the true √n̂ residual scale and the Σf ≈ avg_f·n̂ objective | fully decentralized; renormalizes over the live component (n̂ tracks churn); accuracy ∝ tick budget; estimates bias RB and the stop rule |
//!
//! The `cluster_scenarios` experiment measures the *extra rounds per
//! scheme* each collective costs against the oracle fold under loss —
//! the tradeoff is a number in a CSV, not an anecdote.
//!
//! **Parity contracts** (pinned by `cluster::tests`):
//!
//! * 1 machine, zero faults, tree collective ⇒ **bit-for-bit** equal to
//!   [`crate::coordinator::ShardedRunner`] (same worker count): θ,
//!   iteration count, convergence flag and every recorded IterStats
//!   field, for all seven penalty schemes.
//! * M machines, zero faults, tree, one worker per machine ⇒ bit-for-bit
//!   equal to `ShardedRunner` with `workers = M` — the tree folds the
//!   same shard partials in the same order, so even the RB reference
//!   scheme's folded-residual trajectory is identical. Against the
//!   sequential `Engine` the node trajectories of every *decentralized*
//!   scheme are exact; only the folded global statistics differ by the
//!   documented Chan-vs-flat reassociation (last-ulp regrouping).
//! * Any faults, any collective: same seed ⇒ bit-identical event trace.
//!
//! **Liveness under partition.** A machine cut off by a transport
//! partition keeps iterating: boundary reads fall back to the newest
//! cached values after `silence_timeout`, and after `fallback_after`
//! unanswered retransmissions it substitutes a *local* fold for the
//! missing verdict (counted in
//! [`crate::metrics::NetCounters::collective_fallbacks`]). The rest of
//! the cluster folds without it after `collective_timeout`
//! ([`crate::metrics::NetCounters::collective_timeouts`]), so one
//! isolated machine never poisons the collective; scripted machine churn
//! re-roots the tree deterministically over the live quotient view, and
//! gossip needs no repair at all — its ratio estimates renormalize over
//! whatever remains reachable. RB's `needs_global_residuals()` gating
//! and the NAP [`crate::net::TopologyController`] both operate on the
//! machine-level live graph (RB waits on the round's collective verdict;
//! the activity rule masks machine links whose mean cross-cut η̄
//! collapses).
//!
//! **Transports.** The whole protocol above is generic over the
//! [`crate::net::Transport`] seam. Three backends run it (full matrix in
//! [`crate::net`]):
//!
//! * [`ClusterRunner`] over [`crate::net::NetSim`] — the omniscient
//!   single-threaded driver on the deterministic simulator; every parity
//!   suite and fault study pins this configuration.
//! * [`inproc`] — one OS thread per machine over an in-process channel
//!   mesh ([`crate::net::channel_mesh`]); each machine is a self-driving
//!   [`NodeRuntime`]. Real scheduler interleavings, graceful-leave fault
//!   injection from the harness.
//! * [`proc`] — one OS *process* per machine: the `fadmm-node` binary
//!   speaks line-delimited JSON over stdio through a star router, and
//!   machine death is a real `SIGKILL`.
//!
//! At zero faults the real transports commit *identical iteration
//! counts* to the simulated driver (the fold is order-insensitive by
//! construction: machine-id-ordered absorption out of a `BTreeMap`),
//! which `inproc::tests` and the `proc_transport` integration suite
//! assert scheme by scheme.

mod collective;
mod machine;
mod node;
mod partition;
mod runner;

pub mod inproc;
pub mod proc;

pub use collective::CollectiveKind;
pub use node::{aggregate_obs, NodeReport, NodeRuntime};
pub use partition::{hierarchical_order, hierarchical_partition, MachinePartition};
pub use runner::{factory_of, ClusterConfig, ClusterReport, ClusterRunner};

#[cfg(test)]
mod tests;
