//! Persistent phase-job worker pool.
//!
//! Both distributed runtimes ([`crate::coordinator`] within one process,
//! [`crate::cluster`] per simulated machine) execute their iteration
//! phases on short-lived `std::thread::scope` blocks in the seed design:
//! every phase of every iteration pays a spawn + join. At small `dim`
//! that fixed tax dominates wall-clock, so the adaptive-penalty round
//! savings the paper is about never show up as *time* savings. This
//! module replaces the scoped spawns with a pool of long-lived workers
//! created once per run and fed per-phase jobs through per-worker FIFO
//! queues.
//!
//! ## Design
//!
//! * **Create-once**: [`PhasePool::new`] spawns `W` named workers
//!   (`fadmm-pool-{w}`) that live until the pool drops. "Pinned" means a
//!   fixed worker-thread identity per queue slot (job `j` of a set always
//!   lands on worker `j % W`) — not OS CPU affinity, which `std` does not
//!   expose and this crate takes no dependency for.
//! * **Job sets**: a whole `Vec` of jobs is enqueued atomically under one
//!   mutex, one job per worker queue in submission order. Per-worker FIFO
//!   means two concurrently submitted sets serialize per worker and a
//!   `W`-sized set is co-scheduled one-job-per-worker, so jobs that
//!   rendezvous on an internal [`crate::coordinator::PhaseBarrier`] (the
//!   sharded runner's whole-run worker bodies) cannot self-deadlock.
//! * **Panic ⇒ error, never deadlock** — the pool generalizes PR 1's
//!   poisonable-barrier contract: every job runs under `catch_unwind`,
//!   the first panic message is recorded on the submission's [`Latch`],
//!   and the submitter gets it back as [`PoolPanicked`]. Workers survive
//!   job panics and keep serving later sets.
//! * **Overlap**: [`PhasePool::run`] is the synchronous mini-scope
//!   (dispatch + join before returning, so borrowed captures are safe by
//!   construction). [`PhasePool::dispatch`] is the asynchronous form used
//!   to overlap interior-shard solves with boundary network I/O: it
//!   returns a [`Ticket`] whose `join` reports panics and whose `Drop`
//!   *blocks* until the jobs finish, so even an unwinding caller never
//!   frees state a live job still borrows.
//!
//! The global [`threads_spawned`] counter is bumped for every pool worker
//! *and* every scoped spawn the runtimes perform, which is what lets the
//! bench targets and the ci.sh gate assert that thread spawns per run are
//! O(workers), not O(iterations·workers).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Process-wide count of runtime worker threads ever spawned (pool
/// workers and scoped phase spawns alike). Monotonic; benches diff it
/// around a run to report spawn amortization.
static THREADS_SPAWNED: AtomicU64 = AtomicU64::new(0);

/// Record one runtime thread spawn (called by the pool itself and by the
/// scoped-spawn fallback paths in both runtimes).
pub fn note_thread_spawn() {
    THREADS_SPAWNED.fetch_add(1, Ordering::Relaxed);
}

/// Total runtime thread spawns so far in this process.
pub fn threads_spawned() -> u64 {
    THREADS_SPAWNED.load(Ordering::Relaxed)
}

/// How a runtime executes its per-phase shard jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Persistent [`PhasePool`] workers (default): threads are spawned
    /// once per run and interior/boundary overlap is available.
    Pool,
    /// Seed behaviour: a fresh `std::thread::scope` spawn per phase.
    /// Kept as the bit-parity baseline and for the bench comparison.
    Scoped,
}

impl Default for ExecMode {
    fn default() -> Self {
        ExecMode::Pool
    }
}

impl ExecMode {
    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Pool => "pool",
            ExecMode::Scoped => "scoped",
        }
    }
}

/// A submission's completion latch: counts outstanding jobs and stores
/// the first panic message.
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<String>,
}

impl Latch {
    fn new(jobs: usize) -> Self {
        Latch {
            state: Mutex::new(LatchState { remaining: jobs, panic: None }),
            cv: Condvar::new(),
        }
    }

    /// A worker finished one job (recording its panic message, if any).
    fn complete(&self, panic: Option<String>) {
        let mut st = self.state.lock().unwrap();
        if st.panic.is_none() {
            st.panic = panic;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every job of the submission has finished; returns the
    /// first panic message, if any. Idempotent (re-waiting a finished
    /// latch returns immediately).
    fn wait(&self) -> Option<String> {
        let mut st = self.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.cv.wait(st).unwrap();
        }
        st.panic.clone()
    }
}

/// One queued unit of work. The closure is lifetime-erased at dispatch;
/// soundness is restored by the submitter joining (or `Drop`-blocking on)
/// the [`Ticket`] before the borrowed data can die.
struct Job {
    func: Box<dyn FnOnce() + Send + 'static>,
    latch: Arc<Latch>,
}

struct Shared {
    queues: Vec<VecDeque<Job>>,
    shutdown: bool,
}

/// A submission handle for asynchronously dispatched job sets.
///
/// `join` consumes the ticket and surfaces the first job panic as
/// [`PoolPanicked`]. Dropping an unjoined ticket **blocks** until the
/// jobs complete — that is the safety net that makes
/// [`PhasePool::dispatch`]'s lifetime erasure sound under caller unwind.
pub struct Ticket {
    latch: Option<Arc<Latch>>,
}

impl Ticket {
    /// Wait for the submission and report the first panic, if any.
    pub fn join(mut self) -> Result<(), PoolPanicked> {
        let latch = self.latch.take().expect("ticket latch present until join");
        match latch.wait() {
            None => Ok(()),
            Some(message) => Err(PoolPanicked { message }),
        }
    }
}

impl Drop for Ticket {
    fn drop(&mut self) {
        if let Some(latch) = self.latch.take() {
            latch.wait();
        }
    }
}

/// Error returned when one or more jobs of a submission panicked. The
/// message is the first panicking job's payload.
#[derive(Debug, Clone)]
pub struct PoolPanicked {
    pub message: String,
}

impl std::fmt::Display for PoolPanicked {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "pool worker panicked: {}", self.message)
    }
}

impl std::error::Error for PoolPanicked {}

/// Persistent worker pool; see the module docs for the contract.
pub struct PhasePool {
    shared: Arc<(Mutex<Shared>, Condvar)>,
    handles: Vec<JoinHandle<()>>,
}

impl PhasePool {
    /// Spawn `workers.max(1)` long-lived workers.
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let shared = Arc::new((
            Mutex::new(Shared {
                queues: (0..workers).map(|_| VecDeque::new()).collect(),
                shutdown: false,
            }),
            Condvar::new(),
        ));
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let sh = Arc::clone(&shared);
            note_thread_spawn();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("fadmm-pool-{w}"))
                    .spawn(move || worker_loop(&sh, w))
                    .expect("spawning pool worker"),
            );
        }
        PhasePool { shared, handles }
    }

    pub fn size(&self) -> usize {
        self.handles.len()
    }

    /// Enqueue a job set without waiting for it.
    ///
    /// # Safety
    ///
    /// The jobs' `'s` borrows are erased to `'static`. The caller must
    /// keep every borrowed location alive and un-aliased (per the jobs'
    /// own access pattern) until the returned [`Ticket`] is joined or
    /// dropped — both block until the last job finishes, so holding the
    /// ticket inside the borrowed data's scope is sufficient.
    pub unsafe fn dispatch<'s>(
        &self,
        jobs: Vec<Box<dyn FnOnce() + Send + 's>>,
    ) -> Ticket {
        let latch = Arc::new(Latch::new(jobs.len()));
        let (lock, cv) = &*self.shared;
        {
            let mut st = lock.lock().unwrap();
            for (j, func) in jobs.into_iter().enumerate() {
                // SAFETY: lifetime erasure only; the Ticket contract above
                // guarantees the borrows outlive the job's execution.
                let func = unsafe {
                    std::mem::transmute::<
                        Box<dyn FnOnce() + Send + 's>,
                        Box<dyn FnOnce() + Send + 'static>,
                    >(func)
                };
                let slot = j % self.handles.len();
                st.queues[slot].push_back(Job { func, latch: Arc::clone(&latch) });
            }
        }
        cv.notify_all();
        Ticket { latch: Some(latch) }
    }

    /// Run a job set to completion (dispatch + join). Safe: the jobs'
    /// borrows cannot outlive this call because it does not return until
    /// every job has finished.
    pub fn run<'s>(
        &self,
        jobs: Vec<Box<dyn FnOnce() + Send + 's>>,
    ) -> Result<(), PoolPanicked> {
        // SAFETY: joined before returning, so `'s` strictly outlives every
        // job's execution.
        let ticket = unsafe { self.dispatch(jobs) };
        ticket.join()
    }
}

impl Drop for PhasePool {
    fn drop(&mut self) {
        {
            let (lock, cv) = &*self.shared;
            let mut st = lock.lock().unwrap();
            st.shutdown = true;
            cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &(Mutex<Shared>, Condvar), w: usize) {
    loop {
        let job = {
            let (lock, cv) = shared;
            let mut st = lock.lock().unwrap();
            loop {
                if let Some(job) = st.queues[w].pop_front() {
                    break Some(job);
                }
                if st.shutdown {
                    break None;
                }
                st = cv.wait(st).unwrap();
            }
        };
        let Some(Job { func, latch }) = job else { return };
        let panic = catch_unwind(AssertUnwindSafe(func))
            .err()
            .map(|payload| panic_message(&payload));
        latch.complete(panic);
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(m) = payload.downcast_ref::<&str>() {
        (*m).to_string()
    } else if let Some(m) = payload.downcast_ref::<String>() {
        m.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn boxed<'s>(f: impl FnOnce() + Send + 's) -> Box<dyn FnOnce() + Send + 's> {
        Box::new(f)
    }

    #[test]
    fn runs_borrowed_jobs_and_reuses_workers_across_sets() {
        let pool = PhasePool::new(3);
        let mut data = vec![0u64; 6];
        for round in 1..=3u64 {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
                .chunks_mut(2)
                .map(|chunk| {
                    boxed(move || {
                        for x in chunk {
                            *x += round;
                        }
                    })
                })
                .collect();
            pool.run(jobs).unwrap();
        }
        assert_eq!(data, vec![6u64; 6]);
    }

    #[test]
    fn worker_panic_is_an_error_not_a_deadlock() {
        let pool = PhasePool::new(2);
        let err = pool
            .run(vec![
                boxed(|| {}),
                boxed(|| panic!("boom in job")),
                boxed(|| {}),
            ])
            .unwrap_err();
        assert!(err.message.contains("boom in job"), "got: {}", err.message);
        // the pool survives a job panic and keeps serving
        pool.run(vec![boxed(|| {})]).unwrap();
    }

    #[test]
    fn full_width_set_is_co_scheduled_one_job_per_worker() {
        // jobs rendezvous on an internal phase barrier — this only
        // terminates if all W jobs of the set run concurrently
        use crate::coordinator::PhaseBarrier;
        let pool = PhasePool::new(4);
        let barrier = PhaseBarrier::new(4);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                let b = &barrier;
                boxed(move || {
                    b.wait().unwrap();
                    b.wait().unwrap();
                })
            })
            .collect();
        pool.run(jobs).unwrap();
    }

    #[test]
    fn async_dispatch_overlaps_caller_work_and_joins() {
        let done = std::sync::atomic::AtomicU64::new(0);
        let pool = PhasePool::new(2);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..2)
            .map(|_| {
                let d = &done;
                boxed(move || {
                    d.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        // SAFETY: joined below, inside `done`'s scope.
        let ticket = unsafe { pool.dispatch(jobs) };
        let caller_side = 21 + 21; // caller keeps working while jobs run
        ticket.join().unwrap();
        assert_eq!(done.load(Ordering::SeqCst), 2);
        assert_eq!(caller_side, 42);
    }

    #[test]
    fn dropping_an_unjoined_ticket_blocks_until_jobs_finish() {
        let pool = PhasePool::new(1);
        let mut hits = 0u64;
        {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                vec![boxed(|| hits += 1)];
            // SAFETY: the ticket drops at end of this block, which blocks
            // until the job finished — before `hits` is read below.
            let _ticket = unsafe { pool.dispatch(jobs) };
        }
        assert_eq!(hits, 1);
    }

    #[test]
    fn spawn_counter_is_per_pool_not_per_job() {
        // other tests create pools concurrently, so only delta lower
        // bounds are exact here; the strict O(workers) assertion lives in
        // the single-process bench gate.
        let before = threads_spawned();
        let pool = PhasePool::new(3);
        assert!(threads_spawned() - before >= 3);
        assert_eq!(pool.size(), 3);
        for _ in 0..10 {
            pool.run((0..3).map(|_| boxed(|| {})).collect()).unwrap();
        }
    }

    #[test]
    fn empty_set_completes_immediately() {
        let pool = PhasePool::new(2);
        pool.run(Vec::new()).unwrap();
    }
}
