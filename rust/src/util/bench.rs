//! In-repo micro-benchmark harness (criterion is unavailable offline).
//!
//! Used by the `rust/benches/*.rs` targets (`cargo bench`). Each benchmark
//! warms up, then runs timed batches until a time budget is hit, and
//! reports mean / median / p10 / p90 per-iteration latency. Intentionally
//! simple — enough for regression tracking and the §Perf methodology in
//! EXPERIMENTS.md.

use std::path::PathBuf;
use std::time::Instant;

use super::json::{arr, num, obj, s, Json};
use super::stats;
use crate::error::Error;

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl BenchResult {
    /// Machine-readable form (one entry of `BENCH_<target>.json`).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", s(self.name.as_str())),
            ("iters", num(self.iters as f64)),
            ("mean_ns", num(self.mean_ns)),
            ("median_ns", num(self.median_ns)),
            ("p10_ns", num(self.p10_ns)),
            ("p90_ns", num(self.p90_ns)),
        ])
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} iters  mean {:>12}  median {:>12}  p10 {:>12}  p90 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p10_ns),
            fmt_ns(self.p90_ns),
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Prevent the optimizer from discarding a value (ptr read volatile trick).
pub fn black_box<T>(x: T) -> T {
    unsafe {
        let y = std::ptr::read_volatile(&x);
        std::mem::forget(x);
        y
    }
}

/// Benchmark runner with a global time budget per benchmark.
pub struct Bencher {
    /// seconds of measurement per benchmark (after warmup)
    pub budget_secs: f64,
    /// warmup seconds
    pub warmup_secs: f64,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { budget_secs: 2.0, warmup_secs: 0.3, results: Vec::new() }
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fast settings for CI smoke runs (`FADMM_BENCH_FAST=1`).
    pub fn from_env() -> Self {
        if std::env::var("FADMM_BENCH_FAST").is_ok() {
            Bencher { budget_secs: 0.2, warmup_secs: 0.05, results: Vec::new() }
        } else {
            Self::default()
        }
    }

    /// Measure `f`, printing the result line immediately.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        // warmup
        let t0 = Instant::now();
        while t0.elapsed().as_secs_f64() < self.warmup_secs {
            f();
        }
        // measured batches: size batches so each is ~1ms min
        let probe_t = Instant::now();
        f();
        let probe = probe_t.elapsed().as_secs_f64().max(1e-9);
        let batch = ((0.001 / probe).ceil() as u64).clamp(1, 1_000_000);

        let mut samples = Vec::new();
        let mut total_iters = 0u64;
        let start = Instant::now();
        while start.elapsed().as_secs_f64() < self.budget_secs {
            let bt = Instant::now();
            for _ in 0..batch {
                f();
            }
            let ns = bt.elapsed().as_nanos() as f64 / batch as f64;
            samples.push(ns);
            total_iters += batch;
        }
        let result = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: stats::mean(&samples),
            median_ns: stats::median(&samples),
            p10_ns: stats::percentile(&samples, 10.0),
            p90_ns: stats::percentile(&samples, 90.0),
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Look up a recorded result by exact name.
    pub fn result(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }

    /// Write every recorded result — plus caller-supplied derived fields —
    /// to `BENCH_<target>.json` at the repo root, so the perf trajectory
    /// is tracked commit over commit. The directory can be overridden
    /// with `FADMM_BENCH_DIR` (used by tests); the default resolves the
    /// repo root relative to this crate at compile time.
    pub fn write_json(&self, target: &str, extra: Vec<(&str, Json)>)
                      -> crate::error::Result<PathBuf> {
        let dir = std::env::var("FADMM_BENCH_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(".."));
        let path = dir.join(format!("BENCH_{target}.json"));
        let mut fields = vec![
            ("target", s(target)),
            ("budget_secs", num(self.budget_secs)),
            ("results", arr(self.results.iter().map(BenchResult::to_json).collect())),
        ];
        fields.extend(extra);
        let doc = obj(fields);
        std::fs::write(&path, doc.to_string())
            .map_err(|e| Error::io(format!("writing {}", path.display()), e))?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher { budget_secs: 0.05, warmup_secs: 0.01, results: vec![] };
        let r = b.bench("noop-ish", || {
            black_box(1u64 + black_box(2u64));
        });
        assert!(r.iters > 0);
        assert!(r.mean_ns > 0.0);
    }

    #[test]
    fn json_report_roundtrips() {
        let dir = std::env::temp_dir().join("fadmm_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::env::set_var("FADMM_BENCH_DIR", &dir);
        let mut b = Bencher { budget_secs: 0.02, warmup_secs: 0.0, results: vec![] };
        b.bench("alpha", || {
            black_box(black_box(3u64) * 7);
        });
        let path = b
            .write_json("unit_test", vec![("note", super::super::json::s("ok"))])
            .unwrap();
        std::env::remove_var("FADMM_BENCH_DIR");
        assert!(path.ends_with("BENCH_unit_test.json"));
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("target").unwrap().as_str(), Some("unit_test"));
        assert_eq!(doc.get("note").unwrap().as_str(), Some("ok"));
        let results = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("alpha"));
        assert!(results[0].get("mean_ns").unwrap().as_f64().unwrap() > 0.0);
        assert!(b.result("alpha").is_some());
        assert!(b.result("beta").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
