//! Small self-contained utilities.
//!
//! The offline build environment ships only the `xla` crate's dependency
//! closure, so the usual ecosystem crates (rand, serde, csv, proptest,
//! criterion) are re-implemented here at the minimal scale this project
//! needs. Each submodule is independently unit-tested.

pub mod bench;
pub mod csv;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
