//! Small self-contained utilities.
//!
//! The offline build environment has no registry access, so the default
//! build carries zero external dependencies (the `xla` backend is
//! feature-gated) and the usual ecosystem crates (rand, serde, csv,
//! proptest, criterion) are re-implemented here at the minimal scale this
//! project needs. Each submodule is independently unit-tested.

pub mod bench;
pub mod csv;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
