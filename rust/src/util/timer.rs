//! Lightweight wall-clock timing helpers.

use std::time::Instant;

/// Accumulating timer for named phases of the hot loop.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    entries: Vec<(String, f64, u64)>, // (name, total_secs, count)
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(name, t0.elapsed().as_secs_f64());
        out
    }

    /// Record an externally measured duration.
    pub fn add(&mut self, name: &str, secs: f64) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.0 == name) {
            e.1 += secs;
            e.2 += 1;
        } else {
            self.entries.push((name.to_string(), secs, 1));
        }
    }

    /// (name, total_secs, calls) rows sorted by total time descending.
    pub fn report(&self) -> Vec<(String, f64, u64)> {
        let mut rows = self.entries.clone();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        rows
    }

    /// Human-readable profile table.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        for (name, total, count) in self.report() {
            s.push_str(&format!(
                "{name:<28} {total:10.4}s  {count:8} calls  {:10.1}µs/call\n",
                total / count as f64 * 1e6,
            ));
        }
        s
    }
}

/// Measure a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut t = PhaseTimer::new();
        t.time("a", || std::thread::sleep(std::time::Duration::from_millis(1)));
        t.time("a", || ());
        t.time("b", || ());
        let rows = t.report();
        assert_eq!(rows.len(), 2);
        let a = rows.iter().find(|r| r.0 == "a").unwrap();
        assert_eq!(a.2, 2);
        assert!(a.1 >= 0.001);
    }

    #[test]
    fn timed_returns_value() {
        let (v, secs) = timed(|| 7);
        assert_eq!(v, 7);
        assert!(secs >= 0.0);
    }
}
