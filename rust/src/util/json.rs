//! Minimal JSON parser + emitter (serde is unavailable offline).
//!
//! Supports the full JSON value grammar minus exotic escapes (`\uXXXX` is
//! handled for the BMP). Used for the artifact manifest, experiment configs
//! and result files — all small documents, so the recursive-descent parser
//! keeps no indices and just walks bytes.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object with stable (sorted) key order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Member access helpers; they return `None` on kind mismatch.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            (x >= 0.0 && x.fract() == 0.0).then_some(x as usize)
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Required-field accessors for manifest parsing.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json { offset: 0, msg: format!("missing key '{key}'") })
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (k, item) in v.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (k, (key, val)) in m.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    write_escaped(out, key);
                    out.push(':');
                    val.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors used by result writers.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: impl Into<String>) -> Json {
    Json::Str(x.into())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            map.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c => {
                    // re-assemble multibyte UTF-8 sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let bytes = self
                            .b
                            .get(start..start + width)
                            .ok_or_else(|| self.err("bad utf8"))?;
                        let chunk = std::str::from_utf8(bytes)
                            .map_err(|_| self.err("bad utf8"))?;
                        out.push_str(chunk);
                        self.i = start + width;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"arr":[1,2.5,-3],"name":"hløo","ok":true}"#;
        let v = Json::parse(text).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a":}"#).is_err());
    }

    #[test]
    fn manifest_shape() {
        let text = r#"{"version":1,"dtype":"f64","artifacts":[
            {"name":"m","file":"m.hlo.txt","kind":"moments","d":8,"m":0,"n":16,
             "num_inputs":2,"input_shapes":[[8,16],[16]],"output_shapes":[[],[8],[8,8]]}]}"#;
        let v = Json::parse(text).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("d").unwrap().as_usize(), Some(8));
    }
}
