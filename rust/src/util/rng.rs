//! Deterministic PRNG: PCG-XSH-RR 64/32 plus distribution helpers.
//!
//! Every experiment in the repo is seeded through this generator, so runs
//! are bit-reproducible across machines (`rand` is unavailable offline, and
//! determinism is a feature here anyway: the paper reports medians over 20
//! seeded restarts).

/// PCG-XSH-RR 64/32 (O'Neill 2014). 2^64 period, 32-bit output.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator (used to give every node in a
    /// distributed run its own stream).
    pub fn fork(&mut self, stream: u64) -> Pcg {
        Pcg::new(self.next_u64(), stream.wrapping_mul(0x9E3779B97F4A7C15) | 1)
    }

    /// Next 32 uniformly random bits.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 uniformly random bits.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound).
    pub fn below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        // multiply-shift; bias negligible for bounds << 2^32
        ((self.next_u32() as u64 * bound as u64) >> 32) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller (one value per call; the pair's
    /// second half is discarded to keep the stream position simple).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, len: usize) -> Vec<f64> {
        (0..len).map(|_| self.normal()).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg::new(42, 7);
        let mut b = Pcg::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg::new(42, 1);
        let mut b = Pcg::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg::seed(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_bounds_and_covers() {
        let mut r = Pcg::seed(2);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::seed(3);
        let xs = r.normal_vec(50_000);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::seed(4);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg::seed(9);
        let mut c1 = root.fork(0);
        let mut c2 = root.fork(1);
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }
}
