//! Summary statistics over experiment repetitions.

/// Median of a slice (averaging the middle pair for even lengths).
/// Returns NaN for empty input.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Arithmetic mean (NaN for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n−1 denominator; 0 for n<2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Element-wise median across equally long series; series shorter than the
/// longest are extended with their final value (a converged run holds its
/// last error), matching how the paper plots median curves over restarts.
pub fn median_curve(series: &[Vec<f64>]) -> Vec<f64> {
    let len = series.iter().map(|s| s.len()).max().unwrap_or(0);
    (0..len)
        .map(|t| {
            let col: Vec<f64> = series
                .iter()
                .filter_map(|s| s.get(t).copied().or_else(|| s.last().copied()))
                .collect();
            median(&col)
        })
        .collect()
}

/// Percentile (nearest-rank); p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!(median(&[]).is_nan());
    }

    #[test]
    fn mean_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn median_curve_extends_short_series() {
        let s = vec![vec![10.0, 5.0, 1.0], vec![8.0, 4.0]];
        let m = median_curve(&s);
        assert_eq!(m, vec![9.0, 4.5, 2.5]); // last value 4.0 extended
    }

    #[test]
    fn percentile_bounds() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }
}
