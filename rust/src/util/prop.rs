//! In-repo property-testing harness (proptest is unavailable offline).
//!
//! A property is a closure over a seeded [`Pcg`]; the harness runs it for a
//! fixed number of cases and reports the failing seed so a failure is
//! reproducible with `check_one`. Generators are plain functions on the
//! RNG — no shrinking, but seeds make failures replayable which is the
//! 90% use case.

use super::rng::Pcg;

/// Number of cases per property (kept modest; these run in `cargo test`).
pub const DEFAULT_CASES: usize = 64;

/// Run `prop` for `cases` seeds; panic with the seed on the first failure.
pub fn check_named(name: &str, cases: usize, mut prop: impl FnMut(&mut Pcg)) {
    for case in 0..cases {
        let seed = 0xFAD0_0000 + case as u64;
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Pcg::seed(seed);
            prop(&mut rng);
        }));
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| panic.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!("property '{name}' failed at seed {seed:#x}: {msg}");
        }
    }
}

/// Run a property with the default case count.
pub fn check(name: &str, prop: impl FnMut(&mut Pcg)) {
    check_named(name, DEFAULT_CASES, prop);
}

/// Re-run a single failing seed (debugging helper).
pub fn check_one(seed: u64, mut prop: impl FnMut(&mut Pcg)) {
    let mut rng = Pcg::seed(seed);
    prop(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("addition commutes", |rng| {
            let a = rng.f64();
            let b = rng.f64();
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "seed")]
    fn reports_failing_seed() {
        check("always fails eventually", |rng| {
            assert!(rng.f64() < 0.5, "got a large value");
        });
    }
}
