//! Tiny CSV writer for experiment result series.
//!
//! Output columns are declared once; rows are type-checked against the
//! header length at write time. Fields never need quoting here (numeric and
//! identifier data only), but commas in strings are rejected loudly.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::error::{Error, Result};

/// Streaming CSV writer.
pub struct CsvWriter {
    out: BufWriter<File>,
    ncols: usize,
    path: String,
}

impl CsvWriter {
    /// Create the file (and any missing parent directories) and write the
    /// header row.
    pub fn create(path: impl AsRef<Path>, header: &[&str]) -> Result<CsvWriter> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .map_err(|e| Error::io(format!("mkdir {}", dir.display()), e))?;
        }
        let file = File::create(path)
            .map_err(|e| Error::io(format!("create {}", path.display()), e))?;
        let mut w = CsvWriter {
            out: BufWriter::new(file),
            ncols: header.len(),
            path: path.display().to_string(),
        };
        w.write_strs(header)?;
        Ok(w)
    }

    fn write_strs(&mut self, fields: &[&str]) -> Result<()> {
        if fields.len() != self.ncols {
            return Err(Error::Config(format!(
                "csv {}: row has {} fields, header has {}",
                self.path,
                fields.len(),
                self.ncols
            )));
        }
        let mut line = String::new();
        for (i, f) in fields.iter().enumerate() {
            if f.contains(',') || f.contains('\n') {
                return Err(Error::Config(format!("csv field needs quoting: {f:?}")));
            }
            if i > 0 {
                line.push(',');
            }
            line.push_str(f);
        }
        line.push('\n');
        self.out
            .write_all(line.as_bytes())
            .map_err(|e| Error::io(format!("write {}", self.path), e))
    }

    /// Write a row of mixed values (anything `Display`, pre-formatted).
    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        let refs: Vec<&str> = fields.iter().map(|s| s.as_str()).collect();
        self.write_strs(&refs)
    }

    /// Flush to disk.
    pub fn finish(mut self) -> Result<()> {
        self.out
            .flush()
            .map_err(|e| Error::io(format!("flush {}", self.path), e))
    }
}

/// Format an f64 compactly for CSV output.
pub fn fnum(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x:.6e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_validates() {
        let dir = std::env::temp_dir().join("fadmm_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["1".into(), "2".into()]).unwrap();
        assert!(w.row(&["only-one".into()]).is_err());
        w.row(&[fnum(2.5), fnum(3.0)]).unwrap();
        w.finish().unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,2\n2.500000e0,3\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_commas() {
        let dir = std::env::temp_dir().join("fadmm_csv_test2");
        let mut w = CsvWriter::create(dir.join("t.csv"), &["a"]).unwrap();
        assert!(w.row(&["x,y".into()]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
