//! One cluster machine as an OS process: reads an init line on stdin,
//! runs the ADMM machine protocol over line-delimited JSON, writes a
//! done line on stdout. Spawned and routed by
//! [`fadmm::cluster::proc::ProcCluster`]; wire format documented in
//! [`fadmm::cluster::proc`].

fn main() {
    std::process::exit(fadmm::cluster::proc::node_main());
}
