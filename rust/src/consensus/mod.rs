//! Decentralized consensus-ADMM engine.
//!
//! Solves `min Σ_i f_i(θ_i)  s.t.  θ_i = ρ_ij, ρ_ij = θ_j (j ∈ B_i)` by
//! the bridge-variable-eliminated ADMM of Forero et al. / Yoon & Pavlovic,
//! generalized to *per-edge, per-iteration* penalties η_ij (this paper):
//!
//! ```text
//! θ_i^{t+1} = argmin_θ f_i(θ) + 2λ_iᵀθ + Σ_j η_ij ‖θ − (θ_i^t + θ_j^t)/2‖²
//! λ_i^{t+1} = λ_i^t + ½ Σ_j η̄_ij (θ_i^{t+1} − θ_j^{t+1})
//! η_ij^{t+1} = scheme(observations)            // the paper's contribution
//! ```
//!
//! **Dual symmetrization** (η̄_ij = (η_ij + η_ji)/2): with per-edge
//! penalties the two directions of an edge may disagree (AP/NAP adapt
//! η_ij from node i's local objective). Deriving ADMM from the paper's
//! full bridge-variable Lagrangian (eq. 3) keeps the two per-edge
//! multipliers equal, and they aggregate into λ_i with the *edge-mean*
//! penalty — using the raw directed η_ij there instead silently breaks
//! the Σ_i λ_i = 0 invariant and ADMM drifts to a biased fixed point
//! (caught by `multipliers_sum_to_zero*` and the central-optimum tests).
//! The primal solve keeps the node's own directed η_ij, which is exactly
//! the paper's per-edge emphasis mechanism; η̄ requires neighbours to
//! include their η_ji in the broadcast — one extra scalar per message,
//! still fully decentralized.
//!
//! The engine is generic over a [`LocalSolver`] (the `argmin` above): pure
//! Rust closed forms for the convex demos ([`solvers`]), or the lowered
//! XLA artifact for D-PPCA ([`crate::dppca`]). All parameters are handled
//! as flat `Vec<f64>`s; structured applications flatten/unflatten at the
//! solver boundary.
//!
//! This sequential engine performs exactly the computation+communication
//! schedule of the distributed algorithm (Jacobi-style simultaneous node
//! updates followed by neighbour broadcast); [`crate::coordinator`] runs
//! the same schedule on a sharded worker pool exchanging parameters
//! through a double-buffered arena.

pub mod solvers;

use crate::graph::Graph;
use crate::metrics::{ConvergenceChecker, IterStats, Recorder};
use crate::penalty::{make_scheme, NodeObservation, PenaltyScheme, SchemeKind, SchemeParams};
use crate::util::rng::Pcg;

/// A node's local optimization oracle.
pub trait LocalSolver {
    /// Flattened parameter dimension (identical across nodes).
    fn dim(&self) -> usize;

    /// Initial θ_i (random restarts are seeded through `rng`).
    fn initial_param(&mut self, rng: &mut Pcg) -> Vec<f64>;

    /// Local objective f_i(θ) — must be evaluable at *foreign* parameters
    /// (the AP/NAP schemes score neighbour estimates with it).
    fn objective(&mut self, theta: &[f64]) -> f64;

    /// Score several foreign parameter vectors at once. Backed solvers
    /// override this to fold the whole neighbourhood into one executable
    /// dispatch (EXPERIMENTS.md §Perf); the default loops.
    fn objective_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        thetas.iter().map(|t| self.objective(t)).collect()
    }

    /// Score several foreign parameter vectors into a caller-owned buffer
    /// (the hot-loop variant: `out` keeps its allocation across
    /// iterations, so the default never allocates). Solvers whose
    /// [`LocalSolver::objective_batch`] folds the batch into one backend
    /// dispatch should override this to delegate there.
    fn objective_batch_into(&mut self, thetas: &[Vec<f64>], out: &mut Vec<f64>) {
        out.clear();
        for th in thetas {
            let f = self.objective(th);
            out.push(f);
        }
    }

    /// The penalized local update:
    /// `argmin_θ f_i(θ) + 2λᵀθ + (Ση_ij)‖θ‖² − θᵀ(Ση_ij(θ_i+θ_j)) + const`
    /// where `eta_sum = Σ_j η_ij` and `eta_wsum = Σ_j η_ij (θ_i + θ_j)`.
    fn solve(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
             eta_wsum: &[f64]) -> Vec<f64>;

    /// [`LocalSolver::solve`] into a caller-owned buffer — the hot-loop
    /// variant. The sharded runtime's phase A hands the node's own
    /// parity-`q` arena block in as `out`, so an overriding solver makes
    /// the whole solve-and-broadcast step allocation-free.
    ///
    /// Contract (asserted by the `solve_into_matches_solve_bitwise`
    /// property test for every bundled solver):
    /// * `out.len() == self.dim()`; `out` may hold arbitrary stale data on
    ///   entry and must be fully overwritten (it is never an input);
    /// * the written values are **bit-identical** to what `solve` returns
    ///   for the same arguments — the sequential engine and the sharded
    ///   runtime use different entry points and must not diverge.
    ///
    /// The default forwards to `solve`; closed-form solvers override it to
    /// reuse internal scratch and allocate nothing per call.
    fn solve_into(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
                  eta_wsum: &[f64], out: &mut [f64]) {
        let new = self.solve(theta, lambda, eta_sum, eta_wsum);
        debug_assert_eq!(new.len(), out.len());
        out.copy_from_slice(&new);
    }
}

/// Forwarding impl so heterogeneous solver sets can run behind one
/// `Box<dyn LocalSolver>` (the sharded coordinator's factory builds
/// solvers inside each worker thread, so neither `S` nor the boxed trait
/// object needs to be `Send` — only the factory itself crosses threads).
impl<T: LocalSolver + ?Sized> LocalSolver for Box<T> {
    fn dim(&self) -> usize {
        (**self).dim()
    }

    fn initial_param(&mut self, rng: &mut Pcg) -> Vec<f64> {
        (**self).initial_param(rng)
    }

    fn objective(&mut self, theta: &[f64]) -> f64 {
        (**self).objective(theta)
    }

    fn objective_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        (**self).objective_batch(thetas)
    }

    fn objective_batch_into(&mut self, thetas: &[Vec<f64>], out: &mut Vec<f64>) {
        (**self).objective_batch_into(thetas, out)
    }

    fn solve(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
             eta_wsum: &[f64]) -> Vec<f64> {
        (**self).solve(theta, lambda, eta_sum, eta_wsum)
    }

    fn solve_into(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
                  eta_wsum: &[f64], out: &mut [f64]) {
        (**self).solve_into(theta, lambda, eta_sum, eta_wsum, out)
    }
}

/// Engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    pub scheme: SchemeKind,
    pub params: SchemeParams,
    /// relative objective-change tolerance (paper: 1e-3)
    pub tol: f64,
    /// consecutive under-tolerance iterations required
    pub patience: usize,
    /// iterations before convergence checking starts
    pub warmup: usize,
    pub max_iters: usize,
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            scheme: SchemeKind::Fixed,
            params: SchemeParams::default(),
            tol: 1e-3,
            patience: 3,
            warmup: 5,
            max_iters: 1000,
            seed: 0,
        }
    }
}

/// Result of a run.
#[derive(Debug)]
pub struct RunReport {
    pub iterations: usize,
    pub converged: bool,
    pub recorder: Recorder,
    /// final parameters per node
    pub thetas: Vec<Vec<f64>>,
}

/// The consensus engine (see module docs).
pub struct Engine<S: LocalSolver> {
    graph: Graph,
    solvers: Vec<S>,
    cfg: EngineConfig,
    thetas: Vec<Vec<f64>>,
    lambdas: Vec<Vec<f64>>,
    /// per node, per neighbour-slot penalties η_ij
    etas: Vec<Vec<f64>>,
    schemes: Vec<Box<dyn PenaltyScheme>>,
    /// rev_slot[i][slot] = position of node i in neighbour j's adjacency
    /// list (for the symmetrized dual step; see module docs)
    rev_slot: Vec<Vec<usize>>,
    nbr_mean_prev: Vec<Vec<f64>>,
    global_mean_prev: Vec<f64>,
    f_self_prev: Vec<f64>,
    // reusable scratch (hot-loop allocation hygiene, see DESIGN.md §Perf):
    // `step` allocates nothing in steady state
    scratch_new_thetas: Vec<Vec<f64>>,
    scratch_eta_wsum: Vec<f64>,
    /// per-neighbour midpoint buffers, grown to max degree and reused
    scratch_rhos: Vec<Vec<f64>>,
    /// Σ_j η_ij per node, carried from the solve to the residual pass (the
    /// sharded worker computes η̄ from the same sum — the engines must not
    /// diverge, isolated nodes included)
    scratch_eta_sums: Vec<f64>,
    scratch_nbr_mean: Vec<f64>,
    scratch_global_mean: Vec<f64>,
    scratch_primal_norms: Vec<f64>,
    scratch_dual_norms: Vec<f64>,
    scratch_f_self: Vec<f64>,
    scratch_f_nb: Vec<f64>,
}

impl<S: LocalSolver> Engine<S> {
    /// Build an engine; one solver per graph node.
    pub fn new(graph: Graph, mut solvers: Vec<S>, cfg: EngineConfig) -> Self {
        assert_eq!(graph.len(), solvers.len(), "one solver per node");
        assert!(!solvers.is_empty());
        let dim = solvers[0].dim();
        assert!(solvers.iter().all(|s| s.dim() == dim), "homogeneous dims");
        let mut rng = Pcg::new(cfg.seed, 0xE191E);
        let thetas: Vec<Vec<f64>> = solvers
            .iter_mut()
            .map(|s| {
                let th = s.initial_param(&mut rng);
                assert_eq!(th.len(), dim);
                th
            })
            .collect();
        let n = graph.len();
        let schemes = (0..n)
            .map(|i| make_scheme(cfg.scheme, cfg.params, graph.degree(i)))
            .collect();
        let etas = (0..n)
            .map(|i| vec![cfg.params.eta0; graph.degree(i)])
            .collect();
        let rev_slot = (0..n)
            .map(|i| {
                graph
                    .neighbors(i)
                    .iter()
                    .map(|&j| graph.edge_slot(j, i).expect("graph symmetry"))
                    .collect()
            })
            .collect();
        let max_deg = (0..n).map(|i| graph.degree(i)).max().unwrap_or(0);
        Engine {
            rev_slot,
            lambdas: vec![vec![0.0; dim]; n],
            nbr_mean_prev: vec![vec![0.0; dim]; n],
            global_mean_prev: vec![0.0; dim],
            f_self_prev: vec![f64::INFINITY; n],
            scratch_new_thetas: vec![vec![0.0; dim]; n],
            scratch_eta_wsum: vec![0.0; dim],
            scratch_rhos: vec![vec![0.0; dim]; max_deg],
            scratch_eta_sums: vec![0.0; n],
            scratch_nbr_mean: vec![0.0; dim],
            scratch_global_mean: vec![0.0; dim],
            scratch_primal_norms: vec![0.0; n],
            scratch_dual_norms: vec![0.0; n],
            scratch_f_self: vec![0.0; n],
            scratch_f_nb: Vec::with_capacity(max_deg),
            etas,
            schemes,
            thetas,
            solvers,
            graph,
            cfg,
        }
    }

    /// Current per-node parameters.
    pub fn thetas(&self) -> &[Vec<f64>] {
        &self.thetas
    }

    /// Current per-node out-edge penalties (neighbour-slot order).
    pub fn etas(&self) -> &[Vec<f64>] {
        &self.etas
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Run to convergence or `max_iters`; no application metric.
    pub fn run(&mut self) -> RunReport {
        self.run_with(|_, _| 0.0)
    }

    /// Run with an application-metric callback, invoked once per iteration
    /// with (iteration, thetas); its return value lands in
    /// [`IterStats::app_error`] (the paper's plotted subspace angle).
    pub fn run_with(&mut self, mut app_metric: impl FnMut(usize, &[Vec<f64>]) -> f64)
                    -> RunReport {
        let mut recorder = Recorder::with_capacity(self.cfg.max_iters);
        let mut checker = ConvergenceChecker::new(self.cfg.tol)
            .with_patience(self.cfg.patience)
            .with_warmup(self.cfg.warmup);
        let mut converged = false;
        let mut iterations = 0;
        for t in 0..self.cfg.max_iters {
            let stats = self.step(t, &mut app_metric);
            let objective = stats.objective;
            recorder.push(stats);
            iterations = t + 1;
            if checker.update(objective) {
                converged = true;
                break;
            }
        }
        RunReport {
            iterations,
            converged,
            recorder,
            thetas: self.thetas.clone(),
        }
    }

    /// One full ADMM iteration; public so the benches can drive the hot
    /// loop directly.
    pub fn step(&mut self, t: usize,
                app_metric: &mut impl FnMut(usize, &[Vec<f64>]) -> f64) -> IterStats {
        let n = self.graph.len();
        let dim = self.thetas[0].len();

        // ---- local solves (Jacobi: all nodes see iteration-t neighbours) --
        for i in 0..n {
            let mut eta_sum = 0.0;
            self.scratch_eta_wsum.iter_mut().for_each(|x| *x = 0.0);
            for (slot, &j) in self.graph.neighbors(i).iter().enumerate() {
                let eta = self.etas[i][slot];
                eta_sum += eta;
                let ti = &self.thetas[i];
                let tj = &self.thetas[j];
                for k in 0..dim {
                    self.scratch_eta_wsum[k] += eta * (ti[k] + tj[k]);
                }
            }
            self.scratch_eta_sums[i] = eta_sum;
            self.solvers[i].solve_into(
                &self.thetas[i], &self.lambdas[i], eta_sum,
                &self.scratch_eta_wsum, &mut self.scratch_new_thetas[i]);
        }

        // ---- broadcast -----------------------------------------------------
        std::mem::swap(&mut self.thetas, &mut self.scratch_new_thetas);

        // ---- multiplier updates: λ_i += ½ Σ_j η̄_ij (θ_i − θ_j) ------------
        // (η̄ = edge-mean penalty — see module docs on dual symmetrization)
        for i in 0..n {
            for (slot, &j) in self.graph.neighbors(i).iter().enumerate() {
                let eta = 0.5 * (self.etas[i][slot] + self.etas[j][self.rev_slot[i][slot]]);
                let (ti, tj) = (&self.thetas[i], &self.thetas[j]);
                let li = &mut self.lambdas[i];
                for k in 0..dim {
                    li[k] += 0.5 * eta * (ti[k] - tj[k]);
                }
            }
        }

        // ---- residuals (paper eq. 5) ---------------------------------------
        let mut max_primal: f64 = 0.0;
        let mut max_dual: f64 = 0.0;
        for i in 0..n {
            let inv_deg = 1.0 / self.graph.degree(i).max(1) as f64;
            self.scratch_nbr_mean.iter_mut().for_each(|x| *x = 0.0);
            for &j in self.graph.neighbors(i) {
                for k in 0..dim {
                    self.scratch_nbr_mean[k] += self.thetas[j][k];
                }
            }
            self.scratch_nbr_mean.iter_mut().for_each(|x| *x *= inv_deg);
            // η̄ exactly as the sharded worker derives it (Σ_j η_ij · 1/deg,
            // hence 0 for an isolated node): the recorded dual-residual
            // observations must be identical across the two runtimes
            let eta_bar = self.scratch_eta_sums[i] * inv_deg;
            let mut r2 = 0.0;
            let mut s2 = 0.0;
            for k in 0..dim {
                let r = self.thetas[i][k] - self.scratch_nbr_mean[k];
                let s = eta_bar * (self.scratch_nbr_mean[k] - self.nbr_mean_prev[i][k]);
                r2 += r * r;
                s2 += s * s;
            }
            self.scratch_primal_norms[i] = r2.sqrt();
            self.scratch_dual_norms[i] = s2.sqrt();
            max_primal = max_primal.max(self.scratch_primal_norms[i]);
            max_dual = max_dual.max(self.scratch_dual_norms[i]);
            self.nbr_mean_prev[i].copy_from_slice(&self.scratch_nbr_mean);
        }

        // ---- global residuals (for the RB reference scheme) ----------------
        self.scratch_global_mean.iter_mut().for_each(|x| *x = 0.0);
        for th in &self.thetas {
            for k in 0..dim {
                self.scratch_global_mean[k] += th[k];
            }
        }
        self.scratch_global_mean.iter_mut().for_each(|x| *x /= n as f64);
        let mut gr2 = 0.0;
        for th in &self.thetas {
            for k in 0..dim {
                let d = th[k] - self.scratch_global_mean[k];
                gr2 += d * d;
            }
        }
        let mut gs2 = 0.0;
        for k in 0..dim {
            let d = self.scratch_global_mean[k] - self.global_mean_prev[k];
            gs2 += d * d;
        }
        let eta_global = self.cfg.params.eta0;
        let global_primal = gr2.sqrt();
        let global_dual = eta_global * (n as f64).sqrt() * gs2.sqrt();
        self.global_mean_prev.copy_from_slice(&self.scratch_global_mean);

        // ---- objectives ------------------------------------------------------
        let mut objective = 0.0;
        for i in 0..n {
            let f = self.solvers[i].objective(&self.thetas[i]);
            self.scratch_f_self[i] = f;
            objective += f;
        }

        // ---- η stats (over the η^t used by this iteration's solves) ---------
        // computed *before* the scheme updates so the recorded curves mean
        // the same thing in both runtimes (the sharded leader folds η
        // statistics in phase B, before phase C updates them)
        let (mut min_eta, mut max_eta, mut sum_eta, mut cnt) =
            (f64::INFINITY, 0.0f64, 0.0, 0usize);
        for e in self.etas.iter().flatten() {
            min_eta = min_eta.min(*e);
            max_eta = max_eta.max(*e);
            sum_eta += *e;
            cnt += 1;
        }

        // ---- penalty scheme updates (the paper's contribution) --------------
        for i in 0..n {
            self.scratch_f_nb.clear();
            if self.schemes[i].needs_neighbor_objectives() {
                // evaluate f_i at every ρ_ij = (θ_i + θ_j)/2 in one batched
                // call — the paper uses the bridge estimate instead of θ_j
                // to retain locality
                let deg = self.graph.degree(i);
                for (slot, &j) in self.graph.neighbors(i).iter().enumerate() {
                    let rho = &mut self.scratch_rhos[slot];
                    for k in 0..dim {
                        rho[k] = 0.5 * (self.thetas[i][k] + self.thetas[j][k]);
                    }
                }
                self.solvers[i]
                    .objective_batch_into(&self.scratch_rhos[..deg], &mut self.scratch_f_nb);
            } else {
                self.scratch_f_nb.resize(self.graph.degree(i), 0.0);
            }
            let obs = NodeObservation {
                t,
                primal_norm: self.scratch_primal_norms[i],
                dual_norm: self.scratch_dual_norms[i],
                global_primal,
                global_dual,
                f_self: self.scratch_f_self[i],
                f_self_prev: self.f_self_prev[i],
                f_neighbors: &self.scratch_f_nb,
                live: None,
            };
            self.schemes[i].update(&obs, &mut self.etas[i]);
            self.f_self_prev[i] = self.scratch_f_self[i];
        }

        // ---- stats -----------------------------------------------------------
        IterStats {
            iter: t,
            objective,
            max_primal,
            max_dual,
            mean_eta: if cnt == 0 { 0.0 } else { sum_eta / cnt as f64 },
            min_eta: if cnt == 0 { 0.0 } else { min_eta },
            max_eta,
            app_error: app_metric(t, &self.thetas),
        }
    }

    /// Consensus disagreement: max_i ‖θ_i − θ̄‖₂ (test/diagnostic helper).
    pub fn disagreement(&self) -> f64 {
        let n = self.thetas.len();
        let dim = self.thetas[0].len();
        let mut mean = vec![0.0; dim];
        for th in &self.thetas {
            for k in 0..dim {
                mean[k] += th[k] / n as f64;
            }
        }
        self.thetas
            .iter()
            .map(|th| {
                th.iter()
                    .zip(&mean)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt()
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests;
