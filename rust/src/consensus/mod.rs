//! Decentralized consensus-ADMM engine.
//!
//! Solves `min Σ_i f_i(θ_i)  s.t.  θ_i = ρ_ij, ρ_ij = θ_j (j ∈ B_i)` by
//! the bridge-variable-eliminated ADMM of Forero et al. / Yoon & Pavlovic,
//! generalized to *per-edge, per-iteration* penalties η_ij (this paper):
//!
//! ```text
//! θ_i^{t+1} = argmin_θ f_i(θ) + 2λ_iᵀθ + Σ_j η_ij ‖θ − (θ_i^t + θ_j^t)/2‖²
//! λ_i^{t+1} = λ_i^t + ½ Σ_j η̄_ij (θ_i^{t+1} − θ_j^{t+1})
//! η_ij^{t+1} = scheme(observations)            // the paper's contribution
//! ```
//!
//! **Dual symmetrization** (η̄_ij = (η_ij + η_ji)/2): with per-edge
//! penalties the two directions of an edge may disagree (AP/NAP adapt
//! η_ij from node i's local objective). Deriving ADMM from the paper's
//! full bridge-variable Lagrangian (eq. 3) keeps the two per-edge
//! multipliers equal, and they aggregate into λ_i with the *edge-mean*
//! penalty — using the raw directed η_ij there instead silently breaks
//! the Σ_i λ_i = 0 invariant and ADMM drifts to a biased fixed point
//! (caught by `multipliers_sum_to_zero*` and the central-optimum tests).
//! The primal solve keeps the node's own directed η_ij, which is exactly
//! the paper's per-edge emphasis mechanism; η̄ requires neighbours to
//! include their η_ji in the broadcast — one extra scalar per message,
//! still fully decentralized.
//!
//! The engine is generic over a [`LocalSolver`] (the `argmin` above): pure
//! Rust closed forms for the convex demos ([`solvers`]), or the lowered
//! XLA artifact for D-PPCA ([`crate::dppca`]). All parameters are handled
//! as flat `Vec<f64>`s; structured applications flatten/unflatten at the
//! solver boundary.
//!
//! This sequential engine performs exactly the computation+communication
//! schedule of the distributed algorithm (Jacobi-style simultaneous node
//! updates followed by neighbour broadcast); [`crate::coordinator`] runs
//! the same schedule on a sharded worker pool exchanging parameters
//! through a double-buffered arena.
//!
//! The per-node arithmetic itself — solve, dual step, residuals, scheme
//! update — is the shared [`crate::kernel::NodeKernel`] (one
//! transcription for all four runtimes); this engine supplies the
//! trivial policy instance: owned θ vectors, always-live slots, exact
//! reads, and the flat node-order global fold
//! ([`crate::kernel::FlatRound`]).

pub mod solvers;

use crate::graph::{Graph, NodeId};
use crate::kernel::{AppMetricHook, DualPolicy, FlatRound, KernelScratch,
                    NodeKernel, SlotView, StopTracker};
use crate::metrics::{IterStats, Recorder};
use crate::obs::{MetricsRegistry, Phase as ObsPhase, RoundRow, RoundSeries,
                 RuntimeProbes, Timeline};
use crate::penalty::{SchemeKind, SchemeParams};
use crate::util::rng::Pcg;

/// A node's local optimization oracle.
pub trait LocalSolver {
    /// Flattened parameter dimension (identical across nodes).
    fn dim(&self) -> usize;

    /// Initial θ_i (random restarts are seeded through `rng`).
    fn initial_param(&mut self, rng: &mut Pcg) -> Vec<f64>;

    /// Local objective f_i(θ) — must be evaluable at *foreign* parameters
    /// (the AP/NAP schemes score neighbour estimates with it).
    fn objective(&mut self, theta: &[f64]) -> f64;

    /// Score several foreign parameter vectors at once. Backed solvers
    /// override this to fold the whole neighbourhood into one executable
    /// dispatch (EXPERIMENTS.md §Perf); the default loops.
    fn objective_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        thetas.iter().map(|t| self.objective(t)).collect()
    }

    /// Score several foreign parameter vectors into a caller-owned buffer
    /// (the hot-loop variant: `out` keeps its allocation across
    /// iterations, so the default never allocates). Solvers whose
    /// [`LocalSolver::objective_batch`] folds the batch into one backend
    /// dispatch should override this to delegate there.
    fn objective_batch_into(&mut self, thetas: &[Vec<f64>], out: &mut Vec<f64>) {
        out.clear();
        for th in thetas {
            let f = self.objective(th);
            out.push(f);
        }
    }

    /// The penalized local update:
    /// `argmin_θ f_i(θ) + 2λᵀθ + (Ση_ij)‖θ‖² − θᵀ(Ση_ij(θ_i+θ_j)) + const`
    /// where `eta_sum = Σ_j η_ij` and `eta_wsum = Σ_j η_ij (θ_i + θ_j)`.
    fn solve(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
             eta_wsum: &[f64]) -> Vec<f64>;

    /// [`LocalSolver::solve`] into a caller-owned buffer — the hot-loop
    /// variant. The sharded runtime's phase A hands the node's own
    /// parity-`q` arena block in as `out`, so an overriding solver makes
    /// the whole solve-and-broadcast step allocation-free.
    ///
    /// Contract (asserted by the `solve_into_matches_solve_bitwise`
    /// property test for every bundled solver):
    /// * `out.len() == self.dim()`; `out` may hold arbitrary stale data on
    ///   entry and must be fully overwritten (it is never an input);
    /// * the written values are **bit-identical** to what `solve` returns
    ///   for the same arguments — the sequential engine and the sharded
    ///   runtime use different entry points and must not diverge.
    ///
    /// The default forwards to `solve`; closed-form solvers override it to
    /// reuse internal scratch and allocate nothing per call.
    fn solve_into(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
                  eta_wsum: &[f64], out: &mut [f64]) {
        let new = self.solve(theta, lambda, eta_sum, eta_wsum);
        debug_assert_eq!(new.len(), out.len());
        out.copy_from_slice(&new);
    }
}

/// Forwarding impl so heterogeneous solver sets can run behind one
/// `Box<dyn LocalSolver>` (the sharded coordinator's factory builds
/// solvers inside each worker thread, so neither `S` nor the boxed trait
/// object needs to be `Send` — only the factory itself crosses threads).
impl<T: LocalSolver + ?Sized> LocalSolver for Box<T> {
    fn dim(&self) -> usize {
        (**self).dim()
    }

    fn initial_param(&mut self, rng: &mut Pcg) -> Vec<f64> {
        (**self).initial_param(rng)
    }

    fn objective(&mut self, theta: &[f64]) -> f64 {
        (**self).objective(theta)
    }

    fn objective_batch(&mut self, thetas: &[Vec<f64>]) -> Vec<f64> {
        (**self).objective_batch(thetas)
    }

    fn objective_batch_into(&mut self, thetas: &[Vec<f64>], out: &mut Vec<f64>) {
        (**self).objective_batch_into(thetas, out)
    }

    fn solve(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
             eta_wsum: &[f64]) -> Vec<f64> {
        (**self).solve(theta, lambda, eta_sum, eta_wsum)
    }

    fn solve_into(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
                  eta_wsum: &[f64], out: &mut [f64]) {
        (**self).solve_into(theta, lambda, eta_sum, eta_wsum, out)
    }
}

/// Engine configuration.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    pub scheme: SchemeKind,
    pub params: SchemeParams,
    /// relative objective-change tolerance (paper: 1e-3)
    pub tol: f64,
    /// consecutive under-tolerance iterations required
    pub patience: usize,
    /// iterations before convergence checking starts
    pub warmup: usize,
    pub max_iters: usize,
    pub seed: u64,
    /// enable phase-span timing ([`crate::obs`]); counters/gauges are
    /// always recorded
    pub obs: bool,
    /// record the causal round timeline ([`crate::obs::Timeline`]). The
    /// synchronous engine has no transport clock, so event timestamps
    /// are the round index itself (one track, machine 0)
    pub timeline: bool,
    /// record the per-round convergence series
    /// ([`crate::obs::RoundSeries`]): one row of committed [`IterStats`]
    /// per iteration
    pub series: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            scheme: SchemeKind::Fixed,
            params: SchemeParams::default(),
            tol: 1e-3,
            patience: 3,
            warmup: 5,
            max_iters: 1000,
            seed: 0,
            obs: false,
            timeline: false,
            series: false,
        }
    }
}

/// Result of a run.
#[derive(Debug)]
pub struct RunReport {
    pub iterations: usize,
    pub converged: bool,
    pub recorder: Recorder,
    /// final parameters per node
    pub thetas: Vec<Vec<f64>>,
    /// unified telemetry ([`crate::obs`]); phase-span histograms only
    /// when `cfg.obs` is set
    pub obs: MetricsRegistry,
    /// causal timeline events (empty unless `cfg.timeline` or the global
    /// timeline sink was enabled); timestamps are round indices
    pub timeline: Vec<crate::obs::TlEvent>,
    /// ring-overwritten timeline events (capacity pressure)
    pub timeline_dropped: u64,
    /// per-iteration committed-stats rows (empty unless `cfg.series` or
    /// the global series sink was enabled)
    pub series: Vec<RoundRow>,
    /// series rows lost to decimation/capping
    pub series_dropped: u64,
}

/// The engine's [`SlotView`]: neighbour θ is an owned `Vec` indexed by
/// node id, always live, always exact (lag 0); incoming η is prefetched
/// into a per-node scratch slice (the one place the engine needs
/// cross-node kernel state while a kernel is mutably borrowed).
struct EngineSlots<'a> {
    nbrs: &'a [NodeId],
    thetas: &'a [Vec<f64>],
    eta_in: &'a [f64],
}

impl SlotView for EngineSlots<'_> {
    fn live(&self, _slot: usize) -> bool {
        true
    }

    fn theta(&mut self, slot: usize) -> (&[f64], u64) {
        (&self.thetas[self.nbrs[slot]], 0)
    }

    fn theta_again(&mut self, slot: usize) -> &[f64] {
        &self.thetas[self.nbrs[slot]]
    }

    fn eta_in(&mut self, slot: usize) -> f64 {
        self.eta_in[slot]
    }
}

/// The consensus engine (see module docs).
pub struct Engine<S: LocalSolver> {
    graph: Graph,
    solvers: Vec<S>,
    cfg: EngineConfig,
    thetas: Vec<Vec<f64>>,
    /// per-node protocol state (λ, η, scheme, residual memory) — the
    /// shared kernel owns the arithmetic. Crate-visible so the kernel's
    /// golden-trace tests can diff λ/η bitwise against the frozen
    /// pre-refactor transcription.
    pub(crate) kernels: Vec<NodeKernel>,
    /// rev_slot[i][slot] = position of node i in neighbour j's adjacency
    /// list (for the symmetrized dual step; see module docs)
    rev_slot: Vec<Vec<usize>>,
    /// flat node-order global fold + stop state machine
    flat: FlatRound,
    tracker: StopTracker,
    // reusable scratch (hot-loop allocation hygiene): `step` allocates
    // nothing in steady state
    scratch_new_thetas: Vec<Vec<f64>>,
    kscratch: KernelScratch,
    /// prefetched incoming η_{j→i} per slot (phase B)
    scratch_eta_in: Vec<f64>,
    /// unified telemetry: registered once at construction, recorded via
    /// `Copy` ids in `step` (zero-alloc; clock reads only when `cfg.obs`)
    obs: MetricsRegistry,
    probes: RuntimeProbes,
    /// causal round timeline (bounded ring; no-op when disabled)
    timeline: Timeline,
    /// per-iteration committed-stats series (no-op when disabled)
    series: RoundSeries,
}

impl<S: LocalSolver> Engine<S> {
    /// Build an engine; one solver per graph node.
    pub fn new(graph: Graph, mut solvers: Vec<S>, cfg: EngineConfig) -> Self {
        assert_eq!(graph.len(), solvers.len(), "one solver per node");
        assert!(!solvers.is_empty());
        let dim = solvers[0].dim();
        assert!(solvers.iter().all(|s| s.dim() == dim), "homogeneous dims");
        let mut rng = Pcg::new(cfg.seed, 0xE191E);
        let thetas: Vec<Vec<f64>> = solvers
            .iter_mut()
            .map(|s| {
                let th = s.initial_param(&mut rng);
                assert_eq!(th.len(), dim);
                th
            })
            .collect();
        let n = graph.len();
        let kernels = (0..n)
            .map(|i| NodeKernel::new(cfg.scheme, cfg.params, graph.degree(i), dim))
            .collect();
        let rev_slot = (0..n)
            .map(|i| {
                graph
                    .neighbors(i)
                    .iter()
                    .map(|&j| graph.edge_slot(j, i).expect("graph symmetry"))
                    .collect()
            })
            .collect();
        let max_deg = (0..n).map(|i| graph.degree(i)).max().unwrap_or(0);
        let mut obs =
            MetricsRegistry::new(cfg.obs || crate::obs::global_spans_enabled());
        let probes = RuntimeProbes::register(&mut obs);
        let timeline =
            Timeline::new(cfg.timeline || crate::obs::global_timeline_enabled());
        let series =
            RoundSeries::new(cfg.series || crate::obs::global_series_enabled());
        Engine {
            obs,
            probes,
            timeline,
            series,
            rev_slot,
            kernels,
            flat: FlatRound::new(dim),
            tracker: StopTracker::new(dim, cfg.tol, cfg.patience, cfg.warmup,
                                      cfg.max_iters, cfg.params.eta0),
            scratch_new_thetas: vec![vec![0.0; dim]; n],
            kscratch: KernelScratch::new(dim, max_deg),
            scratch_eta_in: vec![0.0; max_deg],
            thetas,
            solvers,
            graph,
            cfg,
        }
    }

    /// Current per-node parameters.
    pub fn thetas(&self) -> &[Vec<f64>] {
        &self.thetas
    }

    /// Current per-node out-edge penalties (neighbour-slot order), one
    /// borrowed slice per node — no materialization, the state lives in
    /// the per-node kernels.
    pub fn etas(&self) -> impl Iterator<Item = &[f64]> + '_ {
        self.kernels.iter().map(|kn| kn.etas.as_slice())
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// Run to convergence or `max_iters`; no application metric.
    pub fn run(&mut self) -> RunReport {
        self.run_with(|_, _| 0.0)
    }

    /// Run with an application-metric callback, invoked once per iteration
    /// with (iteration, thetas); its return value lands in
    /// [`IterStats::app_error`] (the paper's plotted subspace angle).
    pub fn run_with(&mut self, mut app_metric: impl FnMut(usize, &[Vec<f64>]) -> f64)
                    -> RunReport {
        self.tracker.reset_run();
        for t in 0..self.cfg.max_iters {
            let stats = self.step(t, &mut app_metric);
            let stop = self.tracker.commit(t, stats);
            self.record_commit(t as u64, stats);
            if stop {
                break;
            }
        }
        self.obs.set_gauge(self.probes.iterations, self.tracker.iterations as f64);
        self.obs.set_gauge(self.probes.converged,
                           if self.tracker.converged { 1.0 } else { 0.0 });
        // drain, not clone: repeated runs each report their own rows
        let timeline = self.timeline.drain();
        let timeline_dropped = self.timeline.dropped();
        let series = self.series.drain();
        let series_dropped = self.series.dropped();
        self.obs.absorb_timeline(timeline.len(), timeline_dropped,
                                 series.len(), series_dropped);
        // the sink adds whole registries; the CLI builds one engine per
        // run, so the engine's cumulative-across-runs registry is a
        // single run's worth of data on that path
        crate::obs::global_merge(&self.obs);
        if crate::obs::global_timeline_enabled() {
            crate::obs::global_timeline_merge(timeline.clone());
        }
        if crate::obs::global_series_enabled() {
            crate::obs::global_series_merge(series.clone(), series_dropped);
        }
        RunReport {
            iterations: self.tracker.iterations,
            converged: self.tracker.converged,
            recorder: self.tracker.take_recorder(),
            thetas: self.thetas.clone(),
            // clone, not take: ids stay valid for repeated runs
            obs: self.obs.clone(),
            timeline,
            timeline_dropped,
            series,
            series_dropped,
        }
    }

    /// Timeline + series bookkeeping for a committed iteration. The
    /// synchronous engine has no transport clock, so timeline timestamps
    /// are the round index itself, and every event lands on machine 0.
    fn record_commit(&mut self, t: u64, stats: IterStats) {
        if self.timeline.enabled() {
            self.timeline.commit(t, 0, t);
        }
        if self.series.enabled() {
            let row = RoundRow {
                round: t,
                at: t,
                stats,
                live_nodes: self.graph.len() as u64,
                live_edges: self.graph.edge_count() as u64,
                phase_ns: self.timeline.phase_ns(t),
            };
            self.series.push(row);
        }
    }

    /// Run with the unified [`AppMetricHook`] surface (liveness is
    /// trivially all-true in the synchronous engine).
    pub fn run_hooked(&mut self, hook: &mut dyn AppMetricHook) -> RunReport {
        let live = vec![true; self.graph.len()];
        self.run_with(move |t, thetas| hook.measure(t, thetas, &live))
    }

    /// One full ADMM iteration; public so the benches can drive the hot
    /// loop directly. Every block is one kernel call — the engine only
    /// sequences phases and swaps buffers.
    pub fn step(&mut self, t: usize,
                app_metric: &mut impl FnMut(usize, &[Vec<f64>]) -> f64) -> IterStats {
        let n = self.graph.len();

        // ---- phase A: local solves (Jacobi: all nodes see iteration-t
        // neighbours); θ^{t+1} lands in the swap buffer ---------------------
        let span = self.obs.span();
        for i in 0..n {
            let mut view = EngineSlots {
                nbrs: self.graph.neighbors(i),
                thetas: &self.thetas,
                eta_in: &[],
            };
            self.kernels[i].solve_into(
                &mut self.solvers[i], &self.thetas[i], self.graph.degree(i),
                &mut view, &mut self.kscratch, &mut self.scratch_new_thetas[i]);
        }
        let ns = self.obs.end(self.probes.solve, span);
        if self.timeline.enabled() {
            self.timeline.phase(t as u64, 0, t as u64, ObsPhase::Solve, ns);
        }

        // ---- broadcast -----------------------------------------------------
        let span = self.obs.span();
        std::mem::swap(&mut self.thetas, &mut self.scratch_new_thetas);

        // ---- phase B: symmetrized dual step + residuals + objectives -------
        // (η̄ = edge-mean penalty — see module docs on dual symmetrization;
        // the incoming η_{j→i} are prefetched so the kernel borrow stays
        // node-local)
        for i in 0..n {
            let deg = self.graph.degree(i);
            for (slot, &j) in self.graph.neighbors(i).iter().enumerate() {
                self.scratch_eta_in[slot] =
                    self.kernels[j].etas[self.rev_slot[i][slot]];
            }
            let mut view = EngineSlots {
                nbrs: self.graph.neighbors(i),
                thetas: &self.thetas,
                eta_in: &self.scratch_eta_in,
            };
            self.kernels[i].reduce(
                &mut self.solvers[i], &self.thetas[i], deg, &mut view,
                DualPolicy::exact(), &mut self.kscratch);
        }
        let ns = self.obs.end(self.probes.reduce, span);
        if self.timeline.enabled() {
            self.timeline.phase(t as u64, 0, t as u64, ObsPhase::Reduce, ns);
        }

        // ---- flat global fold (node order — the oracle arithmetic the
        // async runtime diffs against); η stats cover the η^t used by this
        // iteration's solves, *before* phase C updates them ------------------
        let span = self.obs.span();
        self.flat.begin();
        for kn in &self.kernels {
            self.flat.add_node(kn.f_self, kn.primal, kn.dual, &kn.etas);
        }
        for th in &self.thetas {
            self.flat.add_theta(th);
        }
        self.flat.finish_mean();
        for th in &self.thetas {
            self.flat.add_spread(th);
        }
        let g = self.tracker.round_flat(&self.flat);

        // ---- phase C: penalty scheme updates (the paper's contribution) ----
        for i in 0..n {
            self.kernels[i].observe(t, (g.global_primal, g.global_dual), None);
        }
        let ns = self.obs.end(self.probes.observe, span);
        if self.timeline.enabled() {
            self.timeline.phase(t as u64, 0, t as u64, ObsPhase::Observe, ns);
        }
        self.obs.inc(self.probes.rounds, 1);

        // ---- stats -----------------------------------------------------------
        IterStats {
            iter: t,
            objective: g.objective,
            max_primal: g.max_primal,
            max_dual: g.max_dual,
            mean_eta: g.mean_eta,
            min_eta: g.min_eta,
            max_eta: g.max_eta,
            app_error: app_metric(t, &self.thetas),
        }
    }

    /// Consensus disagreement: max_i ‖θ_i − θ̄‖₂ (test/diagnostic helper).
    pub fn disagreement(&self) -> f64 {
        let n = self.thetas.len();
        let dim = self.thetas[0].len();
        let mut mean = vec![0.0; dim];
        for th in &self.thetas {
            for k in 0..dim {
                mean[k] += th[k] / n as f64;
            }
        }
        self.thetas
            .iter()
            .map(|th| {
                th.iter()
                    .zip(&mean)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt()
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests;
