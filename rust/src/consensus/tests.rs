//! Engine correctness: convergence to centralized optima across schemes
//! and topologies; structural invariants of the ADMM loop.

use super::solvers::*;
use super::*;
use crate::graph::Topology;
use crate::linalg::Mat;
use crate::penalty::{SchemeKind, SchemeParams};
use crate::util::prop;
use crate::util::rng::Pcg;

fn quad_nodes(n: usize, dim: usize, seed: u64) -> Vec<QuadraticNode> {
    let mut rng = Pcg::seed(seed);
    (0..n).map(|_| QuadraticNode::random(dim, &mut rng)).collect()
}

fn run_quadratic(scheme: SchemeKind, topo: Topology, n: usize, seed: u64)
                 -> (RunReport, Vec<f64>, f64) {
    let nodes = quad_nodes(n, 3, seed);
    let optimum = QuadraticNode::central_optimum(&nodes);
    let graph = topo.build(n).unwrap();
    let cfg = EngineConfig {
        scheme,
        max_iters: 600,
        tol: 1e-9, // tight: we check parameter error directly
        seed,
        ..Default::default()
    };
    let mut engine = Engine::new(graph, nodes, cfg);
    let report = engine.run();
    let err = report
        .thetas
        .iter()
        .map(|th| {
            th.iter()
                .zip(&optimum)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt()
        })
        .fold(0.0f64, f64::max);
    (report, optimum, err)
}

#[test]
fn all_schemes_reach_central_optimum_complete_graph() {
    for scheme in SchemeKind::ALL {
        let (_, _, err) = run_quadratic(scheme, Topology::Complete, 8, 42);
        assert!(err < 5e-4, "{scheme:?}: param error {err}");
    }
}

#[test]
fn all_schemes_reach_central_optimum_ring() {
    for scheme in SchemeKind::ALL {
        let (_, _, err) = run_quadratic(scheme, Topology::Ring, 8, 7);
        assert!(err < 1e-3, "{scheme:?}: param error {err}");
    }
}

#[test]
fn cluster_topology_converges() {
    for scheme in [SchemeKind::Fixed, SchemeKind::Ap, SchemeKind::Nap] {
        let (_, _, err) = run_quadratic(scheme, Topology::Cluster, 10, 3);
        assert!(err < 1e-3, "{scheme:?}: param error {err}");
    }
}

#[test]
fn engine_is_deterministic() {
    let (r1, _, e1) = run_quadratic(SchemeKind::VpAp, Topology::Ring, 6, 11);
    let (r2, _, e2) = run_quadratic(SchemeKind::VpAp, Topology::Ring, 6, 11);
    assert_eq!(r1.iterations, r2.iterations);
    assert_eq!(e1, e2);
    assert_eq!(r1.thetas, r2.thetas);
}

#[test]
fn solve_into_matches_solve_bitwise() {
    // the trait contract: solve_into (the arena hot path) must be
    // bit-identical to solve for every bundled solver, including on a
    // reused/dirty output buffer and warm internal scratch
    prop::check("solve_into ≡ solve for every bundled solver", |rng| {
        let dim = 2 + rng.below(4);
        let rows = dim + 2 + rng.below(6);
        let a = Mat::randn(rows, dim, rng);
        let b: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
        let mut solvers: Vec<Box<dyn LocalSolver>> = vec![
            Box::new(LeastSquaresNode::new(a.clone(), b.clone())),
            Box::new(RidgeNode::new(a.clone(), b.clone(), rng.range(0.0, 2.0))),
            Box::new(LassoNode::new(a.clone(), b.clone(), rng.range(0.0, 2.0))),
            Box::new(QuadraticNode::random(dim, rng)),
        ];
        for s in solvers.iter_mut() {
            let theta = rng.normal_vec(dim);
            let lambda = rng.normal_vec(dim);
            let eta_sum = rng.range(0.1, 50.0);
            let eta_wsum = rng.normal_vec(dim);
            let direct = s.solve(&theta, &lambda, eta_sum, &eta_wsum);
            let mut buffered = vec![f64::NAN; dim]; // stale contents allowed
            s.solve_into(&theta, &lambda, eta_sum, &eta_wsum, &mut buffered);
            assert_eq!(direct, buffered);
            // again through the now-warm scratch
            let direct2 = s.solve(&theta, &lambda, eta_sum, &eta_wsum);
            s.solve_into(&theta, &lambda, eta_sum, &eta_wsum, &mut buffered);
            assert_eq!(direct2, buffered);
            assert_eq!(direct, direct2, "solve must be stateless across calls");
        }
    });
}

#[test]
fn multipliers_sum_to_zero_under_fixed_penalty() {
    // with symmetric constant η, λ updates are antisymmetric across each
    // edge, so Σ_i λ_i must remain 0 throughout
    let nodes = quad_nodes(6, 3, 5);
    let graph = Topology::Ring.build(6).unwrap();
    let mut engine = Engine::new(graph, nodes, EngineConfig {
        scheme: SchemeKind::Fixed,
        max_iters: 1,
        ..Default::default()
    });
    for t in 0..40 {
        engine.step(t, &mut |_, _| 0.0);
        let dim = engine.thetas()[0].len();
        for k in 0..dim {
            let total: f64 = engine.kernels.iter().map(|kn| kn.lambda[k]).sum();
            assert!(total.abs() < 1e-8, "Σλ[{k}] = {total} at t={t}");
        }
    }
}

#[test]
fn disagreement_shrinks() {
    let nodes = quad_nodes(8, 3, 9);
    let graph = Topology::Complete.build(8).unwrap();
    let mut engine = Engine::new(graph, nodes, EngineConfig {
        scheme: SchemeKind::Ap,
        max_iters: 1,
        ..Default::default()
    });
    engine.step(0, &mut |_, _| 0.0);
    let early = engine.disagreement();
    for t in 1..120 {
        engine.step(t, &mut |_, _| 0.0);
    }
    let late = engine.disagreement();
    assert!(late < early * 1e-2, "disagreement {early} → {late}");
}

#[test]
fn adaptive_schemes_at_least_as_fast_on_average() {
    // the paper's headline: adaptive penalties converge in ≤ iterations of
    // fixed ADMM on average (quadratic consensus, complete graph)
    let mut fixed_total = 0usize;
    let mut vp_total = 0usize;
    for seed in 0..5 {
        let (rf, _, _) = run_quadratic(SchemeKind::Fixed, Topology::Complete, 10, seed);
        let (rv, _, _) = run_quadratic(SchemeKind::Vp, Topology::Complete, 10, seed);
        fixed_total += rf.iterations;
        vp_total += rv.iterations;
    }
    assert!(
        vp_total as f64 <= fixed_total as f64 * 1.25,
        "VP {vp_total} vs fixed {fixed_total}"
    );
}

#[test]
fn least_squares_consensus_recovers_global_fit() {
    // distributed LS over row-partitioned data must match the pooled fit
    let mut rng = Pcg::seed(21);
    let dim = 4;
    let theta_true = rng.normal_vec(dim);
    let mut nodes = Vec::new();
    let mut rows_all = Vec::new();
    let mut b_all = Vec::new();
    for _ in 0..6 {
        let a = Mat::randn(12, dim, &mut rng);
        let b: Vec<f64> = (0..12)
            .map(|r| {
                crate::linalg::Mat::col_vec(a.row(r)).fro_dot(&Mat::col_vec(&theta_true))
                    + 0.01 * rng.normal()
            })
            .collect();
        rows_all.extend_from_slice(a.data());
        b_all.extend_from_slice(&b);
        nodes.push(LeastSquaresNode::new(a, b));
    }
    let pooled_a = Mat::from_vec(6 * 12, dim, rows_all);
    let pooled = {
        let ata = pooled_a.t_matmul(&pooled_a);
        let atb = pooled_a.t_matvec(&b_all);
        crate::linalg::Cholesky::new(&ata).unwrap().solve_vec(&atb)
    };
    let graph = Topology::Ring.build(6).unwrap();
    let mut engine = Engine::new(graph, nodes, EngineConfig {
        scheme: SchemeKind::Nap,
        max_iters: 800,
        tol: 1e-10,
        ..Default::default()
    });
    let report = engine.run();
    for th in &report.thetas {
        for (a, b) in th.iter().zip(&pooled) {
            assert!((a - b).abs() < 1e-3, "node param {a} vs pooled {b}");
        }
    }
}

#[test]
fn lasso_consensus_sparsifies() {
    // strong ℓ1 penalty must zero out noise coordinates consistently
    let mut rng = Pcg::seed(31);
    let dim = 6;
    let mut theta_true = vec![0.0; dim];
    theta_true[0] = 3.0;
    theta_true[1] = -2.0;
    let mut nodes = Vec::new();
    for _ in 0..4 {
        let a = Mat::randn(30, dim, &mut rng);
        let b: Vec<f64> = (0..30)
            .map(|r| {
                Mat::col_vec(a.row(r)).fro_dot(&Mat::col_vec(&theta_true))
                    + 0.05 * rng.normal()
            })
            .collect();
        nodes.push(LassoNode::new(a, b, 8.0));
    }
    let graph = Topology::Complete.build(4).unwrap();
    let mut engine = Engine::new(graph, nodes, EngineConfig {
        scheme: SchemeKind::Ap,
        max_iters: 400,
        ..Default::default()
    });
    let report = engine.run();
    for th in &report.thetas {
        assert!(th[0] > 1.0, "signal coord kept: {th:?}");
        for k in 2..dim {
            assert!(th[k].abs() < 0.2, "noise coord near zero: {th:?}");
        }
    }
}

#[test]
fn observer_sees_every_iteration() {
    let nodes = quad_nodes(4, 2, 1);
    let graph = Topology::Complete.build(4).unwrap();
    let mut engine = Engine::new(graph, nodes, EngineConfig {
        max_iters: 17,
        tol: 0.0, // never converge
        ..Default::default()
    });
    let mut calls = 0;
    let report = engine.run_with(|t, thetas| {
        assert_eq!(t, calls);
        assert_eq!(thetas.len(), 4);
        calls += 1;
        t as f64
    });
    assert_eq!(calls, 17);
    assert_eq!(report.recorder.stats.last().unwrap().app_error, 16.0);
}

#[test]
fn eta_stats_recorded() {
    let nodes = quad_nodes(5, 2, 2);
    let graph = Topology::Ring.build(5).unwrap();
    let mut engine = Engine::new(graph, nodes, EngineConfig {
        scheme: SchemeKind::Ap,
        max_iters: 10,
        tol: 0.0,
        ..Default::default()
    });
    let report = engine.run();
    for s in &report.recorder.stats {
        assert!(s.min_eta > 0.0);
        assert!(s.max_eta >= s.mean_eta && s.mean_eta >= s.min_eta);
    }
}

#[test]
fn random_topologies_converge_property() {
    prop::check_named("consensus on random connected graphs", 10, |rng| {
        let n = 4 + rng.below(8);
        let graph = crate::graph::random_connected(n, 0.5, rng).unwrap();
        let nodes = quad_nodes(n, 2, rng.next_u64());
        let optimum = QuadraticNode::central_optimum(&nodes);
        let mut engine = Engine::new(graph, nodes, EngineConfig {
            scheme: SchemeKind::Nap,
            max_iters: 500,
            tol: 1e-10,
            ..Default::default()
        });
        let report = engine.run();
        for th in &report.thetas {
            for (a, b) in th.iter().zip(&optimum) {
                assert!((a - b).abs() < 5e-3, "{a} vs {b}");
            }
        }
    });
}

#[test]
#[ignore]
fn debug_vp_trace() {
    let nodes = quad_nodes(8, 3, 42);
    let optimum = QuadraticNode::central_optimum(&nodes);
    let graph = Topology::Complete.build(8).unwrap();
    let mut engine = Engine::new(graph, nodes, EngineConfig {
        scheme: SchemeKind::Vp,
        max_iters: 1,
        ..Default::default()
    });
    for t in 0..120 {
        let s = engine.step(t, &mut |_, _| 0.0);
        let err = engine
            .thetas()
            .iter()
            .map(|th| th.iter().zip(&optimum).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt())
            .fold(0.0f64, f64::max);
        println!(
            "t={t:3} obj={:>12.4} r={:.3e} s={:.3e} eta=[{:.1},{:.1}] err={err:.3e}",
            s.objective, s.max_primal, s.max_dual, s.min_eta, s.max_eta
        );
    }
}
