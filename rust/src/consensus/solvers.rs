//! Pure-Rust [`LocalSolver`]s for convex consensus problems.
//!
//! These exercise the engine end-to-end without artifacts and back the
//! quickstart/lasso examples. Each solves the penalized subproblem
//! `argmin f(θ) + 2λᵀθ + (Ση)‖θ‖² − θᵀw + const`, `w = Ση_ij(θ_i+θ_j)`.
//!
//! Every solver implements [`LocalSolver::solve_into`] against internal
//! scratch (a reusable regularized system plus its Cholesky factor), so
//! the hot loop performs **zero heap allocations** per solve in steady
//! state; `solve` is a thin allocating wrapper around the same code path,
//! which makes the two bit-identical by construction. Objectives are
//! likewise accumulated row-wise without materializing residual vectors.

use super::LocalSolver;
use crate::linalg::{Cholesky, Mat};
use crate::util::rng::Pcg;

/// ½‖Aθ − b‖² accumulated row-wise (no residual vector materialized);
/// shared by the least-squares-flavoured objectives below.
fn half_ssq_residual(a: &Mat, b: &[f64], theta: &[f64]) -> f64 {
    let mut acc = 0.0;
    for r in 0..a.rows() {
        let row = a.row(r);
        let mut pred = 0.0;
        for (x, y) in row.iter().zip(theta) {
            pred += x * y;
        }
        let d = pred - b[r];
        acc += d * d;
    }
    0.5 * acc
}

/// Distributed least squares: f_i(θ) = ½‖A_iθ − b_i‖².
pub struct LeastSquaresNode {
    ata: Mat,
    atb: Vec<f64>,
    a: Mat,
    b: Vec<f64>,
    /// solve_into scratch: regularized normal matrix + its Cholesky factor
    lhs: Mat,
    chol: Mat,
}

impl LeastSquaresNode {
    pub fn new(a: Mat, b: Vec<f64>) -> Self {
        assert_eq!(a.rows(), b.len());
        let ata = a.t_matmul(&a);
        let atb = a.t_matvec(&b);
        let d = ata.rows();
        LeastSquaresNode {
            ata,
            atb,
            a,
            b,
            lhs: Mat::zeros(d, d),
            chol: Mat::zeros(d, d),
        }
    }
}

impl LocalSolver for LeastSquaresNode {
    fn dim(&self) -> usize {
        self.ata.rows()
    }

    fn initial_param(&mut self, rng: &mut Pcg) -> Vec<f64> {
        rng.normal_vec(self.dim())
    }

    fn objective(&mut self, theta: &[f64]) -> f64 {
        half_ssq_residual(&self.a, &self.b, theta)
    }

    fn solve(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
             eta_wsum: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.solve_into(theta, lambda, eta_sum, eta_wsum, &mut out);
        out
    }

    fn solve_into(&mut self, _theta: &[f64], lambda: &[f64], eta_sum: f64,
                  eta_wsum: &[f64], out: &mut [f64]) {
        // (AᵀA + 2Ση·I) θ = Aᵀb − 2λ + w
        let d = self.dim();
        debug_assert_eq!(out.len(), d);
        self.lhs.data_mut().copy_from_slice(self.ata.data());
        for i in 0..d {
            self.lhs[(i, i)] += 2.0 * eta_sum + 1e-12;
        }
        for k in 0..d {
            out[k] = self.atb[k] - 2.0 * lambda[k] + eta_wsum[k];
        }
        Cholesky::factor_into(&self.lhs, &mut self.chol)
            .expect("LS normal equations SPD");
        Cholesky::solve_in_place(&self.chol, out);
    }
}

/// Distributed ridge regression: f_i(θ) = ½‖A_iθ − b_i‖² + (ω/2)‖θ‖².
pub struct RidgeNode {
    inner: LeastSquaresNode,
    omega: f64,
}

impl RidgeNode {
    pub fn new(a: Mat, b: Vec<f64>, omega: f64) -> Self {
        assert!(omega >= 0.0);
        RidgeNode { inner: LeastSquaresNode::new(a, b), omega }
    }
}

impl LocalSolver for RidgeNode {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn initial_param(&mut self, rng: &mut Pcg) -> Vec<f64> {
        rng.normal_vec(self.dim())
    }

    fn objective(&mut self, theta: &[f64]) -> f64 {
        let l2: f64 = theta.iter().map(|x| x * x).sum();
        self.inner.objective(theta) + 0.5 * self.omega * l2
    }

    fn solve(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
             eta_wsum: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.solve_into(theta, lambda, eta_sum, eta_wsum, &mut out);
        out
    }

    fn solve_into(&mut self, _theta: &[f64], lambda: &[f64], eta_sum: f64,
                  eta_wsum: &[f64], out: &mut [f64]) {
        let d = self.inner.dim();
        debug_assert_eq!(out.len(), d);
        self.inner.lhs.data_mut().copy_from_slice(self.inner.ata.data());
        for i in 0..d {
            self.inner.lhs[(i, i)] += self.omega + 2.0 * eta_sum + 1e-12;
        }
        for k in 0..d {
            out[k] = self.inner.atb[k] - 2.0 * lambda[k] + eta_wsum[k];
        }
        Cholesky::factor_into(&self.inner.lhs, &mut self.inner.chol)
            .expect("ridge normal equations SPD");
        Cholesky::solve_in_place(&self.inner.chol, out);
    }
}

/// Distributed lasso: f_i(θ) = ½‖A_iθ − b_i‖² + ω‖θ‖₁, solved per
/// iteration by cyclic coordinate descent on the penalized subproblem.
pub struct LassoNode {
    ata: Mat,
    atb: Vec<f64>,
    a: Mat,
    b: Vec<f64>,
    omega: f64,
    /// inner coordinate-descent sweeps per ADMM iteration
    sweeps: usize,
    /// solve_into scratch: regularized diagonal and linear term
    diag: Vec<f64>,
    c: Vec<f64>,
}

impl LassoNode {
    pub fn new(a: Mat, b: Vec<f64>, omega: f64) -> Self {
        assert!(omega >= 0.0);
        let ata = a.t_matmul(&a);
        let atb = a.t_matvec(&b);
        let d = ata.rows();
        LassoNode {
            ata,
            atb,
            a,
            b,
            omega,
            sweeps: 25,
            diag: vec![0.0; d],
            c: vec![0.0; d],
        }
    }
}

fn soft_threshold(x: f64, k: f64) -> f64 {
    if x > k {
        x - k
    } else if x < -k {
        x + k
    } else {
        0.0
    }
}

impl LocalSolver for LassoNode {
    fn dim(&self) -> usize {
        self.ata.rows()
    }

    fn initial_param(&mut self, rng: &mut Pcg) -> Vec<f64> {
        rng.normal_vec(self.dim())
    }

    fn objective(&mut self, theta: &[f64]) -> f64 {
        let l1: f64 = theta.iter().map(|x| x.abs()).sum();
        half_ssq_residual(&self.a, &self.b, theta) + self.omega * l1
    }

    fn solve(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
             eta_wsum: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.solve_into(theta, lambda, eta_sum, eta_wsum, &mut out);
        out
    }

    fn solve_into(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
                  eta_wsum: &[f64], out: &mut [f64]) {
        // minimize ½θᵀQθ − cᵀθ + ω‖θ‖₁ with
        // Q = AᵀA + 2Ση·I, c = Aᵀb − 2λ + w; warm-started at θ^t
        let d = self.dim();
        debug_assert_eq!(out.len(), d);
        out.copy_from_slice(theta);
        let q = &self.ata;
        for k in 0..d {
            self.diag[k] = q[(k, k)] + 2.0 * eta_sum + 1e-12;
            self.c[k] = self.atb[k] - 2.0 * lambda[k] + eta_wsum[k];
        }
        for _ in 0..self.sweeps {
            for k in 0..d {
                // residual correlation excluding coordinate k
                let mut qk_th = 0.0;
                for j in 0..d {
                    if j != k {
                        qk_th += q[(k, j)] * out[j];
                    }
                }
                out[k] = soft_threshold(self.c[k] - qk_th, self.omega) / self.diag[k];
            }
        }
    }
}

/// Generic strongly convex quadratic f(θ) = ½θᵀPθ − qᵀθ (+ c). Used by the
/// engine tests: the centralized optimum (ΣP)⁻¹Σq is known in closed form.
pub struct QuadraticNode {
    pub p: Mat,
    pub q: Vec<f64>,
    /// solve_into scratch: regularized system + its Cholesky factor
    lhs: Mat,
    chol: Mat,
}

impl QuadraticNode {
    pub fn new(p: Mat, q: Vec<f64>) -> Self {
        assert_eq!(p.rows(), p.cols());
        assert_eq!(p.rows(), q.len());
        let d = p.rows();
        QuadraticNode { p, q, lhs: Mat::zeros(d, d), chol: Mat::zeros(d, d) }
    }

    /// Random SPD instance.
    pub fn random(dim: usize, rng: &mut Pcg) -> Self {
        let b = Mat::randn(dim, dim, rng);
        let mut p = b.t_matmul(&b);
        for i in 0..dim {
            p[(i, i)] += 1.0;
        }
        QuadraticNode::new(p, rng.normal_vec(dim))
    }

    /// Centralized optimum of Σ_i f_i for a set of nodes.
    pub fn central_optimum(nodes: &[QuadraticNode]) -> Vec<f64> {
        let d = nodes[0].q.len();
        let mut p_sum = Mat::zeros(d, d);
        let mut q_sum = vec![0.0; d];
        for n in nodes {
            p_sum += &n.p;
            for k in 0..d {
                q_sum[k] += n.q[k];
            }
        }
        Cholesky::new(&p_sum).unwrap().solve_vec(&q_sum)
    }
}

impl LocalSolver for QuadraticNode {
    fn dim(&self) -> usize {
        self.q.len()
    }

    fn initial_param(&mut self, rng: &mut Pcg) -> Vec<f64> {
        rng.normal_vec(self.dim())
    }

    fn objective(&mut self, theta: &[f64]) -> f64 {
        // ½θᵀPθ − qᵀθ, accumulated row-wise (no Pθ vector)
        let d = self.q.len();
        let mut quad = 0.0;
        for r in 0..d {
            let row = self.p.row(r);
            let mut pr = 0.0;
            for (x, y) in row.iter().zip(theta) {
                pr += x * y;
            }
            quad += theta[r] * pr;
        }
        0.5 * quad - theta.iter().zip(&self.q).map(|(a, b)| a * b).sum::<f64>()
    }

    fn solve(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
             eta_wsum: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.solve_into(theta, lambda, eta_sum, eta_wsum, &mut out);
        out
    }

    fn solve_into(&mut self, _theta: &[f64], lambda: &[f64], eta_sum: f64,
                  eta_wsum: &[f64], out: &mut [f64]) {
        // (P + 2Ση·I) θ = q − 2λ + w
        let d = self.dim();
        debug_assert_eq!(out.len(), d);
        self.lhs.data_mut().copy_from_slice(self.p.data());
        for i in 0..d {
            self.lhs[(i, i)] += 2.0 * eta_sum + 1e-12;
        }
        for k in 0..d {
            out[k] = self.q[k] - 2.0 * lambda[k] + eta_wsum[k];
        }
        Cholesky::factor_into(&self.lhs, &mut self.chol).expect("quadratic SPD");
        Cholesky::solve_in_place(&self.chol, out);
    }
}
