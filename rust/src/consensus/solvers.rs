//! Pure-Rust [`LocalSolver`]s for convex consensus problems.
//!
//! These exercise the engine end-to-end without artifacts and back the
//! quickstart/lasso examples. Each solves the penalized subproblem
//! `argmin f(θ) + 2λᵀθ + (Ση)‖θ‖² − θᵀw + const`, `w = Ση_ij(θ_i+θ_j)`.

use super::LocalSolver;
use crate::linalg::{Cholesky, Mat};
use crate::util::rng::Pcg;

/// Distributed least squares: f_i(θ) = ½‖A_iθ − b_i‖².
pub struct LeastSquaresNode {
    ata: Mat,
    atb: Vec<f64>,
    a: Mat,
    b: Vec<f64>,
}

impl LeastSquaresNode {
    pub fn new(a: Mat, b: Vec<f64>) -> Self {
        assert_eq!(a.rows(), b.len());
        LeastSquaresNode { ata: a.t_matmul(&a), atb: a.t_matvec(&b), a, b }
    }
}

impl LocalSolver for LeastSquaresNode {
    fn dim(&self) -> usize {
        self.ata.rows()
    }

    fn initial_param(&mut self, rng: &mut Pcg) -> Vec<f64> {
        rng.normal_vec(self.dim())
    }

    fn objective(&mut self, theta: &[f64]) -> f64 {
        let r = self.a.matvec(theta);
        0.5 * r.iter().zip(&self.b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>()
    }

    fn solve(&mut self, _theta: &[f64], lambda: &[f64], eta_sum: f64,
             eta_wsum: &[f64]) -> Vec<f64> {
        // (AᵀA + 2Ση·I) θ = Aᵀb − 2λ + w
        let d = self.dim();
        let mut lhs = self.ata.clone();
        for i in 0..d {
            lhs[(i, i)] += 2.0 * eta_sum + 1e-12;
        }
        let rhs: Vec<f64> = (0..d)
            .map(|k| self.atb[k] - 2.0 * lambda[k] + eta_wsum[k])
            .collect();
        Cholesky::new(&lhs).expect("LS normal equations SPD").solve_vec(&rhs)
    }
}

/// Distributed ridge regression: f_i(θ) = ½‖A_iθ − b_i‖² + (ω/2)‖θ‖².
pub struct RidgeNode {
    inner: LeastSquaresNode,
    omega: f64,
}

impl RidgeNode {
    pub fn new(a: Mat, b: Vec<f64>, omega: f64) -> Self {
        assert!(omega >= 0.0);
        RidgeNode { inner: LeastSquaresNode::new(a, b), omega }
    }
}

impl LocalSolver for RidgeNode {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn initial_param(&mut self, rng: &mut Pcg) -> Vec<f64> {
        rng.normal_vec(self.dim())
    }

    fn objective(&mut self, theta: &[f64]) -> f64 {
        let l2: f64 = theta.iter().map(|x| x * x).sum();
        self.inner.objective(theta) + 0.5 * self.omega * l2
    }

    fn solve(&mut self, _theta: &[f64], lambda: &[f64], eta_sum: f64,
             eta_wsum: &[f64]) -> Vec<f64> {
        let d = self.dim();
        let mut lhs = self.inner.ata.clone();
        for i in 0..d {
            lhs[(i, i)] += self.omega + 2.0 * eta_sum + 1e-12;
        }
        let rhs: Vec<f64> = (0..d)
            .map(|k| self.inner.atb[k] - 2.0 * lambda[k] + eta_wsum[k])
            .collect();
        Cholesky::new(&lhs).expect("ridge normal equations SPD").solve_vec(&rhs)
    }
}

/// Distributed lasso: f_i(θ) = ½‖A_iθ − b_i‖² + ω‖θ‖₁, solved per
/// iteration by cyclic coordinate descent on the penalized subproblem.
pub struct LassoNode {
    ata: Mat,
    atb: Vec<f64>,
    a: Mat,
    b: Vec<f64>,
    omega: f64,
    /// inner coordinate-descent sweeps per ADMM iteration
    sweeps: usize,
}

impl LassoNode {
    pub fn new(a: Mat, b: Vec<f64>, omega: f64) -> Self {
        assert!(omega >= 0.0);
        LassoNode {
            ata: a.t_matmul(&a),
            atb: a.t_matvec(&b),
            a,
            b,
            omega,
            sweeps: 25,
        }
    }
}

fn soft_threshold(x: f64, k: f64) -> f64 {
    if x > k {
        x - k
    } else if x < -k {
        x + k
    } else {
        0.0
    }
}

impl LocalSolver for LassoNode {
    fn dim(&self) -> usize {
        self.ata.rows()
    }

    fn initial_param(&mut self, rng: &mut Pcg) -> Vec<f64> {
        rng.normal_vec(self.dim())
    }

    fn objective(&mut self, theta: &[f64]) -> f64 {
        let r = self.a.matvec(theta);
        let l1: f64 = theta.iter().map(|x| x.abs()).sum();
        0.5 * r.iter().zip(&self.b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>()
            + self.omega * l1
    }

    fn solve(&mut self, theta: &[f64], lambda: &[f64], eta_sum: f64,
             eta_wsum: &[f64]) -> Vec<f64> {
        // minimize ½θᵀQθ − cᵀθ + ω‖θ‖₁ with
        // Q = AᵀA + 2Ση·I, c = Aᵀb − 2λ + w
        let d = self.dim();
        let mut th = theta.to_vec();
        let q = &self.ata;
        let diag: Vec<f64> = (0..d).map(|k| q[(k, k)] + 2.0 * eta_sum + 1e-12).collect();
        let c: Vec<f64> = (0..d)
            .map(|k| self.atb[k] - 2.0 * lambda[k] + eta_wsum[k])
            .collect();
        for _ in 0..self.sweeps {
            for k in 0..d {
                // residual correlation excluding coordinate k
                let mut qk_th = 0.0;
                for j in 0..d {
                    if j != k {
                        qk_th += q[(k, j)] * th[j];
                    }
                }
                th[k] = soft_threshold(c[k] - qk_th, self.omega) / diag[k];
            }
        }
        th
    }
}

/// Generic strongly convex quadratic f(θ) = ½θᵀPθ − qᵀθ (+ c). Used by the
/// engine tests: the centralized optimum (ΣP)⁻¹Σq is known in closed form.
pub struct QuadraticNode {
    pub p: Mat,
    pub q: Vec<f64>,
}

impl QuadraticNode {
    pub fn new(p: Mat, q: Vec<f64>) -> Self {
        assert_eq!(p.rows(), p.cols());
        assert_eq!(p.rows(), q.len());
        QuadraticNode { p, q }
    }

    /// Random SPD instance.
    pub fn random(dim: usize, rng: &mut Pcg) -> Self {
        let b = Mat::randn(dim, dim, rng);
        let mut p = b.t_matmul(&b);
        for i in 0..dim {
            p[(i, i)] += 1.0;
        }
        QuadraticNode { p, q: rng.normal_vec(dim) }
    }

    /// Centralized optimum of Σ_i f_i for a set of nodes.
    pub fn central_optimum(nodes: &[QuadraticNode]) -> Vec<f64> {
        let d = nodes[0].q.len();
        let mut p_sum = Mat::zeros(d, d);
        let mut q_sum = vec![0.0; d];
        for n in nodes {
            p_sum += &n.p;
            for k in 0..d {
                q_sum[k] += n.q[k];
            }
        }
        Cholesky::new(&p_sum).unwrap().solve_vec(&q_sum)
    }
}

impl LocalSolver for QuadraticNode {
    fn dim(&self) -> usize {
        self.q.len()
    }

    fn initial_param(&mut self, rng: &mut Pcg) -> Vec<f64> {
        rng.normal_vec(self.dim())
    }

    fn objective(&mut self, theta: &[f64]) -> f64 {
        let pt = self.p.matvec(theta);
        0.5 * crate::linalg::Mat::col_vec(theta).fro_dot(&Mat::col_vec(&pt))
            - theta.iter().zip(&self.q).map(|(a, b)| a * b).sum::<f64>()
    }

    fn solve(&mut self, _theta: &[f64], lambda: &[f64], eta_sum: f64,
             eta_wsum: &[f64]) -> Vec<f64> {
        // (P + 2Ση·I) θ = q − 2λ + w
        let d = self.dim();
        let mut lhs = self.p.clone();
        for i in 0..d {
            lhs[(i, i)] += 2.0 * eta_sum + 1e-12;
        }
        let rhs: Vec<f64> = (0..d)
            .map(|k| self.q[k] - 2.0 * lambda[k] + eta_wsum[k])
            .collect();
        Cholesky::new(&lhs).expect("quadratic SPD").solve_vec(&rhs)
    }
}
