//! # fadmm — Fast ADMM for Distributed Optimization with Adaptive Penalty
//!
//! A full-system reproduction of Song, Yoon & Pavlovic (AAAI 2016): a fully
//! decentralized consensus-ADMM runtime whose per-node / per-edge penalty
//! parameters adapt every iteration (schemes VP, AP, NAP and combinations),
//! applied to distributed probabilistic PCA and affine structure from
//! motion.
//!
//! ## Architecture (three layers, Python never at runtime)
//!
//! * **L3 — this crate**: graph topology, node actors, per-edge penalty
//!   schedulers ([`penalty`]), the consensus engine ([`consensus`]), the
//!   D-PPCA application ([`dppca`]), experiments and benches.
//! * **L2 — JAX (build time)**: the node EM/consensus update, lowered once
//!   to HLO text by `python/compile/aot.py`.
//! * **L1 — Pallas (build time)**: the data-touching moment/E-step kernels
//!   embedded in the L2 program.
//!
//! The [`runtime`] module loads the lowered artifacts through the PJRT CPU
//! client (`xla` crate, behind the off-by-default `xla` cargo feature so
//! the default build is dependency-free) and exposes them behind a
//! [`runtime::Backend`] trait; a pure-Rust [`runtime::NativeBackend`]
//! implements the identical math for artifact-free tests and as a
//! cross-check oracle.

pub mod cluster;
pub mod config;
pub mod consensus;
pub mod coordinator;
pub mod data;
pub mod dppca;
pub mod error;
pub mod experiments;
pub mod graph;
pub mod kernel;
pub mod linalg;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod penalty;
pub mod pool;
pub mod runtime;
pub mod sfm;
pub mod util;

pub use error::{Error, Result};
