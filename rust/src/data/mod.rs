//! Synthetic workload generators.
//!
//! Three families, matching the paper's three experiment groups (and the
//! data substitutions documented in DESIGN.md §3):
//!
//! * [`synthetic`] — Gaussian-subspace samples (paper §5.1);
//! * [`turntable`] — rigid 3-D objects on a turntable, affine-projected
//!   into tracked 2-D features (Caltech Turntable substitute, §5.2);
//! * [`trajectories`] — a 135-object corpus of rigid-motion trajectory
//!   matrices with controlled degeneracies (Hopkins 155 substitute).

pub mod partition;
pub mod synthetic;
pub mod trajectories;
pub mod turntable;

pub use partition::{even_split, Partition};
pub use synthetic::{SubspaceData, SubspaceSpec};
pub use trajectories::{TrajectoryCorpus, TrajectoryObject};
pub use turntable::{turntable_objects, TurntableObject, OBJECT_NAMES};
