//! Synthetic turntable objects — the Caltech Turntable substitute.
//!
//! The paper's SfM experiment (§5.2, Figs. 3-5) consumes 2F×N measurement
//! matrices of five rigid objects tracked over 30 turntable frames. We
//! synthesize five objects with distinct geometry (named after the five
//! Caltech objects used in the paper), rotate each about the vertical axis
//! through the full frame sweep, project orthographically, and add pixel
//! noise — exactly the input distribution the downstream pipeline sees.

use crate::linalg::Mat;
use crate::util::rng::Pcg;

/// The five objects reported in the paper.
pub const OBJECT_NAMES: [&str; 5] =
    ["BallSander", "BoxStuff", "Rooster", "Standing", "StorageBin"];

/// One synthetic object: 3-D points + its 2F×N measurement matrix.
#[derive(Debug, Clone)]
pub struct TurntableObject {
    pub name: String,
    /// (N, 3) ground-truth structure (first frame's object coordinates).
    pub structure: Mat,
    /// (2F, N) tracked feature matrix: rows 2f, 2f+1 are frame f's u, v.
    pub measurements: Mat,
    pub frames: usize,
}

/// Geometry specification per object.
#[derive(Debug, Clone, Copy)]
pub struct TurntableSpec {
    pub points: usize,
    pub frames: usize,
    /// total rotation swept over the sequence (radians)
    pub sweep: f64,
    /// observation noise std-dev (in projected units ≈ pixels)
    pub noise: f64,
    /// object size in projected units. Real tracked features live in
    /// pixel coordinates (object extent ~10² px, tracker noise ~1 px);
    /// matching that scale keeps the ML noise precision a* ≈ O(1), the
    /// regime the paper's η⁰ = 10 was tuned for.
    pub scale: f64,
}

impl Default for TurntableSpec {
    fn default() -> Self {
        // 120 points / 30 frames matches the d120 artifact shape
        TurntableSpec {
            points: 120,
            frames: 30,
            sweep: 70f64.to_radians(),
            noise: 0.7,
            scale: 60.0,
        }
    }
}

/// Sample a 3-D point cloud with per-object characteristic geometry.
fn object_cloud(name: &str, points: usize, rng: &mut Pcg) -> Mat {
    let mut p = Mat::zeros(points, 3);
    for i in 0..points {
        let (x, y, z) = match name {
            // cylinder with a handle-ish protrusion
            "BallSander" => {
                let th = rng.range(0.0, std::f64::consts::TAU);
                let h = rng.range(-1.0, 1.0);
                (th.cos() * 0.7, h, th.sin() * 0.7)
            }
            // box: points on the surface of a cuboid
            "BoxStuff" => {
                let face = rng.below(3);
                let sgn = if rng.f64() < 0.5 { -1.0 } else { 1.0 };
                let u = rng.range(-1.0, 1.0);
                let v = rng.range(-0.6, 0.6);
                match face {
                    0 => (sgn * 1.0, u * 0.8, v),
                    1 => (u, sgn * 0.8, v),
                    _ => (u, v * 0.8, sgn * 0.6),
                }
            }
            // tall thin blob with an offset crest
            "Rooster" => {
                let t = rng.f64();
                (0.3 * rng.normal() + 0.4 * (t * 9.0).sin(),
                 1.4 * (t - 0.5),
                 0.3 * rng.normal())
            }
            // person-like: vertical gaussian stack
            "Standing" => (0.35 * rng.normal(), rng.range(-1.2, 1.2), 0.25 * rng.normal()),
            // open box: shell of a cuboid minus the top
            _ => {
                let u = rng.range(-1.0, 1.0);
                let v = rng.range(-1.0, 1.0);
                let w = rng.range(0.0, 0.8);
                match rng.below(5) {
                    0 => (u, -0.0, v),          // bottom
                    1 => (1.0, w, v),
                    2 => (-1.0, w, v),
                    3 => (u, w, 1.0),
                    _ => (u, w, -1.0),
                }
            }
        };
        p[(i, 0)] = x;
        p[(i, 1)] = y;
        p[(i, 2)] = z;
    }
    p
}

/// Orthographic projection of the cloud rotated by `theta` about +y.
/// Returns (u, v) rows for the frame.
fn project(structure: &Mat, theta: f64, noise: f64, rng: &mut Pcg) -> (Vec<f64>, Vec<f64>) {
    let (c, s) = (theta.cos(), theta.sin());
    let n = structure.rows();
    let mut u = Vec::with_capacity(n);
    let mut v = Vec::with_capacity(n);
    for i in 0..n {
        let (x, y, z) = (structure[(i, 0)], structure[(i, 1)], structure[(i, 2)]);
        // rotate about y then orthographic onto the image plane (x, y)
        let xr = c * x + s * z;
        u.push(xr + noise * rng.normal());
        v.push(y + noise * rng.normal());
    }
    (u, v)
}

impl TurntableSpec {
    /// Generate a named object deterministically from `seed`.
    pub fn generate(&self, name: &str, seed: u64) -> TurntableObject {
        let mut rng = Pcg::new(seed, 0xCA17EC);
        let structure = object_cloud(name, self.points, &mut rng).scale(self.scale);
        let mut meas = Mat::zeros(2 * self.frames, self.points);
        for f in 0..self.frames {
            let theta = self.sweep * (f as f64) / (self.frames.max(2) as f64 - 1.0);
            let (u, v) = project(&structure, theta, self.noise, &mut rng);
            meas.row_mut(2 * f).copy_from_slice(&u);
            meas.row_mut(2 * f + 1).copy_from_slice(&v);
        }
        TurntableObject {
            name: name.to_string(),
            structure,
            measurements: meas,
            frames: self.frames,
        }
    }
}

/// The five-object benchmark set with the default spec.
pub fn turntable_objects(seed: u64) -> Vec<TurntableObject> {
    let spec = TurntableSpec::default();
    OBJECT_NAMES
        .iter()
        .enumerate()
        .map(|(k, name)| spec.generate(name, seed.wrapping_add(k as u64)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Svd;

    #[test]
    fn shapes_and_names() {
        let objs = turntable_objects(0);
        assert_eq!(objs.len(), 5);
        for o in &objs {
            assert_eq!(o.measurements.shape(), (60, 120));
            assert_eq!(o.structure.shape(), (120, 3));
        }
        assert_eq!(objs[3].name, "Standing");
    }

    #[test]
    fn deterministic() {
        let a = turntable_objects(9);
        let b = turntable_objects(9);
        assert_eq!(a[0].measurements, b[0].measurements);
    }

    #[test]
    fn centred_measurements_are_nearly_rank_3() {
        // affine rigid scenes have rank-3 centred measurement matrices;
        // noise leaves a sharp spectral gap after σ₃
        let obj = TurntableSpec::default().generate("BoxStuff", 1);
        let mut m = obj.measurements.clone();
        for r in 0..m.rows() {
            let mean: f64 = m.row(r).iter().sum::<f64>() / m.cols() as f64;
            for c in 0..m.cols() {
                m[(r, c)] -= mean;
            }
        }
        let svd = Svd::new(&m).unwrap();
        assert!(svd.s[3] / svd.s[2] < 0.05, "gap: {:?}", &svd.s[..5]);
    }

    #[test]
    fn objects_have_distinct_geometry() {
        let objs = turntable_objects(0);
        for i in 0..objs.len() {
            for j in (i + 1)..objs.len() {
                let diff = objs[i].structure.max_abs_diff(&objs[j].structure);
                assert!(diff > 0.1, "{} vs {}", objs[i].name, objs[j].name);
            }
        }
    }
}
