//! Rigid-motion trajectory corpus — the Hopkins 155 substitute.
//!
//! The paper's Hopkins experiment (§5.2) runs D-PPCA on 135 objects'
//! point-trajectory matrices and reports the mean iterations to
//! convergence per penalty scheme, excluding objects whose subspace-angle
//! error exceeds 15° (non-rigid trajectories a linear model cannot fit).
//! This corpus reproduces those conditions: rigid objects under smooth
//! random camera motion, in bucketed sizes matching the artifact shapes,
//! with a controlled fraction of strongly non-rigid sequences.

use crate::linalg::Mat;
use crate::util::rng::Pcg;

/// One corpus object.
#[derive(Debug, Clone)]
pub struct TrajectoryObject {
    pub id: usize,
    /// (2F, N) trajectory matrix.
    pub measurements: Mat,
    /// (N, 3) ground-truth structure.
    pub structure: Mat,
    pub frames: usize,
    /// true for deliberately non-rigid sequences (expected to fail the
    /// 15° filter, like Hopkins' articulated/non-rigid objects)
    pub degenerate: bool,
}

/// The full corpus.
#[derive(Debug, Clone)]
pub struct TrajectoryCorpus {
    pub objects: Vec<TrajectoryObject>,
}

/// Size buckets: (points, frames). Chosen to match the lowered artifact
/// shapes (D ∈ {60, 100, 140}, per-node samples 2F/5 ∈ {6, 12}).
pub const SIZE_BUCKETS: [(usize, usize); 6] =
    [(60, 15), (60, 30), (100, 15), (100, 30), (140, 15), (140, 30)];

impl TrajectoryCorpus {
    /// Generate `count` objects; `degenerate_frac` of them non-rigid.
    pub fn generate(count: usize, degenerate_frac: f64, seed: u64) -> TrajectoryCorpus {
        let mut root = Pcg::new(seed, 0x40BB1E5);
        let objects = (0..count)
            .map(|id| {
                let mut rng = root.fork(id as u64);
                let (points, frames) = SIZE_BUCKETS[id % SIZE_BUCKETS.len()];
                let degenerate = rng.f64() < degenerate_frac;
                generate_object(id, points, frames, degenerate, &mut rng)
            })
            .collect();
        TrajectoryCorpus { objects }
    }

    /// The paper's corpus size.
    pub fn paper_sized(seed: u64) -> TrajectoryCorpus {
        // 135 usable objects; ~10% made non-rigid to exercise the filter
        Self::generate(135, 0.1, seed)
    }
}

fn generate_object(id: usize, points: usize, frames: usize, degenerate: bool,
                   rng: &mut Pcg) -> TrajectoryObject {
    // gaussian blob structure with anisotropic scale, in pixel-like units
    // (object extent ~10² px, tracker noise ~1 px — keeps a* ≈ O(1), see
    // `turntable::TurntableSpec::scale`)
    let mut structure = Mat::zeros(points, 3);
    let px = 40.0;
    let scales = [px * rng.range(0.6, 1.5), px * rng.range(0.6, 1.5),
                  px * rng.range(0.6, 1.5)];
    for i in 0..points {
        for k in 0..3 {
            structure[(i, k)] = scales[k] * rng.normal();
        }
    }
    // smooth *generic* rotation: the axis-angle rate precesses over the
    // sequence (as with real handheld/vehicle footage), so all three
    // structure directions are excited and the rank-3 model is
    // well-conditioned; degenerate objects are a separate corpus fraction
    let mut meas = Mat::zeros(2 * frames, points);
    let base = [rng.range(0.05, 0.12), rng.range(0.05, 0.12), rng.range(0.05, 0.12)];
    let phase = rng.range(0.0, std::f64::consts::TAU);
    let precession = rng.range(0.2, 0.5);
    let noise = 0.7;
    let mut r = Mat::eye(3);
    for f in 0..frames {
        // integrate a small rotation each frame (matrix exponential via
        // Rodrigues on the small per-frame step); the axis precesses
        let wf = f as f64 * precession + phase;
        let rate = [base[0] * wf.sin(), base[1] * wf.cos(),
                    base[2] * (wf + 1.0).sin()];
        r = rodrigues(rate).matmul(&r);
        for i in 0..points {
            let p = [structure[(i, 0)], structure[(i, 1)], structure[(i, 2)]];
            let mut q = [0.0; 3];
            for (row, qr) in q.iter_mut().enumerate() {
                *qr = r[(row, 0)] * p[0] + r[(row, 1)] * p[1] + r[(row, 2)] * p[2];
            }
            // strongly non-rigid: per-frame structured deformation that a
            // single linear subspace cannot capture
            let (du, dv) = if degenerate {
                let phase = f as f64 * 0.7 + i as f64;
                (0.4 * px * phase.sin() * rng.normal().abs(),
                 0.4 * px * phase.cos() * rng.normal().abs())
            } else {
                (0.0, 0.0)
            };
            meas[(2 * f, i)] = q[0] + du + noise * rng.normal();
            meas[(2 * f + 1, i)] = q[1] + dv + noise * rng.normal();
        }
    }
    TrajectoryObject { id, measurements: meas, structure, frames, degenerate }
}

/// Rodrigues rotation matrix for an axis-angle vector.
fn rodrigues(w: [f64; 3]) -> Mat {
    let theta = (w[0] * w[0] + w[1] * w[1] + w[2] * w[2]).sqrt();
    if theta < 1e-12 {
        return Mat::eye(3);
    }
    let k = [w[0] / theta, w[1] / theta, w[2] / theta];
    let kx = Mat::from_rows(3, 3, &[
        0.0, -k[2], k[1],
        k[2], 0.0, -k[0],
        -k[1], k[0], 0.0,
    ]);
    let mut r = Mat::eye(3);
    r.axpy(theta.sin(), &kx);
    r.axpy(1.0 - theta.cos(), &kx.matmul(&kx));
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Svd;

    #[test]
    fn corpus_sizes() {
        let c = TrajectoryCorpus::generate(12, 0.0, 3);
        assert_eq!(c.objects.len(), 12);
        for (i, o) in c.objects.iter().enumerate() {
            let (p, f) = SIZE_BUCKETS[i % SIZE_BUCKETS.len()];
            assert_eq!(o.measurements.shape(), (2 * f, p));
        }
    }

    #[test]
    fn rigid_objects_rank3() {
        let c = TrajectoryCorpus::generate(6, 0.0, 5);
        for o in &c.objects {
            let mut m = o.measurements.clone();
            for r in 0..m.rows() {
                let mean: f64 = m.row(r).iter().sum::<f64>() / m.cols() as f64;
                for col in 0..m.cols() {
                    m[(r, col)] -= mean;
                }
            }
            let svd = Svd::new(&m).unwrap();
            assert!(svd.s[3] / svd.s[2] < 0.12, "object {} spectrum {:?}", o.id, &svd.s[..5]);
        }
    }

    #[test]
    fn degenerate_objects_not_rank3() {
        let mut rng = Pcg::seed(8);
        let o = generate_object(0, 60, 15, true, &mut rng);
        let svd = Svd::new(&o.measurements).unwrap();
        assert!(svd.s[3] / svd.s[2] > 0.05, "spectrum {:?}", &svd.s[..5]);
    }

    #[test]
    fn rodrigues_is_rotation() {
        let r = rodrigues([0.1, -0.2, 0.05]);
        let should_be_eye = r.t_matmul(&r);
        assert!(should_be_eye.max_abs_diff(&Mat::eye(3)) < 1e-12);
    }

    #[test]
    fn paper_sized_has_some_degenerates() {
        let c = TrajectoryCorpus::paper_sized(1);
        let deg = c.objects.iter().filter(|o| o.degenerate).count();
        assert_eq!(c.objects.len(), 135);
        assert!(deg > 5 && deg < 30, "degenerate count {deg}");
    }
}
