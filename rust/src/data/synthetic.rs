//! Gaussian-subspace synthetic data (paper §5.1).
//!
//! "We generated 500 samples of 20 dimensional observations from a 5-dim
//! subspace following N(0, I), with the Gaussian measurement noise
//! following N(0, 0.2·I)."

use crate::linalg::Mat;
use crate::util::rng::Pcg;

/// A generated dataset together with its ground truth.
#[derive(Debug, Clone)]
pub struct SubspaceData {
    /// (D, N) observations, one sample per column.
    pub x: Mat,
    /// (D, M) ground-truth projection matrix (subspace basis).
    pub w_true: Mat,
    /// Ground-truth mean (D).
    pub mu_true: Vec<f64>,
    /// Noise variance used.
    pub noise_var: f64,
}

/// Parameters for the generator; defaults reproduce the paper's setting.
#[derive(Debug, Clone, Copy)]
pub struct SubspaceSpec {
    pub d: usize,
    pub m: usize,
    pub n: usize,
    pub noise_var: f64,
    /// If false the mean is zero (the paper's setting); if true a random
    /// offset is added (used by robustness tests).
    pub random_mean: bool,
}

impl Default for SubspaceSpec {
    fn default() -> Self {
        SubspaceSpec { d: 20, m: 5, n: 500, noise_var: 0.2, random_mean: false }
    }
}

impl SubspaceSpec {
    /// Generate a dataset.
    pub fn generate(&self, rng: &mut Pcg) -> SubspaceData {
        let w_true = Mat::randn(self.d, self.m, rng);
        let z = Mat::randn(self.m, self.n, rng);
        let mu_true: Vec<f64> = if self.random_mean {
            rng.normal_vec(self.d)
        } else {
            vec![0.0; self.d]
        };
        let mut x = w_true.matmul(&z);
        let sigma = self.noise_var.sqrt();
        for r in 0..self.d {
            for c in 0..self.n {
                x[(r, c)] += mu_true[r] + sigma * rng.normal();
            }
        }
        SubspaceData { x, w_true, mu_true, noise_var: self.noise_var }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{max_principal_angle_deg, Svd};
    use crate::util::prop;

    #[test]
    fn shapes_and_determinism() {
        let spec = SubspaceSpec::default();
        let a = spec.generate(&mut Pcg::seed(1));
        let b = spec.generate(&mut Pcg::seed(1));
        assert_eq!(a.x.shape(), (20, 500));
        assert_eq!(a.w_true.shape(), (20, 5));
        assert_eq!(a.x, b.x);
    }

    #[test]
    fn pca_recovers_subspace() {
        // sanity: top-M left singular vectors of centred data ≈ span(W_true)
        let spec = SubspaceSpec::default();
        let data = spec.generate(&mut Pcg::seed(7));
        let svd = Svd::new(&data.x).unwrap();
        let u5 = svd.u.col_slice(0, 5);
        let angle = max_principal_angle_deg(&u5, &data.w_true).unwrap();
        assert!(angle < 5.0, "angle {angle}");
    }

    #[test]
    fn noise_scale_respected() {
        prop::check("residual energy ≈ noise_var per dim", |rng| {
            let spec = SubspaceSpec { d: 10, m: 2, n: 400, noise_var: 0.5, random_mean: false };
            let data = spec.generate(rng);
            // project out the true subspace; remaining variance ≈ noise
            let (q, _) = crate::linalg::qr_thin(&data.w_true).unwrap();
            let proj = q.matmul(&q.t_matmul(&data.x));
            let resid = &data.x - &proj;
            let var = resid.fro_norm().powi(2) / (spec.n as f64 * (spec.d - spec.m) as f64);
            assert!((var - 0.5).abs() < 0.12, "var {var}");
        });
    }

    #[test]
    fn random_mean_offsets_data() {
        let spec = SubspaceSpec { random_mean: true, ..Default::default() };
        let data = spec.generate(&mut Pcg::seed(3));
        assert!(data.mu_true.iter().any(|&v| v.abs() > 0.1));
    }
}
