//! Even sample partitioning across nodes (paper: "the samples are
//! assigned to each node evenly").

/// Column ranges assigned to each node, plus the padded per-node budget.
#[derive(Debug, Clone)]
pub struct Partition {
    /// Half-open column ranges per node.
    pub ranges: Vec<(usize, usize)>,
    /// max over nodes of range length — the artifact's padded N.
    pub padded: usize,
}

/// Split `n` samples over `j` nodes as evenly as possible: the first
/// `n % j` nodes receive one extra sample (deterministic, contiguous).
pub fn even_split(n: usize, j: usize) -> Partition {
    assert!(j > 0, "even_split: zero nodes");
    let base = n / j;
    let extra = n % j;
    let mut ranges = Vec::with_capacity(j);
    let mut start = 0;
    for i in 0..j {
        let len = base + usize::from(i < extra);
        ranges.push((start, start + len));
        start += len;
    }
    Partition { ranges, padded: base + usize::from(extra > 0) }
}

impl Partition {
    /// Number of samples owned by node `i`.
    pub fn len(&self, i: usize) -> usize {
        let (lo, hi) = self.ranges[i];
        hi - lo
    }

    pub fn num_nodes(&self) -> usize {
        self.ranges.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn covers_everything_without_overlap() {
        prop::check("partition is exact cover", |rng| {
            let n = rng.below(1000);
            let j = 1 + rng.below(30);
            let p = even_split(n, j);
            assert_eq!(p.num_nodes(), j);
            let mut cursor = 0;
            for &(lo, hi) in &p.ranges {
                assert_eq!(lo, cursor);
                assert!(hi >= lo);
                cursor = hi;
            }
            assert_eq!(cursor, n);
        });
    }

    #[test]
    fn balance_within_one() {
        prop::check("sizes differ by ≤ 1", |rng| {
            let n = rng.below(1000);
            let j = 1 + rng.below(30);
            let p = even_split(n, j);
            let sizes: Vec<usize> = (0..j).map(|i| p.len(i)).collect();
            let max = *sizes.iter().max().unwrap();
            let min = *sizes.iter().min().unwrap();
            assert!(max - min <= 1);
            assert_eq!(p.padded, max);
        });
    }

    #[test]
    fn paper_shapes() {
        // the Fig. 2 configurations drive the artifact shape registry
        assert_eq!(even_split(500, 20).padded, 25);
        assert_eq!(even_split(500, 16).padded, 32);
        assert_eq!(even_split(500, 12).padded, 42);
    }
}
