//! PJRT-backed backend executing the AOT-lowered HLO artifacts.
//!
//! Artifacts are HLO *text* (see `python/compile/aot.py` for why), parsed
//! by `HloModuleProto::from_text_file`, compiled once per name on the
//! PJRT CPU client and cached. Literal marshalling is f64 row-major,
//! matching JAX's C-order lowering.

use std::collections::HashMap;

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::{Backend, Manifest};
use crate::dppca::{Moments, PpcaParams};
use crate::error::{Error, Result};
use crate::linalg::Mat;

/// PJRT CPU backend with a per-artifact executable cache.
pub struct XlaBackend {
    client: PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, PjRtLoadedExecutable>,
    /// cumulative executions per artifact kind (perf introspection)
    pub exec_counts: HashMap<&'static str, u64>,
}

impl XlaBackend {
    /// Create from an artifact directory (must contain `manifest.json`).
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<XlaBackend> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = PjRtClient::cpu()?;
        Ok(XlaBackend { client, manifest, cache: HashMap::new(), exec_counts: HashMap::new() })
    }

    /// Create from the default artifact location (`$FADMM_ARTIFACTS` or
    /// `./artifacts`).
    pub fn from_default_dir() -> Result<XlaBackend> {
        Self::new(Manifest::default_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch) the executable for an artifact name.
    fn executable(&mut self, name: &str) -> Result<&PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let entry = self.manifest.get(name)?;
            let path = entry.file.to_string_lossy().to_string();
            let proto = HloModuleProto::from_text_file(&path)?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Eagerly compile every artifact a (d, m, n) experiment shape needs;
    /// returns how many were newly compiled. Called at run start so the
    /// hot loop never hits a compile.
    pub fn warmup(&mut self, d: usize, m: usize, n: usize) -> Result<usize> {
        let names = [
            format!("moments_d{d}_n{n}"),
            format!("node_update_d{d}_m{m}"),
            format!("objective_d{d}_m{m}"),
            format!("objective_batch_d{d}_m{m}"),
            format!("node_update_direct_d{d}_m{m}_n{n}"),
            format!("estep_z_d{d}_m{m}_n{n}"),
        ];
        let mut compiled = 0;
        for name in names {
            if !self.cache.contains_key(&name) {
                self.executable(&name)?;
                compiled += 1;
            }
        }
        Ok(compiled)
    }

    fn run(&mut self, name: &str, kind: &'static str, inputs: &[Literal])
           -> Result<Vec<Literal>> {
        let exe = self.executable(name)?;
        let result = exe.execute::<Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        *self.exec_counts.entry(kind).or_insert(0) += 1;
        Ok(lit.to_tuple()?)
    }
}

// ---- literal marshalling ---------------------------------------------------

fn lit_scalar(x: f64) -> Literal {
    Literal::scalar(x)
}

fn lit_vec(v: &[f64]) -> Literal {
    Literal::vec1(v)
}

fn lit_mat(m: &Mat) -> Result<Literal> {
    Ok(Literal::vec1(m.data()).reshape(&[m.rows() as i64, m.cols() as i64])?)
}

fn take_scalar(lit: &Literal) -> Result<f64> {
    let v = lit.to_vec::<f64>()?;
    v.first()
        .copied()
        .ok_or_else(|| Error::Artifact("empty scalar output".into()))
}

fn take_vec(lit: &Literal, len: usize) -> Result<Vec<f64>> {
    let v = lit.to_vec::<f64>()?;
    if v.len() != len {
        return Err(Error::Shape(format!("output len {} != {len}", v.len())));
    }
    Ok(v)
}

fn take_mat(lit: &Literal, rows: usize, cols: usize) -> Result<Mat> {
    let v = take_vec(lit, rows * cols)?;
    Ok(Mat::from_vec(rows, cols, v))
}

fn expect_outputs(outs: &[Literal], want: usize, name: &str) -> Result<()> {
    if outs.len() != want {
        return Err(Error::Artifact(format!(
            "{name}: expected {want} outputs, got {}",
            outs.len()
        )));
    }
    Ok(())
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla-pjrt"
    }

    fn moments(&mut self, x: &Mat, mask: &[f64]) -> Result<Moments> {
        let (d, n) = x.shape();
        let name = format!("moments_d{d}_n{n}");
        let outs = self.run(&name, "moments", &[lit_mat(x)?, lit_vec(mask)])?;
        expect_outputs(&outs, 3, &name)?;
        Ok(Moments {
            n: take_scalar(&outs[0])?,
            sx: take_vec(&outs[1], d)?,
            sxx: take_mat(&outs[2], d, d)?,
        })
    }

    fn node_update(&mut self, mom: &Moments, params: &PpcaParams,
                   mult: &PpcaParams, eta_sum: f64, eta_w: &PpcaParams)
                   -> Result<(PpcaParams, f64)> {
        let (d, m) = (params.d(), params.m());
        let name = format!("node_update_d{d}_m{m}");
        let inputs = [
            lit_scalar(mom.n),
            lit_vec(&mom.sx),
            lit_mat(&mom.sxx)?,
            lit_mat(&params.w)?,
            lit_vec(&params.mu),
            lit_scalar(params.a),
            lit_mat(&mult.w)?,
            lit_vec(&mult.mu),
            lit_scalar(mult.a),
            lit_scalar(eta_sum),
            lit_mat(&eta_w.w)?,
            lit_vec(&eta_w.mu),
            lit_scalar(eta_w.a),
        ];
        let outs = self.run(&name, "node_update", &inputs)?;
        expect_outputs(&outs, 4, &name)?;
        let p = PpcaParams {
            w: take_mat(&outs[0], d, m)?,
            mu: take_vec(&outs[1], d)?,
            a: take_scalar(&outs[2])?,
        };
        Ok((p, take_scalar(&outs[3])?))
    }

    fn node_update_direct(&mut self, x: &Mat, mask: &[f64], params: &PpcaParams,
                          mult: &PpcaParams, eta_sum: f64, eta_w: &PpcaParams)
                          -> Result<(PpcaParams, f64)> {
        let (d, n) = x.shape();
        let m = params.m();
        let name = format!("node_update_direct_d{d}_m{m}_n{n}");
        let inputs = [
            lit_mat(x)?,
            lit_vec(mask),
            lit_mat(&params.w)?,
            lit_vec(&params.mu),
            lit_scalar(params.a),
            lit_mat(&mult.w)?,
            lit_vec(&mult.mu),
            lit_scalar(mult.a),
            lit_scalar(eta_sum),
            lit_mat(&eta_w.w)?,
            lit_vec(&eta_w.mu),
            lit_scalar(eta_w.a),
        ];
        let outs = self.run(&name, "node_update_direct", &inputs)?;
        expect_outputs(&outs, 4, &name)?;
        let p = PpcaParams {
            w: take_mat(&outs[0], d, m)?,
            mu: take_vec(&outs[1], d)?,
            a: take_scalar(&outs[2])?,
        };
        Ok((p, take_scalar(&outs[3])?))
    }

    fn objective(&mut self, mom: &Moments, params: &PpcaParams) -> Result<f64> {
        let (d, m) = (params.d(), params.m());
        let name = format!("objective_d{d}_m{m}");
        let inputs = [
            lit_scalar(mom.n),
            lit_vec(&mom.sx),
            lit_mat(&mom.sxx)?,
            lit_mat(&params.w)?,
            lit_vec(&params.mu),
            lit_scalar(params.a),
        ];
        let outs = self.run(&name, "objective", &inputs)?;
        expect_outputs(&outs, 1, &name)?;
        take_scalar(&outs[0])
    }

    fn objective_batch(&mut self, mom: &Moments, params: &[PpcaParams])
                       -> Result<Vec<f64>> {
        /// batch width lowered in `python/compile/model.py::OBJECTIVE_BATCH`
        const B: usize = 20;
        if params.is_empty() {
            return Ok(Vec::new());
        }
        let (d, m) = (params[0].d(), params[0].m());
        let name = format!("objective_batch_d{d}_m{m}");
        let mut out = Vec::with_capacity(params.len());
        for chunk in params.chunks(B) {
            // pad short chunks with copies of the first entry
            let mut ws = Vec::with_capacity(B * d * m);
            let mut mus = Vec::with_capacity(B * d);
            let mut a_s = Vec::with_capacity(B);
            for k in 0..B {
                let p = chunk.get(k).unwrap_or(&chunk[0]);
                ws.extend_from_slice(p.w.data());
                mus.extend_from_slice(&p.mu);
                a_s.push(p.a);
            }
            let inputs = [
                lit_scalar(mom.n),
                lit_vec(&mom.sx),
                lit_mat(&mom.sxx)?,
                Literal::vec1(&ws).reshape(&[B as i64, d as i64, m as i64])?,
                Literal::vec1(&mus).reshape(&[B as i64, d as i64])?,
                lit_vec(&a_s),
            ];
            let outs = self.run(&name, "objective_batch", &inputs)?;
            expect_outputs(&outs, 1, &name)?;
            let nlls = take_vec(&outs[0], B)?;
            out.extend_from_slice(&nlls[..chunk.len()]);
        }
        Ok(out)
    }

    fn estep_z(&mut self, x: &Mat, mask: &[f64], params: &PpcaParams) -> Result<Mat> {
        let (d, n) = x.shape();
        let m = params.m();
        let name = format!("estep_z_d{d}_m{m}_n{n}");
        let inputs = [
            lit_mat(x)?,
            lit_vec(mask),
            lit_mat(&params.w)?,
            lit_vec(&params.mu),
            lit_scalar(params.a),
        ];
        let outs = self.run(&name, "estep_z", &inputs)?;
        expect_outputs(&outs, 1, &name)?;
        take_mat(&outs[0], m, n)
    }
}
