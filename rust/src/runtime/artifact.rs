//! Artifact manifest: what `python/compile/aot.py` lowered, and where.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// One lowered artifact.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub d: usize,
    pub m: usize,
    pub n: usize,
    pub num_inputs: usize,
    pub output_shapes: Vec<Vec<usize>>,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    by_name: HashMap<String, ArtifactEntry>,
}

impl Manifest {
    /// Load and validate the manifest from an artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::io(format!("read {}", path.display()), e))?;
        let root = Json::parse(&text)?;
        let version = root.req("version")?.as_usize().unwrap_or(0);
        if version != 1 {
            return Err(Error::Artifact(format!("unsupported manifest version {version}")));
        }
        let dtype = root.req("dtype")?.as_str().unwrap_or("");
        if dtype != "f64" {
            return Err(Error::Artifact(format!("expected f64 artifacts, got '{dtype}'")));
        }
        let mut by_name = HashMap::new();
        for item in root.req("artifacts")?.as_arr().unwrap_or(&[]) {
            let entry = ArtifactEntry {
                name: req_str(item, "name")?,
                file: dir.join(req_str(item, "file")?),
                kind: req_str(item, "kind")?,
                d: req_usize(item, "d")?,
                m: req_usize(item, "m")?,
                n: req_usize(item, "n")?,
                num_inputs: req_usize(item, "num_inputs")?,
                output_shapes: item
                    .req("output_shapes")?
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|s| {
                        s.as_arr()
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(|d| d.as_usize())
                            .collect()
                    })
                    .collect(),
            };
            if !entry.file.exists() {
                return Err(Error::Artifact(format!(
                    "manifest lists missing file {}",
                    entry.file.display()
                )));
            }
            by_name.insert(entry.name.clone(), entry);
        }
        if by_name.is_empty() {
            return Err(Error::Artifact("manifest has no artifacts".into()));
        }
        Ok(Manifest { dir, by_name })
    }

    /// Locate the default artifact directory: `$FADMM_ARTIFACTS` or
    /// `./artifacts` relative to the workspace root.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("FADMM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactEntry> {
        self.by_name.get(name).ok_or_else(|| {
            Error::Artifact(format!(
                "artifact '{name}' not in manifest — is python/compile/shapes.py \
                 in sync with the experiment configuration? (run `make artifacts`)"
            ))
        })
    }

    pub fn contains(&self, name: &str) -> bool {
        self.by_name.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.by_name.keys().map(|s| s.as_str())
    }
}

fn req_str(item: &Json, key: &str) -> Result<String> {
    item.req(key)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| Error::Artifact(format!("manifest: '{key}' not a string")))
}

fn req_usize(item: &Json, key: &str) -> Result<usize> {
    item.req(key)?
        .as_usize()
        .ok_or_else(|| Error::Artifact(format!("manifest: '{key}' not an integer")))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn loads_valid_manifest() {
        let dir = std::env::temp_dir().join("fadmm_manifest_ok");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("m.hlo.txt"), "HloModule m").unwrap();
        write_manifest(&dir, r#"{"version":1,"dtype":"f64","artifacts":[
            {"name":"moments_d8_n16","file":"m.hlo.txt","kind":"moments",
             "d":8,"m":0,"n":16,"num_inputs":2,
             "input_shapes":[[8,16],[16]],"output_shapes":[[],[8],[8,8]]}]}"#);
        let man = Manifest::load(&dir).unwrap();
        assert_eq!(man.len(), 1);
        let e = man.get("moments_d8_n16").unwrap();
        assert_eq!(e.d, 8);
        assert_eq!(e.output_shapes, vec![vec![], vec![8], vec![8, 8]]);
        assert!(man.get("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_dtype_and_version() {
        let dir = std::env::temp_dir().join("fadmm_manifest_bad");
        write_manifest(&dir, r#"{"version":2,"dtype":"f64","artifacts":[]}"#);
        assert!(Manifest::load(&dir).is_err());
        write_manifest(&dir, r#"{"version":1,"dtype":"f32","artifacts":[]}"#);
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_missing_file() {
        let dir = std::env::temp_dir().join("fadmm_manifest_missing");
        write_manifest(&dir, r#"{"version":1,"dtype":"f64","artifacts":[
            {"name":"x","file":"gone.hlo.txt","kind":"moments","d":1,"m":0,
             "n":1,"num_inputs":2,"input_shapes":[],"output_shapes":[]}]}"#);
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
