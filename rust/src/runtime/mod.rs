//! Compute backends: lowered XLA artifacts (production) and the native
//! Rust oracle (tests / threaded runs).
//!
//! The optimization hot path calls one of four operations per node:
//!
//! * `moments` — L1 Pallas kernel, one pass over the raw data;
//! * `node_update` — L2 EM + consensus M-step on cached moments;
//! * `objective` — L2 marginal NLL (used for convergence and by the
//!   AP/NAP penalty schemes on neighbour estimates);
//! * `estep_z` — L1 kernel extracting posterior latents (final structure).
//!
//! `XlaBackend` executes the AOT artifacts through the PJRT CPU client
//! (`xla` crate), compiled lazily and cached per artifact name. It is
//! gated behind the off-by-default `xla` cargo feature so the default
//! build needs no registry access (the offline environment cannot fetch
//! crates); [`NativeBackend`] dispatches to [`crate::dppca::em`] and both
//! must agree to ≲1e-9 (asserted in `rust/tests/integration_runtime.rs`,
//! which only runs under `--features xla`).

mod artifact;
mod native;
#[cfg(feature = "xla")]
mod xla_backend;

pub use artifact::{ArtifactEntry, Manifest};
pub use native::NativeBackend;
#[cfg(feature = "xla")]
pub use xla_backend::XlaBackend;

use crate::dppca::{Moments, PpcaParams};
use crate::error::Result;
use crate::linalg::Mat;

/// A D-PPCA compute backend (object-safe; shared by nodes via `Rc`).
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Masked raw moments of a (D, N) block.
    fn moments(&mut self, x: &Mat, mask: &[f64]) -> Result<Moments>;

    /// One EM + consensus M-step from cached moments.
    /// Returns the new parameters and their marginal NLL.
    fn node_update(&mut self, mom: &Moments, params: &PpcaParams,
                   mult: &PpcaParams, eta_sum: f64, eta_w: &PpcaParams)
                   -> Result<(PpcaParams, f64)>;

    /// Direct path: the same update recomputing moments from raw data
    /// (the faithful per-iteration cost model; see DESIGN.md §1).
    fn node_update_direct(&mut self, x: &Mat, mask: &[f64], params: &PpcaParams,
                          mult: &PpcaParams, eta_sum: f64, eta_w: &PpcaParams)
                          -> Result<(PpcaParams, f64)> {
        let mom = self.moments(x, mask)?;
        self.node_update(&mom, params, mult, eta_sum, eta_w)
    }

    /// Marginal NLL of arbitrary parameters against the node's moments.
    fn objective(&mut self, mom: &Moments, params: &PpcaParams) -> Result<f64>;

    /// Score many parameter sets against one node's moments. The XLA
    /// backend folds the whole batch into a single PJRT dispatch (the
    /// dominant cost for the AP/NAP schemes — EXPERIMENTS.md §Perf); the
    /// default just loops.
    fn objective_batch(&mut self, mom: &Moments, params: &[PpcaParams])
                       -> Result<Vec<f64>> {
        params.iter().map(|p| self.objective(mom, p)).collect()
    }

    /// Posterior latent means (M, N); masked columns zero.
    fn estep_z(&mut self, x: &Mat, mask: &[f64], params: &PpcaParams) -> Result<Mat>;
}

/// Shared, interiorly mutable backend handle used by per-node solvers.
pub type SharedBackend = std::rc::Rc<std::cell::RefCell<dyn Backend>>;

/// Wrap a backend for sharing across the nodes of one engine.
pub fn shared(backend: impl Backend + 'static) -> SharedBackend {
    std::rc::Rc::new(std::cell::RefCell::new(backend))
}
