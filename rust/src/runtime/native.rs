//! Pure-Rust backend: dispatches straight to [`crate::dppca::em`].

use super::Backend;
use crate::dppca::{em, Moments, PpcaParams};
use crate::error::Result;
use crate::linalg::Mat;

/// Artifact-free backend implementing the identical math in Rust.
///
/// Used for: tests without `make artifacts`, the threaded coordinator
/// (PJRT handles are not `Send`), and cross-validation of the artifacts.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        NativeBackend
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn moments(&mut self, x: &Mat, mask: &[f64]) -> Result<Moments> {
        Ok(em::moments(x, mask))
    }

    fn node_update(&mut self, mom: &Moments, params: &PpcaParams,
                   mult: &PpcaParams, eta_sum: f64, eta_w: &PpcaParams)
                   -> Result<(PpcaParams, f64)> {
        em::node_update(mom, params, mult, eta_sum, eta_w)
    }

    fn objective(&mut self, mom: &Moments, params: &PpcaParams) -> Result<f64> {
        em::marginal_nll(mom, params)
    }

    fn estep_z(&mut self, x: &Mat, mask: &[f64], params: &PpcaParams) -> Result<Mat> {
        em::estep_z(x, mask, params)
    }
}
