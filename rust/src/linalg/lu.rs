//! LU factorization with partial pivoting (general square systems).

use super::Mat;
use crate::error::{Error, Result};

/// Packed LU factors with a row-permutation vector.
#[derive(Debug, Clone)]
pub struct Lu {
    lu: Mat,
    perm: Vec<usize>,
    sign: f64,
}

impl Lu {
    /// Factor a square matrix; fails if (numerically) singular.
    pub fn new(a: &Mat) -> Result<Lu> {
        let n = a.rows();
        if a.cols() != n {
            return Err(Error::Shape("lu: matrix not square".into()));
        }
        let mut lu = a.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for k in 0..n {
            // partial pivot
            let mut p = k;
            let mut best = lu[(k, k)].abs();
            for r in (k + 1)..n {
                let v = lu[(r, k)].abs();
                if v > best {
                    best = v;
                    p = r;
                }
            }
            if best < 1e-300 {
                return Err(Error::Numeric(format!("lu: singular at column {k}")));
            }
            if p != k {
                perm.swap(p, k);
                sign = -sign;
                for c in 0..n {
                    let tmp = lu[(k, c)];
                    lu[(k, c)] = lu[(p, c)];
                    lu[(p, c)] = tmp;
                }
            }
            let pivot = lu[(k, k)];
            for r in (k + 1)..n {
                let factor = lu[(r, k)] / pivot;
                lu[(r, k)] = factor;
                for c in (k + 1)..n {
                    let sub = factor * lu[(k, c)];
                    lu[(r, c)] -= sub;
                }
            }
        }
        Ok(Lu { lu, perm, sign })
    }

    /// Solve `A x = b`.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows();
        debug_assert_eq!(b.len(), n);
        let mut y: Vec<f64> = self.perm.iter().map(|&p| b[p]).collect();
        // forward substitution (unit lower)
        for i in 0..n {
            for k in 0..i {
                y[i] -= self.lu[(i, k)] * y[k];
            }
        }
        // backward substitution
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                y[i] -= self.lu[(i, k)] * y[k];
            }
            y[i] /= self.lu[(i, i)];
        }
        y
    }

    /// Solve for a matrix right-hand side.
    pub fn solve(&self, b: &Mat) -> Mat {
        let mut out = Mat::zeros(b.rows(), b.cols());
        for c in 0..b.cols() {
            out.set_col(c, &self.solve_vec(&b.col(c)));
        }
        out
    }

    /// A⁻¹.
    pub fn inverse(&self) -> Mat {
        self.solve(&Mat::eye(self.lu.rows()))
    }

    /// Determinant.
    pub fn det(&self) -> f64 {
        let mut d = self.sign;
        for i in 0..self.lu.rows() {
            d *= self.lu[(i, i)];
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn solves_random_systems() {
        prop::check("LU solve", |rng| {
            let n = 1 + rng.below(8);
            let a = Mat::randn(n, n, rng);
            // regularize so random matrices are safely invertible
            let mut a = a;
            for i in 0..n {
                a[(i, i)] += 3.0;
            }
            let x_true = rng.normal_vec(n);
            let b = a.matvec(&x_true);
            let x = Lu::new(&a).unwrap().solve_vec(&b);
            for (u, v) in x.iter().zip(&x_true) {
                assert!((u - v).abs() < 1e-8);
            }
        });
    }

    #[test]
    fn inverse_roundtrip() {
        prop::check("A A⁻¹ = I (LU)", |rng| {
            let n = 1 + rng.below(7);
            let mut a = Mat::randn(n, n, rng);
            for i in 0..n {
                a[(i, i)] += 3.0;
            }
            let inv = Lu::new(&a).unwrap().inverse();
            assert!(a.matmul(&inv).max_abs_diff(&Mat::eye(n)) < 1e-8);
        });
    }

    #[test]
    fn det_2x2() {
        let a = Mat::from_rows(2, 2, &[3.0, 1.0, 4.0, 2.0]);
        assert!((Lu::new(&a).unwrap().det() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn det_permutation_sign() {
        // row-swapped identity has det −1
        let a = Mat::from_rows(2, 2, &[0.0, 1.0, 1.0, 0.0]);
        assert!((Lu::new(&a).unwrap().det() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_singular() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 2.0, 4.0]);
        assert!(Lu::new(&a).is_err());
    }
}
