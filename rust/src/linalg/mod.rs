//! Dense linear algebra substrate.
//!
//! No LA crates are available in the offline build, so the small-matrix
//! routines the system needs are implemented here from scratch: a dense
//! row-major [`Mat`], Cholesky and LU factorizations, Householder QR,
//! one-sided Jacobi SVD and principal (subspace) angles. Dimensions in
//! this project are modest (D ≤ 150), so clarity and numerical robustness
//! win over blocking/SIMD; the optimization-path hot spots live in the
//! lowered XLA artifacts, not here.

mod chol;
mod lu;
mod mat;
mod qr;
mod subspace;
mod svd;

pub use chol::Cholesky;
pub use lu::Lu;
pub use mat::Mat;
pub use qr::qr_thin;
pub use subspace::{max_principal_angle_deg, principal_angles};
pub use svd::Svd;
