//! Singular value decomposition via one-sided Jacobi rotations.
//!
//! One-sided Jacobi is simple, unconditionally convergent and highly
//! accurate for the moderate sizes used here (centralized SfM baselines,
//! principal angles: at most a few hundred rows, ≤ a few hundred columns).

use super::Mat;
use crate::error::{Error, Result};

/// Thin SVD `A = U Σ Vᵀ` with singular values sorted descending.
#[derive(Debug, Clone)]
pub struct Svd {
    /// (m, k) orthonormal left vectors, k = min(m, n).
    pub u: Mat,
    /// k singular values, descending.
    pub s: Vec<f64>,
    /// (n, k) orthonormal right vectors.
    pub v: Mat,
}

const MAX_SWEEPS: usize = 60;

impl Svd {
    /// Compute the thin SVD.
    pub fn new(a: &Mat) -> Result<Svd> {
        let (m, n) = a.shape();
        if m >= n {
            Self::tall(a)
        } else {
            // A = UΣVᵀ  ⇔  Aᵀ = VΣUᵀ
            let t = Self::tall(&a.t())?;
            Ok(Svd { u: t.v, s: t.s, v: t.u })
        }
    }

    fn tall(a: &Mat) -> Result<Svd> {
        let (m, n) = a.shape();
        debug_assert!(m >= n);
        let mut u = a.clone(); // columns become U·Σ
        let mut v = Mat::eye(n);

        let eps = 1e-15;
        let mut converged = false;
        for _sweep in 0..MAX_SWEEPS {
            let mut off = 0.0f64;
            for p in 0..n {
                for q in (p + 1)..n {
                    // 2x2 Gram block of columns p, q
                    let mut app = 0.0;
                    let mut aqq = 0.0;
                    let mut apq = 0.0;
                    for r in 0..m {
                        let up = u[(r, p)];
                        let uq = u[(r, q)];
                        app += up * up;
                        aqq += uq * uq;
                        apq += up * uq;
                    }
                    if apq.abs() <= eps * (app * aqq).sqrt() + 1e-300 {
                        continue;
                    }
                    off = off.max(apq.abs() / ((app * aqq).sqrt() + 1e-300));
                    // Jacobi rotation annihilating the off-diagonal
                    let zeta = (aqq - app) / (2.0 * apq);
                    let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                    let c = 1.0 / (1.0 + t * t).sqrt();
                    let s = c * t;
                    for r in 0..m {
                        let up = u[(r, p)];
                        let uq = u[(r, q)];
                        u[(r, p)] = c * up - s * uq;
                        u[(r, q)] = s * up + c * uq;
                    }
                    for r in 0..n {
                        let vp = v[(r, p)];
                        let vq = v[(r, q)];
                        v[(r, p)] = c * vp - s * vq;
                        v[(r, q)] = s * vp + c * vq;
                    }
                }
            }
            if off < 1e-14 {
                converged = true;
                break;
            }
        }
        if !converged {
            return Err(Error::Numeric("svd: jacobi sweeps did not converge".into()));
        }

        // extract singular values, normalize U, sort descending
        let mut order: Vec<usize> = (0..n).collect();
        let mut sigmas = vec![0.0f64; n];
        for (j, sig) in sigmas.iter_mut().enumerate() {
            *sig = super::mat::dot(&u.col(j), &u.col(j)).sqrt();
        }
        order.sort_by(|&i, &j| sigmas[j].partial_cmp(&sigmas[i]).unwrap());

        let mut u_out = Mat::zeros(m, n);
        let mut v_out = Mat::zeros(n, n);
        let mut s_out = vec![0.0f64; n];
        for (dst, &src) in order.iter().enumerate() {
            let sig = sigmas[src];
            s_out[dst] = sig;
            let ucol = u.col(src);
            if sig > 1e-300 {
                let scaled: Vec<f64> = ucol.iter().map(|x| x / sig).collect();
                u_out.set_col(dst, &scaled);
            } else {
                u_out.set_col(dst, &ucol); // zero column
            }
            v_out.set_col(dst, &v.col(src));
        }
        Ok(Svd { u: u_out, s: s_out, v: v_out })
    }

    /// Rank-k truncation `U_k Σ_k V_kᵀ` of the decomposed matrix.
    pub fn low_rank(&self, k: usize) -> Mat {
        let k = k.min(self.s.len());
        let uk = self.u.col_slice(0, k);
        let vk = self.v.col_slice(0, k);
        let mut us = uk.clone();
        for c in 0..k {
            for r in 0..us.rows() {
                us[(r, c)] *= self.s[c];
            }
        }
        us.matmul_t(&vk)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn reconstructs() {
        prop::check("UΣVᵀ = A", |rng| {
            let m = 1 + rng.below(10);
            let n = 1 + rng.below(10);
            let a = Mat::randn(m, n, rng);
            let svd = Svd::new(&a).unwrap();
            let k = m.min(n);
            let rec = svd.low_rank(k);
            assert!(rec.max_abs_diff(&a) < 1e-9, "m={m} n={n}");
        });
    }

    #[test]
    fn orthonormal_factors() {
        prop::check("UᵀU = VᵀV = I", |rng| {
            let m = 3 + rng.below(8);
            let n = 1 + rng.below(3);
            let a = Mat::randn(m, n, rng);
            let svd = Svd::new(&a).unwrap();
            let k = m.min(n);
            assert!(svd.u.t_matmul(&svd.u).max_abs_diff(&Mat::eye(k)) < 1e-10);
            assert!(svd.v.t_matmul(&svd.v).max_abs_diff(&Mat::eye(k)) < 1e-10);
        });
    }

    #[test]
    fn singular_values_sorted_nonnegative() {
        prop::check("σ sorted desc, ≥ 0", |rng| {
            let a = Mat::randn(6 + rng.below(5), 1 + rng.below(6), rng);
            let svd = Svd::new(&a).unwrap();
            for w in svd.s.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
            assert!(svd.s.iter().all(|&x| x >= 0.0));
        });
    }

    #[test]
    fn known_diagonal() {
        let a = Mat::from_rows(3, 2, &[3.0, 0.0, 0.0, -2.0, 0.0, 0.0]);
        let svd = Svd::new(&a).unwrap();
        assert!((svd.s[0] - 3.0).abs() < 1e-12);
        assert!((svd.s[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn rank_deficient_ok() {
        // duplicate columns → one zero singular value, still decomposes
        let mut rng = crate::util::rng::Pcg::seed(5);
        let base = Mat::randn(6, 1, &mut rng);
        let mut a = Mat::zeros(6, 2);
        a.set_col(0, &base.col(0));
        a.set_col(1, &base.col(0));
        let svd = Svd::new(&a).unwrap();
        assert!(svd.s[1] < 1e-10);
        assert!(svd.low_rank(2).max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn wide_matrix() {
        let mut rng = crate::util::rng::Pcg::seed(6);
        let a = Mat::randn(3, 7, &mut rng);
        let svd = Svd::new(&a).unwrap();
        assert_eq!(svd.u.shape(), (3, 3));
        assert_eq!(svd.v.shape(), (7, 3));
        assert!(svd.low_rank(3).max_abs_diff(&a) < 1e-9);
    }
}
