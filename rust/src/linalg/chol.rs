//! Cholesky factorization of SPD matrices.

use super::Mat;
use crate::error::{Error, Result};

/// Lower-triangular Cholesky factor `L` with `A = L Lᵀ`.
#[derive(Debug, Clone)]
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factor an SPD matrix; fails on non-positive pivots.
    pub fn new(a: &Mat) -> Result<Cholesky> {
        let mut l = Mat::zeros(a.rows(), a.rows());
        Cholesky::factor_into(a, &mut l)?;
        Ok(Cholesky { l })
    }

    /// Factor an SPD matrix into a caller-owned `n × n` scratch matrix —
    /// the allocation-free path behind [`Cholesky::new`], for hot loops
    /// that refactor a same-shape system every iteration. Only the lower
    /// triangle of `l` is written; stale upper-triangle entries from a
    /// previous factorization are never read, neither here nor by
    /// [`Cholesky::solve_in_place`].
    pub fn factor_into(a: &Mat, l: &mut Mat) -> Result<()> {
        let n = a.rows();
        if a.cols() != n {
            return Err(Error::Shape("cholesky: matrix not square".into()));
        }
        assert_eq!(l.shape(), (n, n), "cholesky: scratch factor shape");
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 || !sum.is_finite() {
                        return Err(Error::Numeric(format!(
                            "cholesky: non-positive pivot {sum:.3e} at {i}"
                        )));
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Ok(())
    }

    /// The factor L.
    pub fn l(&self) -> &Mat {
        &self.l
    }

    /// Solve `A x = b` in place against a factor written by
    /// [`Cholesky::factor_into`]: `x` enters holding `b` and leaves
    /// holding `A⁻¹ b`. Allocation-free.
    pub fn solve_in_place(l: &Mat, x: &mut [f64]) {
        let n = l.rows();
        debug_assert_eq!(x.len(), n);
        // forward: L y = b
        for i in 0..n {
            for k in 0..i {
                x[i] -= l[(i, k)] * x[k];
            }
            x[i] /= l[(i, i)];
        }
        // backward: Lᵀ x = y
        for i in (0..n).rev() {
            for k in (i + 1)..n {
                x[i] -= l[(k, i)] * x[k];
            }
            x[i] /= l[(i, i)];
        }
    }

    /// Solve `A x = b` for one right-hand side.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        Cholesky::solve_in_place(&self.l, &mut x);
        x
    }

    /// Solve `A X = B` column-wise.
    pub fn solve(&self, b: &Mat) -> Mat {
        let mut out = Mat::zeros(b.rows(), b.cols());
        for c in 0..b.cols() {
            out.set_col(c, &self.solve_vec(&b.col(c)));
        }
        out
    }

    /// A⁻¹ (via n solves against identity).
    pub fn inverse(&self) -> Mat {
        self.solve(&Mat::eye(self.l.rows()))
    }

    /// log det A = 2 Σ log L_ii.
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn random_spd(n: usize, rng: &mut crate::util::rng::Pcg) -> Mat {
        let b = Mat::randn(n, n, rng);
        let mut spd = b.matmul_t(&b);
        for i in 0..n {
            spd[(i, i)] += n as f64;
        }
        spd
    }

    #[test]
    fn reconstructs() {
        prop::check("L Lᵀ = A", |rng| {
            let n = 1 + rng.below(8);
            let a = random_spd(n, rng);
            let ch = Cholesky::new(&a).unwrap();
            let rec = ch.l().matmul_t(ch.l());
            assert!(rec.max_abs_diff(&a) < 1e-9 * (n as f64));
        });
    }

    #[test]
    fn solve_inverts() {
        prop::check("A·solve(A,b) = b", |rng| {
            let n = 1 + rng.below(8);
            let a = random_spd(n, rng);
            let b = rng.normal_vec(n);
            let x = Cholesky::new(&a).unwrap().solve_vec(&b);
            let back = a.matvec(&x);
            for (u, v) in back.iter().zip(&b) {
                assert!((u - v).abs() < 1e-8, "{u} vs {v}");
            }
        });
    }

    #[test]
    fn inverse_is_inverse() {
        prop::check("A A⁻¹ = I", |rng| {
            let n = 1 + rng.below(7);
            let a = random_spd(n, rng);
            let inv = Cholesky::new(&a).unwrap().inverse();
            assert!(a.matmul(&inv).max_abs_diff(&Mat::eye(n)) < 1e-8);
        });
    }

    #[test]
    fn factor_into_reuses_scratch_bitwise() {
        // the hot-loop path must match the allocating path exactly, even
        // when the scratch factor carries a previous factorization
        prop::check("repeated factor_into ≡ fresh Cholesky::new", |rng| {
            let n = 1 + rng.below(8);
            let mut l = Mat::zeros(n, n);
            for _ in 0..3 {
                let a = random_spd(n, rng);
                Cholesky::factor_into(&a, &mut l).unwrap();
                let fresh = Cholesky::new(&a).unwrap();
                assert_eq!(l.data(), fresh.l().data());
                let b = rng.normal_vec(n);
                let mut x = b.clone();
                Cholesky::solve_in_place(&l, &mut x);
                assert_eq!(x, fresh.solve_vec(&b));
            }
        });
    }

    #[test]
    fn logdet_matches_2x2() {
        let a = Mat::from_rows(2, 2, &[4.0, 1.0, 1.0, 3.0]);
        let ld = Cholesky::new(&a).unwrap().logdet();
        assert!((ld - (11.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, −1
        assert!(Cholesky::new(&a).is_err());
    }

    #[test]
    fn rejects_non_square() {
        assert!(Cholesky::new(&Mat::zeros(2, 3)).is_err());
    }
}
