//! Thin QR via modified Gram-Schmidt with reorthogonalization.
//!
//! Used to orthonormalize subspace bases before principal-angle
//! computation. MGS with one reorthogonalization pass is numerically
//! equivalent to Householder for the well-conditioned tall-skinny bases
//! this project produces (D×M with M ≤ 5).

use super::Mat;
use crate::error::{Error, Result};

/// Thin QR: returns (Q, R) with Q of shape (m, k) orthonormal columns and
/// R (k, k) upper triangular, where k = rank-checked `a.cols()`.
pub fn qr_thin(a: &Mat) -> Result<(Mat, Mat)> {
    let (m, n) = a.shape();
    if m < n {
        return Err(Error::Shape(format!("qr_thin: need rows ≥ cols, got {m}x{n}")));
    }
    let mut q = a.clone();
    let mut r = Mat::zeros(n, n);
    for j in 0..n {
        let mut v = q.col(j);
        // two MGS passes ("twice is enough", Kahan)
        for _pass in 0..2 {
            for i in 0..j {
                let qi = q.col(i);
                let proj = super::mat::dot(&qi, &v);
                r[(i, j)] += proj;
                for (vk, qk) in v.iter_mut().zip(&qi) {
                    *vk -= proj * qk;
                }
            }
        }
        let norm = super::mat::dot(&v, &v).sqrt();
        if norm < 1e-12 {
            return Err(Error::Numeric(format!("qr_thin: rank deficient at column {j}")));
        }
        r[(j, j)] = norm;
        for vk in v.iter_mut() {
            *vk /= norm;
        }
        q.set_col(j, &v);
    }
    Ok((q, r))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn qr_reconstructs_and_orthonormal() {
        prop::check("QR = A, QᵀQ = I", |rng| {
            let n = 1 + rng.below(4);
            let m = n + rng.below(8);
            let a = Mat::randn(m, n, rng);
            let (q, r) = qr_thin(&a).unwrap();
            assert!(q.matmul(&r).max_abs_diff(&a) < 1e-10);
            assert!(q.t_matmul(&q).max_abs_diff(&Mat::eye(n)) < 1e-12);
            // R upper triangular
            for i in 0..n {
                for j in 0..i {
                    assert_eq!(r[(i, j)], 0.0);
                }
            }
        });
    }

    #[test]
    fn rejects_rank_deficient() {
        let mut a = Mat::zeros(4, 2);
        for i in 0..4 {
            a[(i, 0)] = (i + 1) as f64;
            a[(i, 1)] = 2.0 * (i + 1) as f64; // parallel column
        }
        assert!(qr_thin(&a).is_err());
    }

    #[test]
    fn rejects_wide() {
        assert!(qr_thin(&Mat::zeros(2, 3)).is_err());
    }
}
