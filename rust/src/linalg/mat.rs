//! Dense row-major matrix.

use std::fmt;
use std::ops::{Add, AddAssign, Index, IndexMut, Mul, Neg, Sub, SubAssign};

use crate::error::{Error, Result};
use crate::util::rng::Pcg;

/// Dense f64 matrix, row-major storage.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major slice.
    pub fn from_rows(rows: usize, cols: usize, data: &[f64]) -> Mat {
        assert_eq!(data.len(), rows * cols, "from_rows: length mismatch");
        Mat { rows, cols, data: data.to_vec() }
    }

    /// Take ownership of a row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols, "from_vec: length mismatch");
        Mat { rows, cols, data }
    }

    /// Column vector from a slice.
    pub fn col_vec(data: &[f64]) -> Mat {
        Mat::from_rows(data.len(), 1, data)
    }

    /// Matrix of i.i.d. standard normals.
    pub fn randn(rows: usize, cols: usize, rng: &mut Pcg) -> Mat {
        Mat { rows, cols, data: rng.normal_vec(rows * cols) }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Row-major data slice.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// One row as a slice.
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy of column `c`.
    pub fn col(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    /// Set column `c` from a slice.
    pub fn set_col(&mut self, c: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for r in 0..self.rows {
            self[(r, c)] = v[r];
        }
    }

    /// Transpose.
    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)];
            }
        }
        out
    }

    /// Matrix product `self * other`.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(
            self.cols, other.rows,
            "matmul: {}x{} * {}x{}",
            self.rows, self.cols, other.rows, other.cols
        );
        let mut out = Mat::zeros(self.rows, other.cols);
        // ikj loop order: stream over `other` rows for cache friendliness
        for i in 0..self.rows {
            let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for k in 0..self.cols {
                let aik = self.data[i * self.cols + k];
                if aik == 0.0 {
                    continue;
                }
                let brow = &other.data[k * other.cols..(k + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(brow) {
                    *o += aik * b;
                }
            }
        }
        out
    }

    /// `selfᵀ * other` without materializing the transpose.
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul shape");
        let mut out = Mat::zeros(self.cols, other.cols);
        for k in 0..self.rows {
            let arow = self.row(k);
            let brow = other.row(k);
            for (i, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// `self * otherᵀ` without materializing the transpose.
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t shape");
        let mut out = Mat::zeros(self.rows, other.rows);
        for i in 0..self.rows {
            let arow = self.row(i);
            for j in 0..other.rows {
                let brow = other.row(j);
                out[(i, j)] = dot(arow, brow);
            }
        }
        out
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec shape");
        (0..self.rows).map(|r| dot(self.row(r), v)).collect()
    }

    /// `selfᵀ v` without materializing the transpose.
    pub fn t_matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len(), "t_matvec shape");
        let mut out = vec![0.0; self.cols];
        for r in 0..self.rows {
            let row = self.row(r);
            let vr = v[r];
            for (o, &x) in out.iter_mut().zip(row) {
                *o += vr * x;
            }
        }
        out
    }

    /// Outer product of two vectors.
    pub fn outer(u: &[f64], v: &[f64]) -> Mat {
        let mut m = Mat::zeros(u.len(), v.len());
        for (i, &ui) in u.iter().enumerate() {
            for (j, &vj) in v.iter().enumerate() {
                m[(i, j)] = ui * vj;
            }
        }
        m
    }

    /// Scale in place.
    pub fn scale(&self, s: f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    /// Add `s * other` into self (axpy).
    pub fn axpy(&mut self, s: f64, other: &Mat) {
        assert_eq!(self.shape(), other.shape(), "axpy shape");
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += s * b;
        }
    }

    /// Trace.
    pub fn trace(&self) -> f64 {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).sum()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Frobenius inner product ⟨self, other⟩.
    pub fn fro_dot(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape(), "fro_dot shape");
        dot(&self.data, &other.data)
    }

    /// Max |a_ij − b_ij|.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Columns `lo..hi` as a new matrix.
    pub fn col_slice(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.cols);
        let mut out = Mat::zeros(self.rows, hi - lo);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[lo..hi]);
        }
        out
    }

    /// Rows `lo..hi` as a new matrix.
    pub fn row_slice(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.rows);
        Mat::from_rows(hi - lo, self.cols, &self.data[lo * self.cols..hi * self.cols])
    }

    /// Stack two matrices vertically.
    pub fn vstack(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "vstack shape");
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Mat::from_vec(self.rows + other.rows, self.cols, data)
    }

    /// Symmetrize: (A + Aᵀ)/2.
    pub fn symmetrize(&self) -> Mat {
        assert_eq!(self.rows, self.cols);
        let mut out = self.clone();
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                out[(i, j)] = v;
                out[(j, i)] = v;
            }
        }
        out
    }

    /// Check all entries are finite.
    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Error unless shapes match (library-boundary validation).
    pub fn expect_shape(&self, rows: usize, cols: usize, what: &str) -> Result<()> {
        if self.shape() != (rows, cols) {
            return Err(Error::Shape(format!(
                "{what}: expected {rows}x{cols}, got {}x{}",
                self.rows, self.cols
            )));
        }
        Ok(())
    }
}

/// Dot product of two slices.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

impl Add for &Mat {
    type Output = Mat;
    fn add(self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape(), "add shape");
        let mut out = self.clone();
        out.axpy(1.0, other);
        out
    }
}

impl Sub for &Mat {
    type Output = Mat;
    fn sub(self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape(), "sub shape");
        let mut out = self.clone();
        out.axpy(-1.0, other);
        out
    }
}

impl AddAssign<&Mat> for Mat {
    fn add_assign(&mut self, other: &Mat) {
        self.axpy(1.0, other);
    }
}

impl SubAssign<&Mat> for Mat {
    fn sub_assign(&mut self, other: &Mat) {
        self.axpy(-1.0, other);
    }
}

impl Mul<&Mat> for &Mat {
    type Output = Mat;
    fn mul(self, other: &Mat) -> Mat {
        self.matmul(other)
    }
}

impl Neg for &Mat {
    type Output = Mat;
    fn neg(self) -> Mat {
        self.scale(-1.0)
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for r in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for c in 0..self.cols.min(8) {
                write!(f, "{:>10.4} ", self[(r, c)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_rows(2, 2, &[1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_involution() {
        prop::check("(Aᵀ)ᵀ = A", |rng| {
            let (r, c) = (1 + rng.below(6), 1 + rng.below(6));
            let a = Mat::randn(r, c, rng);
            assert_eq!(a.t().t(), a);
        });
    }

    #[test]
    fn t_matmul_matches_explicit() {
        prop::check("AᵀB fused = explicit", |rng| {
            let (r, c1, c2) = (1 + rng.below(5), 1 + rng.below(5), 1 + rng.below(5));
            let a = Mat::randn(r, c1, rng);
            let b = Mat::randn(r, c2, rng);
            assert!(a.t_matmul(&b).max_abs_diff(&a.t().matmul(&b)) < 1e-12);
        });
    }

    #[test]
    fn matmul_t_matches_explicit() {
        prop::check("ABᵀ fused = explicit", |rng| {
            let (r1, c, r2) = (1 + rng.below(5), 1 + rng.below(5), 1 + rng.below(5));
            let a = Mat::randn(r1, c, rng);
            let b = Mat::randn(r2, c, rng);
            assert!(a.matmul_t(&b).max_abs_diff(&a.matmul(&b.t())) < 1e-12);
        });
    }

    #[test]
    fn matvec_matches_matmul() {
        prop::check("Av = A·[v]", |rng| {
            let (r, c) = (1 + rng.below(6), 1 + rng.below(6));
            let a = Mat::randn(r, c, rng);
            let v = rng.normal_vec(c);
            let got = a.matvec(&v);
            let want = a.matmul(&Mat::col_vec(&v));
            for (i, g) in got.iter().enumerate() {
                assert!((g - want[(i, 0)]).abs() < 1e-12);
            }
        });
    }

    #[test]
    fn t_matvec_matches() {
        prop::check("Aᵀv fused", |rng| {
            let (r, c) = (1 + rng.below(6), 1 + rng.below(6));
            let a = Mat::randn(r, c, rng);
            let v = rng.normal_vec(r);
            let got = a.t_matvec(&v);
            let want = a.t().matvec(&v);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-12);
            }
        });
    }

    #[test]
    fn associativity_within_tolerance() {
        prop::check("(AB)C ≈ A(BC)", |rng| {
            let n = 1 + rng.below(5);
            let a = Mat::randn(n, n, rng);
            let b = Mat::randn(n, n, rng);
            let c = Mat::randn(n, n, rng);
            let lhs = a.matmul(&b).matmul(&c);
            let rhs = a.matmul(&b.matmul(&c));
            assert!(lhs.max_abs_diff(&rhs) < 1e-10);
        });
    }

    #[test]
    fn slices_and_stack() {
        let a = Mat::from_rows(3, 2, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.row_slice(1, 3).data(), &[3., 4., 5., 6.]);
        assert_eq!(a.col_slice(1, 2).data(), &[2., 4., 6.]);
        let b = a.row_slice(0, 1).vstack(&a.row_slice(2, 3));
        assert_eq!(b.data(), &[1., 2., 5., 6.]);
    }

    #[test]
    fn outer_and_trace() {
        let m = Mat::outer(&[1.0, 2.0], &[3.0, 4.0]);
        assert_eq!(m.data(), &[3.0, 4.0, 6.0, 8.0]);
        assert_eq!(Mat::eye(4).trace(), 4.0);
    }

    #[test]
    fn symmetrize_is_symmetric() {
        prop::check("symmetrize", |rng| {
            let n = 1 + rng.below(6);
            let s = Mat::randn(n, n, rng).symmetrize();
            assert!(s.max_abs_diff(&s.t()) == 0.0);
        });
    }

    #[test]
    fn expect_shape_errors() {
        let a = Mat::zeros(2, 3);
        assert!(a.expect_shape(2, 3, "ok").is_ok());
        assert!(a.expect_shape(3, 2, "bad").is_err());
    }
}
