//! Principal angles between subspaces — the paper's error metric.
//!
//! The paper measures "maximum subspace angle" between each node's
//! projection matrix and the ground truth (Fig. 2, 3, 5). The angles
//! between span(A) and span(B) are arccos of the singular values of
//! Q_AᵀQ_B where Q_* are orthonormal bases.

use super::{qr_thin, Mat, Svd};
use crate::error::Result;

/// All principal angles (radians, ascending) between span(a) and span(b).
pub fn principal_angles(a: &Mat, b: &Mat) -> Result<Vec<f64>> {
    let (qa, _) = qr_thin(a)?;
    let (qb, _) = qr_thin(b)?;
    let m = qa.t_matmul(&qb);
    let svd = Svd::new(&m)?;
    // σ ∈ [0, 1] up to rounding; clamp before arccos
    let mut angles: Vec<f64> = svd
        .s
        .iter()
        .map(|&sig| sig.clamp(-1.0, 1.0).acos())
        .collect();
    angles.sort_by(|x, y| x.partial_cmp(y).unwrap());
    Ok(angles)
}

/// Maximum principal angle in degrees (the paper's reported scalar).
pub fn max_principal_angle_deg(a: &Mat, b: &Mat) -> Result<f64> {
    let angles = principal_angles(a, b)?;
    Ok(angles.last().copied().unwrap_or(0.0).to_degrees())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Pcg;

    #[test]
    fn same_subspace_zero_angle() {
        prop::check("angle(span A, span A·R) = 0", |rng| {
            let a = Mat::randn(8 + rng.below(5), 1 + rng.below(3), rng);
            // non-singular recombination spans the same space
            let k = a.cols();
            let mut r = Mat::randn(k, k, rng);
            for i in 0..k {
                r[(i, i)] += 3.0;
            }
            let b = a.matmul(&r);
            let deg = max_principal_angle_deg(&a, &b).unwrap();
            assert!(deg < 1e-5, "angle {deg}");
        });
    }

    #[test]
    fn orthogonal_subspaces_ninety() {
        let mut a = Mat::zeros(4, 1);
        a[(0, 0)] = 1.0;
        let mut b = Mat::zeros(4, 1);
        b[(2, 0)] = 1.0;
        let deg = max_principal_angle_deg(&a, &b).unwrap();
        assert!((deg - 90.0).abs() < 1e-9);
    }

    #[test]
    fn known_angle_2d() {
        // span{e1} vs span{cosθ e1 + sinθ e2}
        let theta = 0.3f64;
        let a = Mat::from_rows(2, 1, &[1.0, 0.0]);
        let b = Mat::from_rows(2, 1, &[theta.cos(), theta.sin()]);
        let deg = max_principal_angle_deg(&a, &b).unwrap();
        assert!((deg - theta.to_degrees()).abs() < 1e-9);
    }

    #[test]
    fn angles_bounded_and_symmetric() {
        prop::check("0 ≤ θ ≤ 90°, θ(A,B) = θ(B,A)", |rng| {
            let d = 6 + rng.below(6);
            let a = Mat::randn(d, 2, rng);
            let b = Mat::randn(d, 2, rng);
            let ab = max_principal_angle_deg(&a, &b).unwrap();
            let ba = max_principal_angle_deg(&b, &a).unwrap();
            assert!((0.0..=90.0 + 1e-9).contains(&ab));
            assert!((ab - ba).abs() < 1e-8);
        });
    }

    #[test]
    fn invariant_to_orthogonal_rotation() {
        let mut rng = Pcg::seed(11);
        let d = 8;
        let a = Mat::randn(d, 3, &mut rng);
        let b = Mat::randn(d, 3, &mut rng);
        let base = max_principal_angle_deg(&a, &b).unwrap();
        // random orthogonal Q via QR of a random matrix
        let (q, _) = qr_thin(&Mat::randn(d, d, &mut rng)).unwrap();
        let rotated = max_principal_angle_deg(&q.matmul(&a), &q.matmul(&b)).unwrap();
        assert!((base - rotated).abs() < 1e-7);
    }
}
