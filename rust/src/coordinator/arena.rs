//! Zero-copy double-buffered parameter arena + the phase barrier.
//!
//! The worker pool exchanges neighbour parameters through two flat
//! scalar buffers per quantity (θ and the directed-edge penalties η)
//! indexed by *epoch parity*: iteration `t` reads the `t % 2` buffer and
//! writes the `(t + 1) % 2` buffer, so a broadcast is just the owner
//! writing its own block — no `Vec` clones, no channels, no staging maps.
//!
//! ## Struct-of-arrays layout and the alignment contract
//!
//! Each quantity is one flat array (struct-of-arrays: all θ together,
//! all η together), addressed through per-node offsets:
//!
//! ```text
//! θ buffer (one of two parities)
//! ┌─ shard 0 ────────────────┐pad┌─ shard 1 ───────────┐pad┌─ …
//! │ θ_0 │ θ_1 │ … │ θ_{k−1}  │▒▒▒│ θ_k │ θ_{k+1} │ …   │▒▒▒│
//! └──────────────────────────┘   └─────────────────────┘
//! 64B-aligned ↑                  64B-aligned ↑
//! ```
//!
//! Buffers are allocated 64-byte aligned ([`RawBuf`]), and
//! [`ParamArena::new_sharded`] pads each *shard's* θ and η block up to
//! the next cache line. Phase A writes are therefore confined to cache
//! lines wholly owned by one worker: two workers never store to the same
//! line (no false sharing), which is what lets the phase-A store
//! bandwidth scale with the worker count at 10^5–10^6 nodes. Padding
//! changes addresses only — never values, never iteration order — so the
//! padded f64 arena is bit-identical to the unpadded one.
//! [`ParamArena::new`] is the single-shard (pad-free) layout the cluster
//! runtime's per-machine arenas use.
//!
//! ## Reduced-precision storage ([`ArenaScalar`])
//!
//! The arena is generic over its storage scalar `P` (default `f64`).
//! With `P = f32` the θ/η *storage* halves, while every kernel operation
//! still runs in f64: blocks are widened on read into per-worker scratch
//! and narrowed on write ([`ArenaScalar::widen`] /
//! [`ArenaScalar::write_through`]). The f64 instantiation compiles to
//! the exact pre-generic code — `widen` returns the arena slice itself
//! and `write_through` hands the solver the arena block — so the default
//! path stays zero-copy and bit-identical. See
//! [`super::runner::Precision`] for when (not) to use f32.
//!
//! ## Safety discipline (why the raw pointers are sound)
//!
//! Every block has exactly one *owner* (the worker whose shard contains
//! the node). The schedule guarantees:
//!
//! * only the owner ever writes a block, and only into the write-parity
//!   buffer of the current phase;
//! * readers only touch the opposite-parity buffer, or the write buffer
//!   *after* the [`PhaseBarrier`] that ends the writing phase;
//! * the barrier is built on `Mutex`/`Condvar`, so every crossing
//!   publishes all prior writes (happens-before) to every reader.
//!
//! Hence no location is ever written concurrently with another access.
//! The accessors are still `unsafe fn`s: the *caller* (the shard loop in
//! [`super::shard`]) is responsible for upholding the schedule.

use std::alloc::{alloc_zeroed, dealloc, Layout};
use std::ops::Range;
use std::sync::{Condvar, Mutex};

use crate::graph::{Graph, NodeId};

/// Cache-line size the arena aligns and pads to.
pub const CACHE_LINE: usize = 64;

fn align_up(x: usize, unit: usize) -> usize {
    x.div_ceil(unit) * unit
}

/// Storage scalar for [`ParamArena`]: `f64` (default, bit-identical,
/// zero-copy) or `f32` (half the parameter footprint; kernel arithmetic
/// stays f64 through widen/narrow at the arena boundary).
///
/// Contract: the all-zero *bit pattern* must equal `ZERO` (the arena
/// allocates zeroed pages), and `widen`/`store`/`write_through` must be
/// elementwise `to_f64`/`from_f64` so the two instantiations differ only
/// in storage rounding.
pub trait ArenaScalar: Copy + Send + Sync + 'static {
    /// Additive identity; must be the all-zero bit pattern.
    const ZERO: Self;

    fn from_f64(x: f64) -> Self;
    fn to_f64(self) -> f64;

    /// Widen a stored block for kernel arithmetic. The `f64` impl
    /// returns `src` itself — no copy, bit-identical; `f32` converts
    /// into `scratch` (caller-provided, ≥ `src.len()`).
    fn widen<'a>(src: &'a [Self], scratch: &'a mut [f64]) -> &'a [f64];

    /// Narrow-store kernel-produced f64 values into a stored block.
    fn store(dst: &mut [Self], src: &[f64]);

    /// Run `write` on an f64 view of `block` and persist the result.
    /// The `f64` impl passes `block` directly (in place, zero-copy);
    /// `f32` routes through `scratch` and narrows after. `write` must
    /// fully overwrite its argument — pre-existing contents are
    /// unspecified.
    fn write_through(block: &mut [Self], scratch: &mut [f64],
                     write: impl FnOnce(&mut [f64]));
}

impl ArenaScalar for f64 {
    const ZERO: f64 = 0.0;

    #[inline]
    fn from_f64(x: f64) -> f64 {
        x
    }

    #[inline]
    fn to_f64(self) -> f64 {
        self
    }

    #[inline]
    fn widen<'a>(src: &'a [f64], _scratch: &'a mut [f64]) -> &'a [f64] {
        src
    }

    #[inline]
    fn store(dst: &mut [f64], src: &[f64]) {
        dst.copy_from_slice(src);
    }

    #[inline]
    fn write_through(block: &mut [f64], _scratch: &mut [f64],
                     write: impl FnOnce(&mut [f64])) {
        write(block);
    }
}

impl ArenaScalar for f32 {
    const ZERO: f32 = 0.0;

    #[inline]
    fn from_f64(x: f64) -> f32 {
        x as f32
    }

    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline]
    fn widen<'a>(src: &'a [f32], scratch: &'a mut [f64]) -> &'a [f64] {
        let out = &mut scratch[..src.len()];
        for (o, &x) in out.iter_mut().zip(src) {
            *o = x as f64;
        }
        out
    }

    #[inline]
    fn store(dst: &mut [f32], src: &[f64]) {
        debug_assert_eq!(dst.len(), src.len());
        for (o, &x) in dst.iter_mut().zip(src) {
            *o = x as f32;
        }
    }

    #[inline]
    fn write_through(block: &mut [f32], scratch: &mut [f64],
                     write: impl FnOnce(&mut [f64])) {
        let tmp = &mut scratch[..block.len()];
        write(tmp);
        for (o, &x) in block.iter_mut().zip(&*tmp) {
            *o = x as f32;
        }
    }
}

/// A fixed-size 64-byte-aligned heap buffer of `P` shared across workers
/// through raw pointers (see the module docs for the aliasing
/// discipline). Allocated zeroed — `ArenaScalar` requires the all-zero
/// pattern to be `P::ZERO`.
struct RawBuf<P> {
    ptr: *mut P,
    len: usize,
}

// Safety: all access goes through the unsafe accessors below, whose
// contract (owner-writes / parity / barrier) excludes data races.
unsafe impl<P: Send> Send for RawBuf<P> {}
unsafe impl<P: Sync> Sync for RawBuf<P> {}

impl<P: ArenaScalar> RawBuf<P> {
    fn new(len: usize) -> RawBuf<P> {
        if len == 0 {
            // no allocation; the pointer is never dereferenced
            return RawBuf { ptr: std::ptr::NonNull::dangling().as_ptr(), len: 0 };
        }
        let layout = Layout::from_size_align(len * std::mem::size_of::<P>(),
                                             CACHE_LINE)
            .expect("arena: layout overflow");
        // Safety: layout has non-zero size; zeroed bytes are P::ZERO by
        // the ArenaScalar contract.
        let ptr = unsafe { alloc_zeroed(layout) } as *mut P;
        assert!(!ptr.is_null(), "arena: allocation of {} bytes failed",
                layout.size());
        RawBuf { ptr, len }
    }

    /// # Safety
    /// `[lo, hi)` must be in bounds and free of concurrent writers.
    unsafe fn read(&self, lo: usize, hi: usize) -> &[P] {
        debug_assert!(lo <= hi && hi <= self.len);
        std::slice::from_raw_parts(self.ptr.add(lo), hi - lo)
    }

    /// # Safety
    /// `idx` must be in bounds and free of concurrent writers.
    unsafe fn get(&self, idx: usize) -> P {
        debug_assert!(idx < self.len);
        *self.ptr.add(idx)
    }

    /// # Safety
    /// `[lo, hi)` must be in bounds and accessed by no other thread for
    /// the lifetime of the returned slice (exclusive ownership).
    #[allow(clippy::mut_from_ref)]
    unsafe fn write(&self, lo: usize, hi: usize) -> &mut [P] {
        debug_assert!(lo <= hi && hi <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(lo), hi - lo)
    }
}

impl<P> Drop for RawBuf<P> {
    fn drop(&mut self) {
        if self.len > 0 {
            // Safety: same layout as the alloc_zeroed in `new`; Drop runs
            // with exclusive access.
            unsafe {
                dealloc(self.ptr as *mut u8,
                        Layout::from_size_align_unchecked(
                            self.len * std::mem::size_of::<P>(), CACHE_LINE));
            }
        }
    }
}

/// Double-buffered θ / η storage for one run (see module docs).
///
/// Layout: node `i`'s parameters live at `[theta_off[i],
/// theta_off[i] + dim)` in each θ buffer; its out-edge penalties
/// (neighbour-slot order, matching `Graph::neighbors(i)`) live at
/// `[eta_off[i], eta_off[i] + degree(i))` in each η buffer, so η_{i→j}
/// for `j` at slot `s` sits at `eta_index(i, s) = eta_off[i] + s`.
/// Offsets are consecutive except at shard starts, which
/// [`ParamArena::new_sharded`] rounds up to a cache line.
pub struct ParamArena<P: ArenaScalar = f64> {
    dim: usize,
    n: usize,
    theta: [RawBuf<P>; 2],
    eta: [RawBuf<P>; 2],
    theta_off: Vec<usize>,
    eta_off: Vec<usize>,
    deg: Vec<usize>,
}

impl<P: ArenaScalar> ParamArena<P> {
    /// Single-shard layout: dense, no padding (node `i`'s θ at
    /// `i · dim`). Used by the cluster runtime's per-machine arenas,
    /// whose phase-A writers are partitioned by `shard_ranges_in` over
    /// disjoint line-aligned-enough machine slices already.
    pub fn new(graph: &Graph, dim: usize) -> ParamArena<P> {
        Self::new_sharded(graph, dim, &[0..graph.len()])
    }

    /// Shard-aware layout: each range in `ranges` starts on a 64-byte
    /// boundary in both the θ and η buffers, so phase-A/phase-C writes by
    /// different workers never share a cache line. `ranges` must be the
    /// shard partition the run will use (`shard_ranges`' output:
    /// ascending, disjoint). Padding affects addresses only — values and
    /// visit order are unchanged, so this is bit-transparent.
    pub fn new_sharded(graph: &Graph, dim: usize,
                       ranges: &[Range<usize>]) -> ParamArena<P> {
        let n = graph.len();
        let unit = CACHE_LINE / std::mem::size_of::<P>();
        let mut is_start = vec![false; n];
        for r in ranges {
            if r.start < n {
                is_start[r.start] = true;
            }
        }
        let mut theta_off = Vec::with_capacity(n);
        let mut eta_off = Vec::with_capacity(n);
        let mut deg = Vec::with_capacity(n);
        let (mut toff, mut eoff) = (0usize, 0usize);
        for i in 0..n {
            if is_start[i] {
                toff = align_up(toff, unit);
                eoff = align_up(eoff, unit);
            }
            theta_off.push(toff);
            eta_off.push(eoff);
            let d = graph.degree(i);
            deg.push(d);
            toff += dim;
            eoff += d;
        }
        ParamArena {
            dim,
            n,
            theta: [RawBuf::new(toff), RawBuf::new(toff)],
            eta: [RawBuf::new(eoff), RawBuf::new(eoff)],
            theta_off,
            eta_off,
            deg,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Bytes of parameter storage (the four scalar buffers, shard padding
    /// included) — the quantity the f32 path halves exactly.
    pub fn param_bytes(&self) -> usize {
        (2 * self.theta[0].len + 2 * self.eta[0].len) * std::mem::size_of::<P>()
    }

    /// Total heap bytes: parameter buffers plus the per-node
    /// offset/degree index (whose width is scalar-independent).
    pub fn heap_bytes(&self) -> usize {
        self.param_bytes()
            + (self.theta_off.capacity() + self.eta_off.capacity()
               + self.deg.capacity()) * std::mem::size_of::<usize>()
    }

    /// Flat η-buffer index of the directed edge (`i` → its neighbour at
    /// `slot`).
    pub fn eta_index(&self, i: NodeId, slot: usize) -> usize {
        debug_assert!(slot < self.deg[i]);
        self.eta_off[i] + slot
    }

    /// # Safety
    /// No worker may be writing `node`'s θ block in `parity` concurrently.
    pub unsafe fn theta(&self, parity: usize, node: NodeId) -> &[P] {
        let lo = self.theta_off[node];
        self.theta[parity & 1].read(lo, lo + self.dim)
    }

    /// # Safety
    /// Caller must be `node`'s owner, during a phase in which `parity` is
    /// the write buffer.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn theta_mut(&self, parity: usize, node: NodeId) -> &mut [P] {
        let lo = self.theta_off[node];
        self.theta[parity & 1].write(lo, lo + self.dim)
    }

    /// η at a flat index (see [`ParamArena::eta_index`]).
    ///
    /// # Safety
    /// No worker may be writing the `parity` η buffer slot concurrently.
    pub unsafe fn eta(&self, parity: usize, idx: usize) -> P {
        self.eta[parity & 1].get(idx)
    }

    /// `node`'s whole out-edge η block, for publishing.
    ///
    /// # Safety
    /// Caller must be `node`'s owner, during a phase in which `parity` is
    /// the write buffer.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn eta_out_mut(&self, parity: usize, node: NodeId) -> &mut [P] {
        let lo = self.eta_off[node];
        self.eta[parity & 1].write(lo, lo + self.deg[node])
    }
}

/// Error returned by [`PhaseBarrier::wait`] once the barrier is poisoned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Poisoned;

struct BarrierState {
    arrived: usize,
    generation: u64,
    poisoned: bool,
}

/// Reusable rendezvous for the worker pool with explicit poisoning: a
/// panicking worker poisons the barrier instead of leaving its peers
/// blocked forever (std's `Barrier` cannot be interrupted).
pub struct PhaseBarrier {
    n: usize,
    state: Mutex<BarrierState>,
    cv: Condvar,
}

impl PhaseBarrier {
    pub fn new(n: usize) -> PhaseBarrier {
        assert!(n > 0, "barrier needs at least one participant");
        PhaseBarrier {
            n,
            state: Mutex::new(BarrierState { arrived: 0, generation: 0, poisoned: false }),
            cv: Condvar::new(),
        }
    }

    /// Block until all `n` workers arrive (or the barrier is poisoned).
    pub fn wait(&self) -> Result<(), Poisoned> {
        let mut g = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if g.poisoned {
            return Err(Poisoned);
        }
        let gen = g.generation;
        g.arrived += 1;
        if g.arrived == self.n {
            g.arrived = 0;
            g.generation = g.generation.wrapping_add(1);
            self.cv.notify_all();
            return Ok(());
        }
        while g.generation == gen && !g.poisoned {
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
        if g.poisoned { Err(Poisoned) } else { Ok(()) }
    }

    /// Poison the barrier, releasing every current and future waiter with
    /// `Err(Poisoned)`.
    pub fn poison(&self) {
        let mut g = self.state.lock().unwrap_or_else(|e| e.into_inner());
        g.poisoned = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{shard_ranges, Topology};
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn arena_layout_matches_graph() {
        let g = Topology::Star.build(4).unwrap(); // deg: [3, 1, 1, 1]
        let a: ParamArena = ParamArena::new(&g, 2);
        assert_eq!(a.dim(), 2);
        assert_eq!(a.len(), 4);
        assert_eq!(a.eta_index(0, 0), 0);
        assert_eq!(a.eta_index(0, 2), 2);
        assert_eq!(a.eta_index(1, 0), 3);
        assert_eq!(a.eta_index(3, 0), 5);
    }

    #[test]
    fn arena_single_thread_roundtrip() {
        let g = Topology::Ring.build(3).unwrap();
        let a: ParamArena = ParamArena::new(&g, 2);
        unsafe {
            a.theta_mut(0, 1).copy_from_slice(&[1.5, -2.5]);
            a.eta_out_mut(1, 2).copy_from_slice(&[7.0, 8.0]);
            assert_eq!(a.theta(0, 1), &[1.5, -2.5]);
            assert_eq!(a.theta(1, 1), &[0.0, 0.0], "buffers are independent");
            assert_eq!(a.eta(1, a.eta_index(2, 1)), 8.0);
            assert_eq!(a.theta(0, 0), &[0.0, 0.0]);
            assert_eq!(a.theta(0, 2), &[0.0, 0.0]);
        }
    }

    #[test]
    fn sharded_layout_aligns_every_shard_start() {
        let g = Topology::Ring.build(10).unwrap();
        let ranges = shard_ranges(&g, 3);
        let a: ParamArena = ParamArena::new_sharded(&g, 3, &ranges);
        for r in &ranges {
            let t = unsafe { a.theta(0, r.start) }.as_ptr() as usize;
            assert_eq!(t % CACHE_LINE, 0, "θ shard start {r:?}");
            let e = unsafe { a.eta_out_mut(0, r.start) }.as_ptr() as usize;
            assert_eq!(e % CACHE_LINE, 0, "η shard start {r:?}");
        }
        // interior nodes stay dense: blocks inside a shard are contiguous
        let r0 = &ranges[0];
        for i in r0.start..r0.end.saturating_sub(1) {
            let a0 = unsafe { a.theta(0, i) }.as_ptr() as usize;
            let a1 = unsafe { a.theta(0, i + 1) }.as_ptr() as usize;
            assert_eq!(a1 - a0, 3 * std::mem::size_of::<f64>());
        }
    }

    #[test]
    fn single_shard_layout_is_dense() {
        // ParamArena::new (the cluster path) must reproduce the unpadded
        // layout exactly: node i's θ at i·dim, η at the degree prefix sum
        let g = Topology::Star.build(5).unwrap();
        let a: ParamArena = ParamArena::new(&g, 2);
        let base = unsafe { a.theta(0, 0) }.as_ptr() as usize;
        for i in 0..5 {
            let p = unsafe { a.theta(0, i) }.as_ptr() as usize;
            assert_eq!(p - base, i * 2 * std::mem::size_of::<f64>());
        }
        assert_eq!(a.eta_index(1, 0), 4); // after the hub's 4 slots
        assert_eq!(a.param_bytes(), (2 * 10 + 2 * 8) * 8);
    }

    #[test]
    fn f32_arena_roundtrips_and_halves_param_bytes() {
        let g = Topology::Ring.build(8).unwrap();
        let ranges = shard_ranges(&g, 2);
        let a64: ParamArena<f64> = ParamArena::new_sharded(&g, 4, &ranges);
        let a32: ParamArena<f32> = ParamArena::new_sharded(&g, 4, &ranges);
        assert_eq!(a32.param_bytes() * 2, a64.param_bytes(),
                   "f32 halves the parameter footprint exactly");
        let vals = [1.25f64, -0.5, 3.0, 1e-3];
        let mut scratch = [0.0f64; 4];
        unsafe {
            f32::store(a32.theta_mut(0, 5), &vals);
            let wide = f32::widen(a32.theta(0, 5), &mut scratch);
            for (w, v) in wide.iter().zip(&vals) {
                assert!((w - v).abs() <= v.abs() * 1e-6, "{w} vs {v}");
            }
        }
        // write_through narrows exactly like store
        let mut scratch2 = [0.0f64; 4];
        unsafe {
            f32::write_through(a32.theta_mut(1, 5), &mut scratch2,
                               |dst| dst.copy_from_slice(&vals));
            assert_eq!(a32.theta(1, 5), &[1.25f32, -0.5, 3.0, 1e-3 as f32]);
        }
    }

    #[test]
    fn f64_widen_is_zero_copy() {
        let src = [1.0f64, 2.0];
        let mut scratch = [0.0f64; 2];
        let wide = f64::widen(&src, &mut scratch);
        assert_eq!(wide.as_ptr(), src.as_ptr(), "no copy on the f64 path");
    }

    #[test]
    fn barrier_synchronizes_writers_and_readers() {
        let g = Topology::Complete.build(4).unwrap();
        let arena: ParamArena = ParamArena::new(&g, 1);
        let barrier = PhaseBarrier::new(4);
        let hits = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for w in 0..4usize {
                let (arena, barrier, hits) = (&arena, &barrier, &hits);
                s.spawn(move || {
                    for t in 0..50usize {
                        let p = t & 1;
                        unsafe { arena.theta_mut(p ^ 1, w)[0] = (t * 4 + w) as f64 };
                        barrier.wait().unwrap();
                        for peer in 0..4 {
                            let got = unsafe { arena.theta(p ^ 1, peer)[0] };
                            assert_eq!(got, (t * 4 + peer) as f64);
                        }
                        hits.fetch_add(1, Ordering::Relaxed);
                        barrier.wait().unwrap();
                    }
                });
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn poisoned_barrier_releases_waiters() {
        let barrier = PhaseBarrier::new(3);
        std::thread::scope(|s| {
            for _ in 0..2 {
                let barrier = &barrier;
                s.spawn(move || {
                    assert_eq!(barrier.wait(), Err(Poisoned));
                    // and every later wait fails immediately
                    assert_eq!(barrier.wait(), Err(Poisoned));
                });
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
            barrier.poison();
        });
    }
}
