//! Zero-copy double-buffered parameter arena + the phase barrier.
//!
//! The worker pool exchanges neighbour parameters through two flat `f64`
//! buffers per quantity (θ and the directed-edge penalties η) indexed by
//! *epoch parity*: iteration `t` reads the `t % 2` buffer and writes the
//! `(t + 1) % 2` buffer, so a broadcast is just the owner writing its own
//! block — no `Vec` clones, no channels, no staging maps.
//!
//! ## Safety discipline (why the raw pointers are sound)
//!
//! Every block has exactly one *owner* (the worker whose shard contains
//! the node). The schedule guarantees:
//!
//! * only the owner ever writes a block, and only into the write-parity
//!   buffer of the current phase;
//! * readers only touch the opposite-parity buffer, or the write buffer
//!   *after* the [`PhaseBarrier`] that ends the writing phase;
//! * the barrier is built on `Mutex`/`Condvar`, so every crossing
//!   publishes all prior writes (happens-before) to every reader.
//!
//! Hence no location is ever written concurrently with another access.
//! The accessors are still `unsafe fn`s: the *caller* (the shard loop in
//! [`super::shard`]) is responsible for upholding the schedule.

use std::sync::{Condvar, Mutex};

use crate::graph::{Graph, NodeId};

/// A fixed-size heap buffer of `f64` shared across workers through raw
/// pointers (see the module docs for the aliasing discipline).
struct RawBuf {
    ptr: *mut f64,
    len: usize,
}

// Safety: all access goes through the unsafe accessors below, whose
// contract (owner-writes / parity / barrier) excludes data races.
unsafe impl Send for RawBuf {}
unsafe impl Sync for RawBuf {}

impl RawBuf {
    fn new(len: usize) -> RawBuf {
        let boxed: Box<[f64]> = vec![0.0; len].into_boxed_slice();
        RawBuf { ptr: Box::into_raw(boxed) as *mut f64, len }
    }

    /// # Safety
    /// `[lo, hi)` must be in bounds and free of concurrent writers.
    unsafe fn read(&self, lo: usize, hi: usize) -> &[f64] {
        debug_assert!(lo <= hi && hi <= self.len);
        std::slice::from_raw_parts(self.ptr.add(lo), hi - lo)
    }

    /// # Safety
    /// `idx` must be in bounds and free of concurrent writers.
    unsafe fn get(&self, idx: usize) -> f64 {
        debug_assert!(idx < self.len);
        *self.ptr.add(idx)
    }

    /// # Safety
    /// `[lo, hi)` must be in bounds and accessed by no other thread for
    /// the lifetime of the returned slice (exclusive ownership).
    #[allow(clippy::mut_from_ref)]
    unsafe fn write(&self, lo: usize, hi: usize) -> &mut [f64] {
        debug_assert!(lo <= hi && hi <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(lo), hi - lo)
    }
}

impl Drop for RawBuf {
    fn drop(&mut self) {
        // Safety: ptr/len came from Box::into_raw of a Box<[f64]> of
        // exactly this length, and Drop runs with exclusive access.
        unsafe {
            drop(Box::from_raw(std::ptr::slice_from_raw_parts_mut(self.ptr, self.len)));
        }
    }
}

/// Double-buffered θ / η storage for one run (see module docs).
///
/// Layout: node `i`'s parameters live at `[i·dim, (i+1)·dim)` in each θ
/// buffer; its out-edge penalties (neighbour-slot order, matching
/// `Graph::neighbors(i)`) live at `[edge_off[i], edge_off[i+1])` in each
/// η buffer, so η_{i→j} for `j` at slot `s` sits at `edge_off[i] + s`.
pub struct ParamArena {
    dim: usize,
    n: usize,
    theta: [RawBuf; 2],
    eta: [RawBuf; 2],
    edge_off: Vec<usize>,
}

impl ParamArena {
    pub fn new(graph: &Graph, dim: usize) -> ParamArena {
        let n = graph.len();
        let mut edge_off = Vec::with_capacity(n + 1);
        let mut acc = 0usize;
        for i in 0..n {
            edge_off.push(acc);
            acc += graph.degree(i);
        }
        edge_off.push(acc);
        ParamArena {
            dim,
            n,
            theta: [RawBuf::new(n * dim), RawBuf::new(n * dim)],
            eta: [RawBuf::new(acc), RawBuf::new(acc)],
            edge_off,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Flat η-buffer index of the directed edge (`i` → its neighbour at
    /// `slot`).
    pub fn eta_index(&self, i: NodeId, slot: usize) -> usize {
        debug_assert!(self.edge_off[i] + slot < self.edge_off[i + 1]);
        self.edge_off[i] + slot
    }

    /// # Safety
    /// No worker may be writing `node`'s θ block in `parity` concurrently.
    pub unsafe fn theta(&self, parity: usize, node: NodeId) -> &[f64] {
        self.theta[parity & 1].read(node * self.dim, (node + 1) * self.dim)
    }

    /// # Safety
    /// As [`ParamArena::theta`], for the whole buffer (leader fold only,
    /// between the post-stats and post-verdict barriers).
    pub unsafe fn theta_all(&self, parity: usize) -> &[f64] {
        self.theta[parity & 1].read(0, self.n * self.dim)
    }

    /// # Safety
    /// Caller must be `node`'s owner, during a phase in which `parity` is
    /// the write buffer.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn theta_mut(&self, parity: usize, node: NodeId) -> &mut [f64] {
        self.theta[parity & 1].write(node * self.dim, (node + 1) * self.dim)
    }

    /// η at a flat index (see [`ParamArena::eta_index`]).
    ///
    /// # Safety
    /// No worker may be writing the `parity` η buffer slot concurrently.
    pub unsafe fn eta(&self, parity: usize, idx: usize) -> f64 {
        self.eta[parity & 1].get(idx)
    }

    /// `node`'s whole out-edge η block, for publishing.
    ///
    /// # Safety
    /// Caller must be `node`'s owner, during a phase in which `parity` is
    /// the write buffer.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn eta_out_mut(&self, parity: usize, node: NodeId) -> &mut [f64] {
        self.eta[parity & 1].write(self.edge_off[node], self.edge_off[node + 1])
    }
}

/// Error returned by [`PhaseBarrier::wait`] once the barrier is poisoned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Poisoned;

struct BarrierState {
    arrived: usize,
    generation: u64,
    poisoned: bool,
}

/// Reusable rendezvous for the worker pool with explicit poisoning: a
/// panicking worker poisons the barrier instead of leaving its peers
/// blocked forever (std's `Barrier` cannot be interrupted).
pub struct PhaseBarrier {
    n: usize,
    state: Mutex<BarrierState>,
    cv: Condvar,
}

impl PhaseBarrier {
    pub fn new(n: usize) -> PhaseBarrier {
        assert!(n > 0, "barrier needs at least one participant");
        PhaseBarrier {
            n,
            state: Mutex::new(BarrierState { arrived: 0, generation: 0, poisoned: false }),
            cv: Condvar::new(),
        }
    }

    /// Block until all `n` workers arrive (or the barrier is poisoned).
    pub fn wait(&self) -> Result<(), Poisoned> {
        let mut g = self.state.lock().unwrap_or_else(|e| e.into_inner());
        if g.poisoned {
            return Err(Poisoned);
        }
        let gen = g.generation;
        g.arrived += 1;
        if g.arrived == self.n {
            g.arrived = 0;
            g.generation = g.generation.wrapping_add(1);
            self.cv.notify_all();
            return Ok(());
        }
        while g.generation == gen && !g.poisoned {
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
        if g.poisoned { Err(Poisoned) } else { Ok(()) }
    }

    /// Poison the barrier, releasing every current and future waiter with
    /// `Err(Poisoned)`.
    pub fn poison(&self) {
        let mut g = self.state.lock().unwrap_or_else(|e| e.into_inner());
        g.poisoned = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn arena_layout_matches_graph() {
        let g = Topology::Star.build(4).unwrap(); // deg: [3, 1, 1, 1]
        let a = ParamArena::new(&g, 2);
        assert_eq!(a.dim(), 2);
        assert_eq!(a.len(), 4);
        assert_eq!(a.eta_index(0, 0), 0);
        assert_eq!(a.eta_index(0, 2), 2);
        assert_eq!(a.eta_index(1, 0), 3);
        assert_eq!(a.eta_index(3, 0), 5);
    }

    #[test]
    fn arena_single_thread_roundtrip() {
        let g = Topology::Ring.build(3).unwrap();
        let a = ParamArena::new(&g, 2);
        unsafe {
            a.theta_mut(0, 1).copy_from_slice(&[1.5, -2.5]);
            a.eta_out_mut(1, 2).copy_from_slice(&[7.0, 8.0]);
            assert_eq!(a.theta(0, 1), &[1.5, -2.5]);
            assert_eq!(a.theta(1, 1), &[0.0, 0.0], "buffers are independent");
            assert_eq!(a.eta(1, a.eta_index(2, 1)), 8.0);
            assert_eq!(a.theta_all(0), &[0.0, 0.0, 1.5, -2.5, 0.0, 0.0]);
        }
    }

    #[test]
    fn barrier_synchronizes_writers_and_readers() {
        let g = Topology::Complete.build(4).unwrap();
        let arena = ParamArena::new(&g, 1);
        let barrier = PhaseBarrier::new(4);
        let hits = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for w in 0..4usize {
                let (arena, barrier, hits) = (&arena, &barrier, &hits);
                s.spawn(move || {
                    for t in 0..50usize {
                        let p = t & 1;
                        unsafe { arena.theta_mut(p ^ 1, w)[0] = (t * 4 + w) as f64 };
                        barrier.wait().unwrap();
                        for peer in 0..4 {
                            let got = unsafe { arena.theta(p ^ 1, peer)[0] };
                            assert_eq!(got, (t * 4 + peer) as f64);
                        }
                        hits.fetch_add(1, Ordering::Relaxed);
                        barrier.wait().unwrap();
                    }
                });
            }
        });
        assert_eq!(hits.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn poisoned_barrier_releases_waiters() {
        let barrier = PhaseBarrier::new(3);
        std::thread::scope(|s| {
            for _ in 0..2 {
                let barrier = &barrier;
                s.spawn(move || {
                    assert_eq!(barrier.wait(), Err(Poisoned));
                    // and every later wait fails immediately
                    assert_eq!(barrier.wait(), Err(Poisoned));
                });
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
            barrier.poison();
        });
    }
}
