//! Sharded worker-pool distributed runtime.
//!
//! The sequential [`crate::consensus::Engine`] executes the distributed
//! algorithm's exact schedule deterministically (the mode used for the
//! paper-figure experiments, where bit-reproducibility matters). This
//! module runs the *same* per-node program on a fixed pool of
//! `W = min(nodes, cores)` worker threads, each owning a contiguous
//! shard of nodes (degree-weighted split, [`crate::graph::shard_ranges`]).
//!
//! It replaces a thread-per-node design that heap-cloned every θ vector
//! per neighbour per iteration through mpsc channels and staged
//! out-of-order deliveries in per-node `HashMap`s — fine for 8 nodes,
//! hopeless for the hundreds-of-nodes regimes adaptive consensus ADMM is
//! evaluated in. Here a "broadcast" is the owner writing its block of a
//! shared, double-buffered parameter arena; neighbours read it in place.
//!
//! ## Schedule (three barriers per iteration)
//!
//! Iteration `t` reads the parity-`t%2` arena buffers and writes parity
//! `(t+1)%2` (no pointer swap — the parity *is* the swap):
//!
//! ```text
//! phase A  each worker, each owned node i:
//!            solve on θ^t, η^t  →  solve_into writes θ^{t+1} directly
//!            into the node's write-parity arena block (no per-call Vec)
//! ── barrier 1 (epoch swap: every θ^{t+1} visible) ──────────────────────
//! phase B  λ update (symmetrized η̄ from own η^t + arena η^t_{j→i}),
//!          residuals, objectives  →  per-shard partial reduction
//!          (Σf, max‖r‖, max‖s‖, η min/mean/max, Σθ, Σ‖θ−m_shard‖²),
//!          node order
//! ── barrier 2 (all partials published) ─────────────────────────────────
//! fold     worker 0 combines the W partials in shard order — O(W·dim),
//!          global residuals derive from the folded centered statistics
//!          (Chan-style mean/spread combination; no per-node rescan) —
//!          checks convergence, runs the app metric
//! ── barrier 3 (verdict visible) ────────────────────────────────────────
//! phase C  penalty-scheme update → publish η^{t+1}; stop if told to
//! ```
//!
//! The η buffers are double-buffered for the same reason θ is: node i's
//! λ step needs its neighbours' *iteration-t* penalties while those
//! neighbours may already be writing their iteration-`t+1` values.
//!
//! ## Allocation-free hot loop
//!
//! In steady state one full iteration performs **zero heap allocations**
//! (asserted by `bench_coordinator`'s counting allocator): phase A writes
//! through [`crate::consensus::LocalSolver::solve_into`] into the arena,
//! phase B reuses per-worker scratch and per-node buffers, the fold
//! combines fixed-size partials into a pre-sized recorder, and phase C's
//! schemes reuse per-node τ buffers. Handing the arena block to the
//! solver is sound because the `&mut [f64]` aliases nothing the solver
//! can reach: it is the owner's parity-`q` block, written by exactly one
//! worker during phase A while every phase-A *read* (θ^t, λ, scratch)
//! lives in the opposite-parity buffer or in worker-private state, and
//! `solve_into` must fully overwrite it, so stale θ^{t−1} bytes are never
//! observable.
//!
//! ## Locality-aware sharding
//!
//! By default the runner relabels nodes with reverse Cuthill–McKee
//! ([`crate::graph::rcm_order`], [`ShardedConfig::relabel`]) before the
//! contiguous degree-weighted split, so neighbours receive nearby ids and
//! phase-B arena reads stay mostly shard-local instead of bouncing cache
//! lines between workers. The permutation is transparent: solver
//! factories, RNG streams, app-metric snapshots and `RunnerReport::thetas`
//! are all keyed by the caller's original node ids. Relabeling changes
//! only shard ownership and the sequential visit order — i.e. the
//! floating-point *grouping* of leader-side reductions — never any
//! node-level arithmetic (θ⁰ seeding stays keyed to original ids).
//!
//! ## Determinism
//!
//! Every node's computation depends only on neighbour parameters at
//! fixed epochs, so results are independent of thread timing. Shards are
//! contiguous and partials combine in shard order, so leader aggregates
//! visit nodes in (relabeled) sequential order; their floating-point
//! grouping (and nothing else) depends on the worker count and the
//! relabeling policy, both recorded/configured on [`ShardedConfig`].
//! With a fixed iteration budget the final parameters are bit-identical
//! for *any* worker count (asserted in the runner tests) for every
//! *decentralized* scheme — node updates never read the leader's folds.
//! The one exception is the non-decentralized RB reference scheme, whose
//! η updates consume the folded global residuals and can therefore pick
//! up last-ulp grouping differences across worker counts. Repeated runs
//! at the same configuration are bit-identical in full for all schemes.
//!
//! ## Execution modes: persistent pool vs scoped spawns
//!
//! The runner executes its worker bodies in one of two modes
//! ([`ShardedConfig::exec`], [`crate::pool::ExecMode`]). The default
//! `Pool` mode lazily creates one [`crate::pool::PhasePool`] per runner:
//! `W` pinned workers spawned once and reused by every later `run()`
//! call, fed whole-run jobs through per-worker queues — thread spawns
//! are O(W) per runner lifetime, not O(runs · W) (`bench_coordinator`
//! reports the amortization; `ci.sh` gates the spawn counts). `Scoped`
//! is the original spawn-per-run `std::thread::scope` baseline, kept as
//! the measurement control. Both modes run the identical `worker_main`
//! body and collect results in worker order, so they are bit-identical
//! (pinned by the runner tests); a worker panic poisons the phase
//! barrier in either mode and surfaces as `Err`, never a deadlock — the
//! pool generalizes the poisonable-barrier design instead of replacing
//! it.
//!
//! PJRT handles are not `Send`, so each worker constructs the solvers
//! for its own shard through the [`SolverFactory`]; sharded runs default
//! to the native backend (identical numbers, see
//! `integration_runtime.rs`). A panicking worker poisons the phase
//! barrier, so failures surface as `Err` instead of a pool deadlock.
//!
//! The shard-partial statistics and their Chan-style combination are
//! shared vocabulary with the cluster runtime ([`crate::cluster`]), which
//! runs this same pool *per machine* and ships
//! [`crate::metrics::StatPartial`]s across a simulated network instead of
//! a mutex — see `cluster::machine` for the composition.
//!
//! ## Memory layout at scale
//!
//! The arena is struct-of-arrays: one flat buffer per quantity per
//! parity (θ×2, η×2), 64-byte aligned, with every *shard's* block padded
//! up to a cache line ([`ParamArena::new_sharded`]) so phase-A/phase-C
//! writes by different workers never touch the same line:
//!
//! ```text
//! θ: ║ shard 0: θ_0 θ_1 … ║pad║ shard 1: θ_k … ║pad║ …   (×2 parities)
//! η: ║ shard 0: η-blocks  ║pad║ shard 1: …     ║pad║ …   (×2 parities)
//!      ↑64B-aligned            ↑64B-aligned
//! ```
//!
//! Combined with the CSR graph (`graph` module docs) and RCM relabeling,
//! a worker's whole iteration touches two dense windows per buffer — its
//! own shard (written) and a neighbourhood halo (read). At 10^6 nodes
//! the parameter footprint is `(2·dim + 2·mean_deg) · scalar_bytes` per
//! node plus three `usize` offsets; [`ShardedConfig::precision`] =
//! [`Precision::F32`] halves the scalar part while keeping every
//! accumulator f64 (see [`Precision`] for when *not* to use it —
//! tolerances ≤ ~1e-6, bit-reproducibility requirements, ill-conditioned
//! local problems). `bench_scale` measures bytes/node and
//! iterations/sec at 1e4–1e6 nodes and `ci.sh` gates the envelope.

mod arena;
mod messages;
mod runner;
mod shard;

pub use arena::{ArenaScalar, ParamArena, PhaseBarrier, Poisoned, CACHE_LINE};
pub use messages::Verdict;
pub use runner::{Precision, RunnerReport, ShardedConfig, ShardedRunner,
                 SolverFactory, ThreadedConfig, ThreadedReport, ThreadedRunner};
