//! Sharded worker-pool distributed runtime.
//!
//! The sequential [`crate::consensus::Engine`] executes the distributed
//! algorithm's exact schedule deterministically (the mode used for the
//! paper-figure experiments, where bit-reproducibility matters). This
//! module runs the *same* per-node program on a fixed pool of
//! `W = min(nodes, cores)` worker threads, each owning a contiguous
//! shard of nodes (degree-weighted split, [`crate::graph::shard_ranges`]).
//!
//! It replaces a thread-per-node design that heap-cloned every θ vector
//! per neighbour per iteration through mpsc channels and staged
//! out-of-order deliveries in per-node `HashMap`s — fine for 8 nodes,
//! hopeless for the hundreds-of-nodes regimes adaptive consensus ADMM is
//! evaluated in. Here a "broadcast" is the owner writing its block of a
//! shared, double-buffered parameter arena; neighbours read it in place.
//!
//! ## Schedule (three barriers per iteration)
//!
//! Iteration `t` reads the parity-`t%2` arena buffers and writes parity
//! `(t+1)%2` (no pointer swap — the parity *is* the swap):
//!
//! ```text
//! phase A  each worker, each owned node i:
//!            solve on θ^t, η^t  →  write θ^{t+1} into the write buffer
//! ── barrier 1 (epoch swap: every θ^{t+1} visible) ──────────────────────
//! phase B  λ update (symmetrized η̄ from own η^t + arena η^t_{j→i}),
//!          residuals, objectives  →  per-shard partial reduction
//!          (Σf, max‖r‖, max‖s‖, η min/mean/max, Σθ), node order
//! ── barrier 2 (all partials published) ─────────────────────────────────
//! fold     worker 0 combines partials in shard order, derives global
//!          residuals + convergence verdict, runs the app metric
//! ── barrier 3 (verdict visible) ────────────────────────────────────────
//! phase C  penalty-scheme update → publish η^{t+1}; stop if told to
//! ```
//!
//! The η buffers are double-buffered for the same reason θ is: node i's
//! λ step needs its neighbours' *iteration-t* penalties while those
//! neighbours may already be writing their iteration-`t+1` values.
//!
//! ## Determinism
//!
//! Every node's computation depends only on neighbour parameters at
//! fixed epochs, so results are independent of thread timing. Shards are
//! contiguous and partials combine in shard order, so leader aggregates
//! visit nodes in sequential order; their floating-point grouping (and
//! nothing else) depends on the worker count, which [`RunnerReport`]
//! records. With a fixed iteration budget the final parameters are
//! bit-identical for *any* worker count (asserted in the runner tests);
//! repeated runs at the same worker count are bit-identical in full.
//!
//! PJRT handles are not `Send`, so each worker constructs the solvers
//! for its own shard through the [`SolverFactory`]; sharded runs default
//! to the native backend (identical numbers, see
//! `integration_runtime.rs`). A panicking worker poisons the phase
//! barrier, so failures surface as `Err` instead of a pool deadlock.

mod arena;
mod messages;
mod runner;
mod shard;

pub use arena::{ParamArena, PhaseBarrier, Poisoned};
pub use messages::Verdict;
pub use runner::{RunnerReport, ShardedConfig, ShardedRunner, SolverFactory,
                 ThreadedConfig, ThreadedReport, ThreadedRunner};
