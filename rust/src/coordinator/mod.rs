//! Threaded distributed runtime.
//!
//! The sequential [`crate::consensus::Engine`] executes the distributed
//! algorithm's exact schedule deterministically (the mode used for the
//! paper-figure experiments, where bit-reproducibility matters). This
//! module runs the *same* per-node program on real OS threads with
//! message-passing — one actor per graph node plus a leader that only
//! aggregates convergence statistics (and the global residuals consumed
//! by the non-decentralized RB reference scheme).
//!
//! Message flow per iteration (matching Algorithm 1 of the paper):
//!
//! ```text
//! node i:  solve → broadcast (θ_i, η_i→j) → collect neighbours
//!        → λ update (symmetrized η̄, see consensus module docs)
//!        → residuals/objectives → stats to leader
//! leader:  aggregate Σf_i, residuals → verdict (continue / stop)
//! node i:  penalty-scheme update → next iteration
//! ```
//!
//! PJRT handles are not `Send`, so threaded runs construct one backend
//! per node thread through the [`SolverFactory`]; for the XLA backend
//! that would mean one PJRT client per thread, hence threaded runs
//! default to the native backend (identical numbers, see
//! `integration_runtime.rs`).

mod messages;
mod runner;

pub use messages::{Broadcast, StatsMsg, Verdict};
pub use runner::{SolverFactory, ThreadedConfig, ThreadedReport, ThreadedRunner};
