//! The sharded worker-pool runner: public API + orchestration.
//!
//! Runs `W = min(nodes, cores)` workers (overridable via
//! [`ShardedConfig::workers`]), each running the shard program in
//! [`super::shard`] over a contiguous node range from
//! [`crate::graph::shard_ranges`]. Parameters travel through the
//! double-buffered [`super::arena::ParamArena`]; worker panics poison the
//! phase barrier and surface as an `Err` instead of a deadlock.
//!
//! Execution is selected by [`ShardedConfig::exec`]: the default
//! [`ExecMode::Pool`] submits the `W` run-long worker jobs to a
//! persistent [`PhasePool`] created once per runner and reused across
//! `run` calls (thread spawns are O(W) per runner, not O(runs·W));
//! [`ExecMode::Scoped`] keeps the original spawn-per-run
//! `std::thread::scope` block as the bit-parity baseline. Both paths run
//! the identical shard program — same barrier schedule, same fold order —
//! so their outputs are bit-identical.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex, OnceLock};

use super::arena::{ArenaScalar, ParamArena, PhaseBarrier};
use super::messages::Verdict;
use super::shard::{worker_main, LeadOutcome, LeadState, ShardPartial, WorkerCtx,
                   WorkerError};
use crate::consensus::LocalSolver;
use crate::error::{Error, Result};
use crate::graph::{rcm_order, relabel_graph, shard_ranges, Graph, NodeId, Relabel};
use crate::kernel::AppMetricHook;
use crate::metrics::Recorder;
use crate::penalty::{SchemeKind, SchemeParams};
use crate::pool::{note_thread_spawn, ExecMode, PhasePool};

/// Builds one node's solver inside its worker thread (backends need not
/// be `Send`; only the factory crosses threads).
pub type SolverFactory<S> = Arc<dyn Fn(NodeId) -> S + Send + Sync>;

/// Storage precision of the parameter arena
/// ([`ShardedConfig::precision`]).
///
/// `F64` is the default and is bit-identical to every prior release: the
/// arena slices flow through the kernel with zero copies. `F32` halves
/// the θ/η storage footprint — the lever that fits 10^6-node runs in
/// cache-and-DRAM budgets — while *all arithmetic stays f64*: blocks are
/// widened on read and narrowed on write at the arena boundary, and the
/// Chan-style [`crate::metrics::StatPartial`] folds plus the stop test
/// keep full-precision accumulators, so convergence verdicts stay
/// honest.
///
/// When **not** to use `F32`: tolerances at or below ~1e-6 (the storage
/// rounding floor, ~1e-7 relative, stalls the residuals there),
/// bit-reproducibility requirements against f64 runs or the sequential
/// engine, and ill-conditioned local problems where θ round-tripping
/// through f32 each iteration perturbs the fixed point. Validation is by
/// iteration-count-delta tolerance against the f64 run, never bit
/// parity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// 8-byte θ/η storage — zero-copy, bit-identical default.
    #[default]
    F64,
    /// 4-byte θ/η storage — half the parameter bytes; f64 arithmetic and
    /// statistics (see type docs for caveats).
    F32,
}

/// Sharded-run configuration (mirrors [`crate::consensus::EngineConfig`]).
#[derive(Debug, Clone, Copy)]
pub struct ShardedConfig {
    pub scheme: SchemeKind,
    pub params: SchemeParams,
    pub tol: f64,
    pub patience: usize,
    pub warmup: usize,
    pub max_iters: usize,
    pub seed: u64,
    /// Worker-pool size; 0 (the default) resolves to
    /// `min(nodes, available_parallelism)`.
    pub workers: usize,
    /// Node-relabeling policy applied before sharding (default: RCM, so
    /// neighbours co-locate and phase-B arena reads stay shard-local).
    /// Transparent to callers: factories, metrics and reported θ all use
    /// the original node ids regardless.
    pub relabel: Relabel,
    /// Worker execution: persistent pool (default) or the scoped-spawn
    /// baseline. Bit-transparent — see the module docs.
    pub exec: ExecMode,
    /// enable phase-span timing ([`crate::obs`]); counters/gauges are
    /// always recorded
    pub obs: bool,
    /// record the per-round convergence series
    /// ([`crate::obs::RoundSeries`]). Rows are derived post-hoc from the
    /// leader's committed stats — `worker_main` is bit-parity pinned, so
    /// nothing is instrumented inside the shard program (no per-round
    /// phase durations; no timeline: the arena has no wire)
    pub series: bool,
    /// Arena storage precision (default [`Precision::F64`], bit-identical
    /// to prior releases; [`Precision::F32`] halves parameter memory —
    /// see the enum docs for caveats).
    pub precision: Precision,
}

/// Backward-compatible name for [`ShardedConfig`] (the thread-per-node
/// runner this replaced used it).
pub type ThreadedConfig = ShardedConfig;

impl Default for ShardedConfig {
    fn default() -> Self {
        ShardedConfig {
            scheme: SchemeKind::Fixed,
            params: SchemeParams::default(),
            tol: 1e-3,
            patience: 3,
            warmup: 5,
            max_iters: 1000,
            seed: 0,
            workers: 0,
            relabel: Relabel::default(),
            exec: ExecMode::default(),
            obs: false,
            series: false,
            precision: Precision::default(),
        }
    }
}

/// Outcome of a sharded run.
#[derive(Debug)]
pub struct RunnerReport {
    pub iterations: usize,
    pub converged: bool,
    pub recorder: Recorder,
    pub thetas: Vec<Vec<f64>>,
    /// resolved worker-pool size (reduction grouping is deterministic
    /// given this value; record it to reproduce a run exactly)
    pub workers: usize,
    /// unified telemetry ([`crate::obs`]): driver-side dispatch span,
    /// spawn counters and outcome gauges (worker internals stay
    /// untouched to preserve bit-parity)
    pub obs: crate::obs::MetricsRegistry,
    /// per-iteration committed-stats rows (empty unless `cfg.series` or
    /// the global series sink was enabled); derived post-hoc from the
    /// recorder, so `phase_ns` is all-zero on this runtime
    pub series: Vec<crate::obs::RoundRow>,
    /// series rows lost to decimation/capping
    pub series_dropped: u64,
}

/// Backward-compatible name for [`RunnerReport`].
pub type ThreadedReport = RunnerReport;

/// Orchestrates the worker pool over a topology.
pub struct ShardedRunner {
    graph: Graph,
    cfg: ShardedConfig,
    /// RCM permutation, computed once per runner: the graph is immutable
    /// for the runner's lifetime, so repeated `run` calls skip the BFS
    /// (ROADMAP open item). Dynamic graphs invalidate through
    /// [`crate::graph::LiveView::generation`] instead.
    rcm_cache: OnceLock<Vec<NodeId>>,
    /// Persistent worker pool (pool mode), created lazily on the first
    /// run and reused by every later one — the spawn-amortization half of
    /// the perf story. Sized to [`ShardedRunner::workers`], which is
    /// fixed for a runner's lifetime.
    pool: OnceLock<PhasePool>,
}

/// Backward-compatible name for [`ShardedRunner`].
pub type ThreadedRunner = ShardedRunner;

impl ShardedRunner {
    pub fn new(graph: Graph, cfg: ShardedConfig) -> Self {
        ShardedRunner { graph, cfg, rcm_cache: OnceLock::new(), pool: OnceLock::new() }
    }

    /// The cached RCM permutation, if a relabeled run has computed it
    /// (test/diagnostics hook — lets callers verify reuse).
    pub fn cached_order(&self) -> Option<&[NodeId]> {
        self.rcm_cache.get().map(Vec::as_slice)
    }

    /// The worker-pool size a run will request. The degree-skew cap in
    /// [`crate::graph::shard_ranges`] may reduce the *actual* count on
    /// heavy-tailed graphs; [`RunnerReport::workers`] records the
    /// resolved value.
    pub fn workers(&self) -> usize {
        let n = self.graph.len();
        if self.cfg.workers > 0 {
            self.cfg.workers.min(n)
        } else {
            std::thread::available_parallelism()
                .map(|c| c.get())
                .unwrap_or(1)
                .min(n)
        }
    }

    /// Run the distributed optimization with no application metric — the
    /// fast path: per-iteration θ is never materialized out of the arena.
    pub fn run<S>(&self, factory: SolverFactory<S>) -> Result<RunnerReport>
    where
        S: LocalSolver,
    {
        self.run_impl(factory, None)
    }

    /// Run with an application-metric callback, invoked by the leader
    /// worker once per iteration with `(iteration, thetas)`; its return
    /// value lands in [`crate::metrics::IterStats::app_error`]. The θ
    /// snapshot is copied into a buffer reused across iterations.
    /// (Liveness is trivially all-true here; see [`ShardedRunner::run_hooked`]
    /// for the unified three-argument surface.)
    pub fn run_with<S>(&self, factory: SolverFactory<S>,
                       mut app_metric: impl FnMut(usize, &[Vec<f64>]) -> f64 + Send)
                       -> Result<RunnerReport>
    where
        S: LocalSolver,
    {
        let mut hook =
            move |t: usize, thetas: &[Vec<f64>], _live: &[bool]| app_metric(t, thetas);
        self.run_impl(factory, Some(&mut hook))
    }

    /// Run with the unified [`AppMetricHook`] surface shared by all four
    /// runtimes (the leader passes all-true liveness).
    pub fn run_hooked<S>(&self, factory: SolverFactory<S>,
                         mut hook: impl AppMetricHook + Send)
                         -> Result<RunnerReport>
    where
        S: LocalSolver,
    {
        self.run_impl(factory, Some(&mut hook))
    }

    fn run_impl<S>(&self, factory: SolverFactory<S>,
                   metric: Option<&mut (dyn AppMetricHook + Send)>)
                   -> Result<RunnerReport>
    where
        S: LocalSolver,
    {
        // monomorphize the whole run on the arena scalar: the f64
        // instantiation is the exact pre-Precision code path
        match self.cfg.precision {
            Precision::F64 => self.run_typed::<S, f64>(factory, metric),
            Precision::F32 => self.run_typed::<S, f32>(factory, metric),
        }
    }

    fn run_typed<S, P>(&self, factory: SolverFactory<S>,
                       metric: Option<&mut (dyn AppMetricHook + Send)>)
                       -> Result<RunnerReport>
    where
        S: LocalSolver,
        P: ArenaScalar,
    {
        let n = self.graph.len();
        // probe one solver for the parameter dimension (factories are
        // deterministic constructors, so this is cheap and side-effect
        // free by contract)
        let dim = factory(0).dim();

        let workers = self.workers();

        // locality-aware sharding: relabel so neighbours co-locate before
        // the contiguous split. `order[shard_id] = original_id`; the
        // permutation is undone at every user-visible surface below. The
        // RCM BFS runs once per runner and is reused by later `run` calls
        // (the graph cannot change under us).
        let identity: Vec<NodeId>;
        let order: &[NodeId] = match self.cfg.relabel {
            Relabel::Identity => {
                identity = (0..n).collect();
                &identity
            }
            Relabel::Rcm => self.rcm_cache.get_or_init(|| rcm_order(&self.graph)),
        };
        let relabeled: Option<Graph> = match self.cfg.relabel {
            Relabel::Identity => None,
            Relabel::Rcm => Some(relabel_graph(&self.graph, order)?),
        };
        let graph: &Graph = relabeled.as_ref().unwrap_or(&self.graph);

        let ranges = shard_ranges(graph, workers);
        // the degree-skew cap may return fewer shards than requested —
        // the barrier, pool, partials and report are all sized off the
        // actual count (a barrier sized to the request would deadlock)
        let workers = ranges.len();

        let arena: ParamArena<P> = ParamArena::new_sharded(graph, dim, &ranges);
        let barrier = PhaseBarrier::new(workers);
        let partials = Mutex::new(vec![ShardPartial::new(dim); workers]);
        let verdict = Mutex::new(Verdict {
            t: 0,
            stop: false,
            global_primal: f64::INFINITY,
            global_dual: f64::INFINITY,
        });
        let ctx = WorkerCtx {
            graph,
            arena: &arena,
            barrier: &barrier,
            partials: &partials,
            verdict: &verdict,
            order,
            cfg: self.cfg,
        };

        // per-run registry (the runner is `&self`-reusable, so telemetry
        // cannot live on the runner itself); spans cover the driver side
        // only — instrumenting `worker_main` would need a shared-state
        // registry inside the bit-parity-pinned shard program
        let mut obs = crate::obs::MetricsRegistry::new(
            self.cfg.obs || crate::obs::global_spans_enabled(),
        );
        let probes = crate::obs::RuntimeProbes::register(&mut obs);
        let spawn_counter = obs.counter("fadmm_threads_spawned_total");
        let workers_gauge = obs.gauge("fadmm_workers");
        let spawned_before = crate::pool::threads_spawned();
        let dispatch_span = obs.span();

        let mut lead_slot = Some(LeadState::new(&self.cfg, dim, metric));
        let mut results: Vec<std::result::Result<Option<LeadOutcome>, WorkerError>> =
            Vec::with_capacity(workers);
        match self.cfg.exec {
            ExecMode::Scoped => std::thread::scope(|s| {
                let mut handles = Vec::with_capacity(workers);
                for (w, range) in ranges.iter().cloned().enumerate() {
                    let factory = Arc::clone(&factory);
                    let lead = if w == 0 { lead_slot.take() } else { None };
                    let ctx_ref = &ctx;
                    note_thread_spawn();
                    handles.push(s.spawn(move || {
                        match catch_unwind(AssertUnwindSafe(|| {
                            worker_main(ctx_ref, w, range, factory, lead)
                        })) {
                            Ok(r) => r,
                            Err(payload) => {
                                // release peers blocked on the barrier, then
                                // report the panic itself
                                ctx_ref.barrier.poison();
                                Err(WorkerError::Panicked(panic_message(&payload)))
                            }
                        }
                    }));
                }
                for h in handles {
                    results.push(h.join().unwrap_or_else(|payload| {
                        Err(WorkerError::Panicked(panic_message(&payload)))
                    }));
                }
            }),
            ExecMode::Pool => {
                // exactly `workers` jobs on a `workers`-sized pool: the
                // whole-set enqueue places one job per pool worker, so the
                // run-long jobs are co-scheduled and the phase barrier
                // inside `worker_main` can always complete
                let pool = self.pool.get_or_init(|| PhasePool::new(workers));
                debug_assert_eq!(pool.size(), workers);
                let slots: Vec<Mutex<Option<
                    std::result::Result<Option<LeadOutcome>, WorkerError>>>> =
                    (0..workers).map(|_| Mutex::new(None)).collect();
                let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                    Vec::with_capacity(workers);
                for (w, range) in ranges.iter().cloned().enumerate() {
                    let factory = Arc::clone(&factory);
                    let lead = if w == 0 { lead_slot.take() } else { None };
                    let ctx_ref = &ctx;
                    let slot = &slots[w];
                    jobs.push(Box::new(move || {
                        let r = match catch_unwind(AssertUnwindSafe(|| {
                            worker_main(ctx_ref, w, range, factory, lead)
                        })) {
                            Ok(r) => r,
                            Err(payload) => {
                                // same contract as scoped mode: free the
                                // peers, then report
                                ctx_ref.barrier.poison();
                                Err(WorkerError::Panicked(panic_message(&payload)))
                            }
                        };
                        *slot.lock().unwrap() = Some(r);
                    }));
                }
                if let Err(p) = pool.run(jobs) {
                    // jobs catch their own panics, so this only fires if
                    // the result store itself panicked
                    return Err(Error::Config(format!(
                        "sharded runner: worker panicked: {}", p.message)));
                }
                // slot order == spawn order: the fold below sees results
                // in the same sequence as the scoped join loop
                for slot in &slots {
                    results.push(slot.lock().unwrap().take().unwrap_or_else(|| {
                        Err(WorkerError::Panicked("worker produced no result".into()))
                    }));
                }
            }
        }

        obs.end(probes.pool_dispatch, dispatch_span);
        obs.inc(spawn_counter, crate::pool::threads_spawned() - spawned_before);
        obs.set_gauge(workers_gauge, workers as f64);

        let mut outcome: Option<LeadOutcome> = None;
        let mut panic_msg: Option<String> = None;
        let mut poisoned = false;
        for r in results {
            match r {
                Ok(Some(l)) => outcome = Some(l),
                Ok(None) => {}
                Err(WorkerError::Panicked(m)) => {
                    if panic_msg.is_none() {
                        panic_msg = Some(m);
                    }
                }
                Err(WorkerError::Poisoned) => poisoned = true,
            }
        }
        if let Some(m) = panic_msg {
            return Err(Error::Config(format!("sharded runner: worker panicked: {m}")));
        }
        if poisoned {
            return Err(Error::Config("sharded runner: a worker failed".into()));
        }
        let lead = outcome
            .ok_or_else(|| Error::Config("sharded runner: leader returned no outcome".into()))?;

        // final parameters sit in the buffer written at the last
        // iteration; un-permute so thetas[i] is the caller's node i
        let parity = lead.iterations & 1;
        let mut thetas = vec![vec![0.0; dim]; n];
        for (i, &orig) in order.iter().enumerate() {
            // Safety: every worker has been joined; no concurrent access.
            let th = unsafe { arena.theta(parity, i) };
            for (d, &x) in thetas[orig].iter_mut().zip(th) {
                *d = x.to_f64();
            }
        }
        obs.inc(probes.rounds, lead.iterations as u64);
        obs.set_gauge(probes.iterations, lead.iterations as f64);
        obs.set_gauge(probes.converged, if lead.converged { 1.0 } else { 0.0 });

        // convergence series, derived from the leader's committed stats
        // (post-hoc: the shard program itself stays untouched). Timestamps
        // are round indices — the arena runtime has no transport clock.
        let mut series = crate::obs::RoundSeries::new(
            self.cfg.series || crate::obs::global_series_enabled(),
        );
        if series.enabled() {
            let live_edges = self.graph.edge_count() as u64;
            for s in &lead.recorder.stats {
                series.push(crate::obs::RoundRow {
                    round: s.iter as u64,
                    at: s.iter as u64,
                    stats: *s,
                    live_nodes: n as u64,
                    live_edges,
                    phase_ns: [0; crate::obs::NPHASES],
                });
            }
        }
        let series_rows = series.drain();
        let series_dropped = series.dropped();
        obs.absorb_timeline(0, 0, series_rows.len(), series_dropped);
        crate::obs::global_merge(&obs);
        if crate::obs::global_series_enabled() {
            crate::obs::global_series_merge(series_rows.clone(), series_dropped);
        }
        Ok(RunnerReport {
            iterations: lead.iterations,
            converged: lead.converged,
            recorder: lead.recorder,
            thetas,
            workers,
            obs,
            series: series_rows,
            series_dropped,
        })
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::solvers::QuadraticNode;
    use crate::consensus::{Engine, EngineConfig};
    use crate::graph::{random_connected, Topology};
    use crate::linalg::Mat;
    use crate::util::rng::Pcg;

    fn quad_factory(n: usize, dim: usize, seed: u64)
                    -> (SolverFactory<QuadraticNode>, Vec<f64>) {
        // materialize all node problems up-front so the central optimum is
        // computable; the factory clones per worker
        let mut rng = Pcg::seed(seed);
        let nodes: Vec<(Mat, Vec<f64>)> = (0..n)
            .map(|_| {
                let q = QuadraticNode::random(dim, &mut rng);
                (q.p, q.q)
            })
            .collect();
        let opt = QuadraticNode::central_optimum(
            &nodes
                .iter()
                .map(|(p, q)| QuadraticNode::new(p.clone(), q.clone()))
                .collect::<Vec<_>>(),
        );
        let nodes = Arc::new(nodes);
        let factory: SolverFactory<QuadraticNode> = Arc::new(move |i| {
            let (p, q) = nodes[i].clone();
            QuadraticNode::new(p, q)
        });
        (factory, opt)
    }

    fn max_err(thetas: &[Vec<f64>], opt: &[f64]) -> f64 {
        thetas
            .iter()
            .map(|th| {
                th.iter()
                    .zip(opt)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>()
                    .sqrt()
            })
            .fold(0.0, f64::max)
    }

    #[test]
    fn sharded_matches_central_optimum() {
        for scheme in [SchemeKind::Fixed, SchemeKind::Ap, SchemeKind::Vp,
                       SchemeKind::Nap] {
            let (factory, opt) = quad_factory(6, 3, 17);
            let runner = ThreadedRunner::new(
                Topology::Complete.build(6).unwrap(),
                ThreadedConfig {
                    scheme,
                    tol: 1e-10,
                    max_iters: 500,
                    ..Default::default()
                },
            );
            let report = runner.run(factory).unwrap();
            for th in &report.thetas {
                assert_eq!(th.len(), 3);
                for (a, b) in th.iter().zip(&opt) {
                    assert!((a - b).abs() < 1e-3, "{scheme:?}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn sharded_is_deterministic() {
        let run = || {
            let (factory, _) = quad_factory(5, 2, 3);
            let runner = ThreadedRunner::new(
                Topology::Ring.build(5).unwrap(),
                ThreadedConfig { scheme: SchemeKind::VpAp, max_iters: 60, tol: 0.0,
                                 ..Default::default() },
            );
            runner.run(factory).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.thetas, b.thetas);
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.recorder.objective_curve(), b.recorder.objective_curve());
    }

    #[test]
    fn sharded_agrees_with_sequential_engine() {
        // same problem, same convergence point (inits differ, optimum
        // doesn't): consensus parameters must match to solver tolerance
        let (factory, opt) = quad_factory(6, 3, 29);
        let runner = ThreadedRunner::new(
            Topology::Cluster.build(6).unwrap(),
            ThreadedConfig { scheme: SchemeKind::Nap, tol: 1e-11, max_iters: 600,
                             ..Default::default() },
        );
        let sharded = runner.run(factory).unwrap();
        for th in &sharded.thetas {
            for (a, b) in th.iter().zip(&opt) {
                assert!((a - b).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn leader_records_every_iteration() {
        let (factory, _) = quad_factory(4, 2, 5);
        let runner = ThreadedRunner::new(
            Topology::Complete.build(4).unwrap(),
            ThreadedConfig { max_iters: 25, tol: 0.0, ..Default::default() },
        );
        let report = runner.run_with(factory, |t, _| t as f64).unwrap();
        assert_eq!(report.iterations, 25);
        assert_eq!(report.recorder.stats.len(), 25);
        assert!(!report.converged);
        assert_eq!(report.recorder.final_error(), 24.0);
    }

    #[test]
    fn engine_parity_star_and_ring_all_schemes() {
        // the sequential Engine is the oracle: on the same problem both
        // runtimes must land on the centralized optimum, every scheme,
        // on a hub topology and a sparse cycle
        for topo in [Topology::Star, Topology::Ring] {
            for scheme in SchemeKind::ALL {
                let (factory, opt) = quad_factory(6, 3, 61);
                let mut rng = Pcg::seed(61);
                let nodes: Vec<QuadraticNode> =
                    (0..6).map(|_| QuadraticNode::random(3, &mut rng)).collect();
                let mut engine = Engine::new(topo.build(6).unwrap(), nodes,
                                             EngineConfig {
                                                 scheme,
                                                 tol: 1e-10,
                                                 max_iters: 1200,
                                                 ..Default::default()
                                             });
                let sequential = engine.run();
                assert!(max_err(&sequential.thetas, &opt) < 1e-3,
                        "engine {topo:?}/{scheme:?}: {}",
                        max_err(&sequential.thetas, &opt));

                let runner = ShardedRunner::new(topo.build(6).unwrap(),
                                                ShardedConfig {
                                                    scheme,
                                                    tol: 1e-10,
                                                    max_iters: 1200,
                                                    ..Default::default()
                                                });
                let sharded = runner.run(factory).unwrap();
                assert!(max_err(&sharded.thetas, &opt) < 1e-3,
                        "sharded {topo:?}/{scheme:?}: {}",
                        max_err(&sharded.thetas, &opt));
            }
        }
    }

    #[test]
    fn rcm_and_identity_match_engine_on_random_graph() {
        // the satellite parity oracle: on a random connected graph, every
        // scheme lands on the centralized optimum under RCM relabeling,
        // under identity labeling, and in the sequential Engine
        let mut grng = Pcg::seed(1234);
        let graph = random_connected(10, 0.35, &mut grng).unwrap();
        for scheme in SchemeKind::ALL {
            for relabel in [Relabel::Rcm, Relabel::Identity] {
                let (factory, opt) = quad_factory(10, 2, 91);
                let runner = ShardedRunner::new(graph.clone(), ShardedConfig {
                    scheme,
                    tol: 1e-10,
                    max_iters: 1500,
                    relabel,
                    ..Default::default()
                });
                let report = runner.run(factory).unwrap();
                assert!(max_err(&report.thetas, &opt) < 5e-3,
                        "sharded {scheme:?}/{relabel:?}: {}",
                        max_err(&report.thetas, &opt));
            }
            let mut rng = Pcg::seed(91);
            let nodes: Vec<QuadraticNode> =
                (0..10).map(|_| QuadraticNode::random(2, &mut rng)).collect();
            let (_, opt) = quad_factory(10, 2, 91);
            let mut engine = Engine::new(graph.clone(), nodes, EngineConfig {
                scheme,
                tol: 1e-10,
                max_iters: 1500,
                ..Default::default()
            });
            let sequential = engine.run();
            assert!(max_err(&sequential.thetas, &opt) < 5e-3,
                    "engine {scheme:?}: {}", max_err(&sequential.thetas, &opt));
        }
    }

    #[test]
    fn rcm_permutation_cached_and_reused_across_runs() {
        // the ROADMAP open item: repeated `run` calls on one runner must
        // skip the RCM BFS. The cache fills on the first run, the second
        // run reuses the same allocation, and both runs are bit-identical.
        let graph = Topology::Ring.build(12).unwrap();
        let runner = ShardedRunner::new(
            graph.clone(),
            ShardedConfig { scheme: SchemeKind::Ap, tol: 0.0, max_iters: 40,
                            workers: 3, ..Default::default() },
        );
        assert!(runner.cached_order().is_none(), "cache empty before any run");
        let (factory, _) = quad_factory(12, 2, 55);
        let a = runner.run(factory).unwrap();
        let cached = runner.cached_order().expect("first RCM run fills the cache");
        assert_eq!(cached, rcm_order(&graph), "cache holds the RCM permutation");
        let ptr = cached.as_ptr();
        let (factory, _) = quad_factory(12, 2, 55);
        let b = runner.run(factory).unwrap();
        assert_eq!(runner.cached_order().unwrap().as_ptr(), ptr,
                   "second run reuses the cached permutation (no recompute)");
        assert_eq!(a.thetas, b.thetas, "reuse is bit-transparent");
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.recorder.objective_curve(), b.recorder.objective_curve());
    }

    #[test]
    fn identity_relabeling_never_fills_rcm_cache() {
        let runner = ShardedRunner::new(
            Topology::Ring.build(6).unwrap(),
            ShardedConfig { max_iters: 5, relabel: Relabel::Identity,
                            ..Default::default() },
        );
        let (factory, _) = quad_factory(6, 2, 5);
        runner.run(factory).unwrap();
        assert!(runner.cached_order().is_none());
    }

    #[test]
    fn relabeling_is_transparent_in_reported_thetas() {
        // with a zero iteration budget the report returns each node's θ⁰,
        // which is seeded by *original* node id — so the reported vector
        // must be bit-identical under any relabeling policy
        let run = |relabel| {
            let (factory, _) = quad_factory(9, 3, 41);
            ShardedRunner::new(
                Topology::Ring.build(9).unwrap(),
                ShardedConfig { max_iters: 0, relabel, ..Default::default() },
            )
            .run(factory)
            .unwrap()
        };
        let id = run(Relabel::Identity);
        let rcm = run(Relabel::Rcm);
        assert_eq!(id.thetas, rcm.thetas);
        assert_eq!(id.iterations, 0);
    }

    #[test]
    fn isolated_node_dual_matches_engine() {
        // degree-0 η̄ is 0 in BOTH runtimes (η̄ = Ση·(1/deg.max(1)); the
        // engine used to fall back to η⁰) — the recorded dual-residual
        // observations must agree bit-for-bit
        let (factory, _) = quad_factory(1, 3, 9);
        let runner = ShardedRunner::new(
            Graph::new(1, &[]).unwrap(),
            ShardedConfig { max_iters: 20, tol: 0.0, ..Default::default() },
        );
        let sharded = runner.run(factory).unwrap();
        let mut rng = Pcg::seed(9);
        let nodes = vec![QuadraticNode::random(3, &mut rng)];
        let mut engine = Engine::new(Graph::new(1, &[]).unwrap(), nodes,
                                     EngineConfig { max_iters: 20, tol: 0.0,
                                                    ..Default::default() });
        let sequential = engine.run();
        assert_eq!(sequential.recorder.stats.len(), sharded.recorder.stats.len());
        for (a, b) in sequential.recorder.stats.iter().zip(&sharded.recorder.stats) {
            assert_eq!(a.max_dual, b.max_dual, "iter {}", a.iter);
            assert_eq!(a.max_dual, 0.0, "no neighbours ⇒ zero dual residual");
        }
    }

    #[test]
    fn both_runtimes_record_pre_update_eta_stats() {
        // IterStats[t] carries the η^t used by iteration t's solves in
        // BOTH runtimes; under an adaptive scheme that means iteration 0
        // must record exactly η⁰ everywhere (the update lands in stats[1])
        let eta0 = SchemeParams::default().eta0;
        let (factory, _) = quad_factory(6, 2, 77);
        let runner = ShardedRunner::new(
            Topology::Ring.build(6).unwrap(),
            ShardedConfig { scheme: SchemeKind::Ap, tol: 0.0, max_iters: 3,
                            ..Default::default() },
        );
        let sharded = runner.run(factory).unwrap();
        let mut rng = Pcg::seed(77);
        let nodes: Vec<QuadraticNode> =
            (0..6).map(|_| QuadraticNode::random(2, &mut rng)).collect();
        let mut engine = Engine::new(Topology::Ring.build(6).unwrap(), nodes,
                                     EngineConfig { scheme: SchemeKind::Ap,
                                                    tol: 0.0, max_iters: 3,
                                                    ..Default::default() });
        let sequential = engine.run();
        for stats in [&sharded.recorder.stats, &sequential.recorder.stats] {
            assert_eq!(stats[0].mean_eta, eta0);
            assert_eq!(stats[0].min_eta, eta0);
            assert_eq!(stats[0].max_eta, eta0);
        }
    }

    #[test]
    fn isolated_node_runs_without_nan() {
        // a degree-0 node exercises every deg.max(1) / eta_count == 0
        // guard in the residual and η-statistics paths
        for scheme in SchemeKind::ALL {
            let (factory, opt) = quad_factory(1, 3, 9);
            let runner = ShardedRunner::new(Graph::new(1, &[]).unwrap(),
                                            ShardedConfig {
                                                scheme,
                                                max_iters: 40,
                                                ..Default::default()
                                            });
            let report = runner.run(factory).unwrap();
            assert!(report.iterations > 0, "{scheme:?}");
            for th in &report.thetas {
                assert!(th.iter().all(|x| x.is_finite()), "{scheme:?}: {th:?}");
            }
            // with no consensus constraint the node solves its own problem
            assert!(max_err(&report.thetas, &opt) < 1e-6, "{scheme:?}");
            for s in &report.recorder.stats {
                assert!(s.objective.is_finite(), "{scheme:?}");
                assert!(s.max_primal.is_finite() && s.max_dual.is_finite(),
                        "{scheme:?}");
                assert_eq!(s.mean_eta, 0.0, "{scheme:?}: no edges, no η");
                assert_eq!(s.min_eta, 0.0, "{scheme:?}");
            }
        }
    }

    #[test]
    fn worker_count_does_not_change_node_results() {
        // node-level computation is independent of the shard layout; with
        // a fixed iteration count the final parameters are bit-identical
        // for any worker count. Holds for every decentralized scheme (Ap
        // here) — leader folds feed only the stop check (disabled via
        // tol = 0); the non-decentralized Rb reference also reads the
        // folded global residuals and is exempt from this guarantee (see
        // the module docs on determinism).
        let run = |workers: usize| {
            let (factory, _) = quad_factory(7, 3, 13);
            let runner = ShardedRunner::new(
                Topology::Ring.build(7).unwrap(),
                ShardedConfig { scheme: SchemeKind::Ap, tol: 0.0, max_iters: 60,
                                workers, ..Default::default() },
            );
            runner.run(factory).unwrap()
        };
        let one = run(1);
        let three = run(3);
        let auto = run(0);
        assert_eq!(one.workers, 1);
        assert_eq!(three.workers, 3);
        assert_eq!(one.thetas, three.thetas);
        assert_eq!(one.thetas, auto.thetas);
        assert_eq!(one.iterations, three.iterations);
    }

    #[test]
    fn boxed_solvers_run_heterogeneously() {
        // Box<dyn LocalSolver> through the forwarding impl: mix quadratic
        // nodes with ridge nodes in one run
        use crate::consensus::solvers::RidgeNode;
        let factory: SolverFactory<Box<dyn LocalSolver>> = Arc::new(|i| {
            let mut rng = Pcg::seed(100 + i as u64);
            let solver: Box<dyn LocalSolver> = if i % 2 == 0 {
                Box::new(QuadraticNode::random(3, &mut rng))
            } else {
                let a = Mat::randn(8, 3, &mut rng);
                let b: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
                Box::new(RidgeNode::new(a, b, 0.5))
            };
            solver
        });
        let runner = ShardedRunner::new(Topology::Ring.build(4).unwrap(),
                                        ShardedConfig { max_iters: 120,
                                                        ..Default::default() });
        let report = runner.run(factory).unwrap();
        assert!(report.iterations > 0);
        assert!(report.thetas.iter().all(|t| t.iter().all(|x| x.is_finite())));
    }

    #[test]
    fn panicking_solver_reports_error_not_deadlock() {
        // both execution modes share the catch_unwind + barrier-poison
        // contract: a worker panic surfaces as Err, never a hang
        for exec in [ExecMode::Pool, ExecMode::Scoped] {
            let factory: SolverFactory<QuadraticNode> = Arc::new(|i| {
                if i == 3 {
                    panic!("solver construction failed on purpose");
                }
                let mut rng = Pcg::seed(1 + i as u64);
                QuadraticNode::random(2, &mut rng)
            });
            let runner = ShardedRunner::new(Topology::Ring.build(6).unwrap(),
                                            ShardedConfig { max_iters: 50, workers: 3,
                                                            exec,
                                                            ..Default::default() });
            let err = runner.run(factory).unwrap_err();
            assert!(err.to_string().contains("panicked"), "{exec:?}: {err}");
        }
    }

    #[test]
    fn pool_and_scoped_execution_are_bit_identical() {
        // the tentpole parity matrix: every scheme (including the folded
        // Rb reference — the worker count is the same on both sides, so
        // even fold-grouping-sensitive schemes must agree exactly), two
        // topologies, θ and every recorded IterStats field bitwise equal
        for topo in [Topology::Ring, Topology::Star] {
            for scheme in SchemeKind::ALL {
                let run = |exec| {
                    let (factory, _) = quad_factory(8, 3, 19);
                    ShardedRunner::new(
                        topo.build(8).unwrap(),
                        ShardedConfig { scheme, tol: 0.0, max_iters: 40,
                                        workers: 3, exec,
                                        ..Default::default() },
                    )
                    .run(factory)
                    .unwrap()
                };
                let pool = run(ExecMode::Pool);
                let scoped = run(ExecMode::Scoped);
                assert_eq!(pool.thetas, scoped.thetas, "{topo:?}/{scheme:?}");
                assert_eq!(pool.iterations, scoped.iterations, "{topo:?}/{scheme:?}");
                assert_eq!(pool.workers, scoped.workers);
                assert_eq!(pool.recorder.stats, scoped.recorder.stats,
                           "{topo:?}/{scheme:?}: IterStats streams diverge");
            }
        }
    }

    #[test]
    fn f32_precision_agrees_with_f64_on_verdict_and_iterations() {
        // the tentpole acceptance contract: the f32 path is validated by
        // an iteration-count-delta tolerance and verdict agreement, never
        // bit parity. tol 1e-4 sits well above f32's ~1e-7 storage floor.
        let run = |precision| {
            let (factory, opt) = quad_factory(8, 3, 23);
            let runner = ShardedRunner::new(
                Topology::Ring.build(8).unwrap(),
                ShardedConfig { scheme: SchemeKind::Ap, tol: 1e-4,
                                max_iters: 800, precision,
                                ..Default::default() },
            );
            (runner.run(factory).unwrap(), opt)
        };
        let (wide, opt) = run(Precision::F64);
        let (narrow, _) = run(Precision::F32);
        assert!(wide.converged, "f64 baseline must converge");
        assert_eq!(wide.converged, narrow.converged, "verdicts agree");
        let delta = wide.iterations.abs_diff(narrow.iterations);
        assert!(delta <= wide.iterations / 4 + 2,
                "iteration counts {} (f64) vs {} (f32) drifted past tolerance",
                wide.iterations, narrow.iterations);
        assert!(max_err(&narrow.thetas, &opt) < 1e-2,
                "f32 run still lands near the centralized optimum: {}",
                max_err(&narrow.thetas, &opt));
    }

    #[test]
    fn f32_default_is_off_and_f64_path_unchanged() {
        assert_eq!(ShardedConfig::default().precision, Precision::F64);
        // explicit F64 is the same code path as the default — identical
        // bits, not merely close
        let run = |precision| {
            let (factory, _) = quad_factory(6, 2, 47);
            ShardedRunner::new(
                Topology::Star.build(6).unwrap(),
                ShardedConfig { scheme: SchemeKind::Vp, tol: 0.0, max_iters: 30,
                                precision, ..Default::default() },
            )
            .run(factory)
            .unwrap()
        };
        let dflt = run(Precision::default());
        let f64e = run(Precision::F64);
        assert_eq!(dflt.thetas, f64e.thetas);
        assert_eq!(dflt.recorder.stats, f64e.recorder.stats);
    }

    #[test]
    fn capped_shards_still_run_star_hub() {
        // star(1001) at 64 requested workers is capped to 5 shards by the
        // degree-skew cap; the barrier/pool must size to the actual count
        // instead of deadlocking, and the report must record it
        let (factory, _) = quad_factory(1001, 2, 3);
        let runner = ShardedRunner::new(
            Topology::Star.build(1001).unwrap(),
            ShardedConfig { max_iters: 3, tol: 0.0, workers: 64,
                            relabel: Relabel::Identity,
                            ..Default::default() },
        );
        let report = runner.run(factory).unwrap();
        assert_eq!(report.iterations, 3);
        assert!(report.workers < 64, "hub cap reduced the pool");
        assert!(report.thetas.iter().all(|t| t.iter().all(|x| x.is_finite())));
    }

    #[test]
    fn pool_worker_count_invariance_matches_scoped() {
        // worker-count invariance (decentralized scheme, fixed budget)
        // holds under the pool exactly as it does under scoped spawning
        let run = |workers: usize, exec| {
            let (factory, _) = quad_factory(7, 3, 13);
            ShardedRunner::new(
                Topology::Ring.build(7).unwrap(),
                ShardedConfig { scheme: SchemeKind::Ap, tol: 0.0, max_iters: 60,
                                workers, exec, ..Default::default() },
            )
            .run(factory)
            .unwrap()
        };
        let p1 = run(1, ExecMode::Pool);
        let p3 = run(3, ExecMode::Pool);
        let p7 = run(7, ExecMode::Pool);
        let s3 = run(3, ExecMode::Scoped);
        assert_eq!(p1.thetas, p3.thetas);
        assert_eq!(p1.thetas, p7.thetas);
        assert_eq!(p3.thetas, s3.thetas);
        assert_eq!(p3.recorder.stats, s3.recorder.stats);
    }
}
