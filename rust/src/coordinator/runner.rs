//! Node actors on OS threads + the aggregating leader.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use super::messages::{Broadcast, StatsMsg, Verdict};
use crate::consensus::LocalSolver;
use crate::error::{Error, Result};
use crate::graph::{Graph, NodeId};
use crate::metrics::{ConvergenceChecker, IterStats, Recorder};
use crate::penalty::{make_scheme, NodeObservation, SchemeKind, SchemeParams};
use crate::util::rng::Pcg;

/// Builds one node's solver inside its thread (backends need not be `Send`).
pub type SolverFactory<S> = Arc<dyn Fn(NodeId) -> S + Send + Sync>;

/// Threaded-run configuration (mirrors [`crate::consensus::EngineConfig`]).
#[derive(Debug, Clone, Copy)]
pub struct ThreadedConfig {
    pub scheme: SchemeKind,
    pub params: SchemeParams,
    pub tol: f64,
    pub patience: usize,
    pub warmup: usize,
    pub max_iters: usize,
    pub seed: u64,
}

impl Default for ThreadedConfig {
    fn default() -> Self {
        ThreadedConfig {
            scheme: SchemeKind::Fixed,
            params: SchemeParams::default(),
            tol: 1e-3,
            patience: 3,
            warmup: 5,
            max_iters: 1000,
            seed: 0,
        }
    }
}

/// Outcome of a threaded run.
#[derive(Debug)]
pub struct ThreadedReport {
    pub iterations: usize,
    pub converged: bool,
    pub recorder: Recorder,
    pub thetas: Vec<Vec<f64>>,
}

/// Orchestrates node actors over a topology.
pub struct ThreadedRunner {
    graph: Graph,
    cfg: ThreadedConfig,
}

impl ThreadedRunner {
    pub fn new(graph: Graph, cfg: ThreadedConfig) -> Self {
        ThreadedRunner { graph, cfg }
    }

    /// Run the distributed optimization; `app_metric` is evaluated by the
    /// leader on the gathered per-iteration parameters.
    pub fn run<S>(&self, factory: SolverFactory<S>,
                  mut app_metric: impl FnMut(usize, &[Vec<f64>]) -> f64)
                  -> Result<ThreadedReport>
    where
        S: LocalSolver + 'static,
    {
        let n = self.graph.len();
        let cfg = self.cfg;

        // channels: per-node broadcast inbox, per-node verdict inbox,
        // shared stats channel into the leader
        let mut bcast_tx: Vec<Sender<Broadcast>> = Vec::with_capacity(n);
        let mut bcast_rx: Vec<Option<Receiver<Broadcast>>> = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            bcast_tx.push(tx);
            bcast_rx.push(Some(rx));
        }
        let (stats_tx, stats_rx) = channel::<StatsMsg>();
        let mut verdict_tx: Vec<Sender<Verdict>> = Vec::with_capacity(n);
        let mut verdict_rx: Vec<Option<Receiver<Verdict>>> = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            verdict_tx.push(tx);
            verdict_rx.push(Some(rx));
        }

        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let neighbors: Vec<NodeId> = self.graph.neighbors(i).to_vec();
            let nb_senders: Vec<Sender<Broadcast>> =
                neighbors.iter().map(|&j| bcast_tx[j].clone()).collect();
            let my_rx = bcast_rx[i].take().expect("rx taken once");
            let my_verdicts = verdict_rx[i].take().expect("rx taken once");
            let stats = stats_tx.clone();
            let factory = factory.clone();
            handles.push(std::thread::spawn(move || {
                node_main(i, cfg, neighbors, nb_senders, my_rx, my_verdicts,
                          stats, factory)
            }));
        }
        drop(stats_tx);

        let leader = self.leader_loop(stats_rx, &verdict_tx, &mut app_metric);

        let mut thetas: Vec<Vec<f64>> = vec![Vec::new(); n];
        for h in handles {
            let (id, theta) = h
                .join()
                .map_err(|_| Error::Config("node thread panicked".into()))?;
            thetas[id] = theta;
        }
        let (iterations, converged, recorder) = leader?;
        Ok(ThreadedReport { iterations, converged, recorder, thetas })
    }

    fn leader_loop(&self, stats_rx: Receiver<StatsMsg>, verdict_tx: &[Sender<Verdict>],
                   app_metric: &mut impl FnMut(usize, &[Vec<f64>]) -> f64)
                   -> Result<(usize, bool, Recorder)> {
        let n = self.graph.len();
        let mut recorder = Recorder::new();
        let mut checker = ConvergenceChecker::new(self.cfg.tol)
            .with_patience(self.cfg.patience)
            .with_warmup(self.cfg.warmup);
        let mut global_mean_prev: Option<Vec<f64>> = None;
        let mut converged = false;
        let mut iterations = 0;

        for t in 0..self.cfg.max_iters {
            let mut pending: Vec<Option<StatsMsg>> = vec![None; n];
            let mut received = 0;
            while received < n {
                let msg = stats_rx
                    .recv()
                    .map_err(|_| Error::Config("node thread died mid-run".into()))?;
                debug_assert_eq!(msg.t, t, "stats tag mismatch");
                let from = msg.from;
                if pending[from].replace(msg).is_none() {
                    received += 1;
                }
            }
            let stats: Vec<StatsMsg> = pending.into_iter().map(|m| m.unwrap()).collect();

            // aggregate
            let objective: f64 = stats.iter().map(|s| s.f_self).sum();
            let max_primal = stats.iter().map(|s| s.primal_norm).fold(0.0, f64::max);
            let max_dual = stats.iter().map(|s| s.dual_norm).fold(0.0, f64::max);
            let eta_min = stats.iter().map(|s| s.eta_min).fold(f64::INFINITY, f64::min);
            let eta_max = stats.iter().map(|s| s.eta_max).fold(0.0, f64::max);
            let eta_cnt: usize = stats.iter().map(|s| s.eta_count).sum();
            let eta_mean = if eta_cnt == 0 {
                0.0
            } else {
                stats.iter().map(|s| s.eta_sum).sum::<f64>() / eta_cnt as f64
            };

            // global residuals (RB reference scheme)
            let dim = stats[0].theta.len();
            let mut gmean = vec![0.0; dim];
            for s in &stats {
                for k in 0..dim {
                    gmean[k] += s.theta[k] / n as f64;
                }
            }
            let mut gr2 = 0.0;
            for s in &stats {
                for k in 0..dim {
                    let d = s.theta[k] - gmean[k];
                    gr2 += d * d;
                }
            }
            let gs2 = match &global_mean_prev {
                Some(prev) => gmean
                    .iter()
                    .zip(prev)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f64>(),
                None => f64::INFINITY,
            };
            let global_dual = if gs2.is_finite() {
                self.cfg.params.eta0 * (n as f64).sqrt() * gs2.sqrt()
            } else {
                f64::INFINITY
            };
            global_mean_prev = Some(gmean);

            let thetas: Vec<Vec<f64>> = stats.iter().map(|s| s.theta.clone()).collect();
            let app_error = app_metric(t, &thetas);
            recorder.push(IterStats {
                iter: t,
                objective,
                max_primal,
                max_dual,
                mean_eta: eta_mean,
                min_eta: if eta_cnt == 0 { 0.0 } else { eta_min },
                max_eta: eta_max,
                app_error,
            });
            iterations = t + 1;
            let stop = checker.update(objective) || t + 1 == self.cfg.max_iters;
            if stop && t + 1 < self.cfg.max_iters {
                converged = true;
            }
            let verdict = Verdict {
                t,
                stop,
                global_primal: gr2.sqrt(),
                global_dual,
            };
            for tx in verdict_tx {
                // a node that already stopped is gone; that's fine on stop
                let _ = tx.send(verdict);
            }
            if stop {
                break;
            }
        }
        Ok((iterations, converged, recorder))
    }
}

/// The per-node actor program (see module docs for the message schedule).
#[allow(clippy::too_many_arguments)]
fn node_main<S: LocalSolver>(
    id: NodeId,
    cfg: ThreadedConfig,
    neighbors: Vec<NodeId>,
    nb_senders: Vec<Sender<Broadcast>>,
    inbox: Receiver<Broadcast>,
    verdicts: Receiver<Verdict>,
    stats: Sender<StatsMsg>,
    factory: SolverFactory<S>,
) -> (NodeId, Vec<f64>) {
    let mut solver = factory(id);
    let dim = solver.dim();
    let deg = neighbors.len();
    let mut rng = Pcg::new(cfg.seed, id as u64 + 1);
    let mut theta = solver.initial_param(&mut rng);
    let mut lambda = vec![0.0; dim];
    let mut etas = vec![cfg.params.eta0; deg];
    let mut scheme = make_scheme(cfg.scheme, cfg.params, deg);
    let mut f_self_prev = f64::INFINITY;
    let mut nbr_mean_prev = vec![0.0; dim];

    let slot_of: HashMap<NodeId, usize> =
        neighbors.iter().enumerate().map(|(s, &j)| (j, s)).collect();
    // out-of-order broadcast staging: (tag → slot → theta/eta)
    let mut pending: HashMap<usize, Vec<Option<(Vec<f64>, f64)>>> = HashMap::new();
    let mut known: Vec<Vec<f64>> = vec![Vec::new(); deg];
    let mut eta_in: Vec<f64> = vec![cfg.params.eta0; deg];

    let collect = |tag: usize,
                       pending: &mut HashMap<usize, Vec<Option<(Vec<f64>, f64)>>>,
                       known: &mut Vec<Vec<f64>>, eta_in: &mut Vec<f64>| {
        loop {
            let entry = pending.entry(tag).or_insert_with(|| vec![None; deg]);
            if entry.iter().all(Option::is_some) {
                let entry = pending.remove(&tag).unwrap();
                for (slot, item) in entry.into_iter().enumerate() {
                    let (th, eta) = item.unwrap();
                    known[slot] = th;
                    eta_in[slot] = eta;
                }
                return;
            }
            match inbox.recv() {
                Ok(msg) => {
                    let slot = slot_of[&msg.from];
                    pending
                        .entry(msg.t)
                        .or_insert_with(|| vec![None; deg])[slot] =
                        Some((msg.theta, msg.eta_to_receiver));
                }
                Err(_) => return, // peers gone; leader will stop us
            }
        }
    };

    // initial exchange: θ⁰ tagged 0
    for (slot, tx) in nb_senders.iter().enumerate() {
        let _ = tx.send(Broadcast {
            from: id,
            t: 0,
            theta: theta.clone(),
            eta_to_receiver: etas[slot],
        });
    }
    collect(0, &mut pending, &mut known, &mut eta_in);

    for t in 0..cfg.max_iters {
        // ---- local solve on iteration-t neighbour parameters -------------
        let eta_sum: f64 = etas.iter().sum();
        let mut eta_wsum = vec![0.0; dim];
        for slot in 0..deg {
            let e = etas[slot];
            for k in 0..dim {
                eta_wsum[k] += e * (theta[k] + known[slot][k]);
            }
        }
        theta = solver.solve(&theta, &lambda, eta_sum, &eta_wsum);

        // ---- broadcast θ^{t+1} with our edge penalties --------------------
        for (slot, tx) in nb_senders.iter().enumerate() {
            let _ = tx.send(Broadcast {
                from: id,
                t: t + 1,
                theta: theta.clone(),
                eta_to_receiver: etas[slot],
            });
        }
        collect(t + 1, &mut pending, &mut known, &mut eta_in);

        // ---- dual update with symmetrized penalties -----------------------
        for slot in 0..deg {
            let eta_bar = 0.5 * (etas[slot] + eta_in[slot]);
            for k in 0..dim {
                lambda[k] += 0.5 * eta_bar * (theta[k] - known[slot][k]);
            }
        }

        // ---- residuals ----------------------------------------------------
        let mut nbr_mean = vec![0.0; dim];
        for slot in 0..deg {
            for k in 0..dim {
                nbr_mean[k] += known[slot][k] / deg.max(1) as f64;
            }
        }
        let eta_bar_node = eta_sum / deg.max(1) as f64;
        let mut r2 = 0.0;
        let mut s2 = 0.0;
        for k in 0..dim {
            let r = theta[k] - nbr_mean[k];
            let s = eta_bar_node * (nbr_mean[k] - nbr_mean_prev[k]);
            r2 += r * r;
            s2 += s * s;
        }
        nbr_mean_prev = nbr_mean;

        // ---- objectives -----------------------------------------------------
        let f_self = solver.objective(&theta);
        let mut f_nb = vec![0.0; deg];
        if scheme.needs_neighbor_objectives() {
            let mut rho = vec![0.0; dim];
            for slot in 0..deg {
                for k in 0..dim {
                    rho[k] = 0.5 * (theta[k] + known[slot][k]);
                }
                f_nb[slot] = solver.objective(&rho);
            }
        }

        // ---- stats → leader; verdict ← leader ------------------------------
        let eta_min = etas.iter().copied().fold(f64::INFINITY, f64::min);
        let eta_max = etas.iter().copied().fold(0.0, f64::max);
        let _ = stats.send(StatsMsg {
            from: id,
            t,
            f_self,
            primal_norm: r2.sqrt(),
            dual_norm: s2.sqrt(),
            eta_min: if deg == 0 { 0.0 } else { eta_min },
            eta_max,
            eta_sum,
            eta_count: deg,
            theta: theta.clone(),
        });
        let verdict = match verdicts.recv() {
            Ok(v) => v,
            Err(_) => break,
        };
        debug_assert_eq!(verdict.t, t);
        if verdict.stop {
            break;
        }

        // ---- penalty-scheme update -----------------------------------------
        let obs = NodeObservation {
            t,
            primal_norm: r2.sqrt(),
            dual_norm: s2.sqrt(),
            global_primal: verdict.global_primal,
            global_dual: verdict.global_dual,
            f_self,
            f_self_prev,
            f_neighbors: &f_nb,
        };
        scheme.update(&obs, &mut etas);
        f_self_prev = f_self;
    }
    (id, theta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::consensus::solvers::QuadraticNode;
    use crate::graph::Topology;
    use crate::linalg::Mat;

    fn quad_factory(n: usize, dim: usize, seed: u64)
                    -> (SolverFactory<QuadraticNode>, Vec<f64>) {
        // materialize all node problems up-front so the central optimum is
        // computable; the factory clones per thread
        let mut rng = Pcg::seed(seed);
        let nodes: Vec<(Mat, Vec<f64>)> = (0..n)
            .map(|_| {
                let q = QuadraticNode::random(dim, &mut rng);
                (q.p, q.q)
            })
            .collect();
        let opt = QuadraticNode::central_optimum(
            &nodes
                .iter()
                .map(|(p, q)| QuadraticNode::new(p.clone(), q.clone()))
                .collect::<Vec<_>>(),
        );
        let nodes = Arc::new(nodes);
        let factory: SolverFactory<QuadraticNode> = Arc::new(move |i| {
            let (p, q) = nodes[i].clone();
            QuadraticNode::new(p, q)
        });
        (factory, opt)
    }

    #[test]
    fn threaded_matches_central_optimum() {
        for scheme in [SchemeKind::Fixed, SchemeKind::Ap, SchemeKind::Vp,
                       SchemeKind::Nap] {
            let (factory, opt) = quad_factory(6, 3, 17);
            let runner = ThreadedRunner::new(
                Topology::Complete.build(6).unwrap(),
                ThreadedConfig {
                    scheme,
                    tol: 1e-10,
                    max_iters: 500,
                    ..Default::default()
                },
            );
            let report = runner.run(factory, |_, _| 0.0).unwrap();
            for th in &report.thetas {
                assert_eq!(th.len(), 3);
                for (a, b) in th.iter().zip(&opt) {
                    assert!((a - b).abs() < 1e-3, "{scheme:?}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn threaded_is_deterministic() {
        let run = || {
            let (factory, _) = quad_factory(5, 2, 3);
            let runner = ThreadedRunner::new(
                Topology::Ring.build(5).unwrap(),
                ThreadedConfig { scheme: SchemeKind::VpAp, max_iters: 60, tol: 0.0,
                                 ..Default::default() },
            );
            runner.run(factory, |_, _| 0.0).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.thetas, b.thetas);
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.recorder.objective_curve(), b.recorder.objective_curve());
    }

    #[test]
    fn threaded_agrees_with_sequential_engine() {
        // same problem, same convergence point (inits differ, optimum
        // doesn't): consensus parameters must match to solver tolerance
        let (factory, opt) = quad_factory(6, 3, 29);
        let runner = ThreadedRunner::new(
            Topology::Cluster.build(6).unwrap(),
            ThreadedConfig { scheme: SchemeKind::Nap, tol: 1e-11, max_iters: 600,
                             ..Default::default() },
        );
        let threaded = runner.run(factory, |_, _| 0.0).unwrap();
        for th in &threaded.thetas {
            for (a, b) in th.iter().zip(&opt) {
                assert!((a - b).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn leader_records_every_iteration() {
        let (factory, _) = quad_factory(4, 2, 5);
        let runner = ThreadedRunner::new(
            Topology::Complete.build(4).unwrap(),
            ThreadedConfig { max_iters: 25, tol: 0.0, ..Default::default() },
        );
        let report = runner.run(factory, |t, _| t as f64).unwrap();
        assert_eq!(report.iterations, 25);
        assert_eq!(report.recorder.stats.len(), 25);
        assert!(!report.converged);
        assert_eq!(report.recorder.final_error(), 24.0);
    }
}
