//! The one shared control record of the sharded runtime.
//!
//! The thread-per-node design this module used to serve exchanged
//! heap-allocated `Broadcast` / `StatsMsg` values over mpsc channels —
//! both are gone: parameters travel through the zero-copy
//! [`crate::coordinator::ParamArena`] and statistics through per-shard
//! partial reductions (`shard::ShardPartial`). What remains is the
//! leader's per-iteration verdict, published once into a shared slot.

/// Leader verdict closing an iteration (written by the leader worker
/// between the post-stats and post-verdict barriers, read by every
/// worker after the latter).
#[derive(Debug, Clone, Copy)]
pub struct Verdict {
    pub t: usize,
    pub stop: bool,
    /// network-wide residuals (consumed only by the RB reference scheme)
    pub global_primal: f64,
    pub global_dual: f64,
}
