//! Wire messages between node actors and the leader.

use crate::graph::NodeId;

/// Neighbour broadcast: parameters plus the sender's penalty on the edge
/// toward the receiver (needed for the symmetrized dual step; one extra
/// scalar per message keeps the scheme fully decentralized).
#[derive(Debug, Clone)]
pub struct Broadcast {
    pub from: NodeId,
    pub t: usize,
    pub theta: Vec<f64>,
    /// η_{from→to} at iteration t
    pub eta_to_receiver: f64,
}

/// Per-iteration statistics a node reports to the leader.
#[derive(Debug, Clone)]
pub struct StatsMsg {
    pub from: NodeId,
    pub t: usize,
    pub f_self: f64,
    pub primal_norm: f64,
    pub dual_norm: f64,
    pub eta_min: f64,
    pub eta_max: f64,
    pub eta_sum: f64,
    pub eta_count: usize,
    /// current parameters (used by the leader's application metric)
    pub theta: Vec<f64>,
}

/// Leader verdict closing an iteration.
#[derive(Debug, Clone, Copy)]
pub struct Verdict {
    pub t: usize,
    pub stop: bool,
    /// network-wide residuals (consumed only by the RB reference scheme)
    pub global_primal: f64,
    pub global_dual: f64,
}
