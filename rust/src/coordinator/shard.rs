//! The per-worker shard program: phase A (solve) → barrier → phase B
//! (duals, residuals, objectives, partial reduction) → barrier → leader
//! fold → barrier → phase C (penalty-scheme update + η publish).
//!
//! See [`super`] (the coordinator module docs) for the full schedule and
//! the determinism argument. Everything here is crate-private; the public
//! surface is [`super::runner::ShardedRunner`].

use std::ops::Range;
use std::sync::Mutex;

use super::arena::ParamArena;
use super::arena::PhaseBarrier;
use super::messages::Verdict;
use super::runner::{ShardedConfig, SolverFactory};
use crate::consensus::LocalSolver;
use crate::graph::{Graph, NodeId};
use crate::metrics::{ConvergenceChecker, IterStats, Recorder, RunningFold,
                     StatPartial};
use crate::penalty::{make_scheme, NodeObservation, PenaltyScheme};
use crate::util::rng::Pcg;

/// Application-metric callback threaded into the leader worker.
pub(crate) type AppMetric<'m> = &'m mut (dyn FnMut(usize, &[Vec<f64>]) -> f64 + Send);

/// Why a worker stopped without a result.
#[derive(Debug)]
pub(crate) enum WorkerError {
    /// A peer poisoned the barrier (it panicked and reported separately).
    Poisoned,
    /// This worker's own body panicked (message extracted by the runner).
    Panicked(String),
}

/// Everything a worker borrows from the runner for the duration of a run.
pub(crate) struct WorkerCtx<'a> {
    /// The (possibly relabeled) graph the pool actually runs on.
    pub graph: &'a Graph,
    pub arena: &'a ParamArena,
    pub barrier: &'a PhaseBarrier,
    pub partials: &'a Mutex<Vec<ShardPartial>>,
    pub verdict: &'a Mutex<Verdict>,
    /// `order[shard_id] = original_id` — the relabeling permutation
    /// (identity when relabeling is off). Everything user-visible (solver
    /// factory, RNG streams, app-metric snapshots, reported θ) is keyed by
    /// original ids; everything pool-internal by shard ids.
    pub order: &'a [NodeId],
    pub cfg: ShardedConfig,
}

/// One shard's contribution to the leader fold, accumulated in sequential
/// node order within the shard so that combining shards in index order
/// reproduces a single-threaded sweep over `0..n`. Since the cluster
/// runtime ([`crate::cluster`]) ships the same statistics across the
/// simulated network, the type now lives in [`crate::metrics`] as
/// [`StatPartial`]; this alias keeps the coordinator's vocabulary.
pub(crate) type ShardPartial = StatPartial;

/// Leader-only state (worker 0): convergence tracking, the recorder, the
/// global-residual memory and the reusable θ snapshot for the app metric.
pub(crate) struct LeadState<'m> {
    checker: ConvergenceChecker,
    recorder: Recorder,
    global_mean_prev: Option<Vec<f64>>,
    fold: RunningFold,
    metric: Option<AppMetric<'m>>,
    snapshot: Vec<Vec<f64>>,
    iterations: usize,
    converged: bool,
}

impl<'m> LeadState<'m> {
    pub(crate) fn new(cfg: &ShardedConfig, metric: Option<AppMetric<'m>>) -> LeadState<'m> {
        LeadState {
            checker: ConvergenceChecker::new(cfg.tol)
                .with_patience(cfg.patience)
                .with_warmup(cfg.warmup),
            recorder: Recorder::with_capacity(cfg.max_iters),
            global_mean_prev: None,
            fold: RunningFold::new(0), // gmean sized lazily at first fold
            metric,
            snapshot: Vec::new(),
            iterations: 0,
            converged: false,
        }
    }
}

/// What the leader worker hands back to the runner.
pub(crate) struct LeadOutcome {
    pub iterations: usize,
    pub converged: bool,
    pub recorder: Recorder,
}

/// Per-node state owned by exactly one worker. θ itself lives only in the
/// arena (zero-copy); everything here is private to the node.
struct NodeState<S> {
    id: NodeId,
    solver: S,
    scheme: Box<dyn PenaltyScheme>,
    /// out-edge penalties η_{i→j}, neighbour-slot order (working copy;
    /// published to the arena at the end of each iteration)
    etas: Vec<f64>,
    lambda: Vec<f64>,
    nbr_mean_prev: Vec<f64>,
    /// flat η-arena index of the *incoming* penalty η_{j→i} per slot
    in_eta_idx: Vec<usize>,
    /// reused neighbour-objective buffer (AP/NAP schemes)
    f_nb: Vec<f64>,
    f_self_prev: f64,
    // carried from phase A/B to phase C within one iteration
    eta_sum: f64,
    f_self: f64,
    primal: f64,
    dual: f64,
}

/// Worker-local scratch, reused across nodes and iterations.
struct Scratch {
    eta_wsum: Vec<f64>,
    nbr_mean: Vec<f64>,
    rhos: Vec<Vec<f64>>,
}

/// The worker body. `widx` is the shard index; worker 0 carries the
/// leader state. Returns the leader outcome (worker 0) or `None`.
pub(crate) fn worker_main<S: LocalSolver>(
    ctx: &WorkerCtx<'_>,
    widx: usize,
    range: Range<usize>,
    factory: SolverFactory<S>,
    mut lead: Option<LeadState<'_>>,
) -> Result<Option<LeadOutcome>, WorkerError> {
    let cfg = ctx.cfg;
    let dim = ctx.arena.dim();

    // ---- construct solvers + per-node state; publish θ⁰ / η⁰ -------------
    // solver construction and θ⁰ seeding are keyed by *original* node id
    // so a relabeled run computes exactly the same per-node trajectories
    let mut nodes: Vec<NodeState<S>> = Vec::with_capacity(range.len());
    let mut max_deg = 0usize;
    for i in range {
        let orig = ctx.order[i];
        let mut solver = factory(orig);
        assert_eq!(solver.dim(), dim, "homogeneous dims");
        let deg = ctx.graph.degree(i);
        max_deg = max_deg.max(deg);
        let mut rng = Pcg::new(cfg.seed, orig as u64 + 1);
        let theta0 = solver.initial_param(&mut rng);
        assert_eq!(theta0.len(), dim);
        let etas = vec![cfg.params.eta0; deg];
        // Safety: we own node i; parity 0 is the pre-loop write buffer and
        // nobody reads it before the init barrier below.
        unsafe {
            ctx.arena.theta_mut(0, i).copy_from_slice(&theta0);
            ctx.arena.eta_out_mut(0, i).copy_from_slice(&etas);
        }
        let in_eta_idx = ctx
            .graph
            .neighbors(i)
            .iter()
            .map(|&j| {
                let slot = ctx.graph.edge_slot(j, i).expect("graph symmetry");
                ctx.arena.eta_index(j, slot)
            })
            .collect();
        nodes.push(NodeState {
            id: i,
            solver,
            scheme: make_scheme(cfg.scheme, cfg.params, deg),
            etas,
            lambda: vec![0.0; dim],
            nbr_mean_prev: vec![0.0; dim],
            in_eta_idx,
            f_nb: vec![0.0; deg],
            f_self_prev: f64::INFINITY,
            eta_sum: 0.0,
            f_self: 0.0,
            primal: 0.0,
            dual: 0.0,
        });
    }
    let mut scratch = Scratch {
        eta_wsum: vec![0.0; dim],
        nbr_mean: vec![0.0; dim],
        rhos: vec![vec![0.0; dim]; max_deg],
    };
    let mut partial = ShardPartial::new(dim);

    // everyone's θ⁰/η⁰ must be visible before the first solve
    ctx.barrier.wait().map_err(|_| WorkerError::Poisoned)?;

    for t in 0..cfg.max_iters {
        let p = t & 1; // read parity (epoch t)
        let q = p ^ 1; // write parity (epoch t+1)

        // ---- phase A: local solves on epoch-t parameters ------------------
        for st in &mut nodes {
            // Safety: phase A reads only parity-p θ (no writers this phase)
            // and writes only our own parity-q block.
            let theta_t = unsafe { ctx.arena.theta(p, st.id) };
            let mut eta_sum = 0.0;
            scratch.eta_wsum.iter_mut().for_each(|x| *x = 0.0);
            for (slot, &j) in ctx.graph.neighbors(st.id).iter().enumerate() {
                let e = st.etas[slot];
                eta_sum += e;
                let tj = unsafe { ctx.arena.theta(p, j) };
                for k in 0..dim {
                    scratch.eta_wsum[k] += e * (theta_t[k] + tj[k]);
                }
            }
            st.eta_sum = eta_sum;
            // Safety: we own st.id and parity-q is this phase's write
            // buffer; nobody reads it before the epoch-swap barrier, and
            // it aliases nothing the solver can see (θ^t lives in the
            // opposite-parity buffer). solve_into overwrites the block in
            // full, so stale θ^{t−1} contents are never observable.
            let theta_next = unsafe { ctx.arena.theta_mut(q, st.id) };
            st.solver.solve_into(theta_t, &st.lambda, eta_sum,
                                 &scratch.eta_wsum, theta_next);
        }
        ctx.barrier.wait().map_err(|_| WorkerError::Poisoned)?; // epoch swap

        // ---- phase B: duals, residuals, objectives, partial reduction -----
        partial.reset();
        for st in &mut nodes {
            let deg = ctx.graph.degree(st.id);
            // Safety: after the barrier every parity-q θ block is complete
            // and no worker writes θ until the next phase A; η parity-p is
            // stable until phase C writes parity-q.
            let th_new = unsafe { ctx.arena.theta(q, st.id) };

            // λ_i += ½ Σ_j η̄_ij (θ_i − θ_j), η̄ the edge-mean penalty
            for (slot, &j) in ctx.graph.neighbors(st.id).iter().enumerate() {
                let eta_in = unsafe { ctx.arena.eta(p, st.in_eta_idx[slot]) };
                let eta_bar = 0.5 * (st.etas[slot] + eta_in);
                let tj = unsafe { ctx.arena.theta(q, j) };
                for k in 0..dim {
                    st.lambda[k] += 0.5 * eta_bar * (th_new[k] - tj[k]);
                }
            }

            // local residuals (paper eq. 5)
            scratch.nbr_mean.iter_mut().for_each(|x| *x = 0.0);
            for &j in ctx.graph.neighbors(st.id) {
                let tj = unsafe { ctx.arena.theta(q, j) };
                for k in 0..dim {
                    scratch.nbr_mean[k] += tj[k];
                }
            }
            let inv_deg = 1.0 / deg.max(1) as f64;
            scratch.nbr_mean.iter_mut().for_each(|x| *x *= inv_deg);
            let eta_bar_node = st.eta_sum * inv_deg;
            let mut r2 = 0.0;
            let mut s2 = 0.0;
            for k in 0..dim {
                let r = th_new[k] - scratch.nbr_mean[k];
                let s = eta_bar_node * (scratch.nbr_mean[k] - st.nbr_mean_prev[k]);
                r2 += r * r;
                s2 += s * s;
            }
            st.nbr_mean_prev.copy_from_slice(&scratch.nbr_mean);
            st.primal = r2.sqrt();
            st.dual = s2.sqrt();

            // objectives (f at bridge midpoints only if the scheme asks)
            st.f_self = st.solver.objective(th_new);
            if st.scheme.needs_neighbor_objectives() {
                for (slot, &j) in ctx.graph.neighbors(st.id).iter().enumerate() {
                    let tj = unsafe { ctx.arena.theta(q, j) };
                    let rho = &mut scratch.rhos[slot];
                    for k in 0..dim {
                        rho[k] = 0.5 * (th_new[k] + tj[k]);
                    }
                }
                st.solver.objective_batch_into(&scratch.rhos[..deg], &mut st.f_nb);
            }

            // shard-local reduction, node order = sequential order
            partial.f_sum += st.f_self;
            partial.max_primal = partial.max_primal.max(st.primal);
            partial.max_dual = partial.max_dual.max(st.dual);
            for &e in &st.etas {
                partial.eta_min = partial.eta_min.min(e);
                partial.eta_max = partial.eta_max.max(e);
                partial.eta_sum += e;
            }
            partial.eta_count += deg;
            for k in 0..dim {
                partial.theta_sum[k] += th_new[k];
            }
        }
        // second shard-local pass over parity-q: spread about the *shard*
        // mean. Centering here (instead of folding raw Σ‖θ‖²) keeps the
        // leader's combined global residual accurate at any ‖θ‖ scale —
        // the subtraction a raw sum-of-squares needs cancels
        // catastrophically once ‖θ‖² ≫ spread.
        partial.node_count = nodes.len();
        if !nodes.is_empty() {
            let inv_count = 1.0 / nodes.len() as f64;
            for k in 0..dim {
                scratch.nbr_mean[k] = partial.theta_sum[k] * inv_count;
            }
            for st in &nodes {
                // Safety: parity-q θ is stable throughout phase B.
                let th = unsafe { ctx.arena.theta(q, st.id) };
                for k in 0..dim {
                    let d = th[k] - scratch.nbr_mean[k];
                    partial.centered_sq += d * d;
                }
            }
        }
        {
            let mut slots = ctx.partials.lock().unwrap_or_else(|e| e.into_inner());
            partial.store_into(&mut slots[widx]);
        }
        ctx.barrier.wait().map_err(|_| WorkerError::Poisoned)?; // stats ready

        // ---- leader fold (worker 0 only) ----------------------------------
        if let Some(lead) = lead.as_mut() {
            fold(ctx, lead, t, q);
        }
        ctx.barrier.wait().map_err(|_| WorkerError::Poisoned)?; // verdict ready

        let verdict = *ctx.verdict.lock().unwrap_or_else(|e| e.into_inner());
        debug_assert_eq!(verdict.t, t, "verdict tag mismatch");
        if verdict.stop {
            break;
        }

        // ---- phase C: penalty-scheme updates + publish η^{t+1} ------------
        for st in &mut nodes {
            let obs = NodeObservation {
                t,
                primal_norm: st.primal,
                dual_norm: st.dual,
                global_primal: verdict.global_primal,
                global_dual: verdict.global_dual,
                f_self: st.f_self,
                f_self_prev: st.f_self_prev,
                f_neighbors: &st.f_nb,
                live: None,
            };
            st.scheme.update(&obs, &mut st.etas);
            st.f_self_prev = st.f_self;
            // Safety: we own node st.id; parity-q η is the write buffer
            // until the next iteration's post-solve barrier.
            unsafe { ctx.arena.eta_out_mut(q, st.id) }.copy_from_slice(&st.etas);
        }
    }

    Ok(lead.map(|l| LeadOutcome {
        iterations: l.iterations,
        converged: l.converged,
        recorder: l.recorder,
    }))
}

/// The leader's fold: combine the W shard partials (in shard order),
/// derive global residuals from their sufficient statistics, run the app
/// metric + convergence check and publish the iteration verdict. Runs
/// between the post-stats and post-verdict barriers.
///
/// O(W·dim + dim) — the fold never touches per-node state. The global
/// primal residual `Σᵢ‖θᵢ − ḡ‖²` comes from the per-shard *centered*
/// statistics (n_s, Σθ, Σ‖θ − m_s‖²) combined in shard order with Chan
/// et al.'s pairwise update, which stays accurate at any ‖θ‖ scale (a
/// raw Σ‖θ‖² − n‖ḡ‖² subtraction loses all precision once ‖θ‖² ≫
/// spread). Only the on-demand app-metric snapshot still reads the
/// parity-`q` arena.
fn fold(ctx: &WorkerCtx<'_>, lead: &mut LeadState<'_>, t: usize, q: usize) {
    let n = ctx.graph.len();
    let dim = ctx.arena.dim();

    if lead.fold.gmean.len() != dim {
        lead.fold.gmean.resize(dim, 0.0);
    }
    lead.fold.reset();
    {
        let slots = ctx.partials.lock().unwrap_or_else(|e| e.into_inner());
        for part in slots.iter() {
            lead.fold.absorb(part);
        }
    }
    debug_assert_eq!(lead.fold.agg_n, n, "every node folded exactly once");
    let objective = lead.fold.objective;
    let gr2 = lead.fold.gr2.max(0.0);
    // like the Engine, the previous global mean starts at zero (so the
    // t = 0 dual is finite and the Rb trajectory matches the oracle)
    let gs2 = match &lead.global_mean_prev {
        Some(prev) => lead
            .fold
            .gmean
            .iter()
            .zip(prev)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>(),
        None => lead.fold.gmean.iter().map(|a| a * a).sum::<f64>(),
    };
    let global_dual = ctx.cfg.params.eta0 * (n as f64).sqrt() * gs2.sqrt();
    if let Some(prev) = lead.global_mean_prev.as_mut() {
        prev.copy_from_slice(&lead.fold.gmean);
    } else {
        lead.global_mean_prev = Some(lead.fold.gmean.clone());
    }

    // app metric: θ materialized (into a reused snapshot) only on demand,
    // indexed by *original* node id so relabeling stays invisible
    let app_error = match lead.metric.as_mut() {
        Some(metric) => {
            if lead.snapshot.len() != n {
                lead.snapshot = vec![vec![0.0; dim]; n];
            }
            // Safety: between the post-stats and post-verdict barriers no
            // worker writes parity-q θ.
            let all = unsafe { ctx.arena.theta_all(q) };
            for i in 0..n {
                lead.snapshot[ctx.order[i]]
                    .copy_from_slice(&all[i * dim..(i + 1) * dim]);
            }
            metric(t, &lead.snapshot)
        }
        None => 0.0,
    };

    lead.recorder.push(IterStats {
        iter: t,
        objective,
        max_primal: lead.fold.max_primal,
        max_dual: lead.fold.max_dual,
        mean_eta: lead.fold.mean_eta(),
        min_eta: lead.fold.min_eta(),
        max_eta: lead.fold.eta_max,
        app_error,
    });
    lead.iterations = t + 1;
    // Engine semantics: converged iff the checker fired, even when that
    // happens exactly on the final iteration
    let hit = lead.checker.update(objective);
    if hit {
        lead.converged = true;
    }
    let stop = hit || t + 1 == ctx.cfg.max_iters;
    *ctx.verdict.lock().unwrap_or_else(|e| e.into_inner()) = Verdict {
        t,
        stop,
        global_primal: gr2.sqrt(),
        global_dual,
    };
}
